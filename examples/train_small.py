"""Train a ~100M-param dense LM for a few hundred steps on CPU.

Exercises the full training substrate: config -> model -> synthetic data
pipeline -> AdamW + cosine schedule -> checkpointing. The same train_step
lowers onto the 256/512-chip meshes in the dry-run.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import ARCHS
from repro.models import CallOpts
from repro.training import (checkpoint, data as data_mod,
                            optimizer as opt_mod, steps)

STEPS = int(sys.argv[sys.argv.index("--steps") + 1]) \
    if "--steps" in sys.argv else 200

# ~100M params: olmo-family, 8 layers, d_model 768
cfg = dataclasses.replace(
    ARCHS["olmo-1b"], name="olmo-100m", num_layers=8, d_model=768,
    num_heads=12, num_kv_heads=12, head_dim=64, d_ff=3072, vocab_size=32768)
print(f"model: {cfg.name}  params~{cfg.param_count()/1e6:.0f}M")

adamw = opt_mod.AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=STEPS)
train_step = jax.jit(steps.make_train_step(cfg, adamw, CallOpts(remat=True)))
params = models.init_params(jax.random.PRNGKey(0), cfg)
opt_state = opt_mod.init_opt_state(params)
ds = data_mod.SyntheticLMData(cfg.vocab_size, seed=1)

t0 = time.time()
for step in range(STEPS):
    batch = {k: jnp.asarray(v) for k, v in ds.batch(step, 8, 256).items()}
    params, opt_state, m = train_step(params, opt_state, batch)
    if step % 20 == 0 or step == STEPS - 1:
        print(f"step {step:4d}  loss={float(m['loss']):.4f}  "
              f"lr={float(m['lr']):.2e}  gnorm={float(m['grad_norm']):.2f}  "
              f"({time.time()-t0:.0f}s)", flush=True)

checkpoint.save("results/olmo-100m.npz", {"params": params})
print("checkpoint written to results/olmo-100m.npz")
