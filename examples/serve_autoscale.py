"""End-to-end driver: REAL model serving with batched requests under
HAS-GPU resource control, plus the full simulated platform comparison.

Part 1 serves an actual (reduced) qwen2.5 through the Gateway -> PodEngine
-> libhas token handshake on CPU, demonstrating vertical scaling speeding
up a live pod. Part 2 replays an Azure-style trace through the cluster
simulator for HAS vs KServe-like vs FaST-GShare-like.

Run:  PYTHONPATH=src python examples/serve_autoscale.py
"""
import time

import numpy as np

from repro.configs import ARCHS, reduced
from repro.core import (ClusterSimulator, FaSTGShareLikePolicy, FnSpec,
                        HybridAutoScaler, KServeLikePolicy, Reconfigurator,
                        SimConfig)
from repro.core.scheduler import HASGPUScheduler
from repro.core.vgpu import PodAlloc, VirtualGPU
from repro.serving import Gateway, InferenceRequest, PodEngine
from repro.workloads import standard_workload

# ---------------------------------------------------------------- part 1
print("=== live serving (reduced qwen2.5, CPU) ===")
cfg = reduced(ARCHS["qwen2.5-3b"])
vgpu = VirtualGPU("GPU-demo", window_ms=50.0)
sched = HASGPUScheduler()
gw = Gateway()
pod = PodAlloc(fn_id="fn-qwen", sm=4, quota=0.3, batch=4)
vgpu.place(pod)
engine = PodEngine(cfg, pod, vgpu, sched, max_seq=64)
gw.register("fn-qwen", engine)

rng = np.random.default_rng(0)


def serve_n(n):
    t0 = time.monotonic()
    for _ in range(n):
        gw.route("fn-qwen", InferenceRequest(
            prompt=rng.integers(1, cfg.vocab_size, 8).astype(np.int32),
            max_new_tokens=4))
    done = []
    while len(done) < n:
        done.extend(gw.pump("fn-qwen"))
    return (time.monotonic() - t0) / n


lat_low = serve_n(8)
engine.set_quota(vgpu, 0.9)  # vertical scale-up: same pod, more tokens
lat_high = serve_n(8)
print(f"per-request wall time at q=0.3: {lat_low*1e3:.0f} ms, "
      f"after vertical scale-up to q=0.9: {lat_high*1e3:.0f} ms "
      f"({lat_low/max(lat_high,1e-9):.2f}x faster, no restart)")

# ---------------------------------------------------------------- part 2
print("\n=== platform comparison on an Azure-style trace ===")
spec = FnSpec(ARCHS["qwen2.5-3b"])
arr = standard_workload(duration_s=120.0, base_rps=25.0, seed=11)
print(f"trace: {len(arr)} requests / 120 s")
for name, Policy, whole in [("HAS-GPU", HybridAutoScaler, False),
                            ("KServe-like", KServeLikePolicy, True),
                            ("FaST-GShare-like", FaSTGShareLikePolicy, False)]:
    recon = Reconfigurator(num_gpus=0, max_gpus=32)
    pol = Policy(recon)
    pol.prewarm(spec, 25.0)
    res = ClusterSimulator(spec, pol, recon, arr,
                           SimConfig(duration_s=120.0,
                                     whole_gpu_cost=whole)).run()
    v = res.violations([1.5, 2.0, 2.5])
    print(f"{name:18s} cost/1k=${res.cost_per_1k:.4f}  "
          f"p95={res.pcts['p95']*1e3:6.0f} ms  "
          f"viol@1.5x/2x/2.5x = {v[1.5]:.3f}/{v[2.0]:.3f}/{v[2.5]:.3f}")
