"""Train RaPP and plug it into the autoscaler (paper's full control loop).

Generates a latency corpus over the assigned architectures, trains the
GAT-based RaPP predictor, reports MAPE vs the DIPPM-style static baseline,
then drives the hybrid autoscaler with the LEARNED predictor instead of
the oracle.

Run:  PYTHONPATH=src python examples/rapp_train.py
"""
import numpy as np

from repro.configs import ARCHS
from repro.core import FnSpec, HybridAutoScaler, Reconfigurator
from repro.core.rapp import RaPPConfig, RaPPModel
from repro.core.rapp import dataset as D, predictor as P, train as T

# --- dataset ---------------------------------------------------------------
corpus = [ARCHS[a] for a in ("olmo-1b", "qwen2.5-3b", "gemma-7b",
                             "mamba2-2.7b", "deepseek-moe-16b")]
ds = D.generate(corpus, batches=(1, 4, 16), samples_per_graph=16, seed=0)
tr, va, te = D.split(ds, holdout_archs=("deepseek-moe-16b",))
print(f"dataset: {len(ds)} samples -> {len(tr)}/{len(va)}/{len(te)}")

# --- train RaPP -------------------------------------------------------------
params = T.train(tr, va, cfg=T.TrainConfig(steps=800, log_every=200))
print(f"RaPP  val MAPE={T.evaluate(params, va):.2f}%  "
      f"test (incl. unseen arch) MAPE={T.evaluate(params, te):.2f}%")

# --- use the learned model inside the autoscaler ------------------------------
rapp = RaPPModel(params)
spec = FnSpec(ARCHS["qwen2.5-3b"])
recon = Reconfigurator(num_gpus=0, max_gpus=8)
scaler = HybridAutoScaler(recon, predictor=rapp)
scaler.prewarm(spec, expected_rps=20.0)
for t, rps in enumerate([20, 60, 120, 30]):
    acts = scaler.scale(float(t * 25), spec, float(rps))
    pods = recon.pods_of(spec.fn_id)
    print(f"R={rps:4.0f} rps -> pods={[(p.sm, round(p.quota, 2)) for p in pods]} "
          f"actions={[a.kind for a in acts]}")
print("RaPP-driven autoscaling complete; invariants:",
      recon.invariant_ok())
