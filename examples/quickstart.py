"""Quickstart: the HAS-GPU core in 60 seconds.

Builds a 2-GPU cluster, deploys a function with a fine-grained allocation,
scales it vertically at runtime (the paper's headline capability), runs the
Kalman-driven hybrid autoscaler against a demand jump, and prints the
resource trajectory.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import ARCHS
from repro.core import (FnSpec, HybridAutoScaler, Reconfigurator, latency,
                        throughput)

# --- a serverless inference function: qwen2.5-3b served at batch 8 --------
spec = FnSpec(ARCHS["qwen2.5-3b"])
print(f"function: {spec.fn_id}  "
      f"(latency on a whole chip: {latency(spec, 8, 8, 1.0)*1e3:.1f} ms)")

# --- cluster + autoscaler ---------------------------------------------------
recon = Reconfigurator(num_gpus=2, max_gpus=8)
scaler = HybridAutoScaler(recon)
scaler.prewarm(spec, expected_rps=20.0)
pods = recon.pods_of(spec.fn_id)
print(f"prewarmed: {[(p.sm, p.quota, p.batch) for p in pods]}")

# --- fine-grained vertical scaling at runtime --------------------------------
pod = pods[0]
gpu = recon.gpu_of_pod(pod.pod_id)
print(f"pod {pod.pod_id}: sm={pod.sm} quota={pod.quota:.2f} "
      f"thpt={throughput(spec, pod.batch, pod.sm, pod.quota):.1f} rps")
new_q = min(1.0, pod.quota + 0.3)
gpu.set_quota(pod.pod_id, new_q)  # runtime quota rewrite — no restart
print(f"vertical scale-up to q={new_q:.2f}: "
      f"thpt={throughput(spec, pod.batch, pod.sm, pod.quota):.1f} rps")

# --- hybrid autoscaling under a demand ramp ----------------------------------
print("\nt(s)  observed_rps  pods  alloc(GPU-fractions)  actions")
for t, rps in enumerate([20, 22, 30, 80, 160, 150, 40, 10, 8, 8]):
    actions = scaler.tick(float(t * 21), spec, float(rps))
    pods = recon.pods_of(spec.fn_id)
    alloc = sum(p.sm / 8 * p.quota for p in pods)
    acts = ";".join(f"{a.kind}" for a in actions) or "-"
    print(f"{t*21:4d}  {rps:12.0f}  {len(pods):4d}  {alloc:18.2f}  {acts}")

print(f"\ncluster GPUs in use: {len(recon.used_gpus())}, "
      f"invariants ok: {recon.invariant_ok()}")
