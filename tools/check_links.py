"""Intra-repo Markdown link checker (the docs CI gate).

Scans Markdown files for ``[text](target)`` links and verifies that
every RELATIVE target resolves to a real file (and, for ``#anchor``
fragments, that the target file actually contains a heading that
slugifies to the anchor). External links (http/https/mailto) are
ignored — this is a drift gate for the repo's own docs, not a network
crawler.

Usage::

    python tools/check_links.py [FILE_OR_DIR ...]

With no arguments, checks ``README.md`` and ``docs/*.md``. Exits
non-zero listing every broken link. Also invoked by
``tests/test_docs.py`` so the gate runs in tier-1, not only in CI.
"""
from __future__ import annotations

import pathlib
import re
import sys
from typing import List, Tuple

# [text](target) — excluding images' leading ! is unnecessary (image
# paths must resolve too); stop at the first closing paren without
# swallowing nested parens in titles
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def _slugify(heading: str) -> str:
    """GitHub-style anchor slug of a Markdown heading."""
    slug = heading.strip().lower()
    slug = re.sub(r"[^\w\- ]", "", slug)
    return slug.replace(" ", "-")


def _anchors(md_path: pathlib.Path) -> set:
    """The set of heading anchors a Markdown file defines."""
    out = set()
    for line in md_path.read_text(encoding="utf-8").splitlines():
        m = re.match(r"#{1,6}\s+(.*)", line)
        if m:
            out.add(_slugify(m.group(1)))
    return out


def check_file(md_path: pathlib.Path,
               repo_root: pathlib.Path) -> List[Tuple[str, str]]:
    """-> list of (link, reason) for every broken link in ``md_path``."""
    broken = []
    text = md_path.read_text(encoding="utf-8")
    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(_EXTERNAL):
            continue
        path_part, _, anchor = target.partition("#")
        if not path_part:                       # same-file #anchor
            if anchor and _slugify(anchor) not in _anchors(md_path):
                broken.append((target, "missing anchor"))
            continue
        resolved = (md_path.parent / path_part).resolve()
        try:
            resolved.relative_to(repo_root.resolve())
        except ValueError:
            broken.append((target, "escapes the repository"))
            continue
        if not resolved.exists():
            broken.append((target, "missing file"))
            continue
        if anchor and resolved.suffix == ".md" \
                and _slugify(anchor) not in _anchors(resolved):
            broken.append((target, "missing anchor"))
    return broken


def default_targets(repo_root: pathlib.Path) -> List[pathlib.Path]:
    """README.md plus every Markdown file under docs/."""
    targets = [repo_root / "README.md"]
    targets += sorted((repo_root / "docs").glob("*.md"))
    return [t for t in targets if t.exists()]


def run(paths=None, repo_root=None) -> List[str]:
    """Check ``paths`` (default: README + docs/) and return a list of
    human-readable failure strings (empty = all links resolve)."""
    repo_root = pathlib.Path(repo_root
                             or pathlib.Path(__file__).resolve().parents[1])
    if paths:
        targets = []
        for p in map(pathlib.Path, paths):
            targets += sorted(p.glob("*.md")) if p.is_dir() else [p]
    else:
        targets = default_targets(repo_root)
    failures = []
    for md in targets:
        for link, reason in check_file(md, repo_root):
            failures.append(f"{md.relative_to(repo_root)}: "
                            f"[{reason}] {link}")
    return failures


def main(argv=None) -> int:
    failures = run(argv if argv else None)
    for f in failures:
        print(f"BROKEN {f}", file=sys.stderr)
    if failures:
        print(f"{len(failures)} broken link(s)", file=sys.stderr)
        return 1
    print("all intra-repo links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
