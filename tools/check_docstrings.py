"""pydocstyle-lite: docstring presence gate for designated modules.

Not a style linter — a drift gate: every PUBLIC class, function, and
method (no leading underscore, not a dunder except ``__init__`` which
is exempt — its contract lives on the class) in the checked modules
must carry a non-trivial docstring. Dataclasses' implicit methods and
properties count like methods. The scope is deliberately small: the
modules whose public APIs the docs site describes.

Usage::

    python tools/check_docstrings.py [MODULE_PATH ...]

With no arguments, checks the default scope below. Exits non-zero
listing every undocumented public symbol. Also invoked by
``tests/test_docs.py`` so the gate runs in tier-1, not only in CI.
"""
from __future__ import annotations

import ast
import pathlib
import sys
from typing import List

# the modules whose public APIs must stay documented
DEFAULT_SCOPE = (
    "src/repro/core/capacity.py",
    "src/repro/core/events.py",
    "src/repro/core/modelstate.py",
    "src/repro/workloads/scenarios.py",
)
MIN_DOC_LEN = 10   # a docstring shorter than this is a placeholder


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def _has_docstring(node) -> bool:
    doc = ast.get_docstring(node)
    return doc is not None and len(doc.strip()) >= MIN_DOC_LEN


def _check_function(node, qualname: str, failures: List[str]) -> None:
    if not _is_public(node.name):
        return
    if not _has_docstring(node):
        failures.append(f"{qualname}.{node.name} (function)")


def _check_class(node, modname: str, failures: List[str]) -> None:
    if not _is_public(node.name):
        return
    qual = f"{modname}.{node.name}"
    if not _has_docstring(node):
        failures.append(f"{qual} (class)")
    for item in node.body:
        if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            _check_function(item, qual, failures)


def check_module(path: pathlib.Path) -> List[str]:
    """-> qualified names of undocumented public symbols in ``path``."""
    tree = ast.parse(path.read_text(encoding="utf-8"), filename=str(path))
    modname = path.stem
    failures: List[str] = []
    if not _has_docstring(tree):
        failures.append(f"{modname} (module)")
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            _check_function(node, modname, failures)
        elif isinstance(node, ast.ClassDef):
            _check_class(node, modname, failures)
    return failures


def run(paths=None, repo_root=None) -> List[str]:
    """Check ``paths`` (default scope when falsy); returns failures."""
    repo_root = pathlib.Path(repo_root
                             or pathlib.Path(__file__).resolve().parents[1])
    targets = [repo_root / p for p in (paths or DEFAULT_SCOPE)]
    failures = []
    for t in targets:
        failures += [f"{t.relative_to(repo_root)}: {f}"
                     for f in check_module(t)]
    return failures


def main(argv=None) -> int:
    failures = run(argv if argv else None)
    for f in failures:
        print(f"UNDOCUMENTED {f}", file=sys.stderr)
    if failures:
        print(f"{len(failures)} undocumented public symbol(s)",
              file=sys.stderr)
        return 1
    print("all public symbols documented")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
