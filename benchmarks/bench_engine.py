"""Wide-engine event throughput: the event-loop perf gate for PR 9/10.

Times the struct-of-arrays wide engine (``core/events.py``) against the
frozen scalar reference (``core/engine_scalar.py``) AND against itself
with the PR 10 batched decide path disabled (``batched_policy=False``,
the PR 9 baseline) on the azure_wide fleet shape — hundreds-to-
thousands of tenant functions, long-tail low-rate traces — and records
events/second, sweep-phase seconds, wall time, and peak traced memory
(tracemalloc, Python-heap peak), plus the streaming-vs-retain memory
comparison on the wide engine. ``--full`` additionally replays a
multi-day Azure-style trace (vectorized builders in
``workloads/azure.py``) at width 2000 through the wide engine alone —
the million-request replay regime the batched sweep targets.

JSON format (schema ``bench_engine/v2``)::

    {
      "schema": "bench_engine/v2",
      "smoke": false,
      "config": {"width": ..., "base_rps": ..., "duration_s": ...,
                 "max_gpus": ..., "seed": ...},
      "results": [
        {"name": "engine_wide", "events_per_s": ..., "n_events": ...,
         "seconds": ..., "peak_mb": ..., "sweep_seconds": ...,
         "n_sweeps": ..., "sweeps_per_s": ..., "fast_ticks": ...},
        {"name": "engine_nobatch", ...},   # batched decide path off
        {"name": "engine_scalar", ...},    # no sweep fields (no sweeps)
        {"name": "mem_stream_wide", "peak_mb": ..., "n_completed": ...},
        {"name": "mem_exact_wide", "peak_mb": ..., "n_completed": ...},
        {"name": "engine_wide_replay", ...}  # --full only
      ],
      "speedup": ...,        # engine_wide events/s over engine_scalar
      "sweep_speedup": ...   # nobatch sweep_seconds over wide ditto
    }

Entry names are stable identifiers; CI runs ``--smoke --check
benchmarks/ref_engine.json`` and fails when the wide engine is more
than ``--factor`` slower than the reference after normalizing by the
scalar engine's throughput on the same machine (the calibration entry,
mirroring ``bench_control_plane``), when the measured speedup falls
below ``--min-speedup`` (default 2.0 in smoke mode — small fleets leave
less O(N*G) work to hoist — and 10.0 at full size, the PR 9 acceptance
floor), or when the batched sweep's sweep-phase speedup over the
legacy loop falls below ``--min-sweep-speedup`` (default 2.0 smoke,
3.0 full — the PR 10 acceptance floor). ``--update-ref`` regenerates
the reference. All engine arms must process the identical event count
or the run fails outright: the bench doubles as a cheap parity
tripwire.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import tracemalloc

from repro.core import SimConfig
from repro.core.engine_scalar import ScalarEventEngine
from repro.core.multisim import MultiFunctionSimulator
from repro.workloads import azure
from repro.workloads.scenarios import get_scenario, make_policy

REF_PATH = "benchmarks/ref_engine.json"

# small enough for a CI runner, wide enough that the sweep/merged-stream
# machinery is what's being timed
SMOKE_CFG = dict(width=250, base_rps=4.0, duration_s=10.0, max_gpus=96,
                 seed=3)
# the acceptance configuration: fleet width where the scalar engine's
# per-tick O(cluster) rescans dominate (>=10x measured on this shape)
FULL_CFG = dict(width=1200, base_rps=5.0, duration_s=15.0, max_gpus=384,
                seed=3)
# the --full replay: two days of Azure-style long-tail traffic across
# 2000 tenants (~14M requests), streamed metrics, no timeline retention,
# 5s sweep cadence — wide engine only (the scalar reference would take
# hours). base_rps is PER-FUNCTION here, unlike the shapes above where
# the same value feeds every tenant's trace at azure_wide's burst mix.
REPLAY_CFG = dict(width=2000, base_rps=0.04, duration_s=172800.0,
                  max_gpus=640, seed=3)


def build_sim(width: int, base_rps: float, duration_s: float,
              max_gpus: int, seed: int, engine_cls=None,
              stream_metrics: bool = False, replay: bool = False,
              batched: bool = True) -> MultiFunctionSimulator:
    """An azure_wide-shaped simulator, built OUTSIDE the timed region
    (trace generation and prewarm placement are setup, not event-loop
    work). ``stream_metrics`` arms the constant-memory sink (wide
    engine only; the scalar reference predates it). ``replay`` swaps in
    the vectorized multi-day trace builders plus the replay-scale
    engine knobs (streamed metrics, no timeline retention, 5s sweeps).
    ``batched=False`` disables the PR 10 batched decide path (the
    legacy per-function sweep loop — the PR 9 baseline)."""
    sc = get_scenario("azure_wide").with_(width=width, max_gpus=max_gpus,
                                          sim_overrides=None)
    if replay:
        sc = sc.with_(trace=lambda d, r, s: azure.replay_workload(
            duration_s=d, base_rps=r, seed=s))
    specs = sc.fn_specs()
    recon = sc.make_recon(None)
    kw = {}
    if stream_metrics or replay:
        kw.update(stream_metrics=True,
                  stream_slo_multipliers=tuple(sc.slo_multipliers))
    if replay:
        kw.update(record_timeline=False, autoscale_interval_s=5.0)
    cfg = SimConfig(duration_s=duration_s, whole_gpu_cost=False, seed=seed,
                    batched_policy=batched, **kw)
    policies, arrs = {}, {}
    for i, spec in enumerate(specs):
        pol = make_policy("has", recon)
        pol.prewarm(spec, base_rps)
        policies[spec.fn_id] = pol
        arrs[spec.fn_id] = sc.arrivals_for(i, duration_s, base_rps, seed)
    ekw = {} if engine_cls is None else {"engine_cls": engine_cls}
    return MultiFunctionSimulator(specs, policies, recon, arrs, cfg, **ekw)


def _sweep_stats(engine) -> dict:
    """Sweep-phase counters (wide engines only — the scalar reference
    drives per-function timers, not sweeps)."""
    secs = getattr(engine, "sweep_seconds", None)
    if secs is None:
        return {}
    n = int(engine.n_sweeps)
    return {"sweep_seconds": secs, "n_sweeps": n,
            "sweeps_per_s": n / secs if secs > 0 else float("inf"),
            "fast_ticks": int(engine.fast_ticks)}


def _run_timed(cfg: dict, engine_cls=None, **build_kw) -> dict:
    """One timed engine run: events/s over the whole drain (the engines
    process identical event streams, so rates are comparable 1:1)."""
    sim = build_sim(**cfg, engine_cls=engine_cls, **build_kw)
    tracemalloc.start()
    t0 = time.perf_counter()
    sim.engine.run()
    dt = time.perf_counter() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    n = int(sim.engine.n_events)
    return {"events_per_s": n / dt if dt > 0 else float("inf"),
            "n_events": n, "seconds": dt, "peak_mb": peak / 1e6,
            **_sweep_stats(sim.engine)}


def _run_memory(cfg: dict, stream_metrics: bool) -> dict:
    """Peak traced memory of one wide-engine run with the streaming
    sink armed vs the retain-everything path (same events)."""
    sim = build_sim(**cfg, stream_metrics=stream_metrics)
    tracemalloc.start()
    sim.engine.run()
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    if stream_metrics:
        n_comp = int(sim.engine.stream_stats.n)
        retained = sum(len(st.completed) for st in sim.engine.fns.values())
        assert retained == 0, (
            f"stream-metrics run retained {retained} completions")
    else:
        n_comp = sum(len(st.completed) for st in sim.engine.fns.values())
    return {"peak_mb": peak / 1e6, "n_completed": n_comp}


def run(smoke: bool = False, replay: bool = False) -> dict:
    cfg = SMOKE_CFG if smoke else FULL_CFG
    results = []
    wide = _run_timed(cfg)
    nobatch = _run_timed(cfg, batched=False)
    scalar = _run_timed(cfg, engine_cls=ScalarEventEngine)
    counts = {"wide": wide["n_events"], "nobatch": nobatch["n_events"],
              "scalar": scalar["n_events"]}
    if len(set(counts.values())) != 1:
        raise AssertionError(
            f"engine event-count divergence: {counts} — the engine arms "
            f"no longer process the same event stream")
    results.append({"name": "engine_wide", **wide})
    results.append({"name": "engine_nobatch", **nobatch})
    results.append({"name": "engine_scalar", **scalar})
    results.append({"name": "mem_stream_wide",
                    **_run_memory(cfg, stream_metrics=True)})
    results.append({"name": "mem_exact_wide",
                    **_run_memory(cfg, stream_metrics=False)})
    report = {"schema": "bench_engine/v2", "smoke": smoke,
              "config": dict(cfg), "results": results,
              "speedup": wide["events_per_s"] / scalar["events_per_s"],
              "sweep_speedup": (nobatch["sweep_seconds"]
                                / max(wide["sweep_seconds"], 1e-12))}
    if replay:
        rep = _run_timed(REPLAY_CFG, replay=True)
        results.append({"name": "engine_wide_replay",
                        "config": dict(REPLAY_CFG), **rep})
    return report


CALIBRATION_ENTRY = "engine_scalar"


def check(report: dict, ref_path: str, factor: float,
          cal_factor: float = 10.0, min_speedup: float = 2.0,
          min_sweep_speedup: float = 2.0) -> int:
    """Fail on event-throughput regression vs the reference.

    Rates are normalized by each run's own scalar-engine throughput
    (same machine, same event stream), which cancels runner-speed
    offsets; the calibration entry itself gets the generous
    ``cal_factor`` gate (machine drift vs genuine shared-path
    regression). The measured wide-over-scalar speedup must also stay
    above ``min_speedup`` and the batched-over-legacy sweep-phase
    speedup above ``min_sweep_speedup`` — absolute floors the PRs'
    acceptance criteria pin, independent of any reference file."""
    with open(ref_path) as f:
        ref = json.load(f)
    if report.get("smoke") != ref.get("smoke"):
        print(f"reference {ref_path} was generated with smoke="
              f"{ref.get('smoke')} but this run used smoke="
              f"{report.get('smoke')}: regenerate the reference in the "
              f"matching mode (e.g. --smoke --update-ref)",
              file=sys.stderr)
        return 1
    if report.get("config") != ref.get("config"):
        print(f"config mismatch vs {ref_path}: ref={ref.get('config')} "
              f"run={report.get('config')}", file=sys.stderr)
        return 1
    ref_by = {r["name"]: r for r in ref["results"]}
    new_by = {r["name"]: r for r in report["results"]}
    failures = []
    ref_cal = ref_by[CALIBRATION_ENTRY]["events_per_s"]
    new_cal = new_by[CALIBRATION_ENTRY]["events_per_s"]
    cal_drift = ref_cal / max(new_cal, 1e-12)
    print(f"      {CALIBRATION_ENTRY:<16} {new_cal:>12,.0f} ev/s  "
          f"(calibration; {cal_drift:.2f}x slower than reference)")
    if cal_drift > cal_factor:
        failures.append(CALIBRATION_ENTRY)
    wide = new_by["engine_wide"]
    ref_rel = ref_by["engine_wide"]["events_per_s"] / ref_cal
    new_rel = wide["events_per_s"] / max(new_cal, 1e-12)
    slowdown = ref_rel / max(new_rel, 1e-12)
    status = "FAIL" if slowdown > factor else "ok"
    print(f"{status:>4}  {'engine_wide':<16} {wide['events_per_s']:>12,.0f}"
          f" ev/s  ({slowdown:.2f}x slower than reference, "
          f"machine-normalized)")
    if slowdown > factor:
        failures.append("engine_wide")
    sp = report["speedup"]
    status = "FAIL" if sp < min_speedup else "ok"
    print(f"{status:>4}  {'speedup':<16} {sp:>12.2f}x  "
          f"(floor {min_speedup:.1f}x)")
    if sp < min_speedup:
        failures.append("speedup")
    ssp = report.get("sweep_speedup", 0.0)
    status = "FAIL" if ssp < min_sweep_speedup else "ok"
    print(f"{status:>4}  {'sweep_speedup':<16} {ssp:>12.2f}x  "
          f"(floor {min_sweep_speedup:.1f}x)")
    if ssp < min_sweep_speedup:
        failures.append("sweep_speedup")
    if failures:
        print(f"regression vs {ref_path}: {failures}", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small fleet width for CI")
    ap.add_argument("--full", action="store_true",
                    help="also replay the multi-day Azure trace at "
                         "width 2000 (wide engine only; minutes of "
                         "wall time — the nightly lane)")
    ap.add_argument("--out", default="BENCH_engine.json")
    ap.add_argument("--check", metavar="REF",
                    help="fail on regression vs this reference")
    ap.add_argument("--factor", type=float, default=3.0)
    ap.add_argument("--cal-factor", type=float, default=10.0,
                    help="max tolerated slowdown of the scalar "
                         "calibration entry itself")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="wide-over-scalar events/s floor (default 2.0 "
                         "smoke, 10.0 full)")
    ap.add_argument("--min-sweep-speedup", type=float, default=None,
                    help="batched-over-legacy sweep-phase floor "
                         "(default 2.0 smoke, 3.0 full)")
    ap.add_argument("--update-ref", action="store_true",
                    help=f"also write the report to {REF_PATH}")
    args = ap.parse_args(argv)
    if args.smoke and args.full:
        ap.error("--smoke and --full are mutually exclusive")

    report = run(smoke=args.smoke, replay=args.full)
    for r in report["results"]:
        if "events_per_s" in r:
            sweep = (f", sweep {r['sweep_seconds']:.2f}s"
                     if "sweep_seconds" in r else "")
            print(f"{r['name']:<18} {r['events_per_s']:>12,.0f} events/s  "
                  f"({r['n_events']} events, {r['seconds']:.2f}s{sweep}, "
                  f"peak {r['peak_mb']:.1f} MB)")
        else:
            print(f"{r['name']:<18} peak {r['peak_mb']:>8.1f} MB  "
                  f"({r['n_completed']} completions)")
    print(f"speedup            {report['speedup']:>12.2f}x wide over scalar")
    print(f"sweep_speedup      {report['sweep_speedup']:>12.2f}x batched "
          f"over legacy loop")
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    if args.update_ref:
        with open(REF_PATH, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
        print(f"wrote {REF_PATH}")
    if args.check:
        floor = args.min_speedup
        if floor is None:
            floor = 2.0 if args.smoke else 10.0
        sweep_floor = args.min_sweep_speedup
        if sweep_floor is None:
            sweep_floor = 2.0 if args.smoke else 3.0
        return check(report, args.check, args.factor, args.cal_factor,
                     floor, sweep_floor)
    return 0


if __name__ == "__main__":
    sys.exit(main())
