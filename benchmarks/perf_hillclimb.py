"""§Perf hillclimb driver: baseline + optimized variants for the three
selected (arch x shape) pairs, each a hypothesis -> change -> measure
cycle recorded for EXPERIMENTS.md.

Pairs (from the baseline roofline table):
  1. gemma-7b x decode_32k      — worst memory (peak > HBM at baseline)
  2. llava-next-34b x train_4k  — most collective-bound
  3. deepseek-moe-16b x decode_32k — worst useful-compute ratio (and the
     paper's serving-step shape: most representative of its technique)

Run:  PYTHONPATH=src python -m benchmarks.perf_hillclimb
(sets the 512-device flag itself; run standalone, not under pytest)
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_config, get_shape  # noqa: E402
from repro.launch import hlo_analysis as ha  # noqa: E402
from repro.launch.dryrun import roofline_terms  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import build_case, call_opts, lower_case  # noqa: E402


def measure(mesh, arch, shape, opts=None, microbatches=None,
            fsdp_params=True):
    cfg, shp = get_config(arch), get_shape(shape)
    case = build_case(cfg, shp, mesh, opts=opts, microbatches=microbatches,
                      fsdp_params=fsdp_params)
    c = lower_case(case, mesh).compile()
    m = c.memory_analysis()
    a = ha.analyze(c.as_text(), case.scan_trip_hints)
    t = roofline_terms(a, mesh.devices.size)
    return {
        "peak_GiB": (m.argument_size_in_bytes + m.temp_size_in_bytes) / 2**30,
        "compute_s": t["compute_s"], "memory_s": t["memory_s"],
        "collective_s": t["collective_s"], "dominant": t["dominant"],
        "flops_per_dev": a.flops, "hbm_GB_per_dev": a.hbm_bytes / 1e9,
        "coll_GB_per_dev": a.collective_bytes / 1e9,
    }


def show(label, r, base=None):
    line = (f"{label:34s} peak={r['peak_GiB']:6.2f}GiB "
            f"compute={r['compute_s']:.3e} mem={r['memory_s']:.3e} "
            f"coll={r['collective_s']:.3e} [{r['dominant']}]")
    if base is not None:
        dom = base["dominant"]
        delta = (base[dom] - r[dom]) / base[dom] * 100
        line += f"  dominant-term delta: {delta:+.1f}%"
    print(line, flush=True)
    return r


def main(out_path="results/perf_hillclimb.json"):
    mesh = make_production_mesh()
    log = {}

    # ---- pair 1: gemma-7b x decode_32k (memory-bound, over-HBM peak) ----
    print("\n== pair 1: gemma-7b x decode_32k ==")
    arch, shape = "gemma-7b", "decode_32k"
    o0 = call_opts(get_config(arch), get_shape(shape), mesh)
    b = show("baseline (paper-faithful)", measure(mesh, arch, shape))
    r1 = show("+ fp8 KV cache", measure(
        mesh, arch, shape,
        dataclasses.replace(o0, cache_dtype="float8_e4m3fn")), b)
    r2 = show("+ fp8 + TP-only weights", measure(
        mesh, arch, shape,
        dataclasses.replace(o0, cache_dtype="float8_e4m3fn"),
        fsdp_params=False), b)
    log["gemma-7b x decode_32k"] = {"baseline": b, "fp8": r1,
                                    "fp8+tp_weights": r2}

    # ---- pair 2: llava-next-34b x train_4k (collective-bound) ----
    print("\n== pair 2: llava-next-34b x train_4k ==")
    arch, shape = "llava-next-34b", "train_4k"
    b = show("baseline (M=auto=16)", measure(mesh, arch, shape))
    r1 = show("microbatches=4 [REFUTED]", measure(mesh, arch, shape,
                                                  microbatches=4), b)
    o0 = call_opts(get_config(arch), get_shape(shape), mesh)
    r2 = show("seq-shard attention [REFUTED]", measure(
        mesh, arch, shape,
        dataclasses.replace(o0, attn_seq_shard=(("data",), "model"))), b)
    log["llava-next-34b x train_4k"] = {"baseline": b,
                                        "M4_refuted": r1,
                                        "seq_shard_refuted": r2}

    # ---- pair 3: deepseek-moe-16b x decode_32k (compute-waste) ----
    print("\n== pair 3: deepseek-moe-16b x decode_32k ==")
    arch, shape = "deepseek-moe-16b", "decode_32k"
    o0 = call_opts(get_config(arch), get_shape(shape), mesh)
    b = show("baseline (per-token groups)", measure(mesh, arch, shape))
    r1 = show("+ single routing group", measure(
        mesh, arch, shape,
        dataclasses.replace(o0, moe_single_group_decode=True)), b)
    r2 = show("+ single group + fp8 cache", measure(
        mesh, arch, shape,
        dataclasses.replace(o0, moe_single_group_decode=True,
                            cache_dtype="float8_e4m3fn")), b)
    r3 = show("+ sg + fp8 + TP-only weights", measure(
        mesh, arch, shape,
        dataclasses.replace(o0, moe_single_group_decode=True,
                            cache_dtype="float8_e4m3fn"),
        fsdp_params=False), b)
    log["deepseek-moe-16b x decode_32k"] = {
        "baseline": b, "single_group": r1, "sg+fp8": r2,
        "sg+fp8+tp_weights": r3}

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(log, f, indent=1)
    print(f"\nwritten to {out_path}")


if __name__ == "__main__":
    main()
