"""Fig 6 — SLO violation rates vs multiplier (1.0..10.0 step 0.25) for
HAS-GPU vs KServe-like vs FaST-GShare-like, plus P90/P95/P99 latencies.

Paper: HAS beats both at tight SLOs (1.5/2.0/2.5x); vs FaST-GShare the
average reduction is 4.8x; KServe shows strong P95/P99 tail from
whole-GPU horizontal scaling.

Also the scenario CLI: ``python -m benchmarks.fig6_slo_violations
--scenario flash_crowd`` runs any registered scenario end-to-end and
emits its ``RunMetrics`` JSON (stdout + results/metrics/). ``--fleet``
overrides the scenario's fleet: either ``type:count,...`` pairs from
``configs/gpus.py`` or the ``all_premium`` preset (the most expensive
registered type only) — e.g.

    python -m benchmarks.fig6_slo_violations --scenario het_mix
    python -m benchmarks.fig6_slo_violations --scenario het_mix \\
        --fleet all_premium

reproduces the mixed-vs-premium USD comparison, and ``--prewarm`` runs
any scenario under the model-state lifecycle engine with
forecast-driven pre-warming (``core/modelstate.py``) — e.g.

    python -m benchmarks.fig6_slo_violations --scenario flash_crowd \\
        --prewarm

shows strictly fewer cold starts and lower SLO violations than the
reactive policy on the same trace.
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np

from repro.configs import ARCHS
from repro.configs.gpus import GPU_TYPES
from repro.core import (ClusterSimulator, FnSpec, Reconfigurator, SimConfig,
                        TickClusterSimulator)
from repro.workloads import standard_workload
from repro.workloads.scenarios import (LIFECYCLE_PREWARM,
                                       POLICIES as POLICY_TABLE,
                                       get_scenario, make_policy,
                                       scenario_names)

TIGHT = (1.5, 2.0, 2.5)
POLICIES = tuple(POLICY_TABLE)  # has, kserve, fast — registry order
ENGINES = {"event": ClusterSimulator, "tick": TickClusterSimulator}
METRICS_DIR = "results/metrics"


def simulate(arch: str, policy: str, arr, base_rps: float, duration: float,
             seed: int = 1, engine: str = "event"):
    """Direct simulator construction — kept for the tick-parity path
    (the tick reference engine predates the scenario registry)."""
    spec = FnSpec(ARCHS[arch])
    recon = Reconfigurator(num_gpus=0, max_gpus=64)
    pol = make_policy(policy, recon)
    pol.prewarm(spec, base_rps)
    sim = ENGINES[engine](spec, pol, recon, arr,
                          SimConfig(duration_s=duration,
                                    whole_gpu_cost=POLICY_TABLE[policy][1],
                                    seed=seed))
    return sim.run()


def compare_engines(archs=("olmo-1b",), duration=180.0, base_rps=25.0,
                    out=sys.stdout, seed=0):
    """Run the fig6 grid on both engines: per-policy violation deltas at
    the tight multipliers plus the wall-clock speedup."""
    import time
    arr = standard_workload(duration, base_rps, seed=seed)
    walls = {}
    res = {}
    for engine in ("tick", "event"):
        t0 = time.perf_counter()
        for arch in archs:
            for pol in POLICIES:
                res[(engine, arch, pol)] = simulate(arch, pol, arr, base_rps,
                                                    duration, engine=engine)
        walls[engine] = time.perf_counter() - t0
    print("# tick-vs-event engine comparison", file=out)
    print("arch,policy,mult,viol_tick,viol_event", file=out)
    for arch in archs:
        for pol in POLICIES:
            vt = res[("tick", arch, pol)].violations(TIGHT)
            ve = res[("event", arch, pol)].violations(TIGHT)
            for m in TIGHT:
                print(f"{arch},{pol},{m},{vt[m]:.4f},{ve[m]:.4f}", file=out)
    speedup = walls["tick"] / max(walls["event"], 1e-9)
    print(f"tick_wall={walls['tick']:.2f}s event_wall={walls['event']:.2f}s "
          f"speedup={speedup:.1f}x", file=out)
    return speedup


def run(archs=("olmo-1b", "gemma-7b", "qwen2.5-3b"), duration=180.0,
        base_rps=25.0, out=sys.stdout, seed=0, scenario="azure_standard"):
    scen = get_scenario(scenario)
    metrics = {}
    for arch in archs:
        per_arch = scen.with_(archs=(arch,))
        for pol in POLICIES:
            metrics[(arch, pol)] = per_arch.run(
                policy=pol, seed=seed, duration_s=duration,
                base_rps=base_rps).metrics
    print(f"# Fig6 SLO violation rates ({scenario} workload)", file=out)
    print("arch,policy,p90_ms,p95_ms,p99_ms," +
          ",".join(f"viol@{m}x" for m in TIGHT), file=out)
    tight_ratio = []
    for arch in archs:
        for pol in POLICIES:
            m = metrics[(arch, pol)]
            lat, viol = m.latency_ms, m.slo_violation_rate
            print(f"{arch},{pol},{lat['p90']:.1f},{lat['p95']:.1f},"
                  f"{lat['p99']:.1f},"
                  + ",".join(f"{viol[str(x)]:.4f}" for x in TIGHT), file=out)
        vh = metrics[(arch, "has")].slo_violation_rate
        vf = metrics[(arch, "fast")].slo_violation_rate
        for x in TIGHT:
            if vh[str(x)] > 0:
                tight_ratio.append(vf[str(x)] / vh[str(x)])
            elif vf[str(x)] > 0:
                tight_ratio.append(10.0)  # HAS had zero violations
    avg_reduction = float(np.mean(tight_ratio)) if tight_ratio else 1.0
    mean_lat = float(np.mean(
        [metrics[(a, "has")].latency_ms["p50"] for a in archs])) * 1e3
    derived = f"fast_over_has_violation_ratio={avg_reduction:.2f}x(paper:4.8x)"
    return mean_lat, derived, metrics


def parse_fleet(text, scen):
    """``--fleet`` values: ``all_premium`` (one pool of the priciest
    registered type, sized to the scenario's total chip budget) or
    comma-separated ``type:count`` pairs."""
    if text is None:
        return None
    if text == "all_premium":
        premium = max((t for t in GPU_TYPES.values()),
                      key=lambda t: t.price_per_hour)
        budget = (sum(c for _, c in scen.fleet) if scen.fleet
                  else scen.max_gpus)
        return ((premium.name, budget),)
    fleet = []
    for part in text.split(","):
        name, _, count = part.partition(":")
        fleet.append((name.strip(), int(count or 8)))
    return tuple(fleet)


def run_scenario_cli(args) -> None:
    scen = get_scenario(args.scenario)
    policies = POLICIES if args.policy == "all" else (args.policy,)
    fleet = parse_fleet(args.fleet, scen)
    suffix = ("" if args.fleet is None else
              "__fleet_" + args.fleet.replace(":", "-").replace(",", "+"))
    if args.prewarm:
        # model-state lifecycle with forecast-driven pre-warming: derived
        # cold-start physics, host-RAM weight caching, keep-warm pods,
        # and Kalman-slope weight promotion (see core/modelstate.py)
        import dataclasses as _dc
        lc = scen.lifecycle or LIFECYCLE_PREWARM
        scen = scen.with_(lifecycle=_dc.replace(
            lc, prewarm_lead_s=LIFECYCLE_PREWARM.prewarm_lead_s))
    os.makedirs(args.out_dir, exist_ok=True)
    for pol in policies:
        m = scen.run(policy=pol, seed=args.seed,
                     duration_s=args.duration, fleet=fleet).metrics
        # baselines run the lifecycle physics but never the pre-warming
        # machinery (Scenario.run strips it) — only label what happened
        psuffix = suffix + ("__prewarm" if args.prewarm and pol == "has"
                            else "")
        path = os.path.join(
            args.out_dir,
            f"{scen.name}__{pol}__seed{args.seed}{psuffix}.json")
        with open(path, "w") as f:
            f.write(m.to_json())
        sys.stdout.write(m.to_json())
        print(f"wrote {path}", file=sys.stderr)
        if args.check:
            from repro.core.metrics import RunMetrics
            ref = RunMetrics.load(args.check)
            diffs = ref.diff(m)
            if diffs:
                print(f"{scen.name}/{pol} drifted from {args.check} "
                      f"({len(diffs)} fields):", file=sys.stderr)
                for d in diffs:
                    print(f"  {d}", file=sys.stderr)
                sys.exit(1)
            print(f"check OK: matches {args.check}", file=sys.stderr)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", help="run one registered scenario and "
                    "emit its RunMetrics JSON")
    ap.add_argument("--policy", default="has", choices=POLICIES + ("all",),
                    help="policy to run (with --scenario)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fleet", default=None,
                    help="fleet override (with --scenario): 'all_premium' "
                    "or 'type:count,type:count' (see configs/gpus.py)")
    ap.add_argument("--prewarm", action="store_true",
                    help="run under the model-state lifecycle engine with "
                    "forecast-driven pre-warming (core/modelstate.py): "
                    "derived cold-start physics, host-RAM weight cache, "
                    "keep-warm pods, Kalman-slope weight promotion")
    ap.add_argument("--duration", type=float, default=None,
                    help="override the horizon (seconds)")
    ap.add_argument("--out-dir", default=METRICS_DIR)
    ap.add_argument("--check", default=None, metavar="REF_JSON",
                    help="compare the run's RunMetrics against a "
                    "committed reference (RunMetrics.diff) and exit "
                    "non-zero on drift — CI's seeded chaos-smoke gate")
    ap.add_argument("--list-scenarios", action="store_true")
    ap.add_argument("--compare-tick", action="store_true")
    args = ap.parse_args(argv)
    if args.list_scenarios:
        for name in scenario_names():
            print(f"{name}: {get_scenario(name).description}")
    elif args.compare_tick:
        compare_engines(duration=args.duration or 180.0, seed=args.seed)
    elif args.scenario:
        run_scenario_cli(args)
    else:
        us, derived, _ = run(duration=args.duration or 180.0,
                             seed=args.seed)
        print(f"fig6_slo_violations,{us:.1f},{derived}")


if __name__ == "__main__":
    main()
