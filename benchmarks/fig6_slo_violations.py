"""Fig 6 — SLO violation rates vs multiplier (1.0..10.0 step 0.25) for
HAS-GPU vs KServe-like vs FaST-GShare-like, plus P90/P95/P99 latencies.

Paper: HAS beats both at tight SLOs (1.5/2.0/2.5x); vs FaST-GShare the
average reduction is 4.8x; KServe shows strong P95/P99 tail from
whole-GPU horizontal scaling.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.configs import ARCHS
from repro.core import (ClusterSimulator, FaSTGShareLikePolicy, FnSpec,
                        HybridAutoScaler, KServeLikePolicy, Reconfigurator,
                        SimConfig, TickClusterSimulator)
from repro.workloads import standard_workload

MULTIPLIERS = [round(1.0 + 0.25 * i, 2) for i in range(37)]
TIGHT = (1.5, 2.0, 2.5)
POLICIES = ("has", "kserve", "fast")
ENGINES = {"event": ClusterSimulator, "tick": TickClusterSimulator}


def simulate(arch: str, policy: str, arr, base_rps: float, duration: float,
             seed: int = 1, engine: str = "event"):
    spec = FnSpec(ARCHS[arch])
    recon = Reconfigurator(num_gpus=0, max_gpus=64)
    pol = {"has": HybridAutoScaler, "kserve": KServeLikePolicy,
           "fast": FaSTGShareLikePolicy}[policy](recon)
    pol.prewarm(spec, base_rps)
    sim = ENGINES[engine](spec, pol, recon, arr,
                          SimConfig(duration_s=duration,
                                    whole_gpu_cost=policy == "kserve",
                                    seed=seed))
    return sim.run()


def compare_engines(archs=("olmo-1b",), duration=180.0, base_rps=25.0,
                    out=sys.stdout, seed=0):
    """Run the fig6 grid on both engines: per-policy violation deltas at
    the tight multipliers plus the wall-clock speedup."""
    import time
    arr = standard_workload(duration, base_rps, seed=seed)
    walls = {}
    res = {}
    for engine in ("tick", "event"):
        t0 = time.perf_counter()
        for arch in archs:
            for pol in POLICIES:
                res[(engine, arch, pol)] = simulate(arch, pol, arr, base_rps,
                                                    duration, engine=engine)
        walls[engine] = time.perf_counter() - t0
    print("# tick-vs-event engine comparison", file=out)
    print("arch,policy,mult,viol_tick,viol_event", file=out)
    for arch in archs:
        for pol in POLICIES:
            vt = res[("tick", arch, pol)].violations(TIGHT)
            ve = res[("event", arch, pol)].violations(TIGHT)
            for m in TIGHT:
                print(f"{arch},{pol},{m},{vt[m]:.4f},{ve[m]:.4f}", file=out)
    speedup = walls["tick"] / max(walls["event"], 1e-9)
    print(f"tick_wall={walls['tick']:.2f}s event_wall={walls['event']:.2f}s "
          f"speedup={speedup:.1f}x", file=out)
    return speedup


def run(archs=("olmo-1b", "gemma-7b", "qwen2.5-3b"), duration=180.0,
        base_rps=25.0, out=sys.stdout, seed=0):
    results = {}
    for arch in archs:
        arr = standard_workload(duration, base_rps, seed=seed)
        for pol in POLICIES:
            res = simulate(arch, pol, arr, base_rps, duration)
            results[(arch, pol)] = res
    print("# Fig6 SLO violation rates (standard workload)", file=out)
    print("arch,policy,p90_ms,p95_ms,p99_ms," +
          ",".join(f"viol@{m}x" for m in TIGHT), file=out)
    tight_ratio = []
    for arch in archs:
        for pol in POLICIES:
            res = results[(arch, pol)]
            v = res.violations(MULTIPLIERS)
            print(f"{arch},{pol},{res.pcts['p90']*1e3:.1f},"
                  f"{res.pcts['p95']*1e3:.1f},{res.pcts['p99']*1e3:.1f},"
                  + ",".join(f"{v[m]:.4f}" for m in TIGHT), file=out)
        vh = results[(arch, "has")].violations(TIGHT)
        vf = results[(arch, "fast")].violations(TIGHT)
        for m in TIGHT:
            if vh[m] > 0:
                tight_ratio.append(vf[m] / vh[m])
            elif vf[m] > 0:
                tight_ratio.append(10.0)  # HAS had zero violations
    avg_reduction = float(np.mean(tight_ratio)) if tight_ratio else 1.0
    mean_lat = float(np.mean(
        [results[(a, "has")].pcts["p50"] for a in archs])) * 1e6
    derived = f"fast_over_has_violation_ratio={avg_reduction:.2f}x(paper:4.8x)"
    return mean_lat, derived, results


if __name__ == "__main__":
    if "--compare-tick" in sys.argv:
        compare_engines()
    else:
        us, derived, _ = run()
        print(f"fig6_slo_violations,{us:.1f},{derived}")
