"""Fig 5 — RaPP vs DIPPM-style static-only predictor: MAPE on validation
(seen archs, unseen configs) and test (incl. fully unseen archs).

Paper: RaPP ~5% MAPE, stable on unseen models; DIPPM degrades 10.1->17.7%.
"""
from __future__ import annotations

import sys
import time

from repro.core.rapp import dataset as D, predictor as P, train as T


def run(quick: bool = True, out=sys.stdout, seed: int = 0):
    import dataclasses as _dc

    import numpy as np

    from repro.core.rapp import features as F

    t0 = time.time()
    corpus = D.build_corpus(n_variants_per_arch=1 if quick else 2, seed=seed)
    batches = (1, 4, 16) if quick else D.BATCHES
    spg = 16 if quick else 30
    steps = 1200 if quick else 3000
    # generate ONE featurized dataset; the DIPPM (static-only) variant is
    # the same rows with runtime-feature columns zeroed
    ds_full = D.generate(corpus, batches=batches, samples_per_graph=spg,
                         seed=seed, with_runtime=True)
    nf = np.array(ds_full.node_feats)
    nf[:, :, F.NODE_STATIC_F:] = 0.0
    gf = np.array(ds_full.global_feats)
    gf[:, F.GLOBAL_STATIC_F:] = 0.0
    ds_static = _dc.replace(ds_full, node_feats=nf, global_feats=gf,
                            priors=np.zeros_like(ds_full.priors))
    results = {}
    for name, with_rt, ds in [("rapp", True, ds_full),
                              ("dippm", False, ds_static)]:
        tr, va, te = D.split(ds)
        params = T.train(
            tr, va, rapp_cfg=P.RaPPConfig(with_runtime=with_rt),
            cfg=T.TrainConfig(steps=steps, log_every=max(steps // 3, 1)),
            verbose=not quick)
        results[name] = {"val_mape": T.evaluate(params, va),
                         "test_mape": T.evaluate(params, te),
                         "n_train": len(tr), "n_test": len(te)}
        if name == "rapp":
            results["_rapp_params"] = params
    r, d = results["rapp"], results["dippm"]
    print(f"# Fig5 RaPP accuracy ({time.time()-t0:.0f}s, "
          f"{r['n_train']} train / {r['n_test']} test)", file=out)
    print("model,val_mape_pct,test_mape_pct", file=out)
    print(f"rapp,{r['val_mape']:.2f},{r['test_mape']:.2f}", file=out)
    print(f"dippm,{d['val_mape']:.2f},{d['test_mape']:.2f}", file=out)
    derived = (f"rapp_test={r['test_mape']:.1f}%;"
               f"dippm_test={d['test_mape']:.1f}%;"
               f"gap={d['test_mape']/max(r['test_mape'],1e-9):.2f}x")
    return r["test_mape"], derived, results


if __name__ == "__main__":
    quick = "--full" not in sys.argv
    mape, derived, _ = run(quick=quick)
    print(f"fig5_rapp_accuracy,{mape:.2f},{derived}")
