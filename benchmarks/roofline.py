"""Roofline analysis from the dry-run's compiled artifacts (deliverable g).

Reads results/dryrun/*.json (written by repro.launch.dryrun) and emits,
per (arch x shape x mesh): the three roofline terms in seconds, the
dominant term, MODEL_FLOPS (6ND / 6·N_active·D for training, 2·N_active·D
per generated/processed token for inference), the MODEL/HLO flops ratio
(usefulness of compiled compute), and a one-line improvement note.
"""
from __future__ import annotations

import glob
import json
import os
import sys

from repro.configs import ARCHS, SHAPES

CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(arch: str, shape: str) -> float:
    cfg = ARCHS[arch]
    shp = SHAPES[shape]
    n_active = cfg.active_param_count()
    if shp.kind == "train":
        return 6.0 * n_active * shp.global_batch * shp.seq_len
    if shp.kind == "prefill":
        return 2.0 * n_active * shp.global_batch * shp.seq_len
    return 2.0 * n_active * shp.global_batch  # decode: one token per seq


def improvement_note(rec: dict) -> str:
    dom = rec["roofline"]["dominant"]
    if dom == "memory_s":
        return ("reduce HBM traffic: fuse/keep activations resident, "
                "wider tiles, avoid f32 spills")
    if dom == "collective_s":
        return ("cut collective bytes: reshard weights (replicate small "
                "arrays), overlap all-gathers with compute")
    return "raise MXU utilization: larger per-device tiles / batch"


def load_records(path: str = "results/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def run(path: str = "results/dryrun", out=sys.stdout, mesh_filter=None):
    recs = load_records(path)
    if mesh_filter:
        recs = [r for r in recs if r["mesh"] == mesh_filter]
    if not recs:
        print("no dry-run records found; run repro.launch.dryrun --all",
              file=out)
        return 0.0, "no_records"
    print("# Roofline (per-device terms, TPU v5e: 197TF bf16, 819GB/s HBM, "
          "50GB/s ICI)", file=out)
    print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
          "model_flops,hlo_flops_total,useful_ratio,peak_GiB,note", file=out)
    n_dom = {"compute_s": 0, "memory_s": 0, "collective_s": 0}
    for r in recs:
        t = r["roofline"]
        mf = model_flops(r["arch"], r["shape"])
        hlo_total = r["hlo_analysis_per_device"]["flops"] * r["chips"]
        ratio = mf / hlo_total if hlo_total else float("nan")
        n_dom[t["dominant"]] += 1
        print(f"{r['arch']},{r['shape']},{r['mesh']},"
              f"{t['compute_s']:.3e},{t['memory_s']:.3e},"
              f"{t['collective_s']:.3e},{t['dominant']},"
              f"{mf:.3e},{hlo_total:.3e},{ratio:.3f},"
              f"{r['memory']['peak_bytes_per_device']/2**30:.2f},"
              f"\"{improvement_note(r)}\"", file=out)
    derived = (f"n={len(recs)};dominant:compute={n_dom['compute_s']}"
               f",memory={n_dom['memory_s']},coll={n_dom['collective_s']}")
    return float(len(recs)), derived


if __name__ == "__main__":
    n, derived = run()
    print(f"roofline,{n},{derived}")
