"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Detailed per-figure CSVs are
written to results/bench/. Pass --full for full-fidelity (slow) runs.
"""
from __future__ import annotations

import io
import os
import sys
import time


def main() -> None:
    full = "--full" in sys.argv
    os.makedirs("results/bench", exist_ok=True)
    rows = []

    from benchmarks import (ablation_kalman, fig4_latency_grid,
                            fig5_rapp_accuracy, fig6_slo_violations,
                            fig7_cost, multi_function, roofline)

    def record(name, fn, *a, **kw):
        buf = io.StringIO()
        t0 = time.time()
        out = fn(*a, out=buf, **kw)
        us, derived = out[0], out[1]
        with open(f"results/bench/{name}.csv", "w") as f:
            f.write(buf.getvalue())
        rows.append((name, us, derived))
        print(f"{name},{us:.2f},{derived}", flush=True)
        return out

    print("name,us_per_call,derived")
    record("fig4_latency_grid", fig4_latency_grid.run)
    record("fig5_rapp_accuracy", fig5_rapp_accuracy.run, quick=not full)
    record("fig6_slo_violations", fig6_slo_violations.run,
           duration=300.0 if full else 120.0)
    record("fig7_cost", fig7_cost.run,
           duration=300.0 if full else 120.0)
    record("multi_function", multi_function.run,
           duration=180.0 if full else 90.0)
    record("ablation_kalman", ablation_kalman.run)
    record("roofline", roofline.run)


if __name__ == "__main__":
    main()
