"""Control-plane decision throughput: the repo's perf trajectory anchor.

Times scaling decisions/second for the oracle and RaPP predictors along
both implementations — the reference scalar triple loop
(`perf_model.most_efficient_config`) and the lattice-backed
`CapacityTable` — plus full `HybridAutoScaler.scale` events at several
fleet sizes, and writes the results to ``BENCH_control_plane.json``.

JSON format (schema `bench_control_plane/v1`)::

    {
      "schema": "bench_control_plane/v1",
      "smoke": false,
      "results": [
        {"name": "mec_oracle_loop", "decisions_per_s": ..., "n": ...,
         "seconds_per_decision": ...},
        {"name": "scale_oracle_fleet64", "fleet_pods": 64, ...},
        ...
      ]
    }

Entry names are stable identifiers; CI runs ``--smoke --check
benchmarks/ref_control_plane.json`` and fails when any entry present in
both files is more than ``--factor`` (default 3x) slower than the
checked-in reference. ``--update-ref`` regenerates the reference.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.configs import ARCHS
from repro.core import perf_model
from repro.core.autoscaler import AutoScalerConfig, HybridAutoScaler
from repro.core.capacity import CapacityTable
from repro.core.perf_model import FnSpec
from repro.core.reconfigurator import Reconfigurator
from repro.core.vgpu import PodAlloc

REF_PATH = "benchmarks/ref_control_plane.json"
ARCH = "qwen2.5-3b"


def _timed(fn, iters: int, chunks: int = 3) -> dict:
    """Best-of-chunks rate: the minimum per-call time over `chunks`
    timing windows. On shared/bursty machines (CI runners, dev
    containers) the mean is dominated by scheduler noise — the best
    window is the stable estimator a regression gate can trust."""
    per = max(1, iters // chunks)
    best = float("inf")
    total = 0
    for _ in range(chunks):
        t0 = time.perf_counter()
        for _ in range(per):
            fn()
        dt = time.perf_counter() - t0
        total += per
        best = min(best, dt / per)
    return {"n": total, "seconds_per_decision": best,
            "decisions_per_s": 1.0 / best if best > 0 else float("inf")}


def bench_mec_oracle(spec: FnSpec, iters: int) -> list:
    """most_efficient_config: reference loop vs lattice table, warm."""
    targets = [0.5, 5.0, 50.0, 500.0]
    table = CapacityTable()
    table.most_efficient_config(spec, 1.0)  # warm the lattice
    perf_model.most_efficient_config(spec, 1.0)  # warm exec_time memo
    out = []
    for name, fn in [
        ("mec_oracle_loop",
         lambda: [perf_model.most_efficient_config(spec, t)
                  for t in targets]),
        ("mec_oracle_table",
         lambda: [table.most_efficient_config(spec, t) for t in targets]),
    ]:
        r = _timed(fn, iters)
        r["n"] *= len(targets)
        r["seconds_per_decision"] /= len(targets)
        r["decisions_per_s"] *= len(targets)
        out.append({"name": name, **r})
    return out


def bench_mec_rapp(spec: FnSpec, batches: tuple) -> list:
    """Cold RaPP config search: per-point jitted forwards (loop) vs one
    forward_batch vmap per (spec, batch) lattice (table)."""
    try:
        import jax
        from repro.core.rapp import predictor as P
    except Exception as e:  # pragma: no cover - jax-less environments
        print(f"# skipping RaPP entries (jax unavailable: {e})",
              file=sys.stderr)
        return []
    params = P.init_params(jax.random.PRNGKey(0))

    def cold_loop():
        model = P.RaPPModel(params)
        perf_model.most_efficient_config(spec, 20.0, predictor=model,
                                         batches=batches)

    def cold_table():
        model = P.RaPPModel(params)
        CapacityTable(predictor=model).most_efficient_config(
            spec, 20.0, batches=batches)

    cold_loop(), cold_table()  # jit-compile both paths outside the timing
    out = []
    for name, fn in [("mec_rapp_loop", cold_loop),
                     ("mec_rapp_table", cold_table)]:
        r = _timed(fn, 3)
        out.append({"name": name, "batches": list(batches), **r})
    return out


def bench_scale(spec: FnSpec, fleet_pods: int, iters: int) -> dict:
    """Full autoscale events against a standing fleet of `fleet_pods`
    pods: capacity read + Algorithm 1 up/down decisions."""
    recon = Reconfigurator(num_gpus=0, max_gpus=max(4, fleet_pods))
    scaler = HybridAutoScaler(recon, cfg=AutoScalerConfig(cooldown_s=0.0))
    for i in range(fleet_pods):
        sm = (1, 2, 4, 8)[i % 4]
        recon.place_pod(PodAlloc(fn_id=spec.fn_id, sm=sm, quota=0.5,
                                 batch=8))
    state = {"now": 0.0}

    def one_event():
        state["now"] += 1.0
        c = scaler.capacity(spec)
        # alternate above/below the triggers so up and down paths both run
        r = c * (1.15 if int(state["now"]) % 2 else 0.4)
        scaler.scale(state["now"], spec, r)

    one_event()  # warm lattices
    r = _timed(one_event, iters)
    return {"name": f"scale_oracle_fleet{fleet_pods}",
            "fleet_pods": fleet_pods, **r}


HET_FLEET = (("a10g", 24), ("a100", 8), ("h100", 4), ("t4", 16))


def bench_het(spec: FnSpec, iters: int) -> list:
    """Heterogeneous-mode entries (--het): the cross-type dollar-
    minimizing config search (`best_config_over` across 4 device
    classes, warm lattices) and first-fit-decreasing fleet packing of a
    64-pod request batch onto the mixed fleet."""
    from repro.configs.gpus import get_gpu_type
    from repro.core.scheduler import FleetPlacer

    table = CapacityTable()
    types = [get_gpu_type(n) for n, _ in HET_FLEET]
    targets = [0.5, 5.0, 50.0, 500.0]
    table.best_config_over(spec, 1.0, types)   # warm all type lattices
    out = []
    r = _timed(lambda: [table.best_config_over(spec, t, types)
                        for t in targets], iters)
    r["n"] *= len(targets)
    r["seconds_per_decision"] /= len(targets)
    r["decisions_per_s"] *= len(targets)
    out.append({"name": "mec_het_table", "gpu_types": [t.name
                                                       for t in types], **r})

    def pack_batch():
        recon = Reconfigurator(num_gpus=0, fleet=HET_FLEET)
        placer = FleetPlacer(recon, table, slo_multiplier=2.0)
        reqs = [(spec, PodAlloc(fn_id=spec.fn_id, sm=(1, 2, 4, 8)[i % 4],
                                quota=0.5, batch=8)) for i in range(64)]
        placed = placer.pack(reqs)
        assert all(g is not None for _, g in placed)
        return recon.fragmentation()

    frag = pack_batch()
    r = _timed(pack_batch, max(2, iters // 4))
    r["n"] *= 64
    r["seconds_per_decision"] /= 64
    r["decisions_per_s"] *= 64
    out.append({"name": "ffd_pack64_het", "pods": 64,
                "fragmentation": frag, **r})
    return out


def bench_reclaim(spec: FnSpec, iters: int) -> dict:
    """Reclaim-reaction latency on a hybrid on-demand/spot fleet: one
    full notice -> react -> kill -> recover cycle, i.e. the control
    plane's end-to-end cost of losing a spot chip — `mark_doomed`, the
    router's replacement scale tick (doomed pods contribute zero
    capacity, new placements avoid the doomed chip), `remove_gpu`, and
    the recovery tick that restores steady state."""
    from repro.configs.gpus import GPUMarket, spot

    market = GPUMarket(price_multiplier=0.3, reclaim_rate_per_hour=6.0,
                       grace_period_s=5.0)
    fleet = (("v5e", 8), (spot("v5e", market), 24))
    recon = Reconfigurator(num_gpus=0, fleet=fleet)
    scaler = HybridAutoScaler(recon, cfg=AutoScalerConfig(cooldown_s=0.0))
    state = {"now": 0.0}
    for _ in range(6):   # converge a standing hybrid fleet
        state["now"] += 1.0
        scaler.scale(state["now"], spec, 400.0)

    def one_cycle():
        state["now"] += 1.0
        now = state["now"]
        victim = next((g for g in recon.used_gpus()
                       if g.gpu_type.market is not None and not g.doomed),
                      None)
        if victim is not None:
            recon.mark_doomed(victim.uuid, kill_at=now + 5.0, now=now)
            scaler.scale(now, spec, 400.0)        # replacement decision
            recon.remove_gpu(victim.uuid, now=now)
        state["now"] += 1.0
        scaler.scale(state["now"], spec, 400.0)   # recovery tick

    one_cycle()
    r = _timed(one_cycle, iters)
    return {"name": "reclaim_react_hybrid",
            "fleet": [f"{get_type_name(t)}:{c}" for t, c in fleet], **r}


def bench_fault_react(spec: FnSpec, iters: int) -> dict:
    """Fault-reaction latency: one full quarantine -> backfill -> lift
    -> recover cycle, i.e. the control plane's end-to-end cost of a
    health-scorer trip (core/faults.py) — `set_quarantined` (the pod's
    capacity contribution drops to zero), the autoscaler's backfill
    tick, the quarantine lift, and the recovery tick that re-absorbs
    the benched capacity."""
    recon = Reconfigurator(num_gpus=0, max_gpus=16)
    scaler = HybridAutoScaler(recon, cfg=AutoScalerConfig(cooldown_s=0.0))
    state = {"now": 0.0}
    for _ in range(6):   # converge a standing fleet
        state["now"] += 1.0
        scaler.scale(state["now"], spec, 400.0)

    def one_cycle():
        state["now"] += 1.0
        now = state["now"]
        victim = next((p for p in recon.pods_of(spec.fn_id)
                       if not p.quarantined and not p.doomed), None)
        if victim is not None:
            recon.set_quarantined(victim.pod_id, True)
            scaler.scale(now, spec, 400.0)        # backfill decision
            recon.set_quarantined(victim.pod_id, False)
        state["now"] += 1.0
        scaler.scale(state["now"], spec, 400.0)   # recovery tick

    one_cycle()
    r = _timed(one_cycle, iters)
    return {"name": "fault_react", **r}


def get_type_name(t) -> str:
    """Fleet-entry display name (str entries or GPUType instances)."""
    return getattr(t, "name", t)


def run(smoke: bool = False, het: bool = False) -> dict:
    spec = FnSpec(ARCHS[ARCH])
    results = []
    results += bench_mec_oracle(spec, iters=5 if smoke else 25)
    results += bench_mec_rapp(spec, batches=(8,) if smoke else
                              (1, 2, 4, 8, 16, 32))
    for fleet in (8, 32) if smoke else (8, 64, 256):
        results.append(bench_scale(spec, fleet,
                                   iters=240 if smoke else 600))
    if het:
        results += bench_het(spec, iters=5 if smoke else 25)
        results.append(bench_reclaim(spec, iters=60 if smoke else 300))
    results.append(bench_fault_react(spec, iters=60 if smoke else 300))
    return {"schema": "bench_control_plane/v1", "smoke": smoke,
            "arch": ARCH, "results": results}


CALIBRATION_ENTRY = "mec_oracle_loop"


def check(report: dict, ref_path: str, factor: float,
          cal_factor: float = 10.0) -> int:
    """Fail on >factor decision-latency regression vs the reference.

    Rates are normalized by each run's own `mec_oracle_loop` throughput
    (pure numpy/python, so a stable proxy for raw machine speed): the
    comparison is "how much slower than the scalar loop on the SAME
    machine", which cancels the dev-machine-vs-CI-runner speed offset
    that an absolute decisions/s comparison would trip over. The
    calibration entry itself is therefore gated separately and more
    generously (`cal_factor`): machine speeds legitimately differ a few
    x, but a >cal_factor drop in the scalar loop means the shared
    scalar path regressed — and would otherwise silently inflate every
    normalized rate."""
    with open(ref_path) as f:
        ref = json.load(f)
    if report.get("smoke") != ref.get("smoke"):
        print(f"reference {ref_path} was generated with smoke="
              f"{ref.get('smoke')} but this run used smoke="
              f"{report.get('smoke')}: entries share names across modes "
              f"but time different workloads; regenerate the reference "
              f"in the matching mode (e.g. --smoke --update-ref)",
              file=sys.stderr)
        return 1
    ref_by_name = {r["name"]: r for r in ref["results"]}
    new_by_name = {r["name"]: r for r in report["results"]}
    ref_cal = ref_by_name[CALIBRATION_ENTRY]["decisions_per_s"]
    new_cal = new_by_name[CALIBRATION_ENTRY]["decisions_per_s"]
    failures = []
    cal_drift = ref_cal / max(new_cal, 1e-12)
    print(f"      {CALIBRATION_ENTRY:<24} {new_cal:>12.1f} dec/s  "
          f"(calibration; {cal_drift:.2f}x slower than reference)")
    if cal_drift > cal_factor:
        failures.append(CALIBRATION_ENTRY)
    for r in report["results"]:
        base = ref_by_name.get(r["name"])
        if base is None or r["name"] == CALIBRATION_ENTRY:
            continue
        mismatch = [k for k in ("batches", "fleet_pods", "gpu_types",
                                "pods", "fleet")
                    if base.get(k) != r.get(k)]
        if mismatch:
            print(f"FAIL  {r['name']:<24} parameter mismatch vs reference:"
                  f" {mismatch}", file=sys.stderr)
            failures.append(r["name"])
            continue
        ref_rel = base["decisions_per_s"] / ref_cal
        new_rel = r["decisions_per_s"] / max(new_cal, 1e-12)
        slowdown = ref_rel / max(new_rel, 1e-12)
        status = "FAIL" if slowdown > factor else "ok"
        print(f"{status:>4}  {r['name']:<24} {r['decisions_per_s']:>12.1f}"
              f" dec/s  ({slowdown:.2f}x slower than reference,"
              f" machine-normalized)")
        if slowdown > factor:
            failures.append(r["name"])
    if failures:
        print(f"regression >{factor}x vs {ref_path}: {failures}",
              file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small fleets/iteration counts for CI")
    ap.add_argument("--het", action="store_true",
                    help="add heterogeneous-fleet entries (cross-type "
                         "config search + FFD packing)")
    ap.add_argument("--out", default="BENCH_control_plane.json")
    ap.add_argument("--check", metavar="REF",
                    help="fail on >factor regression vs this reference")
    ap.add_argument("--factor", type=float, default=3.0)
    ap.add_argument("--cal-factor", type=float, default=10.0,
                    help="max tolerated slowdown of the calibration entry"
                         " itself (machine drift vs scalar-path"
                         " regression)")
    ap.add_argument("--update-ref", action="store_true",
                    help=f"also write the report to {REF_PATH}")
    args = ap.parse_args(argv)

    report = run(smoke=args.smoke, het=args.het)
    for r in report["results"]:
        print(f"{r['name']:<24} {r['decisions_per_s']:>12.1f} decisions/s"
              f"  ({r['seconds_per_decision']*1e3:.3f} ms/decision)")
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    if args.update_ref:
        with open(REF_PATH, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
        print(f"wrote {REF_PATH}")
    if args.check:
        return check(report, args.check, args.factor, args.cal_factor)
    return 0


if __name__ == "__main__":
    sys.exit(main())
