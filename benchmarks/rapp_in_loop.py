"""RaPP-in-the-loop: the autoscaler driven by the TRAINED GNN predictor
vs the roofline oracle — closing the paper's full control loop and
quantifying what prediction error costs at the platform level.

A fast RaPP is trained on a compact corpus, plugged into
HybridAutoScaler(predictor=...), and compared against the oracle-driven
scaler on the same trace.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.configs import ARCHS
from repro.core import (ClusterSimulator, FnSpec, HybridAutoScaler,
                        Reconfigurator, SimConfig)
from repro.core.rapp import RaPPModel, dataset as D, train as T
from repro.workloads import standard_workload


def run(duration=90.0, base_rps=20.0, out=sys.stdout, seed=0,
        train_steps=600):
    arch = "qwen2.5-3b"
    spec = FnSpec(ARCHS[arch])
    corpus = [ARCHS[a] for a in ("olmo-1b", "qwen2.5-3b", "gemma-7b")]
    ds = D.generate(corpus, batches=(1, 4, 8, 16), samples_per_graph=14,
                    seed=seed)
    tr, va, te = D.split(ds, holdout_archs=())
    params = T.train(tr, va, cfg=T.TrainConfig(steps=train_steps,
                                               log_every=10**9),
                     verbose=False)
    mape = T.evaluate(params, va)
    rapp = RaPPModel(params)

    arr = standard_workload(duration, base_rps, seed=seed + 3)
    print("# RaPP-in-the-loop vs oracle predictor", file=out)
    print("predictor,cost_per_1k,p95_ms,viol@2x", file=out)
    rows = {}
    for name, predictor in [("oracle", None), ("rapp", rapp)]:
        recon = Reconfigurator(num_gpus=0, max_gpus=48)
        scaler = HybridAutoScaler(recon, predictor=predictor)
        scaler.prewarm(spec, base_rps)
        res = ClusterSimulator(spec, scaler, recon, arr,
                               SimConfig(duration_s=duration,
                                         seed=seed)).run()
        v = res.violations([2.0])[2.0]
        print(f"{name},{res.cost_per_1k:.5f},{res.pcts['p95']*1e3:.1f},"
              f"{v:.4f}", file=out)
        rows[name] = (res.cost_per_1k, v)
    derived = (f"rapp_val_mape={mape:.1f}%;"
               f"oracle_viol@2x={rows['oracle'][1]:.3f};"
               f"rapp_viol@2x={rows['rapp'][1]:.3f};"
               f"cost_ratio={rows['rapp'][0]/max(rows['oracle'][0],1e-12):.2f}x")
    return rows["rapp"][0] * 1e6, derived


if __name__ == "__main__":
    us, derived = run()
    print(f"rapp_in_loop,{us:.2f},{derived}")
