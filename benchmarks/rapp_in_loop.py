"""RaPP-in-the-loop: the autoscaler driven by the TRAINED GNN predictor
vs the roofline oracle — closing the paper's full control loop and
quantifying what prediction error costs at the platform level.

A fast RaPP is trained on a compact corpus, plugged into
HybridAutoScaler(predictor=...), and compared against the oracle-driven
scaler on the same trace.

Trained weights are cached under results/cache/ keyed by every training
input (corpus, batches, samples, seed, steps, model config), so only
the first invocation pays the training cost — the paper trains RaPP
offline once and serves it online, and reruns of this benchmark are
about the control loop, not the optimizer. ``--retrain`` forces a
fresh train.
"""
from __future__ import annotations

import hashlib
import os
import sys
import time

import numpy as np

from repro.configs import ARCHS
from repro.core import (ClusterSimulator, FnSpec, HybridAutoScaler,
                        Reconfigurator, SimConfig)
from repro.core.rapp import RaPPConfig, RaPPModel, dataset as D, train as T
from repro.workloads import standard_workload

CORPUS = ("olmo-1b", "qwen2.5-3b", "gemma-7b")
BATCHES = (1, 4, 8, 16)
SAMPLES_PER_GRAPH = 14


def _train_rapp(seed: int, train_steps: int, retrain: bool,
                cache_dir: str = "results/cache"):
    """Train (or load) the benchmark's RaPP; returns (params, val MAPE)."""
    import jax
    tag = repr(("rapp_in_loop", CORPUS, BATCHES, SAMPLES_PER_GRAPH,
                D.SMS, D.QUOTAS, seed, train_steps, T.TrainConfig(),
                RaPPConfig()))
    key = hashlib.blake2s(tag.encode(), digest_size=10).hexdigest()
    path = os.path.join(cache_dir, f"rapp_{key}.npz")
    template = T.params_template(seed)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    if not retrain and os.path.exists(path):
        try:
            with np.load(path) as z:
                loaded = [z[f"arr_{i}"] for i in range(len(leaves))]
                mape = float(z["val_mape"])
            ok = all(a.shape == np.shape(b)
                     for a, b in zip(loaded, leaves))
        except Exception as e:  # truncated/corrupt npz: retrain
            print(f"# ignoring unreadable weight cache {path}: {e}",
                  file=sys.stderr)
            ok = False
        if ok:
            return jax.tree_util.tree_unflatten(treedef, loaded), mape
    corpus = [ARCHS[a] for a in CORPUS]
    ds = D.generate(corpus, batches=BATCHES,
                    samples_per_graph=SAMPLES_PER_GRAPH, seed=seed)
    tr, va, te = D.split(ds, holdout_archs=())
    params = T.train(tr, va, cfg=T.TrainConfig(steps=train_steps,
                                               log_every=10**9),
                     verbose=False)
    mape = T.evaluate(params, va)
    os.makedirs(cache_dir, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten(params)
    # temp-file + rename so an interrupted write never leaves a
    # truncated cache behind (the file handle keeps np.savez from
    # appending .npz to the temp name)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, *[np.asarray(x) for x in flat],
                 val_mape=np.float64(mape))
    os.replace(tmp, path)
    return params, mape


def run(duration=90.0, base_rps=20.0, out=sys.stdout, seed=0,
        train_steps=600, retrain=False):
    arch = "qwen2.5-3b"
    spec = FnSpec(ARCHS[arch])
    t_train = time.perf_counter()
    params, mape = _train_rapp(seed, train_steps, retrain)
    train_wall = time.perf_counter() - t_train
    rapp = RaPPModel(params)

    arr = standard_workload(duration, base_rps, seed=seed + 3)
    print("# RaPP-in-the-loop vs oracle predictor", file=out)
    print("predictor,cost_per_1k,p95_ms,viol@2x,sim_wall_s", file=out)
    rows = {}
    walls = {}
    for name, predictor in [("oracle", None), ("rapp", rapp)]:
        recon = Reconfigurator(num_gpus=0, max_gpus=48)
        scaler = HybridAutoScaler(recon, predictor=predictor)
        t0 = time.perf_counter()
        scaler.prewarm(spec, base_rps)
        res = ClusterSimulator(spec, scaler, recon, arr,
                               SimConfig(duration_s=duration,
                                         seed=seed)).run()
        walls[name] = time.perf_counter() - t0
        v = res.violations([2.0])[2.0]
        print(f"{name},{res.cost_per_1k:.5f},{res.pcts['p95']*1e3:.1f},"
              f"{v:.4f},{walls[name]:.2f}", file=out)
        rows[name] = (res.cost_per_1k, v)
    derived = (f"rapp_val_mape={mape:.1f}%;"
               f"oracle_viol@2x={rows['oracle'][1]:.3f};"
               f"rapp_viol@2x={rows['rapp'][1]:.3f};"
               f"cost_ratio={rows['rapp'][0]/max(rows['oracle'][0],1e-12):.2f}x;"
               f"train_wall_s={train_wall:.2f};"
               f"rapp_sim_wall_s={walls['rapp']:.2f}")
    return rows["rapp"][0] * 1e6, derived


if __name__ == "__main__":
    us, derived = run(retrain="--retrain" in sys.argv)
    print(f"rapp_in_loop,{us:.2f},{derived}")
