"""Ablation: Kalman-filter workload prediction vs last-value prediction
(paper §3.3 decouples the predictor precisely so this swap is possible).
"""
from __future__ import annotations

import sys

from repro.configs import ARCHS
from repro.core import (ClusterSimulator, FnSpec, HybridAutoScaler,
                        KalmanPredictor, LastValuePredictor, Reconfigurator,
                        SimConfig)
from repro.workloads import standard_workload, stress_workload


def run(duration=120.0, base_rps=30.0, out=sys.stdout, seed=0):
    spec = FnSpec(ARCHS["qwen2.5-3b"])
    print("# Kalman vs last-value predictor", file=out)
    print("workload,predictor,cost_per_1k,p95_ms,viol@2x", file=out)
    rows = {}
    for wname, arr in [("standard", standard_workload(duration, base_rps,
                                                      seed=seed)),
                       ("stress", stress_workload(duration, base_rps,
                                                  seed=seed))]:
        for name, kls in [("kalman", KalmanPredictor),
                          ("last_value", LastValuePredictor)]:
            recon = Reconfigurator(num_gpus=0, max_gpus=64)
            scaler = HybridAutoScaler(recon)
            scaler.kalman[spec.fn_id] = kls()  # decoupled predictor swap
            scaler.prewarm(spec, base_rps)
            res = ClusterSimulator(spec, scaler, recon, arr,
                                   SimConfig(duration_s=duration,
                                             seed=seed)).run()
            v = res.violations([2.0])[2.0]
            print(f"{wname},{name},{res.cost_per_1k:.5f},"
                  f"{res.pcts['p95']*1e3:.1f},{v:.4f}", file=out)
            rows[(wname, name)] = (res.cost_per_1k, v)
    derived = (f"std:kalman_cost={rows[('standard','kalman')][0]:.4f}"
               f"_vs_lv={rows[('standard','last_value')][0]:.4f};"
               f"stress:kalman_viol={rows[('stress','kalman')][1]:.3f}"
               f"_vs_lv={rows[('stress','last_value')][1]:.3f}")
    return rows[("standard", "kalman")][0] * 1e6, derived


if __name__ == "__main__":
    us, derived = run()
    print(f"ablation_kalman,{us:.2f},{derived}")
