"""Ablation: Kalman-filter workload prediction vs last-value prediction
(paper §3.3 decouples the predictor precisely so this swap is possible).

Runs through the scenario engine with a custom policy factory that
installs the alternative predictor — the registered azure scenarios and
the unified ``RunMetrics`` record do the rest.
"""
from __future__ import annotations

import sys

from repro.configs import ARCHS
from repro.core import (FnSpec, HybridAutoScaler, KalmanPredictor,
                        LastValuePredictor)
from repro.workloads.scenarios import get_scenario

ARCH = "qwen2.5-3b"


def _factory(predictor_cls):
    """Policy factory installing ``predictor_cls`` as the workload
    predictor (the decoupled swap the paper's §3.3 design allows)."""
    fn_id = FnSpec(ARCHS[ARCH]).fn_id

    def make(policy_name, recon):
        if policy_name != "has":  # the predictor swap is HAS-specific
            raise ValueError(f"predictor ablation only supports the 'has' "
                             f"policy, got {policy_name!r}")
        scaler = HybridAutoScaler(recon)
        scaler.kalman[fn_id] = predictor_cls()
        return scaler

    return make


def run(duration=120.0, base_rps=30.0, out=sys.stdout, seed=0):
    print("# Kalman vs last-value predictor", file=out)
    print("workload,predictor,cost_per_1k,p95_ms,viol@2x", file=out)
    rows = {}
    for wname, scen_name in [("standard", "azure_standard"),
                             ("stress", "azure_stress")]:
        scen = get_scenario(scen_name).with_(archs=(ARCH,))
        for name, kls in [("kalman", KalmanPredictor),
                          ("last_value", LastValuePredictor)]:
            m = scen.run(policy="has", seed=seed, duration_s=duration,
                         base_rps=base_rps,
                         policy_factory=_factory(kls)).metrics
            v = m.slo_violation_rate["2.0"]
            print(f"{wname},{name},{m.cost_per_1k_usd:.5f},"
                  f"{m.latency_ms['p95']:.1f},{v:.4f}", file=out)
            rows[(wname, name)] = (m.cost_per_1k_usd, v)
    derived = (f"std:kalman_cost={rows[('standard','kalman')][0]:.4f}"
               f"_vs_lv={rows[('standard','last_value')][0]:.4f};"
               f"stress:kalman_viol={rows[('stress','kalman')][1]:.3f}"
               f"_vs_lv={rows[('stress','last_value')][1]:.3f}")
    return rows[("standard", "kalman")][0] * 1e6, derived


if __name__ == "__main__":
    us, derived = run()
    print(f"ablation_kalman,{us:.2f},{derived}")
