"""Multi-function co-location benchmark (paper §4 cluster setting):
six functions (spanning dense/MoE/SSM/audio families) served
SIMULTANEOUSLY on one shared cluster, per platform.

Reports cluster-level cost per 1K requests, peak chips used, and the
tight-SLO violation average — co-location is where HGO placement and SM
alignment actually matter (functions must pack). Runs through the
scenario engine: the registered ``colocated_mix`` scenario widened to
the full six-architecture fleet.
"""
from __future__ import annotations

import sys

from repro.workloads.scenarios import POLICIES as POLICY_TABLE, get_scenario

FNS = ("olmo-1b", "qwen2.5-3b", "gemma-7b", "mamba2-2.7b",
       "whisper-medium", "deepseek-moe-16b")
TIGHT = (1.5, 2.0, 2.5)
POLICIES = tuple(POLICY_TABLE)


def run(duration=120.0, base_rps=15.0, out=sys.stdout, seed=0):
    scen = get_scenario("colocated_mix").with_(archs=FNS, max_gpus=96,
                                               slo_multipliers=TIGHT)
    print("# Multi-function co-location (6 fns, shared cluster)", file=out)
    print("policy,cluster_cost_per_1k,peak_gpus,cold_starts,"
          + ",".join(f"viol@{m}x" for m in TIGHT), file=out)
    summary = {}
    for pname in POLICIES:
        m = scen.run(policy=pname, seed=seed, duration_s=duration,
                     base_rps=base_rps).metrics
        viol = m.slo_violation_rate
        print(f"{pname},{m.cost_per_1k_usd:.5f},{m.peak_gpus},"
              f"{m.cold_starts},"
              + ",".join(f"{viol[str(x)]:.4f}" for x in TIGHT), file=out)
        summary[pname] = m
    rk = summary["kserve"].cost_per_1k_usd / max(
        summary["has"].cost_per_1k_usd, 1e-12)
    rf = summary["fast"].cost_per_1k_usd / max(
        summary["has"].cost_per_1k_usd, 1e-12)
    derived = (f"colocated:kserve_over_has={rk:.2f}x;fast_over_has={rf:.2f}x;"
               f"has_peak_gpus={summary['has'].peak_gpus};"
               f"kserve_peak_gpus={summary['kserve'].peak_gpus}")
    return summary["has"].cost_per_1k_usd * 1e3, derived


if __name__ == "__main__":
    us, derived = run()
    print(f"multi_function,{us:.3f},{derived}")
