"""Multi-function co-location benchmark (paper §4 cluster setting):
six functions (spanning dense/MoE/SSM/audio families) served
SIMULTANEOUSLY on one shared cluster, per platform.

Reports cluster-level cost per 1K requests, peak chips used, and the
tight-SLO violation average — co-location is where HGO placement and SM
alignment actually matter (functions must pack).
"""
from __future__ import annotations

import sys

import numpy as np

from repro.configs import ARCHS
from repro.core import (FaSTGShareLikePolicy, FnSpec, HybridAutoScaler,
                        KServeLikePolicy, Reconfigurator, SimConfig)
from repro.core.multisim import MultiFunctionSimulator
from repro.workloads import standard_workload

FNS = ("olmo-1b", "qwen2.5-3b", "gemma-7b", "mamba2-2.7b",
       "whisper-medium", "deepseek-moe-16b")
TIGHT = (1.5, 2.0, 2.5)


def run(duration=120.0, base_rps=15.0, out=sys.stdout, seed=0):
    specs = [FnSpec(ARCHS[a]) for a in FNS]
    print("# Multi-function co-location (6 fns, shared cluster)", file=out)
    print("policy,cluster_cost_per_1k,peak_gpus,"
          + ",".join(f"avg_viol@{m}x" for m in TIGHT), file=out)
    summary = {}
    for pname, Policy, whole in [("has", HybridAutoScaler, False),
                                 ("kserve", KServeLikePolicy, True),
                                 ("fast", FaSTGShareLikePolicy, False)]:
        recon = Reconfigurator(num_gpus=0, max_gpus=96)
        policies, arrivals = {}, {}
        for i, spec in enumerate(specs):
            pol = Policy(recon)
            pol.prewarm(spec, base_rps)
            policies[spec.fn_id] = pol
            arrivals[spec.fn_id] = standard_workload(
                duration, base_rps, seed=seed + i * 7)
        sim = MultiFunctionSimulator(
            specs, policies, recon, arrivals,
            SimConfig(duration_s=duration, whole_gpu_cost=whole, seed=seed))
        res = sim.run()
        viols = {m: float(np.mean([r.violations([m])[m]
                                   for r in res.per_fn.values()]))
                 for m in TIGHT}
        print(f"{pname},{res.cluster_cost_per_1k:.5f},{res.peak_gpus},"
              + ",".join(f"{viols[m]:.4f}" for m in TIGHT), file=out)
        summary[pname] = (res.cluster_cost_per_1k, res.peak_gpus, viols)
    rk = summary["kserve"][0] / max(summary["has"][0], 1e-12)
    rf = summary["fast"][0] / max(summary["has"][0], 1e-12)
    derived = (f"colocated:kserve_over_has={rk:.2f}x;fast_over_has={rf:.2f}x;"
               f"has_peak_gpus={summary['has'][1]};"
               f"kserve_peak_gpus={summary['kserve'][1]}")
    return summary["has"][0] * 1e3, derived


if __name__ == "__main__":
    us, derived = run()
    print(f"multi_function,{us:.3f},{derived}")
