"""Measured-profile calibration of the physics against the real stack.

Drives the actual jitted prefill/decode dispatch path of the serving
engine (``repro.serving.PodEngine`` behind the libhas token handshake)
across a deterministic (arch, GPU type, batch, sm, quota) grid and
writes a versioned calibration table (schema ``profile_stack/v1``) with
per-point measured seconds, the analytic roofline prediction for the
same dispatch, and pinned sim-vs-measured relative-error percentiles.
See ``src/repro/profiling/`` for the harness and the consumers
(``CapacityTable(calibration=...)``, the RaPP dataset builder) and
``docs/architecture.md`` ("Calibrating the physics") for the flow.

Usage::

    python -m benchmarks.profile_stack                  # default grid
    python -m benchmarks.profile_stack --smoke          # tiny CI grid
    python -m benchmarks.profile_stack --smoke --check benchmarks/ref_profile_cpu.json
    python -m benchmarks.profile_stack --smoke --update-ref
    python -m benchmarks.profile_stack --kernels        # + Pallas-vs-ref

On CPU the measured numbers validate the plumbing (grid, schema,
determinism — the roofline models an accelerator, so absolute error is
large and expected); on a real accelerator the same command calibrates
the physics. ``--check`` gates schema/grid/analytic drift exactly and
measured-shape drift by a generous machine-normalized factor, mirroring
``bench_control_plane``.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.profiling import (GridSpec, check_report, profile_kernels,
                             run_profile)

REF_PATH = "benchmarks/ref_profile_cpu.json"

SMOKE_GRID = GridSpec(
    archs=("olmo-1b", "mamba2-2.7b"),
    gpu_types=("v5e",),
    batches=(1, 2),
    sms=(2, 4),
    quotas=(0.5, 1.0),
    seq=32, window_ms=20.0, warmup=1, iters=3, reduce=True)

FULL_GRID = GridSpec(
    archs=("olmo-1b", "qwen2.5-3b", "mamba2-2.7b", "deepseek-moe-16b"),
    gpu_types=("v5e", "t4"),
    batches=(1, 2, 4, 8),
    sms=(1, 2, 4, 8),
    quotas=(0.3, 0.5, 1.0),
    seq=64, window_ms=20.0, warmup=2, iters=5, reduce=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (matches the committed "
                         "reference table)")
    ap.add_argument("--archs", nargs="+", help="override grid archs")
    ap.add_argument("--gpu-types", nargs="+",
                    help="override grid device types")
    ap.add_argument("--batches", nargs="+", type=int)
    ap.add_argument("--sms", nargs="+", type=int)
    ap.add_argument("--quotas", nargs="+", type=float)
    ap.add_argument("--seq", type=int, help="KV-cache budget per point")
    ap.add_argument("--warmup", type=int)
    ap.add_argument("--iters", type=int)
    ap.add_argument("--full-configs", action="store_true",
                    help="profile the full (non-reduced) architectures "
                         "(accelerator-sized; not for CPU)")
    ap.add_argument("--kernels", action="store_true",
                    help="also time each Pallas kernel vs its "
                         "kernels/ref.py oracle")
    ap.add_argument("--out", default="PROFILE_stack.json")
    ap.add_argument("--check", metavar="REF",
                    help="fail on schema/grid/analytic drift or "
                         "measured-shape drift vs this reference table")
    ap.add_argument("--factor", type=float, default=10.0,
                    help="max tolerated machine-normalized measured "
                         "drift (generous: absolute machine speed is "
                         "already cancelled)")
    ap.add_argument("--update-ref", action="store_true",
                    help=f"also write the report to {REF_PATH}")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    grid = SMOKE_GRID if args.smoke else FULL_GRID
    overrides = {}
    for field, cast in (("archs", tuple), ("gpu_types", tuple),
                        ("batches", tuple), ("sms", tuple),
                        ("quotas", tuple), ("seq", int),
                        ("warmup", int), ("iters", int)):
        v = getattr(args, field)
        if v is not None:
            overrides[field] = cast(v)
    if args.full_configs:
        overrides["reduce"] = False
    if overrides:
        import dataclasses
        grid = dataclasses.replace(grid, **overrides)

    report = run_profile(grid, smoke=args.smoke, verbose=args.verbose)
    if args.kernels:
        report["kernels"] = profile_kernels(warmup=grid.warmup,
                                            iters=grid.iters)
        for k in report["kernels"]:
            print(f"kernel {k['name']:<18} {k['measured_s']*1e3:9.3f} ms"
                  f"  (ref {k['ref_s']*1e3:9.3f} ms, "
                  f"{k['ratio']:6.2f}x)")
    err = report["error"]
    print(f"{len(report['points'])} points on "
          f"{report['meta']['backend']} "
          f"({report['meta']['device_kind']})")
    for arch, e in sorted(err["per_arch"].items()):
        print(f"  {arch:<18} rel err p50 {e['p50']:10.2f}  "
              f"p95 {e['p95']:10.2f}  ({e['n']} points)")
    print(f"  {'overall':<18} rel err p50 {err['overall']['p50']:10.2f}  "
          f"p95 {err['overall']['p95']:10.2f}")
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    if args.update_ref:
        with open(REF_PATH, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
        print(f"wrote {REF_PATH}")
    if args.check:
        with open(args.check) as f:
            ref = json.load(f)
        failures = check_report(report, ref, factor=args.factor)
        for msg in failures:
            print(f"FAIL  {msg}", file=sys.stderr)
        if failures:
            print(f"calibration check failed vs {args.check} "
                  f"({len(failures)} failure(s))", file=sys.stderr)
            return 1
        print(f"calibration check ok vs {args.check}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
