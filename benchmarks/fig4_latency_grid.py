"""Fig 4 — inference latency under fine-grained (batch, SM, quota) grids.

Validates the paper's two saturation regimes on our roofline physics:
(a) with sufficient SMs, more quota reduces latency (vertical scaling
works); (b) at small batch, more SMs do not help (MXU underfeeding); and
(c) at large batch with few SMs, quota stops helping (compute-starved).
"""
from __future__ import annotations

import sys

import numpy as np

from repro.configs import ARCHS
from repro.core import FnSpec
from repro.core.perf_model import latency_lattice

GRID_BATCHES = (1, 4, 16, 32)
GRID_SM = (1, 2, 4, 8)
GRID_QUOTA = (0.2, 0.4, 0.6, 0.8, 1.0)


def run(arch: str = "gemma-7b", out=sys.stdout):
    spec = FnSpec(ARCHS[arch])
    rows = []
    print(f"# Fig4 latency grid: {arch} (ms)", file=out)
    print("batch,sm,quota,latency_ms", file=out)
    for b in GRID_BATCHES:
        # one vectorized roofline lattice per batch (bitwise-identical
        # to the scalar perf_model.latency loop it replaced)
        tab = latency_lattice(spec, b, np.asarray(GRID_SM),
                              np.asarray(GRID_QUOTA)) * 1e3
        for i, sm in enumerate(GRID_SM):
            for j, q in enumerate(GRID_QUOTA):
                lat = float(tab[i, j])
                rows.append((b, sm, q, lat))
                print(f"{b},{sm},{q},{lat:.3f}", file=out)

    # paper-claim checks
    lat_of = {(b, sm, q): l for b, sm, q, l in rows}
    # (a) quota monotonicity at full SM
    for b in GRID_BATCHES:
        ls = [lat_of[(b, 8, q)] for q in GRID_QUOTA]
        assert all(x >= y - 1e-9 for x, y in zip(ls, ls[1:])), \
            "quota increase must not slow down"
    # (b) small batch: SM 4->8 gives <15% improvement
    small_gain = lat_of[(1, 4, 1.0)] / lat_of[(1, 8, 1.0)]
    # (c) large batch, small SM: quota 0.8->1.0 gives <30% improvement
    starv_gain = lat_of[(32, 1, 0.8)] / lat_of[(32, 1, 1.0)]
    mean_lat = float(np.mean([r[3] for r in rows]))
    derived = (f"small_batch_sm_gain={small_gain:.3f};"
               f"sm_starved_quota_gain={starv_gain:.3f}")
    return mean_lat * 1e3, derived


if __name__ == "__main__":
    us, derived = run()
    print(f"fig4_latency_grid,{us:.1f},{derived}")
