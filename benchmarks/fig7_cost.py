"""Fig 7 — function cost per 1K requests under standard and stress
workloads, per platform (Google Cloud V100 $2.48/h accounting).

Paper: HAS-GPU averages 10.8x cheaper than KServe and 1.72x cheaper than
FaST-GShare (fine-grained platforms billed on fraction actually held;
KServe billed whole-GPU).
"""
from __future__ import annotations

import sys

import numpy as np

from repro.workloads import standard_workload, stress_workload
from benchmarks.fig6_slo_violations import simulate, POLICIES


def run(archs=("olmo-1b", "qwen2.5-3b", "gemma-7b", "mamba2-2.7b",
               "whisper-medium", "deepseek-moe-16b"),
        duration=180.0, out=sys.stdout, seed=0):
    workloads = {
        "standard": (standard_workload(duration, 25.0, seed=seed), 25.0),
        "stress": (stress_workload(duration, 50.0, seed=seed), 50.0),
    }
    print("# Fig7 cost per 1K requests (USD)", file=out)
    print("workload,arch," + ",".join(POLICIES), file=out)
    ratios_kserve, ratios_fast = [], []
    total_cost = 0.0
    for wname, (arr, base) in workloads.items():
        for arch in archs:
            costs = {}
            for pol in POLICIES:
                res = simulate(arch, pol, arr, base, duration)
                costs[pol] = res.cost_per_1k
            print(f"{wname},{arch}," +
                  ",".join(f"{costs[p]:.5f}" for p in POLICIES), file=out)
            if costs["has"] > 0:
                ratios_kserve.append(costs["kserve"] / costs["has"])
                ratios_fast.append(costs["fast"] / costs["has"])
            total_cost += costs["has"]
    rk = float(np.mean(ratios_kserve))
    rk_max = float(np.max(ratios_kserve))
    rf = float(np.mean(ratios_fast))
    derived = (f"kserve_over_has=avg{rk:.2f}x/max{rk_max:.2f}x"
               f"(paper:up-to-10.8x);fast_over_has={rf:.2f}x(paper:1.72x)")
    return total_cost * 1e3, derived


if __name__ == "__main__":
    us, derived = run()
    print(f"fig7_cost,{us:.2f},{derived}")
