"""Fig 7 — function cost per 1K requests under standard and stress
workloads, per platform (Google Cloud V100 $2.48/h accounting).

Paper: HAS-GPU averages 10.8x cheaper than KServe and 1.72x cheaper than
FaST-GShare (fine-grained platforms billed on fraction actually held;
KServe billed whole-GPU).
"""
from __future__ import annotations

import sys

import numpy as np

from repro.workloads.scenarios import get_scenario
from benchmarks.fig6_slo_violations import POLICIES


def run(archs=("olmo-1b", "qwen2.5-3b", "gemma-7b", "mamba2-2.7b",
               "whisper-medium", "deepseek-moe-16b"),
        duration=180.0, out=sys.stdout, seed=0):
    workloads = {
        "standard": (get_scenario("azure_standard"), 25.0),
        "stress": (get_scenario("azure_stress"), 50.0),
    }
    print("# Fig7 cost per 1K requests (USD)", file=out)
    print("workload,arch," + ",".join(POLICIES), file=out)
    ratios_kserve, ratios_fast = [], []
    total_cost = 0.0
    for wname, (scen, base) in workloads.items():
        for arch in archs:
            per_arch = scen.with_(archs=(arch,))
            costs = {}
            for pol in POLICIES:
                m = per_arch.run(policy=pol, seed=seed, duration_s=duration,
                                 base_rps=base).metrics
                costs[pol] = m.cost_per_1k_usd
            print(f"{wname},{arch}," +
                  ",".join(f"{costs[p]:.5f}" for p in POLICIES), file=out)
            if costs["has"] > 0:
                ratios_kserve.append(costs["kserve"] / costs["has"])
                ratios_fast.append(costs["fast"] / costs["has"])
            total_cost += costs["has"]
    rk = float(np.mean(ratios_kserve))
    rk_max = float(np.max(ratios_kserve))
    rf = float(np.mean(ratios_fast))
    derived = (f"kserve_over_has=avg{rk:.2f}x/max{rk_max:.2f}x"
               f"(paper:up-to-10.8x);fast_over_has={rf:.2f}x(paper:1.72x)")
    return total_cost * 1e3, derived


if __name__ == "__main__":
    us, derived = run()
    print(f"fig7_cost,{us:.2f},{derived}")
