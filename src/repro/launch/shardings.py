"""Sharding rules: params, optimizer state, batches, and KV/SSM caches.

Policy (single-pod mesh ("data", "model"); multi-pod adds a leading "pod"
axis used for batch/sequence only — weights are replicated across pods):

  * vocab/embedding rows, attention head projections, FFN hidden, MoE
    experts, SSD heads           -> "model"
  * batch                        -> ("pod","data") for training, "data"
                                    (or ("pod","data")) for serving
  * decode KV-cache sequence dim -> "model" (batch-heavy decode) or
                                    ("pod","data","model") (long-context,
                                    batch=1) — attention contractions over
                                    the sharded axis become all-reduces.

Every rule is divisibility-guarded: a dimension that does not divide the
axis size is left unsharded (e.g. mamba2's vocab 50280 on 16 devices).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_sizes

MODEL = "model"


def _fits(dim: int, mesh, axes) -> bool:
    if axes is None:
        return True
    sizes = axis_sizes(mesh)
    total = 1
    for a in ((axes,) if isinstance(axes, str) else axes):
        if a not in sizes:
            return False
        total *= sizes[a]
    return dim % total == 0


def _guard(spec_entries, shape, mesh):
    """Drop axis assignments that don't divide; pad to rank."""
    entries = list(spec_entries)
    entries = [None] * (len(shape) - len(entries)) + entries
    out = []
    for dim, ax in zip(shape, entries):
        out.append(ax if (ax is not None and _fits(dim, mesh, ax)) else None)
    return P(*out)


# ------------------------------------------------------------------ params
# 2D weight sharding: tensor-parallel dim -> "model", the other matrix dim
# -> "data" (FSDP/ZeRO-style). Optimizer moments follow their parameters,
# so even dbrx-132b's AdamW state fits 16 GiB/chip. XLA inserts the
# per-layer all-gathers (weight streaming) in the scan body.
FSDP = "data"

_PARAM_RULES = {
    # name -> spec template aligned to the LAST len(template) dims
    "embed": (MODEL, FSDP),
    "unembed": (FSDP, MODEL),
    "pos": (None, FSDP),
    "pos_dec": (None, FSDP),
    "pos_enc": (None, FSDP),
    "wq": (FSDP, MODEL), "wk": (FSDP, MODEL), "wv": (FSDP, MODEL),
    "bq": (MODEL,), "bk": (MODEL,), "bv": (MODEL,),
    "wo": (MODEL, FSDP),
    "w_gate": (FSDP, MODEL), "w_up": (FSDP, MODEL), "w_down": (MODEL, FSDP),
    "w_in": (FSDP, MODEL), "b_in": (MODEL,),
    "w_out": (MODEL, FSDP), "b_out": (None,),
    "router": (None, None),
    "in_proj": (FSDP, MODEL), "out_proj": (MODEL, FSDP),
    "conv_w": (None, MODEL), "conv_b": (MODEL,),
    "A_log": (MODEL,), "dt_bias": (MODEL,), "D": (MODEL,),
    "norm_scale": (MODEL,),
    "scale": (None,), "bias": (None,),
    "visual_scale": (),
}

_EXPERT_WEIGHTS = {"w_gate", "w_up", "w_down"}
_EXPERT_TEMPLATE = (MODEL, FSDP, None)  # (E, in, out): expert-parallel + FSDP


def _leaf_name(path):
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def _in_moe(path):
    keys = [str(p.key) for p in path if hasattr(p, "key")]
    return "moe" in keys and "shared" not in keys


def param_specs(params, mesh, fsdp: bool = True):
    """Pytree of PartitionSpec matching params.

    fsdp=False drops the FSDP ("data") factor from weight shardings —
    tensor-parallel only. Right for serving steps where the per-layer
    weight all-gather would dominate decode HBM/ICI traffic and the
    unsharded copy fits (no optimizer state at inference).
    """
    def drop_fsdp(template):
        return tuple(None if a == FSDP else a for a in template)

    def spec_for(path, leaf):
        name = _leaf_name(path)
        if _in_moe(path) and name in _EXPERT_WEIGHTS:
            template = _EXPERT_TEMPLATE
        else:
            template = _PARAM_RULES.get(name, ())
        if not fsdp:
            template = drop_fsdp(template)
        return _guard(template, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def opt_state_specs(opt_state, params_spec, mesh):
    """OptState(step, mu, nu): moments shard like their parameters."""
    from repro.training.optimizer import OptState
    return OptState(step=P(), mu=params_spec, nu=params_spec)


# ------------------------------------------------------------------ batch
def batch_axes(mesh):
    names = set(mesh.axis_names)
    return ("pod", "data") if "pod" in names else ("data",)


def batch_specs(batch, mesh, shape_cfg=None):
    """tokens (B,S) / embeds (B,T,d): shard batch; embeds d on model."""
    baxes = batch_axes(mesh)

    def spec_for(path, leaf):
        name = _leaf_name(path)
        if name in ("frame_embeds", "visual_embeds"):
            return _guard((baxes, None, MODEL), leaf.shape, mesh)
        return _guard((baxes,) + (None,) * (leaf.ndim - 1), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, batch)


# ------------------------------------------------------------------ cache
def cache_specs(cache, mesh, *, long_context: bool = False):
    """KV/SSM cache sharding.

    Leaf shapes (possibly with leading stacked-layer dims):
      k/v:   (..., B, T, K, hd)   -> B: data, T: model (or all axes if B==1)
      conv:  (..., B, W-1, C)     -> B: data, C: model
      state: (..., B, nh, hd, N)  -> B: data, nh: model
    """
    baxes = batch_axes(mesh)
    all_axes = tuple(mesh.axis_names)

    sizes = axis_sizes(mesh)
    msz = sizes.get(MODEL, 1)

    def spec_for(path, leaf):
        name = _leaf_name(path)
        if name in ("k", "v", "cross") or (leaf.ndim >= 4
                                           and name != "state"):
            if long_context:
                return _guard((baxes, all_axes, None, None), leaf.shape, mesh)
            # prefer sharding KV heads when they divide the model axis
            # (no all-reduce in the decode contraction); else the seq dim
            kv_heads = leaf.shape[-2]
            if kv_heads % msz == 0:
                return _guard((baxes, None, MODEL, None), leaf.shape, mesh)
            return _guard((baxes, MODEL, None, None), leaf.shape, mesh)
        if name == "conv":
            return _guard((baxes, None, MODEL), leaf.shape, mesh)
        if name == "state":
            return _guard((baxes, MODEL, None, None), leaf.shape, mesh)
        return _guard((), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, cache)


# ------------------------------------------------------------------ helpers
def to_shardings(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
