"""Serving launcher: deploy a function under HAS-GPU control and replay a
workload through the real engine (CPU: reduced config) or lower the
serving steps against the production mesh (--dry-run).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
      --requests 16 [--sm 4 --quota 0.5 --batch 4] [--dry-run]
"""
import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--sm", type=int, default=4)
    ap.add_argument("--quota", type=float, default=0.5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.dry_run:
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=512 "
            + os.environ.get("XLA_FLAGS", ""))
        from repro.launch.dryrun import run_combo
        run_combo(args.arch, args.shape, multi_pod=args.multi_pod)
        return

    import numpy as np
    from repro.configs import ARCHS, reduced
    from repro.core.scheduler import HASGPUScheduler
    from repro.core.vgpu import PodAlloc, VirtualGPU
    from repro.serving import Gateway, InferenceRequest, PodEngine

    cfg = reduced(ARCHS[args.arch])
    print(f"[serve] reduced {cfg.name} on CPU, pod sm={args.sm} "
          f"q={args.quota} batch={args.batch}")
    vgpu = VirtualGPU("GPU-0", window_ms=50.0)
    sched = HASGPUScheduler()
    gw = Gateway()
    pod = PodAlloc(fn_id=f"fn-{cfg.name}", sm=args.sm, quota=args.quota,
                   batch=args.batch)
    vgpu.place(pod)
    gw.register(pod.fn_id, PodEngine(cfg, pod, vgpu, sched, max_seq=64))

    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    for _ in range(args.requests):
        gw.route(pod.fn_id, InferenceRequest(
            prompt=rng.integers(1, cfg.vocab_size, 8).astype(np.int32),
            max_new_tokens=args.new_tokens))
    done = []
    while len(done) < args.requests:
        done.extend(gw.pump(pod.fn_id))
    lats = sorted(r.latency for r in done)
    print(f"served {len(done)} requests in {time.monotonic()-t0:.2f}s  "
          f"p50={lats[len(lats)//2]*1e3:.0f}ms p95={lats[int(len(lats)*0.95)-1]*1e3:.0f}ms")


if __name__ == "__main__":
    main()
