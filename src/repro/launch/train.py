"""Training launcher.

On a real TPU slice this binary is what every host runs (jax.distributed
initializes from the TPU environment); on CPU it runs the same code on a
host mesh. The dry-run path (--dry-run) lowers against the production
mesh without executing.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b \
      --shape train_4k --steps 100 [--dry-run] [--ckpt path.npz]
"""
import argparse
import os
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced config (CPU-sized)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--lr", type=float, default=6e-4)
    args = ap.parse_args()

    if args.dry_run:
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=512 "
            + os.environ.get("XLA_FLAGS", ""))
        from repro.launch.dryrun import run_combo
        run_combo(args.arch, args.shape, multi_pod=args.multi_pod)
        return

    import jax
    import jax.numpy as jnp
    from repro import models
    from repro.configs import get_config, reduced
    from repro.models import CallOpts
    from repro.training import (checkpoint, data as data_mod,
                                optimizer as opt_mod, steps)

    cfg = get_config(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = reduced(cfg)
        print(f"[train] CPU backend: using reduced {cfg.name} "
              f"({cfg.param_count()/1e6:.1f}M params)")
    adamw = opt_mod.AdamWConfig(lr=args.lr, warmup_steps=args.steps // 10,
                                total_steps=args.steps)
    train_step = jax.jit(steps.make_train_step(cfg, adamw,
                                               CallOpts(remat=True)))
    params = models.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt_mod.init_opt_state(params)
    ds = data_mod.SyntheticLMData(cfg.vocab_size, seed=1)
    t0 = time.time()
    for step in range(args.steps):
        host = ds.batch(step, args.batch, args.seq)
        batch = {"tokens": jnp.asarray(host["tokens"])}
        if cfg.is_encoder_decoder:
            import numpy as np
            batch["frame_embeds"] = jnp.asarray(np.random.default_rng(step)
                .standard_normal((args.batch, cfg.encoder_seq, cfg.d_model)),
                jnp.bfloat16)
        if cfg.num_visual_tokens:
            import numpy as np
            batch["visual_embeds"] = jnp.asarray(np.random.default_rng(step)
                .standard_normal((args.batch, cfg.num_visual_tokens,
                                  cfg.d_model)), jnp.bfloat16)
        params, opt_state, m = train_step(params, opt_state, batch)
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} ({time.time()-t0:.0f}s)",
                  flush=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, {"params": params})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
