"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun

Writes one JSON record per combo (memory analysis, cost analysis, HLO
analyzer roofline terms, collective schedule) consumed by
benchmarks/roofline.py and EXPERIMENTS.md.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCHS, SHAPES, combo_is_supported, get_config, get_shape  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import build_case, lower_case  # noqa: E402

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link


def roofline_terms(analysis, n_chips):
    """Per-device analysis -> the three roofline terms in seconds."""
    compute_s = analysis.flops / PEAK_FLOPS
    memory_s = analysis.hbm_bytes / HBM_BW
    collective_s = analysis.collective_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    terms["dominant"] = max(terms, key=lambda k: terms[k])
    return terms


def run_combo(arch: str, shape: str, multi_pod: bool, verbose=True):
    cfg = get_config(arch)
    shp = get_shape(shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    case = build_case(cfg, shp, mesh)
    lowered = lower_case(case, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax < 0.5 returns [dict]
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    analysis = hlo_analysis.analyze(hlo_text, case.scan_trip_hints)
    terms = roofline_terms(analysis, n_chips)

    record = {
        "arch": arch, "shape": shape, "step": case.step_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": int(n_chips),
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes_per_device": int(mem.argument_size_in_bytes),
            "output_bytes_per_device": int(mem.output_size_in_bytes),
            "temp_bytes_per_device": int(mem.temp_size_in_bytes),
            "peak_bytes_per_device": int(mem.argument_size_in_bytes
                                         + mem.temp_size_in_bytes),
        },
        "xla_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "hlo_analysis_per_device": {
            "flops": analysis.flops,
            "hbm_bytes": analysis.hbm_bytes,
            "collective_bytes": analysis.collective_bytes,
            "collectives": analysis.collectives,
            "while_trips": analysis.while_trips,
            "unknown_trip_whiles": analysis.unknown_trip_whiles,
        },
        "roofline": terms,
    }
    if verbose:
        print(f"[{record['mesh']}] {arch} x {shape}: "
              f"lower {record['lower_s']}s compile {record['compile_s']}s | "
              f"peak/dev {record['memory']['peak_bytes_per_device']/2**30:.2f} GiB | "
              f"flops/dev {analysis.flops:.3e} coll/dev "
              f"{analysis.collective_bytes:.3e}B | dominant "
              f"{terms['dominant']} "
              f"({max(terms['compute_s'], terms['memory_s'], terms['collective_s']):.2e}s)",
              flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    combos = []
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    for a in archs:
        for s in shapes:
            if combo_is_supported(a, s):
                combos.append((a, s))
            else:
                print(f"SKIP {a} x {s} (see DESIGN.md §Arch-applicability)")

    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi_pod in meshes:
        for a, s in combos:
            tag = f"{a}__{s}__{'2x16x16' if multi_pod else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"skip existing {tag}")
                continue
            try:
                rec = run_combo(a, s, multi_pod)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
            except Exception as e:  # a failure here is a sharding bug
                failures.append((tag, repr(e)))
                print(f"FAIL {tag}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nALL DRY-RUN COMBOS PASSED")


if __name__ == "__main__":
    main()
