"""Dry-run case construction: ShapeDtypeStruct inputs + shardings + step fn
for every (architecture x input-shape) combination.

``input_specs`` returns weak-type-correct, shardable stand-ins (no device
allocation); ``build_case`` packages the jittable step with its in/out
shardings and donation config, ready for ``.lower().compile()``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import ArchConfig, ShapeConfig, combo_is_supported
from repro.launch import shardings as sh
from repro.models import CallOpts
from repro.training import optimizer as opt_mod, steps


def call_opts(cfg: ArchConfig, shape: ShapeConfig, mesh=None,
              **overrides) -> CallOpts:
    window = 0
    if shape.name == "long_500k" and not (cfg.family in ("ssm", "hybrid")):
        window = cfg.long_context_window
    logits_spec = None
    act_spec = None
    if mesh is not None:
        baxes = sh.batch_axes(mesh)
        if shape.kind == "train":
            vocab_ok = cfg.vocab_size % 16 == 0
            logits_spec = (baxes, None, "model" if vocab_ok else None)
        if shape.global_batch > 1:
            act_spec = (baxes, None, None)
    base = dict(
        remat=(shape.kind == "train"),
        window=window,
        capacity_factor=2.0 if shape.is_decode else 1.25,
        attn_chunk=4096,
        logits_spec=logits_spec,
        act_spec=act_spec,
    )
    base.update(overrides)
    return CallOpts(**base)


def kv_len_for(cfg: ArchConfig, shape: ShapeConfig) -> int:
    if shape.name == "long_500k" and cfg.long_context_window \
            and cfg.family not in ("ssm", "hybrid"):
        return cfg.long_context_window  # sliding-window ring buffer
    return shape.seq_len


def token_batch_specs(cfg: ArchConfig, shape: ShapeConfig):
    """ShapeDtypeStructs for the model input batch dict (full-seq steps)."""
    B = shape.global_batch
    v = cfg.num_visual_tokens or 0
    seq = shape.seq_len - v if v else shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((B, seq), jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["frame_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if v:
        batch["visual_embeds"] = jax.ShapeDtypeStruct(
            (B, v, cfg.d_model), jnp.bfloat16)
    return batch


def params_struct(cfg: ArchConfig):
    return jax.eval_shape(lambda r: models.init_params(r, cfg),
                          jax.random.PRNGKey(0))


def default_microbatches(cfg: ArchConfig, shape: ShapeConfig, mesh) -> int:
    """Gradient-accumulation depth: target a per-device activation budget
    of ~8k tokens scaled down for wide models."""
    if shape.kind != "train":
        return 1
    sizes = sh.axis_sizes(mesh)
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    b_loc = max(shape.global_batch // dp, 1)
    tokens_per_dev = b_loc * shape.seq_len
    target = max(int(8192 * 2048 / max(cfg.d_model, 2048)), 2048)
    m = 1
    while tokens_per_dev // m > target and m < b_loc:
        m *= 2
    return m


@dataclasses.dataclass
class Case:
    arch: str
    shape: str
    step_name: str           # train_step | prefill_step | decode_step
    fn: Callable             # jittable
    args: tuple              # ShapeDtypeStructs (or concrete arrays)
    in_shardings: tuple
    donate_argnums: tuple
    scan_trip_hints: dict    # trip-count hints for the HLO analyzer
    out_shardings: Any = None  # None = let XLA choose


def _scan_hints(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Static trip counts of every scan in the lowered program, used by the
    HLO analyzer to multiply while-loop bodies (XLA counts them once)."""
    from repro.models import blocks
    _, _, n_periods = blocks.stack_pattern(cfg)
    hints = {}
    if shape.kind == "train":
        hints["microbatches"] = 1  # placeholder; overwritten in build_case
    hints["layers"] = n_periods
    if cfg.is_encoder_decoder:
        hints["encoder"] = cfg.encoder_layers
        hints["decoder"] = cfg.num_layers
    v = cfg.num_visual_tokens or 0
    if shape.kind in ("train", "prefill"):
        S = shape.seq_len
        if cfg.ssm is not None:
            hints["ssd_chunks"] = max(S // min(cfg.ssm.chunk_size, S), 1)
        if S > 4096 and S % 4096 == 0:
            hints["attn_chunks"] = S // 4096
    return hints


def build_case(cfg: ArchConfig, shape: ShapeConfig, mesh,
               opts: Optional[CallOpts] = None,
               adamw: Optional[opt_mod.AdamWConfig] = None,
               microbatches: Optional[int] = None,
               fsdp_params: bool = True) -> Case:
    if not combo_is_supported(cfg.name, shape.name):
        raise ValueError(f"{cfg.name} x {shape.name} is not supported "
                         "(see DESIGN.md §Arch-applicability)")
    opts = opts or call_opts(cfg, shape, mesh)
    p_struct = params_struct(cfg)
    p_spec = sh.param_specs(p_struct, mesh, fsdp=fsdp_params)

    P = jax.sharding.PartitionSpec
    if shape.kind == "train":
        adamw = adamw or opt_mod.AdamWConfig()
        batch = token_batch_specs(cfg, shape)
        opt_struct = jax.eval_shape(
            lambda p: opt_mod.init_opt_state(p, adamw.moment_dtype),
            p_struct)
        if microbatches is None:
            microbatches = default_microbatches(cfg, shape, mesh)
        fn = steps.make_train_step(cfg, adamw, opts, microbatches,
                                   grad_specs=p_spec)
        args = (p_struct, opt_struct, batch)
        opt_spec = sh.opt_state_specs(opt_struct, p_spec, mesh)
        in_sh = (p_spec, opt_spec, sh.batch_specs(batch, mesh))
        donate = (0, 1)
        metrics_spec = {k: P() for k in
                        ("grad_norm", "lr", "loss", "ce", "aux")}
        out_sh = (p_spec, opt_spec, metrics_spec)
    elif shape.kind == "prefill":
        kv_len = kv_len_for(cfg, shape)
        batch = token_batch_specs(cfg, shape)
        fn = steps.make_prefill_step(cfg, kv_len, opts)
        args = (p_struct, batch)
        in_sh = (p_spec, sh.batch_specs(batch, mesh))
        donate = ()
        # pin the freshly created KV cache to the serving cache layout
        with mesh:
            out_struct = jax.eval_shape(fn, *args)
        cache_spec = sh.cache_specs(out_struct[1], mesh)
        out_sh = (None, cache_spec)
    else:  # decode
        kv_len = kv_len_for(cfg, shape)
        B = shape.global_batch
        cache = jax.eval_shape(
            partial(models.init_cache, cfg, B, kv_len,
                    jnp.dtype(opts.cache_dtype)))
        tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        fn = steps.make_decode_step(cfg, opts)
        args = (p_struct, tokens, pos, cache)
        long_ctx = shape.global_batch == 1
        cache_spec = sh.cache_specs(cache, mesh, long_context=long_ctx)
        in_sh = (p_spec, sh.batch_specs({"tokens": tokens}, mesh)["tokens"],
                 P(), cache_spec)
        donate = (3,)
        out_sh = (None, cache_spec)  # output cache aliases the donated input

    hints = _scan_hints(cfg, shape)
    if shape.kind == "train":
        hints["microbatches"] = microbatches
    return Case(arch=cfg.name, shape=shape.name,
                step_name=f"{shape.kind}_step", fn=fn, args=args,
                in_shardings=in_sh, donate_argnums=donate,
                scan_trip_hints=hints, out_shardings=out_sh)


def _maybe_shardings(tree, mesh):
    if tree is None:
        return None
    return jax.tree.map(
        lambda s: (jax.sharding.NamedSharding(mesh, s)
                   if isinstance(s, jax.sharding.PartitionSpec) else s),
        tree, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        or x is None)


def lower_case(case: Case, mesh):
    in_shardings = sh.to_shardings(case.in_shardings, mesh)
    kwargs = {}
    if case.out_shardings is not None:
        kwargs["out_shardings"] = _maybe_shardings(case.out_shardings, mesh)
    jitted = jax.jit(case.fn, in_shardings=in_shardings,
                     donate_argnums=case.donate_argnums, **kwargs)
    with mesh:
        return jitted.lower(*case.args)
