"""Post-SPMD HLO-text analyzer: exact FLOPs / bytes / collective bytes.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count, which silently undercounts every ``lax.scan`` (layer stacks, KV
chunks, SSD chunks). This analyzer parses ``compiled.as_text()`` (the
per-device module after SPMD partitioning), extracts while-loop trip counts
from their condition computations, and recursively accumulates:

  * flops            — dot / convolution ops (2 * out_elems * contraction),
                       including dots inside fusions
  * collective_bytes — operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute
  * hbm_bytes        — roofline memory-traffic model: operand + output
                       bytes of top-level (post-fusion) instructions, with
                       slice-aware accounting (a fusion whose parameter is
                       only dynamic-sliced reads the slice, not the array;
                       dynamic-update-slice traffic is 2x the update size)

All numbers are PER DEVICE (the module is the per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 1, "s4": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    out_type: str
    op: str
    rest: str  # everything after the opening paren: "args), attrs"


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    by_name: Dict[str, Instr]


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    while_trips: dict = dataclasses.field(default_factory=dict)
    unknown_trip_whiles: list = dataclasses.field(default_factory=list)

    def scaled(self, k: float) -> "Analysis":
        return Analysis(self.flops * k, self.hbm_bytes * k,
                        self.collective_bytes * k,
                        {n: v * k for n, v in self.collectives.items()},
                        dict(self.while_trips), list(self.unknown_trip_whiles))

    def add(self, other: "Analysis"):
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        self.collective_bytes += other.collective_bytes
        for n, v in other.collectives.items():
            self.collectives[n] = self.collectives.get(n, 0.0) + v
        self.while_trips.update(other.while_trips)
        self.unknown_trip_whiles.extend(other.unknown_trip_whiles)


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast",
                "all-gather-start", "all-reduce-start",
                "collective-permute-start")

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id",
             "all-gather-done", "all-reduce-done", "collective-permute-done",
             "get-dimension-size"}

# top-level ops whose operand+output bytes we count as HBM traffic
_MEMORY_OPS = set(_COLLECTIVES) | {
    "fusion", "dot", "convolution", "copy", "copy-start",
    "dynamic-update-slice", "dynamic-slice", "gather", "scatter", "reduce",
    "transpose", "reshape", "slice", "concatenate", "broadcast", "sort",
    "pad", "select", "rng-bit-generator", "custom-call", "convert", "iota",
    "add", "multiply", "subtract", "divide", "exponential", "tanh", "rsqrt",
    "maximum", "minimum", "compare", "reduce-window", "select-and-scatter",
    "log", "negate", "sqrt", "power", "and", "or", "xor", "clamp",
}


def parse_module(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if cur is None:
            s = line.strip()
            if s.endswith("{") and "->" in s and ("%" in s or s.startswith("ENTRY")):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", s)
                if m:
                    cur = Computation(m.group(1), [], {})
            continue
        if line.strip() == "}" or line.strip().startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2).strip(), m.group(3), m.group(4))
            cur.instrs.append(ins)
            cur.by_name[ins.name] = ins
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _find_entry(hlo_text: str, comps) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.MULTILINE)
    if m and m.group(1) in comps:
        return m.group(1)
    referenced = set()
    for c in comps.values():
        for ins in c.instrs:
            for ref in re.findall(
                    r"(?:calls|condition|body|to_apply|branch_computations=\{)"
                    r"=?%?([\w.\-]+)", ins.rest):
                referenced.add(ref)
    for name in comps:
        if name not in referenced:
            return name
    return next(iter(comps))


def _operand_types(ins: Instr, comp: Computation) -> List[str]:
    """Types of instruction operands (args before the closing paren)."""
    args = _args_of(ins)
    out = []
    inline = re.findall(r"(\w+\[[\d,]*\](?:\{[^}]*\})?)\s+%?[\w.\-]+", args)
    if inline:
        return inline
    for m in re.finditer(r"%([\w.\-]+)", args):
        d = comp.by_name.get(m.group(1))
        if d is not None:
            out.append(d.out_type)
    return out


def _operand_names(ins: Instr) -> List[str]:
    return re.findall(r"%([\w.\-]+)", _args_of(ins))


def _args_of(ins: Instr) -> str:
    """Args substring: up to the matching close paren of the op's open."""
    depth = 1
    for i, ch in enumerate(ins.rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return ins.rest[:i]
    return ins.rest


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = _shape_elems(ins.out_type)
    ops = _operand_types(ins, comp)
    if not ops:
        return 0.0
    lhs_dims = _shape_dims(ops[0])
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    contraction = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contraction *= lhs_dims[i]
    return 2.0 * out_elems * contraction


def _conv_flops(ins: Instr, comp: Computation) -> float:
    out_elems = _shape_elems(ins.out_type)
    ops = _operand_types(ins, comp)
    if len(ops) < 2:
        return 0.0
    kdims = _shape_dims(ops[1])
    if not kdims:
        return 0.0
    m = re.search(r"feature_group_count=(\d+)", ins.rest)
    fgc = int(m.group(1)) if m else 1
    kernel_elems = 1
    for d in kdims:
        kernel_elems *= d
    out_features = kdims[-1]
    # per output element: kernel_spatial * input_channels_per_group
    per_out = kernel_elems / max(out_features, 1)
    return 2.0 * out_elems * max(per_out, 1.0)


def _comp_flops(comp: Computation, comps) -> float:
    """FLOPs of dots/convs directly inside a (fusion) computation."""
    f = 0.0
    for si in comp.instrs:
        if si.op == "dot":
            f += _dot_flops(si, comp)
        elif si.op == "convolution":
            f += _conv_flops(si, comp)
    return f


def _while_trip_count(ins: Instr, comps) -> Optional[int]:
    m = re.search(r"condition=%?([\w.\-]+)", ins.rest)
    if not m or m.group(1) not in comps:
        return None
    cond = comps[m.group(1)]
    consts = []
    for i in cond.instrs:
        if i.op == "constant" and i.out_type.startswith("s32"):
            mm = re.match(r"\s*(-?\d+)", _args_of(i))
            if mm:
                consts.append(int(mm.group(1)))
    pos = [c for c in consts if c > 0]
    if pos:
        return max(pos)
    return None


def _fusion_hbm_bytes(ins: Instr, comp: Computation, comps) -> float:
    """Slice-aware fusion traffic: params only consumed by dynamic-slice /
    slice read the slice, not the whole array; a root dynamic-update-slice
    writes (and reads) only the update region."""
    args = _args_of(ins)
    operand_names = re.findall(r"%([\w.\-]+)", args)
    callee = None
    mm = re.search(r"calls=%?([\w.\-]+)", ins.rest)
    if mm and mm.group(1) in comps:
        callee = comps[mm.group(1)]
    total = 0.0
    if callee is not None:
        _PASS = ("convert", "bitcast", "copy", "reshape", "transpose",
                 "negate")

        def terminal_uses(name, depth=0):
            """Uses of `name`, looking through element-wise pass-through
            chains (convert/bitcast/...)."""
            out = []
            if depth > 6:
                return out
            for si in callee.instrs:
                if name in _operand_names(si):
                    if si.op in _PASS:
                        out.extend(terminal_uses(si.name, depth + 1))
                    else:
                        out.append(si)
            return out

        dus_list = [si for si in callee.instrs
                    if si.op == "dynamic-update-slice"]
        dus_update_bytes = {}
        for si in dus_list:
            names = _operand_names(si)
            if len(names) >= 2:
                upd = callee.by_name.get(names[1])
                dus_update_bytes[si.name] = (
                    _shape_bytes(upd.out_type) if upd is not None else 0)
        # map param index -> bytes actually read
        param_instrs = {}
        for si in callee.instrs:
            if si.op == "parameter":
                pm = re.match(r"\s*(\d+)", _args_of(si))
                if pm:
                    param_instrs[si.name] = int(pm.group(1))
        reads = {}
        for pname, pidx in param_instrs.items():
            uses = terminal_uses(pname)
            if uses and all(si.op in ("dynamic-slice", "slice")
                            for si in uses):
                # sliced reads: only the slice leaves HBM
                reads[pidx] = sum(_shape_bytes(si.out_type) for si in uses)
            elif uses and all(si.op == "dynamic-update-slice"
                              for si in uses):
                # param flows (possibly via converts) into DUS targets:
                # aliased in place — traffic is the update region only
                reads[pidx] = sum(dus_update_bytes.get(si.name, 0)
                                  for si in uses)
            else:
                d = comp.by_name.get(operand_names[pidx]) \
                    if pidx < len(operand_names) else None
                if d is not None:
                    reads[pidx] = _shape_bytes(d.out_type)
                else:
                    ts = _operand_types(ins, comp)
                    reads[pidx] = _shape_bytes(ts[pidx]) if pidx < len(ts) else 0
        total += sum(reads.values())
        # output: if the fusion result is (a convert/bitcast of) a DUS over
        # the full output buffer, only the update region is written
        out_bytes = _shape_bytes(ins.out_type)
        out_elems = _shape_elems(ins.out_type)
        if dus_list and any(_shape_elems(si.out_type) == out_elems
                            for si in dus_list):
            total += sum(dus_update_bytes.values())
        else:
            total += out_bytes
        return max(total, 0.0)
    ts = _operand_types(ins, comp)
    return sum(_shape_bytes(t) for t in ts) + _shape_bytes(ins.out_type)


def analyze_computation(comp: Computation, comps, hints: List[int],
                        _depth=0) -> Analysis:
    res = Analysis()
    for ins in comp.instrs:
        op = ins.op
        if op in _SKIP_OPS:
            continue
        if op == "while":
            trips = _while_trip_count(ins, comps)
            if trips is None:
                trips = hints.pop(0) if hints else 1
                res.unknown_trip_whiles.append(ins.name)
            res.while_trips[ins.name] = trips
            body_m = re.search(r"body=%?([\w.\-]+)", ins.rest)
            if body_m and body_m.group(1) in comps:
                body = analyze_computation(comps[body_m.group(1)], comps,
                                           hints, _depth + 1)
                res.add(body.scaled(trips))
            continue
        if op in ("call", "conditional", "async-start"):
            for ref in re.findall(r"(?:to_apply|calls)=%?([\w.\-]+)", ins.rest):
                if ref in comps:
                    res.add(analyze_computation(comps[ref], comps, hints,
                                                _depth + 1))
            continue
        if op == "dot":
            res.flops += _dot_flops(ins, comp)
        elif op == "convolution":
            res.flops += _conv_flops(ins, comp)
        elif op == "fusion":
            for ref in re.findall(r"calls=%?([\w.\-]+)", ins.rest):
                if ref in comps:
                    res.flops += _comp_flops(comps[ref], comps)
        if op in _COLLECTIVES:
            b = sum(_shape_bytes(t) for t in _operand_types(ins, comp))
            res.collective_bytes += b
            key = op.replace("-start", "")
            res.collectives[key] = res.collectives.get(key, 0.0) + b
        if op in _MEMORY_OPS:
            if op == "fusion":
                res.hbm_bytes += _fusion_hbm_bytes(ins, comp, comps)
            elif op == "dynamic-update-slice":
                ts = _operand_types(ins, comp)
                upd = _shape_bytes(ts[1]) if len(ts) >= 2 else 0
                res.hbm_bytes += 2 * upd
            elif op == "dynamic-slice":
                res.hbm_bytes += 2 * _shape_bytes(ins.out_type)
            else:
                ts = _operand_types(ins, comp)
                res.hbm_bytes += (sum(_shape_bytes(t) for t in ts)
                                  + _shape_bytes(ins.out_type))
    return res


def analyze(hlo_text: str, trip_hints: Optional[dict] = None) -> Analysis:
    """Analyze a compiled (post-SPMD) HLO module. Per-device totals.

    trip_hints: {label: trips}, consumed in encounter order for while loops
    whose trip count cannot be inferred from their condition.
    """
    comps = parse_module(hlo_text)
    entry = _find_entry(hlo_text, comps)
    hints = list(trip_hints.values()) if trip_hints else []
    return analyze_computation(comps[entry], comps, hints)
