"""Production mesh construction.

Defined as functions (not module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke runs of the same launch code."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
