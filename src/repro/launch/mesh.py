"""Production mesh construction.

Defined as functions (not module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def mesh_kwargs(n_axes: int) -> dict:
    """`axis_types=` for jax.make_mesh where supported (jax >= 0.5 added
    jax.sharding.AxisType; older versions default to Auto implicitly)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_kwargs(len(axes)))


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke runs of the same launch code."""
    return jax.make_mesh((1, 1), ("data", "model"), **mesh_kwargs(2))


def axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
