"""Flat-npz checkpointing for arbitrary pytrees (params + optimizer state).

Keys are '/'-joined tree paths; restore rebuilds into a reference pytree
structure, so sharded device arrays round-trip through host numpy. Atomic
via write-to-temp + rename.
"""
from __future__ import annotations

import os
import tempfile

import jax
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype not in (np.float32, np.float64, np.int32, np.int64,
                             np.uint32, np.bool_, np.int8, np.uint8,
                             np.float16):
            arr = arr.astype(np.float32)  # bf16 etc. stored widened
        flat[key] = arr
    return flat


def _path_str(p):
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(path: str, tree) -> None:
    flat = _flatten(tree)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp.npz")
    os.close(fd)
    try:
        np.savez(tmp, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def restore(path: str, like):
    """Restore into the structure of `like` (a reference pytree)."""
    with np.load(path) as z:
        loaded = dict(z)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_, leaf in leaves:
        key = "/".join(_path_str(p) for p in path_)
        if key not in loaded:
            raise KeyError(f"checkpoint missing {key}")
        arr = loaded[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        out.append(np.asarray(jax.numpy.asarray(arr).astype(leaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)
