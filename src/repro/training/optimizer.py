"""Pure-JAX AdamW with cosine/warmup schedule (no optax dependency).

Optimizer state is a pytree with the same structure as params, sharded
identically by the jit partitioner (first/second moments live on the same
devices as their parameters).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0
    # moment store dtype: "float32" (default) or "bfloat16" — bf16 moments
    # halve optimizer HBM (the fix that brings dbrx-132b train under
    # 16 GiB/chip); update math still runs in f32
    moment_dtype: str = "float32"


class OptState(NamedTuple):
    step: jax.Array
    mu: object   # pytree like params (moment_dtype)
    nu: object   # pytree like params (moment_dtype)


def init_opt_state(params, moment_dtype: str = "float32") -> OptState:
    dt = jnp.dtype(moment_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m.astype(mdt), v.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_mu, new_nu), metrics
