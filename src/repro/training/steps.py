"""Loss and train/serve step functions — the units the launcher jits.

``make_train_step``/``make_prefill_step``/``make_decode_step`` return pure
functions suitable for ``jax.jit`` with explicit in/out shardings; the
dry-run lowers exactly these.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import models
from repro.models import CallOpts
from repro.training import optimizer as opt_mod


def cross_entropy(logits, labels, mask=None):
    """logits: (B,S,V) f32; labels: (B,S) int32. Mean NLL over mask.

    The gold logit is extracted with a one-hot contraction (fuses into the
    reduction under SPMD) instead of take_along_axis, whose gather would
    force an all-gather of vocab-sharded logits.
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    onehot = (iota == labels[..., None]).astype(logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, cfg, batch, opts: CallOpts):
    logits, aux = models.forward(params, cfg, batch, opts)
    if opts.logits_spec is not None:
        logits = jax.lax.with_sharding_constraint(
            logits, jax.sharding.PartitionSpec(*opts.logits_spec))
    tokens = batch["tokens"]
    # VLM: logits cover [visual | text]; next-token loss on the text span.
    v = cfg.num_visual_tokens or 0
    text_logits = logits[:, v:-1] if v else logits[:, :-1]
    labels = tokens[:, 1:]
    loss = cross_entropy(text_logits, labels)
    lb_coef = cfg.moe.load_balance_coef if cfg.moe else 0.0
    return loss + lb_coef * aux, {"ce": loss, "aux": aux}


def make_train_step(cfg, adamw: opt_mod.AdamWConfig,
                    opts: CallOpts = CallOpts(remat=True),
                    microbatches: int = 1, grad_specs=None):
    """Train step with optional gradient-accumulation microbatching.

    With microbatches=M the global batch is processed as M sequential
    slices with f32 gradient accumulation — M-fold lower activation
    footprint at identical math (loss/grads are exact means).

    grad_specs: optional PartitionSpec pytree (same structure as params);
    constrains per-microbatch grads to the parameter sharding so the SPMD
    partitioner emits reduce-scatters instead of all-reduces inside the
    accumulation loop.
    """
    def grad_one(params, batch):
        (l, parts), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch, opts)
        if grad_specs is not None:
            g = jax.tree.map(
                lambda t, s: jax.lax.with_sharding_constraint(
                    t, s) if isinstance(s, jax.sharding.PartitionSpec)
                else t, g, grad_specs)
        return (l, parts), g

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, parts), grads = grad_one(params, batch)
        else:
            # strided split: (B, ...) -> (M, B/M, ...) such that each
            # microbatch draws evenly from every data shard (a contiguous
            # reshape would put microbatch 0 on 1/M of the data axis and
            # replicate compute)
            mb = jax.tree.map(
                lambda x: x.reshape((x.shape[0] // microbatches, microbatches)
                                    + x.shape[1:]).swapaxes(0, 1), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            z = jnp.zeros((), jnp.float32)

            def acc(carry, batch_i):
                gsum, lsum, psum = carry
                (l, parts_i), g = grad_one(params, batch_i)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l, {k: psum[k] + parts_i[k]
                                         for k in psum}), None

            (gsum, lsum, psums), _ = jax.lax.scan(
                acc, (g0, z, {"ce": z, "aux": z}), mb)
            inv = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * inv, gsum)
            loss = lsum * inv
            parts = {k: v * inv for k, v in psums.items()}
        params, opt_state, metrics = opt_mod.apply_updates(
            adamw, params, grads, opt_state)
        metrics.update(loss=loss, **parts)
        return params, opt_state, metrics
    return train_step


def make_forward_step(cfg, opts: CallOpts = CallOpts()):
    def forward_step(params, batch):
        logits, _ = models.forward(params, cfg, batch, opts)
        return logits
    return forward_step


def make_prefill_step(cfg, kv_len: int, opts: CallOpts = CallOpts()):
    def prefill_step(params, batch):
        logits, cache = models.prefill(params, cfg, batch, kv_len, opts)
        return logits, cache
    return prefill_step


def make_decode_step(cfg, opts: CallOpts = CallOpts()):
    def decode_step(params, tokens, pos, cache):
        return models.decode_step(params, cfg, tokens, pos, cache, opts=opts)
    return decode_step
