"""Synthetic token data pipeline (deterministic, seekable, host-side).

A real deployment would swap in an SSTable/ArrayRecord reader; the
interface — ``iterate(batch_size, seq_len)`` yielding dicts of numpy
arrays — is what the train loop consumes, so the swap is local.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    """Zipf-distributed token stream with local n-gram structure so the
    loss actually decreases (pure uniform noise has no learnable signal)."""
    vocab_size: int
    seed: int = 0
    ngram_repeat: int = 8

    def batch(self, step: int, batch_size: int, seq_len: int):
        rng = np.random.default_rng(self.seed + step)
        # zipf-ish marginal over a restricted alphabet
        alpha = 1.2
        ranks = np.arange(1, min(self.vocab_size, 4096) + 1)
        probs = ranks ** (-alpha)
        probs /= probs.sum()
        base = rng.choice(len(probs), size=(batch_size, seq_len), p=probs)
        # inject learnable structure: periodic repeats of a per-row motif
        motif_len = self.ngram_repeat
        motif = base[:, :motif_len]
        reps = seq_len // (2 * motif_len)
        for r in range(reps):
            s = 2 * r * motif_len + motif_len
            base[:, s:s + motif_len] = motif
        return {"tokens": base.astype(np.int32)}

    def iterate(self, batch_size: int, seq_len: int, start_step: int = 0):
        step = start_step
        while True:
            yield self.batch(step, batch_size, seq_len)
            step += 1


def synthetic_batch_for(cfg, shape, step: int = 0, seed: int = 0):
    """Build a host-side numpy batch matching input_specs for (cfg, shape)."""
    data = SyntheticLMData(cfg.vocab_size, seed=seed)
    v = cfg.num_visual_tokens or 0
    seq = shape.seq_len - v if shape.kind == "train" else shape.seq_len
    out = data.batch(step, shape.global_batch, max(seq, 2))
    rng = np.random.default_rng(seed + 1)
    if cfg.is_encoder_decoder:
        out["frame_embeds"] = rng.standard_normal(
            (shape.global_batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    if v:
        out["visual_embeds"] = rng.standard_normal(
            (shape.global_batch, v, cfg.d_model)).astype(np.float32)
    return out
