"""Seeded, composable arrival-process generators.

GPU-sharing policies look identical under smooth load and diverge under
bursts (ESG, Torpor both evaluate across bursty / diurnal / production
traces), so the scenario engine needs more regimes than the single
Azure-like trace in ``azure.py``. Every generator here:

  * returns a sorted ``np.ndarray`` of arrival times in seconds,
  * is deterministic per ``seed`` (own ``np.random.default_rng``; the
    global numpy RNG is never touched),
  * shares the ``(duration_s, base_rps, seed)`` calling convention the
    scenario registry binds against.

Inhomogeneous processes are sampled by Lewis-Shedler thinning against
the analytic rate envelope, so the rate function is the single source
of truth for the process shape.
"""
from __future__ import annotations

from typing import Callable

import numpy as np


def homogeneous_poisson(duration_s: float, rate_rps: float,
                        seed: int = 0) -> np.ndarray:
    """Constant-rate Poisson process: the smooth-load control case."""
    rng = np.random.default_rng(seed)
    n = rng.poisson(max(rate_rps, 0.0) * duration_s)
    return np.sort(rng.uniform(0.0, duration_s, size=n))


def inhomogeneous_poisson(rate_fn: Callable[[np.ndarray], np.ndarray],
                          duration_s: float, rate_max: float,
                          seed: int = 0) -> np.ndarray:
    """Lewis-Shedler thinning: sample a homogeneous process at the
    envelope ``rate_max`` and keep each point with prob rate(t)/max."""
    rng = np.random.default_rng(seed)
    n = rng.poisson(max(rate_max, 1e-12) * duration_s)
    t = np.sort(rng.uniform(0.0, duration_s, size=n))
    rates = np.asarray(rate_fn(t), dtype=float)
    if len(rates) and rates.max() > rate_max * (1.0 + 1e-9):
        raise ValueError(
            f"rate_fn exceeds its envelope ({rates.max():.3f} > "
            f"{rate_max:.3f}): thinning would silently under-sample peaks")
    keep = rng.uniform(0.0, rate_max, size=n) < rates
    return t[keep]


def diurnal(duration_s: float, base_rps: float, amplitude: float = 0.6,
            period_s: float = 240.0, phase: float = 0.0,
            seed: int = 0) -> np.ndarray:
    """Sinusoidal day/night swing around ``base_rps`` (slow drift the
    Kalman predictor should track without overshoot)."""
    amplitude = min(max(amplitude, 0.0), 1.0)

    def rate(t):
        return base_rps * (1.0 + amplitude *
                           np.sin(2.0 * np.pi * t / period_s + phase))

    return inhomogeneous_poisson(rate, duration_s,
                                 base_rps * (1.0 + amplitude), seed)


def mmpp(duration_s: float, base_rps: float, burst_multiplier: float = 5.0,
         mean_calm_s: float = 30.0, mean_burst_s: float = 6.0,
         seed: int = 0) -> np.ndarray:
    """Two-state Markov-modulated Poisson process: exponential dwell
    times alternate a calm state (``base_rps``) with a burst state
    (``base_rps * burst_multiplier``) — abrupt regime switches, unlike
    the smooth diurnal drift."""
    rng = np.random.default_rng(seed)
    chunks = []
    t, bursting = 0.0, False
    while t < duration_s:
        dwell = rng.exponential(mean_burst_s if bursting else mean_calm_s)
        end = min(t + dwell, duration_s)
        rate = base_rps * (burst_multiplier if bursting else 1.0)
        n = rng.poisson(rate * (end - t))
        chunks.append(rng.uniform(t, end, size=n))
        t, bursting = end, not bursting
    if not chunks:
        return np.empty(0)
    return np.sort(np.concatenate(chunks))


def flash_crowd(duration_s: float, base_rps: float,
                spike_multiplier: float = 8.0, spike_at_s: float = None,
                ramp_s: float = 5.0, hold_s: float = 15.0,
                decay_s: float = 20.0, seed: int = 0) -> np.ndarray:
    """Steady base load with one violent spike: linear ramp to
    ``spike_multiplier * base_rps`` over ``ramp_s``, hold, exponential
    decay back — the cold-start stress case."""
    if spike_at_s is None:
        spike_at_s = duration_s / 3.0
    peak = base_rps * spike_multiplier
    t_hold = spike_at_s + ramp_s

    def rate(t):
        r = np.full_like(t, base_rps, dtype=float)
        up = (t >= spike_at_s) & (t < t_hold)
        r[up] = base_rps + (peak - base_rps) * (t[up] - spike_at_s) / ramp_s
        hold = (t >= t_hold) & (t < t_hold + hold_s)
        r[hold] = peak
        dec = t >= t_hold + hold_s
        r[dec] = base_rps + (peak - base_rps) * np.exp(
            -(t[dec] - t_hold - hold_s) / decay_s)
        return r

    return inhomogeneous_poisson(rate, duration_s, peak, seed)


def ramp(duration_s: float, start_rps: float, end_rps: float,
         seed: int = 0) -> np.ndarray:
    """Linear rate sweep start -> end: sustained growth (or drain) that
    exercises steady scale-up/-down rather than burst response."""

    def rate(t):
        return start_rps + (end_rps - start_rps) * t / duration_s

    return inhomogeneous_poisson(rate, duration_s,
                                 max(start_rps, end_rps), seed)


# ---- combinators -----------------------------------------------------------

def superpose(*traces: np.ndarray) -> np.ndarray:
    """Merge independent processes (sum of their rates)."""
    parts = [np.asarray(t, dtype=float) for t in traces if len(t)]
    if not parts:
        return np.empty(0)
    return np.sort(np.concatenate(parts))


def thin(trace: np.ndarray, keep_prob: float, seed: int = 0) -> np.ndarray:
    """Keep each arrival independently with ``keep_prob`` (rate scaling
    that preserves the process shape)."""
    rng = np.random.default_rng(seed)
    trace = np.asarray(trace, dtype=float)
    return trace[rng.uniform(size=len(trace)) < keep_prob]


def time_shift(trace: np.ndarray, dt: float,
               duration_s: float = None) -> np.ndarray:
    """Shift arrivals by ``dt`` seconds, dropping anything outside
    [0, duration_s) when a horizon is given."""
    out = np.asarray(trace, dtype=float) + dt
    out = out[out >= 0.0]
    if duration_s is not None:
        out = out[out < duration_s]
    return out
