from repro.workloads.azure import (TraceConfig, arrivals, rate_series,
                                   standard_workload, stress_workload)
from repro.workloads.generators import (diurnal, flash_crowd,
                                        homogeneous_poisson,
                                        inhomogeneous_poisson, mmpp, ramp,
                                        superpose, thin, time_shift)

__all__ = ["TraceConfig", "arrivals", "rate_series", "standard_workload",
           "stress_workload", "homogeneous_poisson", "inhomogeneous_poisson",
           "diurnal", "mmpp", "flash_crowd", "ramp", "superpose", "thin",
           "time_shift"]
