from repro.workloads.azure import (TraceConfig, arrivals, rate_series,
                                   standard_workload, stress_workload)

__all__ = ["TraceConfig", "arrivals", "rate_series", "standard_workload",
           "stress_workload"]
