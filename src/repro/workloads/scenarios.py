"""Scenario engine: named, seeded workload regimes bound to fleet configs.

A ``Scenario`` binds an arrival-process generator (``generators.py`` /
``azure.py``) to function specs, SLO multipliers, and a fleet config —
homogeneous (``max_gpus`` chips of the reference type) or heterogeneous
(an ordered ``fleet`` of ``(gpu_type_name, max_chips)`` pools from
``configs/gpus.py``) — and knows how to drive either simulator
(``ClusterSimulator`` for one function, ``MultiFunctionSimulator`` for
a co-located set) under any of the registered policies. Every run emits
one ``RunMetrics`` record (``core/metrics.py``) — the unit the
golden-trace regression suite pins.

Adding a scenario is one ``register(Scenario(...))`` call; see
``docs/scenarios.md`` (kept drift-free by ``tests/test_docs.py``) for
the catalogue and the golden-regeneration step that must accompany it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.configs import ARCHS
from repro.configs.gpus import GPUMarket, spot
from repro.core import (ClusterSimulator, FaSTGShareLikePolicy, FaultModel,
                        FnSpec, HybridAutoScaler, KServeLikePolicy,
                        LifecycleConfig, ModelStateTracker, Reconfigurator,
                        ResilienceConfig, SimConfig)
from repro.core.metrics import DEFAULT_MULTIPLIERS, RunMetrics
from repro.core.multisim import MultiFunctionSimulator
from repro.workloads import azure, generators

# policy name -> (constructor, billed-whole-GPU?)
POLICIES: Dict[str, tuple] = {
    "has": (HybridAutoScaler, False),
    "kserve": (KServeLikePolicy, True),
    "fast": (FaSTGShareLikePolicy, False),
}

# per-function seed decorrelation stride for co-located scenarios
_FN_SEED_STRIDE = 7919

#: Physics-derived lifecycle with host caching + one keep-warm pod —
#: the configuration the scale-to-zero / churn scenarios run under.
LIFECYCLE_CACHED = LifecycleConfig(derive_from_physics=True,
                                   host_cache_gb=16.0, keep_warm_pods=1)
#: As above plus forecast-driven pre-warming (fig6 ``--prewarm``).
LIFECYCLE_PREWARM = dataclasses.replace(LIFECYCLE_CACHED,
                                        prewarm_lead_s=5.0)


def make_policy(name: str, recon: Reconfigurator):
    """Instantiate the registered policy ``name`` (``has``/``kserve``/
    ``fast``) with its default config against cluster ``recon``. When
    the cluster carries an active ``ModelStateTracker``, the HAS policy
    adopts its keep-warm / pre-warm knobs automatically (so custom
    ``policy_factory`` hooks honor a scenario's lifecycle too)."""
    return POLICIES[name][0](recon)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named workload regime.

    ``trace`` follows the generator calling convention
    ``(duration_s, base_rps, seed) -> sorted arrival times`` and is
    re-invoked per function with decorrelated seeds. ``fleet`` is an
    optional ordered tuple of ``(gpu_type, max_chips)`` pools — each
    ``gpu_type`` a ``configs/gpus.py`` registry name or a ``GPUType``
    instance (unregistered spot variants from ``spot()`` are passed as
    instances); None means the legacy homogeneous
    cluster of ``max_gpus`` reference-type chips — the construction
    path, and therefore the golden traces, of every pre-heterogeneity
    scenario. ``lifecycle`` attaches the model-state lifecycle engine
    (``core/modelstate.py``): physics-derived cold starts, host-RAM
    weight caching, keep-warm pools, and pre-warming; None (the
    default) runs the legacy flat-constant cold-start physics.
    ``faults`` attaches the fault-injection engine (``core/faults.py``)
    and ``resilience`` the mitigation layer (deadlines + retries,
    health quarantine, admission control); both default to None, which
    keeps the engine's fault layer fully disarmed — the byte-identity
    state of every legacy golden. ``sim_overrides`` passes extra
    ``SimConfig`` keyword overrides (e.g. a tighter ``drop_after_s``
    for overload scenarios). ``width`` replicates the arch list
    round-robin into that many tenant functions (``FnSpec.variant``
    labels keep their fn_ids distinct while the physics caches still
    collapse per arch) — the wide-engine fleet regime; 1 (the
    default) is the legacy one-function-per-arch shape.
    """
    name: str
    description: str
    trace: Callable[[float, float, int], np.ndarray]
    archs: Tuple[str, ...] = ("olmo-1b",)
    base_rps: float = 20.0
    duration_s: float = 120.0
    slo_multipliers: Tuple[float, ...] = DEFAULT_MULTIPLIERS
    max_gpus: int = 64
    colocated: bool = False
    fleet: Optional[Tuple[Tuple[str, int], ...]] = None
    lifecycle: Optional[LifecycleConfig] = None
    faults: Optional[FaultModel] = None
    resilience: Optional[ResilienceConfig] = None
    sim_overrides: Optional[Dict] = None
    width: int = 1

    def with_(self, **overrides) -> "Scenario":
        """A derived scenario (e.g. another arch, horizon, or fleet)."""
        return dataclasses.replace(self, **overrides)

    def fn_specs(self):
        """The ``FnSpec`` list this scenario serves: one per arch, or —
        for ``width > 1`` fleets — ``width`` variant-labelled tenants
        cycling round-robin through the arch list."""
        if self.width <= 1:
            return [FnSpec(ARCHS[a]) for a in self.archs]
        return [FnSpec(ARCHS[self.archs[i % len(self.archs)]],
                       variant=f"w{i:04d}")
                for i in range(self.width)]

    def make_recon(self, fleet=None) -> Reconfigurator:
        """Build this scenario's cluster. ``fleet`` overrides the
        scenario's own fleet declaration (used by benchmark CLIs to
        force e.g. an all-premium fleet); None falls through to the
        scenario default."""
        fleet = fleet if fleet is not None else self.fleet
        if fleet is not None:
            return Reconfigurator(num_gpus=0, fleet=fleet)
        return Reconfigurator(num_gpus=0, max_gpus=self.max_gpus)

    def arrivals_for(self, fn_index: int, duration_s: float,
                     base_rps: float, seed: int) -> np.ndarray:
        """The (decorrelated) arrival-time trace of function
        ``fn_index`` for one run of this scenario."""
        return self.trace(duration_s, base_rps,
                          seed + _FN_SEED_STRIDE * fn_index)

    def run(self, policy: str = "has", seed: int = 0,
            duration_s: Optional[float] = None,
            base_rps: Optional[float] = None,
            policy_factory: Optional[Callable] = None,
            fleet=None, engine_cls=None) -> "ScenarioOutcome":
        """Simulate this scenario under ``policy`` and fold the run into
        a ``RunMetrics``.

        Args:
            policy: registered policy name (``has``/``kserve``/``fast``).
            seed: RNG seed for traces and service noise.
            duration_s/base_rps: optional overrides of the scenario's
                horizon and load.
            policy_factory: ``(policy_name, recon) -> policy`` hook for
                ablations substituting custom-configured policies.
            fleet: fleet-declaration override (see ``make_recon``).
            engine_cls: event-engine override (the scalar reference
                ``core/engine_scalar.py`` for parity/benchmark runs);
                None uses the default wide engine.
        Returns: a ``ScenarioOutcome`` with the run's ``RunMetrics``,
        the engine-level result object, and the simulator itself.
        """
        dur = self.duration_s if duration_s is None else duration_s
        rps = self.base_rps if base_rps is None else base_rps
        specs = self.fn_specs()
        recon = self.make_recon(fleet)
        lc = self.lifecycle
        if lc is not None:
            if policy != "has":
                # baselines get the same start-latency physics but no
                # cache / keep-warm / pre-warming — isolating what the
                # lifecycle machinery (not the physics) buys HAS
                lc = dataclasses.replace(lc, host_cache_gb=0.0,
                                         keep_warm_pods=0,
                                         prewarm_lead_s=0.0)
            recon.attach_modelstate(ModelStateTracker(lc))
        whole = POLICIES[policy][1]
        overrides = dict(self.sim_overrides or {})
        if (overrides.get("stream_metrics")
                and "stream_slo_multipliers" not in overrides):
            # the streaming sink must track exactly the multipliers the
            # RunMetrics fold will ask for
            overrides["stream_slo_multipliers"] = tuple(self.slo_multipliers)
        cfg = SimConfig(duration_s=dur, whole_gpu_cost=whole, seed=seed,
                        faults=self.faults, resilience=self.resilience,
                        **overrides)
        factory = policy_factory or make_policy
        ekw = {} if engine_cls is None else {"engine_cls": engine_cls}
        if self.colocated or len(specs) > 1:
            policies, arrs = {}, {}
            for i, spec in enumerate(specs):
                pol = factory(policy, recon)
                pol.prewarm(spec, rps)
                policies[spec.fn_id] = pol
                arrs[spec.fn_id] = self.arrivals_for(i, dur, rps, seed)
            sim = MultiFunctionSimulator(specs, policies, recon, arrs, cfg,
                                         **ekw)
        else:
            pol = factory(policy, recon)
            pol.prewarm(specs[0], rps)
            sim = ClusterSimulator(specs[0], pol, recon,
                                   self.arrivals_for(0, dur, rps, seed), cfg,
                                   **ekw)
        if recon.modelstate is not None:
            # deploy-time prewarm placements are not run-time starts
            # (the engine adopted lc.idle_retention_factor on its own)
            recon.modelstate.reset_stats()
        result = sim.run()
        metrics = RunMetrics.from_sim(sim, self.name, policy, seed,
                                      self.slo_multipliers)
        return ScenarioOutcome(metrics=metrics, result=result,
                               simulator=sim)


@dataclasses.dataclass
class ScenarioOutcome:
    """What one ``Scenario.run`` returns: the unified ``RunMetrics``
    record (what goldens pin), the engine-level result object, and the
    simulator itself for introspection."""
    metrics: RunMetrics
    result: object       # SimResult or MultiSimResult
    simulator: object    # ClusterSimulator or MultiFunctionSimulator


# ---- registry --------------------------------------------------------------

SCENARIOS: Dict[str, Scenario] = {}


def register(scenario: Scenario) -> Scenario:
    """Add ``scenario`` to the registry (its golden must be generated
    alongside — see docs/scenarios.md). Raises ValueError on duplicate
    names; returns the scenario for chaining."""
    if scenario.name in SCENARIOS:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    SCENARIOS[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    """Look up a registered scenario by name; KeyError lists the
    registered names on a miss."""
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; registered: "
                       f"{', '.join(scenario_names())}") from None


def scenario_names():
    """Sorted names of every registered scenario."""
    return sorted(SCENARIOS)


register(Scenario(
    name="steady_poisson",
    description="Constant-rate Poisson arrivals — the smooth-load control "
                "case where all policies should look alike.",
    trace=generators.homogeneous_poisson))

register(Scenario(
    name="mmpp_burst",
    description="Two-state MMPP: calm base load with abrupt 5x bursts "
                "(regime switches faster than the diurnal drift).",
    trace=lambda d, r, s: generators.mmpp(d, r, burst_multiplier=5.0,
                                          mean_calm_s=25.0, mean_burst_s=6.0,
                                          seed=s)))

register(Scenario(
    name="diurnal",
    description="Sinusoidal day/night swing — slow drift the Kalman "
                "predictor should track without overshoot.",
    trace=lambda d, r, s: generators.diurnal(d, r, amplitude=0.7,
                                             period_s=180.0, seed=s)))

register(Scenario(
    name="flash_crowd",
    description="Steady base with one violent 8x spike (ramp/hold/decay) "
                "— the cold-start and scale-up stress case.",
    trace=lambda d, r, s: generators.flash_crowd(d, r, spike_multiplier=8.0,
                                                 ramp_s=5.0, hold_s=15.0,
                                                 seed=s)))

register(Scenario(
    name="ramp_up",
    description="Linear rate sweep from 20% to 200% of base — sustained "
                "growth exercising steady scale-up.",
    trace=lambda d, r, s: generators.ramp(d, 0.2 * r, 2.0 * r, seed=s)))

register(Scenario(
    name="azure_standard",
    description="Azure-Functions-style replay (diurnal + Poisson + "
                "heavy-tailed bursts + idle gaps) — paper §4 standard.",
    trace=lambda d, r, s: azure.standard_workload(d, r, seed=s),
    base_rps=25.0))

register(Scenario(
    name="azure_stress",
    description="Azure-style replay at stress intensity (higher base, "
                "more and bigger bursts) — paper Fig 7 stress.",
    trace=lambda d, r, s: azure.stress_workload(d, r, seed=s),
    base_rps=40.0))

register(Scenario(
    name="colocated_mix",
    description="Three architectures (dense/SSM/audio) co-located on one "
                "shared cluster under Azure-style load — where HGO "
                "placement and SM alignment matter.",
    trace=lambda d, r, s: azure.standard_workload(d, r, seed=s),
    archs=("olmo-1b", "mamba2-2.7b", "whisper-medium"),
    base_rps=12.0,
    max_gpus=96,
    colocated=True))

register(Scenario(
    name="azure_wide",
    description="Azure-Functions-style replay at fleet width: 400 tenant "
                "functions (a dense/SSM/audio arch mix, round-robin) "
                "co-located on one cluster, each with a long-tail "
                "low-rate trace — the multi-tenant regime the wide "
                "engine's struct-of-arrays batching targets. Runs with "
                "streaming metrics (constant-memory latency sketch) and "
                "per-function rng isolation; only practical post-PR-9.",
    trace=lambda d, r, s: azure.standard_workload(d, r, seed=s),
    archs=("olmo-1b", "mamba2-2.7b", "whisper-medium"),
    base_rps=2.0,
    max_gpus=96,
    colocated=True,
    width=400,
    sim_overrides={"stream_metrics": True, "rng_isolation": True}))

register(Scenario(
    name="het_mix",
    description="Diurnal load on a mixed a10g/a100/h100/t4 fleet — "
                "placement-aware scheduling fills the cheap SLO-capable "
                "a10g pool first and overflows onto premium chips, "
                "undercutting an all-premium fleet severalfold in USD "
                "(fig6 --scenario het_mix [--fleet all_premium]).",
    trace=lambda d, r, s: generators.diurnal(d, r, amplitude=0.7,
                                             period_s=180.0, seed=s),
    base_rps=25.0,
    fleet=(("a10g", 24), ("a100", 8), ("h100", 4), ("t4", 16))))

register(Scenario(
    name="scale_to_zero_lru",
    description="On/off multi-tenant-style load (calm near-idle phases, "
                "abrupt 15x bursts) under the model-state lifecycle engine: "
                "scale-downs demote weights to the node host-RAM LRU cache "
                "and one keep-warm pod stays parked, so burst re-scale-ups "
                "start warm/hot instead of cold.",
    trace=lambda d, r, s: generators.mmpp(d, r, burst_multiplier=15.0,
                                          mean_calm_s=14.0, mean_burst_s=6.0,
                                          seed=s),
    base_rps=10.0,
    lifecycle=dataclasses.replace(LIFECYCLE_CACHED, host_cache_gb=8.0)))

register(Scenario(
    name="multi_tenant_churn",
    description="Three architectures churning in and out on one cluster "
                "with a host-RAM weight-cache budget smaller than the sum "
                "of their weights — LRU eviction pressure decides which "
                "re-scale-ups stay warm (no keep-warm pods: the cache is "
                "the only lifecycle mechanism at work).",
    trace=lambda d, r, s: generators.mmpp(d, r, burst_multiplier=8.0,
                                          mean_calm_s=12.0, mean_burst_s=5.0,
                                          seed=s),
    archs=("olmo-1b", "mamba2-2.7b", "whisper-medium"),
    base_rps=8.0,
    max_gpus=96,
    colocated=True,
    lifecycle=LifecycleConfig(derive_from_physics=True, host_cache_gb=6.0)))

register(Scenario(
    name="flash_crowd_prewarm",
    description="The flash_crowd spike under forecast-driven pre-warming: "
                "the Kalman slope projected prewarm_lead_s ahead starts "
                "weight fetches onto the likely placement nodes before the "
                "wave lands, so scale-up pods start warm — strictly fewer "
                "cold starts and lower SLO violations than reactive HAS "
                "on the same trace (the paper's cold-start argument, "
                "quantified).",
    trace=lambda d, r, s: generators.flash_crowd(d, r, spike_multiplier=8.0,
                                                 ramp_s=5.0, hold_s=15.0,
                                                 seed=s),
    lifecycle=LIFECYCLE_PREWARM))

register(Scenario(
    name="spot_t4_burst",
    description="Spot-first serving: calm load rides cheap t4 slivers "
                "(eligible only at small batches, ~90 rps ceiling per "
                "chip); a 10x flash crowd exceeds every spot-eligible "
                "config, so burst capacity provisions on the on-demand "
                "a100 pool and is released when the spike drains.",
    trace=lambda d, r, s: generators.flash_crowd(d, r,
                                                 spike_multiplier=10.0,
                                                 ramp_s=2.0, hold_s=20.0,
                                                 seed=s),
    base_rps=30.0,
    fleet=(("t4", 16), ("a100", 4))))


# ---- spot preemption scenarios ---------------------------------------------
#
# Markets are tuned so the interesting dynamics land inside the 45 s
# golden window: the EVENING market's correlated storm (60x hazard for
# 8 s every 90 s, first at t=12 s) coincides with the diurnal load
# peak; the STORM market reclaims hard enough that an all-spot fleet
# visibly bleeds SLO during drains.

#: Evening-peak spot market: deep discount, calm base hazard, one
#: correlated reclaim storm per diurnal period aligned with the load peak.
SPOT_MARKET_EVENING = GPUMarket(price_multiplier=0.20,
                                reclaim_rate_per_hour=4.0,
                                grace_period_s=6.0,
                                storm_multiplier=60.0,
                                storm_period_s=90.0,
                                storm_duration_s=8.0,
                                storm_start_s=12.0)

#: Violent reclaim regime: high base hazard, short grace, frequent storms.
SPOT_MARKET_STORM = GPUMarket(price_multiplier=0.30,
                              reclaim_rate_per_hour=12.0,
                              grace_period_s=4.0,
                              storm_multiplier=40.0,
                              storm_period_s=60.0,
                              storm_duration_s=10.0,
                              storm_start_s=15.0)

#: The spot flavor of the reference chip under each market.
V5E_SPOT_EVENING = spot("v5e", SPOT_MARKET_EVENING)
V5E_SPOT_STORM = spot("v5e", SPOT_MARKET_STORM)

_DIURNAL_RECLAIM = Scenario(
    name="diurnal_spot_reclaims",
    description="Diurnal swing on a mixed on-demand/spot v5e fleet whose "
                "spot pool suffers correlated evening reclaims (the "
                "provider draining capacity exactly at the load peak). "
                "The hybrid router keeps an always-warm on-demand floor, "
                "rides the 0.2x spot discount while the market is calm, "
                "and shifts overflow back on-demand when reclaim "
                "pressure spikes — cheaper than the all-on-demand "
                "variant, fewer SLO violations than the all-spot one.",
    trace=lambda d, r, s: generators.diurnal(d, r, amplitude=0.7,
                                             period_s=90.0, seed=s),
    base_rps=400.0,
    fleet=(("v5e", 6), (V5E_SPOT_EVENING, 24)))
register(_DIURNAL_RECLAIM)

register(_DIURNAL_RECLAIM.with_(
    name="diurnal_spot_ondemand",
    description="All-on-demand control for diurnal_spot_reclaims: the "
                "identical trace served entirely from reliable v5e "
                "capacity — zero preemptions, full price. The spot pool "
                "is declared at zero capacity so the run exercises the "
                "exact same heterogeneous control-plane paths as the "
                "hybrid, isolating the router's availability decision. "
                "The cost ceiling the hybrid router must undercut.",
    fleet=(("v5e", 30), (V5E_SPOT_EVENING, 0))))

register(_DIURNAL_RECLAIM.with_(
    name="diurnal_spot_allspot",
    description="All-spot control for diurnal_spot_reclaims: the "
                "identical trace served entirely from reclaimable "
                "capacity (the on-demand v5e pool is declared at zero "
                "capacity, keeping the control-plane paths identical to "
                "the hybrid's). Maximum discount, but every evening "
                "storm tears capacity out right at the load peak — the "
                "SLO floor the hybrid router must beat.",
    fleet=(("v5e", 0), (V5E_SPOT_EVENING, 30))))

register(Scenario(
    name="spot_reclaim_storm",
    description="Steady load on a thin on-demand floor plus a large spot "
                "pool under a violent reclaim regime (12/hr base hazard, "
                "40x storms, 4 s grace): a drain-and-replace stress test "
                "of the RECLAIM_NOTICE/RECLAIM_KILL path — grace-window "
                "draining, in-flight requeue at queue head, and "
                "replacement capacity inside the window.",
    trace=generators.homogeneous_poisson,
    base_rps=600.0,
    fleet=(("v5e", 4), (V5E_SPOT_STORM, 24))))


# ---- fault-injection scenarios ---------------------------------------------
#
# Each scenario arms the core/faults.py engine and ships with a
# resilience-off control sharing the identical trace and fault draws,
# so the goldens pin what each mitigation buys (and costs). Tuned so
# the interesting dynamics land inside the 45 s golden window at
# seed 42: the chip wave sees ~3 hard failures, the straggler regime
# trips multiple quarantines, and the brownout runs saturated
# end-to-end.

_CHIP_FAILURE_WAVE = Scenario(
    name="chip_failure_wave",
    description="Steady load on a capped fleet under a hard-failure "
                "regime (~3 instant chip losses in the window, no grace, "
                "no reclaim notice). In-flight batches on the dead chip "
                "are killed mid-service; the retry policy (2 retries, "
                "0.5 s backoff, 10 s deadline) re-queues them instead of "
                "dropping — zero killed-request drops versus the "
                "control's mid-flight losses, at identical cost. MTTR "
                "and availability meter the repair loop (replacement "
                "capacity re-provisioned by the autoscaler).",
    trace=generators.homogeneous_poisson,
    base_rps=300.0,
    max_gpus=6,
    faults=FaultModel(chip_failure_rate_per_hour=120.0),
    resilience=ResilienceConfig(deadline_s=10.0, max_retries=2,
                                retry_backoff_s=0.5),
    # in-flight work on a hard-failed chip is unrecoverable unless a
    # retry policy exists: the legacy all-or-nothing requeue is off so
    # the control actually loses what the retry policy saves
    sim_overrides={"reclaim_requeue": False, "drop_after_s": 15.0})
register(_CHIP_FAILURE_WAVE)

register(_CHIP_FAILURE_WAVE.with_(
    name="chip_failure_wave_control",
    description="Resilience-off control for chip_failure_wave: the "
                "identical trace and failure draws with no retry "
                "policy — every batch in flight on a dying chip is "
                "dropped on the floor (killed-drop accounting). The "
                "goodput floor the retry policy must beat.",
    resilience=None))

_STRAGGLER_TAIL = Scenario(
    name="straggler_tail",
    description="Steady load where pods intermittently degrade to 10x "
                "service time for ~30 s (thermal throttling / noisy "
                "neighbor). Health scoring (EWMA observed-vs-predicted "
                "service ratio) quarantines the degraded pod out of "
                "dispatch after 2 slow batches; the keep-warm pool "
                "(model-state lifecycle) backfills warm so the bench "
                "costs little — p99 and SLO violations both land well "
                "under the quarantine-off control at <10% extra cost.",
    trace=generators.homogeneous_poisson,
    base_rps=300.0,
    max_gpus=6,
    lifecycle=LIFECYCLE_CACHED,
    faults=FaultModel(straggler_rate_per_hour=50.0, straggler_factor=10.0,
                      straggler_duration_s=30.0),
    resilience=ResilienceConfig(quarantine_ratio=3.0,
                                quarantine_min_samples=2,
                                quarantine_duration_s=10.0))
register(_STRAGGLER_TAIL)

register(_STRAGGLER_TAIL.with_(
    name="straggler_tail_control",
    description="Quarantine-off control for straggler_tail: identical "
                "trace, stragglers, and keep-warm lifecycle, but "
                "degraded pods keep pulling batches — every batch they "
                "take is a 10x-latency batch, setting the tail the "
                "health scorer must cut.",
    resilience=None))

_BROWNOUT_OVERLOAD = Scenario(
    name="brownout_overload",
    description="Sustained arrivals beyond what the one-chip fleet can "
                "serve inside SLO. Admission control brownout-sheds "
                "lowest-headroom requests at arrival (queue capped at "
                "est_capacity * deadline * headroom with an SLO-scale "
                "50 ms deadline), so admitted requests still meet SLO "
                "instead of everything aging into violation — the "
                "2.0x violation rate drops well below the shed-nothing "
                "control at identical cost.",
    trace=generators.homogeneous_poisson,
    base_rps=400.0,
    max_gpus=1,
    resilience=ResilienceConfig(deadline_s=0.05, max_retries=0,
                                admission_headroom=0.5),
    sim_overrides={"drop_after_s": 10.0})
register(_BROWNOUT_OVERLOAD)

register(_BROWNOUT_OVERLOAD.with_(
    name="brownout_overload_control",
    description="Admission-off control for brownout_overload: the "
                "identical saturating trace with no shedding — queues "
                "grow until drop-after aging, nearly every request "
                "violates 2.0x SLO. The violation ceiling brownout "
                "shedding must undercut.",
    resilience=None))
