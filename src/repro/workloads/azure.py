"""Azure-Functions-trace-style workload synthesis (paper §4: Azure Trace
[Zhang et al., SOSP'21] replayed through Grafana k6).

The public trace's per-function invocation series are well modeled by a
diurnal base rate + Poisson arrivals + heavy-tailed bursts + idle gaps.
Generators are deterministic per seed. Rates are per-second.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TraceConfig:
    duration_s: float = 300.0
    base_rps: float = 20.0
    diurnal_amplitude: float = 0.5    # relative swing of the slow wave
    diurnal_period_s: float = 240.0
    burst_rate_per_min: float = 1.5   # Poisson rate of burst onsets
    burst_multiplier: float = 4.0     # peak rate multiple during a burst
    burst_duration_s: float = 12.0
    idle_prob: float = 0.08           # chance a 30s block goes near-idle
    seed: int = 0


def rate_series(cfg: TraceConfig, dt: float = 1.0) -> np.ndarray:
    """Target request rate lambda(t) sampled every dt seconds."""
    rng = np.random.default_rng(cfg.seed)
    t = np.arange(0.0, cfg.duration_s, dt)
    lam = cfg.base_rps * (1.0 + cfg.diurnal_amplitude *
                          np.sin(2 * np.pi * t / cfg.diurnal_period_s))
    # bursts (non-stacking: overlapping bursts take the max multiplier)
    burst_mult = np.ones_like(lam)
    n_bursts = rng.poisson(cfg.burst_rate_per_min * cfg.duration_s / 60.0)
    for _ in range(n_bursts):
        onset = rng.uniform(0, cfg.duration_s)
        dur = rng.exponential(cfg.burst_duration_s)
        mult = 1.0 + rng.exponential(cfg.burst_multiplier - 1.0)
        mask = (t >= onset) & (t < onset + dur)
        burst_mult[mask] = np.maximum(burst_mult[mask], mult)
    lam = lam * burst_mult
    # idle blocks
    block = 30.0
    for b0 in np.arange(0, cfg.duration_s, block):
        if rng.uniform() < cfg.idle_prob:
            lam[(t >= b0) & (t < b0 + block)] *= 0.05
    return np.maximum(lam, 0.0)


def arrivals(cfg: TraceConfig, dt: float = 1.0) -> np.ndarray:
    """Poisson arrival times following rate_series (thinning per bin)."""
    rng = np.random.default_rng(cfg.seed + 1)
    lam = rate_series(cfg, dt)
    times = []
    for i, l in enumerate(lam):
        n = rng.poisson(l * dt)
        times.append(rng.uniform(i * dt, (i + 1) * dt, size=n))
    out = np.sort(np.concatenate(times)) if times else np.array([])
    return out


def standard_workload(duration_s=300.0, base_rps=20.0, seed=0) -> np.ndarray:
    return arrivals(TraceConfig(duration_s=duration_s, base_rps=base_rps,
                                seed=seed))


def rate_series_fast(cfg: TraceConfig, dt: float = 1.0) -> np.ndarray:
    """Vectorized ``rate_series``: the same statistical process (diurnal
    wave x non-stacking bursts x idle blocks) built with slice writes
    and one vectorized idle draw instead of per-burst/per-block boolean
    masks over the full series. Intended for multi-day horizons where
    the scalar builder's O(n_bursts * n_bins) masking dominates.

    NOT bitwise-equal to ``rate_series`` for a given seed — the rng
    draw order differs — so golden-pinned scenarios must keep using the
    scalar builder; this one feeds the replay-scale benchmarks.
    """
    rng = np.random.default_rng(cfg.seed)
    n = int(np.ceil(cfg.duration_s / dt))
    t = np.arange(n) * dt
    lam = cfg.base_rps * (1.0 + cfg.diurnal_amplitude *
                          np.sin(2 * np.pi * t / cfg.diurnal_period_s))
    n_bursts = rng.poisson(cfg.burst_rate_per_min * cfg.duration_s / 60.0)
    if n_bursts:
        onsets = rng.uniform(0, cfg.duration_s, size=n_bursts)
        durs = rng.exponential(cfg.burst_duration_s, size=n_bursts)
        mults = 1.0 + rng.exponential(cfg.burst_multiplier - 1.0,
                                      size=n_bursts)
        burst_mult = np.ones(n)
        lo = np.searchsorted(t, onsets, side="left")
        hi = np.searchsorted(t, onsets + durs, side="left")
        for i0, i1, m in zip(lo.tolist(), hi.tolist(), mults.tolist()):
            seg = burst_mult[i0:i1]
            np.maximum(seg, m, out=seg)
        lam *= burst_mult
    block = 30.0
    n_blocks = int(np.ceil(cfg.duration_s / block))
    idle = np.where(rng.uniform(size=n_blocks) < cfg.idle_prob, 0.05, 1.0)
    lam *= np.repeat(idle, int(round(block / dt)))[:n]
    return np.maximum(lam, 0.0)


def arrivals_fast(cfg: TraceConfig, dt: float = 1.0) -> np.ndarray:
    """Vectorized ``arrivals``: one Poisson draw per bin and one uniform
    draw for every request, placed by bin index — no per-bin Python
    loop. Same caveat as ``rate_series_fast``: equal in distribution to
    the scalar path, not bitwise."""
    rng = np.random.default_rng(cfg.seed + 1)
    lam = rate_series_fast(cfg, dt)
    counts = rng.poisson(lam * dt)
    total = int(counts.sum())
    if total == 0:
        return np.array([])
    bins = np.repeat(np.arange(len(lam)), counts)
    return np.sort((bins + rng.uniform(size=total)) * dt)


def replay_workload(duration_s=172800.0, base_rps=0.06, seed=0) -> np.ndarray:
    """A multi-day low-rate tenant trace for replay-scale benchmarks
    (``bench_engine --full``): the azure_wide trace family generated by
    the vectorized builders."""
    return arrivals_fast(TraceConfig(duration_s=duration_s,
                                     base_rps=base_rps, seed=seed))


def stress_workload(duration_s=300.0, base_rps=40.0, seed=0) -> np.ndarray:
    """Paper Fig 7 'stress': higher base, more and bigger bursts."""
    return arrivals(TraceConfig(
        duration_s=duration_s, base_rps=base_rps, diurnal_amplitude=0.7,
        burst_rate_per_min=3.0, burst_multiplier=5.0, burst_duration_s=20.0,
        idle_prob=0.03, seed=seed))
