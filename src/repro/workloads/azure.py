"""Azure-Functions-trace-style workload synthesis (paper §4: Azure Trace
[Zhang et al., SOSP'21] replayed through Grafana k6).

The public trace's per-function invocation series are well modeled by a
diurnal base rate + Poisson arrivals + heavy-tailed bursts + idle gaps.
Generators are deterministic per seed. Rates are per-second.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TraceConfig:
    duration_s: float = 300.0
    base_rps: float = 20.0
    diurnal_amplitude: float = 0.5    # relative swing of the slow wave
    diurnal_period_s: float = 240.0
    burst_rate_per_min: float = 1.5   # Poisson rate of burst onsets
    burst_multiplier: float = 4.0     # peak rate multiple during a burst
    burst_duration_s: float = 12.0
    idle_prob: float = 0.08           # chance a 30s block goes near-idle
    seed: int = 0


def rate_series(cfg: TraceConfig, dt: float = 1.0) -> np.ndarray:
    """Target request rate lambda(t) sampled every dt seconds."""
    rng = np.random.default_rng(cfg.seed)
    t = np.arange(0.0, cfg.duration_s, dt)
    lam = cfg.base_rps * (1.0 + cfg.diurnal_amplitude *
                          np.sin(2 * np.pi * t / cfg.diurnal_period_s))
    # bursts (non-stacking: overlapping bursts take the max multiplier)
    burst_mult = np.ones_like(lam)
    n_bursts = rng.poisson(cfg.burst_rate_per_min * cfg.duration_s / 60.0)
    for _ in range(n_bursts):
        onset = rng.uniform(0, cfg.duration_s)
        dur = rng.exponential(cfg.burst_duration_s)
        mult = 1.0 + rng.exponential(cfg.burst_multiplier - 1.0)
        mask = (t >= onset) & (t < onset + dur)
        burst_mult[mask] = np.maximum(burst_mult[mask], mult)
    lam = lam * burst_mult
    # idle blocks
    block = 30.0
    for b0 in np.arange(0, cfg.duration_s, block):
        if rng.uniform() < cfg.idle_prob:
            lam[(t >= b0) & (t < b0 + block)] *= 0.05
    return np.maximum(lam, 0.0)


def arrivals(cfg: TraceConfig, dt: float = 1.0) -> np.ndarray:
    """Poisson arrival times following rate_series (thinning per bin)."""
    rng = np.random.default_rng(cfg.seed + 1)
    lam = rate_series(cfg, dt)
    times = []
    for i, l in enumerate(lam):
        n = rng.poisson(l * dt)
        times.append(rng.uniform(i * dt, (i + 1) * dt, size=n))
    out = np.sort(np.concatenate(times)) if times else np.array([])
    return out


def standard_workload(duration_s=300.0, base_rps=20.0, seed=0) -> np.ndarray:
    return arrivals(TraceConfig(duration_s=duration_s, base_rps=base_rps,
                                seed=seed))


def stress_workload(duration_s=300.0, base_rps=40.0, seed=0) -> np.ndarray:
    """Paper Fig 7 'stress': higher base, more and bigger bursts."""
    return arrivals(TraceConfig(
        duration_s=duration_s, base_rps=base_rps, diurnal_amplitude=0.7,
        burst_rate_per_min=3.0, burst_multiplier=5.0, burst_duration_s=20.0,
        idle_prob=0.03, seed=seed))
