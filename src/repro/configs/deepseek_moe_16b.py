"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066].

28L d_model=2048 16H (kv=16) d_ff=1408 (per-expert) vocab=102400.
First layer uses a dense FFN (d_ff 10944) per the paper.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="arXiv:2401.06066 (DeepSeekMoE 16B)",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    moe=MoEConfig(
        num_experts=64,
        experts_per_token=6,
        num_shared_experts=2,
        first_dense=1,
        d_ff_dense=10944,
    ),
    norm="rmsnorm",
    act="silu",
    long_context_window=8192,
)
