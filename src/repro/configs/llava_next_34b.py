"""llava-next-34b — VLM with anyres tiling, stubbed vision tower
[hf:llava-hf/llava-v1.6 family].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000. The ViT/SigLIP
vision encoder + projector is a STUB per the assignment: ``input_specs()``
provides precomputed anyres patch embeddings (2880 visual tokens, i.e.
a 2x2 tile grid + base image at 576 patches each) consumed by the
language decoder.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (anyres); 34b backbone per assignment",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    norm="rmsnorm",
    act="silu",
    rope_theta=5_000_000.0,
    num_visual_tokens=2880,  # anyres: 5 tiles x 576 patches
    long_context_window=8192,
)
