"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave with MoE
[arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
Attention appears once every 8 layers (offset 4 per the paper's block
layout); MoE replaces the FFN every 2 layers (odd layers).
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    source="arXiv:2403.19887 (Jamba v0.1)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    moe=MoEConfig(num_experts=16, experts_per_token=2, every=2, first_dense=1),
    ssm=SSMConfig(d_state=16, expand=2, head_dim=64, n_groups=1),
    attn_period=8,
    attn_offset=4,
    norm="rmsnorm",
    act="silu",
)
