"""command-r-35b — dense GQA, no bias anywhere [hf:CohereForAI/c4ai-command-r-v01].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    norm="layernorm",
    act="silu",
    rope_theta=8_000_000.0,
    tie_embeddings=True,
    long_context_window=8192,
)
