"""Config registry: the 10 assigned architectures, the 4 input shapes,
and the GPU-type catalogue for heterogeneous fleets."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, ShapeConfig, reduced
from repro.configs.gpus import (DEFAULT_GPU_TYPE, GPU_TYPES, GPUType,
                                fleet_from_names, get_gpu_type)
from repro.configs.shapes import SHAPES, get_shape

from repro.configs import (
    mamba2_2p7b,
    dbrx_132b,
    whisper_medium,
    qwen2p5_3b,
    jamba_v0p1_52b,
    llava_next_34b,
    deepseek_moe_16b,
    gemma_7b,
    command_r_35b,
    olmo_1b,
)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        mamba2_2p7b,
        dbrx_132b,
        whisper_medium,
        qwen2p5_3b,
        jamba_v0p1_52b,
        llava_next_34b,
        deepseek_moe_16b,
        gemma_7b,
        command_r_35b,
        olmo_1b,
    )
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)


def combo_is_supported(arch: str, shape: str) -> bool:
    """Whether (arch x shape) is a supported dry-run combination.

    The only principled skip: whisper-medium x long_500k (a 500k-token
    decoder transcript has no audio analogue — DESIGN.md §Arch-applicability).
    """
    if shape == "long_500k" and arch == "whisper-medium":
        return False
    return True


__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "reduced",
    "SHAPES", "get_shape", "ARCHS", "get_config", "list_archs",
    "combo_is_supported",
    "GPUType", "GPU_TYPES", "DEFAULT_GPU_TYPE", "get_gpu_type",
    "fleet_from_names",
]
