"""whisper-medium — encoder-decoder with stubbed conv/mel frontend
[arXiv:2212.04356].

24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865. The mel-spectrogram +
conv feature extractor is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (1500 x d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    source="arXiv:2212.04356 (Whisper), whisper-medium card",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    norm="layernorm",
    act="gelu_plain",
    qkv_bias=True,
    pos_emb="learned",
    is_encoder_decoder=True,
    encoder_layers=24,
    encoder_seq=1500,
    # long_500k skipped: a 500k-token decoder transcript has no audio
    # analogue (30s audio = 1500 frames). See DESIGN.md §Arch-applicability.
)
