"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060].

64L d_model=2560, attention-free, d_ff=0, vocab=50280, ssm_state=128.
Mamba2 block: expand=2 -> d_inner=5120, head_dim=64 -> 80 SSD heads.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060 (Mamba2 SSD), mamba2-2.7b model card",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=8, conv_width=4),
    norm="rmsnorm",
    tie_embeddings=True,
)
