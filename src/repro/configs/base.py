"""Architecture and input-shape configuration dataclasses.

Every assigned architecture is expressed as an ``ArchConfig``; the four
assigned input shapes are ``ShapeConfig``s. Full configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation); smoke tests use
``reduced()`` variants.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    num_shared_experts: int = 0
    # Apply MoE every `every` layers (1 = every layer). Jamba: every 2.
    every: int = 1
    # Number of leading layers that use a dense FFN instead (deepseek-moe: 1).
    first_dense: int = 0
    # Dense-FFN hidden size for `first_dense` layers (0 -> use arch d_ff).
    d_ff_dense: int = 0
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba/Mamba2 (SSD) block configuration."""
    d_state: int
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 8  # B/C projection groups (shardable analogue of GQA)
    conv_width: int = 4
    chunk_size: int = 256  # SSD chunked scan block size

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    source: str  # citation for the config values
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Hybrid interleave: attention appears once per `attn_period` layers at
    # offset `attn_offset`; all other layers are SSM blocks. 0 = not hybrid.
    attn_period: int = 0
    attn_offset: int = 0
    # Attention details
    qkv_bias: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric_ln
    act: str = "silu"  # silu -> SwiGLU, gelu -> GeGLU, gelu_plain -> plain MLP
    rope_theta: float = 10_000.0
    pos_emb: str = "rope"  # rope | learned
    max_learned_pos: int = 32_768  # table size when pos_emb == "learned"
    tie_embeddings: bool = False
    # Encoder-decoder (whisper): encoder consumes stubbed frame embeddings.
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0
    # VLM: number of stubbed visual-patch embedding tokens prepended to text.
    num_visual_tokens: int = 0
    # Window used for the long_500k sliding-window variant on full-attention
    # archs (0 = arch is natively sub-quadratic or long_500k is skipped).
    long_context_window: int = 0
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    # ---- derived ----
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' for layer i of the mixer stack."""
        if self.family == "ssm":
            return "ssm"
        if self.attn_period:
            return "attn" if i % self.attn_period == self.attn_offset else "ssm"
        return "attn"

    def ffn_kind(self, i: int) -> str:
        """'moe' or 'dense' for layer i."""
        if self.moe is None:
            return "dense"
        if i < self.moe.first_dense:
            return "dense"
        if (i - self.moe.first_dense) % self.moe.every == 0:
            return "moe"
        return "dense"

    @functools.lru_cache(maxsize=None)
    def param_count(self) -> int:
        """Approximate total parameter count (embeddings included).

        Memoized (the config is frozen): the perf model and RaPP feature
        extraction evaluate this in per-event hot loops."""
        d, f = self.d_model, self.d_ff
        hd = self.head_dim
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d
        if self.act in ("silu", "gelu"):
            ffn_dense = 3 * d * f
        else:
            ffn_dense = 2 * d * f
        total = 0
        for i in range(self.num_layers):
            if self.layer_kind(i) == "attn":
                total += attn
            else:
                s = self.ssm
                di = s.d_inner(d)
                nh = s.n_heads(d)
                # in_proj (z,x,B,C,dt) + conv + out_proj
                total += d * (2 * di + 2 * s.n_groups * s.d_state + nh) \
                    + s.conv_width * (di + 2 * s.n_groups * s.d_state) \
                    + di * d + 2 * nh
            kind = self.ffn_kind(i)
            if self.family == "ssm":
                pass  # mamba2 has no separate FFN
            elif kind == "moe":
                m = self.moe
                fe = f
                total += (m.num_experts + m.num_shared_experts) * 3 * d * fe
                total += d * m.num_experts  # router
            else:
                fd = (self.moe.d_ff_dense or f) if (self.moe and self.ffn_kind(i) == "dense" and self.moe.first_dense and i < self.moe.first_dense) else f
                total += 3 * d * fd if self.act in ("silu", "gelu") else 2 * d * fd
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.is_encoder_decoder:
            total += self.encoder_layers * (attn + (2 * d * f if self.act == "gelu_plain" else 3 * d * f))
            total += self.num_layers * attn  # cross-attention
        return total

    @functools.lru_cache(maxsize=None)
    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        m, d, f = self.moe, self.d_model, self.d_ff
        n_moe_layers = sum(1 for i in range(self.num_layers) if self.ffn_kind(i) == "moe")
        inactive = n_moe_layers * (m.num_experts - m.experts_per_token) * 3 * d * f
        return full - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family variant for CPU smoke tests.

    2 layers, d_model<=512, <=4 experts, small vocab — per assignment spec.
    """
    d_model = min(cfg.d_model, 256)
    num_heads = min(cfg.num_heads, 4)
    ratio = max(cfg.num_heads // max(cfg.num_kv_heads, 1), 1)
    num_kv_heads = max(num_heads // ratio, 1)
    updates = dict(
        num_layers=2,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv_heads,
        head_dim=64,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        num_visual_tokens=min(cfg.num_visual_tokens, 16),
    )
    if cfg.moe is not None:
        updates["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            experts_per_token=min(cfg.moe.experts_per_token, 2),
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            first_dense=min(cfg.moe.first_dense, 1),
            d_ff_dense=min(cfg.moe.d_ff_dense, 512) if cfg.moe.d_ff_dense else 0,
        )
    if cfg.ssm is not None:
        updates["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=min(cfg.ssm.d_state, 64), n_groups=1,
            head_dim=32, chunk_size=64,
        )
    if cfg.attn_period:
        # keep the hybrid interleave visible in 2 layers: 1 ssm + 1 attn
        updates["attn_period"] = 2
        updates["attn_offset"] = 1
    if cfg.is_encoder_decoder:
        updates["encoder_layers"] = 2
        updates["encoder_seq"] = min(cfg.encoder_seq, 64)
    if cfg.long_context_window:
        updates["long_context_window"] = 64
    return dataclasses.replace(cfg, **updates)
