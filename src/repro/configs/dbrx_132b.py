"""dbrx-132b — 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    moe=MoEConfig(num_experts=16, experts_per_token=4),
    norm="layernorm",
    act="silu",
    rope_theta=500_000.0,
    long_context_window=8192,
)
