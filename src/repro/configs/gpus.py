"""GPU type registry: the heterogeneous-fleet device catalogue.

HAS-GPU's cost argument rests on picking the cheapest (SM, quota)
configuration that still meets the SLO; real clusters offer that choice
across *device types* with different slice counts, peak FLOPs, HBM
bandwidth, and $/hour. A ``GPUType`` is the immutable descriptor of one
such device class — the simulator's roofline physics
(``core/perf_model.py``), the control plane's capacity tables
(``core/capacity.py``), cost accounting (``core/cost.py``), and the
placement-aware scheduler (``core/scheduler.py``) are all parameterized
by it.

``DEFAULT_GPU_TYPE`` carries exactly the constants the simulator was
born with (a TPU v5e-class chip billed at the Google Cloud V100 price,
paper Fig 7), so an all-default fleet reproduces every pre-heterogeneity
golden trace bitwise. The other presets form a deliberate capability /
value ladder around it:

  =========  ======  ==========  =========  ======  ============
  name       slices  peak FLOPs  HBM BW     $/hour  $ per PFLOPs
  =========  ======  ==========  =========  ======  ============
  t4           4       65e12      320e9      0.53      8.2
  a10g         8      140e12      600e9      1.58     11.3
  v5e          8      197e12      819e9      2.48     12.6
  a100         8      312e12     2039e9      4.10     13.1
  h100         8      989e12     3350e9     14.90     15.1
  =========  ======  ==========  =========  ======  ============

Cheaper types have the better $/FLOP but the worse absolute latency, so
whether a device can serve a function at all depends on the SLO: the
latency cap is anchored to the *reference* device
(``perf_model.slo_baseline``), and a type whose whole-chip latency
exceeds ``slo_multiplier x`` that baseline is only ever used as burst
overflow (the ``spot_t4_burst`` scenario exercises exactly this).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class GPUType:
    """One device class in a (possibly mixed) fleet.

    Args/fields:
        name: registry key, unique across ``GPU_TYPES``.
        sm_total: vGPU slice granularity of one chip of this type — a
            pod's spatial allocation is ``sm in 1..sm_total`` slices.
        peak_flops: peak sustained FLOP/s of the whole chip.
        hbm_bw: HBM bandwidth in bytes/s of the whole chip.
        price_per_hour: on-demand $/hour for the whole chip; fine-
            grained billing charges ``(sm / sm_total) * quota`` of it.
        host_to_hbm_bw: host-RAM -> HBM transfer bandwidth in bytes/s
            (the PCIe/interconnect generation of the device class) --
            the model-state lifecycle engine (``core/modelstate.py``)
            derives warm-start weight-load times from it.

    Invariants: all numeric fields are positive; instances are frozen
    (hashable) so they can key capacity-table lattices and memoized
    physics directly.
    """
    name: str
    sm_total: int
    peak_flops: float
    hbm_bw: float
    price_per_hour: float
    host_to_hbm_bw: float = 25e9   # PCIe-gen4-class default

    def __post_init__(self):
        if self.sm_total < 1:
            raise ValueError(f"sm_total={self.sm_total} must be >= 1")
        if min(self.peak_flops, self.hbm_bw, self.price_per_hour,
               self.host_to_hbm_bw) <= 0:
            raise ValueError(f"GPUType {self.name!r}: peak_flops/hbm_bw/"
                             "price_per_hour/host_to_hbm_bw must be "
                             "positive")

    @property
    def price_per_slice_hour(self) -> float:
        """$/hour of one slice at full quota — the scheduler's cheapness
        key when ranking candidate devices."""
        return self.price_per_hour / self.sm_total


# The device the seed simulator modeled: TPU v5e-class peak/bandwidth,
# billed at the Google Cloud V100 price the paper's Fig 7 uses. Every
# pre-heterogeneity golden trace was produced on (implicitly) this type.
DEFAULT_GPU_TYPE = GPUType(name="v5e", sm_total=8, peak_flops=197e12,
                           hbm_bw=819e9, price_per_hour=2.48,
                           host_to_hbm_bw=32e9)

GPU_TYPES: Dict[str, GPUType] = {
    t.name: t
    for t in (
        DEFAULT_GPU_TYPE,
        GPUType(name="h100", sm_total=8, peak_flops=989e12,
                hbm_bw=3.35e12, price_per_hour=14.90,
                host_to_hbm_bw=55e9),
        GPUType(name="a100", sm_total=8, peak_flops=312e12,
                hbm_bw=2.039e12, price_per_hour=4.10,
                host_to_hbm_bw=28e9),
        GPUType(name="a10g", sm_total=8, peak_flops=140e12,
                hbm_bw=600e9, price_per_hour=1.58,
                host_to_hbm_bw=25e9),
        GPUType(name="t4", sm_total=4, peak_flops=65e12,
                hbm_bw=320e9, price_per_hour=0.53,
                host_to_hbm_bw=12e9),
    )
}
GPU_TYPES["default"] = DEFAULT_GPU_TYPE  # alias: the reference device


def get_gpu_type(name) -> GPUType:
    """Resolve a GPU type by registry name (``GPUType`` instances pass
    through unchanged).

    Args:
        name: a key of ``GPU_TYPES`` (``"v5e"``/``"default"``,
            ``"h100"``, ``"a100"``, ``"a10g"``, ``"t4"``) or an already-
            resolved ``GPUType``.
    Returns: the registered ``GPUType`` instance.
    Raises: ``KeyError`` with the available names for unknown keys.
    """
    if isinstance(name, GPUType):
        return name
    try:
        return GPU_TYPES[name]
    except KeyError:
        raise KeyError(f"unknown GPU type {name!r}; available: "
                       f"{sorted(GPU_TYPES)}") from None


def fleet_from_names(fleet) -> Tuple[Tuple[GPUType, int], ...]:
    """Normalize a fleet declaration to ``((GPUType, cap), ...)``.

    Args:
        fleet: iterable of ``(type_name_or_GPUType, max_chips)`` pairs;
            order is the scheduler's tie-break preference order.
    Returns: tuple of ``(GPUType, int cap)`` pairs, same order.
    """
    return tuple((get_gpu_type(n), int(cap)) for n, cap in fleet)
