"""GPU type registry: the heterogeneous-fleet device catalogue.

HAS-GPU's cost argument rests on picking the cheapest (SM, quota)
configuration that still meets the SLO; real clusters offer that choice
across *device types* with different slice counts, peak FLOPs, HBM
bandwidth, and $/hour. A ``GPUType`` is the immutable descriptor of one
such device class — the simulator's roofline physics
(``core/perf_model.py``), the control plane's capacity tables
(``core/capacity.py``), cost accounting (``core/cost.py``), and the
placement-aware scheduler (``core/scheduler.py``) are all parameterized
by it.

``DEFAULT_GPU_TYPE`` carries exactly the constants the simulator was
born with (a TPU v5e-class chip billed at the Google Cloud V100 price,
paper Fig 7), so an all-default fleet reproduces every pre-heterogeneity
golden trace bitwise. The other presets form a deliberate capability /
value ladder around it:

  =========  ======  ==========  =========  ======  ============
  name       slices  peak FLOPs  HBM BW     $/hour  $ per PFLOPs
  =========  ======  ==========  =========  ======  ============
  t4           4       65e12      320e9      0.53      8.2
  a10g         8      140e12      600e9      1.58     11.3
  v5e          8      197e12      819e9      2.48     12.6
  a100         8      312e12     2039e9      4.10     13.1
  h100         8      989e12     3350e9     14.90     15.1
  =========  ======  ==========  =========  ======  ============

Cheaper types have the better $/FLOP but the worse absolute latency, so
whether a device can serve a function at all depends on the SLO: the
latency cap is anchored to the *reference* device
(``perf_model.slo_baseline``), and a type whose whole-chip latency
exceeds ``slo_multiplier x`` that baseline is only ever used as burst
overflow (the ``spot_t4_burst`` scenario exercises exactly this).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class GPUMarket:
    """Spot-market descriptor of a device class: the discounted price
    and the reclaim process that comes with it.

    A ``GPUType`` carrying a market is *spot capacity*: chips of that
    type can be reclaimed by the provider at any time. Reclaims follow
    a per-chip Poisson process with a piecewise-constant hazard — a calm
    base rate (``reclaim_rate_per_hour``) optionally multiplied by
    ``storm_multiplier`` inside deterministic periodic *storm windows*
    (``storm_start_s + k * storm_period_s`` for ``storm_duration_s``
    seconds). Because the windows are shared by every chip of the type,
    storms model *correlated* reclaims — the provider draining a whole
    capacity pool at once (e.g. the evening on-demand peak).

    A reclaim is delivered as a ``RECLAIM_NOTICE`` event opening a
    ``grace_period_s`` drain window, followed by ``RECLAIM_KILL``
    (see ``core/events.py``).

    Fields:
        price_multiplier: spot price as a fraction of the on-demand
            ``price_per_hour`` (``0 <`` x ``<= 1``).
        reclaim_rate_per_hour: base per-chip reclaim hazard (0 = never
            reclaimed; the market is then a pure discount).
        grace_period_s: notice-to-kill drain window.
        storm_multiplier: hazard multiplier inside storm windows
            (>= 1; 1 = no storms).
        storm_period_s: storm window period (0 = no storms).
        storm_duration_s: length of each storm window.
        storm_start_s: start of the first storm window.
    """
    price_multiplier: float = 0.35
    reclaim_rate_per_hour: float = 0.0
    grace_period_s: float = 120.0
    storm_multiplier: float = 1.0
    storm_period_s: float = 0.0
    storm_duration_s: float = 0.0
    storm_start_s: float = 0.0

    def __post_init__(self):
        if not (0.0 < self.price_multiplier <= 1.0):
            raise ValueError(f"price_multiplier={self.price_multiplier} "
                             "must be in (0, 1]")
        if self.reclaim_rate_per_hour < 0 or self.grace_period_s < 0:
            raise ValueError("reclaim_rate_per_hour and grace_period_s "
                             "must be >= 0")
        if self.storm_multiplier < 1.0:
            raise ValueError(f"storm_multiplier={self.storm_multiplier} "
                             "must be >= 1")
        if min(self.storm_period_s, self.storm_duration_s,
               self.storm_start_s) < 0:
            raise ValueError("storm timing fields must be >= 0")
        if 0 < self.storm_period_s <= self.storm_duration_s:
            raise ValueError("storm_duration_s must be shorter than "
                             "storm_period_s")

    @property
    def has_storms(self) -> bool:
        """Whether this market defines correlated storm windows."""
        return (self.storm_period_s > 0 and self.storm_duration_s > 0
                and self.storm_multiplier > 1.0)

    def rate_at(self, t: float) -> float:
        """Per-second reclaim hazard at absolute sim time ``t``."""
        base = self.reclaim_rate_per_hour / 3600.0
        if self.has_storms and t >= self.storm_start_s:
            phase = (t - self.storm_start_s) % self.storm_period_s
            if phase < self.storm_duration_s:
                return base * self.storm_multiplier
        return base

    def _segment_end(self, t: float) -> float:
        """End of the constant-hazard segment containing ``t``."""
        if not self.has_storms:
            return math.inf
        if t < self.storm_start_s:
            return self.storm_start_s
        phase = (t - self.storm_start_s) % self.storm_period_s
        if phase < self.storm_duration_s:
            return t + (self.storm_duration_s - phase)
        return t + (self.storm_period_s - phase)

    def sample_reclaim(self, after: float, rng) -> float:
        """Draw the next reclaim-notice time for one chip alive at
        ``after`` from the piecewise-constant hazard (inverse-CDF in
        integrated-hazard space: one Exp(1) draw walked through the
        calm/storm segments).

        Args:
            after: absolute sim time the chip came under observation.
            rng: a ``numpy.random.Generator`` (the engine's dedicated
                reclaim stream — never the service-noise stream).
        Returns: the absolute notice time, or ``inf`` when the market
        never reclaims.
        """
        if self.reclaim_rate_per_hour <= 0:
            return math.inf
        target = float(rng.exponential(1.0))   # integrated hazard to burn
        t = after
        while True:
            rate = self.rate_at(t)   # > 0: base hazard is positive here
            end = self._segment_end(t)
            if t + target / rate <= end:
                return t + target / rate
            target -= rate * (end - t)
            t = end


@dataclasses.dataclass(frozen=True)
class GPUType:
    """One device class in a (possibly mixed) fleet.

    Args/fields:
        name: registry key, unique across ``GPU_TYPES``.
        sm_total: vGPU slice granularity of one chip of this type — a
            pod's spatial allocation is ``sm in 1..sm_total`` slices.
        peak_flops: peak sustained FLOP/s of the whole chip.
        hbm_bw: HBM bandwidth in bytes/s of the whole chip.
        price_per_hour: on-demand $/hour for the whole chip; fine-
            grained billing charges ``(sm / sm_total) * quota`` of it.
        host_to_hbm_bw: host-RAM -> HBM transfer bandwidth in bytes/s
            (the PCIe/interconnect generation of the device class) --
            the model-state lifecycle engine (``core/modelstate.py``)
            derives warm-start weight-load times from it.
        market: optional ``GPUMarket`` spot descriptor. None (every
            registered preset) means reliable on-demand capacity; a
            market marks the type as reclaimable spot capacity (its
            ``price_per_hour`` is then the already-discounted spot
            price — see ``spot()``). Spot variants are distinct types:
            they key their own capacity lattices, cost pools, and fleet
            pools, so the on-demand flavor of the same silicon is never
            conflated with it.

    Invariants: all numeric fields are positive; instances are frozen
    (hashable) so they can key capacity-table lattices and memoized
    physics directly.
    """
    name: str
    sm_total: int
    peak_flops: float
    hbm_bw: float
    price_per_hour: float
    host_to_hbm_bw: float = 25e9   # PCIe-gen4-class default
    market: Optional[GPUMarket] = None   # None = on-demand capacity

    def __post_init__(self):
        if self.sm_total < 1:
            raise ValueError(f"sm_total={self.sm_total} must be >= 1")
        if min(self.peak_flops, self.hbm_bw, self.price_per_hour,
               self.host_to_hbm_bw) <= 0:
            raise ValueError(f"GPUType {self.name!r}: peak_flops/hbm_bw/"
                             "price_per_hour/host_to_hbm_bw must be "
                             "positive")

    @property
    def price_per_slice_hour(self) -> float:
        """$/hour of one slice at full quota — the scheduler's cheapness
        key when ranking candidate devices."""
        return self.price_per_hour / self.sm_total


# The device the seed simulator modeled: TPU v5e-class peak/bandwidth,
# billed at the Google Cloud V100 price the paper's Fig 7 uses. Every
# pre-heterogeneity golden trace was produced on (implicitly) this type.
DEFAULT_GPU_TYPE = GPUType(name="v5e", sm_total=8, peak_flops=197e12,
                           hbm_bw=819e9, price_per_hour=2.48,
                           host_to_hbm_bw=32e9)

GPU_TYPES: Dict[str, GPUType] = {
    t.name: t
    for t in (
        DEFAULT_GPU_TYPE,
        GPUType(name="h100", sm_total=8, peak_flops=989e12,
                hbm_bw=3.35e12, price_per_hour=14.90,
                host_to_hbm_bw=55e9),
        GPUType(name="a100", sm_total=8, peak_flops=312e12,
                hbm_bw=2.039e12, price_per_hour=4.10,
                host_to_hbm_bw=28e9),
        GPUType(name="a10g", sm_total=8, peak_flops=140e12,
                hbm_bw=600e9, price_per_hour=1.58,
                host_to_hbm_bw=25e9),
        GPUType(name="t4", sm_total=4, peak_flops=65e12,
                hbm_bw=320e9, price_per_hour=0.53,
                host_to_hbm_bw=12e9),
    )
}
GPU_TYPES["default"] = DEFAULT_GPU_TYPE  # alias: the reference device


def get_gpu_type(name) -> GPUType:
    """Resolve a GPU type by registry name (``GPUType`` instances pass
    through unchanged).

    Args:
        name: a key of ``GPU_TYPES`` (``"v5e"``/``"default"``,
            ``"h100"``, ``"a100"``, ``"a10g"``, ``"t4"``) or an already-
            resolved ``GPUType``.
    Returns: the registered ``GPUType`` instance.
    Raises: ``KeyError`` with the available names for unknown keys.
    """
    if isinstance(name, GPUType):
        return name
    try:
        return GPU_TYPES[name]
    except KeyError:
        raise KeyError(f"unknown GPU type {name!r}; available: "
                       f"{sorted(GPU_TYPES)}") from None


def spot(base, market: GPUMarket) -> GPUType:
    """Derive the spot variant of a device class.

    Same silicon (slices, FLOPs, bandwidth), discounted price, and the
    market's reclaim process attached. The variant is named
    ``"<base>-spot"`` and is NOT added to ``GPU_TYPES`` — fleets carry
    the instance directly (``get_gpu_type`` passes instances through).

    Args:
        base: a registered type name or ``GPUType``.
        market: the ``GPUMarket`` describing discount and reclaims.
    Returns: a new frozen ``GPUType`` with ``market`` attached and
    ``price_per_hour`` scaled by ``market.price_multiplier``.
    """
    base = get_gpu_type(base)
    return dataclasses.replace(
        base, name=f"{base.name}-spot",
        price_per_hour=base.price_per_hour * market.price_multiplier,
        market=market)


def fleet_from_names(fleet) -> Tuple[Tuple[GPUType, int], ...]:
    """Normalize a fleet declaration to ``((GPUType, cap), ...)``.

    Args:
        fleet: iterable of ``(type_name_or_GPUType, max_chips)`` pairs;
            order is the scheduler's tie-break preference order.
    Returns: tuple of ``(GPUType, int cap)`` pairs, same order.
    """
    return tuple((get_gpu_type(n), int(cap)) for n, cap in fleet)
