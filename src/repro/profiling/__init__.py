"""Measured-profile calibration: the sim-to-silicon loop.

Everything the simulator reports is derived from the analytic roofline
in ``core/perf_model.py``. This package closes the loop against the
repo's REAL serving stack: ``harness.py`` times the actual jitted
prefill/decode dispatch path of ``serving.PodEngine`` (and, optionally,
the individual Pallas kernels against their ``kernels/ref.py`` oracles)
across a deterministic (arch, batch, sm, quota, GPU type) grid, and
``table.py`` turns the emitted calibration table into a latency source
that ``core.capacity.CapacityTable`` and the RaPP dataset builder can
consume in place of the synthetic roofline.

CLI entry point: ``python -m benchmarks.profile_stack``.
"""
from repro.profiling.harness import (SCHEMA, GridSpec, ProfilePoint,
                                     build_grid, check_report,
                                     error_summary, profile_kernels,
                                     run_profile, windowed_wall)
from repro.profiling.table import CalibrationTable

__all__ = ["SCHEMA", "GridSpec", "ProfilePoint", "build_grid",
           "check_report", "error_summary", "profile_kernels",
           "run_profile", "windowed_wall", "CalibrationTable"]
