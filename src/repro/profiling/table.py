"""Calibration tables as a latency source for the control plane.

``CalibrationTable`` wraps a ``profile_stack/v1`` report (see
``harness.py``) and answers the same question as the roofline oracle —
"latency of one batched inference at (spec, batch, sm, quota, gpu)" —
from MEASURED points instead of the analytic physics. Lookups resolve:

  * exact grid hits -> the measured prefill wall seconds;
  * points inside the measured (sm x quota) hull -> bilinear
    interpolation between the four surrounding measurements;
  * anything else (unmeasured arch/device/batch, off-hull sm/quota, a
    spec whose seq or architecture doesn't match what was profiled)
    -> ``None``, which consumers treat as "fall back to analytic".

``core.capacity.CapacityTable`` accepts one via ``calibration=`` and
overlays measured points onto its lattices; ``core.rapp.dataset`` can
sample one as training targets. Both default to off — with no
calibration every existing golden trace is byte-identical.
"""
from __future__ import annotations

import bisect
import json
from typing import Dict, List, Optional, Tuple

from repro.configs import ARCHS, reduced
from repro.configs.gpus import DEFAULT_GPU_TYPE, GPUType
from repro.core.perf_model import FnSpec

from repro.profiling.harness import SCHEMA, prompt_len

_QKEY = 9  # quota values are rounded to this many decimals for keying


def _qkey(q: float) -> float:
    return round(float(q), _QKEY)


class CalibrationTable:
    """Measured (arch, gpu, batch) -> (sm x quota) latency surfaces."""

    def __init__(self, report: dict):
        """Index a ``profile_stack/v1`` report's prefill points.

        Args:
            report: a parsed calibration JSON as emitted by
                ``harness.run_profile`` / ``benchmarks.profile_stack``.
        Raises: ``ValueError`` on schema mismatch.
        """
        if report.get("schema") != SCHEMA:
            raise ValueError(
                f"calibration table has schema {report.get('schema')!r}; "
                f"expected {SCHEMA!r}")
        self.report = report
        self.meta = report.get("meta", {})
        # (arch, gpu_name, batch) -> {(sm, quota_key): measured_s}
        self._surface: Dict[Tuple[str, str, int],
                            Dict[Tuple[int, float], float]] = {}
        for p in report["points"]:
            if p["phase"] != "prefill":
                continue  # decode points inform error metrics, not
                # the batched-inference latency the simulator models
            key = (p["arch"], p["gpu"], int(p["batch"]))
            self._surface.setdefault(key, {})[
                (int(p["sm"]), _qkey(p["quota"]))] = float(p["measured_s"])
        self._axes: Dict[Tuple[str, str, int],
                         Tuple[List[int], List[float]]] = {
            key: (sorted({sm for sm, _ in pts}),
                  sorted({q for _, q in pts}))
            for key, pts in self._surface.items()}
        # guard: the profiled configuration behind each arch name (the
        # measured surface is only valid for a spec with the identical
        # architecture and profiled prompt length)
        self._profiled_spec: Dict[str, Optional[FnSpec]] = {}
        seq = self.meta.get("seq")
        for arch in {k[0] for k in self._surface}:
            cfg = ARCHS.get(arch)
            if cfg is None or seq is None:
                self._profiled_spec[arch] = None
                continue
            if self.meta.get("reduced", False):
                cfg = reduced(cfg)
            self._profiled_spec[arch] = FnSpec(cfg, seq=prompt_len(cfg,
                                                                   seq))

    @classmethod
    def load(cls, path) -> "CalibrationTable":
        """Load a calibration table from a JSON file path."""
        with open(path) as f:
            return cls(json.load(f))

    def __len__(self) -> int:
        """Number of measured (arch, gpu, batch) latency surfaces."""
        return len(self._surface)

    def latency(self, spec, batch: int, sm: int, quota: float,
                gpu: Optional[GPUType] = None) -> Optional[float]:
        """Measured-or-interpolated latency seconds, or ``None``.

        Args:
            spec: an ``FnSpec`` (guarded against the profiled config)
                or a bare arch-name string (caller asserts relevance).
            batch/sm/quota: the queried configuration.
            gpu: device type; ``None`` means the reference device.
        Returns: seconds when (arch, gpu, batch) was profiled and
        (sm, quota) lies on or within the measured grid; ``None``
        otherwise (consumers fall back to the analytic physics).
        """
        gpu = gpu or DEFAULT_GPU_TYPE
        if isinstance(spec, str):
            arch = spec
        else:
            arch = spec.arch.name
            profiled = self._profiled_spec.get(arch)
            if profiled is not None and spec != profiled:
                return None
        key = (arch, gpu.name, int(batch))
        pts = self._surface.get(key)
        if pts is None:
            return None
        sms, quotas = self._axes[key]
        qk = _qkey(quota)
        s0, s1 = _bracket(sms, sm)
        q0, q1 = _bracket(quotas, qk)
        if s0 is None or q0 is None:
            return None
        corners = [pts.get((s, q)) for s in (s0, s1) for q in (q0, q1)]
        if any(c is None for c in corners):
            return None  # ragged grid: refuse to extrapolate
        v00, v01, v10, v11 = corners
        ws = 0.0 if s1 == s0 else (sm - s0) / (s1 - s0)
        wq = 0.0 if q1 == q0 else (qk - q0) / (q1 - q0)
        return ((1 - ws) * ((1 - wq) * v00 + wq * v01)
                + ws * ((1 - wq) * v10 + wq * v11))


def _bracket(axis, x):
    """(lo, hi) neighbours of ``x`` on a sorted axis; equal on exact
    hits, ``(None, None)`` outside the hull."""
    if not axis or x < axis[0] - 1e-12 or x > axis[-1] + 1e-12:
        return None, None
    i = bisect.bisect_left(axis, x)
    if i < len(axis) and abs(axis[i] - x) <= 1e-12:
        return axis[i], axis[i]
    if i == 0 or i >= len(axis):
        return None, None
    return axis[i - 1], axis[i]
