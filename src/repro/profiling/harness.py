"""Measured-profile harness over the real jitted serving path.

The harness drives exactly the dispatch path production serving uses —
``PodEngine``'s jitted prefill/decode steps behind the ``libhas``
token-acquire handshake — across a deterministic grid of (arch, GPU
type, batch, sm, quota) points, timing each dispatch with
``jax.block_until_ready`` after warmup, and records next to every
measurement the analytic prediction the simulator would have made for
the same dispatch (``perf_model.latency`` for a batched prefill, its
per-token share for one decode step). The emitted report is a versioned
calibration table (schema ``profile_stack/v1``):

  * ``points``: one record per (point, phase) in deterministic grid
    order — ``measured_s`` (min over timed iterations), ``analytic_s``,
    and their relative error;
  * ``error``: sim-vs-measured relative-error percentiles (p50/p95),
    overall and per architecture — the pinned validation metric;
  * ``meta``: device/backend/jax version, the grid, and the timing
    discipline, so tables are reproducible and comparable;
  * ``kernels`` (optional): per-kernel Pallas-vs-``kernels/ref.py``
    timings at fixed shapes.

``check_report`` is the CI gate (mirroring ``bench_control_plane``):
it fails on schema/grid drift, on analytic drift (the physics changed
without regenerating the reference), and on measured-shape drift beyond
a generous machine-normalized factor. On CPU the absolute sim-vs-
measured error is large and meaningless (the roofline models an
accelerator); the gate therefore compares each run's measured surface
normalized by its own median, which cancels raw machine speed.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs import ARCHS, reduced
from repro.configs.gpus import get_gpu_type
from repro.core import perf_model
from repro.core.perf_model import FnSpec

SCHEMA = "profile_stack/v1"
PHASES = ("prefill", "decode")


@dataclasses.dataclass(frozen=True)
class ProfilePoint:
    """One measured configuration: a phase of one dispatch shape.

    ``phase`` is ``"prefill"`` (one batched forward of ``batch x seq``
    tokens — the quantity ``perf_model.latency`` models) or
    ``"decode"`` (one single-token decode step at ``batch``).
    """
    arch: str
    gpu: str
    batch: int
    sm: int
    quota: float
    phase: str

    def key(self) -> list:
        """JSON-stable identity used by ``check_report`` ordering."""
        return [self.arch, self.gpu, self.batch, self.sm, self.quota,
                self.phase]


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """The profiling grid + timing discipline (deterministic order).

    Points are enumerated arch -> gpu -> batch -> sm -> quota -> phase
    in the literal order of these tuples; (sm > device width) points
    are skipped. ``reduce`` profiles the CPU-runnable reduced configs
    (same arch names); on a real accelerator pass ``reduce=False``.
    """
    archs: Tuple[str, ...] = ("olmo-1b", "mamba2-2.7b")
    gpu_types: Tuple[str, ...] = ("v5e",)
    batches: Tuple[int, ...] = (1, 2)
    sms: Tuple[int, ...] = (2, 4)
    quotas: Tuple[float, ...] = (0.5, 1.0)
    phases: Tuple[str, ...] = PHASES
    seq: int = 32
    window_ms: float = 20.0
    warmup: int = 1
    iters: int = 3
    reduce: bool = True

    def grid_meta(self) -> dict:
        """The grid block of the report's ``meta`` (checked exactly)."""
        return {"archs": list(self.archs),
                "gpu_types": list(self.gpu_types),
                "batches": list(self.batches),
                "sms": list(self.sms),
                "quotas": list(self.quotas),
                "phases": list(self.phases)}


def build_grid(spec: GridSpec) -> List[ProfilePoint]:
    """Enumerate the grid's points in deterministic order."""
    pts = []
    for arch in spec.archs:
        if arch not in ARCHS:
            raise KeyError(f"unknown arch {arch!r}; "
                           f"available: {sorted(ARCHS)}")
        for gpu_name in spec.gpu_types:
            gpu = get_gpu_type(gpu_name)
            for batch in spec.batches:
                for sm in spec.sms:
                    if sm > gpu.sm_total:
                        continue
                    for quota in spec.quotas:
                        for phase in spec.phases:
                            pts.append(ProfilePoint(
                                arch=arch, gpu=gpu.name, batch=batch,
                                sm=sm, quota=float(quota), phase=phase))
    return pts


def windowed_wall(cost_s: float, quota: float, window_s: float) -> float:
    """Wall seconds of a dispatch owning ``cost_s`` accelerator-seconds
    at ``quota`` of each window — the exact time-token quantization of
    ``perf_model.latency``, applied to an arbitrary dispatch cost."""
    q = min(max(quota, 1e-3), 1.0)
    if q >= 1.0 - 1e-9:
        return cost_s
    owned = q * window_s
    full = math.floor(cost_s / owned)
    return full * window_s + (cost_s - full * owned)


def analytic_wall(fn_spec: FnSpec, batch: int, sm: int, quota: float,
                  gpu, phase: str, window_ms: float) -> float:
    """The simulator's prediction for one measured dispatch.

    prefill: ``perf_model.latency`` verbatim (one batched inference).
    decode:  the per-token share ``exec_time / seq`` of the batched
    forward, window-quantized the same way.
    """
    if phase == "prefill":
        return perf_model.latency(fn_spec, batch, sm, quota,
                                  window_ms=window_ms, gpu=gpu)
    if phase == "decode":
        cost = perf_model.exec_time(fn_spec, batch, sm, gpu) / fn_spec.seq
        return windowed_wall(cost, quota, window_ms / 1e3)
    raise ValueError(f"unknown phase {phase!r}")


def _rel_err(measured: float, analytic: float) -> float:
    return abs(measured - analytic) / max(analytic, 1e-12)


def error_summary(points: Sequence[dict]) -> dict:
    """p50/p95 of sim-vs-measured relative error, overall and per arch."""
    def pcts(errs):
        p50, p95 = np.percentile(np.asarray(errs, float), [50, 95])
        return {"p50": float(p50), "p95": float(p95), "n": len(errs)}

    by_arch: Dict[str, list] = {}
    for p in points:
        by_arch.setdefault(p["arch"], []).append(p["rel_err"])
    return {"overall": pcts([p["rel_err"] for p in points]),
            "per_arch": {a: pcts(errs) for a, errs in by_arch.items()}}


# ---------------------------------------------------------------------------
# measurement (imports jax lazily: the check/grid logic stays numpy-only)
# ---------------------------------------------------------------------------

def _time_launch(launch, warmup: int, iters: int) -> float:
    """Min wall seconds of ``launch()`` over ``iters`` after ``warmup``
    calls (the first of which pays compilation)."""
    for _ in range(warmup):
        launch()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        launch()
        best = min(best, time.perf_counter() - t0)
    return best


def prompt_len(cfg, seq: int) -> int:
    """Profiled prompt length: half the KV-cache budget that remains
    after any visual-token prefix, so decode positions stay in range."""
    return max(1, (seq - (cfg.num_visual_tokens or 0)) // 2)


def _measure_engine(cfg, params, gpu, batch: int, sm: int, quota: float,
                    phases: Sequence[str], seq: int, window_ms: float,
                    warmup: int, iters: int, uid: int) -> Dict[str, float]:
    """Measure the requested phases of one (batch, sm, quota) pod via
    the real ``PodEngine`` dispatch path (libhas token acquire + jitted
    step + ``block_until_ready``). Returns phase -> measured seconds."""
    import jax
    import jax.numpy as jnp

    from repro.core.scheduler import HASGPUScheduler
    from repro.core.vgpu import PodAlloc, VirtualGPU
    from repro.serving.engine import PodEngine

    vgpu = VirtualGPU(f"GPU-prof-{uid}", window_ms=window_ms,
                      gpu_type=gpu)
    pod = PodAlloc(fn_id=f"prof-{cfg.name}", sm=sm, quota=quota,
                   batch=batch)
    vgpu.place(pod)
    engine = PodEngine(cfg, pod, vgpu, HASGPUScheduler(), max_seq=seq,
                       params=params)
    rng = np.random.default_rng(0)
    L = prompt_len(cfg, seq)
    prompts = rng.integers(1, cfg.vocab_size,
                           size=(batch, L)).astype(np.int32)
    inputs = {"tokens": jnp.asarray(prompts),
              **engine._extra_inputs(batch)}
    out: Dict[str, float] = {}

    def prefill_once():
        logits, cache = engine.libhas.launch(
            engine._prefill, engine.params, inputs,
            cost_s=engine._cost(batch * L))
        jax.block_until_ready(logits)
        return logits, cache

    if "prefill" in phases:
        out["prefill"] = _time_launch(prefill_once, warmup, iters)
    if "decode" in phases:
        logits, cache = prefill_once()
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        v = cfg.num_visual_tokens or 0
        pos = jnp.asarray(v + L, jnp.int32)

        def decode_once():
            logits2, _ = engine.libhas.launch(
                engine._decode, engine.params, tok, pos, cache,
                cost_s=engine._cost(batch))
            jax.block_until_ready(logits2)

        out["decode"] = _time_launch(decode_once, warmup, iters)
    return out


def run_profile(grid: GridSpec, smoke: bool = False,
                verbose: bool = False) -> dict:
    """Profile the serving stack over ``grid`` -> calibration report."""
    import jax

    points = build_grid(grid)
    records: List[dict] = []
    cache: Dict[tuple, Dict[str, float]] = {}
    params_by_cfg: Dict[str, tuple] = {}
    uid = 0
    for pt in points:
        cfg_key = (pt.arch, pt.gpu, pt.batch, pt.sm, pt.quota)
        if cfg_key not in cache:
            if pt.arch not in params_by_cfg:
                cfg = reduced(ARCHS[pt.arch]) if grid.reduce \
                    else ARCHS[pt.arch]
                from repro import models
                params_by_cfg[pt.arch] = (
                    cfg, models.init_params(jax.random.PRNGKey(0), cfg))
            cfg, params = params_by_cfg[pt.arch]
            uid += 1
            cache[cfg_key] = _measure_engine(
                cfg, params, get_gpu_type(pt.gpu), pt.batch, pt.sm,
                pt.quota, grid.phases, grid.seq, grid.window_ms,
                grid.warmup, grid.iters, uid)
            if verbose:
                print(f"profiled {cfg_key}: "
                      f"{ {k: round(v, 6) for k, v in cache[cfg_key].items()} }",
                      flush=True)
        cfg, _ = params_by_cfg[pt.arch]
        # the analytic twin of the measured dispatch: a batched forward
        # of exactly the profiled prompt length
        fn_spec = FnSpec(cfg, seq=prompt_len(cfg, grid.seq))
        measured = cache[cfg_key][pt.phase]
        analytic = analytic_wall(fn_spec, pt.batch, pt.sm, pt.quota,
                                 get_gpu_type(pt.gpu), pt.phase,
                                 grid.window_ms)
        records.append({"arch": pt.arch, "gpu": pt.gpu,
                        "batch": pt.batch, "sm": pt.sm,
                        "quota": pt.quota, "phase": pt.phase,
                        "measured_s": measured, "analytic_s": analytic,
                        "rel_err": _rel_err(measured, analytic)})
    dev = jax.devices()[0]
    return {"schema": SCHEMA, "smoke": smoke,
            "meta": {"backend": jax.default_backend(),
                     "device_kind": getattr(dev, "device_kind", str(dev)),
                     "jax_version": jax.__version__,
                     "reduced": grid.reduce, "seq": grid.seq,
                     "window_ms": grid.window_ms,
                     "warmup": grid.warmup, "iters": grid.iters,
                     "grid": grid.grid_meta()},
            "points": records,
            "error": error_summary(records)}


# ---------------------------------------------------------------------------
# Pallas kernels vs their pure-jnp references
# ---------------------------------------------------------------------------

def _kernel_cases() -> dict:
    """name -> (args builder, kernel fn, reference fn) at fixed tiny
    shapes (CPU interpret mode runs these; on TPU the same call sites
    lower through Mosaic)."""
    import jax.numpy as jnp

    from repro.kernels import ref as kref
    from repro.kernels.ops import (decode_attention, flash_attention,
                                   gmm, ssd_chunk_scan)

    rng = np.random.default_rng(0)

    def r(*shape):
        return jnp.asarray(rng.standard_normal(shape), jnp.float32)

    def fa_args():
        return (r(1, 128, 1, 1, 64), r(1, 128, 1, 64), r(1, 128, 1, 64))

    def dec_args():
        valid = jnp.asarray(np.arange(128) < 100)
        return (r(1, 1, 1, 1, 64), r(1, 128, 1, 64), r(1, 128, 1, 64),
                valid)

    def gmm_args():
        return (r(2, 128, 64), r(2, 64, 128))

    def ssd_args():
        return (r(2, 1, 32, 1, 16), r(2, 1, 32, 1, 16),
                r(2, 1, 32, 1, 16),
                jnp.abs(r(2, 1, 32, 1)) * 0.1,
                -jnp.abs(r(2, 1, 32, 1)) * 0.1,
                jnp.zeros((1, 1, 16, 16), jnp.float32))

    return {
        "flash_attention": (fa_args, flash_attention,
                            kref.flash_attention_ref),
        "decode_attention": (dec_args, decode_attention,
                             kref.decode_attention_ref),
        "moe_gmm": (gmm_args, gmm, kref.gmm_ref),
        "ssd_scan": (ssd_args, ssd_chunk_scan, kref.ssd_chunk_scan_ref),
    }


def profile_kernels(warmup: int = 1, iters: int = 3,
                    names: Optional[Sequence[str]] = None) -> List[dict]:
    """Time each Pallas kernel and its ``kernels/ref.py`` oracle at a
    fixed shape; ``ratio`` = kernel / reference wall time."""
    import jax

    cases = _kernel_cases()
    out = []
    for name in (names or sorted(cases)):
        builder, kfn, rfn = cases[name]
        args = builder()
        jitted_ref = jax.jit(rfn)
        k_s = _time_launch(
            lambda: jax.block_until_ready(kfn(*args)), warmup, iters)
        r_s = _time_launch(
            lambda: jax.block_until_ready(jitted_ref(*args)), warmup,
            iters)
        out.append({"name": name, "measured_s": k_s, "ref_s": r_s,
                    "ratio": k_s / max(r_s, 1e-12)})
    return out


# ---------------------------------------------------------------------------
# the CI gate
# ---------------------------------------------------------------------------

def check_report(report: dict, ref: dict, factor: float = 10.0,
                 analytic_rtol: float = 1e-9) -> List[str]:
    """Compare a fresh report against a committed reference.

    Failures (returned as human-readable strings, empty = pass):

      * schema / smoke-mode / grid / meta mismatch — the reference was
        generated for a different harness configuration; regenerate it;
      * point-key sequence drift — the deterministic ordering or point
        set changed;
      * analytic drift beyond ``analytic_rtol`` — the physics moved
        without regenerating the reference;
      * measured-shape drift: each run's ``measured_s`` is normalized
        by its own median (cancelling absolute machine speed), and the
        p95 of per-point normalized drift must stay within ``factor``;
      * error-metric regression: the overall p95 relative error may
        not exceed the reference's by more than ``factor`` x (in
        ``1 + err`` space, so near-zero references don't blow up).
    """
    failures: List[str] = []
    for field in ("schema", "smoke"):
        if report.get(field) != ref.get(field):
            failures.append(f"{field} mismatch: {report.get(field)!r} vs "
                            f"reference {ref.get(field)!r}")
    if report.get("schema") != SCHEMA:
        failures.append(f"unknown schema {report.get('schema')!r} "
                        f"(expected {SCHEMA!r})")
    if failures:
        return failures
    meta, rmeta = report["meta"], ref["meta"]
    for field in ("grid", "reduced", "seq", "window_ms"):
        if meta.get(field) != rmeta.get(field):
            failures.append(
                f"meta.{field} mismatch: {meta.get(field)!r} vs reference "
                f"{rmeta.get(field)!r}; regenerate the reference "
                f"(--update-ref) if the grid changed deliberately")
    new_keys = [[p["arch"], p["gpu"], p["batch"], p["sm"], p["quota"],
                 p["phase"]] for p in report["points"]]
    ref_keys = [[p["arch"], p["gpu"], p["batch"], p["sm"], p["quota"],
                 p["phase"]] for p in ref["points"]]
    if new_keys != ref_keys:
        failures.append(
            f"point set/order drifted: {len(new_keys)} points vs "
            f"reference {len(ref_keys)} (deterministic grid ordering is "
            f"part of the contract)")
        return failures
    for p, rp in zip(report["points"], ref["points"]):
        a, ra = p["analytic_s"], rp["analytic_s"]
        if abs(a - ra) > analytic_rtol * max(abs(ra), 1e-12):
            failures.append(
                f"analytic drift at {p['arch']}/{p['gpu']}/b{p['batch']}/"
                f"sm{p['sm']}/q{p['quota']}/{p['phase']}: {a!r} vs "
                f"reference {ra!r} — the physics changed; regenerate "
                f"the reference")
    new_m = np.array([p["measured_s"] for p in report["points"]])
    ref_m = np.array([p["measured_s"] for p in ref["points"]])
    norm_new = new_m / max(float(np.median(new_m)), 1e-12)
    norm_ref = ref_m / max(float(np.median(ref_m)), 1e-12)
    ratio = norm_new / np.maximum(norm_ref, 1e-12)
    drift = np.maximum(ratio, 1.0 / np.maximum(ratio, 1e-12))
    p95_drift = float(np.percentile(drift, 95))
    if p95_drift > factor:
        worst = int(np.argmax(drift))
        failures.append(
            f"measured-shape drift: p95 normalized drift "
            f"{p95_drift:.2f}x > {factor}x (worst point "
            f"{new_keys[worst]}: {drift[worst]:.2f}x)")
    new_p95 = report["error"]["overall"]["p95"]
    ref_p95 = ref["error"]["overall"]["p95"]
    if (1.0 + new_p95) / (1.0 + ref_p95) > factor:
        failures.append(
            f"sim-vs-measured error regressed: overall p95 rel err "
            f"{new_p95:.2f} vs reference {ref_p95:.2f} "
            f"(> {factor}x in 1+err space)")
    return failures
