"""Flash attention (prefill) Pallas TPU kernel.

Online-softmax attention tiled for VMEM: grid = (batch x kv_heads x
q_groups, q_blocks, k_blocks) with the k-block axis innermost (TPU grids
iterate sequentially, so the f32 running (m, l, acc) scratch carries
across k blocks). Block shapes are MXU-aligned (multiples of 128 where
the head_dim allows). GQA is expressed through the k/v index_map: query
row bh reads kv head bh // q_groups — no KV replication in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
               scale, causal, window, block_q, block_k, n_k):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = q @ k.T                                       # (bq, bk)

    i = pl.program_id(1)
    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=-1)
    acc_sc[...] = acc_sc[...] * corr[:, None] + p @ v
    m_sc[...] = m_new

    @pl.when(j == n_k - 1)
    def _done():
        o_ref[0] = (acc_sc[...] /
                    jnp.maximum(l_sc[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    """q: (B, S, K, G, hd); k, v: (B, T, K, hd) -> (B, S, K, G, hd)."""
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0
    scale = 1.0 / (hd ** 0.5)

    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * K * G, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, T, hd)
    n_q, n_k = S // block_q, T // block_k

    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_k=n_k),
        grid=(B * K * G, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, i, j, g=G: (bh // g, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, i, j, g=G: (bh // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K * G, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, K, G, S, hd).transpose(0, 3, 1, 2, 4)
