"""Jit'd public wrappers over the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU the
same call sites lower through Mosaic. ``ref.py`` holds the pure-jnp
oracles every kernel is tested against.
"""
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import expert_ffn, gmm
from repro.kernels.ssd_scan import ssd_chunk_scan

__all__ = ["decode_attention", "flash_attention", "expert_ffn", "gmm",
           "ssd_chunk_scan"]
