"""Single-token (decode) GQA attention Pallas TPU kernel.

grid = (batch x kv_heads, k_blocks): each step loads a (block_k, hd) tile
of the KV cache ring buffer into VMEM, applies the validity mask (ring
fill state), and maintains the online-softmax carry for all G query heads
of the kv head at once — the (G, hd) query tile is small and stays
resident. This is the memory-bound kernel of batched decode: arithmetic
intensity ~= G, so block_k is chosen large (512) to stream the cache at
full HBM bandwidth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _dec_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_sc, l_sc, acc_sc,
                *, scale, n_k):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32) * scale          # (G, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    ok = valid_ref[...]                               # (bk,)
    s = q @ k.T                                       # (G, bk)
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=-1)
    acc_sc[...] = acc_sc[...] * corr[:, None] + p @ v
    m_sc[...] = m_new

    @pl.when(j == n_k - 1)
    def _done():
        o_ref[0] = (acc_sc[...] /
                    jnp.maximum(l_sc[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, valid, *, block_k=512, interpret=None):
    """q: (B,1,K,G,hd); k,v: (B,T,K,hd); valid: (T,) -> (B,1,K,G,hd)."""
    B, _, K, G, hd = q.shape
    T = k.shape[1]
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    block_k = min(block_k, T)
    assert T % block_k == 0
    n_k = T // block_k
    scale = 1.0 / (hd ** 0.5)

    qf = q.reshape(B, K, G, hd).reshape(B * K, G, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, T, hd)

    out = pl.pallas_call(
        functools.partial(_dec_kernel, scale=scale, n_k=n_k),
        grid=(B * K, n_k),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda bh, j: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((block_k,), lambda bh, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda bh, j: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, valid)
    return out.reshape(B, 1, K, G, hd)
