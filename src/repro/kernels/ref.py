"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,S,K,G,hd); k,v: (B,T,K,hd) -> (B,S,K,G,hd). f32 softmax."""
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_attention_ref(q, k, v, valid):
    """q: (B,1,K,G,hd); k,v: (B,T,K,hd); valid: (T,) bool -> (B,1,K,G,hd)."""
    hd = q.shape[-1]
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def ssd_chunk_scan_ref(xc, Bc, Cc, dtc, dAc, h0):
    """SSD chunked scan oracle.

    xc: (nc, B, Q, nh, hd); Bc/Cc: (nc, B, Q, nh, N); dtc/dAc: (nc, B, Q, nh);
    h0: (B, nh, hd, N) f32. Returns (final_state, y (nc, B, Q, nh, hd) f32).
    """
    Q = xc.shape[2]

    def body(h, xs_):
        x_i, B_i, C_i, dt_i, dA_i = xs_
        cum = jnp.cumsum(dA_i, axis=1)
        total = cum[:, -1]
        cb = jnp.einsum("bihn,bjhn->bhij", C_i.astype(jnp.float32),
                        B_i.astype(jnp.float32))
        li = cum.transpose(0, 2, 1)[:, :, :, None]
        lj = cum.transpose(0, 2, 1)[:, :, None, :]
        decay = jnp.exp(jnp.where(jnp.tril(jnp.ones((Q, Q), bool)),
                                  li - lj, -1e30))
        scores = cb * decay * dt_i.transpose(0, 2, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhij,bjhp->bihp", scores,
                             x_i.astype(jnp.float32))
        y_inter = jnp.einsum("bihn,bhpn->bihp",
                             C_i.astype(jnp.float32) * jnp.exp(cum)[..., None],
                             h)
        w = dt_i * jnp.exp(total[:, None, :] - cum)
        dstate = jnp.einsum("bjhp,bjhn->bhpn",
                            x_i.astype(jnp.float32) * w[..., None],
                            B_i.astype(jnp.float32))
        h_new = jnp.exp(total)[:, :, None, None] * h + dstate
        return h_new, y_intra + y_inter

    final, y = jax.lax.scan(body, h0, (xc, Bc, Cc, dtc, dAc))
    return final, y


def gmm_ref(x, w):
    """Grouped matmul oracle: x (E,C,K) @ w (E,K,N) -> (E,C,N), f32 acc."""
    return jnp.einsum("eck,ekn->ecn", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def expert_ffn_ref(xe, w_gate, w_up, w_down, act="silu"):
    """xe: (G,E,C,d); weights (E,d,f)/(E,f,d) -> (G,E,C,d)."""
    a = jax.nn.silu if act == "silu" else (
        lambda t: jax.nn.gelu(t, approximate=True))
    h = a(jnp.einsum("gecd,edf->gecf", xe, w_gate)) \
        * jnp.einsum("gecd,edf->gecf", xe, w_up)
    return jnp.einsum("gecf,efd->gecd", h, w_down)
