"""Mamba2 SSD chunk-scan Pallas TPU kernel.

grid = (B x nh, n_chunks) with the chunk axis innermost: the SSM state
(hd, N) lives in an f32 VMEM scratch and carries across chunks (TPU grid
steps run sequentially per core). Each step computes the quadratic
intra-chunk dual form — (Q,Q) decay-masked C.B^T scores feeding the MXU —
plus the carried-state contribution, then advances the state. The (Q,Q)
working set is what the chunk size tunes against VMEM (Q=256 default:
256x256 f32 = 256 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, da_ref, h0_ref, y_ref,
                hout_ref, h_sc, *, Q, n_chunks):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_sc[...] = h0_ref[0].astype(jnp.float32)     # (hd, N)

    x = x_ref[0, 0].astype(jnp.float32)               # (Q, hd)
    Bm = b_ref[0, 0].astype(jnp.float32)              # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)              # (Q, N)
    dt = dt_ref[0, 0].astype(jnp.float32)             # (Q,)
    dA = da_ref[0, 0].astype(jnp.float32)             # (Q,)

    cum = jnp.cumsum(dA)                              # (Q,)
    total = cum[-1]
    cb = Cm @ Bm.T                                    # (Q, Q)
    li = cum[:, None]
    lj = cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.exp(jnp.where(tri, li - lj, -1e30))
    scores = cb * decay * dt[None, :]
    y_intra = scores @ x                              # (Q, hd)
    h = h_sc[...]                                     # (hd, N)
    y_inter = (Cm * jnp.exp(cum)[:, None]) @ h.T      # (Q, hd)
    w = dt * jnp.exp(total - cum)                     # (Q,)
    dstate = (x * w[:, None]).T @ Bm                  # (hd, N)
    h_new = jnp.exp(total) * h + dstate
    h_sc[...] = h_new
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    @pl.when(j == n_chunks - 1)
    def _done():
        hout_ref[0] = h_new


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_scan(xc, Bc, Cc, dtc, dAc, h0, *, interpret=None):
    """xc: (nc,B,Q,nh,hd); Bc/Cc: (nc,B,Q,nh,N); dtc/dAc: (nc,B,Q,nh);
    h0: (B,nh,hd,N) f32. Returns (final (B,nh,hd,N) f32, y (nc,B,Q,nh,hd) f32).
    """
    nc, B, Q, nh, hd = xc.shape
    N = Bc.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    BH = B * nh
    xf = xc.transpose(1, 3, 0, 2, 4).reshape(BH, nc, Q, hd)
    bf = Bc.transpose(1, 3, 0, 2, 4).reshape(BH, nc, Q, N)
    cf = Cc.transpose(1, 3, 0, 2, 4).reshape(BH, nc, Q, N)
    dtf = dtc.transpose(1, 3, 0, 2).reshape(BH, nc, Q)
    daf = dAc.transpose(1, 3, 0, 2).reshape(BH, nc, Q)
    h0f = h0.reshape(BH, hd, N)

    y, hout = pl.pallas_call(
        functools.partial(_ssd_kernel, Q=Q, n_chunks=nc),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, hd), lambda bh, j: (bh, j, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda bh, j: (bh, j, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda bh, j: (bh, j, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, 1, Q), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, hd, N), lambda bh, j: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, hd), lambda bh, j: (bh, j, 0, 0)),
            pl.BlockSpec((1, hd, N), lambda bh, j: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, Q, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, hd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(xf, bf, cf, dtf, daf, h0f)

    y = y.reshape(B, nh, nc, Q, hd).transpose(2, 0, 3, 1, 4)
    return hout.reshape(B, nh, hd, N), y
