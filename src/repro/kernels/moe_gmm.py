"""Grouped (per-expert) blocked matmul Pallas TPU kernel.

gmm(x (E,C,K), w (E,K,N)) -> (E,C,N): grid = (E, C/bc, N/bn, K/bk) with
the K-reduction innermost accumulating into an f32 VMEM scratch tile, the
canonical MXU-blocked matmul. ``expert_ffn`` composes three gmm calls into
the gated expert FFN used by the einsum-dispatch MoE layer — the dispatch
one-hots stay in XLA; the expert compute hot loop is the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_sc, *, n_k):
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    x = x_ref[0].astype(jnp.float32)   # (bc, bk)
    w = w_ref[0].astype(jnp.float32)   # (bk, bn)
    acc_sc[...] += x @ w

    @pl.when(kk == n_k - 1)
    def _done():
        o_ref[0] = acc_sc[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_n", "block_k",
                                             "interpret"))
def gmm(x, w, *, block_c=128, block_n=128, block_k=512, interpret=None):
    """x: (E, C, K) @ w: (E, K, N) -> (E, C, N)."""
    E, C, K = x.shape
    N = w.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bc, bn, bk = min(block_c, C), min(block_n, N), min(block_k, K)
    assert C % bc == 0 and N % bn == 0 and K % bk == 0
    grid = (E, C // bc, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_gmm_kernel, n_k=K // bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bk), lambda e, i, j, kk: (e, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda e, i, j, kk: (e, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bn), lambda e, i, j, kk: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


def expert_ffn(xe, w_gate, w_up, w_down, act="silu", **kw):
    """xe: (G, E, C, d) -> (G, E, C, d) via per-expert gated FFN."""
    G, E, C, d = xe.shape
    f = w_gate.shape[-1]
    x = xe.transpose(1, 0, 2, 3).reshape(E, G * C, d)
    a = jax.nn.silu if act == "silu" else (
        lambda t: jax.nn.gelu(t, approximate=True))
    h = a(gmm(x, w_gate, **kw)) * gmm(x, w_up, **kw)
    y = gmm(h.astype(xe.dtype), w_down, **kw)
    return y.reshape(E, G, C, d).transpose(1, 0, 2, 3)
