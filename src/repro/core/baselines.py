"""Baseline scaling policies the paper compares against.

* KServeLike  — mainstream serverless inference platform: one WHOLE chip
  per pod, horizontal-only HPA on observed load, long cold starts (device
  + runtime init), stabilization-window scale-down.
* FaSTGShareLike — state-of-the-art spatio-temporal GPU sharing FaaS:
  pods use a FIXED fine-grained (batch, sm, quota) chosen offline for
  efficiency, but scaling is horizontal-only (no quota reallocation).

Both run in the same simulator/cluster as HAS — only the policy differs.
Like the hybrid scaler, both consume the roofline physics through the
shared `CapacityTable` lattices (core/capacity.py) rather than scalar
`perf_model` queries.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.core import capacity as capacity_mod
from repro.core.perf_model import FnSpec
from repro.core.reconfigurator import Reconfigurator
from repro.core.vgpu import DEFAULT_WINDOW_MS, PodAlloc, TOTAL_SLICES


@dataclasses.dataclass
class KServeLikeConfig:
    target_utilization: float = 0.7
    min_replicas: int = 1
    stabilization_s: float = 300.0  # k8s HPA default scale-down window
    cold_start_s: float = 15.0     # chip init + runtime + model load
    default_batch: int = 8


class KServeLikePolicy:
    def __init__(self, recon: Reconfigurator,
                 cfg: KServeLikeConfig = KServeLikeConfig(),
                 window_ms: float = 100.0):
        self.recon = recon
        self.cfg = cfg
        self.window_ms = window_ms
        self.table = capacity_mod.shared_table(window_ms=window_ms)
        self._below_since: Dict[str, float] = {}

    def pod_thpt(self, spec: FnSpec) -> float:
        return self.table.throughput(spec, self.cfg.default_batch,
                                     TOTAL_SLICES, 1.0)

    def prewarm(self, spec: FnSpec, expected_rps: float):
        import math as _m
        n = max(self.cfg.min_replicas,
                _m.ceil(expected_rps / max(self.pod_thpt(spec)
                                           * self.cfg.target_utilization,
                                           1e-9)))
        for _ in range(n):
            pod = PodAlloc(fn_id=spec.fn_id, sm=TOTAL_SLICES, quota=1.0,
                           batch=self.cfg.default_batch)
            self.recon.place_pod(pod, None, now=0.0, cold_start_s=0.0)

    def tick(self, now: float, spec: FnSpec, observed_rps: float):
        pods = self.recon.pods_of(spec.fn_id)
        cap = self.pod_thpt(spec)
        desired = max(self.cfg.min_replicas,
                      math.ceil(observed_rps /
                                max(cap * self.cfg.target_utilization, 1e-9)))
        cur = len(pods)
        if desired > cur:
            self._below_since.pop(spec.fn_id, None)
            for _ in range(desired - cur):
                pod = PodAlloc(fn_id=spec.fn_id, sm=TOTAL_SLICES, quota=1.0,
                               batch=self.cfg.default_batch)
                try:
                    self.recon.place_pod(pod, None, now=now,
                                         cold_start_s=self.cfg.cold_start_s)
                except RuntimeError:
                    break
        elif desired < cur:
            since = self._below_since.setdefault(spec.fn_id, now)
            if now - since >= self.cfg.stabilization_s:
                for pod in pods[: cur - desired]:
                    self.recon.remove_pod(pod.pod_id)
                self.recon.release_empty_gpus()
                self._below_since.pop(spec.fn_id, None)
        else:
            self._below_since.pop(spec.fn_id, None)


@dataclasses.dataclass
class FaSTGShareLikeConfig:
    target_utilization: float = 0.8
    min_replicas: int = 1
    stabilization_s: float = 30.0
    cold_start_s: float = 5.0     # container + model load (no vertical path)
    default_batch: int = 8
    unit_rps: float = 20.0        # per-pod capacity the fixed config targets


class FaSTGShareLikePolicy:
    """Fixed most-efficient (b, sm, q) per function; horizontal-only."""

    def __init__(self, recon: Reconfigurator,
                 cfg: FaSTGShareLikeConfig = FaSTGShareLikeConfig(),
                 window_ms: float = 100.0):
        self.recon = recon
        self.cfg = cfg
        self.window_ms = window_ms
        self.table = capacity_mod.shared_table(window_ms=window_ms)
        self._below_since: Dict[str, float] = {}
        self._fixed: Dict[str, tuple] = {}

    def fixed_config(self, spec: FnSpec) -> tuple:
        # FaST-GShare picks the most throughput-efficient FIXED config;
        # efficiency favors full temporal occupancy of its partition
        # (window quantization penalizes fractional quotas), so the fixed
        # unit is (batch, sm, quota=1.0). The whole-quota lattice
        # (quota_step=1.0, default window — the grid the offline pick
        # always used) resolves it in one table lookup.
        if spec.fn_id not in self._fixed:
            self._fixed[spec.fn_id] = capacity_mod.shared_table(
                quota_step=1.0, window_ms=DEFAULT_WINDOW_MS
            ).most_efficient_config(spec, self.cfg.unit_rps,
                                    slo_multiplier=2.0)
        return self._fixed[spec.fn_id]

    def prewarm(self, spec: FnSpec, expected_rps: float):
        import math as _m
        b, sm, q = self.fixed_config(spec)
        cap = self.table.throughput(spec, b, sm, q)
        n = max(self.cfg.min_replicas,
                _m.ceil(expected_rps /
                        max(cap * self.cfg.target_utilization, 1e-9)))
        for _ in range(n):
            pod = PodAlloc(fn_id=spec.fn_id, sm=sm, quota=q, batch=b)
            gpu = None
            cands = [g for g in self.recon.used_gpus() if g.can_place(sm, q)]
            if cands:
                gpu = min(cands, key=lambda g: g.hgo).uuid
            self.recon.place_pod(pod, gpu, now=0.0, cold_start_s=0.0)

    def tick(self, now: float, spec: FnSpec, observed_rps: float):
        b, sm, q = self.fixed_config(spec)
        cap = self.table.throughput(spec, b, sm, q)
        pods = self.recon.pods_of(spec.fn_id)
        desired = max(self.cfg.min_replicas,
                      math.ceil(observed_rps /
                                max(cap * self.cfg.target_utilization, 1e-9)))
        cur = len(pods)
        if desired > cur:
            self._below_since.pop(spec.fn_id, None)
            for _ in range(desired - cur):
                pod = PodAlloc(fn_id=spec.fn_id, sm=sm, quota=q, batch=b)
                gpu = None
                cands = [g for g in self.recon.used_gpus()
                         if g.can_place(sm, q)]
                if cands:
                    gpu = min(cands, key=lambda g: g.hgo).uuid
                try:
                    self.recon.place_pod(pod, gpu, now=now,
                                         cold_start_s=self.cfg.cold_start_s)
                except RuntimeError:
                    break
        elif desired < cur:
            since = self._below_since.setdefault(spec.fn_id, now)
            if now - since >= self.cfg.stabilization_s:
                for pod in pods[: cur - desired]:
                    self.recon.remove_pod(pod.pod_id)
                self.recon.release_empty_gpus()
                self._below_since.pop(spec.fn_id, None)
        else:
            self._below_since.pop(spec.fn_id, None)
