"""Baseline scaling policies the paper compares against.

* KServeLike  — mainstream serverless inference platform: one WHOLE chip
  per pod, horizontal-only HPA on observed load, long cold starts (device
  + runtime init), stabilization-window scale-down.
* FaSTGShareLike — state-of-the-art spatio-temporal GPU sharing FaaS:
  pods use a FIXED fine-grained (batch, sm, quota) chosen offline for
  efficiency, but scaling is horizontal-only (no quota reallocation).

Both run in the same simulator/cluster as HAS — only the policy differs.
Like the hybrid scaler, both consume the roofline physics through the
shared `CapacityTable` lattices (core/capacity.py) rather than scalar
`perf_model` queries.

On a heterogeneous fleet both baselines stay deliberately DEVICE-BLIND
(that is the point of comparing them against HAS's placement-aware
scheduling): they plan capacity against the fleet's first declared
type, and take whatever chips the Reconfigurator hands out — KServe
sizes each pod to the whole chip it lands on; FaST keeps its one fixed
fine-grained config and packs it wherever it fits (cheapest type
first). On a homogeneous fleet both degenerate to the legacy behavior
bitwise.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.core import capacity as capacity_mod
from repro.core import modelstate as modelstate_mod
from repro.core.perf_model import FnSpec
from repro.core.reconfigurator import Reconfigurator
from repro.core.vgpu import DEFAULT_WINDOW_MS, PodAlloc


@dataclasses.dataclass
class KServeLikeConfig:
    target_utilization: float = 0.7
    min_replicas: int = 1
    stabilization_s: float = 300.0  # k8s HPA default scale-down window
    # chip init + runtime + device plugin + model load: composed from
    # the same physics components HAS quotes its constants from
    # (core/modelstate.py), not an independent hand-tuned literal
    cold_start_s: float = modelstate_mod.KSERVE_COLD_START_S
    # extra bring-up beyond weight movement (runtime + device plugin) —
    # what this policy keeps paying even under derived lifecycle physics
    start_overhead_s: float = (modelstate_mod.RUNTIME_INIT_S
                               + modelstate_mod.K8S_DEVICE_INIT_S)
    default_batch: int = 8


class KServeLikePolicy:
    def __init__(self, recon: Reconfigurator,
                 cfg: KServeLikeConfig = KServeLikeConfig(),
                 window_ms: float = 100.0):
        self.recon = recon
        self.cfg = cfg
        self.window_ms = window_ms
        self.table = capacity_mod.shared_table(window_ms=window_ms)
        self._below_since: Dict[str, float] = {}

    def _ref_type(self):
        """The fleet's first declared type — the device class this
        device-blind policy plans capacity against."""
        return self.recon.fleet[0][0]

    def pod_thpt(self, spec: FnSpec) -> float:
        ref = self._ref_type()
        return self.table.throughput(spec, self.cfg.default_batch,
                                     ref.sm_total, 1.0, gpu=ref)

    def _add_whole_gpu_pod(self, spec: FnSpec, now: float,
                           cold_start_s: float) -> None:
        """One replica = one whole chip of whatever type the fleet hands
        out next (the pod is sized to that chip's full slice count)."""
        g = self.recon.add_gpu()
        pod = PodAlloc(fn_id=spec.fn_id, sm=g.gpu_type.sm_total, quota=1.0,
                       batch=self.cfg.default_batch)
        self.recon.place_pod(pod, g.uuid, now=now,
                             cold_start_s=cold_start_s, spec=spec,
                             fresh_chip=True,
                             start_overhead_s=self.cfg.start_overhead_s)

    def prewarm(self, spec: FnSpec, expected_rps: float):
        import math as _m
        n = max(self.cfg.min_replicas,
                _m.ceil(expected_rps / max(self.pod_thpt(spec)
                                           * self.cfg.target_utilization,
                                           1e-9)))
        for _ in range(n):
            self._add_whole_gpu_pod(spec, now=0.0, cold_start_s=0.0)

    def tick(self, now: float, spec: FnSpec, observed_rps: float):
        pods = self.recon.pods_of(spec.fn_id)
        cap = self.pod_thpt(spec)
        desired = max(self.cfg.min_replicas,
                      math.ceil(observed_rps /
                                max(cap * self.cfg.target_utilization, 1e-9)))
        cur = len(pods)
        if desired > cur:
            self._below_since.pop(spec.fn_id, None)
            for _ in range(desired - cur):
                try:
                    self._add_whole_gpu_pod(
                        spec, now=now, cold_start_s=self.cfg.cold_start_s)
                except RuntimeError:
                    break
        elif desired < cur:
            since = self._below_since.setdefault(spec.fn_id, now)
            if now - since >= self.cfg.stabilization_s:
                for pod in pods[: cur - desired]:
                    self.recon.remove_pod(pod.pod_id, now=now)
                self.recon.release_empty_gpus()
                self._below_since.pop(spec.fn_id, None)
        else:
            self._below_since.pop(spec.fn_id, None)


@dataclasses.dataclass
class FaSTGShareLikeConfig:
    target_utilization: float = 0.8
    min_replicas: int = 1
    stabilization_s: float = 30.0
    # container + full runtime + model load (no vertical path), composed
    # from the shared physics components in core/modelstate.py
    cold_start_s: float = modelstate_mod.FAST_GSHARE_COLD_START_S
    start_overhead_s: float = modelstate_mod.RUNTIME_INIT_S
    default_batch: int = 8
    unit_rps: float = 20.0        # per-pod capacity the fixed config targets


class FaSTGShareLikePolicy:
    """Fixed most-efficient (b, sm, q) per function; horizontal-only."""

    def __init__(self, recon: Reconfigurator,
                 cfg: FaSTGShareLikeConfig = FaSTGShareLikeConfig(),
                 window_ms: float = 100.0):
        self.recon = recon
        self.cfg = cfg
        self.window_ms = window_ms
        self.table = capacity_mod.shared_table(window_ms=window_ms)
        self._below_since: Dict[str, float] = {}
        self._fixed: Dict[str, tuple] = {}

    def _ref_type(self):
        """The fleet's first declared type — the device class the
        offline fixed-config pick (and capacity math) is quoted on."""
        return self.recon.fleet[0][0]

    def fixed_config(self, spec: FnSpec) -> tuple:
        # FaST-GShare picks the most throughput-efficient FIXED config;
        # efficiency favors full temporal occupancy of its partition
        # (window quantization penalizes fractional quotas), so the fixed
        # unit is (batch, sm, quota=1.0). The whole-quota lattice
        # (quota_step=1.0, default window — the grid the offline pick
        # always used) resolves it in one table lookup, quoted on the
        # fleet's first type (the policy is device-blind: it never
        # re-fits the config to the chip a pod actually lands on).
        if spec.fn_id not in self._fixed:
            self._fixed[spec.fn_id] = capacity_mod.shared_table(
                quota_step=1.0, window_ms=DEFAULT_WINDOW_MS
            ).most_efficient_config(spec, self.cfg.unit_rps,
                                    slo_multiplier=2.0,
                                    gpu=self._ref_type())
        return self._fixed[spec.fn_id]

    def _choose_gpu(self, sm: int, q: float):
        """Used chip for one fixed-config pod: cheapest device class
        first, least-occupied inside a class (on a homogeneous fleet the
        price key is constant — the legacy min-HGO pick, bitwise)."""
        cands = [g for g in self.recon.used_gpus() if g.can_place(sm, q)]
        if not cands:
            return None
        return min(cands, key=lambda g: (g.gpu_type.price_per_slice_hour,
                                         g.hgo)).uuid

    def prewarm(self, spec: FnSpec, expected_rps: float):
        import math as _m
        b, sm, q = self.fixed_config(spec)
        ref = self._ref_type()
        cap = self.table.throughput(spec, b, sm, q, gpu=ref)
        n = max(self.cfg.min_replicas,
                _m.ceil(expected_rps /
                        max(cap * self.cfg.target_utilization, 1e-9)))
        for _ in range(n):
            pod = PodAlloc(fn_id=spec.fn_id, sm=sm, quota=q, batch=b)
            self.recon.place_pod(pod, self._choose_gpu(sm, q), now=0.0,
                                 cold_start_s=0.0, spec=spec)

    def tick(self, now: float, spec: FnSpec, observed_rps: float):
        b, sm, q = self.fixed_config(spec)
        ref = self._ref_type()
        cap = self.table.throughput(spec, b, sm, q, gpu=ref)
        pods = self.recon.pods_of(spec.fn_id)
        desired = max(self.cfg.min_replicas,
                      math.ceil(observed_rps /
                                max(cap * self.cfg.target_utilization, 1e-9)))
        cur = len(pods)
        if desired > cur:
            self._below_since.pop(spec.fn_id, None)
            for _ in range(desired - cur):
                pod = PodAlloc(fn_id=spec.fn_id, sm=sm, quota=q, batch=b)
                try:
                    self.recon.place_pod(
                        pod, self._choose_gpu(sm, q), now=now,
                        cold_start_s=self.cfg.cold_start_s, spec=spec,
                        start_overhead_s=self.cfg.start_overhead_s)
                except RuntimeError:
                    break
        elif desired < cur:
            since = self._below_since.setdefault(spec.fn_id, now)
            if now - since >= self.cfg.stabilization_s:
                for pod in pods[: cur - desired]:
                    self.recon.remove_pod(pod.pod_id, now=now)
                self.recon.release_empty_gpus()
                self._below_since.pop(spec.fn_id, None)
        else:
            self._below_since.pop(spec.fn_id, None)
