"""Model-state lifecycle engine: where a function's weights live.

The paper identifies cold starts as the factor that "further
exacerbates" SLO violations under horizontal-only scaling, and
Torpor/FaaSwap (PAPERS.md) show that *where the weights live* is what
separates a multi-second cold start from a sub-second warm one. This
module models that lifecycle explicitly. Each function's weights on a
given node occupy one of three tiers:

    COLD  -- object store only: starting a pod pays container init +
             fetch-to-host + load-to-HBM (+ chip init on a fresh chip);
    HOST  -- cached in the node's RAM (an LRU cache with a capacity
             budget): starting a pod skips the fetch;
    GPU   -- resident in a chip's HBM (live or keep-warm pods hold a
             reference): a new replica on that chip starts "hot".

Per-tier latencies are derived from the spec's ``param_count`` (weights
= 2 bytes/param) and per-``GPUType`` host->HBM bandwidth
(``configs/gpus.py``), so bigger models and slower buses genuinely cost
more. The legacy flat cold-start constants are the *calibration anchor*:
the shared physics components below sum exactly to the constants the
policies have always used (2.5 s / 8.0 s for HAS, 5.0 s for
FaST-GShare-like, 15.0 s for KServe-like), and the default
``LifecycleConfig`` is *passive* -- placements pay exactly the
requested constants and no lifecycle state is surfaced -- so every
legacy golden trace stays byte-identical.

Three mechanisms ride on the tracker:

  * **host-RAM weight caching** -- scale-downs demote weights into the
    pod's node cache (LRU, capacity-budgeted) instead of evicting them,
    so a later re-scale-up on that node starts HOST-warm;
  * **keep-warm pools** -- ``HybridAutoScaler`` can retain N quota-zero
    standby pods per function (weights stay GPU-resident; ``CostMeter``
    bills them at a configurable idle-retention price) so reactivation
    is a zero-latency "hot" start;
  * **forecast-driven pre-warming** -- the autoscaler projects the
    Kalman rate forward ``prewarm_lead_s`` seconds and starts weight
    fetches (``promote``) on the likely placement nodes *before* the
    arrival wave lands; a pod placed mid-transfer waits only the
    remaining transfer time.
"""
from __future__ import annotations

import bisect
import dataclasses
import enum
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

from repro.configs.gpus import GPUType

# ---------------------------------------------------------------------------
# Shared start-latency physics components (seconds).
#
# Single source for every policy's cold-start constants: the sums below
# reproduce the flat constants the policies were born with EXACTLY
# (dyadic-friendly values, so the float sums are bitwise the legacy
# literals and the golden traces cannot drift).
# ---------------------------------------------------------------------------
CONTAINER_INIT_S = 0.25     # container + process bring-up
WEIGHT_FETCH_S = 2.0        # object store -> host RAM (reference spec)
WEIGHT_LOAD_S = 0.25        # host RAM -> HBM (reference spec/device)
CHIP_INIT_S = 5.5           # fresh-chip provision + program init
RUNTIME_INIT_S = 2.5        # full serving-runtime bring-up (no vertical path)
K8S_DEVICE_INIT_S = 4.5     # device-plugin/driver attach on whole-GPU stacks

#: HAS warm-chip cold start (container + weight load on a used chip): 2.5 s.
WARM_CHIP_COLD_START_S = CONTAINER_INIT_S + WEIGHT_FETCH_S + WEIGHT_LOAD_S
#: HAS fresh-chip cold start (+ chip/program initialization): 8.0 s.
NEW_GPU_COLD_START_S = WARM_CHIP_COLD_START_S + CHIP_INIT_S
#: FaST-GShare-like cold start (+ full runtime, no vertical path): 5.0 s.
FAST_GSHARE_COLD_START_S = WARM_CHIP_COLD_START_S + RUNTIME_INIT_S
#: KServe-like whole-GPU cold start (fresh chip + runtime + device
#: plugin): 15.0 s.
KSERVE_COLD_START_S = (NEW_GPU_COLD_START_S + RUNTIME_INIT_S
                       + K8S_DEVICE_INIT_S)

#: Object-store -> host bandwidth (bytes/s) the physics mode derives
#: fetch times from (a ~10 Gb/s storage network).
OBJECT_STORE_BW = 1.2e9

#: Quota a keep-warm standby pod parks at: positive (the vGPU quota
#: invariant requires > 0) but serving-irrelevant.
KEEP_WARM_QUOTA = 1e-6

#: Default fraction of a standby pod's full-quota slice price billed
#: while parked — the single source both ``LifecycleConfig`` and
#: ``CostMeter`` quote their defaults from.
IDLE_RETENTION_FACTOR = 0.15


class WeightState(enum.Enum):
    """Residency tier of one function's weights on one node."""
    COLD = "cold"       # object store only
    FETCHING = "fetching"  # transfer to host RAM in flight
    HOST = "host"       # cached in node RAM
    GPU = "gpu"         # resident in a chip's HBM


def weight_bytes(spec) -> float:
    """Weight footprint of ``spec`` in bytes (2 bytes/param, bf16)."""
    return 2.0 * spec.arch.param_count()


@dataclasses.dataclass(frozen=True)
class ColdStartModel:
    """Per-tier start-latency components for one (spec, device) pair.

    ``time_to_ready`` composes them by residency tier: a COLD start
    pays everything, a HOST start skips the fetch, a GPU start pays
    container bring-up only. ``chip_init_s`` is added when a fresh chip
    must be provisioned, ``overhead_s`` carries policy-specific extras
    (serving-runtime bring-up, device-plugin attach).
    """
    container_init_s: float
    fetch_to_host_s: float
    load_to_gpu_s: float
    chip_init_s: float

    def time_to_ready(self, tier: WeightState, fresh_chip: bool = False,
                      wait_s: float = 0.0, overhead_s: float = 0.0) -> float:
        """Seconds until a pod starting at tier ``tier`` can serve.

        Args:
            tier: weight residency at placement time.
            fresh_chip: whether a chip had to be provisioned.
            wait_s: remaining time of an in-flight transfer
                (``FETCHING`` tier only).
            overhead_s: policy-specific extra bring-up.
        """
        t = self.container_init_s
        if tier is WeightState.COLD:
            t += self.fetch_to_host_s + self.load_to_gpu_s
        elif tier is WeightState.FETCHING:
            t += wait_s + self.load_to_gpu_s
        elif tier is WeightState.HOST:
            t += self.load_to_gpu_s
        # GPU tier: weights already in HBM, container bring-up only
        if fresh_chip:
            t += self.chip_init_s
        return t + overhead_s


def physics_cold_model(spec, gpu: GPUType,
                       object_store_bw: float = OBJECT_STORE_BW
                       ) -> ColdStartModel:
    """Derive the per-tier model from the spec's parameter count and the
    device's host->HBM bandwidth (``GPUType.host_to_hbm_bw``)."""
    wb = weight_bytes(spec)
    return ColdStartModel(
        container_init_s=CONTAINER_INIT_S,
        fetch_to_host_s=wb / object_store_bw,
        load_to_gpu_s=wb / gpu.host_to_hbm_bw,
        chip_init_s=CHIP_INIT_S)


@dataclasses.dataclass(frozen=True)
class LifecycleConfig:
    """Knobs of the model-state lifecycle engine.

    The default instance is *passive*: placements pay exactly the
    cold-start constants the caller requested and no lifecycle metrics
    are surfaced -- legacy golden traces are byte-identical. The cache
    / keep-warm / pre-warm features require ``derive_from_physics``
    (tier discounts are only meaningful against the derived
    components).

    Fields:
        derive_from_physics: derive start latencies from
            ``physics_cold_model`` instead of the caller's constants.
        host_cache_gb: per-node host-RAM weight-cache budget in GiB
            (0 disables caching -- scale-downs evict to COLD).
        keep_warm_pods: standby pods ``HybridAutoScaler`` retains per
            function on scale-down (weights stay GPU-resident).
        prewarm_lead_s: forecast horizon for pre-warming; 0 disables.
        idle_retention_factor: fraction of a standby pod's full-quota
            slice price that ``CostMeter`` keeps billing.
        object_store_bw: cold-fetch bandwidth in bytes/s.
    """
    derive_from_physics: bool = False
    host_cache_gb: float = 0.0
    keep_warm_pods: int = 0
    prewarm_lead_s: float = 0.0
    idle_retention_factor: float = IDLE_RETENTION_FACTOR
    object_store_bw: float = OBJECT_STORE_BW

    def __post_init__(self):
        if not self.derive_from_physics and (
                self.host_cache_gb > 0 or self.keep_warm_pods > 0
                or self.prewarm_lead_s > 0):
            raise ValueError(
                "host caching / keep-warm / pre-warming require "
                "derive_from_physics=True (tier discounts are defined "
                "against the derived components, not flat constants)")

    @property
    def is_passive(self) -> bool:
        """True when the engine must be byte-transparent to legacy runs."""
        return not (self.derive_from_physics or self.host_cache_gb > 0
                    or self.keep_warm_pods > 0 or self.prewarm_lead_s > 0)


class NodeWeightCache:
    """Host-RAM LRU weight cache of one node.

    Entries are function ids with their weight footprints, ordered by
    last-use *timestamp* (ties by arrival sequence), not by insertion
    order: transfers are folded in lazily, so an entry admitted "as of"
    its completion time must rank exactly where that time puts it —
    never above weights that were genuinely used later. ``admit``
    evicts from LRU until the capacity budget holds; a model bigger
    than the whole budget is never admitted (it would flush the cache
    for nothing).
    """

    def __init__(self, capacity_bytes: float):
        """Args: capacity_bytes: RAM budget for cached weights."""
        self.capacity_bytes = float(capacity_bytes)
        # fn -> [nbytes, last_used_time, tie-break sequence]
        self._entries: Dict[str, list] = {}
        self._seq = 0

    @property
    def used_bytes(self) -> float:
        """Bytes currently held by cached weights."""
        return sum(e[0] for e in self._entries.values())

    def contains(self, fn_id: str) -> bool:
        """Whether ``fn_id``'s weights are host-cached on this node."""
        return fn_id in self._entries

    def touch(self, fn_id: str, at: float = 0.0) -> None:
        """Mark ``fn_id`` used at time ``at`` (a cache hit); a stale
        touch earlier than the entry's last use is a no-op."""
        e = self._entries.get(fn_id)
        if e is not None and at >= e[1]:
            self._seq += 1
            e[1], e[2] = at, self._seq

    def admit(self, fn_id: str, nbytes: float, at: float = 0.0) -> List[str]:
        """Insert (or refresh) ``fn_id`` as used at time ``at``; returns
        evicted ids in eviction (LRU-first) order."""
        if nbytes > self.capacity_bytes:
            return []   # can't ever fit; don't flush the cache for it
        prior = self._entries.get(fn_id)
        if prior is not None:
            at = max(at, prior[1])   # a re-admit never demotes an entry
        self._seq += 1
        self._entries[fn_id] = [float(nbytes), at, self._seq]
        evicted: List[str] = []
        while self.used_bytes > self.capacity_bytes:
            victim = min(self._entries,
                         key=lambda f: (self._entries[f][1],
                                        self._entries[f][2]))
            del self._entries[victim]
            evicted.append(victim)
        return evicted

    def evict(self, fn_id: str) -> bool:
        """Drop ``fn_id`` from the cache; True if it was present."""
        return self._entries.pop(fn_id, None) is not None

    def clear(self) -> int:
        """Drop every entry (host-cache-loss fault injection); returns
        the number of entries lost."""
        n = len(self._entries)
        self._entries.clear()
        return n

    def lru_order(self) -> List[str]:
        """Cached function ids, least-recently-used first."""
        return sorted(self._entries,
                      key=lambda f: (self._entries[f][1],
                                     self._entries[f][2]))


class ModelStateTracker:
    """The cluster's weight-residency ledger.

    Attached to a ``Reconfigurator`` (``attach_modelstate``); from then
    on ``place_pod`` consults it for start latencies, ``remove_pod``
    demotes weights into the node cache, and the policies use
    ``promote`` / ``host_cached`` / ``gpu_resident`` for pre-warming
    and placement affinity. All methods are O(1)-ish dictionary work --
    the tracker sits on the control plane's hot path.
    """

    def __init__(self, cfg: LifecycleConfig = LifecycleConfig()):
        """Args: cfg: lifecycle knobs (see ``LifecycleConfig``)."""
        self.cfg = cfg
        self._caches: Dict[str, NodeWeightCache] = {}   # node -> LRU
        # (node, fn) -> completion time of an in-flight host fetch
        self._transfers: Dict[Tuple[str, str], float] = {}
        # (gpu uuid, fn) -> number of pods holding the weights in HBM,
        # and the time those weights actually ARRIVE there (a pod
        # placed mid-fetch shares the in-flight load, it does not
        # teleport the weights)
        self._resident: Dict[Tuple[str, str], int] = {}
        self._hbm_ready: Dict[Tuple[str, str], float] = {}
        self._specs: Dict[str, object] = {}             # fn -> FnSpec
        self._starts: Dict[str, int] = {"cold": 0, "warm": 0, "hot": 0}
        self._ttr: List[float] = []                     # time-to-ready (s)
        # monotonic max-seen simulation time: timestamps removal-side
        # cache demotions (remove paths that don't carry a clock)
        self._clock = 0.0

    # ---- config views ------------------------------------------------------
    @property
    def is_passive(self) -> bool:
        """Whether the tracker is byte-transparent (default config)."""
        return self.cfg.is_passive

    def cold_model(self, spec, gpu: GPUType) -> ColdStartModel:
        """The per-tier model for (spec, device) under this config."""
        return physics_cold_model(spec, gpu, self.cfg.object_store_bw)

    # ---- residency queries -------------------------------------------------
    def _cache(self, node: str) -> NodeWeightCache:
        c = self._caches.get(node)
        if c is None:
            c = self._caches[node] = NodeWeightCache(
                self.cfg.host_cache_gb * 2**30)
        return c

    def _tick(self, now: float) -> None:
        self._clock = max(self._clock, now)

    def _sweep(self, node: str, fn_id: str, now: float) -> None:
        """Fold a completed in-flight transfer into the node cache —
        admitted AT its completion time, so a transfer that finished
        long ago ranks below weights genuinely used since (no LRU
        inversion from lazy folding)."""
        self._tick(now)
        tc = self._transfers.get((node, fn_id))
        if tc is not None and tc <= now:
            del self._transfers[(node, fn_id)]
            spec = self._specs.get(fn_id)
            if spec is not None:
                self._cache(node).admit(fn_id, weight_bytes(spec), at=tc)

    def host_cached(self, node: str, fn_id: str,
                    now: Optional[float] = None) -> bool:
        """Whether ``fn_id``'s weights sit in ``node``'s RAM cache
        (completed transfers are folded in first when ``now`` given)."""
        if now is not None:
            self._sweep(node, fn_id, now)
        return self._cache(node).contains(fn_id)

    def gpu_resident(self, gpu_uuid: str, fn_id: str,
                     now: Optional[float] = None) -> bool:
        """Whether chip ``gpu_uuid`` holds ``fn_id``'s weights in HBM.
        With ``now``, the weights must have actually ARRIVED by then —
        a pod still mid-fetch holds a claim, not the weights."""
        if self._resident.get((gpu_uuid, fn_id), 0) <= 0:
            return False
        return (now is None
                or self._hbm_ready.get((gpu_uuid, fn_id), 0.0) <= now)

    def state(self, node: str, fn_id: str, now: float,
              gpu_uuid: Optional[str] = None) -> WeightState:
        """The residency tier of (node, fn) at ``now`` -- GPU when a
        chip is given and its weights have arrived in HBM, else HOST /
        FETCHING / COLD per the node cache, in-flight host transfers,
        and in-flight HBM loads (a chip whose weights are still being
        fetched counts as FETCHING, not GPU)."""
        self._tick(now)
        if gpu_uuid is not None:
            if self.gpu_resident(gpu_uuid, fn_id, now):
                return WeightState.GPU
            if self._resident.get((gpu_uuid, fn_id), 0) > 0:
                return WeightState.FETCHING   # HBM load still in flight
        self._sweep(node, fn_id, now)
        if self._cache(node).contains(fn_id):
            return WeightState.HOST
        if (node, fn_id) in self._transfers:
            return WeightState.FETCHING
        return WeightState.COLD

    def placement_rank(self, gpu, fn_id: str, now: float) -> int:
        """Weight-affinity ordering key for placement: 0 when ``fn_id``'s
        weights are already in the chip's HBM (hot start), 1 when its
        node's host cache holds them (warm), 2 when a prefetch is in
        flight, 3 when cold — the single ranking both the autoscaler
        and the FleetPlacer sort candidate chips by."""
        tier = self.state(gpu.node, fn_id, now, gpu_uuid=gpu.uuid)
        return {WeightState.GPU: 0, WeightState.HOST: 1,
                WeightState.FETCHING: 2, WeightState.COLD: 3}[tier]

    # ---- pre-warming -------------------------------------------------------
    def promote(self, node: str, spec, now: float) -> Optional[float]:
        """Start fetching ``spec``'s weights into ``node``'s RAM.

        Returns the completion time, or None when the weights are
        already host-cached (no-op). An already-running transfer keeps
        its original completion time.
        """
        fn_id = spec.fn_id
        self._specs[fn_id] = spec
        self._sweep(node, fn_id, now)
        if self._cache(node).contains(fn_id):
            return None
        key = (node, fn_id)
        if key not in self._transfers:
            self._transfers[key] = now + (weight_bytes(spec)
                                          / self.cfg.object_store_bw)
        return self._transfers[key]

    # ---- placement / removal hooks (called by the Reconfigurator) ----------
    def on_pod_placed(self, spec, pod, gpu, fresh_chip: bool, now: float,
                      requested_s: float, overhead_s: float = 0.0) -> float:
        """Compute (and record) the start latency of placing ``pod``.

        Passive mode and explicit zero-cost placements (pre-deployed
        pods) return ``requested_s`` unchanged; physics mode derives
        the latency from the weight tier at ``now`` and stamps
        ``pod.start_kind`` with the cold/warm/hot classification.
        """
        if self.is_passive:
            # byte-transparent: no latency change, no bookkeeping (the
            # removal side is equally passive, so any state kept here
            # would leak and misreport long-removed pods as resident)
            return requested_s
        fn_id = spec.fn_id
        self._specs[fn_id] = spec
        self._tick(now)
        key = (gpu.uuid, fn_id)
        if requested_s == 0.0:   # pre-deployed (prewarm): ready at once
            self._resident[key] = self._resident.get(key, 0) + 1
            self._hbm_ready[key] = min(self._hbm_ready.get(key, now), now)
            return 0.0
        model = self.cold_model(spec, gpu.gpu_type)
        self._sweep(gpu.node, fn_id, now)
        # the runtime pulls weights from the FASTEST available source:
        # already-in-HBM, a neighbor pod's in-flight HBM load, the node
        # host cache, an in-flight prefetch, or its own object-store
        # fetch (sharing an in-flight load is NOT always best — a
        # neighbor's chip-init-dominated start can arrive later than a
        # fresh fetch of one's own)
        options = [("cold",
                    model.time_to_ready(WeightState.COLD, fresh_chip))]
        hbm_at = (self._hbm_ready.get(key)
                  if self._resident.get(key, 0) > 0 else None)
        if hbm_at is not None:
            if hbm_at <= now:
                options.append(
                    ("hot", model.time_to_ready(WeightState.GPU,
                                                fresh_chip)))
            else:
                # share the neighbor's in-flight HBM load: wait for
                # its arrival, no fetch/load of our own
                options.append(
                    ("warm", model.container_init_s + (hbm_at - now)
                     + (model.chip_init_s if fresh_chip else 0.0)))
        if self._cache(gpu.node).contains(fn_id):
            options.append(
                ("warm", model.time_to_ready(WeightState.HOST,
                                             fresh_chip)))
        tc = self._transfers.get((gpu.node, fn_id))
        if tc is not None:
            options.append(
                ("warm", model.time_to_ready(WeightState.FETCHING,
                                             fresh_chip,
                                             wait_s=max(0.0, tc - now))))
        kind, t = min(options, key=lambda o: o[1])
        t += overhead_s
        ready = now + t
        # the weights' own movements: a COLD start's fetch lands them
        # in host RAM by the time the start completes (registered as a
        # transfer so the cache folds it in AT that time); an in-flight
        # prefetch keeps its original completion; a HOST hit is a use
        if kind == "cold":
            self._transfers[(gpu.node, fn_id)] = min(
                self._transfers.get((gpu.node, fn_id), float("inf")), ready)
        elif self._cache(gpu.node).contains(fn_id):
            self._cache(gpu.node).touch(fn_id, at=now)
        self._resident[key] = self._resident.get(key, 0) + 1
        self._hbm_ready[key] = min(self._hbm_ready.get(key, float("inf")),
                                   ready)
        pod.start_kind = kind
        self.record_start(fn_id, kind, t)
        return t

    def drop_node_cache(self, node: str, now: Optional[float] = None) -> int:
        """Host-cache-loss fault (``core/faults.py``): drop every
        weight entry cached on ``node`` — and any host fetch still in
        flight toward it — so subsequent starts needing those weights
        demote to COLD and pay the full object-store fetch. Returns
        the number of cached entries lost (0 when the tracker is
        passive or the node has no cache yet)."""
        if self.is_passive:
            return 0
        if now is not None:
            self._tick(now)
        lost = 0
        c = self._caches.get(node)
        if c is not None:
            lost = c.clear()
        for key in [k for k in self._transfers if k[0] == node]:
            del self._transfers[key]
        return lost

    def on_pod_removed(self, pod, gpu, now: Optional[float] = None) -> None:
        """Demote on removal: when the last pod of a function leaves a
        chip, its weights drop out of HBM into the node's host cache
        (LRU admit at the removal time; overflow evicts to COLD)."""
        if self.is_passive:
            return
        at = now if now is not None else self._clock
        self._tick(at)
        key = (gpu.uuid, pod.fn_id)
        n = self._resident.get(key, 0) - 1
        if n > 0:
            self._resident[key] = n
            return
        self._resident.pop(key, None)
        hbm_at = self._hbm_ready.pop(key, at)
        spec = self._specs.get(pod.fn_id)
        if spec is not None and self.cfg.host_cache_gb > 0 and hbm_at <= at:
            # weights killed mid-fetch never reached HBM — their host-
            # side transfer record (if any) folds in on its own; only
            # weights that actually arrived demote from HBM to host
            self._cache(gpu.node).admit(pod.fn_id, weight_bytes(spec),
                                        at=at)

    # ---- statistics --------------------------------------------------------
    def record_start(self, fn_id: str, kind: str, ttr_s: float) -> None:
        """Record one pod start of ``kind`` with time-to-ready
        ``ttr_s`` (the autoscaler reports keep-warm reactivations as
        ``hot`` with 0)."""
        self._starts[kind] = self._starts.get(kind, 0) + 1
        bisect.insort(self._ttr, ttr_s)

    def reset_stats(self) -> None:
        """Clear start/ttr statistics (called after deploy-time
        prewarm so pre-run placements don't pollute run metrics)."""
        self._starts = {"cold": 0, "warm": 0, "hot": 0}
        self._ttr = []

    def start_counts(self) -> Dict[str, int]:
        """Pod starts by kind since the last ``reset_stats``."""
        return dict(self._starts)

    def ttr_percentiles(self) -> Optional[Dict[str, float]]:
        """{p50, p99} time-to-ready in seconds, None with no samples."""
        if not self._ttr:
            return None
        n = len(self._ttr)

        def pct(p: float) -> float:
            return self._ttr[min(n - 1, int(p * (n - 1) + 0.999999))]

        return {"p50": self._ttr[(n - 1) // 2], "p99": pct(0.99)}
