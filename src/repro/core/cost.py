"""Function cost accounting (paper Fig 7).

Each chip is billed at its ``GPUType``'s price (``configs/gpus.py``;
the reference device keeps the Google Cloud V100 price $2.48/hour the
paper uses). Fine-grained platforms (HAS, FaST-like) are charged for
the fraction ``(sm / sm_total) x quota`` actually held on each chip;
whole-GPU platforms (KServe-like) are charged the full chip for the
pod's lifetime.

On a single-type fleet the per-type grouping below accumulates in
exactly the legacy iteration order, so all-default-fleet runs reproduce
the pre-heterogeneity cost streams bitwise.

The old module-level ``GPU_PRICE_PER_HOUR`` constant is deprecated:
price is a per-``GPUType`` field now. Accessing it still works (it
returns the reference device's price) but emits a DeprecationWarning.
"""
from __future__ import annotations

import dataclasses
import warnings

from repro.configs.gpus import DEFAULT_GPU_TYPE
from repro.core.modelstate import IDLE_RETENTION_FACTOR

_DEPRECATED = {"GPU_PRICE_PER_HOUR": DEFAULT_GPU_TYPE.price_per_hour}
_WARNED: set = set()   # each deprecated name warns exactly once/process


def _reset_deprecation_warnings() -> None:
    """Re-arm the once-per-process deprecation warnings (test hook)."""
    _WARNED.clear()


def __getattr__(name: str):
    if name in _DEPRECATED:
        if name not in _WARNED:
            _WARNED.add(name)
            warnings.warn(
                "cost.GPU_PRICE_PER_HOUR is deprecated: GPU price is a "
                "GPUType field (configs/gpus.py); this constant only "
                "reflects the reference device.",
                DeprecationWarning, stacklevel=2)
        return _DEPRECATED[name]
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class CostMeter:
    whole_gpu: bool = False
    total_usd: float = 0.0
    gpu_seconds: float = 0.0
    # fraction of a keep-warm standby pod's full-quota slice price that
    # keeps accruing while it idles in the keep-warm pool (model-state
    # lifecycle; default shared with LifecycleConfig — one source);
    # irrelevant when no pod is standby
    idle_retention_factor: float = IDLE_RETENTION_FACTOR

    def rates(self, recon) -> tuple:
        """(usd/s, gpu-fraction) rates for the current allocation. The
        rate only changes when a policy mutates the cluster, so callers
        integrating between events can sample it once per mutation.

        ``gpu-fraction`` is device-count-weighted (one whole chip of any
        type contributes 1.0) while usd/s weights each chip's share by
        its type's price. Keep-warm standby pods are billed at
        ``idle_retention_factor`` of their full-quota slice share (they
        reserve slices and HBM, not execution time)."""
        fracs = {}  # GPUType -> occupied fraction, first-seen order
        if self.whole_gpu:
            for g in recon.used_gpus():
                fracs[g.gpu_type] = fracs.get(g.gpu_type, 0.0) + 1.0
        else:
            for g in recon.used_gpus():
                t = g.gpu_type
                s = fracs.get(t, 0.0)
                for pod in g.pods:
                    if pod.standby:
                        s += (self.idle_retention_factor
                              * (pod.sm / float(t.sm_total)))
                    else:
                        s += (pod.sm / float(t.sm_total)) * pod.quota
                fracs[t] = s
        usd_rate = 0.0
        frac = 0.0
        for t, s in fracs.items():
            usd_rate += s * t.price_per_hour / 3600.0
            frac += s
        return usd_rate, frac

    def accrue_rates(self, rates: tuple, dt: float) -> None:
        """Integrate a pre-sampled (usd/s, gpu-fraction) rate over dt."""
        self.total_usd += rates[0] * dt
        self.gpu_seconds += rates[1] * dt

    def accrue(self, recon, dt: float) -> None:
        """Integrate cost over dt seconds given current allocations."""
        self.accrue_rates(self.rates(recon), dt)

    def per_1k_requests(self, completed: int) -> float:
        if completed == 0:
            return float("inf")
        return self.total_usd / completed * 1000.0
