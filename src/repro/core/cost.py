"""Function cost accounting (paper Fig 7).

Costs use the Google Cloud V100 price ($2.48/hour). Fine-grained platforms
(HAS, FaST-like) are charged for the fraction (sm/8 x quota) actually
held; whole-GPU platforms (KServe-like) are charged the full chip for the
pod's lifetime.
"""
from __future__ import annotations

import dataclasses

GPU_PRICE_PER_HOUR = 2.48


@dataclasses.dataclass
class CostMeter:
    whole_gpu: bool = False
    total_usd: float = 0.0
    gpu_seconds: float = 0.0

    def rates(self, recon) -> tuple:
        """(usd/s, gpu-fraction) rates for the current allocation. The
        rate only changes when a policy mutates the cluster, so callers
        integrating between events can sample it once per mutation."""
        if self.whole_gpu:
            frac = float(len(recon.used_gpus()))
        else:
            frac = sum((pod.sm / 8.0) * pod.quota
                       for g in recon.used_gpus() for pod in g.pods)
        return frac * GPU_PRICE_PER_HOUR / 3600.0, frac

    def accrue_rates(self, rates: tuple, dt: float) -> None:
        """Integrate a pre-sampled (usd/s, gpu-fraction) rate over dt."""
        self.total_usd += rates[0] * dt
        self.gpu_seconds += rates[1] * dt

    def accrue(self, recon, dt: float) -> None:
        """Integrate cost over dt seconds given current allocations."""
        self.accrue_rates(self.rates(recon), dt)

    def per_1k_requests(self, completed: int) -> float:
        if completed == 0:
            return float("inf")
        return self.total_usd / completed * 1000.0
