"""Function cost accounting (paper Fig 7).

Costs use the Google Cloud V100 price ($2.48/hour). Fine-grained platforms
(HAS, FaST-like) are charged for the fraction (sm/8 x quota) actually
held; whole-GPU platforms (KServe-like) are charged the full chip for the
pod's lifetime.
"""
from __future__ import annotations

import dataclasses

GPU_PRICE_PER_HOUR = 2.48


@dataclasses.dataclass
class CostMeter:
    whole_gpu: bool = False
    total_usd: float = 0.0
    gpu_seconds: float = 0.0

    def accrue(self, recon, dt: float) -> None:
        """Integrate cost over dt seconds given current allocations."""
        rate = 0.0
        if self.whole_gpu:
            rate = len(recon.used_gpus()) * GPU_PRICE_PER_HOUR / 3600.0
            self.gpu_seconds += len(recon.used_gpus()) * dt
        else:
            for g in recon.used_gpus():
                for pod in g.pods:
                    frac = (pod.sm / 8.0) * pod.quota
                    rate += frac * GPU_PRICE_PER_HOUR / 3600.0
                    self.gpu_seconds += frac * dt
        self.total_usd += rate * dt

    def per_1k_requests(self, completed: int) -> float:
        if completed == 0:
            return float("inf")
        return self.total_usd / completed * 1000.0
