"""Discrete-event cluster simulator for serverless inference auto-scaling.

Physics: request arrivals (workload trace) -> gateway load balancer
(throughput-weighted, paper §3) -> per-pod queues -> window-quantized
execution on each pod's (sm, quota) allocation (the vTPU time-token
scheduler's observable behavior, perf_model.latency) -> completion records.

The auto-scaling policy (HAS hybrid / KServe-like / FaST-GShare-like) runs
every ``autoscale_interval_s`` on the observed request rate, mutating the
same Reconfigurator cluster state. Cost and SLO metrics integrate over the
run. Pure Python/numpy — fast enough for hundreds of simulated minutes.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import perf_model
from repro.core.cost import CostMeter
from repro.core.perf_model import FnSpec
from repro.core.reconfigurator import Reconfigurator
from repro.core.slo import Request, percentiles, violation_rates


@dataclasses.dataclass
class SimConfig:
    tick_s: float = 0.02
    autoscale_interval_s: float = 1.0
    duration_s: float = 300.0
    seed: int = 0
    whole_gpu_cost: bool = False
    batch_wait_s: float = 0.01   # max wait to fill a batch
    drop_after_s: float = 60.0   # requests older than this count as violations


@dataclasses.dataclass
class PodRuntime:
    pod_id: str
    busy_until: float = 0.0
    inflight: List[Request] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SimResult:
    latencies: np.ndarray
    n_arrived: int
    n_completed: int
    n_dropped: int
    cost_usd: float
    cost_per_1k: float
    baseline_s: float
    pcts: dict
    pod_seconds: float
    timeline: list

    def violations(self, multipliers):
        lat = self.latencies
        # dropped requests count as violations at every multiplier
        pad = np.full(self.n_dropped, np.inf)
        return violation_rates(np.concatenate([lat, pad]),
                               self.baseline_s, multipliers)


class ClusterSimulator:
    def __init__(self, spec: FnSpec, policy, recon: Reconfigurator,
                 arrivals: np.ndarray, cfg: SimConfig = SimConfig()):
        """arrivals: sorted array of request arrival times (seconds)."""
        self.spec = spec
        self.policy = policy
        self.recon = recon
        self.arrivals = arrivals
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.runtimes: Dict[str, PodRuntime] = {}
        self.queue: deque = deque()  # shared per-function FIFO (pull model)
        self.completed: List[Request] = []
        self.dropped = 0
        self.cost = CostMeter(whole_gpu=cfg.whole_gpu_cost)
        self.timeline: list = []

    # ---- execution ----------------------------------------------------------
    # Pull-based dispatch (OpenFaaS queue-worker semantics): idle ready pods
    # pull up to `batch` requests from the shared function queue; the
    # highest-capacity pods pull first (the gateway's throughput-weighted
    # distribution emerges from pull order + service rates).
    def _execute(self, now: float):
        pods = {p.pod_id: p for p in self.recon.pods_of(self.spec.fn_id)}
        for pid in list(self.runtimes):
            if pid not in pods:
                rt = self.runtimes.pop(pid)
                for r in rt.inflight:  # inflight on a removed pod completes
                    r.completion = rt.busy_until
                    self.completed.append(r)
        order = sorted(
            pods.values(),
            key=lambda p: -perf_model.throughput(self.spec, p.batch, p.sm,
                                                 p.quota))
        for pod in order:
            rt = self.runtimes.setdefault(pod.pod_id, PodRuntime(pod.pod_id))
            if rt.busy_until > now:
                continue
            if rt.inflight:
                for r in rt.inflight:
                    r.completion = rt.busy_until
                self.completed.extend(rt.inflight)
                rt.inflight = []
            if not self.queue or pod.ready_at > now:
                continue
            # batch formation: run when full or the head waited long enough
            if (len(self.queue) < pod.batch
                    and now - self.queue[0].arrival < self.cfg.batch_wait_s):
                continue
            take = min(pod.batch, len(self.queue))
            batch = [self.queue.popleft() for _ in range(take)]
            service = perf_model.latency(self.spec, take, pod.sm, pod.quota,
                                         window_ms=self.recon.window_ms,
                                         rng=self.rng)
            for r in batch:
                r.start = now
            rt.busy_until = now + service
            rt.inflight = batch

    # ---- main loop ------------------------------------------------------------
    def run(self) -> SimResult:
        cfg = self.cfg
        t, ai = 0.0, 0
        n = len(self.arrivals)
        last_scale = -1e9
        window_arrivals = deque()
        while t < cfg.duration_s or ai < n or self._work_left():
            if t > cfg.duration_s + cfg.drop_after_s:
                break
            # arrivals
            while ai < n and self.arrivals[ai] <= t:
                req = Request(self.spec.fn_id, float(self.arrivals[ai]))
                window_arrivals.append(req.arrival)
                self.queue.append(req)
                ai += 1
            # shed requests that aged out in queue
            while self.queue and t - self.queue[0].arrival > cfg.drop_after_s:
                self.queue.popleft()
                self.dropped += 1
            # autoscaler: observed load = arrival rate + backlog drain demand
            # (queued work is gateway-visible and must be scheduled too)
            if t - last_scale >= cfg.autoscale_interval_s:
                while window_arrivals and window_arrivals[0] < t - 5.0:
                    window_arrivals.popleft()
                observed = len(window_arrivals) / max(min(t, 5.0), 1e-9) \
                    if t > 0 else 0.0
                observed += len(self.queue) / 5.0
                self.policy.tick(t, self.spec, observed)
                last_scale = t
                self.timeline.append(
                    (t, observed, len(self.recon.pods_of(self.spec.fn_id)),
                     sum((p.sm / 8.0) * p.quota
                         for p in self.recon.pods_of(self.spec.fn_id))))
            # execution + cost
            self._execute(t)
            self.cost.accrue(self.recon, cfg.tick_s)
            t += cfg.tick_s

        # flush remaining inflight
        for rt in self.runtimes.values():
            for r in rt.inflight:
                r.completion = rt.busy_until
                self.completed.append(r)
        self.dropped += len(self.queue)

        lats = np.array([r.latency for r in self.completed
                         if r.latency is not None])
        base = perf_model.slo_baseline(
            self.spec, getattr(self.policy, "cfg", None).default_batch
            if hasattr(getattr(self.policy, "cfg", None), "default_batch")
            else 8)
        return SimResult(
            latencies=lats, n_arrived=n, n_completed=len(lats),
            n_dropped=self.dropped, cost_usd=self.cost.total_usd,
            cost_per_1k=self.cost.per_1k_requests(len(lats)),
            baseline_s=base, pcts=percentiles(lats),
            pod_seconds=self.cost.gpu_seconds, timeline=self.timeline)

    def _work_left(self) -> bool:
        if self.queue:
            return True
        return any(r.inflight for r in self.runtimes.values())
