"""Single-function cluster simulator for serverless inference auto-scaling.

Physics: request arrivals (workload trace) -> gateway load balancer
(throughput-weighted, paper §3) -> per-pod queues -> window-quantized
execution on each pod's (sm, quota) allocation (the vTPU time-token
scheduler's observable behavior, perf_model.latency) -> completion records.

The auto-scaling policy (HAS hybrid / KServe-like / FaST-GShare-like) runs
every ``autoscale_interval_s`` on the observed request rate, mutating the
same Reconfigurator cluster state. Cost and SLO metrics integrate over the
run.

Since PR 1 this is a thin wrapper over the discrete-event engine in
``core/events.py`` (heap-scheduled arrivals / batch timeouts / pod-free /
autoscale-timer events) — orders of magnitude faster than scanning a
20 ms tick over the trace. The original tick engine survives as
``core/simulator_tick.py`` and the parity test
(``tests/test_event_parity.py``) pins the two engines together.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core import perf_model
from repro.core.cost import CostMeter
from repro.core.events import (EventEngine, FunctionState, PodRuntime,
                               SimConfig)
from repro.core.metrics import baseline_batch_of
from repro.core.perf_model import FnSpec
from repro.core.reconfigurator import Reconfigurator
from repro.core.slo import Request, percentiles, violation_rates

__all__ = ["ClusterSimulator", "PodRuntime", "SimConfig", "SimResult",
           "result_from_state"]


@dataclasses.dataclass
class SimResult:
    latencies: np.ndarray
    n_arrived: int
    n_completed: int
    n_dropped: int
    cost_usd: float
    cost_per_1k: float
    baseline_s: float
    pcts: dict
    pod_seconds: float
    timeline: list
    cold_starts: int = 0
    action_counts: dict = dataclasses.field(default_factory=dict)

    def violations(self, multipliers):
        lat = self.latencies
        # dropped requests count as violations at every multiplier
        pad = np.full(self.n_dropped, np.inf)
        return violation_rates(np.concatenate([lat, pad]),
                               self.baseline_s, multipliers)


def result_from_state(st: FunctionState, cost: CostMeter,
                      baseline_batch: int = 8) -> SimResult:
    """Fold a drained FunctionState into the stable SimResult API."""
    lats = np.array([r.latency for r in st.completed
                     if r.latency is not None])
    # stream-metrics runs fold completions into the engine's sink
    # instead of retaining them: the count survives on the state
    n_comp = len(lats) + getattr(st, "stream_n_completed", 0)
    base = perf_model.slo_baseline(st.spec, baseline_batch)
    return SimResult(
        latencies=lats, n_arrived=len(st.arrivals), n_completed=n_comp,
        n_dropped=st.dropped, cost_usd=cost.total_usd,
        cost_per_1k=cost.per_1k_requests(n_comp),
        baseline_s=base, pcts=percentiles(lats),
        pod_seconds=cost.gpu_seconds, timeline=st.timeline,
        cold_starts=st.cold_starts, action_counts=dict(st.action_counts))


class ClusterSimulator:
    def __init__(self, spec: FnSpec, policy, recon: Reconfigurator,
                 arrivals: np.ndarray, cfg: SimConfig = SimConfig(),
                 engine_cls=EventEngine):
        """arrivals: sorted array of request arrival times (seconds).
        ``engine_cls`` swaps the event engine (the scalar reference
        ``core/engine_scalar.py`` for parity/benchmark runs)."""
        self.spec = spec
        self.policy = policy
        self.recon = recon
        self.arrivals = arrivals
        self.cfg = cfg
        self.cost = CostMeter(whole_gpu=cfg.whole_gpu_cost)
        self.state = FunctionState(spec, policy, arrivals)
        self.engine = engine_cls(recon, cfg, [self.state], cost=self.cost,
                                 rng=np.random.default_rng(cfg.seed),
                                 track_peak=True)

    # introspection used by tests/tools; delegates to the engine state
    @property
    def queue(self):
        return self.state.queue

    @property
    def completed(self) -> List[Request]:
        return self.state.completed

    @property
    def dropped(self) -> int:
        return self.state.dropped

    @property
    def runtimes(self) -> Dict[str, PodRuntime]:
        return self.state.runtimes

    @property
    def timeline(self) -> list:
        return self.state.timeline

    @property
    def peak_gpus(self) -> int:
        return self.engine.peak_gpus

    def run(self) -> SimResult:
        self.engine.run()
        return result_from_state(self.state, self.cost,
                                 baseline_batch_of(self.policy))
