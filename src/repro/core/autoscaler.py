"""Hybrid vertical + horizontal auto-scaling — paper Algorithm 1.

Scale-up: vertical first (add time-quota to pods, largest-SM pods first —
a small quota increment there buys the most throughput), then horizontal
onto the least-occupied used GPU (HGO metric), then a fresh GPU with the
most cost-efficient (batch, sm, quota) for the residual gap.
Scale-down: mirrored, smallest-SM pods first, cooldown-guarded, always
keeping one pod alive (no scale-to-zero => no cold start).

The latency predictor is pluggable: the trained RaPP model or the
roofline oracle (both expose lat(spec, batch, sm, quota) seconds).
Either way the scaler consumes it through a `CapacityTable`
(core/capacity.py): per-(spec, batch) (sm x quota) latency lattices
filled in one batched call, so a scaling decision is argmin/lookup work
instead of ~480 scalar predictor queries; per-function capacity C_f is
maintained incrementally by the Reconfigurator instead of re-invoking
the predictor for every pod at every autoscale event.

Heterogeneous fleets: every throughput/latency/SLO query is evaluated
against the device type actually hosting (or candidate to host) the
pod, and new-capacity decisions use the cross-type dollar-minimizing
search (`CapacityTable.best_config_over`) plus first-fit-decreasing
fragment packing (`core/scheduler.FleetPlacer`). On a single-type fleet
every one of those paths degenerates to the legacy behavior — the
homogeneous golden traces are reproduced bitwise.

Spot fleets (any ``GPUType`` carrying a ``GPUMarket``) additionally
activate the hybrid cost/SLO router: an always-warm ON-DEMAND FLOOR
(``spot_od_floor`` of predicted demand must be served by reliable
capacity before any new pod may land on spot), a reclaim-pressure
breaker (when more than ``reclaim_pressure_max`` reclaim notices landed
within ``reclaim_pressure_window_s``, overflow shifts to on-demand
until the storm passes), doomed-chip avoidance (chips inside a reclaim
grace window are never placement targets and their pods contribute
zero capacity — so reclaimed capacity is replaced within the grace
window by the ordinary scale-up paths), and floor-guarded scale-down
(on-demand pods above the floor are shed first — they are the expensive
ones — but the floor itself is never breached, so a demand trough can
not leave a spot-only rump that a reclaim storm would wipe out). When
demand falls, ``_rebalance_to_spot`` migrates overflow back from
on-demand to spot make-before-break: the spot replacement is placed
first and the on-demand pod is only retired once the replacement is
ready. All of it is inert — bitwise — on fleets without a market.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.configs.gpus import DEFAULT_GPU_TYPE, GPUType
from repro.core import capacity as capacity_mod
from repro.core import modelstate as modelstate_mod
from repro.core.kalman import BatchedKalman, KalmanPredictor
from repro.core.perf_model import FnSpec
from repro.core.reconfigurator import Reconfigurator
from repro.core.scheduler import FleetPlacer
from repro.core.vgpu import PodAlloc, TOTAL_SLICES


@dataclasses.dataclass
class AutoScalerConfig:
    alpha: float = 0.85        # scale-up trigger: R > C_f * alpha
    beta: float = 0.55         # scale-down trigger: R < C_f * beta
    quota_step: float = 0.1    # Delta I_q
    min_quota: float = 0.1
    cooldown_s: float = 20.0   # T_cooldown between scale-downs
    r_min: float = 1.0         # minimum retained capacity (RPS)
    default_batch: int = 8
    default_sm: int = 4
    # cold-start physics: derived from the shared component sums in
    # core/modelstate.py (2.5 s warm-chip / 8.0 s fresh-chip), the same
    # source the baseline policies quote theirs from
    cold_start_s: float = modelstate_mod.WARM_CHIP_COLD_START_S
    new_gpu_cold_start_s: float = modelstate_mod.NEW_GPU_COLD_START_S
    slo_multiplier: float = 1.5  # latency cap: m x whole-chip baseline
    service_overhead_s: float = 0.02  # batching/dispatch overhead per cycle
    # ---- model-state lifecycle knobs (inert without an attached
    # ModelStateTracker; see core/modelstate.py) ----
    keep_warm_pods: int = 0    # standby pods retained per fn on scale-down
    prewarm_lead_s: float = 0.0  # forecast horizon for weight pre-warming
    # ---- hybrid spot router knobs (inert on market-free fleets) ----
    spot_od_floor: float = 0.25       # demand fraction kept on on-demand
    reclaim_pressure_window_s: float = 12.0   # pressure lookback window
    reclaim_pressure_max: int = 2     # notices/window before spot is cut


@dataclasses.dataclass
class ScalingAction:
    fn_id: str
    pod_id: str
    kind: str          # vup | vdown | hup | hdown
    detail: str = ""


class HybridAutoScaler:
    def __init__(self, recon: Reconfigurator,
                 predictor: Optional[Callable] = None,
                 cfg: AutoScalerConfig = AutoScalerConfig(),
                 window_ms: float = 100.0):
        self.recon = recon
        # a cluster with an active ModelStateTracker carries the
        # lifecycle knobs (keep-warm pool size, pre-warm lead) in its
        # tracker config; adopt any the caller left at the inert
        # defaults so EVERY construction path — including custom
        # policy_factory hooks — honors the scenario's lifecycle
        tracker = recon.modelstate
        if tracker is not None and not tracker.is_passive:
            adopt = {}
            if cfg.keep_warm_pods == 0 and tracker.cfg.keep_warm_pods > 0:
                adopt["keep_warm_pods"] = tracker.cfg.keep_warm_pods
            if cfg.prewarm_lead_s == 0 and tracker.cfg.prewarm_lead_s > 0:
                adopt["prewarm_lead_s"] = tracker.cfg.prewarm_lead_s
            if adopt:
                cfg = dataclasses.replace(cfg, **adopt)
        self.cfg = cfg
        self.window_ms = window_ms
        if predictor is None:
            self.table = capacity_mod.shared_table(cfg.quota_step, window_ms)
        else:
            self.table = capacity_mod.CapacityTable(
                predictor, quota_step=cfg.quota_step, window_ms=window_ms)
        self.predict_latency = self.table.lat
        self.placer = FleetPlacer(recon, self.table,
                                  slo_multiplier=cfg.slo_multiplier)
        self.kalman: Dict[str, KalmanPredictor] = {}
        self.last_scale_down: Dict[str, float] = {}
        self._cap_models: Dict[str, Callable] = {}
        self._prev_pred: Dict[str, tuple] = {}   # fn -> (t, predicted R)
        # quota a keep-warm pod served with before parking: reactivation
        # restores the known-good allocation instead of re-deriving a
        # borderline SLO-floor quota
        self._parked_quota: Dict[str, float] = {}
        # hybrid spot router active iff the fleet declares a market
        self._spot_fleet = any(t.market is not None
                               for t, _ in getattr(recon, "fleet", ()))
        # in-flight od->spot migrations: fn_id -> (od_pod_id, spot_pod_id);
        # the od pod retires only once its spot replacement is ready
        self._migrations: Dict[str, tuple] = {}

    def _tracker(self):
        """The cluster's active ModelStateTracker, or None (legacy)."""
        tr = self.recon.modelstate
        return tr if tr is not None and not tr.is_passive else None

    # ---- throughput helpers ------------------------------------------------
    def thpt(self, spec: FnSpec, batch: int, sm: int, quota: float,
             gpu: Optional[GPUType] = None) -> float:
        return self.table.throughput(spec, batch, sm, quota,
                                     self.cfg.service_overhead_s, gpu)

    def pod_thpt(self, spec: FnSpec, pod: PodAlloc) -> float:
        return self.thpt(spec, pod.batch, pod.sm, pod.quota, pod.gpu_type)

    def _ensure_capacity_model(self, spec: FnSpec) -> None:
        model = self._cap_models.get(spec.fn_id)
        if model is None:
            # keep-warm standby pods hold weights, not capacity; doomed
            # pods are draining toward a reclaim kill and quarantined
            # pods are health-benched stragglers — writing them off
            # now is what makes the scaler replace them inside the
            # grace/quarantine window
            model = self._cap_models[spec.fn_id] = (
                lambda p, _s=spec: 0.0
                if (p.standby or p.doomed or p.quarantined) else
                self.thpt(_s, p.batch, p.sm, p.quota, p.gpu_type))
        # no-op when already installed; re-registers (and recomputes
        # contributions) if another scaler on the same cluster took over
        self.recon.register_capacity_model(spec.fn_id, model)

    def capacity(self, spec: FnSpec) -> float:
        self._ensure_capacity_model(spec)
        return self.recon.fn_capacity(spec.fn_id)

    # ---- main entry ----------------------------------------------------------
    def tick(self, now: float, spec: FnSpec,
             observed_rps: float) -> List[ScalingAction]:
        k = self.kalman.setdefault(spec.fn_id, KalmanPredictor())
        predicted = k.update(observed_rps)
        self._maybe_prewarm(now, spec, predicted)
        return self.scale(now, spec, predicted)

    # ---- forecast-driven pre-warming ---------------------------------------
    def _maybe_prewarm(self, now: float, spec: FnSpec, R: float) -> None:
        """Project the Kalman estimate ``prewarm_lead_s`` ahead; when
        the projection crosses the scale-up trigger, start weight
        fetches on the likely placement nodes (the least-occupied used
        chips with room and the next fresh-chip node) so the coming
        horizontal-ups find host-cached weights."""
        tracker = self._tracker()
        lead = self.cfg.prewarm_lead_s
        prev = self._prev_pred.get(spec.fn_id)
        self._prev_pred[spec.fn_id] = (now, R)
        if tracker is None or lead <= 0 or prev is None:
            return
        t0, r0 = prev
        if now <= t0:
            return
        slope = (R - r0) / (now - t0)
        if slope <= 0:
            return
        if not self.recon.pods_of(spec.fn_id):
            return
        projected = R + slope * lead
        if projected <= self.capacity(spec) * self.cfg.alpha:
            return
        nodes = []
        used = sorted((g for g in self.recon.used_gpus()
                       if g.slices_free > 0 or g.can_place(
                           self.cfg.default_sm, self.cfg.min_quota)),
                      key=lambda g: g.hgo)
        nodes += [g.node for g in used[:2]]
        nodes.append(self.recon.peek_next_node())
        for node in dict.fromkeys(nodes):   # de-dup, keep order
            tracker.promote(node, spec, now)

    def scale(self, now: float, spec: FnSpec, R: float) -> List[ScalingAction]:
        cfg = self.cfg
        actions: List[ScalingAction] = []
        pods = self.recon.pods_of(spec.fn_id)
        if not pods:
            actions += self._bootstrap(now, spec, max(R, cfg.r_min))
            return actions
        c_f = self.capacity(spec)

        if R > c_f * cfg.alpha:                      # ---- scale UP
            delta = R - c_f * cfg.alpha
            delta, acts = self._reactivate_standby(now, spec, pods, delta)
            actions += acts
            if delta > 0:
                delta, acts = self._vertical_up(spec, pods, delta)
                actions += acts
            if delta > 0:
                delta, acts = self._horizontal_up_used(now, spec, delta, R)
                actions += acts
            if delta > 0:
                actions += self._horizontal_up_new(now, spec, delta, R)
        elif (R < c_f * cfg.beta and c_f > cfg.r_min
              and now - self.last_scale_down.get(spec.fn_id, -1e18)
              >= cfg.cooldown_s):                    # ---- scale DOWN
            delta = c_f - max(R, cfg.r_min) / cfg.alpha
            acts = self._scale_down(now, spec, pods, delta, R)
            if acts:
                self.last_scale_down[spec.fn_id] = now
            actions += acts
            self.recon.release_empty_gpus()
        if self._spot_fleet and now > 0.0:
            # now > 0: prewarm drives scale() at t=0 to lay out the
            # steady state — migrating it mid-deploy would churn pods
            # before traffic even starts
            actions += self._rebalance_to_spot(now, spec, R)
        return actions

    # ---- hybrid spot router ------------------------------------------------
    def _od_capacity(self, spec: FnSpec, pods) -> float:
        """Serving capacity on RELIABLE (market-free) devices — the
        quantity the on-demand floor is measured against."""
        return sum(self.pod_thpt(spec, p) for p in pods
                   if not p.standby and not p.doomed and not p.quarantined
                   and (p.gpu_type is None or p.gpu_type.market is None))

    def _reclaim_pressure(self, now: float) -> int:
        """Reclaim notices within the trailing pressure window."""
        log = getattr(self.recon, "reclaim_log", ())
        lo = now - self.cfg.reclaim_pressure_window_s
        n = 0
        for t in reversed(log):
            if t < lo:
                break
            n += 1
        return n

    def _spot_allowed(self, now: float, spec: FnSpec, R: float) -> bool:
        """Whether NEW capacity may land on spot right now: the
        on-demand floor must already hold and recent reclaim pressure
        must be below the breaker threshold."""
        pods = self.recon.pods_of(spec.fn_id)
        if self._od_capacity(spec, pods) < self.cfg.spot_od_floor * R - 1e-9:
            return False
        return self._reclaim_pressure(now) <= self.cfg.reclaim_pressure_max

    def _route_types(self, types: List[GPUType],
                     spot_ok: bool) -> List[GPUType]:
        """Filter candidate fresh-chip types by the router decision —
        never down to nothing (an all-spot fleet still serves)."""
        if spot_ok:
            return types
        od = [t for t in types if t.market is None]
        return od or types

    def _rebalance_to_spot(self, now, spec, R) -> List[ScalingAction]:
        """Shift on-demand overflow back onto spot once reclaim pressure
        subsides: place one spot replacement sized like the largest
        above-floor on-demand pod, and retire that pod only when the
        replacement is ready (make-before-break: no capacity dip). One
        migration in flight per function — the cold start self-throttles
        the drain rate. This is the return direction of the router: the
        storm response converts spot capacity to on-demand, and without
        it the expensive bulge would persist under the scale-down
        hysteresis (beta) long after the market calmed down."""
        actions: List[ScalingAction] = []
        pend = self._migrations.get(spec.fn_id)
        pods = self.recon.pods_of(spec.fn_id)
        by_id = {p.pod_id: p for p in pods}
        if pend is not None:
            od_pod = by_id.get(pend[0])
            spot_pod = by_id.get(pend[1])
            if (od_pod is None or spot_pod is None or spot_pod.doomed
                    or od_pod.standby):
                # handover lost its endpoints (scale-down took the od
                # pod, or the replacement was itself reclaimed) — abort
                self._migrations.pop(spec.fn_id, None)
            elif spot_pod.ready_at <= now:
                self.recon.remove_pod(od_pod.pod_id, now=now)
                self.recon.release_empty_gpus()
                self._migrations.pop(spec.fn_id, None)
                actions.append(ScalingAction(
                    spec.fn_id, od_pod.pod_id, "hdown",
                    f"migrated to spot ({spot_pod.pod_id})"))
            return actions
        c_f = self.capacity(spec)
        if (R > c_f * self.cfg.alpha            # scale-up owns this tick
                or not self._spot_allowed(now, spec, R)):
            return actions
        od_cap = self._od_capacity(spec, pods)
        floor = self.cfg.spot_od_floor * R
        cands = [p for p in pods
                 if not p.standby and not p.doomed and not p.quarantined
                 and (p.gpu_type is None or p.gpu_type.market is None)
                 and od_cap - self.pod_thpt(spec, p) >= floor - 1e-9]
        if not cands:
            return actions
        victim = max(cands, key=lambda p: self.pod_thpt(spec, p))
        need = max(self.pod_thpt(spec, victim), self.cfg.r_min)
        spot_types = list(dict.fromkeys(
            t for t, _ in self.recon.fleet if t.market is not None))
        t, b, sm, q = self.table.best_config_over(
            spec, need, spot_types, slo_multiplier=self.cfg.slo_multiplier)
        pod = PodAlloc(fn_id=spec.fn_id, sm=sm, quota=q, batch=b)
        host = self.placer.place_one(
            spec, pod, now=now, cold_start_s=self.cfg.cold_start_s,
            new_gpu_cold_start_s=self.cfg.new_gpu_cold_start_s,
            allowed_types=spot_types)
        if host is None:          # spot pool exhausted — nothing to do
            return actions
        self._migrations[spec.fn_id] = (victim.pod_id, pod.pod_id)
        actions.append(ScalingAction(
            spec.fn_id, pod.pod_id, "hup",
            f"spot takeover of {victim.pod_id} (b={b} sm={sm} "
            f"q={q:.2f} [{t.name}])"))
        return actions

    # ---- bootstrap -----------------------------------------------------------
    def _placement_types(self, now: float = 0.0, spec: Optional[FnSpec] = None,
                         R: float = 0.0) -> List[GPUType]:
        """Device types a fresh chip could come from, in fleet order —
        when every cap is reached, all fleet types (the config is still
        computed; placement may then fail exactly as before). On a spot
        fleet the hybrid router additionally filters reclaimable types
        out while the on-demand floor is unmet or reclaim pressure is
        high."""
        avail = self.recon.available_gpu_types()
        types = avail or [t for t, _ in self.recon.fleet]
        if self._spot_fleet and spec is not None:
            types = self._route_types(types,
                                      self._spot_allowed(now, spec, R))
        return types

    def _bootstrap(self, now, spec, target_rps) -> List[ScalingAction]:
        self._ensure_capacity_model(spec)
        t, b, sm, q = self.table.best_config_over(
            spec, target_rps, self._placement_types(now, spec, target_rps),
            slo_multiplier=self.cfg.slo_multiplier)
        gpu = self._gpu_with_room(sm, q, t, fn_id=spec.fn_id, now=now)
        pod = PodAlloc(fn_id=spec.fn_id, sm=sm, quota=q, batch=b)
        cold = (self.cfg.cold_start_s if gpu is not None
                else self.cfg.new_gpu_cold_start_s)
        self.recon.place_pod(pod, gpu.uuid if gpu else None, now=now,
                             cold_start_s=cold, gpu_type=t, spec=spec)
        tag = "" if t == DEFAULT_GPU_TYPE else f" [{t.name}]"
        return [ScalingAction(spec.fn_id, pod.pod_id, "hup",
                              f"bootstrap b={b} sm={sm} q={q:.2f}{tag}")]

    def _affinity_rank(self, g, fn_id: Optional[str], now: float):
        """Weight-residency rank of chip ``g`` for ``fn_id`` at ``now``
        (``ModelStateTracker.placement_rank``: HBM-resident < host-
        cached < fetch in flight < cold) — constant 0 without an active
        lifecycle tracker, so legacy ordering is untouched."""
        tracker = self._tracker()
        if tracker is None or fn_id is None:
            return 0
        return tracker.placement_rank(g, fn_id, now)

    def _gpu_with_room(self, sm, q, gpu_type=None, fn_id=None, now=0.0):
        """Least-occupied used GPU that can host (sm, q) — restricted to
        ``gpu_type`` chips, since the config was priced for that device
        (a no-op filter on a homogeneous fleet). With an active
        lifecycle tracker, chips already holding (or caching) the
        function's weights rank first."""
        cands = [g for g in self.recon.used_gpus()
                 if (gpu_type is None or g.gpu_type == gpu_type)
                 and not g.doomed and g.can_place(sm, q)]
        if not cands:
            return None
        return min(cands,
                   key=lambda g: (self._affinity_rank(g, fn_id, now), g.hgo))

    # ---- keep-warm pool reactivation ---------------------------------------
    def _reactivate_standby(self, now, spec, pods, delta):
        """Reactivate keep-warm standby pods before any other scale-up
        path: a quota rewrite on a pod whose weights never left HBM is
        instant capacity (a "hot" start) at zero transfer cost."""
        actions = []
        tracker = self._tracker()
        if tracker is None:
            return delta, actions
        step = self.cfg.quota_step
        for pod in pods:
            if delta <= 0:
                break
            if not pod.standby or pod.doomed:
                continue
            gpu = self.recon.gpu_of_pod(pod.pod_id)
            if gpu is None:
                continue
            avail = gpu.max_avail_quota_for(pod)
            q_floor = self.table.min_quota_for_slo(
                spec, pod.batch, pod.sm, self.cfg.slo_multiplier,
                gpu=pod.gpu_type) or self.cfg.min_quota
            floor = max(self.cfg.min_quota, q_floor)
            if floor > avail + 1e-9:
                continue   # partition filled up; stays standby
            # restore the quota the pod served with before parking (a
            # known-good allocation with SLO headroom), topped up by
            # quota steps while the gap demands more
            q = max(self._parked_quota.get(pod.pod_id, 0.0), floor)
            if q > avail + 1e-9:
                continue
            while (q + step <= avail + 1e-9
                   and self.thpt(spec, pod.batch, pod.sm, q,
                                 pod.gpu_type) < delta):
                q += step
            self._parked_quota.pop(pod.pod_id, None)
            pod.standby = False
            pod.start_kind = "hot"
            self.recon.set_quota(pod.pod_id, q)
            tracker.record_start(spec.fn_id, "hot", 0.0)
            delta -= self.thpt(spec, pod.batch, pod.sm, q, pod.gpu_type)
            actions.append(ScalingAction(spec.fn_id, pod.pod_id, "hup",
                                         f"reactivate q={q:.2f}"))
        return delta, actions

    # ---- vertical scale-up (paper L3-9) ---------------------------------------
    def _vertical_up(self, spec, pods, delta):
        actions = []
        for pod in sorted(pods, key=lambda p: -p.sm):
            if delta <= 0:
                break
            if pod.standby or pod.doomed or pod.quarantined:
                continue   # keep-warm pods rejoin via reactivation only;
                           # doomed/quarantined pods are out of service
            gpu = self.recon.gpu_of_pod(pod.pod_id)
            if gpu is None:
                continue
            a_q = gpu.max_avail_quota_for(pod)
            base = self.pod_thpt(spec, pod)
            step = self.cfg.quota_step
            n, gained, new_q = 0, 0.0, pod.quota
            while pod.quota + step * (n + 1) <= a_q + 1e-9 \
                    and delta - gained > 0:
                n += 1
                cand_q = pod.quota + step * n
                gained = self.thpt(spec, pod.batch, pod.sm, cand_q,
                                   pod.gpu_type) - base
                new_q = cand_q
            if n > 0:
                self.recon.set_quota(pod.pod_id, new_q)
                delta -= gained
                actions.append(ScalingAction(
                    spec.fn_id, pod.pod_id, "vup",
                    f"q->{new_q:.2f} (+{gained:.1f} rps)"))
        return delta, actions

    # ---- horizontal scale-up onto a used GPU (paper L10-17) --------------------
    def _type_slo_capable(self, spec, batch, t: GPUType) -> bool:
        """Whether device class ``t`` has ANY SLO-satisfying quota at
        ``batch`` on its full width (lattice lookup, cached by the
        table) — spot classes that can never meet the SLO rank behind
        every capable class when choosing a used chip."""
        return self.table.min_quota_for_slo(
            spec, batch, t.sm_total, self.cfg.slo_multiplier,
            gpu=t) is not None

    def _horizontal_up_used(self, now, spec, delta, R=0.0):
        actions = []
        if self.recon.is_heterogeneous:
            # mixed fleet: SLO-capable device classes first (a cheap
            # spot chip would dead-end the used-GPU path), cheapest
            # $/slice class next, weight affinity, HGO inside a class.
            # Doomed chips are draining toward a kill; on a spot fleet
            # the router may additionally bar reclaimable chips.
            b0 = self.cfg.default_batch
            used = [g for g in self.recon.used_gpus() if not g.doomed]
            if self._spot_fleet and not self._spot_allowed(now, spec, R):
                od = [g for g in used if g.gpu_type.market is None]
                used = od or used
            gpu = min(used, key=lambda g: (
                not self._type_slo_capable(spec, b0, g.gpu_type),
                g.gpu_type.price_per_slice_hour,
                self._affinity_rank(g, spec.fn_id, now),
                g.hgo)) if used else None
        elif self._tracker() is not None:
            # lifecycle runs: the legacy capacity-seeking choice (lowest
            # HGO — the chip that can host the widest/fastest config)
            # with weight affinity only as the tie-break, restricted to
            # chips that can actually host something. Affinity must NOT
            # outrank HGO here: the pod's shape is chosen from the
            # host's headroom, and a weight-affine but crowded chip
            # yields slow slivers (or dead-ends the used-GPU path into
            # fresh-chip spam) — a start is warm for ~2 s once; a bad
            # (sm, quota) is slow for the pod's whole lifetime.
            cands = []
            for g in self.recon.used_gpus():
                if g.doomed:
                    continue
                s_avail, q_avail = g.max_avail_alloc()
                if s_avail > 0 and q_avail >= self.cfg.min_quota:
                    cands.append(g)
            gpu = min(cands, key=lambda g: (
                g.hgo,
                self._affinity_rank(g, spec.fn_id, now))) if cands else None
        else:
            gpu = self.recon.lowest_hgo_gpu()
        if gpu is None:
            return delta, actions
        t = gpu.gpu_type
        s_max, q_max = gpu.max_avail_alloc()
        if s_max <= 0 or q_max < self.cfg.min_quota:
            return delta, actions
        b = self.cfg.default_batch
        c_max = self.thpt(spec, b, s_max, q_max, t)
        if c_max <= delta:
            return delta, actions  # used GPUs can't close the gap; go new
        q_floor = self.table.min_quota_for_slo(
            spec, b, s_max, self.cfg.slo_multiplier, gpu=t)
        if q_floor is None or q_floor > q_max + 1e-9:
            return delta, actions  # no SLO-satisfying slot on used GPUs
        step = self.cfg.quota_step
        n, cap = 0, 0.0
        while step * (n + 1) <= q_max + 1e-9 and cap < delta:
            n += 1
            cap = self.thpt(spec, b, s_max, step * n, t)
        q = max(step * max(n, 1), q_floor)
        pod = PodAlloc(fn_id=spec.fn_id, sm=s_max, quota=q, batch=b)
        self.recon.place_pod(pod, gpu.uuid, now=now,
                             cold_start_s=self.cfg.cold_start_s, spec=spec)
        actions.append(ScalingAction(spec.fn_id, pod.pod_id, "hup",
                                     f"used-gpu {gpu.uuid} sm={s_max} "
                                     f"q={q:.2f}"))
        return delta - cap, actions

    # ---- horizontal scale-up onto a new GPU (paper L18-19) ---------------------
    def prewarm(self, spec: FnSpec, expected_rps: float):
        """Deploy the steady-state config before traffic starts (ready
        immediately) — models a function already deployed, as in §4."""
        self._bootstrap(0.0, spec, expected_rps)
        # close any residual capacity gap exactly as the algorithm would
        for _ in range(8):
            if self.capacity(spec) * self.cfg.alpha >= expected_rps:
                break
            self.scale(0.0, spec, expected_rps)
        for pod in self.recon.pods_of(spec.fn_id):
            pod.ready_at = 0.0

    def _horizontal_up_new(self, now, spec, delta, R=0.0):
        actions = []
        het = self.recon.is_heterogeneous
        while delta > 0:
            # the router decision is re-taken per placement: each pod
            # landing on on-demand grows the floor until spot opens up
            types = self._placement_types(now, spec, R)
            t, b, sm, q = self.table.best_config_over(
                spec, delta, types,
                slo_multiplier=self.cfg.slo_multiplier)
            pod = PodAlloc(fn_id=spec.fn_id, sm=sm, quota=q, batch=b)
            if het:
                # mixed fleet: FFD-pack onto existing fragments of a
                # cheaper SLO-capable type before opening a fresh chip.
                # On a spot fleet the placer is held to the router's
                # type set; if those pools are exhausted, fall back to
                # anything rather than under-provision.
                allowed = types if self._spot_fleet else None
                host = self.placer.place_one(
                    spec, pod, now=now,
                    cold_start_s=self.cfg.cold_start_s,
                    new_gpu_cold_start_s=self.cfg.new_gpu_cold_start_s,
                    allowed_types=allowed)
                if host is None and allowed is not None:
                    host = self.placer.place_one(
                        spec, pod, now=now,
                        cold_start_s=self.cfg.cold_start_s,
                        new_gpu_cold_start_s=self.cfg.new_gpu_cold_start_s)
                if host is None:   # fleet exhausted
                    break
                t = host.gpu_type
            else:
                try:
                    self.recon.place_pod(
                        pod, None, now=now,
                        cold_start_s=self.cfg.new_gpu_cold_start_s,
                        gpu_type=t, spec=spec)
                except RuntimeError:   # cluster at capacity
                    break
            cap = self.thpt(spec, pod.batch, pod.sm, pod.quota, t)
            tag = "" if t == DEFAULT_GPU_TYPE else f" [{t.name}]"
            actions.append(ScalingAction(spec.fn_id, pod.pod_id, "hup",
                                         f"new-gpu sm={pod.sm} "
                                         f"q={pod.quota:.2f}{tag}"))
            delta -= cap
        return actions

    # ---- scale-down (paper L20-26) ----------------------------------------------
    def _standby_count(self, fn_id: str) -> int:
        """Keep-warm standby pods currently parked for ``fn_id``."""
        return sum(1 for p in self.recon.pods_of(fn_id) if p.standby)

    def _scale_down(self, now, spec, pods, delta, R=0.0):
        actions = []
        tracker = self._tracker()
        # Expensive on-demand pods shed first on a spot fleet (the spot
        # discount is the whole point of carrying reclaim risk), BUT
        # never below the router's on-demand floor — that floor is what
        # absorbs the next reclaim storm. On a market-free fleet the
        # spot key is constant and the stable sort degenerates to the
        # legacy smallest-SM order bitwise.
        def _down_key(p):
            is_spot = p.gpu_type is not None and p.gpu_type.market is not None
            return (1 if is_spot else 0, p.sm)
        # Floor the demand estimate at the scale-down trigger line
        # (c_f * beta) and at r_min: a transient predictor collapse
        # (R ~ 0 while traffic is live) must not shed the on-demand
        # floor down to a spot-only rump — rebuilding it on fresh
        # reclaimable chips is slow and swamps the queue. Under a
        # sustained real trough c_f itself decays, so the floor follows
        # demand down geometrically instead of instantly.
        od_floor = 0.0
        if self._spot_fleet:
            c_now = sum(self.pod_thpt(spec, p) for p in pods
                        if not p.standby and not p.doomed
                        and not p.quarantined)
            od_floor = self.cfg.spot_od_floor * max(
                R, c_now * self.cfg.beta, self.cfg.r_min)
        for pod in sorted(pods, key=_down_key):
            if delta <= 0:
                break
            if pod.standby:
                continue   # already parked in the keep-warm pool
            if pod.doomed:
                continue   # draining toward a reclaim kill; not ours
            is_od = pod.gpu_type is None or pod.gpu_type.market is None
            if (od_floor > 0.0 and is_od
                    and self._od_capacity(spec,
                                          self.recon.pods_of(spec.fn_id))
                    - self.pod_thpt(spec, pod) < od_floor - 1e-9):
                continue   # shedding this pod would breach the od floor
            remaining = [p for p in self.recon.pods_of(spec.fn_id)
                         if not p.standby]
            is_last = len(remaining) == 1
            contrib = self.pod_thpt(spec, pod)
            step = self.cfg.quota_step
            if not is_last and contrib <= delta + 1e-9:
                if (tracker is not None and pod.ready_at <= now
                        and self._standby_count(spec.fn_id)
                        < self.cfg.keep_warm_pods):
                    # only READY pods qualify for keep-warm (a pod still
                    # mid-cold-start has no warm state to keep, and its
                    # later reactivation would be a bogus "hot" start)
                    # keep-warm: park the pod at ~zero quota instead of
                    # evicting — weights stay GPU-resident, reactivation
                    # is a hot start; CostMeter bills idle retention
                    self._parked_quota[pod.pod_id] = pod.quota
                    pod.standby = True
                    self.recon.set_quota(pod.pod_id,
                                         modelstate_mod.KEEP_WARM_QUOTA)
                    actions.append(ScalingAction(spec.fn_id, pod.pod_id,
                                                 "hdown", "kept-warm"))
                else:
                    self.recon.remove_pod(pod.pod_id, now=now)
                    actions.append(ScalingAction(spec.fn_id, pod.pod_id,
                                                 "hdown", "removed"))
                delta -= contrib
                continue
            # vertical scale-down: shed quota stepwise (never below the
            # SLO-satisfying floor for this pod's (batch, sm) on its
            # host device)
            q_floor = self.table.min_quota_for_slo(
                spec, pod.batch, pod.sm,
                self.cfg.slo_multiplier, gpu=pod.gpu_type) \
                or self.cfg.min_quota
            floor = max(self.cfg.min_quota, q_floor)
            n = 0
            while pod.quota - step * (n + 1) >= floor - 1e-9:
                cand = self.thpt(spec, pod.batch, pod.sm,
                                 pod.quota - step * (n + 1), pod.gpu_type)
                if contrib - cand > delta:
                    break
                n += 1
            if n > 0:
                new_q = pod.quota - step * n
                shed = contrib - self.thpt(spec, pod.batch, pod.sm, new_q,
                                           pod.gpu_type)
                self.recon.set_quota(pod.pod_id, new_q)
                delta -= shed
                actions.append(ScalingAction(spec.fn_id, pod.pod_id, "vdown",
                                             f"q->{new_q:.2f}"))
        return actions


# ---- batched sweep decide path (wide engine fast path) ----------------------
#
# The wide engine's autoscale sweep touches EVERY active function; at
# azure_wide width the Python-per-function observe -> Kalman -> decide
# loop dominates wall-clock even though almost every tick is a no-op
# (the prediction sits inside the [beta, alpha] band and scale() returns
# without acting). SweepDecider vectorizes exactly that common case:
# one BatchedKalman update for the fleet plus one array comparison
# against lattice-backed capacities classifies every slot into
# no-op / scale-up / scale-down / bootstrap bands, and only the slots
# that actually need action drop into the per-function scale() path.
#
# Correctness contract: for an ELIGIBLE slot, the batched classify plus
# (for action slots) a direct ``scale(now, spec, predicted)`` call is
# byte-identical to ``tick(now, spec, observed)`` — the filter lanes
# reproduce KalmanPredictor bitwise, the band tests reuse scale()'s own
# expressions, and a no-op tick's scale() call has no observable side
# effects. Ineligible slots (spot router, active pre-warm forecasting,
# non-Kalman predictors, HybridAutoScaler subclasses) always take the
# full per-function tick().

def fast_path_eligible(policy) -> bool:
    """Whether ``policy``'s per-tick behavior is fully captured by the
    batched decide path.

    Requires exactly ``HybridAutoScaler`` (a subclass may override
    anything), no spot router (``_rebalance_to_spot`` runs — and may
    act — on every tick of a spot fleet), and no forecast-driven
    pre-warming (``_maybe_prewarm`` reads consecutive predictions only
    when a tracker is live AND ``prewarm_lead_s > 0``; otherwise its
    only effect is `_prev_pred` bookkeeping nothing reads).
    """
    return (type(policy) is HybridAutoScaler
            and not policy._spot_fleet
            and (policy._tracker() is None
                 or policy.cfg.prewarm_lead_s <= 0))


class SweepDecider:
    """Struct-of-arrays decide pass over the fleet's function slots.

    Slots are adopted with :meth:`bind` (one per function, at engine
    start); each sweep then calls :meth:`decide` once with the batched
    observations to get per-slot predictions and an action mask. The
    per-slot band tests mirror ``HybridAutoScaler.scale`` exactly:

        up        = pred > C_f * alpha
        down-cand = pred < C_f * beta  and  C_f > r_min
                    and  now - last_scale_down >= cooldown_s
        action    = up | down-cand | no-pods (bootstrap)

    A fresh down-candidate routes to scale() even when scale() will end
    up shedding nothing — the fast path only ever skips ticks that are
    provably no-ops. But sterile down attempts REPEAT: scale() only
    refreshes the cooldown clock when it actually sheds, so a function
    pinned at its floor (single pod, quota at the SLO minimum)
    re-candidates every sweep forever — the dominant tick class on
    long-tail fleets. ``_scale_down``'s two shed gates are monotone in
    ``delta = C_f - max(R, r_min)/alpha`` (a pod removable at delta is
    removable at any larger delta; a quota step shed-blocked at delta
    stays blocked at any smaller one), so one action-free call at
    delta0 proves every retry with delta <= delta0 action-free while
    the pod set is unchanged. ``sterile_delta`` memoizes that proof per
    slot; the engine wipes it whenever the slot's pod set is refreshed
    and suppresses proven-sterile down-candidates on the fast path.
    """

    def __init__(self, n_slots: int):
        self.n = n_slots
        self.kalman = BatchedKalman(n_slots)
        self.eligible = np.zeros(n_slots, dtype=bool)
        # alpha defaults to 1 (not 0) so the delta division is warning-
        # free on unbound lanes — their results are masked out anyway
        self.alpha = np.ones(n_slots)
        self.beta = np.zeros(n_slots)
        self.cooldown = np.zeros(n_slots)
        self.r_min = np.zeros(n_slots)
        self.last_down = np.full(n_slots, -1e18)
        # largest scale-down delta proven action-free for the CURRENT
        # pod set (-inf: no proof); see the class docstring
        self.sterile_delta = np.full(n_slots, -np.inf)
        # memoized policy.capacity(spec) per slot — C_f only changes
        # when the slot's pod set / quotas / health flags do, so the
        # engine invalidates it at the same points as sterile_delta
        # (plus quarantine-set, which flips capacity without a refresh)
        self.cap = np.zeros(n_slots)
        self.cap_ok = [False] * n_slots
        self._policies: list = [None] * n_slots
        self._fids: list = [None] * n_slots

    def bind(self, slot: int, policy, fn_id: str) -> bool:
        """Adopt ``(policy, fn_id)`` into ``slot``; returns whether the
        slot is eligible for the fast path. Creates (or adopts) the
        policy's Kalman lane — a pre-seeded non-Kalman predictor (the
        ablation swap) makes the slot ineligible."""
        self._policies[slot] = policy
        self._fids[slot] = fn_id
        ok = fast_path_eligible(policy)
        if ok:
            pred = policy.kalman.setdefault(fn_id, KalmanPredictor())
            ok = type(pred) is KalmanPredictor
            if ok:
                self.kalman.bind(slot, pred)
                cfg = policy.cfg
                self.alpha[slot] = cfg.alpha
                self.beta[slot] = cfg.beta
                self.cooldown[slot] = cfg.cooldown_s
                self.r_min[slot] = cfg.r_min
                self.last_down[slot] = policy.last_scale_down.get(
                    fn_id, -1e18)
        self.eligible[slot] = ok
        return ok

    def decide(self, now: float, obs: np.ndarray, cap: np.ndarray,
               has_pods: np.ndarray, mask: np.ndarray):
        """One batched observe -> predict -> classify pass.

        ``mask`` selects the slots participating this sweep (active AND
        eligible); other lanes keep their state and return stale
        predictions that callers must ignore. Returns
        ``(pred, action, sterile, down_band, delta)``:

        - ``action`` flags masked slots needing a real ``scale()`` call;
        - ``sterile`` flags down-candidates suppressed by a memoized
          action-free proof (``delta <= sterile_delta``) — the engine
          may fast-path them ONLY while the cluster has no empty chips
          (so scale()'s trailing ``release_empty_gpus()`` would no-op);
        - ``down_band`` / ``delta`` let the engine record a fresh proof
          when a slow-path down-band scale() returns no actions.
        """
        pred = self.kalman.update(obs, mask)
        up = pred > cap * self.alpha
        down = ((pred < cap * self.beta) & (cap > self.r_min)
                & (now - self.last_down >= self.cooldown))
        # scale() evaluates the up band first, so the down band (and
        # with it the sterility memo) only applies when up is False
        down_band = down & ~up & has_pods
        delta = cap - np.maximum(pred, self.r_min) / self.alpha
        sterile = down_band & (delta <= self.sterile_delta)
        action = mask & (up | down | ~has_pods) & ~sterile
        return pred, action, mask & sterile, down_band, delta

    def refresh_after_scale(self, slot: int) -> None:
        """Re-read ``last_scale_down`` after a slow-path scale() call
        (a shed refreshes the cooldown clock the band test reads)."""
        self.last_down[slot] = self._policies[slot].last_scale_down.get(
            self._fids[slot], -1e18)

    def sync_back(self) -> None:
        """Scatter filter lanes back into the per-policy predictors."""
        self.kalman.sync_back()
