"""Hybrid vertical + horizontal auto-scaling — paper Algorithm 1.

Scale-up: vertical first (add time-quota to pods, largest-SM pods first —
a small quota increment there buys the most throughput), then horizontal
onto the least-occupied used GPU (HGO metric), then a fresh GPU with the
most cost-efficient (batch, sm, quota) for the residual gap.
Scale-down: mirrored, smallest-SM pods first, cooldown-guarded, always
keeping one pod alive (no scale-to-zero => no cold start).

The latency predictor is pluggable: the trained RaPP model or the
roofline oracle (both expose lat(spec, batch, sm, quota) seconds).
Either way the scaler consumes it through a `CapacityTable`
(core/capacity.py): per-(spec, batch) (sm x quota) latency lattices
filled in one batched call, so a scaling decision is argmin/lookup work
instead of ~480 scalar predictor queries; per-function capacity C_f is
maintained incrementally by the Reconfigurator instead of re-invoking
the predictor for every pod at every autoscale event.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

from repro.core import capacity as capacity_mod
from repro.core.kalman import KalmanPredictor
from repro.core.perf_model import FnSpec
from repro.core.reconfigurator import Reconfigurator
from repro.core.vgpu import PodAlloc, TOTAL_SLICES


@dataclasses.dataclass
class AutoScalerConfig:
    alpha: float = 0.85        # scale-up trigger: R > C_f * alpha
    beta: float = 0.55         # scale-down trigger: R < C_f * beta
    quota_step: float = 0.1    # Delta I_q
    min_quota: float = 0.1
    cooldown_s: float = 20.0   # T_cooldown between scale-downs
    r_min: float = 1.0         # minimum retained capacity (RPS)
    default_batch: int = 8
    default_sm: int = 4
    cold_start_s: float = 2.5  # container + weight load on a warm chip
    new_gpu_cold_start_s: float = 8.0   # + chip/program initialization
    slo_multiplier: float = 1.5  # latency cap: m x whole-chip baseline
    service_overhead_s: float = 0.02  # batching/dispatch overhead per cycle


@dataclasses.dataclass
class ScalingAction:
    fn_id: str
    pod_id: str
    kind: str          # vup | vdown | hup | hdown
    detail: str = ""


class HybridAutoScaler:
    def __init__(self, recon: Reconfigurator,
                 predictor: Optional[Callable] = None,
                 cfg: AutoScalerConfig = AutoScalerConfig(),
                 window_ms: float = 100.0):
        self.recon = recon
        self.cfg = cfg
        self.window_ms = window_ms
        if predictor is None:
            self.table = capacity_mod.shared_table(cfg.quota_step, window_ms)
        else:
            self.table = capacity_mod.CapacityTable(
                predictor, quota_step=cfg.quota_step, window_ms=window_ms)
        self.predict_latency = self.table.lat
        self.kalman: Dict[str, KalmanPredictor] = {}
        self.last_scale_down: Dict[str, float] = {}
        self._cap_models: Dict[str, Callable] = {}

    # ---- throughput helpers ------------------------------------------------
    def thpt(self, spec: FnSpec, batch: int, sm: int, quota: float) -> float:
        return batch / (self.table.lat(spec, batch, sm, quota)
                        + self.cfg.service_overhead_s)

    def pod_thpt(self, spec: FnSpec, pod: PodAlloc) -> float:
        return self.thpt(spec, pod.batch, pod.sm, pod.quota)

    def _ensure_capacity_model(self, spec: FnSpec) -> None:
        model = self._cap_models.get(spec.fn_id)
        if model is None:
            model = self._cap_models[spec.fn_id] = (
                lambda p, _s=spec: self.thpt(_s, p.batch, p.sm, p.quota))
        # no-op when already installed; re-registers (and recomputes
        # contributions) if another scaler on the same cluster took over
        self.recon.register_capacity_model(spec.fn_id, model)

    def capacity(self, spec: FnSpec) -> float:
        self._ensure_capacity_model(spec)
        return self.recon.fn_capacity(spec.fn_id)

    # ---- main entry ----------------------------------------------------------
    def tick(self, now: float, spec: FnSpec,
             observed_rps: float) -> List[ScalingAction]:
        k = self.kalman.setdefault(spec.fn_id, KalmanPredictor())
        predicted = k.update(observed_rps)
        return self.scale(now, spec, predicted)

    def scale(self, now: float, spec: FnSpec, R: float) -> List[ScalingAction]:
        cfg = self.cfg
        actions: List[ScalingAction] = []
        pods = self.recon.pods_of(spec.fn_id)
        if not pods:
            actions += self._bootstrap(now, spec, max(R, cfg.r_min))
            return actions
        c_f = self.capacity(spec)

        if R > c_f * cfg.alpha:                      # ---- scale UP
            delta = R - c_f * cfg.alpha
            delta, acts = self._vertical_up(spec, pods, delta)
            actions += acts
            if delta > 0:
                delta, acts = self._horizontal_up_used(now, spec, delta)
                actions += acts
            if delta > 0:
                actions += self._horizontal_up_new(now, spec, delta)
        elif (R < c_f * cfg.beta and c_f > cfg.r_min
              and now - self.last_scale_down.get(spec.fn_id, -1e18)
              >= cfg.cooldown_s):                    # ---- scale DOWN
            delta = c_f - max(R, cfg.r_min) / cfg.alpha
            acts = self._scale_down(spec, pods, delta)
            if acts:
                self.last_scale_down[spec.fn_id] = now
            actions += acts
            self.recon.release_empty_gpus()
        return actions

    # ---- bootstrap -----------------------------------------------------------
    def _bootstrap(self, now, spec, target_rps) -> List[ScalingAction]:
        self._ensure_capacity_model(spec)
        b, sm, q = self.table.most_efficient_config(
            spec, target_rps, slo_multiplier=self.cfg.slo_multiplier)
        gpu = self._gpu_with_room(sm, q)
        pod = PodAlloc(fn_id=spec.fn_id, sm=sm, quota=q, batch=b)
        cold = (self.cfg.cold_start_s if gpu is not None
                else self.cfg.new_gpu_cold_start_s)
        self.recon.place_pod(pod, gpu.uuid if gpu else None, now=now,
                             cold_start_s=cold)
        return [ScalingAction(spec.fn_id, pod.pod_id, "hup",
                              f"bootstrap b={b} sm={sm} q={q:.2f}")]

    def _gpu_with_room(self, sm, q):
        cands = [g for g in self.recon.used_gpus() if g.can_place(sm, q)]
        if not cands:
            return None
        return min(cands, key=lambda g: g.hgo)

    # ---- vertical scale-up (paper L3-9) ---------------------------------------
    def _vertical_up(self, spec, pods, delta):
        actions = []
        for pod in sorted(pods, key=lambda p: -p.sm):
            if delta <= 0:
                break
            gpu = self.recon.gpu_of_pod(pod.pod_id)
            if gpu is None:
                continue
            a_q = gpu.max_avail_quota_for(pod)
            base = self.pod_thpt(spec, pod)
            step = self.cfg.quota_step
            n, gained, new_q = 0, 0.0, pod.quota
            while pod.quota + step * (n + 1) <= a_q + 1e-9 \
                    and delta - gained > 0:
                n += 1
                cand_q = pod.quota + step * n
                gained = self.thpt(spec, pod.batch, pod.sm, cand_q) - base
                new_q = cand_q
            if n > 0:
                self.recon.set_quota(pod.pod_id, new_q)
                delta -= gained
                actions.append(ScalingAction(
                    spec.fn_id, pod.pod_id, "vup",
                    f"q->{new_q:.2f} (+{gained:.1f} rps)"))
        return delta, actions

    # ---- horizontal scale-up onto a used GPU (paper L10-17) --------------------
    def _horizontal_up_used(self, now, spec, delta):
        actions = []
        gpu = self.recon.lowest_hgo_gpu()
        if gpu is None:
            return delta, actions
        s_max, q_max = gpu.max_avail_alloc()
        if s_max <= 0 or q_max < self.cfg.min_quota:
            return delta, actions
        b = self.cfg.default_batch
        c_max = self.thpt(spec, b, s_max, q_max)
        if c_max <= delta:
            return delta, actions  # used GPUs can't close the gap; go new
        q_floor = self.table.min_quota_for_slo(
            spec, b, s_max, self.cfg.slo_multiplier)
        if q_floor is None or q_floor > q_max + 1e-9:
            return delta, actions  # no SLO-satisfying slot on used GPUs
        step = self.cfg.quota_step
        n, cap = 0, 0.0
        while step * (n + 1) <= q_max + 1e-9 and cap < delta:
            n += 1
            cap = self.thpt(spec, b, s_max, step * n)
        q = max(step * max(n, 1), q_floor)
        pod = PodAlloc(fn_id=spec.fn_id, sm=s_max, quota=q, batch=b)
        self.recon.place_pod(pod, gpu.uuid, now=now,
                             cold_start_s=self.cfg.cold_start_s)
        actions.append(ScalingAction(spec.fn_id, pod.pod_id, "hup",
                                     f"used-gpu {gpu.uuid} sm={s_max} "
                                     f"q={q:.2f}"))
        return delta - cap, actions

    # ---- horizontal scale-up onto a new GPU (paper L18-19) ---------------------
    def prewarm(self, spec: FnSpec, expected_rps: float):
        """Deploy the steady-state config before traffic starts (ready
        immediately) — models a function already deployed, as in §4."""
        self._bootstrap(0.0, spec, expected_rps)
        # close any residual capacity gap exactly as the algorithm would
        for _ in range(8):
            if self.capacity(spec) * self.cfg.alpha >= expected_rps:
                break
            self.scale(0.0, spec, expected_rps)
        for pod in self.recon.pods_of(spec.fn_id):
            pod.ready_at = 0.0

    def _horizontal_up_new(self, now, spec, delta):
        actions = []
        while delta > 0:
            b, sm, q = self.table.most_efficient_config(
                spec, delta, slo_multiplier=self.cfg.slo_multiplier)
            pod = PodAlloc(fn_id=spec.fn_id, sm=sm, quota=q, batch=b)
            try:
                self.recon.place_pod(pod, None, now=now,
                                     cold_start_s=self.cfg.new_gpu_cold_start_s)
            except RuntimeError:   # cluster at capacity
                break
            cap = self.thpt(spec, b, sm, q)
            actions.append(ScalingAction(spec.fn_id, pod.pod_id, "hup",
                                         f"new-gpu sm={sm} q={q:.2f}"))
            delta -= cap
        return actions

    # ---- scale-down (paper L20-26) ----------------------------------------------
    def _scale_down(self, spec, pods, delta):
        actions = []
        # smallest-SM pods first, keep at least one pod
        for pod in sorted(pods, key=lambda p: p.sm):
            if delta <= 0:
                break
            remaining = self.recon.pods_of(spec.fn_id)
            is_last = len(remaining) == 1
            contrib = self.pod_thpt(spec, pod)
            step = self.cfg.quota_step
            if not is_last and contrib <= delta + 1e-9:
                self.recon.remove_pod(pod.pod_id)
                delta -= contrib
                actions.append(ScalingAction(spec.fn_id, pod.pod_id, "hdown",
                                             "removed"))
                continue
            # vertical scale-down: shed quota stepwise (never below the
            # SLO-satisfying floor for this pod's (batch, sm))
            q_floor = self.table.min_quota_for_slo(
                spec, pod.batch, pod.sm,
                self.cfg.slo_multiplier) or self.cfg.min_quota
            floor = max(self.cfg.min_quota, q_floor)
            n = 0
            while pod.quota - step * (n + 1) >= floor - 1e-9:
                cand = self.thpt(spec, pod.batch, pod.sm,
                                 pod.quota - step * (n + 1))
                if contrib - cand > delta:
                    break
                n += 1
            if n > 0:
                new_q = pod.quota - step * n
                shed = contrib - self.thpt(spec, pod.batch, pod.sm, new_q)
                self.recon.set_quota(pod.pod_id, new_q)
                delta -= shed
                actions.append(ScalingAction(spec.fn_id, pod.pod_id, "vdown",
                                             f"q->{new_q:.2f}"))
        return actions
