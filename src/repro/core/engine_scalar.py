"""Frozen scalar reference of the discrete-event engine (pre-PR-9).

This module preserves the event engine exactly as it was before the
wide-engine refactor of ``core/events.py``: one heap pop per event
(every request arrival is its own heap event), one autoscale timer
chain per function, and cluster cost/fragmentation rates re-sampled
after every per-function autoscale event. It plays the same role
``core/simulator_tick.py`` played for PR 1 — the executable spec the
optimized engine is differentially tested against:

  * ``tests/test_engine_parity.py`` fuzzes random small scenario
    configs (mixed fleets, spot markets, fault models, lifecycle
    on/off) through both engines and requires byte-identical
    ``RunMetrics``;
  * ``benchmarks/bench_engine.py`` times wide-vs-scalar events/s on the
    wide configuration and gates the speedup in CI.

The shared dataclasses (``SimConfig`` / ``FunctionState`` /
``PodRuntime``) and the event-kind constants are imported from
``core/events.py`` — only the engine class itself is frozen here. The
wide-engine-only knobs (``SimConfig.stream_metrics`` /
``rng_isolation``) are intentionally ignored by this class: parity runs
compare the two engines over the legacy feature space.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Optional

import numpy as np

from repro.core import capacity as capacity_mod
from repro.core import perf_model
from repro.core.cost import CostMeter
from repro.core.events import (ARRIVAL, AUTOSCALE, CHIP_FAIL, DISPATCH,
                               OBS_WINDOW_S, POD_FAULT, QUAR_LIFT,
                               RECLAIM_KILL, RECLAIM_NOTICE, RETRY,
                               FunctionState, PodRuntime, SimConfig)
from repro.core.faults import FaultInjector, HealthTracker
from repro.core.reconfigurator import Reconfigurator
from repro.core.slo import Request

__all__ = ["ScalarEventEngine"]


class ScalarEventEngine:
    """The pre-wide-refactor event engine, verbatim (one heap pop per
    event, per-function autoscale timer chains, rates re-sampled per
    function tick). The differential-fuzz parity suite
    (``tests/test_engine_parity.py``) runs every random config through
    BOTH engines and requires byte-identical ``RunMetrics``, and
    ``benchmarks/bench_engine.py`` times the wide engine against this
    one. Do not optimize this class: its value is being frozen."""

    def __init__(self, recon: Reconfigurator, cfg: SimConfig,
                 fns: List[FunctionState], cost: Optional[CostMeter] = None,
                 rng: Optional[np.random.Generator] = None,
                 track_peak: bool = False):
        self.recon = recon
        self.cfg = cfg
        self.fns: Dict[str, FunctionState] = {st.fid: st for st in fns}
        self.cost = cost or CostMeter(whole_gpu=cfg.whole_gpu_cost)
        # an active model-state lifecycle dictates the keep-warm idle-
        # retention billing rate; adopt it so every construction path
        # (not just the scenario engine) bills standby pods consistently
        tracker = getattr(recon, "modelstate", None)
        if tracker is not None and not tracker.is_passive:
            self.cost.idle_retention_factor = \
                tracker.cfg.idle_retention_factor
        self.rng = rng or np.random.default_rng(cfg.seed)
        self.track_peak = track_peak
        self.peak_gpus = 0
        self.now = 0.0
        self._heap: list = []
        self._seq = itertools.count()
        self._thpt_cache: Dict[tuple, float] = {}
        self.n_events = 0   # heap pops processed (bench_engine events/s)
        # service times read the shared oracle lattice tables — pod
        # configs straight off the control plane's grid are a lattice
        # hit; off-grid quotas (accumulated vertical steps) take the
        # table's exact scalar fallback. Dispatch-order throughput uses
        # the default-window table (the ordering metric has always been
        # window-independent of the cluster's window_ms).
        self._svc_table = capacity_mod.shared_table(
            window_ms=recon.window_ms)
        self._ord_table = capacity_mod.shared_table()
        self._cost_rates = self.cost.rates(recon)
        # spatial fragmentation is integrated over time exactly like
        # cost: the value only changes when a policy mutates the
        # cluster, so it is re-sampled at autoscale events
        self._frag_rate = recon.fragmentation()
        self.frag_integral = 0.0
        # ---- spot reclaims ----
        # active only when the fleet declares a reclaiming market; the
        # reclaim stream is SEPARATE from the service-noise rng so
        # reclaim-free runs stay bitwise identical to legacy traces
        self._has_spot = any(
            t.market is not None and t.market.reclaim_rate_per_hour > 0
            for t, _ in getattr(recon, "fleet", ()))
        self._reclaim_rng = np.random.default_rng([cfg.seed, 0x5EC1A13])
        self._reclaim_scheduled: set = set()   # chip uuids with a draw
        self.preempt: Dict[str, int] = {
            "reclaims": 0, "drained_batches": 0, "killed_batches": 0,
            "requeued_requests": 0, "dropped_in_flight": 0}
        # ---- fault injection + resilience (core/faults.py) ----
        # all inert (and cost-free on the hot path) unless armed: the
        # injector draws from its own dedicated streams and the
        # resilience machinery only changes gated code paths, so
        # fault-free runs stay bitwise identical to legacy traces
        fm = cfg.faults
        horizon = cfg.duration_s + cfg.drop_after_s
        self._injector = (FaultInjector(fm, cfg.seed, horizon)
                          if fm is not None and fm.is_active else None)
        res = cfg.resilience
        self._res = res if res is not None and res.is_active else None
        self._health = (HealthTracker(res)
                        if self._res is not None and res.quarantine_active
                        else None)
        self._admit = self._res is not None and res.admission_active
        self._admit_wait = (res.deadline_s * res.admission_headroom
                            if self._admit else 0.0)
        self._slow: Dict[str, tuple] = {}   # pod_id -> (until, factor)
        self.fault_counts: Dict[str, int] = {
            "chip_failures": 0, "stragglers": 0, "cache_losses": 0,
            "blackouts": 0, "quarantines": 0}
        if self._injector is not None:
            self.fault_counts["blackouts"] = len(self._injector.blackouts)
        self.retries = 0                    # requeues granted by the policy
        # open capacity outages [fn_id, t_open, target ready-pod count]
        # opened by chip failures, closed when the replacement capacity
        # is READY again (checked at autoscale ticks); downtime is
        # integrated between events exactly like cost/fragmentation
        self._outages: List[list] = []
        self._down_rate = 0.0
        self.downtime = 0.0
        self.mttr_samples: List[float] = []

    @property
    def fault_layer_active(self) -> bool:
        """Whether this run carries an armed fault model or resilience
        config — the gate for the fault fields in ``RunMetrics``."""
        return self._injector is not None or self._res is not None

    def availability(self) -> float:
        """1 minus the fraction of the integrated horizon during which
        at least one function had a capacity outage open (a chip
        hard-failure not yet made whole by READY replacement pods)."""
        horizon = getattr(self, "_integrated_to", 0.0)
        if horizon <= 0:
            return 1.0
        return max(0.0, 1.0 - self.downtime / horizon)

    # ---- event queue -------------------------------------------------------
    def _push(self, t: float, kind: int, st) -> None:
        # payload is the FunctionState for function events, the chip
        # uuid (str) for reclaim events; seq keeps tuples comparable
        heapq.heappush(self._heap, (t, kind, next(self._seq), st))

    # ---- helpers -----------------------------------------------------------
    def _thpt(self, st: FunctionState, pod) -> float:
        """Dispatch-ordering throughput of one pod on its host device,
        memoized per (fn, batch, sm, quota, device type)."""
        t = pod.gpu_type
        key = (st.fid, pod.batch, pod.sm, pod.quota,
               t.name if t is not None else None)
        v = self._thpt_cache.get(key)
        if v is None:
            v = self._ord_table.throughput(st.spec, pod.batch, pod.sm,
                                           pod.quota, gpu=t)
            self._thpt_cache[key] = v
        return v

    def _service(self, st: FunctionState, batch: int, pod) -> tuple:
        """One batch's service time as ``(predicted, drawn)``: the
        deterministic wall-clock from the shared lattice table (on the
        pod's host device type), and that times a fresh lognormal noise
        draw. The predicted half is the health tracker's baseline."""
        det = self._svc_table.lat(st.spec, batch, pod.sm, pod.quota,
                                  pod.gpu_type)
        return det, det * float(self.rng.lognormal(
            mean=0.0, sigma=perf_model.SERVICE_NOISE_SIGMA))

    def _refresh_pods(self, st: FunctionState) -> None:
        """Re-read the function's pod set after its policy may have
        mutated the cluster; flush runtimes of removed (or parked
        keep-warm standby) pods — standby pods hold weights, not
        serving capacity, so dispatch never sees them."""
        pods = [p for p in self.recon.pods_of(st.fid) if not p.standby]
        alive = {p.pod_id for p in pods}
        for pid in list(st.runtimes):
            if pid not in alive:
                rt = st.runtimes.pop(pid)
                for r in rt.inflight:  # inflight on a removed pod completes
                    r.completion = rt.busy_until
                st.completed.extend(rt.inflight)
        st.pod_order = sorted(pods, key=lambda p: -self._thpt(st, p))
        st.maybe_idle = True
        if self._admit:
            # admission control's drain-capacity estimate: every pod
            # that will take work (cold-starting pods count — they are
            # capacity within the deadline horizon; doomed/quarantined
            # ones never take new batches)
            st.est_capacity = sum(self._thpt(st, p) for p in st.pod_order
                                  if not p.doomed and not p.quarantined)

    def _shed(self, t: float, st: FunctionState) -> None:
        q = st.queue
        drop_after = self.cfg.drop_after_s
        if self._res is not None and self._res.deadline_s > 0:
            # a queued request past its deadline is already dead to the
            # caller — age it out now instead of at drop_after_s
            drop_after = min(drop_after, self._res.deadline_s)
        kinds = st.drop_kinds
        while q and t - q[0].arrival > drop_after:
            q.popleft()
            st.dropped += 1
            kinds["aged"] += 1

    def _any_work_left(self, now: float) -> bool:
        return any(st.work_left(now) for st in self.fns.values())

    def _count_actions(self, t: float, st: FunctionState,
                       before: Dict[str, float]) -> None:
        """Diff the pod set across one policy tick into per-kind scaling
        counts and cold starts (works for any policy, including ones
        whose tick() returns nothing)."""
        ac = st.action_counts
        after = {p.pod_id: p for p in st.pod_order}
        for pid, quota in before.items():
            pod = after.get(pid)
            if pod is None:
                ac["hdown"] += 1
            elif pod.quota > quota + 1e-12:
                ac["vup"] += 1
            elif pod.quota < quota - 1e-12:
                ac["vdown"] += 1
        for pid, pod in after.items():
            if pid not in before:
                ac["hup"] += 1
                if pod.ready_at > t:
                    # lifecycle-classified starts count under their kind;
                    # without a tracker every late-ready pod is "cold"
                    kind = pod.start_kind or "cold"
                    st.start_counts[kind] = st.start_counts.get(kind, 0) + 1
                    if kind == "cold":
                        st.cold_starts += 1
                elif pod.start_kind == "hot":
                    # keep-warm reactivation: instant capacity, no wait
                    st.start_counts["hot"] += 1

    # ---- event handlers ----------------------------------------------------
    def _on_arrival(self, t: float, st: FunctionState) -> None:
        arr = st._arr
        i, n = st.next_arrival, len(arr)
        q = st.queue
        fid = st.fid
        if self._admit:
            # SLO-aware brownout: reject an arrival outright when the
            # backlog already needs more than the deadline headroom to
            # drain at current capacity — an explicit fast failure
            # instead of burning the request's latency budget in queue
            max_q = st.est_capacity * self._admit_wait
            kinds = st.drop_kinds
            while i < n and arr[i] <= t:
                if q and len(q) >= max_q:
                    st.dropped += 1
                    kinds["shed"] += 1
                else:
                    q.append(Request(fid, arr[i]))
                i += 1
        else:
            while i < n and arr[i] <= t:
                q.append(Request(fid, arr[i]))
                i += 1
        st.next_arrival = i
        if i < n:
            self._push(arr[i], ARRIVAL, st)
        # if the last scan proved every pod busy (or cold-starting), the
        # new request cannot be dispatched before the next pod-free /
        # pod-ready / autoscale event re-scans — skip the pod loop
        if st.maybe_idle:
            self._dispatch(t, st)

    def _on_autoscale(self, t: float, st: FunctionState) -> None:
        cfg = self.cfg
        if self._injector is not None and self._injector.in_blackout(t):
            # control-plane blackout: the timer fires but the policy is
            # unreachable — no scaling decision, no replacement capacity,
            # no outage-recovery bookkeeping. Aging and dispatch keep
            # running (the data plane is fine), and the timer chain
            # stays alive so the tick after the window acts normally.
            self._shed(t, st)
            nxt = t + cfg.autoscale_interval_s
            if nxt <= cfg.duration_s or self._any_work_left(t):
                self._push(nxt, AUTOSCALE, st)
            self._dispatch(t, st)
            return
        self._shed(t, st)
        # both the arrival term and the backlog-drain term divide by
        # the elapsed-horizon-clamped window (PR 10 fix: the backlog
        # term used to divide by the full OBS_WINDOW_S even when
        # t < OBS_WINDOW_S, undercounting backlog demand early on)
        win = max(min(t, OBS_WINDOW_S), 1e-9) if t > 0 else OBS_WINDOW_S
        observed = st.observed_in_window(t) / win if t > 0 else 0.0
        observed += len(st.queue) / win  # backlog drain demand
        # snapshot quota VALUES before the policy mutates pods in place;
        # between autoscale events the pod set is immutable, so the
        # cached pod_order is the authoritative before-state
        before = {p.pod_id: p.quota for p in st.pod_order}
        st.policy.tick(t, st.spec, observed)
        self._refresh_pods(st)
        self._count_actions(t, st, before)
        self._cost_rates = self.cost.rates(self.recon)
        self._frag_rate = self.recon.fragmentation()
        st.timeline.append(
            (t, observed, len(st.pod_order),
             sum((p.sm / (p.gpu_type.sm_total if p.gpu_type else 8.0))
                 * p.quota for p in st.pod_order)))
        if self.track_peak:
            self.peak_gpus = max(self.peak_gpus,
                                 len(self.recon.used_gpus()))
        nxt = t + cfg.autoscale_interval_s
        if nxt <= cfg.duration_s or self._any_work_left(t):
            self._push(nxt, AUTOSCALE, st)
        self._schedule_reclaims(t)
        self._schedule_faults(t)
        if self._outages:
            self._close_recovered_outages(t)
        self._dispatch(t, st)

    # ---- spot reclaims -----------------------------------------------------
    def _schedule_reclaims(self, t: float) -> None:
        """Draw a reclaim-notice time for every live spot chip that has
        none yet (fresh chips appear at autoscale events, so this runs
        at seed time and after each policy tick). Draws come from the
        dedicated reclaim rng in chip-creation order — deterministic
        for a given seed and decision history."""
        if not self._has_spot:
            return
        horizon = self.cfg.duration_s + self.cfg.drop_after_s
        for g in self.recon.gpus.values():
            m = g.gpu_type.market
            if (m is None or m.reclaim_rate_per_hour <= 0
                    or g.uuid in self._reclaim_scheduled):
                continue
            self._reclaim_scheduled.add(g.uuid)
            tr = m.sample_reclaim(t, self._reclaim_rng)
            if tr <= horizon:
                self._push(tr, RECLAIM_NOTICE, g.uuid)

    def _on_reclaim_notice(self, t: float, uuid: str) -> None:
        """Open the grace window on chip ``uuid``: mark its pods doomed
        (capacity drops to zero, so the next autoscale tick starts
        replacing them), count batches that will finish inside the
        window as drained, and schedule the kill. A chip the policy
        already released is ignored."""
        g = self.recon.gpus.get(uuid)
        if g is None or g.doomed:
            return
        kill_at = t + g.gpu_type.market.grace_period_s
        self.recon.mark_doomed(uuid, kill_at, now=t)
        self.preempt["reclaims"] += 1
        for pod in g.pods:
            st = self.fns.get(pod.fn_id)
            if st is None:
                continue
            rt = st.runtimes.get(pod.pod_id)
            if rt is not None and rt.inflight and t < rt.busy_until <= kill_at:
                self.preempt["drained_batches"] += 1
        self._push(kill_at, RECLAIM_KILL, uuid)

    def _on_reclaim_kill(self, t: float, uuid: str) -> None:
        """Close the grace window: deliver batches that finished in
        time, requeue (or drop) still-running ones at the queue head,
        remove every pod through the indexed path (demoting weights
        when a lifecycle tracker is attached), and drop the chip. The
        cost/fragmentation rates are re-sampled by the caller."""
        g = self.recon.gpus.get(uuid)
        if g is None:
            return
        affected: Dict[str, FunctionState] = {}
        requeue: Dict[str, List[Request]] = {}
        for pod in g.pods:
            st = self.fns.get(pod.fn_id)
            if st is None:
                continue
            affected[st.fid] = st
            rt = st.runtimes.pop(pod.pod_id, None)
            if rt is None or not rt.inflight:
                continue
            if rt.busy_until <= t:   # drained: finished, delivery was lazy
                for r in rt.inflight:
                    r.completion = rt.busy_until
                st.completed.extend(rt.inflight)
            else:                    # killed mid-batch
                self.preempt["killed_batches"] += 1
                keep = self._apply_retry_policy(t, st, rt.inflight)
                if keep:
                    requeue.setdefault(st.fid, []).extend(keep)
                    self.preempt["requeued_requests"] += len(keep)
                dead = len(rt.inflight) - len(keep)
                if dead:
                    self.preempt["dropped_in_flight"] += dead
            rt.inflight = []
        for fid, reqs in requeue.items():
            self._requeue(t, affected[fid], reqs)
        self.recon.remove_gpu(uuid, now=t)
        self._reclaim_scheduled.discard(uuid)
        for st in affected.values():
            self._refresh_pods(st)
            self._dispatch(t, st)
        self._cost_rates = self.cost.rates(self.recon)
        self._frag_rate = self.recon.fragmentation()

    # ---- fault injection + resilience (core/faults.py) ---------------------
    def _apply_retry_policy(self, t: float, st: FunctionState,
                            reqs: List[Request]) -> List[Request]:
        """Decide the fate of a killed batch's in-flight requests:
        returns the ones to requeue, accounts the rest as "killed"
        drops. Without a resilience config this is the legacy boolean
        ``reclaim_requeue`` (all or nothing); with one, each request is
        retried only while it has budget left (``max_retries``) and —
        when deadlines are armed — can still complete in time after
        ``retry_backoff_s``."""
        res = self._res
        if res is None:
            if self.cfg.reclaim_requeue:
                return list(reqs)
            st.dropped += len(reqs)
            st.drop_kinds["killed"] += len(reqs)
            return []
        keep: List[Request] = []
        dead = 0
        for r in reqs:
            if (r.retries < res.max_retries
                    and (res.deadline_s <= 0
                         or t + res.retry_backoff_s
                         <= r.arrival + res.deadline_s)):
                r.retries += 1
                self.retries += 1
                keep.append(r)
            else:
                dead += 1
        if dead:
            st.dropped += dead
            st.drop_kinds["killed"] += dead
        return keep

    def _requeue(self, t: float, st: FunctionState,
                 reqs: List[Request]) -> None:
        """Requeue retried requests at the queue head in arrival order
        (they are older than anything still queued — FIFO and ``_shed``
        rely on it), after ``retry_backoff_s`` when armed."""
        res = self._res
        if res is not None and res.retry_backoff_s > 0:
            self._push(t + res.retry_backoff_s, RETRY, (st.fid, reqs))
            return
        for r in sorted(reqs, key=lambda r: r.arrival, reverse=True):
            r.start = None
            st.queue.appendleft(r)

    def _on_retry(self, t: float, payload) -> None:
        """A backoff window closed: the retried requests rejoin their
        function's queue head and dispatch re-scans."""
        fid, reqs = payload
        st = self.fns.get(fid)
        if st is None:
            return
        for r in sorted(reqs, key=lambda r: r.arrival, reverse=True):
            r.start = None
            st.queue.appendleft(r)
        self._dispatch(t, st)

    def _schedule_faults(self, t: float) -> None:
        """Draw fault times for every live chip / pod / node that has
        none yet (fresh entities appear at autoscale events, so this
        runs at seed time and after each policy tick — mirroring
        ``_schedule_reclaims``). Each process draws from its own
        dedicated stream in entity-creation order: deterministic for a
        given seed and decision history."""
        inj = self._injector
        if inj is None:
            return
        m = inj.model
        horizon = inj.horizon_s
        if m.chip_failure_rate_per_hour > 0:
            for g in self.recon.gpus.values():
                if g.uuid in inj.chip_drawn:
                    continue
                inj.chip_drawn.add(g.uuid)
                tf = inj.draw_chip_failure(t)
                if tf <= horizon:
                    self._push(tf, CHIP_FAIL, g.uuid)
        if m.straggler_rate_per_hour > 0:
            for g in self.recon.gpus.values():
                for p in g.pods:
                    if p.pod_id in inj.pod_drawn:
                        continue
                    inj.pod_drawn.add(p.pod_id)
                    ts = inj.draw_straggler(t)
                    if ts <= horizon:
                        self._push(ts, POD_FAULT, ("straggler", p.pod_id))
        if m.cache_loss_rate_per_hour > 0:
            for g in self.recon.gpus.values():
                if g.node in inj.node_drawn:
                    continue
                inj.node_drawn.add(g.node)
                tc = inj.draw_cache_loss(t)
                if tc <= horizon:
                    self._push(tc, POD_FAULT, ("cache_loss", g.node))

    def _on_chip_fail(self, t: float, uuid: str) -> None:
        """Chip hard-failure: instant kill, no grace window. Finished
        batches deliver (their completion predates the failure);
        running batches go through the retry policy; the chip leaves
        through the same ``remove_gpu`` path a reclaim kill uses; and a
        capacity outage opens per affected function, closed when its
        READY pod count recovers (MTTR / availability accounting)."""
        g = self.recon.gpus.get(uuid)
        if g is None:
            return   # already scaled away or reclaimed
        self.fault_counts["chip_failures"] += 1
        affected: Dict[str, FunctionState] = {}
        requeue: Dict[str, List[Request]] = {}
        for pod in g.pods:
            st = self.fns.get(pod.fn_id)
            if st is None:
                continue
            affected[st.fid] = st
            rt = st.runtimes.pop(pod.pod_id, None)
            if rt is None or not rt.inflight:
                continue
            if rt.busy_until <= t:   # finished before the failure
                for r in rt.inflight:
                    r.completion = rt.busy_until
                st.completed.extend(rt.inflight)
            else:                    # killed mid-batch, instantly
                keep = self._apply_retry_policy(t, st, rt.inflight)
                if keep:
                    requeue.setdefault(st.fid, []).extend(keep)
            rt.inflight = []
        for st in affected.values():
            # outage target: the pre-failure READY capacity headcount
            target = sum(1 for p in st.pod_order
                         if not p.doomed and not p.quarantined)
            if any(p.fn_id == st.fid and not p.standby for p in g.pods):
                self._outages.append([st.fid, t, target])
        self.recon.remove_gpu(uuid, now=t)
        self._reclaim_scheduled.discard(uuid)
        for fid, reqs in requeue.items():
            self._requeue(t, affected[fid], reqs)
        for st in affected.values():
            self._refresh_pods(st)
            self._dispatch(t, st)
        self._down_rate = 1.0 if self._outages else 0.0
        self._cost_rates = self.cost.rates(self.recon)
        self._frag_rate = self.recon.fragmentation()

    def _close_recovered_outages(self, t: float) -> None:
        """Close every outage whose function has its READY (non-doomed,
        non-quarantined) pod count back at the pre-failure target;
        record each repair time for MTTR."""
        still = []
        for o in self._outages:
            fid, t0, target = o
            st = self.fns.get(fid)
            ready = (sum(1 for p in st.pod_order
                         if p.ready_at <= t and not p.doomed
                         and not p.quarantined)
                     if st is not None else target)
            if ready >= target:
                self.mttr_samples.append(t - t0)
            else:
                still.append(o)
        self._outages = still
        self._down_rate = 1.0 if still else 0.0

    def _on_pod_fault(self, t: float, payload) -> None:
        """A pod-scoped fault lands: open a straggler window (service
        times inflate until it closes) or drop a node's host weight
        cache. Each entity redraws its next fault after the current one
        — a proper per-entity Poisson process — until it disappears."""
        kind, target = payload
        inj = self._injector
        m = inj.model
        if kind == "straggler":
            if self.recon.pod(target) is None:
                return   # pod scaled away; its process dies with it
            self.fault_counts["stragglers"] += 1
            until = t + m.straggler_duration_s
            self._slow[target] = (until, m.straggler_factor)
            nxt = inj.draw_straggler(until)
        else:   # cache_loss
            self.fault_counts["cache_losses"] += 1
            tracker = getattr(self.recon, "modelstate", None)
            if tracker is not None:
                tracker.drop_node_cache(target, now=t)
            nxt = inj.draw_cache_loss(t)
        if nxt <= inj.horizon_s:
            self._push(nxt, POD_FAULT, payload)

    def _quarantine(self, t: float, st: FunctionState, pod) -> None:
        """Health trip: pull the pod out of dispatch exactly like a
        doomed chip (zero capacity, no new batches — the in-flight
        batch finishes), schedule the lift, and reset its score so it
        returns with a clean slate."""
        if pod.quarantined or pod.doomed:
            return
        self.fault_counts["quarantines"] += 1
        self.recon.set_quarantined(pod.pod_id, True)
        self._health.reset(pod.pod_id)
        self._push(t + self._res.quarantine_duration_s, QUAR_LIFT,
                   (st.fid, pod.pod_id))

    def _on_quarantine_lift(self, t: float, payload) -> None:
        """A quarantine window closed: the pod (if still alive) rejoins
        dispatch and the capacity model counts it again."""
        fid, pod_id = payload
        pod = self.recon.pod(pod_id)
        if pod is not None and pod.quarantined:
            self.recon.set_quarantined(pod_id, False)
        st = self.fns.get(fid)
        if st is not None:
            self._refresh_pods(st)
            self._dispatch(t, st)

    def _dispatch(self, t: float, st: FunctionState) -> None:
        """Idle ready pods pull batches, highest-throughput first.

        Completion delivery is lazy: a finished batch's completion times
        were fixed when it started (``busy_until``), so handing it to
        ``completed`` can wait until its pod next pulls (or the final
        flush) without observable difference.
        """
        cfg = self.cfg
        self._shed(t, st)
        q = st.queue
        runtimes = st.runtimes
        any_idle = False
        for pod in st.pod_order:
            rt = runtimes.get(pod.pod_id)
            if rt is None:
                rt = runtimes[pod.pod_id] = PodRuntime(pod.pod_id)
            if rt.busy_until > t:
                continue
            if rt.inflight:
                for r in rt.inflight:
                    r.completion = rt.busy_until
                st.completed.extend(rt.inflight)
                rt.inflight = []
            if pod.doomed or pod.quarantined:
                continue   # draining (reclaim kill) or health-benched
            if not q:
                any_idle = True  # free pod waiting for work
                break
            if pod.ready_at > t:  # cold-starting; wake when ready
                if not rt.wake_scheduled:
                    rt.wake_scheduled = True
                    self._push(pod.ready_at, DISPATCH, st)
                continue
            if len(q) < pod.batch:
                # compare against the absolute deadline (the same float
                # the wakeup is scheduled at) so the timeout event is
                # never judged "not yet due" by rounding
                tmo = q[0].arrival + cfg.batch_wait_s
                if tmo - t > 1e-9:
                    if tmo > st.timeout_at:  # head timeouts are monotone
                        st.timeout_at = tmo
                        self._push(tmo, DISPATCH, st)
                    any_idle = True  # idle, waiting to fill its batch
                    continue
            take = min(pod.batch, len(q))
            batch = [q.popleft() for _ in range(take)]
            det, service = self._service(st, take, pod)
            if self._injector is not None:
                slow = self._slow.get(pod.pod_id)
                if slow is not None and t < slow[0]:
                    service *= slow[1]   # inside a straggler window
            if self._health is not None and det > 0:
                # health sample: the full observed/predicted ratio
                # (noise AND straggler inflation); the batch that tripped
                # the score still runs — quarantine bars the NEXT pull
                if self._health.observe(pod.pod_id, service / det):
                    self._quarantine(t, st, pod)
            for r in batch:
                r.start = t
            rt.busy_until = t + service
            rt.inflight = batch
            self._push(rt.busy_until, DISPATCH, st)
        st.maybe_idle = any_idle

    # ---- main loop ---------------------------------------------------------
    def run(self) -> None:
        """Drain the event heap to completion: seeds first arrivals and
        autoscale timers, then processes events in (time, kind, seq)
        order while integrating cost and fragmentation exactly between
        events. Arrivals later than ``duration_s + drop_after_s`` are
        shed. After return, every ``FunctionState`` holds its completed
        requests and the cost meter its integrated totals."""
        cfg = self.cfg
        cutoff = cfg.duration_s + cfg.drop_after_s
        for st in self.fns.values():
            self._refresh_pods(st)
            if st._arr:
                self._push(st._arr[0], ARRIVAL, st)
            self._push(0.0, AUTOSCALE, st)
        self._schedule_reclaims(0.0)   # chips provisioned at prewarm
        self._schedule_faults(0.0)
        self._cost_rates = self.cost.rates(self.recon)
        self._frag_rate = self.recon.fragmentation()
        usd_rate, gsec_rate = self._cost_rates
        frag_rate = self._frag_rate
        down_rate = self._down_rate
        usd = gsec = frag = down = 0.0
        last_t = 0.0
        heap = self._heap
        pop = heapq.heappop
        while heap:
            t, kind, _, st = pop(heap)
            self.n_events += 1
            if t > cutoff:
                # anything still queued has, by construction, aged out
                usd += usd_rate * (cutoff - last_t)
                gsec += gsec_rate * (cutoff - last_t)
                frag += frag_rate * (cutoff - last_t)
                down += down_rate * (cutoff - last_t)
                last_t = cutoff
                break
            if t > last_t:
                usd += usd_rate * (t - last_t)
                gsec += gsec_rate * (t - last_t)
                frag += frag_rate * (t - last_t)
                down += down_rate * (t - last_t)
                last_t = t
            self.now = t
            if kind == ARRIVAL:
                self._on_arrival(t, st)
            elif kind == AUTOSCALE:
                self._on_autoscale(t, st)
                usd_rate, gsec_rate = self._cost_rates
                frag_rate = self._frag_rate
                down_rate = self._down_rate
            elif kind == RECLAIM_NOTICE:   # payload is the chip uuid
                self._on_reclaim_notice(t, st)
            elif kind == RECLAIM_KILL:     # chip leaves: rates change
                self._on_reclaim_kill(t, st)
                usd_rate, gsec_rate = self._cost_rates
                frag_rate = self._frag_rate
            elif kind == CHIP_FAIL:        # payload is the chip uuid
                self._on_chip_fail(t, st)
                usd_rate, gsec_rate = self._cost_rates
                frag_rate = self._frag_rate
                down_rate = self._down_rate
            elif kind == POD_FAULT:        # payload is (kind, target)
                self._on_pod_fault(t, st)
            elif kind == RETRY:            # payload is (fn_id, requests)
                self._on_retry(t, st)
            elif kind == QUAR_LIFT:        # payload is (fn_id, pod_id)
                self._on_quarantine_lift(t, st)
            else:
                self._dispatch(t, st)
        if last_t < cfg.duration_s:  # idle pods accrue cost to end of run
            usd += usd_rate * (cfg.duration_s - last_t)
            gsec += gsec_rate * (cfg.duration_s - last_t)
            frag += frag_rate * (cfg.duration_s - last_t)
            down += down_rate * (cfg.duration_s - last_t)
        self.cost.total_usd += usd
        self.cost.gpu_seconds += gsec
        self.frag_integral += frag
        self.downtime += down
        self._integrated_to = max(last_t, cfg.duration_s)
        self._flush()

    def fragmentation_avg(self) -> float:
        """Time-averaged fraction of slice capacity on used chips left
        unallocated over the integrated horizon — the spatial-waste
        metric mixed-fleet bin-packing (FleetPlacer) minimizes."""
        horizon = getattr(self, "_integrated_to", 0.0)
        return self.frag_integral / horizon if horizon > 0 else 0.0

    def _flush(self) -> None:
        for st in self.fns.values():
            for rt in st.runtimes.values():
                for r in rt.inflight:
                    r.completion = rt.busy_until
                    st.completed.append(r)
                rt.inflight = []
            st.dropped += len(st.queue)
            st.drop_kinds["aged"] += len(st.queue)
            st.queue.clear()
            # arrivals never injected (cutoff break) are dropped too
            leftover = len(st._arr) - st.next_arrival
            st.dropped += leftover
            st.drop_kinds["aged"] += leftover
            st.next_arrival = len(st._arr)
        # outages still open at the end of the horizon close there
        horizon = getattr(self, "_integrated_to", 0.0)
        for _, t0, _ in self._outages:
            self.mttr_samples.append(max(0.0, horizon - t0))
        self._outages = []
