"""Multi-function co-located simulation (paper §4: the MLPerf-derived
function benchmark runs simultaneously on one 10-GPU cluster).

Steps N per-function simulators over a shared clock, a shared
Reconfigurator (so functions compete for chips and pack under SM
alignment / HGO placement), and a single cluster-level cost meter.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.cost import CostMeter
from repro.core.perf_model import FnSpec
from repro.core.reconfigurator import Reconfigurator
from repro.core.simulator import ClusterSimulator, SimConfig, SimResult
from repro.core.slo import Request, percentiles


@dataclasses.dataclass
class MultiSimResult:
    per_fn: Dict[str, SimResult]
    cluster_cost_usd: float
    cluster_cost_per_1k: float
    peak_gpus: int


class MultiFunctionSimulator:
    """Co-simulates several functions against one cluster."""

    def __init__(self, specs: List[FnSpec], policies, recon: Reconfigurator,
                 arrivals: Dict[str, np.ndarray],
                 cfg: SimConfig = SimConfig()):
        self.cfg = cfg
        self.recon = recon
        self.cost = CostMeter(whole_gpu=cfg.whole_gpu_cost)
        self.sims = {}
        for spec in specs:
            sub = ClusterSimulator(spec, policies[spec.fn_id], recon,
                                   arrivals[spec.fn_id], cfg)
            sub.cost = CostMeter(whole_gpu=cfg.whole_gpu_cost)  # unused
            self.sims[spec.fn_id] = sub
        self.peak_gpus = 0

    def run(self) -> MultiSimResult:
        cfg = self.cfg
        t = 0.0
        idx = {f: 0 for f in self.sims}
        last_scale = {f: -1e9 for f in self.sims}
        window = {f: [] for f in self.sims}
        while t < cfg.duration_s + cfg.drop_after_s:
            alive = t < cfg.duration_s or any(
                idx[f] < len(s.arrivals) or s._work_left()
                for f, s in self.sims.items())
            if not alive:
                break
            for fid, sim in self.sims.items():
                n = len(sim.arrivals)
                while idx[fid] < n and sim.arrivals[idx[fid]] <= t:
                    req = Request(fid, float(sim.arrivals[idx[fid]]))
                    window[fid].append(req.arrival)
                    sim.queue.append(req)
                    idx[fid] += 1
                while sim.queue and t - sim.queue[0].arrival > cfg.drop_after_s:
                    sim.queue.popleft()
                    sim.dropped += 1
                if t - last_scale[fid] >= cfg.autoscale_interval_s:
                    window[fid] = [a for a in window[fid] if a >= t - 5.0]
                    obs = len(window[fid]) / max(min(t, 5.0), 1e-9) \
                        if t > 0 else 0.0
                    obs += len(sim.queue) / 5.0
                    sim.policy.tick(t, sim.spec, obs)
                    last_scale[fid] = t
                sim._execute(t)
            self.cost.accrue(self.recon, cfg.tick_s)
            self.peak_gpus = max(self.peak_gpus, len(self.recon.used_gpus()))
            t += cfg.tick_s

        per_fn = {}
        total_completed = 0
        for fid, sim in self.sims.items():
            for rt in sim.runtimes.values():
                for r in rt.inflight:
                    r.completion = rt.busy_until
                    sim.completed.append(r)
                rt.inflight = []
            sim.dropped += len(sim.queue)
            sim.queue.clear()
            lats = np.array([r.latency for r in sim.completed
                             if r.latency is not None])
            from repro.core import perf_model
            base = perf_model.slo_baseline(sim.spec, 8)
            per_fn[fid] = SimResult(
                latencies=lats, n_arrived=len(sim.arrivals),
                n_completed=len(lats), n_dropped=sim.dropped,
                cost_usd=0.0, cost_per_1k=0.0, baseline_s=base,
                pcts=percentiles(lats), pod_seconds=0.0, timeline=[])
            total_completed += len(lats)
        return MultiSimResult(
            per_fn=per_fn, cluster_cost_usd=self.cost.total_usd,
            cluster_cost_per_1k=(self.cost.total_usd / total_completed * 1e3
                                 if total_completed else float("inf")),
            peak_gpus=self.peak_gpus)
