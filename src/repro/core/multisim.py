"""Multi-function co-located simulation (paper §4: the MLPerf-derived
function benchmark runs simultaneously on one 10-GPU cluster).

Runs N functions through the shared discrete-event engine
(``core/events.py``) against one Reconfigurator — so functions compete
for chips and pack under SM alignment / HGO placement — with a single
cluster-level cost meter integrated between events.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.cost import CostMeter
from repro.core.events import EventEngine, FunctionState, SimConfig
from repro.core.metrics import baseline_batch_of
from repro.core.perf_model import FnSpec
from repro.core.reconfigurator import Reconfigurator
from repro.core.simulator import SimResult, result_from_state


@dataclasses.dataclass
class MultiSimResult:
    per_fn: Dict[str, SimResult]
    cluster_cost_usd: float
    cluster_cost_per_1k: float
    peak_gpus: int


class MultiFunctionSimulator:
    """Co-simulates several functions against one cluster."""

    def __init__(self, specs: List[FnSpec], policies, recon: Reconfigurator,
                 arrivals: Dict[str, np.ndarray],
                 cfg: SimConfig = SimConfig(), engine_cls=EventEngine):
        self.cfg = cfg
        self.recon = recon
        self.cost = CostMeter(whole_gpu=cfg.whole_gpu_cost)
        self.states = [FunctionState(spec, policies[spec.fn_id],
                                     arrivals[spec.fn_id])
                       for spec in specs]
        self.engine = engine_cls(recon, cfg, self.states, cost=self.cost,
                                 rng=np.random.default_rng(cfg.seed),
                                 track_peak=True)

    @property
    def peak_gpus(self) -> int:
        return self.engine.peak_gpus

    def run(self) -> MultiSimResult:
        self.engine.run()
        per_fn = {}
        total_completed = 0
        zero_cost = CostMeter()  # per-fn cost is cluster-level, not split
        for st in self.states:
            per_fn[st.fn_id] = result_from_state(
                st, zero_cost, baseline_batch_of(st.policy))
            total_completed += per_fn[st.fn_id].n_completed
        return MultiSimResult(
            per_fn=per_fn, cluster_cost_usd=self.cost.total_usd,
            cluster_cost_per_1k=(self.cost.total_usd / total_completed * 1e3
                                 if total_completed else float("inf")),
            peak_gpus=self.engine.peak_gpus)
