"""GPU Re-configurator: direct cluster accelerator management.

The paper's component bypasses the k8s device plugin and manages GPUs by
UUID via NVML so the auto-scaler can target specific chips and rewrite
pods' resource device-files at runtime. Here it owns the authoritative
map uuid -> VirtualGPU, performs placements/removals/quota rewrites, and
exposes the occupancy views (HGO) the auto-scaler reads.

Cluster-state reads are indexed for the control plane's hot path:

  * pod -> GPU and pod -> PodAlloc maps make `gpu_of_pod` (and thus
    `set_quota` / `remove_pod`) O(1) instead of a scan over every pod
    of every GPU;
  * a fn -> {gpu: pod count} index lets `pods_of` touch only the GPUs
    actually hosting that function — while still returning pods in the
    exact order the original full scan produced (GPUs in creation
    order, pods in partition order), because policies tie-break sorts
    on that order and the golden traces pin it;
  * per-function capacity is maintained incrementally: a policy
    registers a throughput model (pod -> RPS) once per function and
    every place/remove/set_quota updates that pod's cached
    contribution, so `fn_capacity` costs one short ordered sum with
    ZERO predictor calls per autoscale event. (The sum itself is
    re-folded in pod order rather than kept as a running float so the
    result is bitwise identical to the naive re-summation.)
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, List, Optional

from repro.core.vgpu import PodAlloc, VirtualGPU


class Reconfigurator:
    def __init__(self, num_gpus: int = 0, gpus_per_node: int = 1,
                 window_ms: float = 100.0, max_gpus: Optional[int] = None):
        self.gpus: Dict[str, VirtualGPU] = {}
        self.window_ms = window_ms
        self.gpus_per_node = gpus_per_node
        self.max_gpus = max_gpus
        # per-instance counter: GPU uuids are a function of this
        # cluster's own history, not of how many Reconfigurators the
        # process created before it (a module-level count made runs
        # irreproducible within one process)
        self._gpu_counter = itertools.count()
        # ---- hot-path indexes ----
        self._pods: Dict[str, PodAlloc] = {}          # pod_id -> pod
        self._pod_gpu: Dict[str, str] = {}            # pod_id -> gpu uuid
        self._fn_gpus: Dict[str, Dict[str, int]] = {}  # fn -> {uuid: #pods}
        self._capacity_models: Dict[str, Callable[[PodAlloc], float]] = {}
        self._contrib: Dict[str, float] = {}          # pod_id -> thpt
        for _ in range(num_gpus):
            self.add_gpu()

    # ---- topology ----------------------------------------------------------
    def add_gpu(self) -> VirtualGPU:
        if self.max_gpus is not None and len(self.gpus) >= self.max_gpus:
            raise RuntimeError("cluster at max GPU capacity")
        i = next(self._gpu_counter)
        uuid = f"GPU-{i:04d}"
        node = f"node-{i // self.gpus_per_node}"
        g = VirtualGPU(uuid, node=node, window_ms=self.window_ms, index=i)
        g.owner = self   # direct GPU-level mutations keep indexes fresh
        self.gpus[uuid] = g
        return g

    def release_empty_gpus(self, keep: int = 0) -> List[str]:
        """Return (and drop) GPUs with no pods (paper L25-26)."""
        empty = [u for u, g in self.gpus.items() if not g.pods]
        released = []
        for u in empty:
            if len(self.gpus) <= keep:
                break
            self.gpus[u].owner = None
            del self.gpus[u]
            released.append(u)
        return released

    # ---- views -------------------------------------------------------------
    def used_gpus(self) -> List[VirtualGPU]:
        return [g for g in self.gpus.values() if g.pods]

    def pods_of(self, fn_id: str) -> List[PodAlloc]:
        gmap = self._fn_gpus.get(fn_id)
        if not gmap:
            return []
        out: List[PodAlloc] = []
        for u in sorted(gmap, key=lambda u: self.gpus[u].index):
            out.extend(p for p in self.gpus[u].pods if p.fn_id == fn_id)
        return out

    def gpu_of_pod(self, pod_id: str) -> Optional[VirtualGPU]:
        uuid = self._pod_gpu.get(pod_id)
        return self.gpus.get(uuid) if uuid is not None else None

    def pod(self, pod_id: str) -> Optional[PodAlloc]:
        return self._pods.get(pod_id)

    def lowest_hgo_gpu(self, exclude=()) -> Optional[VirtualGPU]:
        used = [g for g in self.used_gpus() if g.uuid not in exclude]
        if not used:
            return None
        return min(used, key=lambda g: g.hgo)

    # ---- incremental per-function capacity ---------------------------------
    def register_capacity_model(self, fn_id: str,
                                model: Callable[[PodAlloc], float]) -> None:
        """Install the throughput model (pod -> RPS) whose per-pod values
        `fn_capacity` aggregates; contributions for pods already placed
        are (re)computed immediately."""
        if self._capacity_models.get(fn_id) is model:
            return
        self._capacity_models[fn_id] = model
        for p in self.pods_of(fn_id):
            self._contrib[p.pod_id] = model(p)

    def _update_contrib(self, pod: PodAlloc) -> None:
        model = self._capacity_models.get(pod.fn_id)
        if model is not None:
            self._contrib[pod.pod_id] = model(pod)

    def fn_capacity(self, fn_id: str) -> float:
        """Aggregate capacity C_f from cached per-pod contributions —
        summed in pod order, matching the naive re-summation bitwise."""
        if fn_id not in self._capacity_models:
            raise KeyError(f"no capacity model registered for {fn_id!r}")
        contrib = self._contrib
        return sum(contrib[p.pod_id] for p in self.pods_of(fn_id))

    # ---- index hooks (called by owned VirtualGPUs on any mutation) ---------
    def _index_place(self, pod: PodAlloc, g: VirtualGPU) -> None:
        self._pods[pod.pod_id] = pod
        self._pod_gpu[pod.pod_id] = g.uuid
        gmap = self._fn_gpus.setdefault(pod.fn_id, {})
        gmap[g.uuid] = gmap.get(g.uuid, 0) + 1
        self._update_contrib(pod)

    def _index_remove(self, pod: PodAlloc, g: VirtualGPU) -> None:
        self._pods.pop(pod.pod_id, None)
        self._pod_gpu.pop(pod.pod_id, None)
        self._contrib.pop(pod.pod_id, None)
        gmap = self._fn_gpus.get(pod.fn_id)
        if gmap is not None:
            n = gmap.get(g.uuid, 0) - 1
            if n > 0:
                gmap[g.uuid] = n
            else:
                gmap.pop(g.uuid, None)
            if not gmap:
                self._fn_gpus.pop(pod.fn_id, None)

    def _index_quota(self, pod: PodAlloc) -> None:
        self._update_contrib(pod)

    # ---- mutations ---------------------------------------------------------
    def place_pod(self, pod: PodAlloc, gpu_uuid: Optional[str] = None,
                  now: float = 0.0, cold_start_s: float = 0.0) -> PodAlloc:
        if gpu_uuid is None:
            g = self.add_gpu()
        else:
            g = self.gpus[gpu_uuid]
        pod.created_at = now
        pod.ready_at = now + cold_start_s
        g.place(pod)
        return pod

    def remove_pod(self, pod_id: str) -> None:
        g = self.gpu_of_pod(pod_id)
        if g is not None:
            g.remove(pod_id)

    def set_quota(self, pod_id: str, quota: float) -> None:
        g = self.gpu_of_pod(pod_id)
        if g is None:
            raise KeyError(pod_id)
        g.set_quota(pod_id, quota)

    # ---- invariants ----------------------------------------------------------
    def invariant_ok(self) -> bool:
        if not all(g.invariant_ok() for g in self.gpus.values()):
            return False
        # the indexes must agree with the authoritative GPU state
        indexed = set(self._pods)
        actual = {p.pod_id for g in self.gpus.values() for p in g.pods}
        return indexed == actual
