"""GPU Re-configurator: direct cluster accelerator management.

The paper's component bypasses the k8s device plugin and manages GPUs by
UUID via NVML so the auto-scaler can target specific chips and rewrite
pods' resource device-files at runtime. Here it owns the authoritative
map uuid -> VirtualGPU, performs placements/removals/quota rewrites, and
exposes the occupancy views (HGO) the auto-scaler reads.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional

from repro.core.vgpu import PodAlloc, VirtualGPU

_gpu_counter = itertools.count()


class Reconfigurator:
    def __init__(self, num_gpus: int = 0, gpus_per_node: int = 1,
                 window_ms: float = 100.0, max_gpus: Optional[int] = None):
        self.gpus: Dict[str, VirtualGPU] = {}
        self.window_ms = window_ms
        self.gpus_per_node = gpus_per_node
        self.max_gpus = max_gpus
        for _ in range(num_gpus):
            self.add_gpu()

    # ---- topology ----------------------------------------------------------
    def add_gpu(self) -> VirtualGPU:
        if self.max_gpus is not None and len(self.gpus) >= self.max_gpus:
            raise RuntimeError("cluster at max GPU capacity")
        i = next(_gpu_counter)
        uuid = f"GPU-{i:04d}"
        node = f"node-{i // self.gpus_per_node}"
        g = VirtualGPU(uuid, node=node, window_ms=self.window_ms)
        self.gpus[uuid] = g
        return g

    def release_empty_gpus(self, keep: int = 0) -> List[str]:
        """Return (and drop) GPUs with no pods (paper L25-26)."""
        empty = [u for u, g in self.gpus.items() if not g.pods]
        released = []
        for u in empty:
            if len(self.gpus) <= keep:
                break
            del self.gpus[u]
            released.append(u)
        return released

    # ---- views -------------------------------------------------------------
    def used_gpus(self) -> List[VirtualGPU]:
        return [g for g in self.gpus.values() if g.pods]

    def pods_of(self, fn_id: str) -> List[PodAlloc]:
        return [p for g in self.gpus.values() for p in g.pods
                if p.fn_id == fn_id]

    def gpu_of_pod(self, pod_id: str) -> Optional[VirtualGPU]:
        for g in self.gpus.values():
            if any(p.pod_id == pod_id for p in g.pods):
                return g
        return None

    def lowest_hgo_gpu(self, exclude=()) -> Optional[VirtualGPU]:
        used = [g for g in self.used_gpus() if g.uuid not in exclude]
        if not used:
            return None
        return min(used, key=lambda g: g.hgo)

    # ---- mutations ---------------------------------------------------------
    def place_pod(self, pod: PodAlloc, gpu_uuid: Optional[str] = None,
                  now: float = 0.0, cold_start_s: float = 0.0) -> PodAlloc:
        if gpu_uuid is None:
            g = self.add_gpu()
        else:
            g = self.gpus[gpu_uuid]
        pod.created_at = now
        pod.ready_at = now + cold_start_s
        g.place(pod)
        return pod

    def remove_pod(self, pod_id: str) -> None:
        g = self.gpu_of_pod(pod_id)
        if g is not None:
            g.remove(pod_id)

    def set_quota(self, pod_id: str, quota: float) -> None:
        g = self.gpu_of_pod(pod_id)
        if g is None:
            raise KeyError(pod_id)
        g.set_quota(pod_id, quota)

    # ---- invariants ----------------------------------------------------------
    def invariant_ok(self) -> bool:
        return all(g.invariant_ok() for g in self.gpus.values())
