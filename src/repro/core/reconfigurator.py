"""GPU Re-configurator: direct cluster accelerator management.

The paper's component bypasses the k8s device plugin and manages GPUs by
UUID via NVML so the auto-scaler can target specific chips and rewrite
pods' resource device-files at runtime. Here it owns the authoritative
map uuid -> VirtualGPU, performs placements/removals/quota rewrites, and
exposes the occupancy views (HGO) the auto-scaler reads.

Cluster-state reads are indexed for the control plane's hot path:

  * pod -> GPU and pod -> PodAlloc maps make `gpu_of_pod` (and thus
    `set_quota` / `remove_pod`) O(1) instead of a scan over every pod
    of every GPU;
  * a fn -> {gpu: pod count} index lets `pods_of` touch only the GPUs
    actually hosting that function — while still returning pods in the
    exact order the original full scan produced (GPUs in creation
    order, pods in partition order), because policies tie-break sorts
    on that order and the golden traces pin it;
  * per-function capacity is maintained incrementally: a policy
    registers a throughput model (pod -> RPS) once per function and
    every place/remove/set_quota updates that pod's cached
    contribution, so `fn_capacity` costs one short ordered sum with
    ZERO predictor calls per autoscale event. (The sum itself is
    re-folded in pod order rather than kept as a running float so the
    result is bitwise identical to the naive re-summation.)
Heterogeneous fleets: a Reconfigurator can be constructed with a
``fleet`` — an ordered list of ``(GPUType, max_chips)`` pairs — instead
of the homogeneous ``max_gpus`` cap. ``add_gpu`` then allocates from
the first type with remaining capacity (or a requested type), and the
placement-aware policies read ``available_gpu_types`` /
``is_heterogeneous`` / ``fragmentation`` to bin-pack across the mix.
The default fleet is a single reference-type pool of ``max_gpus``
chips, which reproduces the legacy behavior exactly.
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.configs.gpus import DEFAULT_GPU_TYPE, GPUType, get_gpu_type
from repro.core.vgpu import PodAlloc, VirtualGPU


class Reconfigurator:
    def __init__(self, num_gpus: int = 0, gpus_per_node: int = 1,
                 window_ms: float = 100.0, max_gpus: Optional[int] = None,
                 fleet: Optional[Sequence[Tuple]] = None):
        self.gpus: Dict[str, VirtualGPU] = {}
        self.window_ms = window_ms
        self.gpus_per_node = gpus_per_node
        self.max_gpus = max_gpus
        # fleet: ordered (GPUType, cap) pairs; None cap = unbounded.
        # The default single-entry reference fleet IS the legacy
        # homogeneous cluster (same uuids, same cap semantics).
        if fleet is None:
            self.fleet: Tuple[Tuple[GPUType, Optional[int]], ...] = (
                (DEFAULT_GPU_TYPE, max_gpus),)
        else:
            # merge duplicate-type pools (first-occurrence order): caps
            # sum, an unbounded pool makes the type unbounded — so
            # _cap_of / available_gpu_types / max_gpus all agree on one
            # number per type
            merged: Dict[GPUType, Optional[int]] = {}
            for t, cap in fleet:
                t = get_gpu_type(t)
                if t not in merged:
                    merged[t] = cap
                elif merged[t] is None or cap is None:
                    merged[t] = None
                else:
                    merged[t] += cap
            self.fleet = tuple(merged.items())
            caps = [c for _, c in self.fleet]
            self.max_gpus = (sum(caps) if all(c is not None for c in caps)
                             else None)
        # per-instance counter: GPU uuids are a function of this
        # cluster's own history, not of how many Reconfigurators the
        # process created before it (a module-level count made runs
        # irreproducible within one process)
        self._gpu_counter = itertools.count()
        self._type_counts: Dict[GPUType, int] = {}   # live chips per type
        # node slots are reused: a released chip returns its slot to the
        # pool, so the node's host RAM (and its weight cache, when a
        # ModelStateTracker is attached) persists across scale cycles
        self._node_counts: Dict[int, int] = {}       # node slot -> live chips
        self.modelstate = None   # optional ModelStateTracker
        # spot-reclaim notice times (appended by mark_doomed): the
        # hybrid router's reclaim-pressure signal reads the tail
        self.reclaim_log: List[float] = []
        # chip-drop listeners, the cluster-level sibling of
        # VirtualGPU.remove_listeners: fired with the chip as it leaves
        # the cluster (_drop_gpu), whatever the removal path — policy
        # release, spot reclaim kill, or chip hard-failure. The event
        # engine uses this to prune per-chip bookkeeping (uuids are
        # never reused, so a dropped chip's entries are dead weight)
        self.drop_listeners: List[Callable[[VirtualGPU], None]] = []
        # ---- hot-path indexes ----
        self._pods: Dict[str, PodAlloc] = {}          # pod_id -> pod
        self._pod_gpu: Dict[str, str] = {}            # pod_id -> gpu uuid
        self._fn_gpus: Dict[str, Dict[str, int]] = {}  # fn -> {uuid: #pods}
        self._capacity_models: Dict[str, Callable[[PodAlloc], float]] = {}
        self._contrib: Dict[str, float] = {}          # pod_id -> thpt
        # incremental |used_gpus()|: maintained by the place/remove
        # hooks so the wide engine's per-sweep peak tracking is O(1)
        # instead of an O(G) scan per function per tick
        self.n_used_gpus = 0
        for _ in range(num_gpus):
            self.add_gpu()

    # ---- model-state lifecycle ---------------------------------------------
    def attach_modelstate(self, tracker) -> None:
        """Install a ``ModelStateTracker`` (core/modelstate.py): from now
        on placements consult it for start latencies and removals demote
        weights into the pod's node host-RAM cache."""
        self.modelstate = tracker

    def _next_node_slot(self) -> int:
        """Lowest node slot with room for another chip."""
        n = 0
        while self._node_counts.get(n, 0) >= self.gpus_per_node:
            n += 1
        return n

    def peek_next_node(self) -> str:
        """Node name the next fresh chip would land on (used by the
        pre-warming policy to promote weights ahead of provisioning)."""
        return f"node-{self._next_node_slot()}"

    # ---- topology ----------------------------------------------------------
    @property
    def is_heterogeneous(self) -> bool:
        """True when the fleet declares more than one device type."""
        return len({t for t, _ in self.fleet}) > 1

    def _cap_of(self, gpu_type: GPUType) -> Optional[int]:
        for t, cap in self.fleet:
            if t == gpu_type:
                return cap
        return 0   # type not in this fleet

    def type_count(self, gpu_type: GPUType) -> int:
        """Live chips of ``gpu_type`` currently in the cluster."""
        return self._type_counts.get(gpu_type, 0)

    def available_gpu_types(self, min_sm: int = 1) -> List[GPUType]:
        """Fleet types (declaration order) that can still provision a
        fresh chip wide enough for an ``sm >= min_sm`` pod."""
        out = []
        for t, cap in self.fleet:
            if t.sm_total < min_sm or t in out:
                continue
            if cap is None or self.type_count(t) < cap:
                out.append(t)
        return out

    def add_gpu(self, gpu_type=None, min_sm: int = 1) -> VirtualGPU:
        """Provision one fresh chip.

        Args:
            gpu_type: a ``GPUType`` (or registry name) to allocate; None
                picks the first fleet type with remaining capacity that
                fits ``min_sm``.
            min_sm: minimum slice width the chip must offer (so a pod
                sized for an 8-slice device never lands on a 4-slice
                one).
        Raises: RuntimeError when the fleet is exhausted.
        """
        if gpu_type is not None:
            t = get_gpu_type(gpu_type)
            cap = self._cap_of(t)
            if cap is not None and self.type_count(t) >= cap:
                raise RuntimeError("cluster at max GPU capacity")
        else:
            avail = self.available_gpu_types(min_sm)
            if not avail:
                raise RuntimeError("cluster at max GPU capacity")
            t = avail[0]
        i = next(self._gpu_counter)
        uuid = f"GPU-{i:04d}"
        slot = self._next_node_slot()
        g = VirtualGPU(uuid, node=f"node-{slot}", window_ms=self.window_ms,
                       index=i, gpu_type=t)
        g.owner = self   # direct GPU-level mutations keep indexes fresh
        self.gpus[uuid] = g
        self._type_counts[t] = self._type_counts.get(t, 0) + 1
        self._node_counts[slot] = self._node_counts.get(slot, 0) + 1
        return g

    def release_empty_gpus(self, keep: int = 0) -> List[str]:
        """Return (and drop) GPUs with no pods (paper L25-26)."""
        if len(self.gpus) == self.n_used_gpus:
            return []   # O(1) fast path: nothing empty to scan for
        empty = [u for u, g in self.gpus.items() if not g.pods]
        released = []
        for u in empty:
            if len(self.gpus) <= keep:
                break
            self._drop_gpu(self.gpus[u])
            released.append(u)
        return released

    def _drop_gpu(self, g: VirtualGPU) -> None:
        """Unregister an (empty) chip and return its node slot."""
        g.owner = None
        self._type_counts[g.gpu_type] -= 1
        slot = int(g.node.rsplit("-", 1)[1])
        self._node_counts[slot] -= 1
        del self.gpus[g.uuid]
        for listener in self.drop_listeners:
            listener(g)

    # ---- spot reclaims -----------------------------------------------------
    def mark_doomed(self, uuid: str, kill_at: float,
                    now: Optional[float] = None) -> None:
        """Open the reclaim grace window on chip ``uuid``: stamp its
        kill time, mark every hosted pod ``doomed`` (their cached
        capacity contributions drop to whatever the registered model
        says about doomed pods — the HAS model says zero), and append
        the notice to ``reclaim_log`` for the router's pressure signal.

        Args:
            uuid: the chip under notice (must be live).
            kill_at: absolute time ``RECLAIM_KILL`` will fire.
            now: notice time for the log (defaults to ``kill_at``).
        """
        g = self.gpus[uuid]
        g.reclaim_at = kill_at
        for p in g.pods:
            p.doomed = True
            self._update_contrib(p)
        self.reclaim_log.append(kill_at if now is None else now)

    def set_quarantined(self, pod_id: str, flag: bool) -> None:
        """Flip the health-quarantine flag on ``pod_id`` and refresh its
        cached capacity contribution: a quarantined pod is written off
        by the HAS capacity model (it contributes zero), so the next
        autoscale tick replaces it — exactly the doomed-chip drain
        semantics, but reversible when the quarantine window lifts.
        No-op for unknown pods (the straggler may have been scaled
        away before its health score tripped)."""
        pod = self._pods.get(pod_id)
        if pod is not None and pod.quarantined != flag:
            pod.quarantined = flag
            self._update_contrib(pod)

    def remove_gpu(self, uuid: str, now: Optional[float] = None) -> None:
        """Forcibly remove chip ``uuid`` (spot ``RECLAIM_KILL``): every
        hosted pod is removed through the ordinary indexed path — with
        an attached lifecycle tracker their weights demote to the
        node's host cache as of ``now`` — then the chip itself leaves
        the cluster, returning its node slot. No-op for unknown uuids
        (the chip may have been scaled away inside the grace window).
        """
        g = self.gpus.get(uuid)
        if g is None:
            return
        for p in list(g.pods):
            self.remove_pod(p.pod_id, now=now)
        self._drop_gpu(g)

    # ---- views -------------------------------------------------------------
    def used_gpus(self) -> List[VirtualGPU]:
        return [g for g in self.gpus.values() if g.pods]

    def pods_of(self, fn_id: str) -> List[PodAlloc]:
        gmap = self._fn_gpus.get(fn_id)
        if not gmap:
            return []
        out: List[PodAlloc] = []
        for u in sorted(gmap, key=lambda u: self.gpus[u].index):
            out.extend(p for p in self.gpus[u].pods if p.fn_id == fn_id)
        return out

    def gpu_of_pod(self, pod_id: str) -> Optional[VirtualGPU]:
        uuid = self._pod_gpu.get(pod_id)
        return self.gpus.get(uuid) if uuid is not None else None

    def pod(self, pod_id: str) -> Optional[PodAlloc]:
        return self._pods.get(pod_id)

    def lowest_hgo_gpu(self, exclude=()) -> Optional[VirtualGPU]:
        # doomed chips are draining toward a reclaim kill: never a
        # horizontal-up target (no-op filter on reclaim-free fleets)
        used = [g for g in self.used_gpus()
                if g.uuid not in exclude and not g.doomed]
        if not used:
            return None
        return min(used, key=lambda g: g.hgo)

    def fragmentation(self) -> float:
        """Fraction of slice capacity on USED chips left unallocated —
        the spatial-waste metric mixed-fleet bin-packing minimizes
        (0.0 for an empty cluster)."""
        used = self.used_gpus()
        total = sum(g.gpu_type.sm_total for g in used)
        if not total:
            return 0.0
        free = sum(g.slices_free for g in used)
        return free / total

    # ---- incremental per-function capacity ---------------------------------
    def register_capacity_model(self, fn_id: str,
                                model: Callable[[PodAlloc], float]) -> None:
        """Install the throughput model (pod -> RPS) whose per-pod values
        `fn_capacity` aggregates; contributions for pods already placed
        are (re)computed immediately."""
        if self._capacity_models.get(fn_id) is model:
            return
        self._capacity_models[fn_id] = model
        for p in self.pods_of(fn_id):
            self._contrib[p.pod_id] = model(p)

    def _update_contrib(self, pod: PodAlloc) -> None:
        model = self._capacity_models.get(pod.fn_id)
        if model is not None:
            self._contrib[pod.pod_id] = model(pod)

    def fn_capacity(self, fn_id: str) -> float:
        """Aggregate capacity C_f from cached per-pod contributions —
        summed in pod order, matching the naive re-summation bitwise."""
        if fn_id not in self._capacity_models:
            raise KeyError(f"no capacity model registered for {fn_id!r}")
        contrib = self._contrib
        return sum(contrib[p.pod_id] for p in self.pods_of(fn_id))

    # ---- index hooks (called by owned VirtualGPUs on any mutation) ---------
    def _index_place(self, pod: PodAlloc, g: VirtualGPU) -> None:
        self._pods[pod.pod_id] = pod
        self._pod_gpu[pod.pod_id] = g.uuid
        if len(g.pods) == 1:   # hook fires after append: 0 -> 1 pods
            self.n_used_gpus += 1
        gmap = self._fn_gpus.setdefault(pod.fn_id, {})
        gmap[g.uuid] = gmap.get(g.uuid, 0) + 1
        self._update_contrib(pod)

    def _index_remove(self, pod: PodAlloc, g: VirtualGPU) -> None:
        self._pods.pop(pod.pod_id, None)
        self._pod_gpu.pop(pod.pod_id, None)
        if not g.pods:         # hook fires after removal: 1 -> 0 pods
            self.n_used_gpus -= 1
        self._contrib.pop(pod.pod_id, None)
        gmap = self._fn_gpus.get(pod.fn_id)
        if gmap is not None:
            n = gmap.get(g.uuid, 0) - 1
            if n > 0:
                gmap[g.uuid] = n
            else:
                gmap.pop(g.uuid, None)
            if not gmap:
                self._fn_gpus.pop(pod.fn_id, None)

    def _index_quota(self, pod: PodAlloc) -> None:
        self._update_contrib(pod)

    # ---- mutations ---------------------------------------------------------
    def place_pod(self, pod: PodAlloc, gpu_uuid: Optional[str] = None,
                  now: float = 0.0, cold_start_s: float = 0.0,
                  gpu_type=None, spec=None, fresh_chip: Optional[bool] = None,
                  start_overhead_s: float = 0.0) -> PodAlloc:
        """Place ``pod`` on ``gpu_uuid``, or on a fresh chip when None
        (of ``gpu_type`` if given, else the first fleet type with
        capacity wide enough for ``pod.sm``).

        With an attached ``ModelStateTracker`` and a ``spec``, the
        requested ``cold_start_s`` is re-derived from the weight
        residency tier at ``now`` (cold / host-cached / GPU-resident);
        ``fresh_chip`` forces the fresh-chip classification when the
        caller provisioned the chip itself (default: inferred from
        ``gpu_uuid is None``), and ``start_overhead_s`` carries
        policy-specific extra bring-up (runtime / device plugin).
        """
        if gpu_uuid is None:
            g = self.add_gpu(gpu_type, min_sm=pod.sm)
        else:
            g = self.gpus[gpu_uuid]
        if self.modelstate is not None and spec is not None:
            fresh = fresh_chip if fresh_chip is not None else gpu_uuid is None
            cold_start_s = self.modelstate.on_pod_placed(
                spec, pod, g, fresh, now, requested_s=cold_start_s,
                overhead_s=start_overhead_s)
        pod.created_at = now
        pod.ready_at = now + cold_start_s
        g.place(pod)
        return pod

    def remove_pod(self, pod_id: str, now: Optional[float] = None) -> None:
        """Remove ``pod_id`` from its chip; with an attached lifecycle
        tracker its weights demote to the node's host cache as of
        ``now`` (falling back to the tracker's last-seen time)."""
        g = self.gpu_of_pod(pod_id)
        if g is not None:
            if self.modelstate is not None:
                pod = self._pods.get(pod_id)
                if pod is not None:
                    self.modelstate.on_pod_removed(pod, g, now)
            g.remove(pod_id)

    def set_quota(self, pod_id: str, quota: float) -> None:
        g = self.gpu_of_pod(pod_id)
        if g is None:
            raise KeyError(pod_id)
        g.set_quota(pod_id, quota)

    # ---- invariants ----------------------------------------------------------
    def invariant_ok(self) -> bool:
        if not all(g.invariant_ok() for g in self.gpus.values()):
            return False
        # the indexes must agree with the authoritative GPU state
        indexed = set(self._pods)
        actual = {p.pod_id for g in self.gpus.values() for p in g.pods}
        if indexed != actual:
            return False
        return self.n_used_gpus == sum(1 for g in self.gpus.values() if g.pods)
