"""SLO bookkeeping: per-request latency records and violation analysis."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass(slots=True)
class Request:
    fn_id: str
    arrival: float
    start: Optional[float] = None
    completion: Optional[float] = None
    #: requeues consumed after mid-flight kills (chip failure / reclaim)
    #: under a resilience retry policy — bounded by
    #: ``ResilienceConfig.max_retries`` (core/faults.py)
    retries: int = 0

    @property
    def latency(self) -> Optional[float]:
        if self.completion is None:
            return None
        return self.completion - self.arrival


def violation_rates(latencies: np.ndarray, baseline_s: float,
                    multipliers) -> Dict[float, float]:
    """Fraction of requests with latency > m * baseline, per multiplier m
    (paper Fig 6: multipliers 1..10 step 0.25)."""
    out = {}
    n = len(latencies)
    for m in multipliers:
        if n == 0:
            out[float(m)] = 1.0
        else:
            out[float(m)] = float((latencies > m * baseline_s).mean())
    return out


def percentiles(latencies: np.ndarray) -> Dict[str, float]:
    if len(latencies) == 0:
        return {"p50": float("inf"), "p90": float("inf"),
                "p95": float("inf"), "p99": float("inf")}
    return {
        "p50": float(np.percentile(latencies, 50)),
        "p90": float(np.percentile(latencies, 90)),
        "p95": float(np.percentile(latencies, 95)),
        "p99": float(np.percentile(latencies, 99)),
    }
