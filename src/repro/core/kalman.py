"""Kalman-filter short-term request-rate predictor (paper §3.3).

Scalar filter with state R (requests/s):
    R'_t = A R_{t-1},   P'_t = A P_{t-1} A + Q
    K    = P'_t H / (H P'_t H + D)
    R    = R'_t + K (z_t - H R'_t),   P = (1 - K H) P'_t

The predictor is decoupled from the auto-scaling algorithm (paper: "the
HAS autoscaler decouples the request prediction model"), so any object
with ``update(observed) -> predicted`` plugs in.

``BatchedKalman`` is the struct-of-arrays form of the same filter: one
lane per function slot, one numpy ``update`` for the whole fleet. Each
lane's arithmetic keeps the scalar filter's exact expression order, so
per-slot results are byte-identical to running ``KalmanPredictor``
slot by slot (IEEE-754 float64 elementwise ops match Python floats).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class KalmanPredictor:
    A: float = 1.0      # state transition
    H: float = 1.0      # observation model
    Q: float = 8.0      # process noise (workload drift)
    D: float = 8.0      # measurement noise
    R: float = 0.0      # state estimate (RPS)
    P: float = 1.0      # estimate covariance

    def update(self, observed_rps: float) -> float:
        r_pred = self.A * self.R
        p_pred = self.A * self.P * self.A + self.Q
        s = self.H * p_pred * self.H + self.D
        if s <= 0.0:
            # Degenerate innovation covariance (Q = D = 0 with a
            # collapsed P): the gain is 0/0, so the measurement carries
            # no usable information — coast on the prediction instead
            # of dividing by zero.
            self.R, self.P = r_pred, p_pred
            return max(self.R, 0.0)
        k = p_pred * self.H / s
        self.R = r_pred + k * (observed_rps - self.H * r_pred)
        self.P = (1.0 - k * self.H) * p_pred
        return max(self.R, 0.0)

    def predict(self) -> float:
        return max(self.A * self.R, 0.0)


@dataclasses.dataclass
class LastValuePredictor:
    """Naive baseline: predict the current observation (ablation)."""
    R: float = 0.0

    def update(self, observed_rps: float) -> float:
        self.R = observed_rps
        return self.R

    def predict(self) -> float:
        return self.R


class BatchedKalman:
    """Struct-of-arrays Kalman bank: N filter lanes updated in one
    vectorized pass.

    Lanes are *adopted* from live ``KalmanPredictor`` instances with
    :meth:`bind` (copying their current A/H/Q/D/R/P into the arrays);
    from then on the arrays are authoritative. :meth:`sync_back`
    scatters lane state back into the adopted scalar predictors so
    post-run introspection (tests, ablations) sees the same filter
    state a scalar run would leave behind.
    """

    def __init__(self, n_slots: int):
        self.n = n_slots
        self.A = np.ones(n_slots)
        self.H = np.ones(n_slots)
        self.Q = np.zeros(n_slots)
        self.D = np.zeros(n_slots)
        self.R = np.zeros(n_slots)
        self.P = np.ones(n_slots)
        self.bound = np.zeros(n_slots, dtype=bool)
        self._refs: list = [None] * n_slots

    def bind(self, slot: int, predictor: KalmanPredictor) -> None:
        """Adopt ``predictor``'s scalar state into lane ``slot``."""
        for name in ("A", "H", "Q", "D", "R", "P"):
            getattr(self, name)[slot] = getattr(predictor, name)
        self._refs[slot] = predictor
        self.bound[slot] = True

    def update(self, z: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """One fleet-wide filter step. Lanes where ``mask`` is False are
        left untouched (their returned prediction is stale state).

        Per masked lane this is byte-identical to
        ``KalmanPredictor.update(z[slot])``, including the degenerate-
        covariance coast (s <= 0 → keep the a-priori state).
        """
        A, H, Q, D = self.A, self.H, self.Q, self.D
        r_pred = A * self.R
        p_pred = A * self.P * A + Q
        s = H * p_pred * H + D
        deg = s <= 0.0
        k = p_pred * H / np.where(deg, 1.0, s)
        new_r = np.where(deg, r_pred, r_pred + k * (z - H * r_pred))
        new_p = np.where(deg, p_pred, (1.0 - k * H) * p_pred)
        self.R = np.where(mask, new_r, self.R)
        self.P = np.where(mask, new_p, self.P)
        # Python's max(R, 0.0) returns R when R >= 0.0 (so -0.0 stays
        # -0.0) and 0.0 otherwise (including NaN) — mirror that exactly.
        return np.where(self.R >= 0.0, self.R, 0.0)

    def sync_back(self) -> None:
        """Scatter lane state back into the adopted scalar predictors."""
        for slot, ref in enumerate(self._refs):
            if ref is not None:
                ref.R = float(self.R[slot])
                ref.P = float(self.P[slot])
