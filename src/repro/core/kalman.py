"""Kalman-filter short-term request-rate predictor (paper §3.3).

Scalar filter with state R (requests/s):
    R'_t = A R_{t-1},   P'_t = A P_{t-1} A + Q
    K    = P'_t H / (H P'_t H + D)
    R    = R'_t + K (z_t - H R'_t),   P = (1 - K H) P'_t

The predictor is decoupled from the auto-scaling algorithm (paper: "the
HAS autoscaler decouples the request prediction model"), so any object
with ``update(observed) -> predicted`` plugs in.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class KalmanPredictor:
    A: float = 1.0      # state transition
    H: float = 1.0      # observation model
    Q: float = 8.0      # process noise (workload drift)
    D: float = 8.0      # measurement noise
    R: float = 0.0      # state estimate (RPS)
    P: float = 1.0      # estimate covariance

    def update(self, observed_rps: float) -> float:
        r_pred = self.A * self.R
        p_pred = self.A * self.P * self.A + self.Q
        k = p_pred * self.H / (self.H * p_pred * self.H + self.D)
        self.R = r_pred + k * (observed_rps - self.H * r_pred)
        self.P = (1.0 - k * self.H) * p_pred
        return max(self.R, 0.0)

    def predict(self) -> float:
        return max(self.A * self.R, 0.0)


@dataclasses.dataclass
class LastValuePredictor:
    """Naive baseline: predict the current observation (ablation)."""
    R: float = 0.0

    def update(self, observed_rps: float) -> float:
        self.R = observed_rps
        return self.R

    def predict(self) -> float:
        return self.R
