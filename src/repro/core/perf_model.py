"""Roofline-grounded latency ground truth for the cluster simulator.

This is the simulator's physics: the latency of one inference of function
(arch, batch) on ``sm`` slices with quota ``q``. It is derived from the
architecture's analytic FLOPs/bytes (validated against the dry-run's
compiled-HLO numbers — benchmarks/roofline.py cross-checks), with:

  * an MXU-efficiency curve eff(batch, sm) that saturates with batch and
    degrades with more slices (small batches cannot feed a wide MXU) —
    reproducing paper Fig 4's two saturation regimes;
  * time-window quantization for quota < 1 (paper §3.1): execution only
    proceeds while the pod holds time tokens.

RaPP (core/rapp) is trained against noisy samples of this oracle WITHOUT
seeing its functional form — it sees only jaxpr-derived features, exactly
as the paper's RaPP sees TVM IR features of models profiled on hardware.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import numpy as np

from repro.configs import ArchConfig
from repro.core.vgpu import TOTAL_SLICES, DEFAULT_WINDOW_MS

# per-chip hardware constants (TPU v5e)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
SEQ_PER_REQUEST = 128  # tokens processed per inference request
SERVICE_NOISE_SIGMA = 0.03  # lognormal jitter on simulated service times


@dataclasses.dataclass(frozen=True)
class FnSpec:
    """A serverless inference function: an architecture served at a batch."""
    arch: ArchConfig
    seq: int = SEQ_PER_REQUEST

    @property
    def fn_id(self) -> str:
        return f"fn-{self.arch.name}"


@functools.lru_cache(maxsize=None)
def fn_flops(spec: FnSpec, batch: int) -> float:
    """Forward-pass FLOPs for one batched inference."""
    cfg = spec.arch
    tokens = batch * spec.seq
    core = 2.0 * cfg.active_param_count() * tokens
    # attention score+value flops (full causal over seq)
    if not cfg.is_attention_free:
        n_attn = sum(1 for i in range(cfg.num_layers)
                     if cfg.layer_kind(i) == "attn")
        core += n_attn * 4.0 * batch * spec.seq * spec.seq \
            * cfg.num_heads * cfg.head_dim * 0.5
    return core


@functools.lru_cache(maxsize=None)
def fn_bytes(spec: FnSpec, batch: int) -> float:
    """HBM traffic for one batched inference (weights + activations)."""
    cfg = spec.arch
    weight_bytes = 2.0 * cfg.active_param_count()
    act_bytes = 2.0 * batch * spec.seq * cfg.d_model * cfg.num_layers * 4
    return weight_bytes + act_bytes


def mxu_efficiency(batch: int, sm: int) -> float:
    """Fraction of peak sustained: saturating in batch, degrading in sm.

    b_half: batch at which half the slice's peak is reached; wider
    allocations need more parallel work to fill their MXUs.
    """
    b_half = 2.0 * sm
    return batch / (batch + b_half)


@functools.lru_cache(maxsize=None)
def exec_time(spec: FnSpec, batch: int, sm: int) -> float:
    """Seconds of *owned* accelerator time for one inference at full quota.

    Memoized: (spec, batch, sm) fully determines the value, specs are
    frozen dataclasses, and the simulators' hot paths (dispatch ordering,
    the autoscaler's (batch, sm, quota) grid searches) hit the same keys
    millions of times per run."""
    frac = sm / TOTAL_SLICES
    compute = fn_flops(spec, batch) / (frac * PEAK_FLOPS
                                       * mxu_efficiency(batch, sm))
    memory = fn_bytes(spec, batch) / (frac * HBM_BW)
    # small fixed dispatch overhead per inference
    return max(compute, memory) + 0.25e-3


def latency(spec: FnSpec, batch: int, sm: int, quota: float,
            window_ms: float = DEFAULT_WINDOW_MS,
            rng: Optional[np.random.Generator] = None) -> float:
    """Wall-clock latency of one inference under (sm, quota).

    The pod owns ``quota`` of each window; execution of total demand T
    spans ceil(T / (quota*W)) windows, of which the last is partial.
    """
    t = exec_time(spec, batch, sm)
    w = window_ms / 1e3
    q = min(max(quota, 1e-3), 1.0)
    if q >= 1.0 - 1e-9:
        wall = t
    else:
        owned_per_window = q * w
        full_windows = math.floor(t / owned_per_window)
        rem = t - full_windows * owned_per_window
        wall = full_windows * w + rem
    if rng is not None:
        wall *= float(rng.lognormal(mean=0.0, sigma=SERVICE_NOISE_SIGMA))
    return wall


def throughput(spec: FnSpec, batch: int, sm: int, quota: float,
               window_ms: float = DEFAULT_WINDOW_MS,
               overhead_s: float = 0.0) -> float:
    """Requests/second capability (paper: batch / latency). ``overhead_s``
    models per-cycle batching/dispatch overhead for capacity planning."""
    return batch / (latency(spec, batch, sm, quota, window_ms) + overhead_s)


def slo_baseline(spec: FnSpec, batch: int) -> float:
    """Paper §4.3: theoretical shortest inference time (whole chip,
    full quota, no sharing)."""
    return exec_time(spec, batch, TOTAL_SLICES)


def cost_rate(sm: int, quota: float, price_per_hour: float = 2.48) -> float:
    """$/second while holding (sm, quota) — paper Fig 7 accounting
    (Google Cloud V100 price), charged on actual fraction held."""
    return price_per_hour / 3600.0 * (sm / TOTAL_SLICES) * quota


# ---- vectorized config-lattice forms ---------------------------------------
# Array counterparts of the scalar physics above, used by the control
# plane's CapacityTable (core/capacity.py). Each mirrors its scalar twin
# operation-for-operation so the results are BITWISE identical — the
# autoscaler's golden traces depend on `lat > cap`-style comparisons and
# must not move by even one ulp when the lattice replaces the loop
# (tests/test_capacity.py pins exact equality).

def quota_grid(quota_step: float = 0.1) -> np.ndarray:
    """The quota values the control-plane loops enumerate: qi * step for
    qi = 1..round(1/step), with the loop's exact float arithmetic."""
    nq = int(round(1.0 / quota_step))
    return np.array([qi * quota_step for qi in range(1, nq + 1)])


def exec_time_lattice(spec: FnSpec, batch: int,
                      sms: np.ndarray) -> np.ndarray:
    """Vectorized `exec_time` over an array of SM partition sizes."""
    sms = np.asarray(sms, dtype=np.float64)
    frac = sms / TOTAL_SLICES
    eff = batch / (batch + 2.0 * sms)          # mxu_efficiency, b_half=2*sm
    compute = fn_flops(spec, batch) / (frac * PEAK_FLOPS * eff)
    memory = fn_bytes(spec, batch) / (frac * HBM_BW)
    return np.maximum(compute, memory) + 0.25e-3


def latency_lattice(spec: FnSpec, batch: int, sms: np.ndarray,
                    quotas: np.ndarray,
                    window_ms: float = DEFAULT_WINDOW_MS) -> np.ndarray:
    """Vectorized `latency` over the (sm x quota) lattice -> (S, Q)."""
    t = exec_time_lattice(spec, batch, sms)[:, None]         # (S, 1)
    w = window_ms / 1e3
    q = np.minimum(np.maximum(np.asarray(quotas, np.float64), 1e-3),
                   1.0)[None, :]                             # (1, Q)
    owned = q * w
    with np.errstate(divide="ignore"):
        full = np.floor(t / owned)
    rem = t - full * owned
    return np.where(q >= 1.0 - 1e-9, t, full * w + rem)


def throughput_lattice(spec: FnSpec, batch: int, sms: np.ndarray,
                       quotas: np.ndarray,
                       window_ms: float = DEFAULT_WINDOW_MS,
                       overhead_s: float = 0.0) -> np.ndarray:
    """Vectorized `throughput` over the (sm x quota) lattice -> (S, Q)."""
    return batch / (latency_lattice(spec, batch, sms, quotas, window_ms)
                    + overhead_s)


def cost_rate_lattice(sms: np.ndarray, quotas: np.ndarray,
                      price_per_hour: float = 2.48) -> np.ndarray:
    """Vectorized `cost_rate` over the (sm x quota) lattice -> (S, Q)."""
    sms = np.asarray(sms, dtype=np.float64)
    return (price_per_hour / 3600.0
            * (sms[:, None] / TOTAL_SLICES) * np.asarray(quotas)[None, :])


def most_efficient_config(spec: FnSpec, target_rps: float,
                          predictor=None,
                          batches=(1, 2, 4, 8, 16, 32),
                          quota_step: float = 0.1,
                          slo_multiplier: Optional[float] = 2.0) -> tuple:
    """Paper: RaPPbyThroughput — cheapest (batch, sm, quota) meeting
    target_rps on a fresh chip, subject to the latency SLO
    (lat <= slo_multiplier x whole-chip baseline for that batch).
    Falls back to the most capable SLO-satisfying config."""
    pred = predictor or (lambda s, b, sm, q: latency(s, b, sm, q))
    best, best_cost = None, float("inf")
    fallback, fb_thpt = None, -1.0
    for b in batches:
        cap = (slo_multiplier * slo_baseline(spec, b)
               if slo_multiplier else float("inf"))
        for sm in range(1, TOTAL_SLICES + 1):
            for qi in range(1, int(round(1.0 / quota_step)) + 1):
                q = qi * quota_step
                lat = pred(spec, b, sm, q)
                if lat > cap:
                    continue
                thpt = b / lat
                if thpt > fb_thpt:
                    fallback, fb_thpt = (b, sm, q), thpt
                if thpt >= target_rps:
                    c = cost_rate(sm, q)
                    if c < best_cost:
                        best, best_cost = (b, sm, q), c
    if best is None:
        best = fallback or (batches[-1], TOTAL_SLICES, 1.0)
    return best


def min_quota_for_slo(spec: FnSpec, batch: int, sm: int,
                      slo_multiplier: float = 2.0,
                      quota_step: float = 0.1,
                      predictor=None) -> Optional[float]:
    """Smallest quota at which (batch, sm) meets the latency SLO."""
    pred = predictor or (lambda s, b, sm_, q: latency(s, b, sm_, q))
    cap = slo_multiplier * slo_baseline(spec, batch)
    for qi in range(1, int(round(1.0 / quota_step)) + 1):
        q = qi * quota_step
        if pred(spec, batch, sm, q) <= cap:
            return q
    return None
