"""Roofline-grounded latency ground truth for the cluster simulator.

This is the simulator's physics: the latency of one inference of function
(arch, batch) on ``sm`` slices with quota ``q``. It is derived from the
architecture's analytic FLOPs/bytes (validated against the dry-run's
compiled-HLO numbers — benchmarks/roofline.py cross-checks), with:

  * an MXU-efficiency curve eff(batch, sm) that saturates with batch and
    degrades with more slices (small batches cannot feed a wide MXU) —
    reproducing paper Fig 4's two saturation regimes;
  * time-window quantization for quota < 1 (paper §3.1): execution only
    proceeds while the pod holds time tokens.

RaPP (core/rapp) is trained against noisy samples of this oracle WITHOUT
seeing its functional form — it sees only jaxpr-derived features, exactly
as the paper's RaPP sees TVM IR features of models profiled on hardware.

Every device-dependent function takes a ``gpu: GPUType`` (peak FLOPs,
HBM bandwidth, slice count, $/hour — ``configs/gpus.py``) defaulting to
the reference device, whose constants are exactly the ones this module
was born with: calls that do not pass ``gpu`` are bitwise identical to
the pre-heterogeneity physics. The SLO baseline stays anchored to the
reference device regardless of which device serves (a function's SLO is
a property of the function, not of the chip it happened to land on), so
latency caps are comparable across a mixed fleet.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import numpy as np

from repro.configs import ArchConfig
from repro.configs.gpus import DEFAULT_GPU_TYPE, GPUType
from repro.core.vgpu import TOTAL_SLICES, DEFAULT_WINDOW_MS

# reference-chip hardware constants (TPU v5e) — kept as module-level
# aliases of DEFAULT_GPU_TYPE for backward compatibility
PEAK_FLOPS = DEFAULT_GPU_TYPE.peak_flops
HBM_BW = DEFAULT_GPU_TYPE.hbm_bw
SEQ_PER_REQUEST = 128  # tokens processed per inference request
SERVICE_NOISE_SIGMA = 0.03  # lognormal jitter on simulated service times


@dataclasses.dataclass(frozen=True)
class FnSpec:
    """A serverless inference function: an architecture served at a batch."""
    arch: ArchConfig
    seq: int = SEQ_PER_REQUEST
    # tenant label for wide fleets: distinguishes fn_ids when hundreds
    # of functions share an architecture, but is excluded from eq/hash
    # so every physics lru_cache and CapacityTable lattice collapses
    # across variants (same arch + seq => same physics)
    variant: str = dataclasses.field(default="", compare=False)

    @property
    def fn_id(self) -> str:
        if self.variant:
            return f"fn-{self.arch.name}-{self.variant}"
        return f"fn-{self.arch.name}"


@functools.lru_cache(maxsize=None)
def fn_flops(spec: FnSpec, batch: int) -> float:
    """Forward-pass FLOPs for one batched inference."""
    cfg = spec.arch
    tokens = batch * spec.seq
    core = 2.0 * cfg.active_param_count() * tokens
    # attention score+value flops (full causal over seq)
    if not cfg.is_attention_free:
        n_attn = sum(1 for i in range(cfg.num_layers)
                     if cfg.layer_kind(i) == "attn")
        core += n_attn * 4.0 * batch * spec.seq * spec.seq \
            * cfg.num_heads * cfg.head_dim * 0.5
    return core


@functools.lru_cache(maxsize=None)
def fn_bytes(spec: FnSpec, batch: int) -> float:
    """HBM traffic for one batched inference (weights + activations)."""
    cfg = spec.arch
    weight_bytes = 2.0 * cfg.active_param_count()
    act_bytes = 2.0 * batch * spec.seq * cfg.d_model * cfg.num_layers * 4
    return weight_bytes + act_bytes


def slice_width(gpu: GPUType) -> float:
    """Per-slice MXU width of ``gpu`` relative to the reference device
    (peak FLOPs per slice, normalized). Exactly 1.0 for the reference
    chip — the efficiency curve below is then bitwise the legacy one."""
    return ((gpu.peak_flops / gpu.sm_total)
            / (DEFAULT_GPU_TYPE.peak_flops / DEFAULT_GPU_TYPE.sm_total))


def mxu_efficiency(batch: int, sm: int,
                   gpu: GPUType = DEFAULT_GPU_TYPE) -> float:
    """Fraction of peak sustained: saturating in batch, degrading in sm.

    b_half: batch at which half the slice's peak is reached; wider
    allocations need more parallel work to fill their MXUs — and a
    slice of a faster chip is itself a wider MXU, so b_half scales with
    the device's per-slice width (1.0 on the reference device). This is
    why premium chips do not strictly dominate in $/request: their
    slices only reach high efficiency at large batches.
    """
    b_half = 2.0 * sm * slice_width(gpu)
    return batch / (batch + b_half)


@functools.lru_cache(maxsize=None)
def exec_time(spec: FnSpec, batch: int, sm: int,
              gpu: GPUType = DEFAULT_GPU_TYPE) -> float:
    """Seconds of *owned* accelerator time for one inference at full quota
    on ``sm`` slices of a ``gpu``-type chip.

    Memoized: (spec, batch, sm, gpu) fully determines the value, specs
    and GPU types are frozen dataclasses, and the simulators' hot paths
    (dispatch ordering, the autoscaler's (batch, sm, quota) grid
    searches) hit the same keys millions of times per run."""
    frac = sm / gpu.sm_total
    compute = fn_flops(spec, batch) / (frac * gpu.peak_flops
                                       * mxu_efficiency(batch, sm, gpu))
    memory = fn_bytes(spec, batch) / (frac * gpu.hbm_bw)
    # small fixed dispatch overhead per inference
    return max(compute, memory) + 0.25e-3


def latency(spec: FnSpec, batch: int, sm: int, quota: float,
            window_ms: float = DEFAULT_WINDOW_MS,
            rng: Optional[np.random.Generator] = None,
            gpu: GPUType = DEFAULT_GPU_TYPE) -> float:
    """Wall-clock latency of one inference under (sm, quota) on ``gpu``.

    The pod owns ``quota`` of each window; execution of total demand T
    spans ceil(T / (quota*W)) windows, of which the last is partial.
    """
    t = exec_time(spec, batch, sm, gpu)
    w = window_ms / 1e3
    q = min(max(quota, 1e-3), 1.0)
    if q >= 1.0 - 1e-9:
        wall = t
    else:
        owned_per_window = q * w
        full_windows = math.floor(t / owned_per_window)
        rem = t - full_windows * owned_per_window
        wall = full_windows * w + rem
    if rng is not None:
        wall *= float(rng.lognormal(mean=0.0, sigma=SERVICE_NOISE_SIGMA))
    return wall


def throughput(spec: FnSpec, batch: int, sm: int, quota: float,
               window_ms: float = DEFAULT_WINDOW_MS,
               overhead_s: float = 0.0,
               gpu: GPUType = DEFAULT_GPU_TYPE) -> float:
    """Requests/second capability (paper: batch / latency). ``overhead_s``
    models per-cycle batching/dispatch overhead for capacity planning."""
    return batch / (latency(spec, batch, sm, quota, window_ms, gpu=gpu)
                    + overhead_s)


def slo_baseline(spec: FnSpec, batch: int) -> float:
    """Paper §4.3: theoretical shortest inference time (whole chip, full
    quota, no sharing) — on the REFERENCE device, deliberately: a
    function's SLO must not move with the chip that happens to serve it,
    or latency caps would be incomparable across a mixed fleet."""
    return exec_time(spec, batch, TOTAL_SLICES)


def cost_rate(sm: int, quota: float,
              gpu: GPUType = DEFAULT_GPU_TYPE) -> float:
    """$/second while holding (sm, quota) on a ``gpu``-type chip — paper
    Fig 7 accounting (reference price: Google Cloud V100), charged on
    the fraction of the chip actually held."""
    return gpu.price_per_hour / 3600.0 * (sm / gpu.sm_total) * quota


# ---- vectorized config-lattice forms ---------------------------------------
# Array counterparts of the scalar physics above, used by the control
# plane's CapacityTable (core/capacity.py). Each mirrors its scalar twin
# operation-for-operation so the results are BITWISE identical — the
# autoscaler's golden traces depend on `lat > cap`-style comparisons and
# must not move by even one ulp when the lattice replaces the loop
# (tests/test_capacity.py pins exact equality).

def quota_grid(quota_step: float = 0.1) -> np.ndarray:
    """The quota values the control-plane loops enumerate: qi * step for
    qi = 1..round(1/step), with the loop's exact float arithmetic."""
    nq = int(round(1.0 / quota_step))
    return np.array([qi * quota_step for qi in range(1, nq + 1)])


def exec_time_lattice(spec: FnSpec, batch: int, sms: np.ndarray,
                      gpu: GPUType = DEFAULT_GPU_TYPE) -> np.ndarray:
    """Vectorized `exec_time` over an array of SM partition sizes."""
    sms = np.asarray(sms, dtype=np.float64)
    frac = sms / gpu.sm_total
    # mxu_efficiency: b_half = 2*sm*slice_width (width 1.0 on the
    # reference device keeps this bitwise the legacy expression)
    eff = batch / (batch + 2.0 * sms * slice_width(gpu))
    compute = fn_flops(spec, batch) / (frac * gpu.peak_flops * eff)
    memory = fn_bytes(spec, batch) / (frac * gpu.hbm_bw)
    return np.maximum(compute, memory) + 0.25e-3


def latency_lattice(spec: FnSpec, batch: int, sms: np.ndarray,
                    quotas: np.ndarray,
                    window_ms: float = DEFAULT_WINDOW_MS,
                    gpu: GPUType = DEFAULT_GPU_TYPE) -> np.ndarray:
    """Vectorized `latency` over the (sm x quota) lattice -> (S, Q)."""
    t = exec_time_lattice(spec, batch, sms, gpu)[:, None]    # (S, 1)
    w = window_ms / 1e3
    q = np.minimum(np.maximum(np.asarray(quotas, np.float64), 1e-3),
                   1.0)[None, :]                             # (1, Q)
    owned = q * w
    with np.errstate(divide="ignore"):
        full = np.floor(t / owned)
    rem = t - full * owned
    return np.where(q >= 1.0 - 1e-9, t, full * w + rem)


def throughput_lattice(spec: FnSpec, batch: int, sms: np.ndarray,
                       quotas: np.ndarray,
                       window_ms: float = DEFAULT_WINDOW_MS,
                       overhead_s: float = 0.0,
                       gpu: GPUType = DEFAULT_GPU_TYPE) -> np.ndarray:
    """Vectorized `throughput` over the (sm x quota) lattice -> (S, Q)."""
    return batch / (latency_lattice(spec, batch, sms, quotas, window_ms,
                                    gpu)
                    + overhead_s)


def cost_rate_lattice(sms: np.ndarray, quotas: np.ndarray,
                      gpu: GPUType = DEFAULT_GPU_TYPE) -> np.ndarray:
    """Vectorized `cost_rate` over the (sm x quota) lattice -> (S, Q)."""
    sms = np.asarray(sms, dtype=np.float64)
    return (gpu.price_per_hour / 3600.0
            * (sms[:, None] / gpu.sm_total) * np.asarray(quotas)[None, :])


def _resolve_pred(predictor, gpu: GPUType):
    """Scalar latency callable for ``gpu``: oracle when ``predictor`` is
    None; custom predictors keep the legacy 4-arg call on the reference
    device and receive ``gpu=`` only off it. A 4-arg-only predictor on
    a non-reference device fails HERE with an actionable message
    instead of a bare TypeError deep inside a lattice fill."""
    if predictor is None:
        return lambda s, b, sm, q: latency(s, b, sm, q, gpu=gpu)
    if gpu == DEFAULT_GPU_TYPE:   # value equality: user-constructed
        return predictor          # reference-equal devices count too
    import inspect
    try:
        params = inspect.signature(predictor).parameters.values()
        accepts_gpu = any(
            p.name == "gpu" or p.kind is inspect.Parameter.VAR_KEYWORD
            for p in params)
    except (TypeError, ValueError):   # builtins etc.: assume capable
        accepts_gpu = True
    if not accepts_gpu:
        raise TypeError(
            f"predictor {predictor!r} only implements the 4-arg "
            f"lat(spec, batch, sm, quota) protocol, but device type "
            f"{gpu.name!r} was requested; heterogeneous fleets need "
            f"lat(spec, batch, sm, quota, gpu=...) (see RaPPModel)")
    return lambda s, b, sm, q: predictor(s, b, sm, q, gpu=gpu)


def most_efficient_config(spec: FnSpec, target_rps: float,
                          predictor=None,
                          batches=(1, 2, 4, 8, 16, 32),
                          quota_step: float = 0.1,
                          slo_multiplier: Optional[float] = 2.0,
                          gpu: GPUType = DEFAULT_GPU_TYPE) -> tuple:
    """Paper: RaPPbyThroughput — cheapest (batch, sm, quota) meeting
    target_rps on a fresh ``gpu``-type chip, subject to the latency SLO
    (lat <= slo_multiplier x reference whole-chip baseline for that
    batch). Falls back to the most capable SLO-satisfying config."""
    pred = _resolve_pred(predictor, gpu)
    best, best_cost = None, float("inf")
    fallback, fb_thpt = None, -1.0
    for b in batches:
        cap = (slo_multiplier * slo_baseline(spec, b)
               if slo_multiplier else float("inf"))
        for sm in range(1, gpu.sm_total + 1):
            for qi in range(1, int(round(1.0 / quota_step)) + 1):
                q = qi * quota_step
                lat = pred(spec, b, sm, q)
                if lat > cap:
                    continue
                thpt = b / lat
                if thpt > fb_thpt:
                    fallback, fb_thpt = (b, sm, q), thpt
                if thpt >= target_rps:
                    c = cost_rate(sm, q, gpu)
                    if c < best_cost:
                        best, best_cost = (b, sm, q), c
    if best is None:
        best = fallback or (batches[-1], gpu.sm_total, 1.0)
    return best


def min_quota_for_slo(spec: FnSpec, batch: int, sm: int,
                      slo_multiplier: float = 2.0,
                      quota_step: float = 0.1,
                      predictor=None,
                      gpu: GPUType = DEFAULT_GPU_TYPE) -> Optional[float]:
    """Smallest quota at which (batch, sm) on ``gpu`` meets the SLO."""
    pred = _resolve_pred(predictor, gpu)
    cap = slo_multiplier * slo_baseline(spec, batch)
    for qi in range(1, int(round(1.0 / quota_step)) + 1):
        q = qi * quota_step
        if pred(spec, batch, sm, q) <= cap:
            return q
    return None
