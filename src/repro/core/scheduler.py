"""HAS-GPU-Scheduler: vGPU time-token scheduling, GPU clients, and the
placement-aware fleet packer.

The paper's scheduler abstracts each physical GPU into a vGPU with a
time-token window; every pod gets a GPU client, and the pod's runtime
(libhas, via intercepted cuLaunchKernel) must acquire time tokens before
executing kernels. Vertical scaling = rewriting the pod's token share,
effective at the next window — no restart.

On TPU the dispatch unit is a jitted step, so the handshake happens per
step (DESIGN.md §2). This module implements the token accounting both in
real time (for the CPU serving demo) and in virtual time (for tests).

``FleetPlacer`` is the heterogeneous-fleet addition: first-fit-
decreasing bin-packing of pod requests onto a mixed fleet's SM
fragments, preferring cheaper device types that still meet the
function's SLO, falling back to capable-but-expensive (or
SLO-violating spot) types only when the cheap pools are exhausted.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.gpus import GPUType
from repro.core.vgpu import PodAlloc, VirtualGPU


class TokenLedger:
    """Window-based token accounting for one vGPU partition set.

    Tokens are seconds of owned execution time within the current window.
    ``acquire(pod_id, cost_s, now)`` returns the time at which the pod may
    run a task costing ``cost_s`` seconds, advancing windows as needed.
    """

    def __init__(self, vgpu: VirtualGPU):
        self.vgpu = vgpu
        self.window_s = vgpu.window_ms / 1e3
        self._window_start: Dict[str, float] = {}
        self._budget: Dict[str, float] = {}

    def quota_of(self, pod_id: str) -> float:
        part = self.vgpu.partition_of(pod_id)
        if part is None:
            raise KeyError(
                f"pod {pod_id!r} is not placed on GPU {self.vgpu.uuid} "
                "(removed, reclaimed, or never placed) — stale client?")
        return next(p.quota for p in part.pods if p.pod_id == pod_id)

    def release(self, pod_id: str) -> None:
        """Drop the pod's window/budget state (idempotent). Must be
        called when the pod leaves the GPU, or the ledger leaks one
        entry per departed pod for the life of the chip."""
        self._window_start.pop(pod_id, None)
        self._budget.pop(pod_id, None)

    def acquire(self, pod_id: str, cost_s: float, now: float) -> float:
        """Virtual-time acquire: returns completion time of the task."""
        q = self.quota_of(pod_id)
        w = self.window_s
        ws = self._window_start.get(pod_id, now - (now % w))
        budget = self._budget.get(pod_id, q * w)
        t = max(now, ws)
        remaining = cost_s
        while remaining > 1e-12:
            if t >= ws + w:  # advance to the window containing t
                ws = t - ((t - ws) % w)
                budget = q * w
            if budget <= 1e-12:
                ws = ws + w
                t = ws
                budget = q * w
                continue
            use = min(remaining, budget, ws + w - t)
            if use <= 1e-12:
                ws += w
                t = max(t, ws)
                budget = q * w
                continue
            t += use
            remaining -= use
            budget -= use
        self._window_start[pod_id] = ws
        self._budget[pod_id] = budget
        return t


class GPUClient:
    """Per-pod client handle (paper: created by the vGPU for each pod)."""

    def __init__(self, ledger: TokenLedger, pod_id: str):
        self.ledger = ledger
        self.pod_id = pod_id
        self._lock = threading.Lock()

    def acquire(self, cost_s: float) -> None:
        """Real-time acquire: sleeps until the pod's token share allows a
        task of cost_s seconds (the libhas handshake)."""
        with self._lock:
            now = time.monotonic()
            done_at = self.ledger.acquire(self.pod_id, cost_s, now)
            wait = done_at - now - cost_s
            if wait > 0:
                time.sleep(wait)


class HASGPUScheduler:
    """Node daemon view: one ledger per vGPU, clients per pod."""

    def __init__(self):
        self.ledgers: Dict[str, TokenLedger] = {}
        self.clients: Dict[str, GPUClient] = {}

    def register_gpu(self, vgpu: VirtualGPU) -> TokenLedger:
        ledger = self.ledgers.get(vgpu.uuid)
        if ledger is None:
            ledger = self.ledgers[vgpu.uuid] = TokenLedger(vgpu)
            # pod churn (scale-down, spot reclaims) must not leak ledger
            # or client state: release on every removal, however driven
            vgpu.remove_listeners.append(
                lambda g, pod: self.release(g.uuid, pod.pod_id))
        return ledger

    def release(self, gpu_uuid: str, pod_id: str) -> None:
        """Release all scheduler state of one departed pod (idempotent):
        its token-ledger window/budget entries and its client handle."""
        ledger = self.ledgers.get(gpu_uuid)
        if ledger is not None:
            ledger.release(pod_id)
        self.clients.pop(f"{gpu_uuid}/{pod_id}", None)

    def client_for(self, vgpu: VirtualGPU, pod_id: str) -> GPUClient:
        ledger = self.register_gpu(vgpu)
        key = f"{vgpu.uuid}/{pod_id}"
        if key not in self.clients:
            self.clients[key] = GPUClient(ledger, pod_id)
        return self.clients[key]


# --------------------------------------------------------------------------
# Placement-aware fleet packing (heterogeneous clusters)
# --------------------------------------------------------------------------

class FleetPlacer:
    """First-fit-decreasing bin-packing of pods onto a mixed fleet.

    Ordering rules:

      * requests are placed in DECREASING SM width (classic FFD: wide
        pods first, narrow pods fill the leftover fragments — this is
        what keeps ``Reconfigurator.fragmentation`` low);
      * candidate chips for one request are ranked by
        (type $/slice-hour, creation order): cheaper device classes are
        filled before expensive ones, and within a class the oldest
        chip first (first fit);
      * device types that cannot meet the function's SLO at the pod's
        (batch, sm) — per ``CapacityTable.min_quota_for_slo`` — are
        deferred: they are only used when no SLO-capable chip or fresh
        type remains (spot overflow, the ``spot_t4_burst`` regime).

    The placer mutates the cluster through the ordinary
    ``Reconfigurator`` APIs, so all invariants/indexes hold.
    """

    def __init__(self, recon, table, slo_multiplier: float = 2.0):
        """Args:
            recon: the cluster to pack into.
            table: a ``CapacityTable`` used for the SLO feasibility
                checks (any predictor).
            slo_multiplier: latency cap as a multiple of the reference
                whole-chip baseline.
        """
        self.recon = recon
        self.table = table
        self.slo_multiplier = slo_multiplier

    # ---- weight affinity ---------------------------------------------------
    def _affinity_rank(self, g: VirtualGPU, fn_id: str, now: float) -> int:
        """Model-state placement affinity at ``now``
        (``ModelStateTracker.placement_rank``: HBM-resident <
        host-cached < fetch in flight < cold) — constant 0 without an
        active lifecycle tracker, so legacy packing order is
        untouched."""
        tracker = getattr(self.recon, "modelstate", None)
        if tracker is None or tracker.is_passive:
            return 0
        return tracker.placement_rank(g, fn_id, now)

    # ---- SLO feasibility ---------------------------------------------------
    def slo_ok(self, spec, pod: PodAlloc, gpu_type: GPUType) -> bool:
        """Whether (pod.batch, pod.sm, pod.quota) on ``gpu_type`` meets
        the SLO (the pod must be narrow enough for the device at all)."""
        if pod.sm > gpu_type.sm_total:
            return False
        floor = self.table.min_quota_for_slo(
            spec, pod.batch, pod.sm, self.slo_multiplier, gpu=gpu_type)
        return floor is not None and floor <= pod.quota + 1e-9

    # ---- single placement --------------------------------------------------
    def place_one(self, spec, pod: PodAlloc, now: float = 0.0,
                  cold_start_s: float = 0.0,
                  new_gpu_cold_start_s: Optional[float] = None,
                  allow_slo_overflow: bool = True,
                  allowed_types: Optional[Sequence[GPUType]] = None,
                  ) -> Optional[VirtualGPU]:
        """Place one pod: cheapest SLO-capable fragment first, then a
        fresh chip of the cheapest SLO-capable type, then (optionally)
        any type that physically fits. Chips inside a spot-reclaim
        grace window (``doomed``) are never candidates.

        Args:
            spec: the pod's function (for SLO feasibility checks).
            pod: an unplaced ``PodAlloc``.
            now: placement time (stamps ``created_at``).
            cold_start_s: cold start when joining a warm (used) chip.
            new_gpu_cold_start_s: cold start when a fresh chip must be
                provisioned; defaults to ``cold_start_s``.
            allow_slo_overflow: permit SLO-violating hosts when nothing
                SLO-capable remains (spot overflow) instead of failing.
            allowed_types: optional device-type restriction (the hybrid
                router's on-demand-only routing during reclaim
                pressure); None = all fleet types.
        Returns: the hosting GPU, or None when the fleet cannot host
        the pod at all (under the restriction, if any).
        """
        if new_gpu_cold_start_s is None:
            new_gpu_cold_start_s = cold_start_s
        type_ok = (lambda t: True) if allowed_types is None \
            else set(allowed_types).__contains__
        used = [g for g in self.recon.used_gpus()
                if not g.doomed and type_ok(g.gpu_type)
                and g.can_place(pod.sm, pod.quota)]
        used.sort(key=lambda g: (g.gpu_type.price_per_slice_hour,
                                 self._affinity_rank(g, pod.fn_id, now),
                                 g.index))
        deferred: List = []
        for g in used:
            if not self.slo_ok(spec, pod, g.gpu_type):
                deferred.append(g)
                continue
            self.recon.place_pod(pod, g.uuid, now=now,
                                 cold_start_s=cold_start_s, spec=spec)
            return g
        fresh = sorted(
            (t for t in self.recon.available_gpu_types(min_sm=pod.sm)
             if type_ok(t) and self.slo_ok(spec, pod, t)),
            key=lambda t: t.price_per_slice_hour)
        if fresh:
            g = self.recon.add_gpu(fresh[0])
            self.recon.place_pod(pod, g.uuid, now=now,
                                 cold_start_s=new_gpu_cold_start_s,
                                 spec=spec, fresh_chip=True)
            return g
        if not allow_slo_overflow:
            return None
        # overflow: violate the SLO rather than drop — used fragments
        # first (no provisioning cost), then any fresh type that fits
        if deferred:
            g = deferred[0]
            self.recon.place_pod(pod, g.uuid, now=now,
                                 cold_start_s=cold_start_s, spec=spec)
            return g
        types = [t for t in self.recon.available_gpu_types(min_sm=pod.sm)
                 if type_ok(t)]
        if not types:
            return None
        t = min(types, key=lambda t: t.price_per_slice_hour)
        g = self.recon.add_gpu(t)
        self.recon.place_pod(pod, g.uuid, now=now,
                             cold_start_s=new_gpu_cold_start_s,
                             spec=spec, fresh_chip=True)
        return g

    # ---- batch packing (FFD) -----------------------------------------------
    def pack(self, requests: Sequence[Tuple], now: float = 0.0,
             cold_start_s: float = 0.0) -> List[Tuple]:
        """First-fit-decreasing pack of ``(spec, pod)`` requests.

        Args:
            requests: iterable of ``(FnSpec, PodAlloc)`` pairs; the pods
                must be unplaced.
            now/cold_start_s: forwarded to ``place_pod``.
        Returns: list of ``(pod, gpu_or_None)`` in placement (FFD)
        order; None marks pods the fleet could not host.
        """
        order = sorted(requests, key=lambda r: -r[1].sm)
        out = []
        for spec, pod in order:
            out.append((pod, self.place_one(spec, pod, now=now,
                                            cold_start_s=cold_start_s)))
        return out
