"""HAS-GPU-Scheduler: vGPU time-token scheduling + GPU clients.

The paper's scheduler abstracts each physical GPU into a vGPU with a
time-token window; every pod gets a GPU client, and the pod's runtime
(libhas, via intercepted cuLaunchKernel) must acquire time tokens before
executing kernels. Vertical scaling = rewriting the pod's token share,
effective at the next window — no restart.

On TPU the dispatch unit is a jitted step, so the handshake happens per
step (DESIGN.md §2). This module implements the token accounting both in
real time (for the CPU serving demo) and in virtual time (for tests).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional

from repro.core.vgpu import VirtualGPU


class TokenLedger:
    """Window-based token accounting for one vGPU partition set.

    Tokens are seconds of owned execution time within the current window.
    ``acquire(pod_id, cost_s, now)`` returns the time at which the pod may
    run a task costing ``cost_s`` seconds, advancing windows as needed.
    """

    def __init__(self, vgpu: VirtualGPU):
        self.vgpu = vgpu
        self.window_s = vgpu.window_ms / 1e3
        self._window_start: Dict[str, float] = {}
        self._budget: Dict[str, float] = {}

    def quota_of(self, pod_id: str) -> float:
        part = self.vgpu.partition_of(pod_id)
        if part is None:
            raise KeyError(pod_id)
        return next(p.quota for p in part.pods if p.pod_id == pod_id)

    def acquire(self, pod_id: str, cost_s: float, now: float) -> float:
        """Virtual-time acquire: returns completion time of the task."""
        q = self.quota_of(pod_id)
        w = self.window_s
        ws = self._window_start.get(pod_id, now - (now % w))
        budget = self._budget.get(pod_id, q * w)
        t = max(now, ws)
        remaining = cost_s
        while remaining > 1e-12:
            if t >= ws + w:  # advance to the window containing t
                ws = t - ((t - ws) % w)
                budget = q * w
            if budget <= 1e-12:
                ws = ws + w
                t = ws
                budget = q * w
                continue
            use = min(remaining, budget, ws + w - t)
            if use <= 1e-12:
                ws += w
                t = max(t, ws)
                budget = q * w
                continue
            t += use
            remaining -= use
            budget -= use
        self._window_start[pod_id] = ws
        self._budget[pod_id] = budget
        return t


class GPUClient:
    """Per-pod client handle (paper: created by the vGPU for each pod)."""

    def __init__(self, ledger: TokenLedger, pod_id: str):
        self.ledger = ledger
        self.pod_id = pod_id
        self._lock = threading.Lock()

    def acquire(self, cost_s: float) -> None:
        """Real-time acquire: sleeps until the pod's token share allows a
        task of cost_s seconds (the libhas handshake)."""
        with self._lock:
            now = time.monotonic()
            done_at = self.ledger.acquire(self.pod_id, cost_s, now)
            wait = done_at - now - cost_s
            if wait > 0:
                time.sleep(wait)


class HASGPUScheduler:
    """Node daemon view: one ledger per vGPU, clients per pod."""

    def __init__(self):
        self.ledgers: Dict[str, TokenLedger] = {}
        self.clients: Dict[str, GPUClient] = {}

    def register_gpu(self, vgpu: VirtualGPU) -> TokenLedger:
        ledger = self.ledgers.setdefault(vgpu.uuid, TokenLedger(vgpu))
        return ledger

    def client_for(self, vgpu: VirtualGPU, pod_id: str) -> GPUClient:
        ledger = self.register_gpu(vgpu)
        key = f"{vgpu.uuid}/{pod_id}"
        if key not in self.clients:
            self.clients[key] = GPUClient(ledger, pod_id)
        return self.clients[key]
