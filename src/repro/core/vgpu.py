"""vAccelerator (paper: vGPU) — fine-grained spatio-temporal allocation.

A physical chip is abstracted as a vGPU with ``TOTAL_SLICES`` equal compute
slices (the TPU analogue of MPS SM partitions — DESIGN.md §2). Allocation
is spatio-temporal:

  * spatial:  a pod owns a *partition* of ``sm`` slices, fixed at pod
    creation (like an MPS CUDA context's SM set);
  * temporal: within its partition, a pod owns a *time-token quota*
    ``q in (0, 1]`` of the scheduling window — runtime-mutable, which is
    what makes vertical scaling cheap (paper §3.1, Fig 2).

SM alignment (paper Fig 2): pods within a GPU are stacked onto aligned
partitions — a new pod either joins an existing partition of the same size
(sharing its time window) or carves a new partition from free slices.
This prevents spatial fragmentation.

Since the heterogeneous-fleet refactor each ``VirtualGPU`` carries a
``GPUType`` (``configs/gpus.py``): slice capacity is the type's
``sm_total`` (``TOTAL_SLICES`` remains the reference device's 8), and
occupancy/cost fractions are relative to that capacity.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

from repro.configs.gpus import DEFAULT_GPU_TYPE, GPU_TYPES, GPUType

TOTAL_SLICES = 8          # slice granularity of the REFERENCE chip type
DEFAULT_WINDOW_MS = 100.0  # time-token window (cgroups-like period)

# pods can never be wider than the widest registered device
_MAX_POD_SM = max(t.sm_total for t in GPU_TYPES.values())

_pod_counter = itertools.count()


@dataclasses.dataclass
class PodAlloc:
    """One function instance and its resource allocation.

    ``sm`` is validated against the widest registered device here; the
    strict per-device bound (``sm <= gpu_type.sm_total``) is enforced at
    placement, where the hosting chip is known. ``gpu_type`` is stamped
    by ``VirtualGPU.place`` so the pod's physics (service times,
    throughput, billing) follow the device actually hosting it.

    ``standby`` marks a keep-warm pod (quota parked near zero, weights
    held in HBM, excluded from dispatch and capacity, billed at the
    idle-retention price); ``start_kind`` is the model-state lifecycle
    engine's cold/warm/hot classification of the pod's last start
    (None outside lifecycle-enabled runs). ``doomed`` marks a pod whose
    host chip received a spot ``RECLAIM_NOTICE``: it drains (finishes
    in-flight work, contributes zero capacity, receives no new batches)
    until the grace window closes and the chip is killed.
    ``quarantined`` marks a pod whose health score tripped
    (``core/faults.py``): same drain semantics as doomed — no dispatch,
    zero capacity, skipped by ``Gateway.route`` — but the pod returns
    to service when the quarantine window lifts.
    """
    fn_id: str
    sm: int                      # slices in its partition (1..sm_total)
    quota: float                 # time-token share of the partition window
    batch: int                   # serving batch size
    pod_id: str = ""
    gpu_uuid: str = ""
    created_at: float = 0.0
    ready_at: float = 0.0        # cold start completion time
    gpu_type: Optional[GPUType] = None   # stamped at placement
    standby: bool = False        # keep-warm pool member (not serving)
    start_kind: Optional[str] = None     # cold | warm | hot (lifecycle)
    doomed: bool = False         # host chip inside a reclaim grace window
    quarantined: bool = False    # health-tripped straggler (faults.py)

    def __post_init__(self):
        if not self.pod_id:
            self.pod_id = f"pod-{next(_pod_counter)}"
        self._validate()

    def _validate(self):
        if not (1 <= self.sm <= _MAX_POD_SM):
            raise ValueError(f"sm={self.sm} out of range")
        if not (0.0 < self.quota <= 1.0 + 1e-9):
            raise ValueError(f"quota={self.quota} out of range")


@dataclasses.dataclass
class Partition:
    """An aligned group of slices shared (in time) by its pods."""
    sm: int
    pods: List[PodAlloc] = dataclasses.field(default_factory=list)

    @property
    def quota_used(self) -> float:
        return sum(p.quota for p in self.pods)

    @property
    def quota_free(self) -> float:
        return max(0.0, 1.0 - self.quota_used)


class VirtualGPU:
    """One physical chip under HAS scheduling."""

    def __init__(self, uuid: str, node: str = "node-0",
                 window_ms: float = DEFAULT_WINDOW_MS, index: int = 0,
                 gpu_type: GPUType = DEFAULT_GPU_TYPE):
        self.uuid = uuid
        self.node = node
        self.window_ms = window_ms
        self.index = index           # creation order within its cluster
        self.gpu_type = gpu_type
        self.partitions: List[Partition] = []
        self._pod_part: Dict[str, Partition] = {}  # pod_id -> partition
        # the owning Reconfigurator (if any) keeps cluster-wide indexes;
        # mutations made directly on the GPU notify it so those indexes
        # stay authoritative regardless of which API level is used
        self.owner = None
        # spot reclaim: kill time once a RECLAIM_NOTICE opened the grace
        # window (None = chip not under notice)
        self.reclaim_at: Optional[float] = None
        # observers called as listener(gpu, pod) after a pod is removed
        # (e.g. HASGPUScheduler releasing the pod's token-ledger state)
        self.remove_listeners: List = []

    @property
    def doomed(self) -> bool:
        """Whether this chip is inside a spot-reclaim grace window."""
        return self.reclaim_at is not None

    # ---- capacity queries -------------------------------------------------
    @property
    def sm_total(self) -> int:
        """Slice capacity of this chip (its type's granularity)."""
        return self.gpu_type.sm_total

    @property
    def slices_used(self) -> int:
        return sum(p.sm for p in self.partitions)

    @property
    def slices_free(self) -> int:
        return self.gpu_type.sm_total - self.slices_used

    @property
    def pods(self) -> List[PodAlloc]:
        return [pod for part in self.partitions for pod in part.pods]

    @property
    def hgo(self) -> float:
        """HAS GPU Occupancy: sum over pods of (sm/sm_total) * quota
        (paper L11), relative to this chip's own slice capacity."""
        return sum((pod.sm / self.gpu_type.sm_total) * pod.quota
                   for pod in self.pods)

    def partition_of(self, pod_id: str) -> Optional[Partition]:
        return self._pod_part.get(pod_id)

    def max_avail_quota_for(self, pod: PodAlloc) -> float:
        """Paper: RetriveMaxAvailQuotaForPod — headroom in its partition."""
        part = self.partition_of(pod.pod_id)
        if part is None:
            raise KeyError(pod.pod_id)
        return pod.quota + part.quota_free

    def max_avail_alloc(self) -> tuple:
        """Paper: RetriveMaxAvailQuotaAndSM — the largest (sm, quota) a new
        pod could get on this GPU under SM alignment."""
        best = (0, 0.0)
        if self.slices_free > 0:
            best = (self.slices_free, 1.0)
        for part in self.partitions:
            if part.quota_free > 1e-9:
                cand = (part.sm, part.quota_free)
                if cand[0] * cand[1] > best[0] * best[1]:
                    best = cand
        return best

    # ---- placement (SM-alignment enforced) --------------------------------
    def can_place(self, sm: int, quota: float) -> bool:
        if self.slices_free >= sm:
            return True
        return any(p.sm == sm and p.quota_free >= quota - 1e-9
                   for p in self.partitions)

    def place(self, pod: PodAlloc) -> Partition:
        """Place under SM alignment: join an existing same-size partition
        with quota headroom, else carve a new partition from free slices."""
        part = None
        for cand in self.partitions:
            if cand.sm == pod.sm and cand.quota_free >= pod.quota - 1e-9:
                cand.pods.append(pod)
                part = cand
                break
        if part is None and self.slices_free >= pod.sm:
            part = Partition(sm=pod.sm, pods=[pod])
            self.partitions.append(part)
        if part is None:
            raise RuntimeError(
                f"GPU {self.uuid} ({self.gpu_type.name}): cannot place "
                f"sm={pod.sm} q={pod.quota:.2f} "
                f"(free slices {self.slices_free})")
        pod.gpu_uuid = self.uuid
        pod.gpu_type = self.gpu_type
        self._pod_part[pod.pod_id] = part
        if self.owner is not None:
            self.owner._index_place(pod, self)
        return part

    def remove(self, pod_id: str) -> None:
        part = self._pod_part.pop(pod_id, None)
        pod = None
        if part is not None:
            pod = next((p for p in part.pods if p.pod_id == pod_id), None)
        for part in self.partitions:
            part.pods = [p for p in part.pods if p.pod_id != pod_id]
        self.partitions = [p for p in self.partitions if p.pods]
        if pod is not None:
            if self.owner is not None:
                self.owner._index_remove(pod, self)
            for listener in self.remove_listeners:
                listener(self, pod)

    # ---- vertical scaling (runtime quota reallocation, paper Fig 2) -------
    def set_quota(self, pod_id: str, quota: float) -> None:
        part = self.partition_of(pod_id)
        if part is None:
            raise KeyError(pod_id)
        pod = next(p for p in part.pods if p.pod_id == pod_id)
        others = part.quota_used - pod.quota
        if others + quota > 1.0 + 1e-9:
            raise ValueError(
                f"quota {quota:.2f} exceeds partition headroom "
                f"({1.0 - others:.2f})")
        if quota <= 0:
            raise ValueError("quota must be positive; use remove() to free")
        pod.quota = quota
        if self.owner is not None:
            self.owner._index_quota(pod)

    def invariant_ok(self) -> bool:
        """Conservation invariants (used by property tests)."""
        if self.slices_used > self.gpu_type.sm_total:
            return False
        return all(p.quota_used <= 1.0 + 1e-9 for p in self.partitions)
