"""Reference tick-scanned cluster simulator (pre-PR-1 engine, slimmed).

Scans a fixed ``tick_s`` clock over the whole trace: per tick it injects
arrivals, sheds aged requests, runs the autoscaler on schedule, and lets
idle pods pull batches. Kept as the semantic reference for the
discrete-event engine (``core/events.py``) — the parity test
(``tests/test_event_parity.py``) runs both on the same seeded trace and
pins conservation, completion counts, and latency/cost metrics together.
O(duration / tick_s) regardless of load, so use the event engine for
anything but short parity traces.
"""
from __future__ import annotations

from collections import deque
from typing import Dict

import numpy as np

from repro.core import perf_model
from repro.core.cost import CostMeter
from repro.core.perf_model import FnSpec
from repro.core.reconfigurator import Reconfigurator
from repro.core.metrics import baseline_batch_of
from repro.core.simulator import PodRuntime, SimConfig, SimResult
from repro.core.slo import Request, percentiles


class TickClusterSimulator:
    """Single-function simulator quantized to ``cfg.tick_s``."""

    def __init__(self, spec: FnSpec, policy, recon: Reconfigurator,
                 arrivals: np.ndarray, cfg: SimConfig = SimConfig()):
        self.spec = spec
        self.policy = policy
        self.recon = recon
        self.arrivals = arrivals
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.runtimes: Dict[str, PodRuntime] = {}
        self.queue: deque = deque()  # shared per-function FIFO (pull model)
        self.completed = []
        self.dropped = 0
        self.cost = CostMeter(whole_gpu=cfg.whole_gpu_cost)
        self.timeline: list = []

    # ---- execution ----------------------------------------------------------
    # Pull-based dispatch (OpenFaaS queue-worker semantics): idle ready pods
    # pull up to `batch` requests from the shared function queue; the
    # highest-capacity pods pull first (the gateway's throughput-weighted
    # distribution emerges from pull order + service rates).
    def _execute(self, now: float):
        pods = {p.pod_id: p for p in self.recon.pods_of(self.spec.fn_id)}
        for pid in list(self.runtimes):
            if pid not in pods:
                rt = self.runtimes.pop(pid)
                for r in rt.inflight:  # inflight on a removed pod completes
                    r.completion = rt.busy_until
                    self.completed.append(r)
        order = sorted(
            pods.values(),
            key=lambda p: -perf_model.throughput(self.spec, p.batch, p.sm,
                                                 p.quota))
        for pod in order:
            rt = self.runtimes.setdefault(pod.pod_id, PodRuntime(pod.pod_id))
            if rt.busy_until > now:
                continue
            if rt.inflight:
                for r in rt.inflight:
                    r.completion = rt.busy_until
                self.completed.extend(rt.inflight)
                rt.inflight = []
            if not self.queue or pod.ready_at > now:
                continue
            # batch formation: run when full or the head waited long enough
            if (len(self.queue) < pod.batch
                    and now - self.queue[0].arrival < self.cfg.batch_wait_s):
                continue
            take = min(pod.batch, len(self.queue))
            batch = [self.queue.popleft() for _ in range(take)]
            service = perf_model.latency(self.spec, take, pod.sm, pod.quota,
                                         window_ms=self.recon.window_ms,
                                         rng=self.rng)
            for r in batch:
                r.start = now
            rt.busy_until = now + service
            rt.inflight = batch

    # ---- main loop ------------------------------------------------------------
    def run(self) -> SimResult:
        cfg = self.cfg
        t, ai = 0.0, 0
        n = len(self.arrivals)
        last_scale = -1e9
        window_arrivals = deque()
        while t < cfg.duration_s or ai < n or self._work_left():
            if t > cfg.duration_s + cfg.drop_after_s:
                break
            # arrivals
            while ai < n and self.arrivals[ai] <= t:
                req = Request(self.spec.fn_id, float(self.arrivals[ai]))
                window_arrivals.append(req.arrival)
                self.queue.append(req)
                ai += 1
            # shed requests that aged out in queue
            while self.queue and t - self.queue[0].arrival > cfg.drop_after_s:
                self.queue.popleft()
                self.dropped += 1
            # autoscaler: observed load = arrival rate + backlog drain demand
            # (queued work is gateway-visible and must be scheduled too)
            if t - last_scale >= cfg.autoscale_interval_s:
                while window_arrivals and window_arrivals[0] < t - 5.0:
                    window_arrivals.popleft()
                observed = len(window_arrivals) / max(min(t, 5.0), 1e-9) \
                    if t > 0 else 0.0
                observed += len(self.queue) / 5.0
                self.policy.tick(t, self.spec, observed)
                last_scale = t
                self.timeline.append(
                    (t, observed, len(self.recon.pods_of(self.spec.fn_id)),
                     sum((p.sm / 8.0) * p.quota
                         for p in self.recon.pods_of(self.spec.fn_id))))
            # execution + cost
            self._execute(t)
            self.cost.accrue(self.recon, cfg.tick_s)
            t += cfg.tick_s

        # flush remaining inflight
        for rt in self.runtimes.values():
            for r in rt.inflight:
                r.completion = rt.busy_until
                self.completed.append(r)
        self.dropped += len(self.queue)

        lats = np.array([r.latency for r in self.completed
                         if r.latency is not None])
        base = perf_model.slo_baseline(self.spec,
                                       baseline_batch_of(self.policy))
        return SimResult(
            latencies=lats, n_arrived=n, n_completed=len(lats),
            n_dropped=self.dropped, cost_usd=self.cost.total_usd,
            cost_per_1k=self.cost.per_1k_requests(len(lats)),
            baseline_s=base, pcts=percentiles(lats),
            pod_seconds=self.cost.gpu_seconds, timeline=self.timeline)

    def _work_left(self) -> bool:
        if self.queue:
            return True
        return any(r.inflight for r in self.runtimes.values())
