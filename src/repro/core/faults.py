"""Fault injection + graceful degradation (the chaos layer).

The event engine (``core/events.py``) can run under a composable
``FaultModel`` describing four discrete fault processes, each drawn
from its OWN dedicated seeded rng stream (the spot-reclaim template:
service noise and reclaim draws are untouched, so fault-free runs stay
bitwise identical to every legacy golden trace):

  * **chip hard-failure** — a live chip dies instantly (no grace
    window, unlike a spot ``RECLAIM_NOTICE``): in-flight batches are
    killed on the spot and the chip leaves through the same
    ``remove_gpu`` plumbing a reclaim kill uses;
  * **transient straggler** — a pod's service times inflate by
    ``straggler_factor`` for ``straggler_duration_s`` (a noisy
    neighbor, thermal throttle, or failing HBM stack);
  * **host-cache loss** — one node's host-RAM weight cache drops
    (``ModelStateTracker.drop_node_cache``): every model cached there
    demotes to COLD, so the next start on that node pays the full
    object-store fetch;
  * **control-plane blackout** — autoscale timers fire but the policy
    is unreachable for ``blackout_duration_s``: no scaling decisions,
    no replacement capacity, while dispatch keeps serving.

The resilience half (``ResilienceConfig``) is the degradation
machinery a production gateway pairs with that chaos:

  * **deadlines + bounded retries** — every request carries an implicit
    deadline (``arrival + deadline_s``); a batch killed mid-flight is
    requeued at the queue head only while its requests have retry
    budget left AND can still meet their deadlines (generalizing the
    boolean ``SimConfig.reclaim_requeue`` into a first-class retry
    policy with backoff-aware requeue accounting);
  * **health scoring + quarantine** — a per-pod EWMA of observed vs
    ``CapacityTable``-predicted service time (``HealthTracker``); a pod
    whose ratio exceeds ``quarantine_ratio`` is quarantined: excluded
    from dispatch and ``Gateway.route`` exactly like a doomed chip,
    and written off by the capacity model so the next autoscale tick
    replaces it;
  * **SLO-aware admission control** — when the queue is already deeper
    than the function can drain inside the deadline headroom, new
    arrivals are brownout-shed AT ARRIVAL (an explicit fast failure)
    instead of aging out in queue after burning their latency budget.

Both configs are inert by default: a zero-rate ``FaultModel`` and the
default ``ResilienceConfig`` leave the engine byte-identical to a run
with neither attached.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

# Dedicated rng-stream salts, one per fault process (spawned as
# ``default_rng([seed, SALT])`` like the reclaim stream's 0x5EC1A13):
# the processes stay decorrelated from each other, from service noise,
# and from reclaim draws, so enabling one fault kind never perturbs
# another kind's schedule.
CHIP_FAIL_STREAM = 0xFA170C1
STRAGGLER_STREAM = 0xFA170C2
CACHE_LOSS_STREAM = 0xFA170C3
BLACKOUT_STREAM = 0xFA170C4


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Rates and shapes of the four injectable fault processes.

    All rates are Poisson hazards in events/hour — per live chip
    (``chip_failure_rate_per_hour``), per live pod
    (``straggler_rate_per_hour``), per live node
    (``cache_loss_rate_per_hour``), or cluster-global
    (``blackout_rate_per_hour``). A model with every rate at zero is
    inert (``is_active`` False) and the engine skips the chaos paths
    entirely — byte-identical to running with no model at all.
    """
    chip_failure_rate_per_hour: float = 0.0
    straggler_rate_per_hour: float = 0.0
    straggler_factor: float = 4.0      # service-time inflation while slow
    straggler_duration_s: float = 10.0
    cache_loss_rate_per_hour: float = 0.0
    blackout_rate_per_hour: float = 0.0
    blackout_duration_s: float = 5.0

    def __post_init__(self):
        for f in ("chip_failure_rate_per_hour", "straggler_rate_per_hour",
                  "cache_loss_rate_per_hour", "blackout_rate_per_hour"):
            if getattr(self, f) < 0:
                raise ValueError(f"{f} must be >= 0")
        if self.straggler_factor < 1.0:
            raise ValueError("straggler_factor must be >= 1 (an inflation)")
        if self.straggler_duration_s <= 0 or self.blackout_duration_s <= 0:
            raise ValueError("fault window durations must be > 0")

    @property
    def is_active(self) -> bool:
        """Whether any fault process has a non-zero rate."""
        return (self.chip_failure_rate_per_hour > 0
                or self.straggler_rate_per_hour > 0
                or self.cache_loss_rate_per_hour > 0
                or self.blackout_rate_per_hour > 0)


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Degradation machinery knobs; every mechanism is off by default.

    ``deadline_s`` gives each request an implicit deadline at
    ``arrival + deadline_s``: queued requests past it age out, and a
    killed batch's requests are only retried while they can still make
    it (after ``retry_backoff_s``). ``max_retries`` bounds how many
    times one request may be requeued after kills. A positive
    ``quarantine_ratio`` arms per-pod health scoring; a positive
    ``admission_headroom`` (with a deadline) arms brownout shedding —
    a new arrival is rejected when the queue already needs more than
    ``deadline_s * admission_headroom`` to drain at current capacity.
    """
    deadline_s: float = 0.0            # 0 = no per-request deadline
    max_retries: int = 1               # requeue budget per request
    retry_backoff_s: float = 0.0       # delay before a requeue re-enters
    health_alpha: float = 0.35         # EWMA weight of the newest sample
    quarantine_ratio: float = 0.0      # observed/predicted trip level; 0=off
    quarantine_min_samples: int = 3    # batches before the EWMA is trusted
    quarantine_duration_s: float = 15.0
    admission_headroom: float = 0.0    # deadline fraction the queue may hold

    def __post_init__(self):
        if self.deadline_s < 0 or self.retry_backoff_s < 0:
            raise ValueError("deadline_s / retry_backoff_s must be >= 0")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if not (0.0 < self.health_alpha <= 1.0):
            raise ValueError("health_alpha must be in (0, 1]")
        if self.quarantine_ratio < 0 or self.admission_headroom < 0:
            raise ValueError("ratios must be >= 0")
        if self.quarantine_min_samples < 1:
            raise ValueError("quarantine_min_samples must be >= 1")
        if self.quarantine_duration_s <= 0:
            raise ValueError("quarantine_duration_s must be > 0")

    @property
    def quarantine_active(self) -> bool:
        """Whether health scoring + quarantine is armed."""
        return self.quarantine_ratio > 0

    @property
    def admission_active(self) -> bool:
        """Whether brownout admission control is armed (needs a
        deadline to measure headroom against)."""
        return self.admission_headroom > 0 and self.deadline_s > 0

    @property
    def is_active(self) -> bool:
        """Whether any resilience mechanism is armed."""
        return (self.deadline_s > 0 or self.quarantine_active
                or self.admission_headroom > 0)


class HealthTracker:
    """Per-pod EWMA of observed vs predicted service time.

    Fed one sample per dispatched batch (the ratio of the drawn service
    time — noise and any straggler inflation included — to the
    ``CapacityTable`` deterministic prediction). With service noise at
    sigma 0.03 a healthy pod's EWMA hovers at ~1.0; a straggler
    inflating by 3-4x trips any ratio above ~1.5 within
    ``quarantine_min_samples`` batches.
    """

    def __init__(self, cfg: ResilienceConfig):
        """Args: cfg: the run's resilience knobs (alpha/ratio/samples)."""
        self.cfg = cfg
        self._ewma: Dict[str, Tuple[float, int]] = {}  # pod -> (value, n)

    def observe(self, pod_id: str, ratio: float) -> bool:
        """Fold one observed/predicted sample in; True when the pod's
        smoothed ratio now exceeds the quarantine trip level (with at
        least ``quarantine_min_samples`` samples behind it)."""
        a = self.cfg.health_alpha
        v, n = self._ewma.get(pod_id, (1.0, 0))
        v = (1.0 - a) * v + a * ratio
        n += 1
        self._ewma[pod_id] = (v, n)
        return (n >= self.cfg.quarantine_min_samples
                and v > self.cfg.quarantine_ratio)

    def reset(self, pod_id: str) -> None:
        """Forget ``pod_id``'s history (on quarantine entry, so a lifted
        pod starts with a clean score instead of instantly re-tripping)."""
        self._ewma.pop(pod_id, None)

    def score(self, pod_id: str) -> float:
        """The pod's current smoothed observed/predicted ratio."""
        return self._ewma.get(pod_id, (1.0, 0))[0]


class FaultInjector:
    """Owns the four dedicated rng streams and the draw bookkeeping.

    The engine asks for the next event time of each process (in chip /
    pod / node creation order, so schedules are deterministic for a
    given seed and decision history) and schedules the heap events
    itself; ``chip_drawn`` / ``pod_drawn`` / ``node_drawn`` record which
    entities already have a pending draw, mirroring the reclaim path's
    ``_reclaim_scheduled``. Blackout windows are precomputed over the
    whole horizon at construction (the process is cluster-global, so
    nothing about the run can influence it).
    """

    def __init__(self, model: FaultModel, seed: int, horizon_s: float):
        """Args:
            model: the fault processes to drive.
            seed: the run's ``SimConfig.seed`` (streams decorrelate via
                per-process salts).
            horizon_s: draws beyond this are never scheduled.
        """
        self.model = model
        self.horizon_s = float(horizon_s)
        self._chip_rng = np.random.default_rng([seed, CHIP_FAIL_STREAM])
        self._strag_rng = np.random.default_rng([seed, STRAGGLER_STREAM])
        self._cache_rng = np.random.default_rng([seed, CACHE_LOSS_STREAM])
        self._black_rng = np.random.default_rng([seed, BLACKOUT_STREAM])
        self.chip_drawn: set = set()
        self.pod_drawn: set = set()
        self.node_drawn: set = set()
        self.blackouts: List[Tuple[float, float]] = self._draw_blackouts()

    @staticmethod
    def _exp_after(rng: np.random.Generator, rate_per_hour: float,
                   t: float) -> float:
        return t + float(rng.exponential(3600.0 / rate_per_hour))

    def draw_chip_failure(self, t: float) -> float:
        """Next hard-failure time of a chip first seen live at ``t``."""
        return self._exp_after(self._chip_rng,
                               self.model.chip_failure_rate_per_hour, t)

    def draw_straggler(self, t: float) -> float:
        """Next straggler-window start for a pod, drawn from ``t``
        (first sight or the end of its previous window)."""
        return self._exp_after(self._strag_rng,
                               self.model.straggler_rate_per_hour, t)

    def draw_cache_loss(self, t: float) -> float:
        """Next host-cache-loss time for a node, drawn from ``t``."""
        return self._exp_after(self._cache_rng,
                               self.model.cache_loss_rate_per_hour, t)

    def _draw_blackouts(self) -> List[Tuple[float, float]]:
        m = self.model
        if m.blackout_rate_per_hour <= 0:
            return []
        out, t = [], 0.0
        while True:
            t = self._exp_after(self._black_rng, m.blackout_rate_per_hour, t)
            if t > self.horizon_s:
                return out
            out.append((t, t + m.blackout_duration_s))
            t += m.blackout_duration_s   # windows never overlap

    def in_blackout(self, t: float) -> bool:
        """Whether the control plane is blacked out at ``t``."""
        for a, b in self.blackouts:
            if t < a:
                return False
            if t < b:
                return True
        return False
