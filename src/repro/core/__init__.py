"""HAS-GPU core: the paper's contribution.

vGPU spatio-temporal allocation, GPU Re-configurator, Kalman workload
prediction, hybrid auto-scaling (Algorithm 1), RaPP performance
prediction, baseline policies, and the cluster simulator.
"""
from repro.configs.gpus import (DEFAULT_GPU_TYPE, GPU_TYPES, GPUType,
                                get_gpu_type)
from repro.core.autoscaler import (AutoScalerConfig, HybridAutoScaler,
                                   ScalingAction)
from repro.core.baselines import (FaSTGShareLikeConfig, FaSTGShareLikePolicy,
                                  KServeLikeConfig, KServeLikePolicy)
from repro.core.capacity import CapacityTable, shared_table
from repro.core.faults import (FaultInjector, FaultModel, HealthTracker,
                               ResilienceConfig)
from repro.core.kalman import KalmanPredictor, LastValuePredictor
from repro.core.metrics import RunMetrics, baseline_batch_of
from repro.core.modelstate import (ColdStartModel, LifecycleConfig,
                                   ModelStateTracker, NodeWeightCache,
                                   WeightState)
from repro.core.perf_model import (FnSpec, cost_rate, exec_time, latency,
                                   most_efficient_config, slo_baseline,
                                   throughput)
from repro.core.events import EventEngine, FunctionState
from repro.core.reconfigurator import Reconfigurator
from repro.core.scheduler import FleetPlacer
from repro.core.simulator import ClusterSimulator, SimConfig, SimResult
from repro.core.simulator_tick import TickClusterSimulator
from repro.core.vgpu import (DEFAULT_WINDOW_MS, TOTAL_SLICES, Partition,
                             PodAlloc, VirtualGPU)

__all__ = [
    "AutoScalerConfig", "HybridAutoScaler", "ScalingAction",
    "FaSTGShareLikeConfig", "FaSTGShareLikePolicy",
    "KServeLikeConfig", "KServeLikePolicy",
    "CapacityTable", "shared_table",
    "FaultInjector", "FaultModel", "HealthTracker", "ResilienceConfig",
    "KalmanPredictor", "LastValuePredictor",
    "RunMetrics", "baseline_batch_of",
    "FnSpec", "cost_rate", "exec_time", "latency", "most_efficient_config",
    "slo_baseline", "throughput",
    "Reconfigurator", "ClusterSimulator", "SimConfig", "SimResult",
    "EventEngine", "FunctionState", "TickClusterSimulator",
    "DEFAULT_WINDOW_MS", "TOTAL_SLICES", "Partition", "PodAlloc",
    "VirtualGPU",
    "GPUType", "GPU_TYPES", "DEFAULT_GPU_TYPE", "get_gpu_type",
    "FleetPlacer",
    "ColdStartModel", "LifecycleConfig", "ModelStateTracker",
    "NodeWeightCache", "WeightState",
]
