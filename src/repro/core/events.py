"""Discrete-event engine for the cluster simulators.

One `heapq` event queue drives N co-located functions against a shared
Reconfigurator: request arrivals, batch-timeout wakeups, pod-free
(service completion) wakeups, pod-ready (cold-start completion) wakeups,
and per-function autoscale timers. `ClusterSimulator` (N=1) and
`MultiFunctionSimulator` (N>1) are thin wrappers over this engine.

Semantics are those of the reference tick engine
(`core/simulator_tick.py`), continuous in time instead of quantized to a
20 ms tick:

  * pull-based dispatch — idle ready pods pull up to `batch` requests
    from their function's FIFO, highest-throughput pods first;
  * batch formation — a pod runs when the queue can fill its batch or
    the head request has waited `batch_wait_s`;
  * drop-after-aging — queued requests older than `drop_after_s` are
    shed (and count as violations);
  * autoscaling — every `autoscale_interval_s` the policy sees the 5 s
    observed arrival rate plus backlog drain demand;
  * cost — integrated exactly between events; the $/s rate only changes
    when a policy mutates the cluster, so it is re-sampled after each
    autoscale event rather than every tick;
  * spot reclaims — chips of a ``GPUType`` carrying a ``GPUMarket``
    (configs/gpus.py) draw reclaim times from the market's hazard
    process on a DEDICATED rng stream (service noise is untouched, so
    reclaim-free runs are bitwise identical to pre-spot traces). A
    `RECLAIM_NOTICE` opens the grace window: every pod on the chip is
    marked doomed (drains — finishes in-flight batches, takes no new
    ones, contributes zero capacity, so the very next autoscale tick
    replaces it). `RECLAIM_KILL` then removes the chip: finished
    batches deliver, still-running batches are requeued at the head of
    the function queue (or dropped, per ``SimConfig.reclaim_requeue``),
    and with a lifecycle tracker attached the weights demote to the
    node's host cache (``modelstate.on_pod_removed``);
  * faults + resilience — a ``SimConfig.faults`` (``core/faults.py``)
    schedules chip hard-failures, transient stragglers, host-cache
    losses, and control-plane blackouts from dedicated rng streams;
    a ``SimConfig.resilience`` arms per-request deadlines with a
    bounded retry budget, EWMA health scoring that quarantines
    stragglers out of dispatch like doomed chips, and brownout
    admission control that sheds un-serveable arrivals explicitly.
    Both are inert by default — fault-free runs stay bitwise identical
    to every legacy trace.

Invariant: between two consecutive autoscale events of a function, its
pod set and every pod's (sm, quota) are immutable — policies are the
only mutators and they run inside autoscale events, EXCEPT for spot
reclaim events, which re-sample the caches they invalidate (pod order,
cost/fragmentation rates) themselves. The engine exploits this by
caching each function's throughput-sorted pod order, per-config
service times (deterministic part; noise is drawn per batch), and the
cluster cost rate.

The wide engine (PR 9). ``EventEngine`` is organized for fleet-width
runs (thousands of co-located functions, tens of millions of requests —
the Azure-replay regime of ``azure_wide``) while staying byte-identical
to the frozen scalar reference (``core/engine_scalar.py``) on every
legacy trace:

  * struct-of-arrays arrival stream — all functions' arrival times are
    merged into parallel sorted numpy arrays (time, function slot,
    within-function position) walked by one cursor, instead of one heap
    push + pop per request;
  * batched autoscale sweeps — every function ticks on the same
    ``autoscale_interval_s`` grid, so all same-timestamp autoscale
    events collapse into ONE sweep over a per-slot active mask, and the
    cluster-wide cost/fragmentation rates are re-sampled once per sweep
    (each intermediate value the scalar engine computed between
    same-timestamp ticks integrates over dt = 0, so only the post-sweep
    rate is observable — bitwise the same integrals);
  * the heap is reserved for genuinely irregular events: dispatch
    wakeups (batch completions, cold-start readiness, batch timeouts),
    spot reclaims, and the fault layer;
  * O(1) peak-GPU tracking via the Reconfigurator's incremental
    ``n_used_gpus`` counter instead of an O(cluster) scan per tick;
  * optional constant-memory metrics (``SimConfig.stream_metrics``):
    completions fold into a streaming accumulator
    (``core/metrics.py::RunStreamStats``) at delivery instead of being
    retained as ``Request`` objects — exact below the accumulator's
    exact-mode limit, a bounded-relative-error log-binned quantile
    sketch beyond it;
  * optional per-function service-noise streams
    (``SimConfig.rng_isolation``): each function draws its lognormal
    service noise from its own dedicated rng, so one function's fate
    (faults, reclaims, bursts) cannot perturb another's trace through
    shared-stream interleaving.

Both knobs default off, and the sweep/merged-stream machinery is
value-preserving, so legacy runs remain bitwise identical to
pre-wide-engine traces (pinned by ``tests/test_goldens.py`` and fuzzed
by ``tests/test_engine_parity.py``).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from time import perf_counter
from typing import Dict, List, Optional

import numpy as np

from repro.core import capacity as capacity_mod
from repro.core import perf_model
from repro.core.cost import CostMeter
from repro.core.faults import (FaultInjector, FaultModel, HealthTracker,
                               ResilienceConfig)
from repro.core.perf_model import FnSpec
from repro.core.reconfigurator import Reconfigurator
from repro.core.slo import Request

# Event kinds double as same-timestamp priorities, mirroring the tick
# engine's per-tick order: arrivals, then reclaim notices (so a policy
# ticking at the same instant already sees the doomed capacity), then
# autoscale, then kills, then execution. Only the RELATIVE order of
# ARRIVAL < AUTOSCALE < DISPATCH matters for legacy traces.
ARRIVAL, RECLAIM_NOTICE, AUTOSCALE, RECLAIM_KILL, DISPATCH = 0, 1, 2, 3, 4
# Fault-layer kinds (core/faults.py) sort AFTER every legacy kind at an
# identical timestamp, so arming the chaos layer cannot perturb the
# relative order of any legacy event pair: chip hard-failures, pod
# faults (straggler windows / host-cache losses), backoff-delayed
# retry requeues, and quarantine lifts.
CHIP_FAIL, POD_FAULT, RETRY, QUAR_LIFT = 5, 6, 7, 8

OBS_WINDOW_S = 5.0  # observed-rate sliding window (paper: short horizon)


@dataclasses.dataclass
class SimConfig:
    """Simulation-run knobs shared by the event and tick engines:
    horizon (``duration_s``), autoscale cadence, RNG ``seed``,
    whole-GPU vs fine-grained billing, batch-formation wait, and the
    drop-after aging bound. Invariant: a config is immutable for the
    lifetime of one simulator run."""
    tick_s: float = 0.02         # used by the tick reference engine only
    autoscale_interval_s: float = 1.0
    duration_s: float = 300.0
    seed: int = 0
    whole_gpu_cost: bool = False
    batch_wait_s: float = 0.01   # max wait to fill a batch
    drop_after_s: float = 60.0   # requests older than this count as violations
    # spot reclaims: requeue a killed batch's in-flight requests at the
    # queue head (latency keeps accruing from the original arrival) —
    # False drops them instead (counted as violations)
    reclaim_requeue: bool = True
    # chaos layer (core/faults.py): fault processes to inject and the
    # degradation machinery to run them against. Both default to None
    # (and an inert FaultModel/ResilienceConfig is equivalent to None):
    # fault-free runs are bitwise identical to legacy traces
    faults: Optional[FaultModel] = None
    resilience: Optional[ResilienceConfig] = None
    # ---- wide-engine knobs (PR 9) ----
    # stream completions into the constant-memory metrics accumulator
    # (core/metrics.py::RunStreamStats) at delivery instead of
    # retaining Request objects per function — the azure_wide-scale
    # replay path. SLO-violation counting needs the multipliers at fold
    # time; None falls back to metrics.DEFAULT_MULTIPLIERS
    stream_metrics: bool = False
    stream_slo_multipliers: Optional[tuple] = None
    # draw each function's service noise from its own dedicated rng
    # stream (seeded [seed, salt, slot]) instead of the shared one, so
    # per-function traces are independent of co-tenant scheduling.
    # Both knobs default off: legacy runs stay bitwise identical
    rng_isolation: bool = False
    # ---- batched-sweep knobs (PR 10) ----
    # vectorize the per-sweep policy path (batched shed/observe, one
    # BatchedKalman update for the fleet, array band classification —
    # see core/autoscaler.py::SweepDecider); slots the decider can't
    # prove fast-path-safe, and every slot when False, take the legacy
    # per-function tick() loop. Byte-identical either way
    batched_policy: bool = True
    # retain the per-function (t, observed, pods, quota) autoscale
    # timeline; off for replay-scale runs where nothing reads it
    # (RunMetrics never does) and the per-sweep appends dominate memory
    record_timeline: bool = True


@dataclasses.dataclass
class PodRuntime:
    """Execution-side state of one pod: when its current batch finishes
    (``busy_until``), the in-flight requests (delivered lazily at the
    pod's next pull), and whether a cold-start wakeup is already
    queued. Created on first dispatch, dropped when the pod is
    removed."""
    pod_id: str
    busy_until: float = 0.0
    inflight: List[Request] = dataclasses.field(default_factory=list)
    wake_scheduled: bool = False  # cold-start wakeup already queued


@dataclasses.dataclass
class FunctionState:
    """Per-function simulation state threaded through the event engine."""
    spec: FnSpec
    policy: object
    arrivals: np.ndarray
    queue: deque = dataclasses.field(default_factory=deque)
    runtimes: Dict[str, PodRuntime] = dataclasses.field(default_factory=dict)
    completed: List[Request] = dataclasses.field(default_factory=list)
    timeline: list = dataclasses.field(default_factory=list)
    dropped: int = 0
    cold_starts: int = 0
    # per-kind scaling mutations observed at autoscale events (policy-
    # agnostic: derived by diffing the pod set, not from tick() returns)
    action_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"vup": 0, "vdown": 0, "hup": 0, "hdown": 0})
    # model-state lifecycle classification of pod starts (cold = weights
    # fetched from the object store, warm = host-cached / in-flight
    # prefetch, hot = GPU-resident incl. keep-warm reactivations);
    # only populated when a lifecycle tracker stamps pod.start_kind
    start_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"cold": 0, "warm": 0, "hot": 0})
    # drop causes (surfaced in RunMetrics only when the fault layer is
    # active): "aged" = timed out in queue (drop_after / deadline,
    # incl. end-of-run flush), "shed" = brownout admission rejection at
    # arrival, "killed" = lost mid-flight to a kill with no retry left
    drop_kinds: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"aged": 0, "shed": 0, "killed": 0})
    # predicted serving capacity (RPS) of the current non-excluded pod
    # set — refreshed with pod_order, read by admission control
    est_capacity: float = 0.0
    next_arrival: int = 0
    timeout_at: float = -np.inf   # latest batch-timeout wakeup scheduled
    pod_order: List = dataclasses.field(default_factory=list)
    # True unless the last full pod scan proved every pod busy/cold-starting
    # (then arrivals can be enqueued without rescanning)
    maybe_idle: bool = True
    fid: str = ""
    # wide-engine slot index (position in the engine's function list —
    # the index the struct-of-arrays state is keyed by)
    slot: int = -1
    # completions folded into the streaming accumulator instead of
    # retained in ``completed`` (stream_metrics runs only)
    stream_n_completed: int = 0

    def __post_init__(self):
        self.arrivals = np.asarray(self.arrivals, dtype=float)
        self.fid = self.spec.fn_id
        self._arr = self.arrivals.tolist()  # plain floats for the hot loop
        # per-function dispatch-throughput memo (bounded: see
        # EventEngine._thpt) and lazily computed SLO baseline
        self._thpt_cache: Dict[tuple, float] = {}
        self._slo_base: Optional[float] = None
        self._svc_rng = None   # set by the engine (shared or per-slot)
        # memoized (len(pod_order), quota-share sum) for timeline rows
        # of fast-path no-op ticks; invalidated with pod_order
        self._tl_cache: Optional[tuple] = None

    @property
    def fn_id(self) -> str:
        """The function's id (``FnSpec.fn_id``), the engine's key."""
        return self.fid

    def observed_in_window(self, t: float) -> int:
        """Arrivals in [t - OBS_WINDOW_S, t] — the sliding-window count
        the tick engine kept in a deque, read off the sorted trace."""
        lo = np.searchsorted(self.arrivals, t - OBS_WINDOW_S, side="left")
        hi = np.searchsorted(self.arrivals, t, side="right")
        return int(hi - lo)

    def work_left(self, now: float) -> bool:
        """Whether this function still has pending work at ``now`` —
        queued requests, uninjected arrivals, or batches still running
        (used to decide if autoscale timers must keep firing past the
        nominal horizon)."""
        if self.queue or self.next_arrival < len(self._arr):
            return True
        # a finished-but-undelivered batch (busy_until <= now, delivery is
        # lazy) is not pending work — only still-running batches count
        return any(rt.inflight and rt.busy_until > now
                   for rt in self.runtimes.values())


def window_counts(m_t: np.ndarray, m_slot: np.ndarray, t: float,
                  n_slots: int) -> np.ndarray:
    """Per-slot arrival counts in ``[t - OBS_WINDOW_S, t]`` off the
    merged sorted arrival arrays — one vectorized searchsorted pass
    over the whole fleet replacing per-function ``observed_in_window``
    calls. Exactly equal, slot by slot, to
    ``FunctionState.observed_in_window(t)``: the stable merge preserves
    each function's sorted subsequence, so the two searchsorted bounds
    select the same multiset of arrivals per slot."""
    lo = np.searchsorted(m_t, t - OBS_WINDOW_S, side="left")
    hi = np.searchsorted(m_t, t, side="right")
    return np.bincount(m_slot[lo:hi], minlength=n_slots)


# per-function dispatch-throughput memo cap: vertical scaling
# accumulates off-grid quota floats, so an unbounded memo grows one
# entry per (batch, sm, quota, device) EVER seen — across a long wide
# run that is effectively unbounded. The memo clears when full (it is a
# pure cache: values are recomputed identically on the next miss).
_THPT_CACHE_MAX = 1024

# seed salt for the per-function service-noise streams (rng_isolation)
_SVC_STREAM_SALT = 0x15A7A7E5


class EventEngine:
    """Shared discrete-event core for single- and multi-function runs —
    the wide engine (see the module docstring for the struct-of-arrays
    layout and what stays on the heap)."""

    def __init__(self, recon: Reconfigurator, cfg: SimConfig,
                 fns: List[FunctionState], cost: Optional[CostMeter] = None,
                 rng: Optional[np.random.Generator] = None,
                 track_peak: bool = False):
        self.recon = recon
        self.cfg = cfg
        self.fns: Dict[str, FunctionState] = {st.fid: st for st in fns}
        # function-slot assignment: the order policies were seeded in is
        # the order the scalar engine's per-function timer chains fired
        # in, so the sweep iterates the same order
        self.fn_list: List[FunctionState] = list(fns)
        for i, st in enumerate(self.fn_list):
            st.slot = i
        self.cost = cost or CostMeter(whole_gpu=cfg.whole_gpu_cost)
        # an active model-state lifecycle dictates the keep-warm idle-
        # retention billing rate; adopt it so every construction path
        # (not just the scenario engine) bills standby pods consistently
        tracker = getattr(recon, "modelstate", None)
        if tracker is not None and not tracker.is_passive:
            self.cost.idle_retention_factor = \
                tracker.cfg.idle_retention_factor
        self.rng = rng or np.random.default_rng(cfg.seed)
        # service-noise streams: shared legacy stream by default;
        # dedicated per-slot streams under rng_isolation (the wide
        # isolation property tests rely on this)
        for st in self.fn_list:
            st._svc_rng = (np.random.default_rng(
                [cfg.seed, _SVC_STREAM_SALT, st.slot])
                if cfg.rng_isolation else self.rng)
        self.track_peak = track_peak
        self.peak_gpus = 0
        self.now = 0.0
        self.n_events = 0   # processed events (bench_engine events/s)
        # sweep-phase instrumentation (bench_engine sweeps/s) and the
        # count of per-function ticks served by the batched fast path
        self.sweep_seconds = 0.0
        self.n_sweeps = 0
        self.fast_ticks = 0
        # batched decide state, built in run() when cfg.batched_policy:
        # the SweepDecider (core/autoscaler.py) plus the merged sorted
        # arrival arrays retained in numpy form for window_counts
        self._decider = None
        self._m_t: Optional[np.ndarray] = None
        self._m_slot: Optional[np.ndarray] = None
        self._heap: list = []
        self._seq = itertools.count()
        # constant-memory metrics sink (stream_metrics runs only);
        # lazily imported — metrics.py is a consumer of this module's
        # engines, not a dependency of the hot path
        self._sink = None
        if cfg.stream_metrics:
            from repro.core.metrics import (DEFAULT_MULTIPLIERS,
                                            RunStreamStats)
            self._sink = RunStreamStats(
                cfg.stream_slo_multipliers or DEFAULT_MULTIPLIERS)
        # functions whose trace a reclaim/fault event actually touched
        # (chips shared with an affected pod count): the rng-isolation
        # tests assert untouched functions are unperturbed
        self.touched_fns: set = set()
        # service times read the shared oracle lattice tables — pod
        # configs straight off the control plane's grid are a lattice
        # hit; off-grid quotas (accumulated vertical steps) take the
        # table's exact scalar fallback. Dispatch-order throughput uses
        # the default-window table (the ordering metric has always been
        # window-independent of the cluster's window_ms).
        self._svc_table = capacity_mod.shared_table(
            window_ms=recon.window_ms)
        self._ord_table = capacity_mod.shared_table()
        self._cost_rates = self.cost.rates(recon)
        # spatial fragmentation is integrated over time exactly like
        # cost: the value only changes when a policy mutates the
        # cluster, so it is re-sampled at autoscale events
        self._frag_rate = recon.fragmentation()
        self.frag_integral = 0.0
        # ---- spot reclaims ----
        # active only when the fleet declares a reclaiming market; the
        # reclaim stream is SEPARATE from the service-noise rng so
        # reclaim-free runs stay bitwise identical to legacy traces
        self._has_spot = any(
            t.market is not None and t.market.reclaim_rate_per_hour > 0
            for t, _ in getattr(recon, "fleet", ()))
        self._reclaim_rng = np.random.default_rng([cfg.seed, 0x5EC1A13])
        self._reclaim_scheduled: set = set()   # chip uuids with a draw
        if self._has_spot:
            # prune the draw bookkeeping as chips leave the cluster
            # (policy release, reclaim kill, or hard failure): uuids
            # are never reused, so a dropped chip's entry can never be
            # consulted again — without this the set grows without
            # bound across a long spot replay
            recon.drop_listeners.append(
                lambda g: self._reclaim_scheduled.discard(g.uuid))
        self.preempt: Dict[str, int] = {
            "reclaims": 0, "drained_batches": 0, "killed_batches": 0,
            "requeued_requests": 0, "dropped_in_flight": 0}
        # ---- fault injection + resilience (core/faults.py) ----
        # all inert (and cost-free on the hot path) unless armed: the
        # injector draws from its own dedicated streams and the
        # resilience machinery only changes gated code paths, so
        # fault-free runs stay bitwise identical to legacy traces
        fm = cfg.faults
        horizon = cfg.duration_s + cfg.drop_after_s
        self._injector = (FaultInjector(fm, cfg.seed, horizon)
                          if fm is not None and fm.is_active else None)
        res = cfg.resilience
        self._res = res if res is not None and res.is_active else None
        self._health = (HealthTracker(res)
                        if self._res is not None and res.quarantine_active
                        else None)
        self._admit = self._res is not None and res.admission_active
        self._admit_wait = (res.deadline_s * res.admission_headroom
                            if self._admit else 0.0)
        self._slow: Dict[str, tuple] = {}   # pod_id -> (until, factor)
        self.fault_counts: Dict[str, int] = {
            "chip_failures": 0, "stragglers": 0, "cache_losses": 0,
            "blackouts": 0, "quarantines": 0}
        if self._injector is not None:
            self.fault_counts["blackouts"] = len(self._injector.blackouts)
        self.retries = 0                    # requeues granted by the policy
        # open capacity outages [fn_id, t_open, target ready-pod count]
        # opened by chip failures, closed when the replacement capacity
        # is READY again (checked at autoscale ticks); downtime is
        # integrated between events exactly like cost/fragmentation
        self._outages: List[list] = []
        self._down_rate = 0.0
        self.downtime = 0.0
        self.mttr_samples: List[float] = []

    @property
    def fault_layer_active(self) -> bool:
        """Whether this run carries an armed fault model or resilience
        config — the gate for the fault fields in ``RunMetrics``."""
        return self._injector is not None or self._res is not None

    @property
    def stream_stats(self):
        """The run's constant-memory metrics accumulator
        (``core/metrics.py::RunStreamStats``), or None for legacy
        retain-everything runs — ``RunMetrics.from_sim`` switches on
        this."""
        return self._sink

    def availability(self) -> float:
        """1 minus the fraction of the integrated horizon during which
        at least one function had a capacity outage open (a chip
        hard-failure not yet made whole by READY replacement pods)."""
        horizon = getattr(self, "_integrated_to", 0.0)
        if horizon <= 0:
            return 1.0
        return max(0.0, 1.0 - self.downtime / horizon)

    # ---- event queue -------------------------------------------------------
    def _push(self, t: float, kind: int, st) -> None:
        # payload is the FunctionState for function events, the chip
        # uuid (str) for reclaim events; seq keeps tuples comparable
        heapq.heappush(self._heap, (t, kind, next(self._seq), st))

    # ---- helpers -----------------------------------------------------------
    def _thpt(self, st: FunctionState, pod) -> float:
        """Dispatch-ordering throughput of one pod on its host device,
        memoized per function keyed (batch, sm, quota, device type) and
        bounded at ``_THPT_CACHE_MAX`` entries (cleared when full): the
        engine-level unbounded memo grew one entry per config ever seen
        across the whole run, which at fleet width was a leak."""
        t = pod.gpu_type
        key = (pod.batch, pod.sm, pod.quota,
               t.name if t is not None else None)
        cache = st._thpt_cache
        v = cache.get(key)
        if v is None:
            if len(cache) >= _THPT_CACHE_MAX:
                cache.clear()
            v = self._ord_table.throughput(st.spec, pod.batch, pod.sm,
                                           pod.quota, gpu=t)
            cache[key] = v
        return v

    def _service(self, st: FunctionState, batch: int, pod) -> tuple:
        """One batch's service time as ``(predicted, drawn)``: the
        deterministic wall-clock from the shared lattice table (on the
        pod's host device type), and that times a fresh lognormal noise
        draw (from the function's own stream under ``rng_isolation``,
        the shared legacy stream otherwise). The predicted half is the
        health tracker's baseline."""
        det = self._svc_table.lat(st.spec, batch, pod.sm, pod.quota,
                                  pod.gpu_type)
        return det, det * float(st._svc_rng.lognormal(
            mean=0.0, sigma=perf_model.SERVICE_NOISE_SIGMA))

    def _deliver(self, st: FunctionState, reqs: List[Request]) -> None:
        """Hand a batch of completed requests to the metrics layer:
        appended to ``st.completed`` (legacy), or folded into the
        streaming accumulator and dropped (``stream_metrics`` — the
        constant-memory path). Callers stamp ``completion`` first."""
        if self._sink is None:
            st.completed.extend(reqs)
            return
        if st._slo_base is None:
            from repro.core.metrics import baseline_batch_of
            st._slo_base = perf_model.slo_baseline(
                st.spec, baseline_batch_of(st.policy))
        st.stream_n_completed += len(reqs)
        self._sink.fold(st._slo_base, reqs)

    def _refresh_pods(self, st: FunctionState) -> None:
        """Re-read the function's pod set after its policy may have
        mutated the cluster; flush runtimes of removed (or parked
        keep-warm standby) pods — standby pods hold weights, not
        serving capacity, so dispatch never sees them."""
        pods = [p for p in self.recon.pods_of(st.fid) if not p.standby]
        alive = {p.pod_id for p in pods}
        for pid in list(st.runtimes):
            if pid not in alive:
                rt = st.runtimes.pop(pid)
                for r in rt.inflight:  # inflight on a removed pod completes
                    r.completion = rt.busy_until
                self._deliver(st, rt.inflight)
        st.pod_order = sorted(pods, key=lambda p: -self._thpt(st, p))
        st._tl_cache = None
        if self._decider is not None:
            # the slot's pod set may have changed: any memoized
            # "scale-down is action-free" proof and cached capacity
            # are stale
            self._decider.sterile_delta[st.slot] = -np.inf
            self._decider.cap_ok[st.slot] = False
        st.maybe_idle = True
        if self._admit:
            # admission control's drain-capacity estimate: every pod
            # that will take work (cold-starting pods count — they are
            # capacity within the deadline horizon; doomed/quarantined
            # ones never take new batches)
            st.est_capacity = sum(self._thpt(st, p) for p in st.pod_order
                                  if not p.doomed and not p.quarantined)

    def _shed(self, t: float, st: FunctionState) -> None:
        q = st.queue
        drop_after = self.cfg.drop_after_s
        if self._res is not None and self._res.deadline_s > 0:
            # a queued request past its deadline is already dead to the
            # caller — age it out now instead of at drop_after_s
            drop_after = min(drop_after, self._res.deadline_s)
        kinds = st.drop_kinds
        while q and t - q[0].arrival > drop_after:
            q.popleft()
            st.dropped += 1
            kinds["aged"] += 1

    def _any_work_left(self, now: float) -> bool:
        return any(st.work_left(now) for st in self.fns.values())

    def _count_actions(self, t: float, st: FunctionState,
                       before: Dict[str, float]) -> None:
        """Diff the pod set across one policy tick into per-kind scaling
        counts and cold starts (works for any policy, including ones
        whose tick() returns nothing)."""
        ac = st.action_counts
        after = {p.pod_id: p for p in st.pod_order}
        for pid, quota in before.items():
            pod = after.get(pid)
            if pod is None:
                ac["hdown"] += 1
            elif pod.quota > quota + 1e-12:
                ac["vup"] += 1
            elif pod.quota < quota - 1e-12:
                ac["vdown"] += 1
        for pid, pod in after.items():
            if pid not in before:
                ac["hup"] += 1
                if pod.ready_at > t:
                    # lifecycle-classified starts count under their kind;
                    # without a tracker every late-ready pod is "cold"
                    kind = pod.start_kind or "cold"
                    st.start_counts[kind] = st.start_counts.get(kind, 0) + 1
                    if kind == "cold":
                        st.cold_starts += 1
                elif pod.start_kind == "hot":
                    # keep-warm reactivation: instant capacity, no wait
                    st.start_counts["hot"] += 1

    # ---- event handlers ----------------------------------------------------
    def _on_arrival(self, t: float, st: FunctionState) -> None:
        arr = st._arr
        i, n = st.next_arrival, len(arr)
        q = st.queue
        fid = st.fid
        if self._admit:
            # SLO-aware brownout: reject an arrival outright when the
            # backlog already needs more than the deadline headroom to
            # drain at current capacity — an explicit fast failure
            # instead of burning the request's latency budget in queue
            max_q = st.est_capacity * self._admit_wait
            kinds = st.drop_kinds
            while i < n and arr[i] <= t:
                if q and len(q) >= max_q:
                    st.dropped += 1
                    kinds["shed"] += 1
                else:
                    q.append(Request(fid, arr[i]))
                i += 1
        else:
            while i < n and arr[i] <= t:
                q.append(Request(fid, arr[i]))
                i += 1
        st.next_arrival = i
        # (no next-arrival heap push: the merged-stream cursor in run()
        # is the arrival schedule; entries this block already ingested
        # are skipped there by comparing against ``next_arrival``)
        # if the last scan proved every pod busy (or cold-starting), the
        # new request cannot be dispatched before the next pod-free /
        # pod-ready / autoscale event re-scans — skip the pod loop
        if st.maybe_idle:
            self._dispatch(t, st)

    def _sweep(self, t: float) -> bool:
        """One autoscale sweep: every still-active function's tick at
        grid time ``t``, in slot order — the same order the scalar
        engine's per-function timer chains fired in, with every
        per-function effect (policy tick, pod refresh, reclaim/fault
        draws, dispatch) preserved in place. Cluster-wide cost and
        fragmentation rates are re-sampled ONCE after the sweep: each
        intermediate value the scalar engine computed between
        same-timestamp ticks integrates over dt = 0, so only the
        post-sweep rate is observable. Returns whether any function's
        timer is still live (i.e. the sweep chain continues).

        With ``cfg.batched_policy`` the sweep is two passes instead of
        one Python loop doing everything: a vectorized pre-pass (batched
        shed + one ``window_counts`` call + one ``BatchedKalman`` update
        + one array band classification — see ``_sweep_batched``), then
        a slot-order pass where provably-no-op ticks take a light
        epilogue and only slots needing action (or with a policy the
        decider can't vectorize) run the full per-function path. Either
        way the sweep is byte-identical to the legacy loop."""
        t0 = perf_counter()
        try:
            if (self._decider is not None
                    and not (self._injector is not None
                             and self._injector.in_blackout(t))):
                return self._sweep_batched(t)
            return self._sweep_loop(t)
        finally:
            self.sweep_seconds += perf_counter() - t0
            self.n_sweeps += 1

    def _observed_window_s(self, t: float) -> float:
        """The observed-rate normalization window at sweep time ``t``:
        the trailing OBS_WINDOW_S, shrunk to the elapsed horizon on
        early ticks. BOTH the arrival term and the backlog-drain term
        divide by this — before PR 10 the backlog term divided by the
        full window even when ``t < OBS_WINDOW_S``, systematically
        undercounting backlog demand on early ticks."""
        return max(min(t, OBS_WINDOW_S), 1e-9) if t > 0 else OBS_WINDOW_S

    def _sweep_loop(self, t: float) -> bool:
        """The legacy per-function sweep loop: blackout sweeps (the
        policy is unreachable, so there is nothing to batch) and
        ``batched_policy=False`` runs (the bench baseline)."""
        cfg = self.cfg
        chain = t + cfg.autoscale_interval_s <= cfg.duration_s
        active = self._active
        blackout = (self._injector is not None
                    and self._injector.in_blackout(t))
        recon = self.recon
        track_peak = self.track_peak
        # amortized-O(N) continuation check: within one sweep work only
        # drains (arrivals and retries land between sweeps, and a
        # function's own tick can't create work it didn't have), so a
        # slot proven workless stays workless — resume the scan where
        # the previous call stopped instead of re-scanning the fleet
        # per function (the scalar engine's O(N^2) tail). Answers are
        # identical to ``_any_work_left``.
        fl = self.fn_list
        n_fl = len(fl)
        scan = 0

        def work_ahead() -> bool:
            nonlocal scan
            while scan < n_fl and not fl[scan].work_left(t):
                scan += 1
            return scan < n_fl

        win = self._observed_window_s(t)
        for st in self.fn_list:
            if not active[st.slot]:
                continue
            self.n_events += 1
            if blackout:
                # control-plane blackout: the timer fires but the
                # policy is unreachable — no scaling decision, no
                # replacement capacity, no outage-recovery bookkeeping.
                # Aging and dispatch keep running (the data plane is
                # fine), and the timer stays alive so the tick after
                # the window acts normally.
                self._shed(t, st)
                if not (chain or work_ahead()):
                    active[st.slot] = False
                self._dispatch(t, st)
                continue
            self._shed(t, st)
            observed = st.observed_in_window(t) / win if t > 0 else 0.0
            observed += len(st.queue) / win  # backlog drain demand
            # snapshot quota VALUES before the policy mutates pods in
            # place; between autoscale events the pod set is immutable,
            # so the cached pod_order is the authoritative before-state
            before = {p.pod_id: p.quota for p in st.pod_order}
            st.policy.tick(t, st.spec, observed)
            self._refresh_pods(st)
            self._count_actions(t, st, before)
            if cfg.record_timeline:
                st.timeline.append(
                    (t, observed, len(st.pod_order),
                     sum((p.sm / (p.gpu_type.sm_total if p.gpu_type else 8.0))
                         * p.quota for p in st.pod_order)))
            if track_peak and recon.n_used_gpus > self.peak_gpus:
                # intermediate per-function peaks matter: a later
                # function's tick may release what this one just used
                self.peak_gpus = recon.n_used_gpus
            if not (chain or work_ahead()):
                active[st.slot] = False
            self._schedule_reclaims(t)
            self._schedule_faults(t)
            if self._outages:
                self._close_recovered_outages(t)
            self._dispatch(t, st)
        self._cost_rates = self.cost.rates(recon)
        self._frag_rate = recon.fragmentation()
        return bool(active.any())

    def _sweep_batched(self, t: float) -> bool:
        """The vectorized sweep. Pass 1 hoists the order-free per-slot
        work out of the policy loop: shed/age (touches only the slot's
        own queue), the observed rate (arrival counts off the merged
        arrays via ``window_counts`` + the backlog term — no slot's
        policy can change another slot's queue within a sweep, so
        observing up front is value-preserving), capacity/pod gathers
        for decider-eligible slots (a policy only ever mutates its own
        function's pods, so these are stable across the sweep too), and
        one ``SweepDecider.decide`` call (batched Kalman + band
        classification). Pass 2 walks active slots in slot order:

          * fast path (eligible, classified no-op): the tick is provably
            action-free — skip the policy call, the pod refresh/diff and
            the reclaim/fault rescans (no new chips or pods can have
            appeared), keep the timeline row (memoized pod summary),
            the peak check, the chain check and dispatch;
          * eligible slots needing action call ``scale()`` directly with
            the batched prediction (byte-identical to ``tick()`` — the
            filter lane already did the update);
          * ineligible slots run the full legacy ``tick()`` path.
        """
        cfg = self.cfg
        chain = t + cfg.autoscale_interval_s <= cfg.duration_s
        active = self._active
        recon = self.recon
        track_peak = self.track_peak
        dec = self._decider
        fl = self.fn_list
        n_fl = len(fl)
        scan = 0

        def work_ahead() -> bool:
            nonlocal scan
            while scan < n_fl and not fl[scan].work_left(t):
                scan += 1
            return scan < n_fl

        idx = np.nonzero(active)[0].tolist()
        if not idx:
            self._cost_rates = self.cost.rates(recon)
            self._frag_rate = recon.fragmentation()
            return False
        # ---- pass 1: batched shed + observe + decide ----
        # (scalar indexing into numpy arrays is ~100ns a pop; the hot
        # loops stay on Python lists and convert once per sweep)
        win = self._observed_window_s(t)
        if t > 0 and self._m_t is not None:
            arr_l = (window_counts(self._m_t, self._m_slot, t, n_fl)
                     / win).tolist()
        else:
            arr_l = [0.0] * n_fl
        el = dec.eligible.tolist()
        cap_ok = dec.cap_ok
        cap = dec.cap
        obs_l = [0.0] * n_fl
        hp_l = [False] * n_fl
        mask_l = [False] * n_fl
        for i in idx:
            st = fl[i]
            self._shed(t, st)
            obs_l[i] = arr_l[i] + len(st.queue) / win
            if el[i]:
                mask_l[i] = True
                if not cap_ok[i]:
                    cap[i] = st.policy.capacity(st.spec)
                    cap_ok[i] = True
                hp_l[i] = bool(st.pod_order)
        obs = np.array(obs_l)
        mask = np.array(mask_l)
        pred, action, sterile, down_band, delta = dec.decide(
            t, obs, cap, np.array(hp_l), mask)
        pred_l = pred.tolist()
        action_l = action.tolist()
        sterile_l = sterile.tolist()
        down_l = down_band.tolist()
        delta_l = delta.tolist()
        # ---- pass 2: slot-order epilogues ----
        for i in idx:
            st = fl[i]
            self.n_events += 1
            fast = mask_l[i] and not action_l[i]
            if fast and sterile_l[i] and len(recon.gpus) != recon.n_used_gpus:
                # the sterility proof covers scale()'s shed loop but its
                # trailing release_empty_gpus() is only a no-op while no
                # empty chips exist — some do, so run the real call
                fast = False
            if fast:
                # fast path: a provably action-free tick
                self.fast_ticks += 1
                if cfg.record_timeline:
                    cache = st._tl_cache
                    if cache is None:
                        cache = st._tl_cache = (
                            len(st.pod_order),
                            sum((p.sm / (p.gpu_type.sm_total
                                         if p.gpu_type else 8.0))
                                * p.quota for p in st.pod_order))
                    st.timeline.append((t, obs_l[i]) + cache)
                if track_peak and recon.n_used_gpus > self.peak_gpus:
                    self.peak_gpus = recon.n_used_gpus
                if not (chain or work_ahead()):
                    active[i] = False
                if self._outages:
                    self._close_recovered_outages(t)
                self._dispatch(t, st)
                continue
            before = {p.pod_id: p.quota for p in st.pod_order}
            acts = None
            if mask_l[i]:
                # eligible slot needing action: the filter lane already
                # ran the Kalman update, hand scale() the prediction
                acts = st.policy.scale(t, st.spec, pred_l[i])
                dec.refresh_after_scale(i)
            else:
                st.policy.tick(t, st.spec, obs_l[i])
            self._refresh_pods(st)
            if mask_l[i] and down_l[i] and not acts:
                # an action-free down-band call: memoize the proof (the
                # refresh above wiped any prior one) so future retries
                # with delta <= this one fast-path until the pod set
                # changes
                dec.sterile_delta[i] = delta_l[i]
            self._count_actions(t, st, before)
            if cfg.record_timeline:
                st.timeline.append(
                    (t, obs_l[i], len(st.pod_order),
                     sum((p.sm / (p.gpu_type.sm_total if p.gpu_type else 8.0))
                         * p.quota for p in st.pod_order)))
            if track_peak and recon.n_used_gpus > self.peak_gpus:
                self.peak_gpus = recon.n_used_gpus
            if not (chain or work_ahead()):
                active[i] = False
            self._schedule_reclaims(t)
            self._schedule_faults(t)
            if self._outages:
                self._close_recovered_outages(t)
            self._dispatch(t, st)
        self._cost_rates = self.cost.rates(recon)
        self._frag_rate = recon.fragmentation()
        return bool(active.any())

    # ---- spot reclaims -----------------------------------------------------
    def _schedule_reclaims(self, t: float) -> None:
        """Draw a reclaim-notice time for every live spot chip that has
        none yet (fresh chips appear at autoscale events, so this runs
        at seed time and after each policy tick). Draws come from the
        dedicated reclaim rng in chip-creation order — deterministic
        for a given seed and decision history."""
        if not self._has_spot:
            return
        horizon = self.cfg.duration_s + self.cfg.drop_after_s
        for g in self.recon.gpus.values():
            m = g.gpu_type.market
            if (m is None or m.reclaim_rate_per_hour <= 0
                    or g.uuid in self._reclaim_scheduled):
                continue
            self._reclaim_scheduled.add(g.uuid)
            tr = m.sample_reclaim(t, self._reclaim_rng)
            if tr <= horizon:
                self._push(tr, RECLAIM_NOTICE, g.uuid)

    def _on_reclaim_notice(self, t: float, uuid: str) -> None:
        """Open the grace window on chip ``uuid``: mark its pods doomed
        (capacity drops to zero, so the next autoscale tick starts
        replacing them), count batches that will finish inside the
        window as drained, and schedule the kill. A chip the policy
        already released is ignored."""
        g = self.recon.gpus.get(uuid)
        if g is None or g.doomed:
            return
        kill_at = t + g.gpu_type.market.grace_period_s
        self.recon.mark_doomed(uuid, kill_at, now=t)
        self.preempt["reclaims"] += 1
        for pod in g.pods:
            self.touched_fns.add(pod.fn_id)
            st = self.fns.get(pod.fn_id)
            if st is None:
                continue
            rt = st.runtimes.get(pod.pod_id)
            if rt is not None and rt.inflight and t < rt.busy_until <= kill_at:
                self.preempt["drained_batches"] += 1
        self._push(kill_at, RECLAIM_KILL, uuid)

    def _on_reclaim_kill(self, t: float, uuid: str) -> None:
        """Close the grace window: deliver batches that finished in
        time, requeue (or drop) still-running ones at the queue head,
        remove every pod through the indexed path (demoting weights
        when a lifecycle tracker is attached), and drop the chip. The
        cost/fragmentation rates are re-sampled by the caller."""
        g = self.recon.gpus.get(uuid)
        if g is None:
            return
        affected: Dict[str, FunctionState] = {}
        requeue: Dict[str, List[Request]] = {}
        for pod in g.pods:
            self.touched_fns.add(pod.fn_id)
            st = self.fns.get(pod.fn_id)
            if st is None:
                continue
            affected[st.fid] = st
            rt = st.runtimes.pop(pod.pod_id, None)
            if rt is None or not rt.inflight:
                continue
            if rt.busy_until <= t:   # drained: finished, delivery was lazy
                for r in rt.inflight:
                    r.completion = rt.busy_until
                self._deliver(st, rt.inflight)
            else:                    # killed mid-batch
                self.preempt["killed_batches"] += 1
                keep = self._apply_retry_policy(t, st, rt.inflight)
                if keep:
                    requeue.setdefault(st.fid, []).extend(keep)
                    self.preempt["requeued_requests"] += len(keep)
                dead = len(rt.inflight) - len(keep)
                if dead:
                    self.preempt["dropped_in_flight"] += dead
            rt.inflight = []
        for fid, reqs in requeue.items():
            self._requeue(t, affected[fid], reqs)
        self.recon.remove_gpu(uuid, now=t)
        self._reclaim_scheduled.discard(uuid)
        for st in affected.values():
            self._refresh_pods(st)
            self._dispatch(t, st)
        self._cost_rates = self.cost.rates(self.recon)
        self._frag_rate = self.recon.fragmentation()

    # ---- fault injection + resilience (core/faults.py) ---------------------
    def _apply_retry_policy(self, t: float, st: FunctionState,
                            reqs: List[Request]) -> List[Request]:
        """Decide the fate of a killed batch's in-flight requests:
        returns the ones to requeue, accounts the rest as "killed"
        drops. Without a resilience config this is the legacy boolean
        ``reclaim_requeue`` (all or nothing); with one, each request is
        retried only while it has budget left (``max_retries``) and —
        when deadlines are armed — can still complete in time after
        ``retry_backoff_s``."""
        res = self._res
        if res is None:
            if self.cfg.reclaim_requeue:
                return list(reqs)
            st.dropped += len(reqs)
            st.drop_kinds["killed"] += len(reqs)
            return []
        keep: List[Request] = []
        dead = 0
        for r in reqs:
            if (r.retries < res.max_retries
                    and (res.deadline_s <= 0
                         or t + res.retry_backoff_s
                         <= r.arrival + res.deadline_s)):
                r.retries += 1
                self.retries += 1
                keep.append(r)
            else:
                dead += 1
        if dead:
            st.dropped += dead
            st.drop_kinds["killed"] += dead
        return keep

    def _requeue(self, t: float, st: FunctionState,
                 reqs: List[Request]) -> None:
        """Requeue retried requests at the queue head in arrival order
        (they are older than anything still queued — FIFO and ``_shed``
        rely on it), after ``retry_backoff_s`` when armed."""
        res = self._res
        if res is not None and res.retry_backoff_s > 0:
            self._push(t + res.retry_backoff_s, RETRY, (st.fid, reqs))
            return
        for r in sorted(reqs, key=lambda r: r.arrival, reverse=True):
            r.start = None
            st.queue.appendleft(r)

    def _on_retry(self, t: float, payload) -> None:
        """A backoff window closed: the retried requests rejoin their
        function's queue head and dispatch re-scans."""
        fid, reqs = payload
        st = self.fns.get(fid)
        if st is None:
            return
        for r in sorted(reqs, key=lambda r: r.arrival, reverse=True):
            r.start = None
            st.queue.appendleft(r)
        self._dispatch(t, st)

    def _schedule_faults(self, t: float) -> None:
        """Draw fault times for every live chip / pod / node that has
        none yet (fresh entities appear at autoscale events, so this
        runs at seed time and after each policy tick — mirroring
        ``_schedule_reclaims``). Each process draws from its own
        dedicated stream in entity-creation order: deterministic for a
        given seed and decision history."""
        inj = self._injector
        if inj is None:
            return
        m = inj.model
        horizon = inj.horizon_s
        if m.chip_failure_rate_per_hour > 0:
            for g in self.recon.gpus.values():
                if g.uuid in inj.chip_drawn:
                    continue
                inj.chip_drawn.add(g.uuid)
                tf = inj.draw_chip_failure(t)
                if tf <= horizon:
                    self._push(tf, CHIP_FAIL, g.uuid)
        if m.straggler_rate_per_hour > 0:
            for g in self.recon.gpus.values():
                for p in g.pods:
                    if p.pod_id in inj.pod_drawn:
                        continue
                    inj.pod_drawn.add(p.pod_id)
                    ts = inj.draw_straggler(t)
                    if ts <= horizon:
                        self._push(ts, POD_FAULT, ("straggler", p.pod_id))
        if m.cache_loss_rate_per_hour > 0:
            for g in self.recon.gpus.values():
                if g.node in inj.node_drawn:
                    continue
                inj.node_drawn.add(g.node)
                tc = inj.draw_cache_loss(t)
                if tc <= horizon:
                    self._push(tc, POD_FAULT, ("cache_loss", g.node))

    def _on_chip_fail(self, t: float, uuid: str) -> None:
        """Chip hard-failure: instant kill, no grace window. Finished
        batches deliver (their completion predates the failure);
        running batches go through the retry policy; the chip leaves
        through the same ``remove_gpu`` path a reclaim kill uses; and a
        capacity outage opens per affected function, closed when its
        READY pod count recovers (MTTR / availability accounting)."""
        g = self.recon.gpus.get(uuid)
        if g is None:
            return   # already scaled away or reclaimed
        self.fault_counts["chip_failures"] += 1
        affected: Dict[str, FunctionState] = {}
        requeue: Dict[str, List[Request]] = {}
        for pod in g.pods:
            self.touched_fns.add(pod.fn_id)
            st = self.fns.get(pod.fn_id)
            if st is None:
                continue
            affected[st.fid] = st
            rt = st.runtimes.pop(pod.pod_id, None)
            if rt is None or not rt.inflight:
                continue
            if rt.busy_until <= t:   # finished before the failure
                for r in rt.inflight:
                    r.completion = rt.busy_until
                self._deliver(st, rt.inflight)
            else:                    # killed mid-batch, instantly
                keep = self._apply_retry_policy(t, st, rt.inflight)
                if keep:
                    requeue.setdefault(st.fid, []).extend(keep)
            rt.inflight = []
        for st in affected.values():
            # outage target: the pre-failure READY capacity headcount
            target = sum(1 for p in st.pod_order
                         if not p.doomed and not p.quarantined)
            if any(p.fn_id == st.fid and not p.standby for p in g.pods):
                self._outages.append([st.fid, t, target])
        self.recon.remove_gpu(uuid, now=t)
        self._reclaim_scheduled.discard(uuid)
        for fid, reqs in requeue.items():
            self._requeue(t, affected[fid], reqs)
        for st in affected.values():
            self._refresh_pods(st)
            self._dispatch(t, st)
        self._down_rate = 1.0 if self._outages else 0.0
        self._cost_rates = self.cost.rates(self.recon)
        self._frag_rate = self.recon.fragmentation()

    def _close_recovered_outages(self, t: float) -> None:
        """Close every outage whose function has its READY (non-doomed,
        non-quarantined) pod count back at the pre-failure target;
        record each repair time for MTTR."""
        still = []
        for o in self._outages:
            fid, t0, target = o
            st = self.fns.get(fid)
            ready = (sum(1 for p in st.pod_order
                         if p.ready_at <= t and not p.doomed
                         and not p.quarantined)
                     if st is not None else target)
            if ready >= target:
                self.mttr_samples.append(t - t0)
            else:
                still.append(o)
        self._outages = still
        self._down_rate = 1.0 if still else 0.0

    def _on_pod_fault(self, t: float, payload) -> None:
        """A pod-scoped fault lands: open a straggler window (service
        times inflate until it closes) or drop a node's host weight
        cache. Each entity redraws its next fault after the current one
        — a proper per-entity Poisson process — until it disappears."""
        kind, target = payload
        inj = self._injector
        m = inj.model
        if kind == "straggler":
            pod = self.recon.pod(target)
            if pod is None:
                return   # pod scaled away; its process dies with it
            self.touched_fns.add(pod.fn_id)
            self.fault_counts["stragglers"] += 1
            until = t + m.straggler_duration_s
            self._slow[target] = (until, m.straggler_factor)
            nxt = inj.draw_straggler(until)
        else:   # cache_loss
            self.fault_counts["cache_losses"] += 1
            for g in self.recon.gpus.values():
                if g.node == target:
                    self.touched_fns.update(p.fn_id for p in g.pods)
            tracker = getattr(self.recon, "modelstate", None)
            if tracker is not None:
                tracker.drop_node_cache(target, now=t)
            nxt = inj.draw_cache_loss(t)
        if nxt <= inj.horizon_s:
            self._push(nxt, POD_FAULT, payload)

    def _quarantine(self, t: float, st: FunctionState, pod) -> None:
        """Health trip: pull the pod out of dispatch exactly like a
        doomed chip (zero capacity, no new batches — the in-flight
        batch finishes), schedule the lift, and reset its score so it
        returns with a clean slate."""
        if pod.quarantined or pod.doomed:
            return
        self.touched_fns.add(st.fid)
        self.fault_counts["quarantines"] += 1
        self.recon.set_quarantined(pod.pod_id, True)
        if self._decider is not None:
            # quarantine zeroes the pod in the capacity model without a
            # pod-set refresh — drop the slot's cached C_f (the sterile
            # proof survives: _scale_down's arithmetic ignores the flag)
            self._decider.cap_ok[st.slot] = False
        self._health.reset(pod.pod_id)
        self._push(t + self._res.quarantine_duration_s, QUAR_LIFT,
                   (st.fid, pod.pod_id))

    def _on_quarantine_lift(self, t: float, payload) -> None:
        """A quarantine window closed: the pod (if still alive) rejoins
        dispatch and the capacity model counts it again."""
        fid, pod_id = payload
        pod = self.recon.pod(pod_id)
        if pod is not None and pod.quarantined:
            self.recon.set_quarantined(pod_id, False)
        st = self.fns.get(fid)
        if st is not None:
            self._refresh_pods(st)
            self._dispatch(t, st)

    def _dispatch(self, t: float, st: FunctionState) -> None:
        """Idle ready pods pull batches, highest-throughput first.

        Completion delivery is lazy: a finished batch's completion times
        were fixed when it started (``busy_until``), so handing it to
        ``completed`` can wait until its pod next pulls (or the final
        flush) without observable difference.
        """
        cfg = self.cfg
        self._shed(t, st)
        q = st.queue
        runtimes = st.runtimes
        any_idle = False
        for pod in st.pod_order:
            rt = runtimes.get(pod.pod_id)
            if rt is None:
                rt = runtimes[pod.pod_id] = PodRuntime(pod.pod_id)
            if rt.busy_until > t:
                continue
            if rt.inflight:
                for r in rt.inflight:
                    r.completion = rt.busy_until
                self._deliver(st, rt.inflight)
                rt.inflight = []
            if pod.doomed or pod.quarantined:
                continue   # draining (reclaim kill) or health-benched
            if not q:
                any_idle = True  # free pod waiting for work
                break
            if pod.ready_at > t:  # cold-starting; wake when ready
                if not rt.wake_scheduled:
                    rt.wake_scheduled = True
                    self._push(pod.ready_at, DISPATCH, st)
                continue
            if len(q) < pod.batch:
                # compare against the absolute deadline (the same float
                # the wakeup is scheduled at) so the timeout event is
                # never judged "not yet due" by rounding
                tmo = q[0].arrival + cfg.batch_wait_s
                if tmo - t > 1e-9:
                    if tmo > st.timeout_at:  # head timeouts are monotone
                        st.timeout_at = tmo
                        self._push(tmo, DISPATCH, st)
                    any_idle = True  # idle, waiting to fill its batch
                    continue
            take = min(pod.batch, len(q))
            batch = [q.popleft() for _ in range(take)]
            det, service = self._service(st, take, pod)
            if self._injector is not None:
                slow = self._slow.get(pod.pod_id)
                if slow is not None and t < slow[0]:
                    service *= slow[1]   # inside a straggler window
            if self._health is not None and det > 0:
                # health sample: the full observed/predicted ratio
                # (noise AND straggler inflation); the batch that tripped
                # the score still runs — quarantine bars the NEXT pull
                if self._health.observe(pod.pod_id, service / det):
                    self._quarantine(t, st, pod)
            for r in batch:
                r.start = t
            rt.busy_until = t + service
            rt.inflight = batch
            self._push(rt.busy_until, DISPATCH, st)
        st.maybe_idle = any_idle

    # ---- main loop ---------------------------------------------------------
    def run(self) -> None:
        """Drain the simulation to completion. Three event sources are
        interleaved in (time, kind) order — the merged struct-of-arrays
        arrival stream (kind ARRIVAL), the heap of irregular events
        (dispatch wakeups, reclaims, faults), and the shared autoscale
        sweep timer (kind AUTOSCALE) — while cost and fragmentation are
        integrated exactly between distinct event times. Arrivals later
        than ``duration_s + drop_after_s`` are shed. After return,
        every ``FunctionState`` holds its completed requests (or the
        streaming accumulator its folded metrics) and the cost meter
        its integrated totals."""
        cfg = self.cfg
        cutoff = cfg.duration_s + cfg.drop_after_s
        fn_list = self.fn_list
        for st in fn_list:
            self._refresh_pods(st)
        # ---- merged arrival stream (struct-of-arrays) ----
        # parallel sorted arrays: arrival time, owning function slot,
        # within-function position. One cursor replaces one heap
        # push+pop per request; a stable sort keeps equal-time arrivals
        # in slot order.
        parts = [st.arrivals for st in fn_list if len(st.arrivals)]
        if parts:
            m_t = np.concatenate(parts)
            m_slot = np.concatenate(
                [np.full(len(st.arrivals), st.slot, dtype=np.int64)
                 for st in fn_list if len(st.arrivals)])
            m_pos = np.concatenate(
                [np.arange(len(st.arrivals), dtype=np.int64)
                 for st in fn_list if len(st.arrivals)])
            order = np.argsort(m_t, kind="stable")
            # the sorted numpy form is retained for the batched sweep's
            # window_counts pass; the list copies keep the cursor loop
            # out of numpy scalar-indexing overhead
            self._m_t = m_t[order]
            self._m_slot = m_slot[order]
            m_tl = self._m_t.tolist()
            m_sl = self._m_slot.tolist()
            m_pl = m_pos[order].tolist()
        else:
            m_tl, m_sl, m_pl = [], [], []
        n_arr, mc = len(m_tl), 0
        # ---- batched decide state ----
        # one SweepDecider slot per function: eligible slots (plain
        # HybridAutoScaler with a Kalman predictor, no spot router, no
        # pre-warm forecasting) take the vectorized fast path; the rest
        # keep the per-function tick() loop
        if cfg.batched_policy:
            from repro.core.autoscaler import SweepDecider
            self._decider = SweepDecider(len(fn_list))
            for st in fn_list:
                self._decider.bind(st.slot, st.policy, st.fid)
        # ---- autoscale sweep state ----
        # every function ticks on the same grid (seeded at t=0, stepped
        # by autoscale_interval_s); the per-slot active mask replaces
        # the scalar engine's per-function timer chains
        self._active = np.ones(len(fn_list), dtype=bool)
        sweep_t = 0.0
        self._schedule_reclaims(0.0)   # chips provisioned at prewarm
        self._schedule_faults(0.0)
        self._cost_rates = self.cost.rates(self.recon)
        self._frag_rate = self.recon.fragmentation()
        usd_rate, gsec_rate = self._cost_rates
        frag_rate = self._frag_rate
        down_rate = self._down_rate
        usd = gsec = frag = down = 0.0
        last_t = 0.0
        heap = self._heap
        pop = heapq.heappop
        INF = float("inf")
        while True:
            # skip merged entries an earlier block ingest already
            # consumed (an arrival handler pulls EVERY arrival <= t of
            # its function, exactly like the scalar engine)
            while mc < n_arr and m_pl[mc] < fn_list[m_sl[mc]].next_arrival:
                mc += 1
            # next event = min over the three sources by (time, kind):
            # ARRIVAL(0) < RECLAIM_NOTICE(1) < AUTOSCALE(2) < the rest,
            # mirroring the scalar engine's same-timestamp priorities
            t = m_tl[mc] if mc < n_arr else INF
            kind, src = ARRIVAL, 0
            if heap:
                h = heap[0]
                if h[0] < t or (h[0] == t and h[1] < kind):
                    t, kind, src = h[0], h[1], 1
            if sweep_t is not None and (sweep_t < t or
                                        (sweep_t == t and AUTOSCALE < kind)):
                t, kind, src = sweep_t, AUTOSCALE, 2
            if t == INF:
                break
            if t > cutoff:
                # anything still queued has, by construction, aged out
                usd += usd_rate * (cutoff - last_t)
                gsec += gsec_rate * (cutoff - last_t)
                frag += frag_rate * (cutoff - last_t)
                down += down_rate * (cutoff - last_t)
                last_t = cutoff
                break
            if t > last_t:
                usd += usd_rate * (t - last_t)
                gsec += gsec_rate * (t - last_t)
                frag += frag_rate * (t - last_t)
                down += down_rate * (t - last_t)
                last_t = t
            self.now = t
            if src == 0:                   # merged arrival stream
                st = fn_list[m_sl[mc]]
                mc += 1
                self.n_events += 1
                self._on_arrival(t, st)
            elif src == 2:                 # autoscale sweep
                sweep_t = (t + cfg.autoscale_interval_s
                           if self._sweep(t) else None)
                usd_rate, gsec_rate = self._cost_rates
                frag_rate = self._frag_rate
                down_rate = self._down_rate
            else:                          # irregular heap events
                t, kind, _, st = pop(heap)
                self.n_events += 1
                if kind == RECLAIM_NOTICE:   # payload is the chip uuid
                    self._on_reclaim_notice(t, st)
                elif kind == RECLAIM_KILL:   # chip leaves: rates change
                    self._on_reclaim_kill(t, st)
                    usd_rate, gsec_rate = self._cost_rates
                    frag_rate = self._frag_rate
                elif kind == CHIP_FAIL:      # payload is the chip uuid
                    self._on_chip_fail(t, st)
                    usd_rate, gsec_rate = self._cost_rates
                    frag_rate = self._frag_rate
                    down_rate = self._down_rate
                elif kind == POD_FAULT:      # payload is (kind, target)
                    self._on_pod_fault(t, st)
                elif kind == RETRY:          # payload is (fn_id, requests)
                    self._on_retry(t, st)
                elif kind == QUAR_LIFT:      # payload is (fn_id, pod_id)
                    self._on_quarantine_lift(t, st)
                else:
                    self._dispatch(t, st)
        if last_t < cfg.duration_s:  # idle pods accrue cost to end of run
            usd += usd_rate * (cfg.duration_s - last_t)
            gsec += gsec_rate * (cfg.duration_s - last_t)
            frag += frag_rate * (cfg.duration_s - last_t)
            down += down_rate * (cfg.duration_s - last_t)
        self.cost.total_usd += usd
        self.cost.gpu_seconds += gsec
        self.frag_integral += frag
        self.downtime += down
        self._integrated_to = max(last_t, cfg.duration_s)
        self._flush()

    def fragmentation_avg(self) -> float:
        """Time-averaged fraction of slice capacity on used chips left
        unallocated over the integrated horizon — the spatial-waste
        metric mixed-fleet bin-packing (FleetPlacer) minimizes."""
        horizon = getattr(self, "_integrated_to", 0.0)
        return self.frag_integral / horizon if horizon > 0 else 0.0

    def _flush(self) -> None:
        if self._decider is not None:
            # scatter the batched filter lanes back into the per-policy
            # KalmanPredictor objects so post-run introspection sees the
            # same filter state a scalar run would leave behind
            self._decider.sync_back()
        for st in self.fns.values():
            for rt in st.runtimes.values():
                for r in rt.inflight:
                    r.completion = rt.busy_until
                self._deliver(st, rt.inflight)
                rt.inflight = []
            st.dropped += len(st.queue)
            st.drop_kinds["aged"] += len(st.queue)
            st.queue.clear()
            # arrivals never injected (cutoff break) are dropped too
            leftover = len(st._arr) - st.next_arrival
            st.dropped += leftover
            st.drop_kinds["aged"] += leftover
            st.next_arrival = len(st._arr)
        # outages still open at the end of the horizon close there
        horizon = getattr(self, "_integrated_to", 0.0)
        for _, t0, _ in self._outages:
            self.mttr_samples.append(max(0.0, horizon - t0))
        self._outages = []
