"""Unified metrics pipeline: one structured record per simulation run.

Every scenario run — single- or multi-function, any policy — folds into
the same ``RunMetrics`` record, computed in one place instead of being
re-derived ad hoc inside each ``benchmarks/fig*.py``. The record is
JSON-round-trippable, which is what the golden-trace regression suite
(``tests/test_goldens.py``) pins: any policy or engine change that
shifts SLO/cost behavior fails with a readable field-by-field diff.

Violation rates pool *normalized* latencies (latency / per-function SLO
baseline) across functions, so multi-function runs aggregate without
privileging any one function's absolute latency scale; dropped requests
count as violations at every multiplier (normalized latency = inf),
matching ``SimResult.violations``.

Runs on a non-reference fleet (any declared GPU type other than the
default) additionally carry ``fragmentation`` — the time-averaged
free-slice fraction on used chips, the spatial-waste metric the
placement-aware scheduler minimizes. The field is omitted from the
serialized record for reference-fleet runs so every pre-heterogeneity
golden stays byte-identical.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional

import numpy as np

from repro.configs.gpus import DEFAULT_GPU_TYPE
from repro.core import perf_model
from repro.core.slo import percentiles

ACTION_KINDS = ("vup", "vdown", "hup", "hdown")
DEFAULT_MULTIPLIERS = (1.5, 2.0, 2.5)
_SIG_DIGITS = 12  # float rounding on serialize: stable, still "tight"


def baseline_batch_of(policy) -> int:
    """Batch the SLO baseline is quoted at (paper §4.3): the policy's
    default serving batch, falling back to 8."""
    cfg = getattr(policy, "cfg", None)
    return cfg.default_batch if hasattr(cfg, "default_batch") else 8


def _round(x: float) -> float:
    if x == 0.0 or not math.isfinite(x):
        return x
    return round(x, _SIG_DIGITS - 1 - int(math.floor(math.log10(abs(x)))))


def _jsonf(x: float):
    """RFC-8259-safe float: non-finite values (empty-run percentiles,
    cost of a zero-completion run) serialize as null, not Infinity."""
    return _round(x) if math.isfinite(x) else None


def _unjsonf(x):
    return float("inf") if x is None else x


# ---- streaming accumulators (the wide engine's constant-memory path) ----
#: below this many samples the accumulator keeps every latency and
#: answers percentile queries exactly (identical to the pooled path);
#: past it the samples spill into the log-binned sketch
STREAM_EXACT_LIMIT = 100_000
#: sketch range: 10 us .. 10,000 s covers every latency the simulator
#: can produce (service floors are ~ms, drop_after caps the tail)
_SKETCH_LO = 1e-5
_SKETCH_HI = 1e4
_SKETCH_BINS = 4096


class StreamingQuantiles:
    """Constant-memory latency quantiles: exact up to ``exact_limit``
    samples, then a fixed log-binned histogram.

    The sketch spans [lo, hi) with ``bins`` geometric bins (default
    10 us..10,000 s over 4096 bins, ratio 10^(9/4096) per bin). A
    queried quantile returns the geometric midpoint of the bin holding
    the target order statistic, so its relative error vs that order
    statistic is at most half a bin width — ratio^0.5 - 1 ~= 0.26%.
    Against numpy's linearly interpolated percentile this adds at most
    one inter-sample gap; the documented (and tested) bound is <= 0.6%
    relative error wherever adjacent order statistics fall within a
    bin of each other (true for any smooth latency distribution at
    realistic n; a quantile sitting exactly on a bimodal jump is
    inherently ambiguous for every histogram sketch). Out-of-range
    values clamp to the edge bins. Below the exact limit the answers
    are byte-identical to ``slo.percentiles`` on the pooled array.
    """

    def __init__(self, exact_limit: int = STREAM_EXACT_LIMIT,
                 lo: float = _SKETCH_LO, hi: float = _SKETCH_HI,
                 bins: int = _SKETCH_BINS):
        self.exact_limit = int(exact_limit)
        self.lo, self.hi, self.bins = float(lo), float(hi), int(bins)
        self._log_lo = math.log(self.lo)
        self._log_span = math.log(self.hi) - self._log_lo
        self.n = 0
        self._exact: Optional[List[float]] = []
        self._counts: Optional[np.ndarray] = None

    #: documented worst-case relative error of a sketch-mode quantile
    #: for in-range values (half a geometric bin)
    @property
    def rel_err_bound(self) -> float:
        """Worst-case relative quantile error once spilled to the sketch."""
        return (self.hi / self.lo) ** (0.5 / self.bins) - 1.0

    @property
    def is_sketch(self) -> bool:
        """True once the accumulator has spilled into histogram mode."""
        return self._counts is not None

    def _bin_of(self, x: np.ndarray) -> np.ndarray:
        idx = ((np.log(np.maximum(x, self.lo)) - self._log_lo)
               / self._log_span * self.bins).astype(np.int64)
        return np.clip(idx, 0, self.bins - 1)

    def _spill(self) -> None:
        self._counts = np.zeros(self.bins, dtype=np.int64)
        if self._exact:
            arr = np.asarray(self._exact, dtype=float)
            np.add.at(self._counts, self._bin_of(arr), 1)
        self._exact = None

    def add_many(self, values) -> None:
        """Fold an array of latency samples (seconds) into the sketch."""
        arr = np.asarray(values, dtype=float)
        if arr.size == 0:
            return
        self.n += arr.size
        if self._counts is None:
            self._exact.extend(arr.tolist())
            if len(self._exact) > self.exact_limit:
                self._spill()
        else:
            np.add.at(self._counts, self._bin_of(arr), 1)

    def add_one(self, x: float) -> None:
        """Single-sample fast path (the dominant delivery shape on
        long-tail fleets: one completion per batch). Exact mode appends
        in pure Python — bit-identical to ``add_many([x])``; sketch
        mode delegates to the array path so the bin arithmetic (and any
        platform quirk of numpy's log) stays identical to it."""
        if self._counts is None:
            self.n += 1
            self._exact.append(float(x))
            if len(self._exact) > self.exact_limit:
                self._spill()
        else:
            self.add_many((x,))

    def percentiles(self) -> Dict[str, float]:
        """p50/p90/p95/p99 in the ``slo.percentiles`` shape: exact below
        the limit, bin-midpoint answers (rel err <= ``rel_err_bound``)
        after the spill, inf for an empty accumulator."""
        if self._counts is None:
            return percentiles(np.asarray(self._exact, dtype=float))
        cum = np.cumsum(self._counts)
        total = int(cum[-1])
        if total == 0:
            return percentiles(np.empty(0))
        ratio = (self.hi / self.lo) ** (1.0 / self.bins)
        out = {}
        for name, q in (("p50", 50), ("p90", 90), ("p95", 95), ("p99", 99)):
            # rank of np.percentile's linear interpolation target; the
            # bin holding it bounds the true value within rel_err_bound
            rank = math.ceil(q / 100.0 * (total - 1)) if total > 1 else 0
            b = int(np.searchsorted(cum, rank + 1))
            out[name] = self.lo * ratio ** (b + 0.5)
        return out


class RunStreamStats:
    """Streaming ``RunMetrics`` inputs for the wide engine: exact SLO
    violation counters per multiplier plus a ``StreamingQuantiles``
    latency sketch, folded one delivery batch at a time so a
    10M-request replay never holds its latencies in RAM.

    Violation counts are *exact* regardless of sketch mode — each
    completion is compared against ``m * slo_baseline`` at fold time —
    so only the latency percentiles degrade (within the documented
    bound) on runs past the exact limit.
    """

    def __init__(self, multipliers=DEFAULT_MULTIPLIERS,
                 exact_limit: int = STREAM_EXACT_LIMIT):
        self.multipliers = tuple(float(m) for m in multipliers)
        self.viol = {m: 0 for m in self.multipliers}
        self.n = 0
        self.quantiles = StreamingQuantiles(exact_limit=exact_limit)

    def fold(self, slo_baseline_s: float, reqs) -> None:
        """Fold one batch of completed requests measured against the
        owning function's SLO baseline (seconds)."""
        if len(reqs) == 1:
            # scalar fast path: skip the array ceremony for the
            # single-completion deliveries that dominate long-tail
            # replays. Float division and comparison are IEEE-identical
            # to the one-element array ops below.
            lat = reqs[0].latency
            if lat is None:
                return
            self.n += 1
            self.quantiles.add_one(lat)
            norm = lat / slo_baseline_s
            for m in self.multipliers:
                if norm > m:
                    self.viol[m] += 1
            return
        lats = np.asarray([r.latency for r in reqs
                           if r.latency is not None], dtype=float)
        if lats.size == 0:
            return
        self.n += lats.size
        self.quantiles.add_many(lats)
        norm = lats / slo_baseline_s
        for m in self.multipliers:
            self.viol[m] += int((norm > m).sum())

    def describe(self) -> Dict[str, object]:
        """Provenance summary serialized as ``RunMetrics.streaming``."""
        q = self.quantiles
        d: Dict[str, object] = {"mode": "sketch" if q.is_sketch else "exact",
                                "n": int(self.n),
                                "exact_limit": int(q.exact_limit)}
        if q.is_sketch:
            d["bins"] = int(q.bins)
            d["rel_err_bound"] = _round(q.rel_err_bound)
        return d


@dataclasses.dataclass
class RunMetrics:
    """The one record every simulation run emits."""
    scenario: str
    policy: str
    seed: int
    duration_s: float
    n_arrived: int
    n_completed: int
    n_dropped: int
    latency_ms: Dict[str, float]          # p50 / p90 / p95 / p99
    slo_violation_rate: Dict[str, float]  # str(multiplier) -> rate
    cost_usd: float
    cost_per_1k_usd: float
    gpu_seconds: float
    cold_starts: int
    scaling_actions: Dict[str, int]       # vup / vdown / hup / hdown
    peak_gpus: int
    # time-averaged free-slice fraction on used chips; None (and absent
    # from the JSON) for reference-fleet runs — legacy goldens pin the
    # exact serialized byte stream
    fragmentation: Optional[float] = None
    # model-state lifecycle metrics (core/modelstate.py): pod starts by
    # residency tier and time-to-ready percentiles; None (and absent
    # from the JSON) unless an active lifecycle tracker ran — legacy
    # goldens stay byte-identical
    start_kinds: Optional[Dict[str, int]] = None      # cold / warm / hot
    time_to_ready_ms: Optional[Dict[str, float]] = None   # p50 / p99
    # spot preemption accounting (core/events.py reclaim path); None
    # (and absent from the JSON) unless the fleet declares a spot
    # market — legacy goldens stay byte-identical
    preemptions: Optional[Dict[str, int]] = None
    # fault-layer accounting (core/faults.py): fault counts per kind,
    # retries granted, the shed-vs-aged-vs-killed drop breakdown, mean
    # time to recovery after chip failures (None when nothing failed),
    # and capacity availability. All None (and absent from the JSON)
    # unless the run armed a fault model or a resilience config —
    # legacy goldens stay byte-identical
    faults: Optional[Dict[str, int]] = None
    retries: Optional[int] = None
    drop_breakdown: Optional[Dict[str, int]] = None   # aged/killed/shed
    mttr_s: Optional[float] = None
    availability: Optional[float] = None
    # streaming-metrics provenance (wide engine, ``stream_metrics``
    # runs): accumulator mode (exact vs sketch), sample count, and the
    # sketch's error bound when spilled. None (and absent from the
    # JSON) for retain-everything runs — legacy goldens stay
    # byte-identical
    streaming: Optional[Dict] = None

    # ---- construction ------------------------------------------------------
    @classmethod
    def from_sim(cls, sim, scenario: str, policy: str, seed: int,
                 slo_multipliers=DEFAULT_MULTIPLIERS) -> "RunMetrics":
        """Fold a finished ``ClusterSimulator`` / ``MultiFunctionSimulator``
        (anything wrapping an ``EventEngine``) into one record."""
        engine = sim.engine
        lat_parts: List[np.ndarray] = []
        norm_parts: List[np.ndarray] = []
        n_arrived = n_completed = n_dropped = cold = 0
        actions = {k: 0 for k in ACTION_KINDS}
        for st in engine.fns.values():
            base = perf_model.slo_baseline(st.spec,
                                           baseline_batch_of(st.policy))
            lats = np.array([r.latency for r in st.completed
                             if r.latency is not None], dtype=float)
            lat_parts.append(lats)
            norm_parts.append(lats / base)
            norm_parts.append(np.full(st.dropped, np.inf))
            n_arrived += len(st.arrivals)
            n_completed += len(lats)
            n_dropped += st.dropped
            cold += st.cold_starts
            for k in ACTION_KINDS:
                actions[k] += st.action_counts.get(k, 0)
        # the wide engine's stream-metrics runs fold completions into a
        # RunStreamStats sink instead of retaining them: percentiles
        # and violation counts come from the accumulator (violations
        # exact; dropped requests still count as inf at every
        # multiplier, matching the pooled semantics)
        sink = getattr(engine, "stream_stats", None)
        streaming = None
        if sink is not None:
            missing = [m for m in slo_multipliers
                       if float(m) not in sink.viol]
            if missing:
                raise ValueError(
                    f"streaming sink lacks multipliers {missing}: pass "
                    f"them via SimConfig.stream_slo_multipliers (sink "
                    f"tracks {sorted(sink.viol)})")
            pcts = sink.quantiles.percentiles()
            n_completed = int(sink.n)
            denom = sink.n + n_dropped
            viol = {str(float(m)):
                    ((sink.viol[float(m)] + n_dropped) / denom
                     if denom else 1.0)
                    for m in slo_multipliers}
            streaming = sink.describe()
        else:
            lats = np.concatenate(lat_parts) if lat_parts else np.empty(0)
            norm = np.concatenate(norm_parts) if norm_parts else np.empty(0)
            pcts = percentiles(lats)
            viol = {str(float(m)): (float((norm > m).mean()) if len(norm)
                                    else 1.0)
                    for m in slo_multipliers}
        cost = engine.cost
        # surface fragmentation only for non-reference fleets: the
        # serialized record of an all-default run must stay bitwise
        # what it was before heterogeneity existed
        frag = None
        fleet = getattr(engine.recon, "fleet", ())
        if any(t != DEFAULT_GPU_TYPE for t, _ in fleet):
            frag = float(engine.fragmentation_avg())
        # lifecycle runs additionally carry per-tier start counts and
        # time-to-ready percentiles; absent otherwise (golden pin)
        start_kinds = ttr_ms = None
        tracker = getattr(engine.recon, "modelstate", None)
        if tracker is not None and not tracker.is_passive:
            start_kinds = {"cold": 0, "warm": 0, "hot": 0}
            for st in engine.fns.values():
                for k in start_kinds:
                    start_kinds[k] += st.start_counts.get(k, 0)
            pcts_s = tracker.ttr_percentiles()
            if pcts_s is not None:
                ttr_ms = {k: v * 1e3 for k, v in pcts_s.items()}
        # spot fleets additionally carry the preemption counters
        preempt = None
        if any(getattr(t, "market", None) is not None for t, _ in fleet):
            preempt = dict(getattr(engine, "preempt", {}) or {})
        # fault-layer runs carry the chaos/resilience accounting
        faults = retries = drop_breakdown = mttr = avail = None
        if getattr(engine, "fault_layer_active", False):
            faults = dict(engine.fault_counts)
            retries = int(engine.retries)
            drop_breakdown = {"aged": 0, "killed": 0, "shed": 0}
            for st in engine.fns.values():
                for k in drop_breakdown:
                    drop_breakdown[k] += st.drop_kinds.get(k, 0)
            if engine.mttr_samples:
                mttr = float(np.mean(engine.mttr_samples))
            avail = float(engine.availability())
        return cls(
            scenario=scenario, policy=policy, seed=int(seed),
            duration_s=float(engine.cfg.duration_s),
            n_arrived=n_arrived, n_completed=n_completed,
            n_dropped=n_dropped,
            latency_ms={k: v * 1e3 for k, v in pcts.items()},
            slo_violation_rate=viol,
            cost_usd=cost.total_usd,
            cost_per_1k_usd=cost.per_1k_requests(n_completed),
            gpu_seconds=cost.gpu_seconds,
            cold_starts=cold, scaling_actions=actions,
            peak_gpus=int(engine.peak_gpus),
            fragmentation=frag,
            start_kinds=start_kinds, time_to_ready_ms=ttr_ms,
            preemptions=preempt,
            faults=faults, retries=retries, drop_breakdown=drop_breakdown,
            mttr_s=mttr, availability=avail,
            streaming=streaming)

    # ---- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if d.get("fragmentation") is None:
            d.pop("fragmentation", None)   # reference-fleet runs omit it
        else:
            d["fragmentation"] = _jsonf(d["fragmentation"])
        if d.get("start_kinds") is None:   # non-lifecycle runs omit both
            d.pop("start_kinds", None)
        if d.get("time_to_ready_ms") is None:
            d.pop("time_to_ready_ms", None)
        else:
            d["time_to_ready_ms"] = {
                k: _jsonf(v) for k, v in sorted(d["time_to_ready_ms"].items())}
        if d.get("preemptions") is None:   # market-free runs omit it
            d.pop("preemptions", None)
        else:
            d["preemptions"] = dict(sorted(d["preemptions"].items()))
        if d.get("faults") is None:   # fault-layer-free runs omit all five
            for k in ("faults", "retries", "drop_breakdown", "mttr_s",
                      "availability"):
                d.pop(k, None)
        else:
            d["faults"] = dict(sorted(d["faults"].items()))
            d["drop_breakdown"] = dict(sorted((d["drop_breakdown"]
                                               or {}).items()))
            # mttr_s stays null when no outage ever opened
            if d.get("mttr_s") is not None:
                d["mttr_s"] = _jsonf(d["mttr_s"])
            if d.get("availability") is not None:
                d["availability"] = _jsonf(d["availability"])
        if d.get("streaming") is None:   # retain-everything runs omit it
            d.pop("streaming", None)
        else:
            d["streaming"] = dict(sorted(d["streaming"].items()))
        for k in ("duration_s", "cost_usd", "cost_per_1k_usd",
                  "gpu_seconds"):
            d[k] = _jsonf(d[k])
        d["latency_ms"] = {k: _jsonf(v)
                           for k, v in sorted(d["latency_ms"].items())}
        d["slo_violation_rate"] = {
            k: _jsonf(v) for k, v in sorted(d["slo_violation_rate"].items())}
        d["scaling_actions"] = {k: d["scaling_actions"].get(k, 0)
                                for k in ACTION_KINDS}
        return d

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_dict(cls, d: dict) -> "RunMetrics":
        fields = {f.name for f in dataclasses.fields(cls)}
        d = {k: v for k, v in d.items() if k in fields}
        for k in ("cost_per_1k_usd",):
            d[k] = _unjsonf(d.get(k))
        for k in ("latency_ms", "slo_violation_rate"):
            d[k] = {sub: _unjsonf(v) for sub, v in d.get(k, {}).items()}
        # optional float dicts must round-trip non-finite values too:
        # to_dict nulls them via _jsonf, so from_dict must _unjsonf them
        # symmetrically (a loaded golden otherwise compares None != inf)
        if d.get("time_to_ready_ms") is not None:
            d["time_to_ready_ms"] = {
                sub: _unjsonf(v) for sub, v in d["time_to_ready_ms"].items()}
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "RunMetrics":
        return cls.from_dict(json.loads(s))

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path) -> "RunMetrics":
        with open(path) as f:
            return cls.from_json(f.read())

    # ---- regression diffing ------------------------------------------------
    def diff(self, other: "RunMetrics", rel: float = 1e-6,
             abs_tol: float = 1e-9) -> List[str]:
        """Readable field-by-field differences vs ``other`` (the fresh
        run), empty when everything matches within tolerance. Counts and
        labels compare exactly; floats within ``rel``/``abs_tol``."""

        def close(a, b):
            if a is None or b is None:  # serialized non-finite float
                return a == b
            if isinstance(a, float) or isinstance(b, float):
                a, b = float(a), float(b)
                if math.isinf(a) or math.isinf(b):
                    return a == b
                return math.isclose(a, b, rel_tol=rel, abs_tol=abs_tol)
            return a == b

        out = []
        mine, theirs = self.to_dict(), other.to_dict()
        for key in sorted(set(mine) | set(theirs)):
            a, b = mine.get(key), theirs.get(key)
            if isinstance(a, dict) or isinstance(b, dict):
                a, b = a or {}, b or {}
                for sub in sorted(set(a) | set(b)):
                    if not close(a.get(sub, float("nan")),
                                 b.get(sub, float("nan"))):
                        out.append(f"{key}[{sub}]: golden={a.get(sub)} "
                                   f"run={b.get(sub)}")
            elif not close(a, b):
                out.append(f"{key}: golden={a} run={b}")
        return out
