"""RaPP predictor: GAT blocks over the operator graph + global-feature MLP
-> inference latency for any (batch, SM partition, quota) configuration.

DIPPM baseline (Panner Selvam & Brorsson 2023): same skeleton, but only
STATIC features — per-op runtime profiles and the graph quota profile are
zeroed (the paper retrofits resource configs into its static features and
retrains; `with_runtime=False` reproduces exactly that).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gpus import DEFAULT_GPU_TYPE
from repro.core.rapp import features as F
from repro.core.rapp import gat


@dataclasses.dataclass(frozen=True)
class RaPPConfig:
    gat_dim: int = 32
    gat_heads: int = 4
    gat_layers: int = 3
    mlp_hidden: int = 128
    with_runtime: bool = True  # False -> DIPPM-style static-only


def init_params(rng, cfg: RaPPConfig = RaPPConfig()):
    ks = jax.random.split(rng, cfg.gat_layers + 3)
    layers = []
    in_dim = F.NODE_F
    for i in range(cfg.gat_layers):
        layers.append(gat.init_gat_layer(ks[i], in_dim, cfg.gat_dim,
                                         cfg.gat_heads))
        in_dim = cfg.gat_dim * cfg.gat_heads
    return {
        "gat": layers,
        "global_mlp": gat.init_mlp(ks[-3], [F.GLOBAL_F, cfg.mlp_hidden,
                                            cfg.mlp_hidden]),
        "head": gat.init_mlp(ks[-2], [in_dim + cfg.mlp_hidden,
                                      cfg.mlp_hidden, cfg.mlp_hidden // 2, 1]),
    }


def forward_one(params, node_feats, adj, mask, global_feats, prior=0.0):
    """Residual head: output = prior (closed-form log-ms anchor from the
    runtime quota profile; 0 for the static-only baseline) + GNN delta."""
    h = node_feats
    for layer in params["gat"]:
        h = gat.gat_layer(layer, h, adj, mask)
    denom = jnp.maximum(mask.sum(), 1.0)
    pooled = (h * mask[:, None]).sum(0) / denom      # mean pool
    g = gat.mlp(params["global_mlp"], global_feats, final_linear=False)
    out = gat.mlp(params["head"], jnp.concatenate([pooled, g]))
    return prior + out[0]  # log-latency (ms)


forward_batch = jax.vmap(forward_one, in_axes=(None, 0, 0, 0, 0, 0))

# config-lattice variant: one graph, many (sm, quota) points — node
# features / adjacency / mask are shared (in_axes=None), only global
# features and priors carry the per-point configuration
forward_lattice = jax.vmap(forward_one,
                           in_axes=(None, None, None, None, 0, 0))


def predict_latency_ms(params, batch_dict):
    """batch_dict of stacked tensorized samples -> latency in ms."""
    logl = forward_batch(params, batch_dict["node_feats"],
                         batch_dict["adj"], batch_dict["mask"],
                         batch_dict["global"], batch_dict["prior"])
    return jnp.expm1(jnp.maximum(logl, 0.0)) + 1e-6


_GRAPH_CACHE = {}   # (arch name, batch, seq) -> coarsened OpGraph


def _profile_rng(seed: int, arch_name: str, batch: int, seq: int,
                 gpu=DEFAULT_GPU_TYPE) -> np.random.Generator:
    """Profiling-noise generator derived from the query key.

    The profile noise models *measurement* jitter, so it must be a
    fixed property of what was profiled — a shared generator made
    predicted latencies depend on query ORDER. The profiles are
    measured once per (arch, batch, device) and reused for every
    queried (sm, quota), exactly like the paper's runtime profiler, so
    the seed covers the (arch, batch, device) part of the query key.
    blake2s (not Python `hash`, which is salted per process) keys the
    stream stably; the reference device keeps the legacy tag so its
    streams (and hence predictions) are unchanged."""
    tag = f"{seed}|{arch_name}|{batch}|{seq}"
    if gpu is not None and gpu != DEFAULT_GPU_TYPE:
        tag += f"|{gpu.name}"
    digest = hashlib.blake2s(tag.encode(), digest_size=8).digest()
    return np.random.default_rng(int.from_bytes(digest, "little"))


class RaPPModel:
    """Trained-weights wrapper exposing the autoscaler predictor protocol:
    lat(spec, batch, sm, quota) -> seconds.

    Scalar queries run one jitted `forward_one`; the control plane's
    CapacityTable instead calls `predict_lattice`, which tensorizes every
    (sm, quota) lattice point into stacked arrays and runs ONE
    `forward_batch` vmap — a single device round-trip per (spec, batch)
    instead of one per lattice point."""

    # shared across instances so fresh models reuse XLA compilations
    _jit = staticmethod(jax.jit(forward_one))
    _jit_lattice = staticmethod(jax.jit(forward_lattice))

    def __init__(self, params, cfg: RaPPConfig = RaPPConfig(), seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.seed = seed
        self._cache = {}
        self._shared = {}   # (arch name, batch) -> shared tensorization

    def _graph(self, spec, batch):
        key = (spec.arch.name, batch, spec.seq)
        if key not in _GRAPH_CACHE:
            # coarsen once at extraction: tensorize's fit-check then
            # short-circuits on every lattice point; cached process-wide
            # (graphs are pure functions of (arch, batch, seq))
            g = F.extract_graph(spec.arch, batch, seq=spec.seq)
            _GRAPH_CACHE[key] = F._coarsen(g, F.MAX_NODES)
        return _GRAPH_CACHE[key]

    def _shared_tensors(self, spec, batch, gpu=None):
        gpu = gpu or DEFAULT_GPU_TYPE
        key = (spec.arch.name, batch, spec.seq, gpu.name)
        if key not in self._shared:
            rng = _profile_rng(self.seed, spec.arch.name, batch, spec.seq,
                               gpu)
            self._shared[key] = F.tensorize_shared(
                self._graph(spec, batch), spec, batch, rng,
                with_runtime=self.cfg.with_runtime, gpu=gpu)
        return self._shared[key]

    def __call__(self, spec, batch, sm, quota, gpu=None) -> float:
        gpu = gpu or DEFAULT_GPU_TYPE
        key = (spec.arch.name, batch, spec.seq, sm, round(quota, 3),
               gpu.name)
        if key in self._cache:
            return self._cache[key]
        sh = self._shared_tensors(spec, batch, gpu)
        g, prior = F._assemble(sh, sm, quota)
        logl = self._jit(self.params, sh["node_feats"], sh["adj"],
                         sh["mask"], g, prior)
        lat_s = float(np.expm1(max(float(logl), 0.0)) + 1e-6) / 1e3
        self._cache[key] = lat_s
        return lat_s

    def predict_lattice(self, spec, batch, sms, quotas,
                        gpu=None) -> np.ndarray:
        """(len(sms), len(quotas)) latency seconds for the full lattice
        on device ``gpu`` (reference when None), evaluated in one
        batched forward pass."""
        gpu = gpu or DEFAULT_GPU_TYPE
        points = [(int(sm), float(q)) for sm in sms for q in quotas]
        sh = self._shared_tensors(spec, batch, gpu)
        t = F.tensorize_lattice(None, spec, batch, points, None,
                                shared=sh)
        logl = np.asarray(self._jit_lattice(
            self.params, t["node_feats"], t["adj"], t["mask"],
            t["global"], t["prior"]))
        lat_s = (np.expm1(np.maximum(logl.astype(np.float64), 0.0))
                 + 1e-6) / 1e3
        for (sm, q), v in zip(points, lat_s):
            # first writer wins so scalar and lattice paths never
            # disagree about an already-served key
            self._cache.setdefault(
                (spec.arch.name, batch, spec.seq, sm, round(q, 3),
                 gpu.name),
                float(v))
        return lat_s.reshape(len(sms), len(quotas))
