"""RaPP predictor: GAT blocks over the operator graph + global-feature MLP
-> inference latency for any (batch, SM partition, quota) configuration.

DIPPM baseline (Panner Selvam & Brorsson 2023): same skeleton, but only
STATIC features — per-op runtime profiles and the graph quota profile are
zeroed (the paper retrofits resource configs into its static features and
retrains; `with_runtime=False` reproduces exactly that).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rapp import features as F
from repro.core.rapp import gat


@dataclasses.dataclass(frozen=True)
class RaPPConfig:
    gat_dim: int = 32
    gat_heads: int = 4
    gat_layers: int = 3
    mlp_hidden: int = 128
    with_runtime: bool = True  # False -> DIPPM-style static-only


def init_params(rng, cfg: RaPPConfig = RaPPConfig()):
    ks = jax.random.split(rng, cfg.gat_layers + 3)
    layers = []
    in_dim = F.NODE_F
    for i in range(cfg.gat_layers):
        layers.append(gat.init_gat_layer(ks[i], in_dim, cfg.gat_dim,
                                         cfg.gat_heads))
        in_dim = cfg.gat_dim * cfg.gat_heads
    return {
        "gat": layers,
        "global_mlp": gat.init_mlp(ks[-3], [F.GLOBAL_F, cfg.mlp_hidden,
                                            cfg.mlp_hidden]),
        "head": gat.init_mlp(ks[-2], [in_dim + cfg.mlp_hidden,
                                      cfg.mlp_hidden, cfg.mlp_hidden // 2, 1]),
    }


def forward_one(params, node_feats, adj, mask, global_feats, prior=0.0):
    """Residual head: output = prior (closed-form log-ms anchor from the
    runtime quota profile; 0 for the static-only baseline) + GNN delta."""
    h = node_feats
    for layer in params["gat"]:
        h = gat.gat_layer(layer, h, adj, mask)
    denom = jnp.maximum(mask.sum(), 1.0)
    pooled = (h * mask[:, None]).sum(0) / denom      # mean pool
    g = gat.mlp(params["global_mlp"], global_feats, final_linear=False)
    out = gat.mlp(params["head"], jnp.concatenate([pooled, g]))
    return prior + out[0]  # log-latency (ms)


forward_batch = jax.vmap(forward_one, in_axes=(None, 0, 0, 0, 0, 0))


def predict_latency_ms(params, batch_dict):
    """batch_dict of stacked tensorized samples -> latency in ms."""
    logl = forward_batch(params, batch_dict["node_feats"],
                         batch_dict["adj"], batch_dict["mask"],
                         batch_dict["global"], batch_dict["prior"])
    return jnp.expm1(jnp.maximum(logl, 0.0)) + 1e-6


class RaPPModel:
    """Trained-weights wrapper exposing the autoscaler predictor protocol:
    lat(spec, batch, sm, quota) -> seconds."""

    def __init__(self, params, cfg: RaPPConfig = RaPPConfig(), seed: int = 0):
        self.params = params
        self.cfg = cfg
        self._graphs = {}
        self._rng = np.random.default_rng(seed)
        self._jit = jax.jit(forward_one)
        self._cache = {}

    def _graph(self, spec, batch):
        key = (spec.arch.name, batch)
        if key not in self._graphs:
            from repro.configs import reduced
            self._graphs[key] = F.extract_graph(spec.arch, batch,
                                                seq=spec.seq)
        return self._graphs[key]

    def __call__(self, spec, batch, sm, quota) -> float:
        key = (spec.arch.name, batch, sm, round(quota, 3))
        if key in self._cache:
            return self._cache[key]
        g = self._graph(spec, batch)
        t = F.tensorize(g, spec, batch, sm, quota, self._rng,
                        with_runtime=self.cfg.with_runtime)
        logl = self._jit(self.params, t["node_feats"], t["adj"], t["mask"],
                         t["global"], t["prior"])
        lat_s = float(np.expm1(max(float(logl), 0.0)) + 1e-6) / 1e3
        self._cache[key] = lat_s
        return lat_s
