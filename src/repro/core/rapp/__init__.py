from repro.core.rapp.predictor import RaPPConfig, RaPPModel, init_params
from repro.core.rapp import dataset, features, train

__all__ = ["RaPPConfig", "RaPPModel", "init_params", "dataset", "features",
           "train"]
