"""RaPP latency dataset generation.

The paper profiles all official PyTorch models under various (batch, SM,
quota) configs: 53,400 samples split 42,720 / 5,340 / 5,340. Our model zoo
is the 10 assigned architectures plus synthetic same-family variants
(depth/width jittered) for diversity. Labels are noisy measurements of the
roofline oracle (the simulator's physics). The test split holds out BOTH
unseen configurations and entire unseen architectures (paper §4.2 tests
"unseen configurations and models").
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional

import numpy as np

from repro.configs import ARCHS, ArchConfig, reduced
from repro.configs.gpus import DEFAULT_GPU_TYPE, get_gpu_type
from repro.core import perf_model
from repro.core.perf_model import FnSpec
from repro.core.rapp import features as F

BATCHES = (1, 2, 4, 8, 16, 32)
SMS = (1, 2, 3, 4, 5, 6, 7, 8)
QUOTAS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0)


def _variant(cfg: ArchConfig, rng: np.random.Generator) -> ArchConfig:
    """Same-family synthetic variant (diversifies the training corpus)."""
    import dataclasses as dc
    scale = float(rng.choice([0.5, 0.75, 1.25, 1.5]))
    layers = max(2, int(cfg.num_layers * float(rng.choice([0.25, 0.5, 0.75]))))
    d_model = int(cfg.d_model * scale) // 128 * 128 or 128
    heads = max(1, cfg.num_heads)
    updates = dict(num_layers=layers, d_model=d_model,
                   name=f"{cfg.name}-var{layers}x{d_model}")
    if cfg.d_ff:
        updates["d_ff"] = int(cfg.d_ff * scale) // 128 * 128 or 256
    return dc.replace(cfg, **updates)


def build_corpus(n_variants_per_arch: int = 2, seed: int = 0
                 ) -> List[ArchConfig]:
    rng = np.random.default_rng(seed)
    corpus = list(ARCHS.values())
    for cfg in list(ARCHS.values()):
        for _ in range(n_variants_per_arch):
            try:
                corpus.append(_variant(cfg, rng))
            except Exception:
                pass
    return corpus


@dataclasses.dataclass
class Dataset:
    node_feats: np.ndarray
    adj: np.ndarray
    mask: np.ndarray
    global_feats: np.ndarray
    priors: np.ndarray
    labels_logms: np.ndarray
    arch_names: np.ndarray

    def __len__(self):
        return len(self.labels_logms)

    def subset(self, idx):
        return Dataset(self.node_feats[idx], self.adj[idx], self.mask[idx],
                       self.global_feats[idx], self.priors[idx],
                       self.labels_logms[idx], self.arch_names[idx])


def generate(corpus: Optional[List[ArchConfig]] = None,
             batches=BATCHES, sms=SMS, quotas=QUOTAS,
             samples_per_graph: int = 24, seed: int = 0,
             with_runtime: bool = True, verbose: bool = False,
             gpu_types=(DEFAULT_GPU_TYPE,), calibration=None) -> Dataset:
    """Sample (arch, batch) graphs x random (sm, quota) configs.

    ``gpu_types`` widens the corpus across device classes: each sampled
    config is measured (features AND label) on one of the given types,
    so a single model learns the cross-device latency surface via the
    device-descriptor features. The default single-reference tuple
    reproduces the legacy dataset exactly.

    ``calibration`` (a ``repro.profiling.CalibrationTable``) replaces
    the oracle label with the MEASURED latency for every sampled config
    the table covers — the paper's setting, where RaPP trains on models
    profiled on hardware. Configs the table misses keep the noisy
    oracle label, so a partial profile still yields a full dataset."""
    rng = np.random.default_rng(seed)
    corpus = corpus or build_corpus()
    gpu_types = [get_gpu_type(t) for t in gpu_types]
    rows = {k: [] for k in ("node_feats", "adj", "mask", "global", "prior")}
    labels, names = [], []
    for cfg in corpus:
        for b in batches:
            try:
                graph = F.extract_graph(cfg, b)
            except Exception as e:
                if verbose:
                    print(f"skip {cfg.name} b={b}: {e}")
                continue
            spec = FnSpec(cfg)
            n_rows = 0
            for gpu in gpu_types:
                # configs wider than the device saturate at its width
                dev_sms = tuple(min(s, gpu.sm_total) for s in sms)
                combos = sorted(set(itertools.product(dev_sms, quotas)))
                pick = rng.choice(len(combos),
                                  size=min(samples_per_graph, len(combos)),
                                  replace=False)
                for ci in pick:
                    sm, q = combos[ci]
                    t = F.tensorize(graph, spec, b, sm, q, rng,
                                    with_runtime=with_runtime, gpu=gpu)
                    label = None
                    if calibration is not None:
                        label = calibration.latency(spec, b, sm, q,
                                                    gpu=gpu)
                    if label is None:
                        label = perf_model.latency(spec, b, sm, q,
                                                   rng=rng, gpu=gpu)
                    for k in rows:
                        rows[k].append(t[k])
                    labels.append(np.log1p(label * 1e3))  # log(ms)
                    names.append(cfg.name)
                n_rows += len(pick)
            if verbose:
                print(f"{cfg.name} b={b}: {n_rows} samples", flush=True)
    return Dataset(
        node_feats=np.stack(rows["node_feats"]),
        adj=np.stack(rows["adj"]),
        mask=np.stack(rows["mask"]),
        global_feats=np.stack(rows["global"]),
        priors=np.array(rows["prior"], np.float32),
        labels_logms=np.array(labels, np.float32),
        arch_names=np.array(names))


def split(ds: Dataset, holdout_archs=("gemma-7b", "deepseek-moe-16b"),
          val_frac: float = 0.1, seed: int = 0):
    """Train/val/test: test = unseen archs + random unseen configs."""
    rng = np.random.default_rng(seed)
    is_holdout = np.isin(ds.arch_names, holdout_archs)
    rest = np.where(~is_holdout)[0]
    rng.shuffle(rest)
    n_val = int(len(rest) * val_frac)
    n_test_cfg = int(len(rest) * val_frac)
    val_idx = rest[:n_val]
    test_cfg_idx = rest[n_val:n_val + n_test_cfg]
    train_idx = rest[n_val + n_test_cfg:]
    test_idx = np.concatenate([np.where(is_holdout)[0], test_cfg_idx])
    return ds.subset(train_idx), ds.subset(val_idx), ds.subset(test_idx)
