"""RaPP feature extraction: jaxpr -> operator graph (+ runtime profiles).

The paper converts models to TVM Relay IRModule and extracts (a) static
operator/graph features and (b) *runtime* features: per-operator latency
profiled under a full time quota and 6 SM partitions, plus whole-graph
latency under a full SM allocation and 5 quotas. Here the IR is the jaxpr
of the architecture's forward pass (the JAX-native unified IR); `lax.scan`
bodies are summarized into single nodes with trip-count-scaled features,
keeping graphs compact for every architecture.

Runtime profiles come from the op-level micro-profiler below — a
shape-driven roofline of each operator at slice granularity with
measurement noise, standing in for TVM's debug-executor timings. RaPP
never sees the simulator's full-model oracle; it must learn quota/window
effects and graph aggregation from these per-op signals, as in the paper.

Heterogeneous fleets: profiles are measured on the queried device (the
profiler runs per device class, like any real benchmark harness), and
the global feature vector carries a 3-dim device descriptor (log peak-
FLOPs ratio, log bandwidth ratio, slice-count ratio vs the reference
chip) so ONE RaPP model predicts across GPU types.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.configs.gpus import DEFAULT_GPU_TYPE, GPUType
from repro.core.vgpu import TOTAL_SLICES

OP_CLASSES = ("dot", "conv", "elementwise", "reduce", "gather",
              "scan", "other")
N_OP_CLASSES = len(OP_CLASSES)
SM_PROFILE_POINTS = (1, 2, 3, 4, 6, 8)       # paper: six SM configurations
QUOTA_PROFILE_POINTS = (0.2, 0.4, 0.6, 0.8, 1.0)  # paper: five quotas

PEAK_FLOPS = DEFAULT_GPU_TYPE.peak_flops
HBM_BW = DEFAULT_GPU_TYPE.hbm_bw
N_DEVICE_F = 3   # device descriptor dims in the global feature head

_ELEMENTWISE = {"add", "sub", "mul", "div", "max", "min", "exp", "log",
                "tanh", "logistic", "rsqrt", "sqrt", "pow", "integer_pow",
                "neg", "sign", "select_n", "convert_element_type", "custom_jvp_call",
                "erf", "abs", "floor", "ceil", "round", "clamp", "and", "or",
                "xor", "not", "cos", "sin", "squeeze", "expand_dims"}
_REDUCE = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
           "argmax", "argmin", "cumsum", "cumprod", "cumlogsumexp",
           "reduce_and", "reduce_or", "logsumexp", "reduce_precision"}
_GATHER = {"gather", "scatter", "scatter-add", "scatter_add", "take",
           "dynamic_slice", "dynamic_update_slice", "sort", "top_k",
           "iota", "one_hot", "argsort"}


@dataclasses.dataclass
class OpNode:
    op_class: int
    flops: float
    bytes_in: float
    bytes_out: float
    max_dim: float
    contraction: float
    trips: float


@dataclasses.dataclass
class OpGraph:
    nodes: List[OpNode]
    edges: List[Tuple[int, int]]
    total_flops: float
    total_bytes: float
    class_counts: np.ndarray  # (N_OP_CLASSES,)


def _var_bytes(v) -> float:
    try:
        return float(np.prod(v.aval.shape) * v.aval.dtype.itemsize)
    except Exception:
        return 0.0


def _classify(prim_name: str) -> int:
    if prim_name in ("dot_general",):
        return OP_CLASSES.index("dot")
    if "conv" in prim_name:
        return OP_CLASSES.index("conv")
    if prim_name in ("scan", "while", "fori_loop"):
        return OP_CLASSES.index("scan")
    if prim_name in _ELEMENTWISE:
        return OP_CLASSES.index("elementwise")
    if prim_name in _REDUCE or prim_name.startswith("reduce"):
        return OP_CLASSES.index("reduce")
    if prim_name in _GATHER:
        return OP_CLASSES.index("gather")
    return OP_CLASSES.index("other")


def _eqn_flops(eqn) -> Tuple[float, float]:
    """(flops, contraction_size) estimate for one equation."""
    prim = eqn.primitive.name
    out_elems = sum(float(np.prod(v.aval.shape)) for v in eqn.outvars
                    if hasattr(v.aval, "shape"))
    if prim == "dot_general":
        dims = eqn.params["dimension_numbers"]
        (lc, _), _ = dims
        lhs_shape = eqn.invars[0].aval.shape
        contraction = float(np.prod([lhs_shape[i] for i in lc])) if lc else 1.0
        return 2.0 * out_elems * contraction, contraction
    if "conv" in prim:
        rhs = eqn.invars[1].aval.shape if len(eqn.invars) > 1 else (1,)
        k = float(np.prod(rhs[:-1]))
        return 2.0 * out_elems * k, k
    if prim in _REDUCE:
        in_elems = sum(float(np.prod(v.aval.shape)) for v in eqn.invars
                       if hasattr(v.aval, "shape"))
        return in_elems, 1.0
    if prim in _ELEMENTWISE:
        return out_elems, 1.0
    return 0.0, 1.0


def _walk(jaxpr, trips: float, nodes, edges, var_producer):
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in ("scan", "while", "closed_call", "pjit", "custom_vjp_call",
                    "custom_jvp_call", "remat", "checkpoint", "cond"):
            # descend; scan multiplies trip count and is itself a node
            inner_trips = trips
            sub = None
            if prim == "scan":
                inner_trips = trips * eqn.params.get("length", 1)
                sub = eqn.params["jaxpr"].jaxpr
            elif prim in ("closed_call", "pjit", "custom_vjp_call",
                          "custom_jvp_call", "remat", "checkpoint"):
                j = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                sub = j.jaxpr if hasattr(j, "jaxpr") else j
            elif prim == "cond":
                branches = eqn.params.get("branches", ())
                sub = branches[0].jaxpr if branches else None
            if sub is not None:
                n_before = len(nodes)
                _walk(sub, inner_trips, nodes, edges, {})
                if prim == "scan":
                    # connect scan region sequentially to the outer graph
                    for v in eqn.invars:
                        p = var_producer.get(id(v))
                        if p is not None and n_before < len(nodes):
                            edges.append((p, n_before))
                for v in eqn.outvars:
                    var_producer[id(v)] = len(nodes) - 1 if nodes else 0
                continue
        flops, contraction = _eqn_flops(eqn)
        b_in = sum(_var_bytes(v) for v in eqn.invars
                   if hasattr(v, "aval"))
        b_out = sum(_var_bytes(v) for v in eqn.outvars)
        dims = [d for v in eqn.outvars if hasattr(v.aval, "shape")
                for d in v.aval.shape]
        node = OpNode(op_class=_classify(prim), flops=flops * trips,
                      bytes_in=b_in * trips, bytes_out=b_out * trips,
                      max_dim=float(max(dims) if dims else 1),
                      contraction=contraction, trips=trips)
        idx = len(nodes)
        nodes.append(node)
        for v in eqn.invars:
            p = var_producer.get(id(v))
            if p is not None:
                edges.append((p, idx))
        for v in eqn.outvars:
            var_producer[id(v)] = idx


def extract_graph(cfg: ArchConfig, batch: int, seq: int = 128) -> OpGraph:
    """Trace the forward pass and build the operator graph."""
    from repro import models
    from repro.models import CallOpts

    params = jax.eval_shape(lambda r: models.init_params(r, cfg),
                            jax.random.PRNGKey(0))
    batch_spec = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.is_encoder_decoder:
        batch_spec["frame_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.num_visual_tokens:
        v = min(cfg.num_visual_tokens, 64)
        batch_spec["visual_embeds"] = jax.ShapeDtypeStruct(
            (batch, v, cfg.d_model), jnp.bfloat16)

    def fwd(p, b):
        logits, _ = models.forward(p, cfg, b, CallOpts(attn_chunk=1 << 30))
        return logits

    jaxpr = jax.make_jaxpr(fwd)(params, batch_spec)
    nodes, edges = [], []
    _walk(jaxpr.jaxpr, 1.0, nodes, edges, {})
    counts = np.zeros(N_OP_CLASSES)
    for n in nodes:
        counts[n.op_class] += 1
    return OpGraph(nodes=nodes, edges=edges,
                   total_flops=sum(n.flops for n in nodes),
                   total_bytes=sum(n.bytes_in + n.bytes_out for n in nodes),
                   class_counts=counts)


# ------------------------------------------------------------- runtime prof
def op_profile(node: OpNode, rng: np.random.Generator,
               gpu: GPUType = DEFAULT_GPU_TYPE) -> np.ndarray:
    """Per-operator latency at full quota under the 6 SM partitions —
    the stand-in for the paper's TVM-debug-executor Runtime Profiler,
    measured on the ``gpu`` device class (points wider than the device
    saturate at its full width)."""
    out = np.zeros(len(SM_PROFILE_POINTS), np.float32)
    # shape-driven MXU efficiency: small contractions underfeed the MXU
    for i, sm in enumerate(SM_PROFILE_POINTS):
        frac = min(sm, gpu.sm_total) / gpu.sm_total
        eff = min(1.0, node.contraction / (128.0 * frac * 8)) \
            if node.op_class == OP_CLASSES.index("dot") else 1.0
        eff = max(eff, 0.05)
        compute = node.flops / (frac * gpu.peak_flops * eff)
        memory = (node.bytes_in + node.bytes_out) / (frac * gpu.hbm_bw)
        t = max(compute, memory) + 1e-6
        out[i] = t * rng.lognormal(0.0, 0.05)
    return out


def graph_quota_profile(spec, batch: int, rng: np.random.Generator,
                        gpu: GPUType = DEFAULT_GPU_TYPE) -> np.ndarray:
    """Whole-graph latency at full SM under the 5 quota points (paper:
    'runtime profiler evaluates the model under a full SM configuration
    and five distinct quota configurations'), on the ``gpu`` device."""
    from repro.core import perf_model
    out = np.zeros(len(QUOTA_PROFILE_POINTS), np.float32)
    for i, q in enumerate(QUOTA_PROFILE_POINTS):
        out[i] = perf_model.latency(spec, batch, gpu.sm_total, q, rng=rng,
                                    gpu=gpu)
    return out


# ------------------------------------------------------------- tensorize
MAX_NODES = 160
NODE_STATIC_F = N_OP_CLASSES + 5
NODE_RUNTIME_F = len(SM_PROFILE_POINTS)
NODE_F = NODE_STATIC_F + NODE_RUNTIME_F
# totals, counts, (b, sm, q), device descriptor
GLOBAL_STATIC_F = 2 + N_OP_CLASSES + 3 + N_DEVICE_F
GLOBAL_RUNTIME_F = len(QUOTA_PROFILE_POINTS)
GLOBAL_F = GLOBAL_STATIC_F + GLOBAL_RUNTIME_F


def _coarsen(graph: OpGraph, max_nodes: int) -> OpGraph:
    """Merge low-flops nodes into their predecessors until it fits.

    Non-mutating: merges happen on copies, so a cached OpGraph can be
    tensorized any number of times with identical results (the previous
    in-place merge accumulated across calls, making features — and hence
    RaPP predictions — depend on how often a graph had been queried)."""
    if len(graph.nodes) <= max_nodes:
        return graph
    nodes = [dataclasses.replace(n) for n in graph.nodes]
    order = np.argsort([n.flops for n in nodes])
    keep = set(range(len(nodes)))
    merged_into = {}
    for idx in order:
        if len(keep) <= max_nodes:
            break
        preds = [a for a, b in graph.edges if b == idx and a in keep]
        if not preds:
            continue
        tgt = preds[-1]
        a, b = nodes[tgt], nodes[idx]
        a.flops += b.flops
        a.bytes_in += b.bytes_in
        a.bytes_out += b.bytes_out
        a.max_dim = max(a.max_dim, b.max_dim)
        keep.discard(idx)
        merged_into[idx] = tgt
    remap = {old: new for new, old in enumerate(sorted(keep))}

    def res(i):
        while i in merged_into:
            i = merged_into[i]
        return remap.get(i)

    new_edges = set()
    for a, b in graph.edges:
        ra, rb = res(a), res(b)
        if ra is not None and rb is not None and ra != rb:
            new_edges.add((ra, rb))
    kept = [nodes[i] for i in sorted(keep)]
    return OpGraph(kept, sorted(new_edges), graph.total_flops,
                   graph.total_bytes, graph.class_counts)


def device_descriptor(gpu: GPUType) -> np.ndarray:
    """The 3-dim device embedding carried in the global features:
    log peak-FLOPs ratio, log HBM-bandwidth ratio, and slice-count
    ratio, all vs the reference device (so the reference embeds as
    [0, 0, 1])."""
    return np.array(
        [np.log(gpu.peak_flops / DEFAULT_GPU_TYPE.peak_flops),
         np.log(gpu.hbm_bw / DEFAULT_GPU_TYPE.hbm_bw),
         gpu.sm_total / DEFAULT_GPU_TYPE.sm_total], np.float32)


def tensorize_shared(graph: OpGraph, spec, batch: int,
                     rng: np.random.Generator, with_runtime: bool = True,
                     gpu: GPUType = DEFAULT_GPU_TYPE):
    """The (sm, quota)-independent part of tensorization: node features
    (including the runtime profiles — measured once per (arch, batch,
    device), like the paper's profiler, NOT per queried config),
    adjacency, node mask, the global-feature head, and the raw quota
    profile. One call serves an entire (sm x quota) config lattice."""
    graph = _coarsen(graph, MAX_NODES)
    n = len(graph.nodes)
    feats = np.zeros((MAX_NODES, NODE_F), np.float32)
    for i, node in enumerate(graph.nodes[:MAX_NODES]):
        onehot = np.zeros(N_OP_CLASSES, np.float32)
        onehot[node.op_class] = 1.0
        static = np.array([np.log1p(node.flops), np.log1p(node.bytes_in),
                           np.log1p(node.bytes_out), np.log1p(node.max_dim),
                           np.log1p(node.trips)], np.float32)
        runtime = (np.log1p(op_profile(node, rng, gpu) * 1e6)
                   if with_runtime else np.zeros(NODE_RUNTIME_F, np.float32))
        feats[i] = np.concatenate([onehot, static, runtime])
    adj = np.zeros((MAX_NODES, MAX_NODES), np.float32)
    for a, b in graph.edges:
        if a < MAX_NODES and b < MAX_NODES:
            adj[a, b] = 1.0
            adj[b, a] = 1.0
    adj[np.arange(MAX_NODES), np.arange(MAX_NODES)] = 1.0
    mask = np.zeros(MAX_NODES, np.float32)
    mask[:min(n, MAX_NODES)] = 1.0
    head = np.concatenate([
        [np.log1p(graph.total_flops), np.log1p(graph.total_bytes)],
        np.log1p(graph.class_counts), [np.log1p(batch)]])
    if with_runtime:
        prof = graph_quota_profile(spec, batch, rng, gpu)  # s, full SM
        g_rt = np.log1p(prof * 1e3)
    else:
        prof = None
        g_rt = np.zeros(GLOBAL_RUNTIME_F, np.float32)
    return {"node_feats": feats, "adj": adj, "mask": mask,
            "head": head, "g_rt": g_rt, "prof": prof, "gpu": gpu}


def _assemble(shared, sm: int, quota: float):
    """Per-(sm, quota) completion of a shared tensorization (the device
    comes from the shared dict — profiles were measured on it)."""
    gpu = shared.get("gpu", DEFAULT_GPU_TYPE)
    g_static = np.concatenate(
        [shared["head"], [sm / gpu.sm_total, quota],
         device_descriptor(gpu)]).astype(np.float32)
    prof = shared["prof"]
    if prof is not None:
        # closed-form prior: interpolate the quota profile at this quota,
        # scale exec time by the slice fraction -> log-ms anchor the GNN
        # refines (residual learning; the static-only baseline has no
        # profile, hence prior = 0 — the paper's DIPPM handicap)
        q_lat = float(np.interp(quota, QUOTA_PROFILE_POINTS, prof))
        prior = np.log1p(q_lat * (gpu.sm_total / max(sm, 1)) * 1e3)
    else:
        prior = 0.0
    return (np.concatenate([g_static, shared["g_rt"]]).astype(np.float32),
            np.float32(prior))


def tensorize(graph: OpGraph, spec, batch: int, sm: int, quota: float,
              rng: np.random.Generator, with_runtime: bool = True,
              gpu: GPUType = DEFAULT_GPU_TYPE):
    """-> dict of numpy arrays: node_feats (MAX_NODES, NODE_F), adj mask,
    node mask, global feats (GLOBAL_F,)."""
    shared = tensorize_shared(graph, spec, batch, rng,
                              with_runtime=with_runtime, gpu=gpu)
    g, prior = _assemble(shared, sm, quota)
    return {"node_feats": shared["node_feats"], "adj": shared["adj"],
            "mask": shared["mask"], "global": g, "prior": prior}


def tensorize_lattice(graph: OpGraph, spec, batch: int, points,
                      rng: np.random.Generator, with_runtime: bool = True,
                      shared=None, gpu: GPUType = DEFAULT_GPU_TYPE):
    """Tensorize every (sm, quota) in ``points`` against ONE shared
    feature extraction: node features / adjacency / mask are common to
    the whole lattice (vmap them with in_axes=None); only the stacked
    global features and priors vary per point. Pass ``shared`` (a
    cached `tensorize_shared` result) to skip re-extraction — `graph`,
    `rng`, and `gpu` are then unused (the shared dict pins the
    device)."""
    if shared is None:
        shared = tensorize_shared(graph, spec, batch, rng,
                                  with_runtime=with_runtime, gpu=gpu)
    gs, priors = zip(*(_assemble(shared, sm, q) for sm, q in points))
    return {"node_feats": shared["node_feats"], "adj": shared["adj"],
            "mask": shared["mask"], "global": np.stack(gs),
            "prior": np.array(priors, np.float32)}
