"""Graph Attention (GAT, Velickovic et al. 2018) blocks in pure JAX.

Dense-adjacency formulation (graphs are padded to MAX_NODES): per head,
e_ij = LeakyReLU(a_src . Wh_i + a_dst . Wh_j), attention is softmaxed over
the masked neighborhood, and features aggregate as h'_i = ELU(sum_j a_ij
Wh_j). The attention mechanism captures potential kernel-fusion affinity
between adjacent operators (paper §3.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def init_gat_layer(rng, in_dim: int, out_dim: int, heads: int):
    k1, k2, k3 = jax.random.split(rng, 3)
    scale = 1.0 / np.sqrt(in_dim)
    return {
        "W": jax.random.normal(k1, (heads, in_dim, out_dim)) * scale,
        "a_src": jax.random.normal(k2, (heads, out_dim)) * scale,
        "a_dst": jax.random.normal(k3, (heads, out_dim)) * scale,
    }


def gat_layer(p, h, adj, mask):
    """h: (N, F); adj: (N, N) 1/0; mask: (N,) 1/0 -> (N, heads*out)."""
    hw = jnp.einsum("nf,hfo->hno", h, p["W"])          # (H, N, O)
    src = jnp.einsum("hno,ho->hn", hw, p["a_src"])     # (H, N)
    dst = jnp.einsum("hno,ho->hn", hw, p["a_dst"])
    e = src[:, :, None] + dst[:, None, :]              # (H, N, N)
    e = jax.nn.leaky_relu(e, 0.2)
    neigh = adj * mask[None, :] * mask[:, None]
    e = jnp.where(neigh[None] > 0, e, -1e30)
    att = jax.nn.softmax(e, axis=-1)
    att = jnp.where(neigh[None] > 0, att, 0.0)
    out = jnp.einsum("hij,hjo->hio", att, hw)          # (H, N, O)
    out = jax.nn.elu(out)
    H, N, O = out.shape
    return out.transpose(1, 0, 2).reshape(N, H * O) * mask[:, None]


def init_mlp(rng, dims):
    ks = jax.random.split(rng, len(dims) - 1)
    return [{"W": jax.random.normal(k, (a, b)) / np.sqrt(a),
             "b": jnp.zeros((b,))}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def mlp(params, x, final_linear=True):
    for i, layer in enumerate(params):
        x = x @ layer["W"] + layer["b"]
        if i < len(params) - 1 or not final_linear:
            x = jax.nn.gelu(x)
    return x
