"""RaPP training loop (pure-JAX AdamW over the GAT+MLP predictor)."""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rapp import dataset as ds_mod
from repro.core.rapp import predictor as P
from repro.training import optimizer as opt_mod


@dataclasses.dataclass
class TrainConfig:
    lr: float = 1e-3
    steps: int = 1500
    batch_size: int = 64
    seed: int = 0
    log_every: int = 200


def _batch_of(ds, idx):
    return {"node_feats": jnp.asarray(ds.node_feats[idx]),
            "adj": jnp.asarray(ds.adj[idx]),
            "mask": jnp.asarray(ds.mask[idx]),
            "global": jnp.asarray(ds.global_feats[idx]),
            "prior": jnp.asarray(ds.priors[idx])}


def params_template(seed: int = 0,
                    rapp_cfg: P.RaPPConfig = P.RaPPConfig()):
    """Parameter tree with the training-time structure — used to
    restore checkpoints saved as flattened leaves."""
    return P.init_params(jax.random.PRNGKey(seed), rapp_cfg)


def mape(pred_ms: np.ndarray, true_ms: np.ndarray) -> float:
    return float(np.mean(np.abs(pred_ms - true_ms)
                         / np.maximum(true_ms, 1e-6)) * 100.0)


def evaluate(params, ds, batch_size: int = 256) -> float:
    preds = []
    for i in range(0, len(ds), batch_size):
        idx = np.arange(i, min(i + batch_size, len(ds)))
        b = _batch_of(ds, idx)
        preds.append(np.asarray(P.predict_latency_ms(params, b)))
    pred_ms = np.concatenate(preds)
    true_ms = np.expm1(ds.labels_logms)
    return mape(pred_ms, true_ms)


def train(train_ds, val_ds, rapp_cfg: P.RaPPConfig = P.RaPPConfig(),
          cfg: TrainConfig = TrainConfig(), verbose: bool = True):
    rng = np.random.default_rng(cfg.seed)
    params = P.init_params(jax.random.PRNGKey(cfg.seed), rapp_cfg)
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    adamw = opt_mod.AdamWConfig(lr=cfg.lr, warmup_steps=50,
                                total_steps=cfg.steps, weight_decay=0.01)
    opt_state = opt_mod.init_opt_state(params)

    def loss_fn(p, batch, labels):
        logl = P.forward_batch(p, batch["node_feats"], batch["adj"],
                               batch["mask"], batch["global"],
                               batch["prior"])
        return jnp.mean((logl - labels) ** 2)

    @jax.jit
    def step(p, s, batch, labels):
        loss, grads = jax.value_and_grad(loss_fn)(p, batch, labels)
        p, s, m = opt_mod.apply_updates(adamw, p, grads, s)
        return p, s, loss

    n = len(train_ds)
    t0 = time.time()
    best_params, best_val = params, float("inf")
    eval_every = max(cfg.steps // 8, 50)
    for i in range(cfg.steps):
        idx = rng.choice(n, size=min(cfg.batch_size, n), replace=False)
        batch = _batch_of(train_ds, idx)
        labels = jnp.asarray(train_ds.labels_logms[idx])
        params, opt_state, loss = step(params, opt_state, batch, labels)
        if (i % eval_every == 0 or i == cfg.steps - 1) and len(val_ds):
            vm = evaluate(params, val_ds)
            if vm < best_val:
                best_val = vm
                best_params = jax.tree.map(jnp.copy, params)
            if verbose and (i % cfg.log_every == 0 or i == cfg.steps - 1):
                print(f"step {i:5d} loss={float(loss):.4f} "
                      f"val_MAPE={vm:.2f}% (best {best_val:.2f}%) "
                      f"({time.time()-t0:.0f}s)", flush=True)
    return best_params
