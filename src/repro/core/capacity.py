"""Config-lattice capacity tables: the vectorized control plane.

The hybrid autoscaler's decisions search a fine-grained (batch, sm,
quota) configuration space: ``most_efficient_config`` alone enumerates
~480 points per scaling decision, each of which used to be a separate
scalar predictor call — a separate single-sample jitted GAT forward when
RaPP is in the loop. `CapacityTable` replaces those scalar queries with
precomputed lattices: for each (gpu type, spec, batch) triple the full
(sm x quota) grid is filled in ONE batched call —

  * oracle:  the numpy-vectorized roofline lattice
    (`perf_model.latency_lattice`), bitwise identical to the scalar
    `perf_model.latency` so golden traces are unchanged;
  * RaPP:    one `forward_batch` vmap invocation over all lattice
    points (`RaPPModel.predict_lattice`) — a single device round-trip
    instead of ~480;
  * anything else exposing ``lat(spec, b, sm, q)``: a cached scalar
    fill, preserving the pluggable-predictor protocol.

`most_efficient_config` / `min_quota_for_slo` then become masked
argmin/argmax lookups over the cached tables, replicating the reference
triple loop's scan order and strict-inequality tie-breaking exactly
(first maximal/minimal point in (batch, sm, quota) C-order wins), so the
table-backed versions return the identical (b, sm, q) tuples —
tests/test_capacity.py pins this across every registered architecture.

Heterogeneous fleets add one dimension: every query takes an optional
``gpu`` (a ``GPUType`` from ``configs/gpus.py``, default = the reference
device, whose lattices are bitwise the pre-heterogeneity ones), and
``best_config_over`` runs the same search across a set of device types,
minimizing *dollars per second* rather than quota — the cross-type
ladder HAS-GPU's cost argument rests on.

Off-lattice quotas (vertical scaling accumulates ``quota + n*step``
float sums that are not bitwise lattice points) fall back to the exact
scalar path and are memoized, so correctness never depends on grid
snapping.

The sim-to-silicon loop: passing ``calibration=`` (a
``repro.profiling.CalibrationTable`` built by
``benchmarks/profile_stack.py`` from the REAL jitted serving path)
overlays measured latencies onto every lattice point the table covers,
interpolating inside its measured hull and falling back to the
analytic physics off-grid. The default (no calibration) keeps every
golden trace byte-identical.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.gpus import DEFAULT_GPU_TYPE, GPUType
from repro.core import perf_model
from repro.core.perf_model import FnSpec
from repro.core.vgpu import DEFAULT_WINDOW_MS, TOTAL_SLICES

DEFAULT_BATCHES = (1, 2, 4, 8, 16, 32)


class CapacityTable:
    """Cached (sm x quota) latency lattices per (gpu type, spec, batch),
    plus the table-backed control-plane queries.

    Exposes the same ``lat(spec, b, sm, q) -> seconds`` protocol as the
    predictors it wraps (now with an optional trailing ``gpu``), so
    policies can consume it transparently. Invariant: for the reference
    device the cached lattices are bitwise identical to the scalar
    ``perf_model.latency`` — golden traces ride on this.
    """

    def __init__(self, predictor: Optional[Callable] = None,
                 quota_step: float = 0.1,
                 window_ms: float = DEFAULT_WINDOW_MS,
                 calibration=None):
        """Args:
            predictor: optional latency model ``(spec, b, sm, q[, gpu])
                -> seconds``; None uses the roofline oracle. Objects
                exposing ``predict_lattice`` (e.g. ``RaPPModel``) are
                filled in one batched call per (gpu, spec, batch).
            quota_step: grid pitch of the quota axis (control-plane
                loops enumerate ``qi * quota_step``).
            window_ms: time-token window the latencies are quoted at.
            calibration: optional ``repro.profiling.CalibrationTable``
                of MEASURED latencies (the sim-to-silicon loop):
                lattice points and scalar lookups it covers — exactly
                or by interpolation inside its measured hull — resolve
                to measured seconds, everything else falls back to the
                predictor/oracle. Default None: fully analytic, every
                golden trace byte-identical.
        """
        self.predictor = predictor
        self.quota_step = quota_step
        self.window_ms = window_ms
        self.calibration = calibration
        self.sms = np.arange(1, TOTAL_SLICES + 1)  # reference device grid
        self.quotas = perf_model.quota_grid(quota_step)
        self._sms_by_type: Dict[GPUType, np.ndarray] = {
            DEFAULT_GPU_TYPE: self.sms}
        # cost is predictor-independent: one (S, Q) grid per gpu type
        self._cost_by_type: Dict[GPUType, np.ndarray] = {}
        self._lattices: Dict[Tuple, np.ndarray] = {}
        self._scalar: Dict[Tuple, float] = {}

    # ---- per-type grids ----------------------------------------------------
    def sms_for(self, gpu: GPUType) -> np.ndarray:
        """The SM-axis grid ``1..sm_total`` for a device type."""
        sms = self._sms_by_type.get(gpu)
        if sms is None:
            sms = self._sms_by_type[gpu] = np.arange(1, gpu.sm_total + 1)
        return sms

    def cost_grid(self, gpu: GPUType) -> np.ndarray:
        """(S, Q) $/second of holding each lattice point on ``gpu``."""
        cost = self._cost_by_type.get(gpu)
        if cost is None:
            cost = self._cost_by_type[gpu] = perf_model.cost_rate_lattice(
                self.sms_for(gpu), self.quotas, gpu)
        return cost

    # ---- lattice fill ------------------------------------------------------
    def lattice(self, spec: FnSpec, batch: int,
                gpu: GPUType = DEFAULT_GPU_TYPE) -> np.ndarray:
        """(S, Q) latency seconds for every lattice point of ``gpu``,
        one batched evaluation per (gpu, spec, batch), cached forever."""
        key = (gpu, spec, batch)
        tab = self._lattices.get(key)
        if tab is None:
            sms = self.sms_for(gpu)
            if self.predictor is None:
                tab = perf_model.latency_lattice(
                    spec, batch, sms, self.quotas, self.window_ms, gpu)
            elif hasattr(self.predictor, "predict_lattice"):
                tab = np.asarray(self.predictor.predict_lattice(
                    spec, batch, sms, self.quotas, gpu=gpu),
                    dtype=np.float64)
            else:  # arbitrary scalar predictor: cached loop fill
                pred = perf_model._resolve_pred(self.predictor, gpu)
                tab = np.array(
                    [[pred(spec, batch, int(sm), float(q))
                      for q in self.quotas] for sm in sms],
                    dtype=np.float64)
            if self.calibration is not None:
                tab = self._overlay_calibration(tab, spec, batch, gpu)
            self._lattices[key] = tab
        return tab

    def _overlay_calibration(self, tab: np.ndarray, spec: FnSpec,
                             batch: int, gpu: GPUType) -> np.ndarray:
        """Replace lattice points the calibration table covers with
        measured seconds; analytic values survive everywhere else."""
        out = tab.copy()
        for si, sm in enumerate(self.sms_for(gpu)):
            for qi, q in enumerate(self.quotas):
                v = self.calibration.latency(spec, batch, int(sm),
                                             float(q), gpu=gpu)
                if v is not None:
                    out[si, qi] = v
        return out

    # ---- predictor protocol ------------------------------------------------
    def _scalar_lat(self, spec: FnSpec, b: int, sm: int, q: float,
                    gpu: GPUType) -> float:
        """Memoized exact scalar fallback for off-lattice quotas."""
        key = (gpu, spec, b, sm, q)
        v = self._scalar.get(key)
        if v is None:
            if self.calibration is not None:
                v = self.calibration.latency(spec, b, sm, q, gpu=gpu)
                if v is not None:
                    self._scalar[key] = v
                    return v
            if self.predictor is None:
                v = perf_model.latency(spec, b, sm, q,
                                       window_ms=self.window_ms, gpu=gpu)
            else:
                v = perf_model._resolve_pred(self.predictor, gpu)(
                    spec, b, sm, q)
            self._scalar[key] = v
        return v

    def lat(self, spec: FnSpec, b: int, sm: int, q: float,
            gpu: Optional[GPUType] = None) -> float:
        """Latency lookup: lattice hit when q is bitwise on-grid, exact
        scalar fallback (cached) otherwise. ``gpu`` None means the
        reference device."""
        gpu = gpu or DEFAULT_GPU_TYPE
        qi = int(round(q / self.quota_step))
        if 1 <= qi <= len(self.quotas) and q == self.quotas[qi - 1]:
            return float(self.lattice(spec, b, gpu)[sm - 1, qi - 1])
        return self._scalar_lat(spec, b, sm, q, gpu)

    __call__ = lat

    def throughput(self, spec: FnSpec, b: int, sm: int, q: float,
                   overhead_s: float = 0.0,
                   gpu: Optional[GPUType] = None) -> float:
        """Requests/second of one pod at (b, sm, q) on ``gpu`` with
        per-cycle dispatch ``overhead_s`` added to the latency."""
        return b / (self.lat(spec, b, sm, q, gpu) + overhead_s)

    # ---- table-backed control-plane queries --------------------------------
    def _search(self, spec: FnSpec, target_rps: float, batches,
                slo_multiplier: Optional[float], gpu: GPUType):
        """Shared per-type search core.

        Returns ``(eligible_best, eligible_cost, fallback_best,
        fallback_thpt)`` where the *eligible* pair is the cheapest
        SLO-satisfying config meeting ``target_rps`` (None/inf when the
        type can't meet it) and the *fallback* pair is the most capable
        SLO-satisfying config (None/-inf when no config meets the SLO).
        Tie-breaking replicates the reference loop: first minimal /
        maximal point in (batch, sm, quota) C-order wins.
        """
        lat = np.stack([self.lattice(spec, b, gpu) for b in batches])
        caps = np.array([slo_multiplier * perf_model.slo_baseline(spec, b)
                         if slo_multiplier else np.inf for b in batches])
        valid = lat <= caps[:, None, None]
        barr = np.asarray(batches, dtype=np.float64)
        thpt = barr[:, None, None] / lat
        sms = self.sms_for(gpu)
        best, best_cost = None, float("inf")
        eligible = valid & (thpt >= target_rps)
        if eligible.any():
            # strict `<` in the reference loop keeps the FIRST minimal-
            # cost point in scan order; argmin over C-order does the same
            cost = np.broadcast_to(self.cost_grid(gpu), lat.shape)
            masked = np.where(eligible, cost, np.inf)
            bi, si, qi = np.unravel_index(np.argmin(masked), lat.shape)
            best = (batches[bi], int(sms[si]), float(self.quotas[qi]))
            best_cost = float(masked[bi, si, qi])
        fallback, fb_thpt = None, float("-inf")
        if valid.any():
            # most capable SLO-satisfying config (first maximal
            # throughput in scan order, matching strict `>`)
            masked = np.where(valid, thpt, -np.inf)
            bi, si, qi = np.unravel_index(np.argmax(masked), lat.shape)
            fallback = (batches[bi], int(sms[si]), float(self.quotas[qi]))
            fb_thpt = float(masked[bi, si, qi])
        return best, best_cost, fallback, fb_thpt

    def most_efficient_config(self, spec: FnSpec, target_rps: float,
                              batches=DEFAULT_BATCHES,
                              slo_multiplier: Optional[float] = 2.0,
                              gpu: Optional[GPUType] = None) -> tuple:
        """Table-backed `perf_model.most_efficient_config`: masked argmin
        over the stacked (B, S, Q) lattice of one device type, identical
        result tuple as the scalar reference loop."""
        gpu = gpu or DEFAULT_GPU_TYPE
        best, _, fallback, _ = self._search(spec, target_rps, batches,
                                            slo_multiplier, gpu)
        return best or fallback or (batches[-1], gpu.sm_total, 1.0)

    def best_config_over(self, spec: FnSpec, target_rps: float,
                         gpu_types: Sequence[GPUType],
                         batches=DEFAULT_BATCHES,
                         slo_multiplier: Optional[float] = 2.0) -> tuple:
        """Cross-type `most_efficient_config`, minimizing DOLLARS.

        Args:
            spec/target_rps/batches/slo_multiplier: as in
                ``most_efficient_config``.
            gpu_types: candidate device types in preference order
                (ties in $/s resolve to the earlier type).
        Returns: ``(gpu, batch, sm, quota)`` — the cheapest-in-$/s
        config across all candidate types that meets ``target_rps``
        under the SLO; falls back to the highest-throughput
        SLO-satisfying config across types, then to the first type's
        maximal config. Invariant: with a single candidate type this
        returns exactly ``(gpu, *most_efficient_config(..., gpu=gpu))``.
        """
        gpu_types = list(gpu_types)
        best = None
        best_cost = float("inf")
        fallback, fb_thpt = None, float("-inf")
        for gpu in gpu_types:
            b, c, fb, ft = self._search(spec, target_rps, batches,
                                        slo_multiplier, gpu)
            if b is not None and c < best_cost:
                best, best_cost = (gpu,) + b, c
            if fb is not None and ft > fb_thpt:
                fallback, fb_thpt = (gpu,) + fb, ft
        if best is not None:
            return best
        if fallback is not None:
            return fallback
        g = gpu_types[0]
        return (g, batches[-1], g.sm_total, 1.0)

    def min_quota_for_slo(self, spec: FnSpec, batch: int, sm: int,
                          slo_multiplier: float = 2.0,
                          gpu: Optional[GPUType] = None
                          ) -> Optional[float]:
        """Smallest on-grid quota at which (batch, sm) on ``gpu`` meets
        the latency SLO; None when no quota does."""
        gpu = gpu or DEFAULT_GPU_TYPE
        cap = slo_multiplier * perf_model.slo_baseline(spec, batch)
        ok = self.lattice(spec, batch, gpu)[sm - 1] <= cap
        if not ok.any():
            return None
        return float(self.quotas[int(np.argmax(ok))])


# ---- shared oracle tables ---------------------------------------------------
# The oracle lattices are pure functions of (gpu type, spec, batch,
# quota_step, window_ms); sharing one table per (quota_step, window_ms)
# across the autoscaler, the baselines, and the event engine means each
# lattice is built once per process.
_SHARED: Dict[Tuple[float, float], CapacityTable] = {}


def shared_table(quota_step: float = 0.1,
                 window_ms: float = DEFAULT_WINDOW_MS) -> CapacityTable:
    """Process-wide oracle `CapacityTable` for (quota_step, window_ms)."""
    key = (quota_step, window_ms)
    tab = _SHARED.get(key)
    if tab is None:
        tab = _SHARED[key] = CapacityTable(predictor=None,
                                           quota_step=quota_step,
                                           window_ms=window_ms)
    return tab
