"""Config-lattice capacity tables: the vectorized control plane.

The hybrid autoscaler's decisions search a fine-grained (batch, sm,
quota) configuration space: ``most_efficient_config`` alone enumerates
~480 points per scaling decision, each of which used to be a separate
scalar predictor call — a separate single-sample jitted GAT forward when
RaPP is in the loop. `CapacityTable` replaces those scalar queries with
precomputed lattices: for each (spec, batch) pair the full (sm x quota)
grid is filled in ONE batched call —

  * oracle:  the numpy-vectorized roofline lattice
    (`perf_model.latency_lattice`), bitwise identical to the scalar
    `perf_model.latency` so golden traces are unchanged;
  * RaPP:    one `forward_batch` vmap invocation over all lattice
    points (`RaPPModel.predict_lattice`) — a single device round-trip
    instead of ~480;
  * anything else exposing ``lat(spec, b, sm, q)``: a cached scalar
    fill, preserving the pluggable-predictor protocol.

`most_efficient_config` / `min_quota_for_slo` then become masked
argmin/argmax lookups over the cached tables, replicating the reference
triple loop's scan order and strict-inequality tie-breaking exactly
(first maximal/minimal point in (batch, sm, quota) C-order wins), so the
table-backed versions return the identical (b, sm, q) tuples —
tests/test_capacity.py pins this across every registered architecture.

Off-lattice quotas (vertical scaling accumulates ``quota + n*step``
float sums that are not bitwise lattice points) fall back to the exact
scalar path and are memoized, so correctness never depends on grid
snapping.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core import perf_model
from repro.core.perf_model import FnSpec
from repro.core.vgpu import DEFAULT_WINDOW_MS, TOTAL_SLICES

DEFAULT_BATCHES = (1, 2, 4, 8, 16, 32)


class CapacityTable:
    """Cached (sm x quota) latency lattices per (spec, batch), plus the
    table-backed control-plane queries. Exposes the same
    ``lat(spec, b, sm, q) -> seconds`` protocol as the predictors it
    wraps, so policies can consume it transparently."""

    def __init__(self, predictor: Optional[Callable] = None,
                 quota_step: float = 0.1,
                 window_ms: float = DEFAULT_WINDOW_MS):
        self.predictor = predictor
        self.quota_step = quota_step
        self.window_ms = window_ms
        self.sms = np.arange(1, TOTAL_SLICES + 1)
        self.quotas = perf_model.quota_grid(quota_step)
        # cost is predictor-independent: one (S, Q) grid for the table
        self._cost = perf_model.cost_rate_lattice(self.sms, self.quotas)
        self._lattices: Dict[Tuple, np.ndarray] = {}
        self._scalar: Dict[Tuple, float] = {}

    # ---- lattice fill ------------------------------------------------------
    def lattice(self, spec: FnSpec, batch: int) -> np.ndarray:
        """(S, Q) latency seconds for every lattice point, one batched
        evaluation per (spec, batch), cached forever."""
        key = (spec, batch)
        tab = self._lattices.get(key)
        if tab is None:
            if self.predictor is None:
                tab = perf_model.latency_lattice(
                    spec, batch, self.sms, self.quotas, self.window_ms)
            elif hasattr(self.predictor, "predict_lattice"):
                tab = np.asarray(self.predictor.predict_lattice(
                    spec, batch, self.sms, self.quotas), dtype=np.float64)
            else:  # arbitrary scalar predictor: cached loop fill
                tab = np.array(
                    [[self.predictor(spec, batch, int(sm), float(q))
                      for q in self.quotas] for sm in self.sms],
                    dtype=np.float64)
            self._lattices[key] = tab
        return tab

    # ---- predictor protocol ------------------------------------------------
    def _scalar_lat(self, spec: FnSpec, b: int, sm: int, q: float) -> float:
        key = (spec, b, sm, q)
        v = self._scalar.get(key)
        if v is None:
            if self.predictor is None:
                v = perf_model.latency(spec, b, sm, q,
                                       window_ms=self.window_ms)
            else:
                v = self.predictor(spec, b, sm, q)
            self._scalar[key] = v
        return v

    def lat(self, spec: FnSpec, b: int, sm: int, q: float) -> float:
        """Latency lookup: lattice hit when q is bitwise on-grid, exact
        scalar fallback (cached) otherwise."""
        qi = int(round(q / self.quota_step))
        if 1 <= qi <= len(self.quotas) and q == self.quotas[qi - 1]:
            return float(self.lattice(spec, b)[sm - 1, qi - 1])
        return self._scalar_lat(spec, b, sm, q)

    __call__ = lat

    def throughput(self, spec: FnSpec, b: int, sm: int, q: float,
                   overhead_s: float = 0.0) -> float:
        return b / (self.lat(spec, b, sm, q) + overhead_s)

    # ---- table-backed control-plane queries --------------------------------
    def most_efficient_config(self, spec: FnSpec, target_rps: float,
                              batches=DEFAULT_BATCHES,
                              slo_multiplier: Optional[float] = 2.0
                              ) -> tuple:
        """Table-backed `perf_model.most_efficient_config`: masked argmin
        over the stacked (B, S, Q) lattice, identical result tuple."""
        lat = np.stack([self.lattice(spec, b) for b in batches])  # (B,S,Q)
        caps = np.array([slo_multiplier * perf_model.slo_baseline(spec, b)
                         if slo_multiplier else np.inf for b in batches])
        valid = lat <= caps[:, None, None]
        barr = np.asarray(batches, dtype=np.float64)
        thpt = barr[:, None, None] / lat
        best = None
        eligible = valid & (thpt >= target_rps)
        if eligible.any():
            # strict `<` in the reference loop keeps the FIRST minimal-
            # cost point in scan order; argmin over C-order does the same
            cost = np.broadcast_to(self._cost, lat.shape)
            masked = np.where(eligible, cost, np.inf)
            bi, si, qi = np.unravel_index(np.argmin(masked), lat.shape)
            best = (batches[bi], int(self.sms[si]), float(self.quotas[qi]))
        if best is None and valid.any():
            # fallback: most capable SLO-satisfying config (first maximal
            # throughput in scan order, matching strict `>`)
            masked = np.where(valid, thpt, -np.inf)
            bi, si, qi = np.unravel_index(np.argmax(masked), lat.shape)
            best = (batches[bi], int(self.sms[si]), float(self.quotas[qi]))
        return best or (batches[-1], TOTAL_SLICES, 1.0)

    def min_quota_for_slo(self, spec: FnSpec, batch: int, sm: int,
                          slo_multiplier: float = 2.0) -> Optional[float]:
        """Smallest on-grid quota at which (batch, sm) meets the SLO."""
        cap = slo_multiplier * perf_model.slo_baseline(spec, batch)
        ok = self.lattice(spec, batch)[sm - 1] <= cap
        if not ok.any():
            return None
        return float(self.quotas[int(np.argmax(ok))])


# ---- shared oracle tables ---------------------------------------------------
# The oracle lattices are pure functions of (spec, batch, quota_step,
# window_ms); sharing one table per (quota_step, window_ms) across the
# autoscaler, the baselines, and the event engine means each lattice is
# built once per process.
_SHARED: Dict[Tuple[float, float], CapacityTable] = {}


def shared_table(quota_step: float = 0.1,
                 window_ms: float = DEFAULT_WINDOW_MS) -> CapacityTable:
    """Process-wide oracle `CapacityTable` for (quota_step, window_ms)."""
    key = (quota_step, window_ms)
    tab = _SHARED.get(key)
    if tab is None:
        tab = _SHARED[key] = CapacityTable(predictor=None,
                                           quota_step=quota_step,
                                           window_ms=window_ms)
    return tab
