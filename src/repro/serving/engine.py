"""Serving engine: real JAX prefill/decode under HAS resource control.

One ``PodEngine`` is a function instance: jitted prefill + decode steps
for its architecture, a batcher, and a libhas shim that acquires time
tokens sized by the pod's (sm, quota) before every dispatch. The CPU demo
uses reduced configs; the dispatch path (batch -> prefill -> n x decode)
is the production one.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import ArchConfig
from repro.configs.gpus import DEFAULT_GPU_TYPE
from repro.core.perf_model import FnSpec, exec_time
from repro.core.scheduler import HASGPUScheduler
from repro.core.vgpu import PodAlloc, VirtualGPU
from repro.models import CallOpts
from repro.serving.batcher import Batcher, InferenceRequest
from repro.serving.libhas import LibHas
from repro.training import steps


@functools.lru_cache(maxsize=None)
def compiled_steps(cfg: ArchConfig, max_seq: int, opts: CallOpts) -> tuple:
    """Shared jitted ``(prefill, decode)`` steps for one architecture.

    Pods of the same function differ only in (sm, quota, batch) — none
    of which affect compilation — so every engine of a fn shares one
    jit cache instead of re-tracing per pod (the profiling harness
    sweeps many (sm, quota) points per arch and rides on this too)."""
    return (jax.jit(steps.make_prefill_step(cfg, max_seq, opts)),
            jax.jit(steps.make_decode_step(cfg, opts)))


class PodEngine:
    def __init__(self, cfg: ArchConfig, pod: PodAlloc, vgpu: VirtualGPU,
                 scheduler: HASGPUScheduler,
                 max_seq: int = 256, seed: int = 0,
                 params=None, opts: CallOpts = CallOpts(),
                 pad_id: int = 0):
        self.cfg = cfg
        self.pod = pod
        self.spec = FnSpec(cfg, seq=max_seq)
        self.max_seq = max_seq
        self.opts = opts
        self.params = params if params is not None else models.init_params(
            jax.random.PRNGKey(seed), cfg)
        client = scheduler.client_for(vgpu, pod.pod_id)
        self.libhas = LibHas(client=client)
        self.batcher = Batcher(max_batch=pod.batch, pad_id=pad_id)
        self._prefill, self._decode = compiled_steps(cfg, max_seq, opts)
        self.completed: List[InferenceRequest] = []

    # cost of one dispatch in *owned accelerator seconds* for this pod,
    # on the chip actually hosting it — charging at reference-device
    # physics would over-token fast chips and under-token slow ones
    def _cost(self, n_tokens_equiv: int) -> float:
        gpu = self.pod.gpu_type or DEFAULT_GPU_TYPE
        t_full = exec_time(self.spec, max(self.pod.batch, 1), self.pod.sm,
                           gpu)
        return t_full * n_tokens_equiv / self.spec.seq

    def _extra_inputs(self, B):
        extra = {}
        if self.cfg.is_encoder_decoder:
            extra["frame_embeds"] = jnp.zeros(
                (B, self.cfg.encoder_seq, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.num_visual_tokens:
            extra["visual_embeds"] = jnp.zeros(
                (B, self.cfg.num_visual_tokens, self.cfg.d_model),
                jnp.bfloat16)
        return extra

    def submit(self, req: InferenceRequest) -> None:
        self.batcher.submit(req)

    def step(self) -> List[InferenceRequest]:
        """Serve one batch if ready. Returns completed requests."""
        if not self.batcher.ready():
            return []
        reqs = self.batcher.next_batch()
        prompts = self.batcher.pad_prompts(reqs, pad_id=self.batcher.pad_id,
                                           pad_to=None)
        B, L = prompts.shape
        v = self.cfg.num_visual_tokens or 0
        batch = {"tokens": jnp.asarray(prompts), **self._extra_inputs(B)}
        logits, cache = self.libhas.launch(
            self._prefill, self.params, batch, cost_s=self._cost(B * L))
        n_new = max(r.max_new_tokens for r in reqs)
        outs = np.zeros((B, n_new), np.int32)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for i in range(n_new):
            outs[:, i] = np.asarray(tok[:, 0])
            pos = jnp.asarray(v + L + i, jnp.int32)
            logits, cache = self.libhas.launch(
                self._decode, self.params, tok, pos, cache,
                cost_s=self._cost(B))
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        now = time.monotonic()
        for j, r in enumerate(reqs):
            r.output = outs[j, :r.max_new_tokens]
            r.completed_at = now
        self.completed.extend(reqs)
        return reqs

    def set_quota(self, vgpu: VirtualGPU, quota: float) -> None:
        """Vertical scaling at runtime: next token acquisition sees it."""
        vgpu.set_quota(self.pod.pod_id, quota)
