from repro.serving.batcher import Batcher, InferenceRequest
from repro.serving.engine import PodEngine
from repro.serving.gateway import Gateway
from repro.serving.libhas import LibHas, MemoryBudgetExceeded

__all__ = ["Batcher", "InferenceRequest", "PodEngine", "Gateway", "LibHas",
           "MemoryBudgetExceeded"]
