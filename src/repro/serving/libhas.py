"""libhas — the pod-side resource-control shim.

In the paper this is an LD_PRELOAD library interposing CUDA Driver API
calls (cuLaunchKernel / cuMemAlloc) to enforce the pod's time-token and
memory allocations. The TPU/JAX analogue intercepts at the jitted-step
dispatch boundary: the engine wraps every step call in
``LibHas.launch(...)``, which (a) acquires time tokens from the pod's GPU
client and (b) enforces the pod's HBM budget against the compiled step's
memory analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.scheduler import GPUClient


class MemoryBudgetExceeded(RuntimeError):
    pass


@dataclasses.dataclass
class LibHas:
    client: GPUClient
    hbm_budget_bytes: Optional[int] = None
    cost_estimator: Optional[Callable[..., float]] = None
    launches: int = 0
    tokens_acquired_s: float = 0.0

    def check_memory(self, compiled) -> None:
        """cuMemAlloc-interception analogue: reject steps whose compiled
        footprint exceeds the pod's budget. The footprint is the full
        resident set of one step — arguments, scratch, AND outputs
        (outputs are live allocations the step must fit alongside its
        inputs; counting only args+temp under-reserved by the output
        size and let over-budget steps through)."""
        if self.hbm_budget_bytes is None:
            return
        m = compiled.memory_analysis()
        need = (m.argument_size_in_bytes + m.temp_size_in_bytes
                + m.output_size_in_bytes)
        if need > self.hbm_budget_bytes:
            raise MemoryBudgetExceeded(
                f"step needs {need} B > budget {self.hbm_budget_bytes} B")

    def launch(self, fn, *args, cost_s: Optional[float] = None, **kw):
        """cuLaunchKernel-interception analogue: acquire tokens, then run."""
        if cost_s is None and self.cost_estimator is not None:
            cost_s = self.cost_estimator(*args, **kw)
        if cost_s is not None:
            self.client.acquire(cost_s)
            self.tokens_acquired_s += cost_s
        self.launches += 1
        return fn(*args, **kw)
