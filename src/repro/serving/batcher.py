"""Request batching for the serving engine (paper gateway -> pod path)."""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Deque, List, Optional

import numpy as np

_req_ids = itertools.count()


@dataclasses.dataclass
class InferenceRequest:
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 16
    req_id: int = dataclasses.field(default_factory=lambda: next(_req_ids))
    arrival: float = dataclasses.field(default_factory=time.monotonic)
    output: Optional[np.ndarray] = None
    completed_at: Optional[float] = None

    @property
    def latency(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return self.completed_at - self.arrival


class Batcher:
    """Greedy size/timeout batcher with right-aligned prompt padding."""

    def __init__(self, max_batch: int, max_wait_s: float = 0.02,
                 pad_id: int = 0):
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.pad_id = pad_id
        self.queue: Deque[InferenceRequest] = deque()

    def submit(self, req: InferenceRequest) -> None:
        self.queue.append(req)

    def ready(self, now: Optional[float] = None) -> bool:
        if not self.queue:
            return False
        if len(self.queue) >= self.max_batch:
            return True
        now = now if now is not None else time.monotonic()
        return now - self.queue[0].arrival >= self.max_wait_s

    def next_batch(self) -> List[InferenceRequest]:
        take = min(self.max_batch, len(self.queue))
        return [self.queue.popleft() for _ in range(take)]

    @staticmethod
    def pad_prompts(reqs: List[InferenceRequest], pad_id: int = 0,
                    pad_to: Optional[int] = None) -> np.ndarray:
        """Left-pad to a common length so decode positions align.

        Args:
            reqs: non-empty list of requests.
            pad_id: fill token for the left padding.
            pad_to: fixed output width. None (the default) pads to the
                longest prompt in the batch; an explicit width must be
                >= 1, and prompts longer than it are truncated to their
                TRAILING ``pad_to`` tokens — with left padding the tail
                of the prompt is what sits next to the decode position.
        Returns: ``(len(reqs), L) int32`` array.
        Raises: ``ValueError`` for an empty batch or ``pad_to < 1``.
        """
        if not reqs:
            raise ValueError("pad_prompts: empty request list")
        if pad_to is None:
            L = max(len(r.prompt) for r in reqs)
        else:
            L = int(pad_to)
            if L < 1:
                raise ValueError(f"pad_prompts: pad_to={pad_to} must be "
                                 ">= 1 (or None to fit the batch)")
        out = np.full((len(reqs), L), pad_id, np.int32)
        for i, r in enumerate(reqs):
            p = r.prompt[-L:]   # keep the tail when truncating
            out[i, L - len(p):] = p
        return out
