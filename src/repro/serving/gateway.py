"""Gateway: request entry point + throughput-weighted load balancing
across a function's pod engines (paper: 'the load balancer is updated with
request distribution information according to the throughput capability of
different function pods')."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.gpus import DEFAULT_GPU_TYPE
from repro.core.perf_model import FnSpec, throughput
from repro.serving.batcher import InferenceRequest
from repro.serving.engine import PodEngine


class Gateway:
    def __init__(self):
        self.engines: Dict[str, List[PodEngine]] = {}

    def register(self, fn_id: str, engine: PodEngine) -> None:
        self.engines.setdefault(fn_id, []).append(engine)

    def deregister(self, fn_id: str, pod_id: str) -> None:
        if fn_id not in self.engines:
            return
        self.engines[fn_id] = [e for e in self.engines[fn_id]
                               if e.pod.pod_id != pod_id]

    def route(self, fn_id: str, req: InferenceRequest) -> PodEngine:
        pods = self.engines.get(fn_id, [])
        if not pods:
            raise KeyError(f"no pods for {fn_id}")
        # least normalized backlog: queue / predicted throughput on the
        # pod's OWN device — on a mixed fleet, capability differs per chip
        def score(e: PodEngine) -> float:
            cap = throughput(e.spec, e.pod.batch, e.pod.sm, e.pod.quota,
                             gpu=e.pod.gpu_type or DEFAULT_GPU_TYPE)
            return len(e.batcher.queue) / max(cap, 1e-9)
        eng = min(pods, key=score)
        eng.submit(req)
        return eng

    def pump(self, fn_id: str) -> List[InferenceRequest]:
        done = []
        for e in self.engines.get(fn_id, []):
            done.extend(e.step())
        return done
