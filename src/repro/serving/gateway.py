"""Gateway: request entry point + throughput-weighted load balancing
across a function's pod engines (paper: 'the load balancer is updated with
request distribution information according to the throughput capability of
different function pods')."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.gpus import DEFAULT_GPU_TYPE
from repro.core.perf_model import FnSpec, throughput
from repro.serving.batcher import InferenceRequest
from repro.serving.engine import PodEngine


class Gateway:
    def __init__(self):
        self.engines: Dict[str, List[PodEngine]] = {}
        # per-config roofline throughput memo for the routing score:
        # recomputing the roofline on every routed request made route()
        # O(predictor) per request; the score only changes when a pod's
        # (batch, sm, quota, device) changes
        self._thpt_cache: Dict[tuple, float] = {}

    def register(self, fn_id: str, engine: PodEngine) -> None:
        self.engines.setdefault(fn_id, []).append(engine)

    def deregister(self, fn_id: str, pod_id: str) -> None:
        pods = self.engines.get(fn_id)
        if pods is None:
            return
        pods = [e for e in pods if e.pod.pod_id != pod_id]
        if pods:
            self.engines[fn_id] = pods
        else:
            # prune the key: a fully drained function is unknown again
            # (route() raises, and the fn_id list stays truthful)
            del self.engines[fn_id]

    def _pod_throughput(self, e: PodEngine) -> float:
        """The pod's roofline throughput on its own device, memoized per
        (fn, batch, sm, quota, device type) — a quota rewrite lands on a
        fresh key, so runtime vertical scaling stays correct."""
        t = e.pod.gpu_type or DEFAULT_GPU_TYPE
        key = (e.spec.fn_id, e.pod.batch, e.pod.sm, e.pod.quota, t.name)
        v = self._thpt_cache.get(key)
        if v is None:
            v = throughput(e.spec, e.pod.batch, e.pod.sm, e.pod.quota, gpu=t)
            self._thpt_cache[key] = v
        return v

    def route(self, fn_id: str, req: InferenceRequest) -> PodEngine:
        pods = self.engines.get(fn_id)
        if not pods:
            known = ", ".join(sorted(self.engines)) or "<none>"
            raise KeyError(
                f"no pods for {fn_id!r}; registered fn_ids: {known}")
        # doomed (reclaim grace window) and quarantined (health-tripped
        # straggler, core/faults.py) pods take no new requests — unless
        # literally nothing else serves this function
        live = [e for e in pods
                if not e.pod.doomed and not e.pod.quarantined] or pods
        # least normalized backlog: queue / predicted throughput on the
        # pod's OWN device — on a mixed fleet, capability differs per chip
        eng = min(live, key=lambda e: (len(e.batcher.queue)
                                       / max(self._pod_throughput(e), 1e-9)))
        eng.submit(req)
        return eng

    def pump(self, fn_id: str) -> List[InferenceRequest]:
        done = []
        for e in self.engines.get(fn_id, []):
            done.extend(e.step())
        return done
