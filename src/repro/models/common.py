"""Shared building blocks: norms, activations, rotary embeddings, init."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------- init
def dense_param(rng, shape, dtype, in_axis: int = 0):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis] if in_axis < len(shape) else shape[0]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_param(rng, shape, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------- norms
def init_norm(cfg, d: int):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    if cfg.norm == "nonparametric_ln":
        return {}
    raise ValueError(cfg.norm)


def apply_norm(cfg, p, x, eps: float = 1e-5):
    """Norms run in f32 and cast back (TPU-standard)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
        x = x * p["scale"]
    else:  # layernorm / nonparametric_ln
        mu = jnp.mean(x, axis=-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(jnp.var(x, axis=-1, keepdims=True) + eps)
        if cfg.norm == "layernorm":
            x = x * p["scale"] + p["bias"]
    return x.astype(dt)


# ---------------------------------------------------------------- activations
def activation(name: str):
    if name in ("silu",):
        return jax.nn.silu
    if name in ("gelu", "gelu_plain"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


# ---------------------------------------------------------------- rotary
def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- misc
def causal_mask_bias(q_pos, k_pos, window: int = 0):
    """Additive bias (0 / -inf) for causal (+ optional sliding window) masking.

    q_pos: (..., S_q), k_pos: (..., S_k) -> (..., S_q, S_k)
    """
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    if window:
        ok &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)
