"""Mamba2 (SSD — state-space duality) block, chunked-scan formulation.

Reference: Dao & Gu, "Transformers are SSMs" (arXiv:2405.21060). The
sequence is split into chunks; within a chunk the SSD is computed in its
quadratic "attention-like" dual form (MXU-friendly einsums), and a
`lax.scan` carries the (heads, head_dim, state) SSM state across chunks.
The intra-chunk dual form has a Pallas TPU kernel in
``repro.kernels.ssd_scan``; this module is the jnp reference and the
dry-run lowering path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common


def ssm_dims(cfg):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_ch = di + 2 * s.n_groups * s.d_state
    return di, nh, conv_ch


def init_ssm(rng, cfg):
    s = cfg.ssm
    d = cfg.d_model
    di, nh, conv_ch = ssm_dims(cfg)
    dt = common.dtype_of(cfg)
    ks = jax.random.split(rng, 5)
    proj_out = 2 * di + 2 * s.n_groups * s.d_state + nh  # z, x, B, C, dt
    # dt bias: inverse-softplus of dt ~ U[1e-3, 1e-1]
    u = jax.random.uniform(ks[2], (nh,), jnp.float32,
                           np.log(1e-3), np.log(1e-1))
    dt0 = jnp.exp(u)
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    return {
        "in_proj": common.dense_param(ks[0], (d, proj_out), dt),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_ch), jnp.float32)
                   * (1.0 / np.sqrt(s.conv_width))).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jax.random.uniform(ks[3], (nh,), jnp.float32, 1.0, 16.0)),
        "dt_bias": dt_bias,
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": common.dense_param(ks[4], (di, d), dt),
    }


def _split_proj(cfg, proj):
    s = cfg.ssm
    di, nh, _ = ssm_dims(cfg)
    gn = s.n_groups * s.d_state
    z, xs, Bm, Cm, dt_raw = jnp.split(
        proj, [di, 2 * di, 2 * di + gn, 2 * di + 2 * gn], axis=-1)
    return z, xs, Bm, Cm, dt_raw


def _causal_conv(cfg, p, xbc):
    """Depthwise causal conv over (B, S, C) channels."""
    s = cfg.ssm
    W = s.conv_width
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad, p["conv_w"][:, None, :].astype(xbc.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=xbc.shape[-1])
    return jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))


def _gated_norm(p, y, z, eps=1e-5):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + eps)
    return y * p["norm_scale"]


def ssd_forward(cfg, p, x, *, initial_state=None, return_state=False,
                use_kernels=False):
    """Full-sequence SSD. x: (B, S, d) -> (B, S, d).

    Scans over chunks of `cfg.ssm.chunk_size`; requires S % chunk == 0 or
    S <= chunk.
    """
    s = cfg.ssm
    di, nh, _ = ssm_dims(cfg)
    hpg = nh // s.n_groups
    B_, S, _ = x.shape
    Q = min(s.chunk_size, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    nc = S // Q

    proj = x @ p["in_proj"]
    z, xs, Bm, Cm, dt_raw = _split_proj(cfg, proj)
    xbc_raw = jnp.concatenate([xs, Bm, Cm], axis=-1)
    # pre-conv window for decode (pad in case S < conv_width - 1)
    conv_tail = jnp.pad(
        xbc_raw, ((0, 0), (max(s.conv_width - 1 - S, 0), 0), (0, 0))
    )[:, -(s.conv_width - 1):]
    xbc = _causal_conv(cfg, p, xbc_raw)
    xs, Bm, Cm = jnp.split(xbc, [di, di + s.n_groups * s.d_state], axis=-1)

    xh = xs.reshape(B_, S, nh, s.head_dim)
    Bg = Bm.reshape(B_, S, s.n_groups, s.d_state)
    Cg = Cm.reshape(B_, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])  # (nh,)
    dA = dt * A  # (B,S,nh), negative

    # chunked tensors: (nc, B, Q, ...)
    def chunked(t):
        return t.reshape(B_, nc, Q, *t.shape[2:]).transpose(1, 0, *range(2, t.ndim + 1))

    xc, Bc, Cc = chunked(xh), chunked(Bg), chunked(Cg)
    dtc, dAc = chunked(dt), chunked(dA)

    # expand groups -> heads upfront: (nc, B, Q, nh, N)
    Bc = jnp.repeat(Bc, hpg, axis=3).reshape(nc, B_, Q, nh, s.d_state)
    Cc = jnp.repeat(Cc, hpg, axis=3).reshape(nc, B_, Q, nh, s.d_state)
    h0 = (initial_state if initial_state is not None
          else jnp.zeros((B_, nh, s.head_dim, s.d_state), jnp.float32))

    if use_kernels:
        from repro.kernels import ssd_scan
        final, yc = ssd_scan.ssd_chunk_scan(xc, Bc, Cc, dtc, dAc, h0)
        y = yc.transpose(1, 0, 2, 3, 4).reshape(B_, S, nh, s.head_dim)
    else:
        def body(h, xs_):
            x_i, B_i, C_i, dt_i, dA_i = xs_
            cum = jnp.cumsum(dA_i, axis=1)          # (B,Q,nh)
            total = cum[:, -1]                      # (B,nh)
            # intra-chunk dual (quadratic, attention-like) form
            cb = jnp.einsum("bihn,bjhn->bhij", C_i.astype(jnp.float32),
                            B_i.astype(jnp.float32))           # (B,nh,Q,Q)
            li = cum.transpose(0, 2, 1)[:, :, :, None]         # (B,nh,Q,1)
            lj = cum.transpose(0, 2, 1)[:, :, None, :]         # (B,nh,1,Q)
            # mask BEFORE exp: the i<j branch would overflow and poison
            # gradients through the where
            diff = jnp.where(jnp.tril(jnp.ones((Q, Q), bool)),
                             li - lj, -1e30)
            decay = jnp.exp(diff)
            scores = cb * decay * dt_i.transpose(0, 2, 1)[:, :, None, :]
            y_intra = jnp.einsum("bhij,bjhp->bihp", scores,
                                 x_i.astype(jnp.float32))
            # carried-state contribution
            y_inter = jnp.einsum("bihn,bhpn->bihp",
                                 C_i.astype(jnp.float32)
                                 * jnp.exp(cum)[..., None], h)
            # state update
            w = dt_i * jnp.exp(total[:, None, :] - cum)        # (B,Q,nh)
            dstate = jnp.einsum("bjhp,bjhn->bhpn",
                                x_i.astype(jnp.float32) * w[..., None],
                                B_i.astype(jnp.float32))
            h_new = jnp.exp(total)[:, :, None, None] * h + dstate
            return h_new, y_intra + y_inter

        final, yc = jax.lax.scan(body, h0, (xc, Bc, Cc, dtc, dAc))
        y = yc.transpose(1, 0, 2, 3, 4).reshape(B_, S, nh, s.head_dim)

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = _gated_norm(p, y.reshape(B_, S, di), z)
    out = y.astype(x.dtype) @ p["out_proj"]
    if return_state:
        return out, (conv_tail, final)
    return out


def ssd_decode_step(cfg, p, x, conv_state, ssm_state):
    """One-token decode. x: (B,1,d); conv_state: (B, W-1, conv_ch);
    ssm_state: (B, nh, hd, N) f32. Returns (y, new_conv_state, new_ssm_state).
    """
    s = cfg.ssm
    di, nh, conv_ch = ssm_dims(cfg)
    B_ = x.shape[0]
    proj = x @ p["in_proj"]
    z, xs, Bm, Cm, dt_raw = _split_proj(cfg, proj)
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)  # (B,1,C)
    window = jnp.concatenate([conv_state, xbc], axis=1)  # (B,W,C)
    new_conv_state = window[:, 1:]
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)
    xs, Bm, Cm = jnp.split(conv_out, [di, di + s.n_groups * s.d_state], axis=-1)

    xh = xs.reshape(B_, nh, s.head_dim).astype(jnp.float32)
    hpg = nh // s.n_groups
    Bh = jnp.repeat(Bm.reshape(B_, s.n_groups, s.d_state), hpg, axis=1)
    Ch = jnp.repeat(Cm.reshape(B_, s.n_groups, s.d_state), hpg, axis=1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)  # (B,nh)
    dstate = jnp.einsum("bhp,bhn->bhpn", xh * dt[..., None],
                        Bh.astype(jnp.float32))
    new_state = a[:, :, None, None] * ssm_state + dstate
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xh
    y = _gated_norm(p, y.reshape(B_, 1, di), z)
    return y.astype(x.dtype) @ p["out_proj"], new_conv_state, new_state
