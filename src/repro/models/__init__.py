from repro.models.api import (CallOpts, decode_step, forward, init_cache,
                              init_params, prefill)

__all__ = ["CallOpts", "init_params", "forward", "prefill", "decode_step",
           "init_cache"]
