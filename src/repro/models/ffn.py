"""Feed-forward layers: gated dense FFN and GSPMD-style einsum-dispatch MoE.

The MoE uses the TPU-canonical fixed-capacity one-hot dispatch (Switch /
GLaM / MaxText lineage): tokens are grouped, routed within groups, and
dispatched/combined via einsums so that expert parallelism shards cleanly
over the `model` mesh axis (XLA inserts the all-to-alls). The hot expert
matmul has a Pallas grouped-matmul kernel in ``repro.kernels.moe_gmm``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common


# ------------------------------------------------------------------ dense
def init_dense_ffn(rng, cfg, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = common.dtype_of(cfg)
    ks = jax.random.split(rng, 3)
    if cfg.act == "gelu_plain":
        return {"w_in": common.dense_param(ks[0], (d, f), dt),
                "b_in": jnp.zeros((f,), dt),
                "w_out": common.dense_param(ks[1], (f, d), dt),
                "b_out": jnp.zeros((d,), dt)}
    return {"w_gate": common.dense_param(ks[0], (d, f), dt),
            "w_up": common.dense_param(ks[1], (d, f), dt),
            "w_down": common.dense_param(ks[2], (f, d), dt)}


def dense_ffn(cfg, p, x):
    act = common.activation(cfg.act)
    if cfg.act == "gelu_plain":
        return act(x @ p["w_in"] + p["b_in"]) @ p["w_out"] + p["b_out"]
    return (act(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


# ------------------------------------------------------------------ MoE
def init_moe(rng, cfg):
    m, d, f = cfg.moe, cfg.d_model, cfg.d_ff
    dt = common.dtype_of(cfg)
    ks = jax.random.split(rng, 5)
    E = m.num_experts

    def expert_stack(rng, shape_in, shape_out):
        return common.dense_param(rng, (E, shape_in, shape_out), dt, in_axis=1)

    p = {
        "router": common.dense_param(ks[0], (d, E), jnp.float32),
        "w_gate": expert_stack(ks[1], d, f),
        "w_up": expert_stack(ks[2], d, f),
        "w_down": expert_stack(ks[3], f, d),
    }
    if m.num_shared_experts:
        p["shared"] = init_dense_ffn(ks[4], cfg, d_ff=f * m.num_shared_experts)
    return p


def _route(cfg, logits):
    """logits (G,T,E) f32 -> weights (G,T,E) with top-k renormalized."""
    m = cfg.moe
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, m.experts_per_token)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    weights = jnp.zeros_like(probs)
    oh = jax.nn.one_hot(top_idx, m.num_experts, dtype=probs.dtype)  # (G,T,k,E)
    weights = (oh * top_w[..., None]).sum(axis=-2)
    return weights, probs


def moe_ffn(cfg, p, x, *, capacity_factor: float = 1.25, use_kernels=False,
            single_group: bool = False):
    """x: (B, S, d). Groups = batch rows (or one group for single-token
    decode when ``single_group`` — slashes per-token expert-slot waste).
    Returns (y, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.experts_per_token
    orig_shape = None
    if single_group and S == 1 and B > 1:
        orig_shape = (B, S, d)
        x = x.reshape(1, B, d)
        B, S = 1, B
    G, T = B, S  # group per sequence
    C = max(1, int(-(-k * T // E) * capacity_factor))
    C = -(-C // 8) * 8 if C > 8 else C  # MXU-align larger capacities
    C = min(C, T)  # never exceed the group's token count

    logits = jnp.einsum("gtd,de->gte", x.astype(jnp.float32), p["router"])
    weights, probs = _route(cfg, logits)  # (G,T,E)
    mask = (weights > 0).astype(jnp.float32)
    # position of each token within its expert's capacity buffer
    pos = jnp.cumsum(mask, axis=1) * mask - mask  # (G,T,E), 0-based
    keep = (pos < C).astype(jnp.float32) * mask
    dispatch = jax.nn.one_hot(pos.astype(jnp.int32), C,
                              dtype=x.dtype) * keep[..., None]  # (G,T,E,C)
    combine = dispatch.astype(jnp.float32) * weights[..., None]

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, x)  # (G,E,C,d)
    if use_kernels:
        from repro.kernels import moe_gmm
        ye = moe_gmm.expert_ffn(xe, p["w_gate"], p["w_up"], p["w_down"], cfg.act)
    else:
        act = common.activation(cfg.act)
        h = act(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) \
            * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
        ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)

    if m.num_shared_experts:
        y = y + dense_ffn(cfg, p["shared"], x)

    # Switch-style load-balance aux loss
    frac_tokens = mask.mean(axis=1)          # (G,E) fraction routed
    frac_probs = probs.mean(axis=1)          # (G,E) mean router prob
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
    out = y.astype(x.dtype)
    if orig_shape is not None:
        out = out.reshape(orig_shape)
    return out, aux
