"""Layer-stack machinery: heterogeneous blocks as prefix + scanned periods.

Architectures mix block kinds (attention vs SSM mixers; dense vs MoE FFNs;
deepseek's dense first layer). We factor the per-layer kind sequence into a
short unrolled *prefix* plus the smallest repeating *period*, then
``lax.scan`` over periods with stacked parameters — keeping the HLO compact
(fast 512-device lowering) while supporting every assigned architecture.

A BlockKind is the static tuple ``(mixer, ffn, d_ff)`` with
mixer in {'attn','ssm'}, ffn in {'dense','moe','none'}.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention, common, ffn as ffn_mod, ssm as ssm_mod


@dataclasses.dataclass(frozen=True)
class CallOpts:
    """Runtime (non-architecture) options for a model call."""
    use_kernels: bool = False
    attn_chunk: int = 4096
    capacity_factor: float = 1.25
    window: int = 0  # sliding-window override for self-attention (0 = full)
    remat: bool = False  # checkpoint the scanned period body (training)
    # sharding hint for logits (B, S, V), e.g. (("pod","data"), None, "model");
    # None = no constraint (single-device smoke runs)
    logits_spec: tuple = None
    # sharding hint for the residual stream (B, S, d). Anchors the batch to
    # the data axis so FSDP-sharded weights are all-gathered (weight
    # streaming) instead of XLA de-sharding the batch.
    act_spec: tuple = None
    # ---- beyond-paper perf levers (§Perf hillclimb) ----
    # KV-cache element type ("bfloat16" | "float8_e4m3fn"): fp8 halves
    # decode cache footprint and streaming bytes
    cache_dtype: str = "bfloat16"
    # (batch_axes, model_axis) for sequence-sharded attention — used when
    # num_heads doesn't divide the model axis (e.g. llava's 56 heads on
    # 16): avoids mid-head splits that force f32 score all-reduces
    attn_seq_shard: tuple = None
    # route decode tokens as ONE routing group instead of per-token groups:
    # capacity shrinks from E*max(1,..) slots per token to ~k*B/E total
    moe_single_group_decode: bool = False


def _constrain(h, spec):
    if spec is None:
        return h
    import jax
    return jax.lax.with_sharding_constraint(
        h, jax.sharding.PartitionSpec(*spec))


# ------------------------------------------------------------------ pattern
def layer_kinds(cfg):
    kinds = []
    for i in range(cfg.num_layers):
        mixer = cfg.layer_kind(i)
        if mixer == "ssm" and cfg.family == "ssm":
            kinds.append((mixer, "none", 0))
            continue
        f = cfg.ffn_kind(i)
        dff = cfg.d_ff
        if (f == "dense" and cfg.moe is not None
                and i < cfg.moe.first_dense and cfg.moe.d_ff_dense):
            dff = cfg.moe.d_ff_dense
        kinds.append((mixer, f, dff))
    return kinds


def stack_pattern(cfg):
    """-> (prefix_kinds, period_kinds, n_periods)."""
    kinds = layer_kinds(cfg)
    L = len(kinds)
    best = None  # (period_len, prefix_len, prefix, period, n)
    for prefix in range(0, min(L, 4)):
        rest = kinds[prefix:]
        n = len(rest)
        if n == 0:
            continue
        for p in range(1, n + 1):
            if n % p == 0 and rest == rest[:p] * (n // p):
                cand = (p, prefix, tuple(kinds[:prefix]), tuple(rest[:p]), n // p)
                if best is None or (cand[0], cand[1]) < (best[0], best[1]):
                    best = cand
                break  # smallest period for this prefix
    _, _, prefix_kinds, period_kinds, n_periods = best
    return prefix_kinds, period_kinds, n_periods


# ------------------------------------------------------------------ init
def init_block(rng, cfg, kind):
    mixer, f, dff = kind
    ks = jax.random.split(rng, 4)
    p = {"ln1": common.init_norm(cfg, cfg.d_model)}
    if mixer == "attn":
        p["attn"] = attention.init_attention(ks[0], cfg)
    else:
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg)
    if f == "dense":
        p["ln2"] = common.init_norm(cfg, cfg.d_model)
        p["ffn"] = ffn_mod.init_dense_ffn(ks[1], cfg, d_ff=dff)
    elif f == "moe":
        p["ln2"] = common.init_norm(cfg, cfg.d_model)
        p["moe"] = ffn_mod.init_moe(ks[1], cfg)
    return p


def init_stack(rng, cfg):
    prefix_kinds, period_kinds, n_periods = stack_pattern(cfg)
    k_prefix, k_periods = jax.random.split(rng)
    prefix = [init_block(k, cfg, kind)
              for k, kind in zip(jax.random.split(k_prefix, max(len(prefix_kinds), 1)),
                                 prefix_kinds)]

    def init_period(r):
        rs = jax.random.split(r, len(period_kinds))
        return tuple(init_block(rs[i], cfg, kind)
                     for i, kind in enumerate(period_kinds))

    periods = jax.vmap(init_period)(jax.random.split(k_periods, n_periods))
    return {"prefix": prefix, "periods": periods}


# ------------------------------------------------------------------ cache
def init_block_cache(cfg, kind, batch, kv_len, dtype):
    mixer = kind[0]
    if mixer == "attn":
        a = attention.dims_of(cfg)
        return {"k": jnp.zeros((batch, kv_len, a.num_kv_heads, a.head_dim), dtype),
                "v": jnp.zeros((batch, kv_len, a.num_kv_heads, a.head_dim), dtype)}
    s = cfg.ssm
    di, nh, conv_ch = ssm_mod.ssm_dims(cfg)
    return {"conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
            "state": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32)}


def init_stack_cache(cfg, batch, kv_len, dtype=jnp.bfloat16):
    prefix_kinds, period_kinds, n_periods = stack_pattern(cfg)
    prefix = [init_block_cache(cfg, k, batch, kv_len, dtype) for k in prefix_kinds]

    def one_period(_):
        return tuple(init_block_cache(cfg, k, batch, kv_len, dtype)
                     for k in period_kinds)

    periods = jax.vmap(one_period)(jnp.arange(n_periods))
    return {"prefix": prefix, "periods": periods}


def _kv_into_ring(k, kv_len):
    """Place full-prefill K (B,S,...) into a ring buffer of length kv_len."""
    B, S = k.shape[:2]
    if S <= kv_len:
        buf = jnp.zeros((B, kv_len) + k.shape[2:], k.dtype)
        return jax.lax.dynamic_update_slice(
            buf, k, (0,) * k.ndim)
    tail = k[:, -kv_len:]
    return jnp.roll(tail, shift=(S - kv_len) % kv_len, axis=1)


# ------------------------------------------------------------------ apply
def apply_block_full(cfg, kind, p, h, positions, opts: CallOpts,
                     kv_len: Optional[int] = None):
    """Full-sequence block. Returns (h, aux_loss, cache_entry_or_None)."""
    mixer, f, _ = kind
    aux = jnp.zeros((), jnp.float32)
    cache_entry = None
    if mixer == "attn":
        hn = common.apply_norm(cfg, p["ln1"], h)
        if kv_len is not None:
            o, (k, v) = attention.self_attention(
                cfg, p["attn"], hn, positions, window=opts.window,
                attn_chunk=opts.attn_chunk, use_kernels=opts.use_kernels,
                return_kv=True, seq_shard=opts.attn_seq_shard)
            cache_entry = {"k": _kv_into_ring(k, kv_len),
                           "v": _kv_into_ring(v, kv_len)}
        else:
            o = attention.self_attention(
                cfg, p["attn"], hn, positions, window=opts.window,
                attn_chunk=opts.attn_chunk, use_kernels=opts.use_kernels,
                seq_shard=opts.attn_seq_shard)
        h = h + o
    else:
        hn = common.apply_norm(cfg, p["ln1"], h)
        if kv_len is not None:
            o, (conv_tail, state) = ssm_mod.ssd_forward(
                cfg, p["ssm"], hn, return_state=True,
                use_kernels=opts.use_kernels)
            cache_entry = {"conv": conv_tail, "state": state}
        else:
            o = ssm_mod.ssd_forward(cfg, p["ssm"], hn,
                                    use_kernels=opts.use_kernels)
        h = h + o
    if f == "dense":
        h = h + ffn_mod.dense_ffn(cfg, p["ffn"],
                                  common.apply_norm(cfg, p["ln2"], h))
    elif f == "moe":
        y, aux = ffn_mod.moe_ffn(cfg, p["moe"],
                                 common.apply_norm(cfg, p["ln2"], h),
                                 capacity_factor=opts.capacity_factor,
                                 use_kernels=opts.use_kernels)
        h = h + y
    return _constrain(h, opts.act_spec), aux, cache_entry


def apply_block_decode(cfg, kind, p, h, cache_entry, pos, opts: CallOpts):
    """One-token decode block. Returns (h, new_cache_entry)."""
    mixer, f, _ = kind
    if mixer == "attn":
        hn = common.apply_norm(cfg, p["ln1"], h)
        o, nk, nv = attention.decode_self_attention(
            cfg, p["attn"], hn, cache_entry["k"], cache_entry["v"], pos,
            window=opts.window, use_kernels=opts.use_kernels)
        new_entry = {"k": nk, "v": nv}
        h = h + o
    else:
        hn = common.apply_norm(cfg, p["ln1"], h)
        o, nconv, nstate = ssm_mod.ssd_decode_step(
            cfg, p["ssm"], hn, cache_entry["conv"], cache_entry["state"])
        new_entry = {"conv": nconv, "state": nstate}
        h = h + o
    if f == "dense":
        h = h + ffn_mod.dense_ffn(cfg, p["ffn"],
                                  common.apply_norm(cfg, p["ln2"], h))
    elif f == "moe":
        y, _ = ffn_mod.moe_ffn(cfg, p["moe"],
                               common.apply_norm(cfg, p["ln2"], h),
                               capacity_factor=2.0,
                               use_kernels=opts.use_kernels,
                               single_group=opts.moe_single_group_decode)
        h = h + y
    return _constrain(h, opts.act_spec), new_entry


# ------------------------------------------------------------------ stack
def apply_stack(cfg, stack, h, positions, opts: CallOpts,
                kv_len: Optional[int] = None):
    """Full-sequence stack. Returns (h, aux_total, cache_or_None)."""
    prefix_kinds, period_kinds, _ = stack_pattern(cfg)
    h = _constrain(h, opts.act_spec)
    aux_total = jnp.zeros((), jnp.float32)
    prefix_cache = []
    for kind, p in zip(prefix_kinds, stack["prefix"]):
        h, aux, ce = apply_block_full(cfg, kind, p, h, positions, opts, kv_len)
        aux_total = aux_total + aux
        prefix_cache.append(ce)

    def body(carry, pp):
        h_, aux_ = carry
        ces = []
        for i, kind in enumerate(period_kinds):
            h_, a, ce = apply_block_full(cfg, kind, pp[i], h_, positions,
                                         opts, kv_len)
            aux_ = aux_ + a
            ces.append(ce)
        return (h_, aux_), tuple(ces)

    if opts.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (h, aux_total), period_cache = jax.lax.scan(
        body, (h, aux_total), stack["periods"])
    cache = None
    if kv_len is not None:
        cache = {"prefix": prefix_cache, "periods": period_cache}
    return h, aux_total, cache


def decode_stack(cfg, stack, h, pos, cache, opts: CallOpts):
    """One-token decode through the stack. Returns (h, new_cache)."""
    prefix_kinds, period_kinds, _ = stack_pattern(cfg)
    new_prefix = []
    for kind, p, ce in zip(prefix_kinds, stack["prefix"], cache["prefix"]):
        h, nce = apply_block_decode(cfg, kind, p, h, ce, pos, opts)
        new_prefix.append(nce)

    def body(h_, xs):
        pp, pc = xs
        nces = []
        for i, kind in enumerate(period_kinds):
            h_, nce = apply_block_decode(cfg, kind, pp[i], h_, pc[i], pos, opts)
            nces.append(nce)
        return h_, tuple(nces)

    h, new_periods = jax.lax.scan(body, h, (stack["periods"], cache["periods"]))
    return h, {"prefix": new_prefix, "periods": new_periods}
