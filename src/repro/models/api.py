"""Unified model API dispatching on architecture family.

  init_params(rng, cfg)                       -> params pytree
  forward(params, cfg, batch, opts)           -> (logits, aux_loss)
  prefill(params, cfg, batch, kv_len, opts)   -> (last logits, cache)
  decode_step(params, cfg, tokens, pos, cache, opts) -> (logits, cache)
  init_cache(cfg, batch, kv_len)              -> cache pytree

``batch`` is a dict: {"tokens": (B,S)} plus, per family,
{"frame_embeds": (B,T_enc,d)} (audio) or {"visual_embeds": (B,V,d)} (vlm).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import encdec, lm
from repro.models.blocks import CallOpts


def init_params(rng, cfg):
    if cfg.is_encoder_decoder:
        return encdec.init_params(rng, cfg)
    return lm.init_params(rng, cfg)


def forward(params, cfg, batch, opts: CallOpts = CallOpts()):
    if cfg.is_encoder_decoder:
        return encdec.forward(params, cfg, batch["tokens"],
                              batch["frame_embeds"], opts)
    return lm.forward(params, cfg, batch["tokens"],
                      visual_embeds=batch.get("visual_embeds"), opts=opts)


def prefill(params, cfg, batch, kv_len: int, opts: CallOpts = CallOpts()):
    if cfg.is_encoder_decoder:
        return encdec.prefill(params, cfg, batch["tokens"],
                              batch["frame_embeds"], kv_len, opts)
    return lm.prefill(params, cfg, batch["tokens"], kv_len,
                      visual_embeds=batch.get("visual_embeds"), opts=opts)


def decode_step(params, cfg, tokens, pos, cache, opts: CallOpts = CallOpts()):
    if cfg.is_encoder_decoder:
        return encdec.decode_step(params, cfg, tokens, pos, cache, opts)
    return lm.decode_step(params, cfg, tokens, pos, cache, opts=opts)


def init_cache(cfg, batch_size: int, kv_len: int, dtype=jnp.bfloat16):
    if cfg.is_encoder_decoder:
        return encdec.init_cache(cfg, batch_size, kv_len, dtype)
    return lm.init_cache(cfg, batch_size, kv_len, dtype)


__all__ = ["CallOpts", "init_params", "forward", "prefill", "decode_step",
           "init_cache"]
