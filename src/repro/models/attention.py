"""GQA attention: full-sequence (KV-chunked flash-style), cross, and decode.

The full-sequence path scans over KV chunks carrying (m, l, acc) in f32 —
the XLA analogue of flash attention, keeping the S x S score matrix out of
HBM. The Pallas TPU kernel in ``repro.kernels.flash_attention`` implements
the same contraction for the MXU; this module is the jnp reference and the
path used for dry-run lowering (Pallas cannot lower on the CPU backend).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import common

NEG_INF = -2.0e38  # large-but-finite; avoids NaNs from (-inf) - (-inf)


@dataclasses.dataclass(frozen=True)
class AttnDims:
    num_heads: int
    num_kv_heads: int
    head_dim: int

    @property
    def q_groups(self) -> int:
        return self.num_heads // self.num_kv_heads


def dims_of(cfg) -> AttnDims:
    return AttnDims(cfg.num_heads, cfg.num_kv_heads, cfg.head_dim)


# ------------------------------------------------------------------ params
def init_attention(rng, cfg, d_model: int | None = None):
    d = d_model or cfg.d_model
    a = dims_of(cfg)
    dt = common.dtype_of(cfg)
    ks = jax.random.split(rng, 4)
    p = {
        "wq": common.dense_param(ks[0], (d, a.num_heads * a.head_dim), dt),
        "wk": common.dense_param(ks[1], (d, a.num_kv_heads * a.head_dim), dt),
        "wv": common.dense_param(ks[2], (d, a.num_kv_heads * a.head_dim), dt),
        "wo": common.dense_param(ks[3], (a.num_heads * a.head_dim, d), dt, in_axis=0),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((a.num_heads * a.head_dim,), dt)
        p["bk"] = jnp.zeros((a.num_kv_heads * a.head_dim,), dt)
        p["bv"] = jnp.zeros((a.num_kv_heads * a.head_dim,), dt)
    return p


def project_qkv(cfg, p, x):
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,K,hd)."""
    a = dims_of(cfg)
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, a.num_heads, a.head_dim)
    k = k.reshape(B, S, a.num_kv_heads, a.head_dim)
    v = v.reshape(B, S, a.num_kv_heads, a.head_dim)
    return q, k, v


# ------------------------------------------------------------------ core SDPA
def _direct_attention(q, k, v, bias):
    """q: (B,S,K,G,hd); k,v: (B,T,K,hd); bias: broadcastable (B,1,1,S,T)."""
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", q, k, preferred_element_type=jnp.float32)
    s = s * scale + bias
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v)
    return o


def _chunked_attention(q, k, v, q_pos, k_pos, causal, window, chunk):
    """Flash-style online-softmax attention, scanning KV chunks.

    q: (B,S,K,G,hd); k/v: (B,T,K,hd); q_pos: (S,), k_pos: (T,).
    """
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    n_chunks = T // chunk
    kc = k.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    kpc = k_pos.reshape(n_chunks, chunk)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qf = q.astype(jnp.float32) * scale

    def body(carry, xs):
        m, l, acc = carry
        k_i, v_i, kp_i = xs
        s = jnp.einsum("bskgd,bckd->bkgsc", qf, k_i.astype(jnp.float32))
        ok = jnp.ones((S, chunk), bool)
        if causal:
            ok &= kp_i[None, :] <= q_pos[:, None]
        if window:
            ok &= kp_i[None, :] > (q_pos[:, None] - window)
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgsc,bckd->bkgsd", p, v_i.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, K, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    a0 = jnp.zeros((B, K, G, S, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, kpc))
    o = acc / jnp.maximum(l[..., None], 1e-30)
    return o.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,S,K,G,hd)


def self_attention(cfg, p, x, positions, *, causal=True, window=0,
                   attn_chunk=2048, use_kernels=False, return_kv=False,
                   seq_shard=None):
    """Full-sequence self attention. x: (B,S,d) -> (B,S,d).

    seq_shard: optional mesh axis spec for sharding the QUERY sequence dim
    (with K/V replicated over it). Used when num_heads does not divide the
    model axis — head-sharding would split heads mid-head_dim and force
    f32 score all-reduces; sequence sharding keeps the contraction local.
    """
    a = dims_of(cfg)
    B, S, _ = x.shape
    q, k, v = project_qkv(cfg, p, x)
    if cfg.pos_emb == "rope":
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    if seq_shard is not None:
        P = jax.sharding.PartitionSpec
        batch_ax = seq_shard[0]
        model_ax = seq_shard[1]
        q = jax.lax.with_sharding_constraint(
            q, P(batch_ax, model_ax, None, None))
        k = jax.lax.with_sharding_constraint(k, P(batch_ax, None, None, None))
        v = jax.lax.with_sharding_constraint(v, P(batch_ax, None, None, None))
    qg = q.reshape(B, S, a.num_kv_heads, a.q_groups, a.head_dim)
    if use_kernels:
        from repro.kernels import flash_attention as fa
        o = fa.flash_attention(qg, k, v, causal=causal, window=window)
    elif S <= max(attn_chunk, 2048) or S % attn_chunk != 0:
        bias = 0.0
        if causal or window:
            bias = common.causal_mask_bias(positions, positions,
                                           window if window else 0)
            bias = jnp.maximum(bias, NEG_INF)[None, None, None]
        o = _direct_attention(qg, k, v, bias).astype(x.dtype)
    else:
        o = _chunked_attention(qg, k, v, positions, positions, causal,
                               window, attn_chunk)
    o = o.reshape(B, S, a.num_heads * a.head_dim)
    out = o @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def cross_attention(cfg, p, x, enc_k, enc_v):
    """Decoder cross-attention against precomputed encoder K/V."""
    a = dims_of(cfg)
    B, S, _ = x.shape
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, a.num_kv_heads, a.q_groups, a.head_dim)
    o = _direct_attention(q, enc_k, enc_v, 0.0).astype(x.dtype)
    return o.reshape(B, S, a.num_heads * a.head_dim) @ p["wo"]


def encode_kv(cfg, p, enc_out):
    """Precompute cross-attention K/V from encoder output."""
    a = dims_of(cfg)
    B, T, _ = enc_out.shape
    k = enc_out @ p["wk"]
    v = enc_out @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return (k.reshape(B, T, a.num_kv_heads, a.head_dim),
            v.reshape(B, T, a.num_kv_heads, a.head_dim))


# ------------------------------------------------------------------ decode
def decode_self_attention(cfg, p, x, cache_k, cache_v, pos, *, window=0,
                          use_kernels=False):
    """One-token decode. x: (B,1,d); cache_k/v: (B,T,K,hd) ring buffers.

    ``pos`` is the absolute position of the new token (scalar int32). Keys
    are stored rope-applied at absolute positions, so ring-buffer reuse is
    correct without rope recomputation. Returns (out, new_k, new_v).
    """
    a = dims_of(cfg)
    B, _, _ = x.shape
    T = cache_k.shape[1]
    q, k, v = project_qkv(cfg, p, x)  # (B,1,H,hd), (B,1,K,hd)
    if cfg.pos_emb == "rope":
        ppos = jnp.full((1,), pos, jnp.int32)
        q = common.apply_rope(q, ppos, cfg.rope_theta)
        k = common.apply_rope(k, ppos, cfg.rope_theta)
    slot = pos % T
    new_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))
    qg = q.reshape(B, 1, a.num_kv_heads, a.q_groups, a.head_dim)
    idx = jnp.arange(T)
    valid = jnp.where(pos >= T, jnp.ones((T,), bool), idx <= pos)
    # quantized caches (e.g. fp8) are converted on-chip after the HBM read
    kr = new_k if new_k.dtype == x.dtype else new_k.astype(x.dtype)
    vr = new_v if new_v.dtype == x.dtype else new_v.astype(x.dtype)
    if use_kernels:
        from repro.kernels import decode_attention as da
        o = da.decode_attention(qg, kr, vr, valid)
    else:
        bias = jnp.where(valid, 0.0, NEG_INF)[None, None, None, None, :]
        o = _direct_attention(qg, kr, vr, bias).astype(x.dtype)
    o = o.reshape(B, 1, a.num_heads * a.head_dim)
    return o @ p["wo"], new_k, new_v
