"""Whisper-style encoder-decoder transformer.

The mel-spectrogram + conv frontend is a STUB per the assignment:
``frame_embeds`` (B, encoder_seq, d_model) arrive precomputed. This module
implements the full transformer: bidirectional encoder, and a decoder with
self-attention (KV-cached) + cross-attention to the encoded audio.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, common, ffn as ffn_mod
from repro.models.blocks import CallOpts


def _init_layer(rng, cfg, cross: bool):
    ks = jax.random.split(rng, 3)
    p = {
        "ln1": common.init_norm(cfg, cfg.d_model),
        "attn": attention.init_attention(ks[0], cfg),
        "ln_ffn": common.init_norm(cfg, cfg.d_model),
        "ffn": ffn_mod.init_dense_ffn(ks[1], cfg),
    }
    if cross:
        p["ln_x"] = common.init_norm(cfg, cfg.d_model)
        p["xattn"] = attention.init_attention(ks[2], cfg)
    return p


def init_params(rng, cfg):
    ks = jax.random.split(rng, 6)
    dt = common.dtype_of(cfg)

    def stacked(rng_, n, cross):
        return jax.vmap(lambda r: _init_layer(r, cfg, cross))(
            jax.random.split(rng_, n))

    return {
        "embed": common.embed_param(ks[0], (cfg.vocab_size, cfg.d_model), dt),
        "pos_dec": common.embed_param(ks[1], (cfg.max_learned_pos, cfg.d_model), dt),
        "pos_enc": common.embed_param(ks[2], (cfg.encoder_seq, cfg.d_model), dt),
        "encoder": stacked(ks[3], cfg.encoder_layers, cross=False),
        "decoder": stacked(ks[4], cfg.num_layers, cross=True),
        "ln_enc": common.init_norm(cfg, cfg.d_model),
        "ln_dec": common.init_norm(cfg, cfg.d_model),
    }


def encode(params, cfg, frame_embeds, opts: CallOpts = CallOpts()):
    """frame_embeds: (B, T_enc, d) stubbed conv features -> (B, T_enc, d)."""
    T = frame_embeds.shape[1]
    pos = jnp.arange(T, dtype=jnp.int32)
    dt = common.dtype_of(cfg)
    h = frame_embeds.astype(dt) + params["pos_enc"][pos].astype(dt)

    def body(h_, lp):
        hn = common.apply_norm(cfg, lp["ln1"], h_)
        h_ = h_ + attention.self_attention(cfg, lp["attn"], hn, pos,
                                           causal=False,
                                           attn_chunk=opts.attn_chunk,
                                           use_kernels=opts.use_kernels)
        hn = common.apply_norm(cfg, lp["ln_ffn"], h_)
        return h_ + ffn_mod.dense_ffn(cfg, lp["ffn"], hn), None

    if opts.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["encoder"])
    return common.apply_norm(cfg, params["ln_enc"], h)


def encode_cross_kv(params, cfg, enc_out):
    """Precompute per-decoder-layer cross K/V: pytrees stacked over layers."""
    def one(lp):
        return attention.encode_kv(cfg, lp["xattn"], enc_out)
    return jax.vmap(one, in_axes=0)(params["decoder"])


def _decoder_layer_full(cfg, lp, h, pos, cross_kv, opts, kv_len):
    hn = common.apply_norm(cfg, lp["ln1"], h)
    if kv_len is not None:
        o, (k, v) = attention.self_attention(
            cfg, lp["attn"], hn, pos, attn_chunk=opts.attn_chunk,
            use_kernels=opts.use_kernels, return_kv=True)
        from repro.models.blocks import _kv_into_ring
        ce = {"k": _kv_into_ring(k, kv_len), "v": _kv_into_ring(v, kv_len)}
    else:
        o = attention.self_attention(cfg, lp["attn"], hn, pos,
                                     attn_chunk=opts.attn_chunk,
                                     use_kernels=opts.use_kernels)
        ce = None
    h = h + o
    hn = common.apply_norm(cfg, lp["ln_x"], h)
    h = h + attention.cross_attention(cfg, lp["xattn"], hn, *cross_kv)
    hn = common.apply_norm(cfg, lp["ln_ffn"], h)
    return h + ffn_mod.dense_ffn(cfg, lp["ffn"], hn), ce


def forward(params, cfg, tokens, frame_embeds, opts: CallOpts = CallOpts()):
    """Teacher-forced full-sequence decoder logits (training)."""
    enc = encode(params, cfg, frame_embeds, opts)
    cross_kv = encode_cross_kv(params, cfg, enc)
    S = tokens.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)
    h = params["embed"][tokens] + params["pos_dec"][pos].astype(common.dtype_of(cfg))

    def body(h_, xs):
        lp, ckv = xs
        h_, _ = _decoder_layer_full(cfg, lp, h_, pos, ckv, opts, None)
        return h_, None

    if opts.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, (params["decoder"], cross_kv))
    h = common.apply_norm(cfg, params["ln_dec"], h)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"],
                        preferred_element_type=jnp.float32)
    return logits, jnp.zeros((), jnp.float32)


def prefill(params, cfg, tokens, frame_embeds, kv_len,
            opts: CallOpts = CallOpts()):
    """Encode audio + prefill decoder. Returns (last logits, cache)."""
    enc = encode(params, cfg, frame_embeds, opts)
    cross_kv = encode_cross_kv(params, cfg, enc)
    S = tokens.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)
    h = params["embed"][tokens] + params["pos_dec"][pos].astype(common.dtype_of(cfg))

    def body(h_, xs):
        lp, ckv = xs
        h_, ce = _decoder_layer_full(cfg, lp, h_, pos, ckv, opts, kv_len)
        return h_, ce

    h, self_cache = jax.lax.scan(body, h, (params["decoder"], cross_kv))
    h = common.apply_norm(cfg, params["ln_dec"], h[:, -1:])
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"],
                        preferred_element_type=jnp.float32)
    return logits, {"self": self_cache, "cross": cross_kv}


def decode_step(params, cfg, tokens, pos, cache, opts: CallOpts = CallOpts()):
    """One decoder token. cache = {self: stacked KV, cross: stacked KV}."""
    ppos = jnp.minimum(jnp.full((1,), pos, jnp.int32), cfg.max_learned_pos - 1)
    h = params["embed"][tokens] + params["pos_dec"][ppos].astype(common.dtype_of(cfg))

    def body(h_, xs):
        lp, ce, ckv = xs
        hn = common.apply_norm(cfg, lp["ln1"], h_)
        o, nk, nv = attention.decode_self_attention(
            cfg, lp["attn"], hn, ce["k"], ce["v"], pos,
            use_kernels=opts.use_kernels)
        h_ = h_ + o
        hn = common.apply_norm(cfg, lp["ln_x"], h_)
        h_ = h_ + attention.cross_attention(cfg, lp["xattn"], hn, *ckv)
        hn = common.apply_norm(cfg, lp["ln_ffn"], h_)
        h_ = h_ + ffn_mod.dense_ffn(cfg, lp["ffn"], hn)
        return h_, {"k": nk, "v": nv}

    h, new_self = jax.lax.scan(body, h,
                               (params["decoder"], cache["self"], cache["cross"]))
    h = common.apply_norm(cfg, params["ln_dec"], h)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"],
                        preferred_element_type=jnp.float32)
    return logits, {"self": new_self, "cross": cache["cross"]}


def init_cache(cfg, batch, kv_len, dtype=jnp.bfloat16):
    a = attention.dims_of(cfg)
    L = cfg.num_layers

    def kv(T):
        return {"k": jnp.zeros((L, batch, T, a.num_kv_heads, a.head_dim), dtype),
                "v": jnp.zeros((L, batch, T, a.num_kv_heads, a.head_dim), dtype)}

    cross = kv(cfg.encoder_seq)
    return {"self": kv(kv_len), "cross": (cross["k"], cross["v"])}
