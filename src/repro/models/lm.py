"""Decoder-only LM covering dense / MoE / SSM / hybrid / VLM families."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import blocks, common
from repro.models.blocks import CallOpts


def init_params(rng, cfg):
    ks = jax.random.split(rng, 4)
    dt = common.dtype_of(cfg)
    p = {
        "embed": common.embed_param(ks[0], (cfg.vocab_size, cfg.d_model), dt),
        "stack": blocks.init_stack(ks[1], cfg),
        "ln_f": common.init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = common.dense_param(ks[2], (cfg.d_model, cfg.vocab_size), dt)
    if cfg.pos_emb == "learned":
        p["pos"] = common.embed_param(ks[3], (cfg.max_learned_pos, cfg.d_model), dt)
    if cfg.num_visual_tokens:
        # projector bias stand-in: stubbed vision tower emits d_model embeds
        p["visual_scale"] = jnp.ones((), jnp.float32)
    return p


def _embed(cfg, p, tokens, positions, visual_embeds=None):
    h = p["embed"][tokens]
    if cfg.name.startswith("gemma"):
        h = (h.astype(jnp.float32) * jnp.sqrt(float(cfg.d_model))).astype(h.dtype)
    if visual_embeds is not None:
        ve = (visual_embeds.astype(jnp.float32) * p["visual_scale"])
        h = jnp.concatenate([ve.astype(h.dtype), h], axis=1)
    if cfg.pos_emb == "learned":
        h = h + p["pos"][positions]
    return h


def _unembed(cfg, p, h):
    w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    return jnp.einsum("bsd,dv->bsv", h, w, preferred_element_type=jnp.float32)


def forward(params, cfg, tokens, *, visual_embeds=None,
            opts: CallOpts = CallOpts()):
    """Full-sequence logits. tokens: (B, S_text); visual_embeds: (B, V, d)."""
    B, S_text = tokens.shape
    S = S_text + (visual_embeds.shape[1] if visual_embeds is not None else 0)
    positions = jnp.arange(S, dtype=jnp.int32)
    h = _embed(cfg, params, tokens, positions, visual_embeds)
    h, aux, _ = blocks.apply_stack(cfg, params["stack"], h, positions, opts)
    h = common.apply_norm(cfg, params["ln_f"], h)
    return _unembed(cfg, params, h), aux


def prefill(params, cfg, tokens, kv_len: int, *, visual_embeds=None,
            opts: CallOpts = CallOpts()):
    """Prefill: returns (last-token logits, cache)."""
    B, S_text = tokens.shape
    S = S_text + (visual_embeds.shape[1] if visual_embeds is not None else 0)
    positions = jnp.arange(S, dtype=jnp.int32)
    h = _embed(cfg, params, tokens, positions, visual_embeds)
    h, aux, cache = blocks.apply_stack(cfg, params["stack"], h, positions,
                                       opts, kv_len=kv_len)
    h = common.apply_norm(cfg, params["ln_f"], h[:, -1:])
    return _unembed(cfg, params, h), cache


def decode_step(params, cfg, tokens, pos, cache, *, opts: CallOpts = CallOpts()):
    """One decode step. tokens: (B, 1); pos: scalar absolute position.

    Returns (logits (B,1,V), new_cache).
    """
    positions = jnp.full((1,), pos, jnp.int32)
    h = params["embed"][tokens]
    if cfg.name.startswith("gemma"):
        h = (h.astype(jnp.float32) * jnp.sqrt(float(cfg.d_model))).astype(h.dtype)
    if cfg.pos_emb == "learned":
        h = h + params["pos"][jnp.minimum(positions, cfg.max_learned_pos - 1)]
    h, new_cache = blocks.decode_stack(cfg, params["stack"], h, pos, cache, opts)
    h = common.apply_norm(cfg, params["ln_f"], h)
    return _unembed(cfg, params, h), new_cache


def init_cache(cfg, batch, kv_len, dtype=jnp.bfloat16):
    return blocks.init_stack_cache(cfg, batch, kv_len, dtype)
