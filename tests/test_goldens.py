"""Golden-trace regression suite.

Every registered scenario runs at a small scale under fixed seeds and
its ``RunMetrics`` must match the checked-in golden
(``tests/goldens/<scenario>__<policy>.json``) within tight tolerances —
any engine or policy change that shifts SLO/cost behavior fails here
with a field-by-field diff instead of silently drifting the paper's
reproduced claims.

Intentional behavior changes regenerate the corpus:

    PYTHONPATH=src python -m pytest tests/test_goldens.py --update-goldens

then commit the JSON diff alongside the change that explains it.
"""
import pathlib

import pytest

from repro.core.metrics import RunMetrics
from repro.workloads.scenarios import get_scenario, scenario_names

GOLDEN_DIR = pathlib.Path(__file__).parent / "goldens"
GOLDEN_SEED = 42
GOLDEN_DURATION_S = 45.0  # small scale: every case sub-second on CPU

# every scenario is pinned under the paper's policy; the smooth control
# case is additionally pinned under both baselines so baseline-policy
# regressions are caught too
CASES = [(name, "has") for name in scenario_names()]
CASES += [("steady_poisson", "kserve"), ("steady_poisson", "fast")]

# counts compare exactly; floats within 1e-6 relative (loose enough for
# cross-platform libm noise, tight enough that any real behavior shift
# — one extra request, one different scaling decision — fails)
REL_TOL = 1e-6
ABS_TOL = 1e-9


def golden_path(name: str, policy: str) -> pathlib.Path:
    return GOLDEN_DIR / f"{name}__{policy}.json"


def run_case(name: str, policy: str) -> RunMetrics:
    scen = get_scenario(name)
    return scen.run(policy=policy, seed=GOLDEN_SEED,
                    duration_s=GOLDEN_DURATION_S).metrics


@pytest.mark.parametrize("name,policy", CASES,
                         ids=[f"{n}-{p}" for n, p in CASES])
def test_golden(name, policy, request):
    path = golden_path(name, policy)
    metrics = run_case(name, policy)
    if request.config.getoption("--update-goldens"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        metrics.save(path)
        pytest.skip(f"golden rewritten: {path.name}")
    assert path.exists(), (
        f"missing golden {path.name}; generate the corpus with "
        f"pytest tests/test_goldens.py --update-goldens")
    golden = RunMetrics.load(path)
    diffs = golden.diff(metrics, rel=REL_TOL, abs_tol=ABS_TOL)
    assert not diffs, (
        f"{name}/{policy} drifted from golden ({len(diffs)} fields):\n  "
        + "\n  ".join(diffs)
        + "\nIf intentional, rerun with --update-goldens and commit.")


def test_corpus_has_no_orphans():
    """Every checked-in golden corresponds to a registered case, so
    renamed/removed scenarios can't leave stale pins behind."""
    expected = {golden_path(n, p).name for n, p in CASES}
    actual = {p.name for p in GOLDEN_DIR.glob("*.json")}
    assert actual <= expected, f"orphan goldens: {sorted(actual - expected)}"


HOMOGENEOUS_EQUIV_CASES = [("steady_poisson", "has"),
                           ("steady_poisson", "kserve"),
                           ("steady_poisson", "fast"),
                           ("azure_standard", "has")]


@pytest.mark.parametrize("name,policy", HOMOGENEOUS_EQUIV_CASES,
                         ids=[f"{n}-{p}" for n, p in
                              HOMOGENEOUS_EQUIV_CASES])
def test_homogeneous_fleet_byte_identical_to_golden(name, policy):
    """Heterogeneous-fleet equivalence: driving the mixed-fleet code
    path with a single reference-type pool must produce RunMetrics
    BYTE-identical to the pre-refactor goldens — not merely within
    tolerance. Placement, physics, cost accounting, and serialization
    must all collapse exactly to the legacy behavior when every chip is
    the default type."""
    path = golden_path(name, policy)
    if not path.exists():
        pytest.skip("corpus not generated yet")
    scen = get_scenario(name)
    # run through the explicit-fleet construction path (exercises the
    # fleet plumbing, not the legacy max_gpus shortcut)
    metrics = scen.run(policy=policy, seed=GOLDEN_SEED,
                       duration_s=GOLDEN_DURATION_S,
                       fleet=(("default", scen.max_gpus),)).metrics
    assert metrics.to_json() == path.read_text(), (
        f"{name}/{policy}: single-default-type fleet run is not "
        f"byte-identical to the pre-heterogeneity golden")


def test_goldens_carry_real_traffic():
    """Guard the corpus itself: a golden pinned on an empty or trivially
    idle run would regression-test nothing."""
    for name, policy in CASES:
        path = golden_path(name, policy)
        if not path.exists():
            pytest.skip("corpus not generated yet")
        g = RunMetrics.load(path)
        assert g.n_arrived > 100, (name, policy)
        assert g.n_arrived == g.n_completed + g.n_dropped, (name, policy)
        assert g.cost_usd > 0, (name, policy)
