"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

rng = np.random.default_rng(0)


def arr(*s, dtype=jnp.bfloat16, scale=1.0):
    return jnp.asarray(rng.standard_normal(s) * scale, dtype)


def rel_err(a, b):
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    return float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-9))


@pytest.mark.parametrize("B,S,T,K,G,hd", [
    (1, 128, 128, 1, 1, 64),
    (2, 256, 256, 2, 2, 64),
    (1, 128, 128, 2, 4, 128),
])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention(B, S, T, K, G, hd, dtype, causal, window):
    q = arr(B, S, K, G, hd, dtype=dtype)
    k = arr(B, T, K, hd, dtype=dtype)
    v = arr(B, T, K, hd, dtype=dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    assert out.shape == want.shape and out.dtype == want.dtype
    assert rel_err(out, want) < (0.03 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("B,T,K,G,hd,pos", [
    (2, 128, 2, 2, 64, 100),
    (1, 256, 1, 8, 128, 10),
    (4, 64, 4, 1, 64, 63),
])
def test_decode_attention(B, T, K, G, hd, pos):
    q = arr(B, 1, K, G, hd)
    k = arr(B, T, K, hd)
    v = arr(B, T, K, hd)
    valid = jnp.asarray(np.arange(T) <= pos)
    out = ops.decode_attention(q, k, v, valid, block_k=64)
    want = ref.decode_attention_ref(q, k, v, valid)
    assert rel_err(out, want) < 0.03


@pytest.mark.parametrize("nc,B,Q,nh,hd,N", [
    (2, 1, 32, 2, 32, 16),
    (4, 2, 64, 4, 64, 32),
    (8, 1, 16, 1, 64, 128),
])
def test_ssd_chunk_scan(nc, B, Q, nh, hd, N):
    xc = arr(nc, B, Q, nh, hd, dtype=jnp.float32, scale=0.2)
    Bc = arr(nc, B, Q, nh, N, dtype=jnp.float32, scale=0.2)
    Cc = arr(nc, B, Q, nh, N, dtype=jnp.float32, scale=0.2)
    dtc = jnp.abs(arr(nc, B, Q, nh, dtype=jnp.float32, scale=0.05))
    dAc = -jnp.abs(arr(nc, B, Q, nh, dtype=jnp.float32, scale=0.1))
    h0 = jnp.asarray(rng.standard_normal((B, nh, hd, N)) * 0.1, jnp.float32)
    hk, yk = ops.ssd_chunk_scan(xc, Bc, Cc, dtc, dAc, h0)
    hr, yr = ref.ssd_chunk_scan_ref(xc, Bc, Cc, dtc, dAc, h0)
    assert rel_err(yk, yr) < 1e-4
    assert rel_err(hk, hr) < 1e-4


@pytest.mark.parametrize("E,C,K,N", [(2, 64, 128, 64), (4, 128, 64, 96),
                                     (1, 32, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_gmm(E, C, K, N, dtype):
    x = arr(E, C, K, dtype=dtype)
    w = arr(E, K, N, dtype=dtype)
    out = ops.gmm(x, w, block_c=32, block_n=32, block_k=64)
    want = ref.gmm_ref(x, w)
    assert rel_err(out, want) < (0.02 if dtype == jnp.bfloat16 else 1e-5)


def test_expert_ffn():
    G, E, C, d, f = 2, 2, 32, 64, 128
    xe = arr(G, E, C, d)
    wg, wu = arr(E, d, f, scale=0.3), arr(E, d, f, scale=0.3)
    wd = arr(E, f, d, scale=0.3)
    out = ops.expert_ffn(xe, wg, wu, wd, "silu", block_c=32, block_n=32,
                         block_k=32)
    want = ref.expert_ffn_ref(xe, wg, wu, wd, "silu")
    assert rel_err(out, want) < 0.05
