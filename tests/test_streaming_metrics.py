"""Streaming-metrics equivalence: the constant-memory accumulator must
agree with the exact pooled path.

Covers the PR 9 satellite: sketch quantiles within the documented error
bound on adversarial latency distributions (bimodal, heavy-tail,
constant), exact-mode byte-identity below the spill limit, RunMetrics
round-tripping of the new ``streaming`` field, and end-to-end
stream-vs-retain equality of a real wide-engine run.
"""
import json

import numpy as np
import pytest

from repro.core.metrics import (DEFAULT_MULTIPLIERS, RunMetrics,
                                RunStreamStats, STREAM_EXACT_LIMIT,
                                StreamingQuantiles)
from repro.core.slo import percentiles
from repro.workloads.scenarios import get_scenario

#: documented sketch accuracy (StreamingQuantiles docstring)
DOC_BOUND = 0.006


def _adversarial(name: str, n: int, rng: np.random.Generator) -> np.ndarray:
    if name == "bimodal":
        # 2 ms floor mode vs 1.5 s tail mode, asymmetric weights so the
        # queried quantiles sit inside a mode, not on the jump
        pick = rng.random(n) < 0.7
        return np.where(pick, rng.normal(2e-3, 2e-4, n).clip(1e-4),
                        rng.normal(1.5, 0.1, n).clip(0.5))
    if name == "heavy_tail":
        return rng.pareto(1.5, n) * 1e-2 + 1e-3
    if name == "constant":
        return np.full(n, 0.125)
    raise KeyError(name)


@pytest.mark.parametrize("dist", ["bimodal", "heavy_tail", "constant"])
def test_sketch_within_documented_bound(dist):
    """Sketch-mode quantiles vs numpy on adversarial distributions."""
    rng = np.random.default_rng(11)
    data = _adversarial(dist, 50_000, rng)
    q = StreamingQuantiles(exact_limit=1_000)  # force the spill early
    for chunk in np.array_split(data, 37):     # uneven streamed batches
        q.add_many(chunk)
    assert q.is_sketch and q.n == len(data)
    got = q.percentiles()
    want = percentiles(data)
    for k in want:
        rel = abs(got[k] - want[k]) / want[k]
        assert rel <= DOC_BOUND, (dist, k, got[k], want[k], rel)
    assert q.rel_err_bound < DOC_BOUND


def test_exact_mode_byte_identical_below_limit():
    """Below the spill limit the accumulator IS slo.percentiles."""
    rng = np.random.default_rng(5)
    data = rng.lognormal(-3.0, 1.0, 5_000)
    q = StreamingQuantiles()
    q.add_many(data[:2_000])
    q.add_many(data[2_000:])
    assert not q.is_sketch
    assert q.percentiles() == percentiles(data)


def test_sketch_clamps_out_of_range():
    """Values outside [lo, hi) land in the edge bins, not out of range."""
    q = StreamingQuantiles(exact_limit=0)
    q.add_many([1e-9, 1e-8, 1e-7, 1e6])
    got = q.percentiles()
    assert all(np.isfinite(v) and v > 0 for v in got.values())
    assert got["p50"] <= q.lo * 2          # underflow edge bin
    assert got["p99"] >= q.hi / 2          # overflow edge bin


def test_empty_accumulator_is_inf():
    q = StreamingQuantiles(exact_limit=0)
    assert q.percentiles() == percentiles(np.empty(0))
    s = RunStreamStats()
    assert s.n == 0 and all(v == 0 for v in s.viol.values())


def test_violation_counts_exact_even_in_sketch_mode():
    """SLO violation counters never degrade: fold-time comparison, not
    a sketch read-back."""

    class _R:  # minimal Request stand-in
        def __init__(self, lat):
            self.latency = lat

    rng = np.random.default_rng(9)
    lats = rng.lognormal(-2.0, 1.2, 30_000)
    base = 0.2
    s = RunStreamStats(exact_limit=100)    # deep in sketch mode
    for chunk in np.array_split(lats, 11):
        s.fold(base, [_R(x) for x in chunk])
    assert s.quantiles.is_sketch
    norm = lats / base
    for m in DEFAULT_MULTIPLIERS:
        assert s.viol[m] == int((norm > m).sum())
    # None latencies (undelivered stand-ins) are ignored, like the pool
    s.fold(base, [_R(None)])
    assert s.n == len(lats)


def test_describe_tracks_mode_transition():
    s = RunStreamStats(exact_limit=10)
    d = s.describe()
    assert d == {"mode": "exact", "n": 0, "exact_limit": 10}

    class _R:
        def __init__(self, lat):
            self.latency = lat

    s.fold(1.0, [_R(0.5)] * 25)
    d = s.describe()
    assert d["mode"] == "sketch" and d["n"] == 25
    assert d["bins"] == 4096 and 0 < d["rel_err_bound"] <= DOC_BOUND


def test_default_exact_limit_is_constant_memory_scale():
    """The default crossover keeps exact-mode RAM modest (~0.8 MB of
    floats) while every golden-scale run stays exact."""
    assert 10_000 <= STREAM_EXACT_LIMIT <= 1_000_000


# ---- RunMetrics integration ------------------------------------------------

WIDE_SMALL = dict(width=8, duration_s=8.0, seed=5)


def _wide_run(stream: bool):
    sc = get_scenario("azure_wide").with_(
        width=WIDE_SMALL["width"],
        sim_overrides=({"stream_metrics": True, "rng_isolation": True}
                       if stream else {"rng_isolation": True}))
    return sc.run("has", seed=WIDE_SMALL["seed"],
                  duration_s=WIDE_SMALL["duration_s"]).metrics


def test_stream_vs_retain_equal_below_exact_limit():
    """End to end: a stream-metrics run and a retain-everything run of
    the same config produce the same record (the streaming field aside)
    — the accumulator is exact below the spill limit, violation
    counters always."""
    streamed = _wide_run(stream=True)
    retained = _wide_run(stream=False)
    assert streamed.streaming is not None and retained.streaming is None
    assert streamed.streaming["mode"] == "exact"
    ds, dr = streamed.to_dict(), retained.to_dict()
    ds.pop("streaming")
    assert ds == dr


def test_streaming_field_round_trips():
    """from_dict/from_json must round-trip the new streaming fields."""
    m = _wide_run(stream=True)
    again = RunMetrics.from_json(m.to_json())
    assert again.streaming == m.streaming
    assert again.to_json() == m.to_json()
    # and absent stays absent (legacy goldens): no key, None field
    plain = _wide_run(stream=False)
    d = json.loads(plain.to_json())
    assert "streaming" not in d
    assert RunMetrics.from_dict(d).streaming is None


def test_missing_multiplier_raises_clear_error():
    """A sink that doesn't track a requested multiplier must fail the
    fold loudly, not silently report a wrong rate."""
    sc = get_scenario("azure_wide").with_(
        width=4, sim_overrides={"stream_metrics": True,
                                "stream_slo_multipliers": (1.5,)})
    with pytest.raises(ValueError, match="stream_slo_multipliers"):
        sc.run("has", seed=1, duration_s=6.0)
