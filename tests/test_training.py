"""Training substrate tests: optimizer math, data determinism, checkpoint
round-trip, loss decrease, microbatch-equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ARCHS, reduced
from repro.models import CallOpts
from repro.training import (checkpoint, data as data_mod,
                            optimizer as opt_mod, steps)

CFG = reduced(ARCHS["olmo-1b"])


def test_adamw_decreases_quadratic():
    adamw = opt_mod.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                                weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt_mod.init_opt_state(params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt_mod.apply_updates(adamw, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_lr_schedule_shape():
    adamw = opt_mod.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_frac=0.1)
    lrs = [float(opt_mod.schedule(adamw, jnp.asarray(s))) for s in
           [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, rel=1e-3)


def test_data_deterministic_and_structured():
    ds = data_mod.SyntheticLMData(vocab_size=512, seed=3)
    b1 = ds.batch(7, 4, 64)["tokens"]
    b2 = ds.batch(7, 4, 64)["tokens"]
    np.testing.assert_array_equal(b1, b2)
    assert b1.max() < 512 and b1.min() >= 0
    # motif structure: second motif block equals the first
    m = ds.ngram_repeat
    np.testing.assert_array_equal(b1[:, :m], b1[:, m:2 * m])


def test_loss_decreases():
    adamw = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=50)
    train_step = jax.jit(steps.make_train_step(CFG, adamw, CallOpts()))
    params = models.init_params(jax.random.PRNGKey(0), CFG)
    opt_state = opt_mod.init_opt_state(params)
    ds = data_mod.SyntheticLMData(CFG.vocab_size)
    losses = []
    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(step, 8, 128).items()}
        params, opt_state, m = train_step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_microbatching_matches_full_batch():
    """Gradient accumulation must be exact (same loss and params)."""
    adamw = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    params = models.init_params(jax.random.PRNGKey(0), CFG)
    ds = data_mod.SyntheticLMData(CFG.vocab_size)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0, 8, 64).items()}
    outs = {}
    for m in (1, 4):
        step = jax.jit(steps.make_train_step(CFG, adamw, CallOpts(), m))
        p, s, metrics = step(params, opt_mod.init_opt_state(params), batch)
        outs[m] = (p, float(metrics["loss"]))
    assert outs[1][1] == pytest.approx(outs[4][1], rel=2e-2)
    err = max(float(jnp.abs(a.astype(jnp.float32)
                            - b.astype(jnp.float32)).max())
              for a, b in zip(jax.tree.leaves(outs[1][0]),
                              jax.tree.leaves(outs[4][0])))
    assert err < 5e-2


def test_checkpoint_roundtrip(tmp_path):
    params = models.init_params(jax.random.PRNGKey(0), CFG)
    state = opt_mod.init_opt_state(params)
    tree = {"params": params, "opt": state}
    path = str(tmp_path / "ckpt.npz")
    checkpoint.save(path, tree)
    restored = checkpoint.restore(path, tree)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))
