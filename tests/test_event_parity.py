"""Tick-vs-event engine parity: both engines run the same seeded trace
and must agree on conservation, completion counts, and latency/cost
metrics (within the tolerance the tick quantization itself introduces).

The tick engine (core/simulator_tick.py) quantizes dispatch to 20 ms
tick boundaries, so its latencies sit up to ~2 ticks above the event
engine's continuous-time values; cost integrates identically up to one
tick per allocation change.
"""
import numpy as np
import pytest

from repro.configs import ARCHS

# the tick engine makes these the most expensive tests in the repo; the
# golden-trace suite guards the event engine on the fast path, and CI
# runs this module's full parity check on a nightly schedule
pytestmark = pytest.mark.slow
from repro.core import (ClusterSimulator, FaSTGShareLikePolicy, FnSpec,
                        HybridAutoScaler, KServeLikePolicy, Reconfigurator,
                        SimConfig, TickClusterSimulator)
from repro.core.vgpu import PodAlloc
from repro.workloads import TraceConfig, arrivals

SPEC = FnSpec(ARCHS["olmo-1b"])
DURATION = 30.0
BASE_RPS = 15.0
TICK_S = 0.02


@pytest.fixture(scope="module")
def trace():
    return arrivals(TraceConfig(duration_s=DURATION, base_rps=BASE_RPS,
                                seed=11))


def _run(engine_cls, policy_name, trace):
    recon = Reconfigurator(num_gpus=0, max_gpus=32)
    pol = {"has": HybridAutoScaler, "kserve": KServeLikePolicy,
           "fast": FaSTGShareLikePolicy}[policy_name](recon)
    pol.prewarm(SPEC, BASE_RPS)
    sim = engine_cls(SPEC, pol, recon, trace,
                     SimConfig(duration_s=DURATION,
                               whole_gpu_cost=policy_name == "kserve"))
    return sim.run()


class StaticPolicy:
    """No-op policy: isolates engine mechanics from control-loop feedback."""

    def tick(self, now, spec, observed_rps):
        return []


def _run_static(engine_cls, trace):
    recon = Reconfigurator(num_gpus=0, max_gpus=8)
    for _ in range(3):
        recon.place_pod(PodAlloc(fn_id=SPEC.fn_id, sm=4, quota=0.5, batch=8),
                        None, now=0.0, cold_start_s=0.0)
    sim = engine_cls(SPEC, StaticPolicy(), recon, trace,
                     SimConfig(duration_s=DURATION))
    return sim.run()


def test_static_cluster_parity(trace):
    """With a fixed pod set (no autoscaler feedback) the engines must
    agree tightly: same completions, same drops, cost within the
    one-tick integration error, latencies within tick quantization."""
    tick = _run_static(TickClusterSimulator, trace)
    ev = _run_static(ClusterSimulator, trace)
    for res in (tick, ev):
        assert res.n_arrived == res.n_completed + res.n_dropped
    assert ev.n_arrived == tick.n_arrived
    assert ev.n_completed == tick.n_completed
    assert ev.n_dropped == tick.n_dropped
    # cost: identical allocation held for the same horizon
    assert ev.cost_usd == pytest.approx(tick.cost_usd, rel=0.05)
    assert ev.pod_seconds == pytest.approx(tick.pod_seconds, rel=0.05)
    # the tick engine delays each dispatch by up to ~2 ticks, never less
    for p in ("p50", "p99"):
        assert abs(ev.pcts[p] - tick.pcts[p]) <= 3 * TICK_S, p


@pytest.mark.parametrize("policy", ["has", "kserve", "fast"])
def test_policy_driven_parity(policy, trace):
    """Full control loop: conservation holds exactly; completions match;
    p50/p99 and cost agree within the feedback-amplified tolerance."""
    tick = _run(TickClusterSimulator, policy, trace)
    ev = _run(ClusterSimulator, policy, trace)
    for res in (tick, ev):
        assert res.n_arrived == res.n_completed + res.n_dropped
        assert res.n_arrived == len(trace)
    assert ev.n_completed == tick.n_completed
    assert ev.n_dropped == tick.n_dropped
    assert ev.cost_usd == pytest.approx(tick.cost_usd, rel=0.25)
    assert abs(ev.pcts["p50"] - tick.pcts["p50"]) \
        <= max(3 * TICK_S, 0.5 * tick.pcts["p50"])
    assert abs(ev.pcts["p99"] - tick.pcts["p99"]) \
        <= max(5 * TICK_S, 0.5 * tick.pcts["p99"])


def test_tick_converges_to_event():
    """The event engine is the tick_s -> 0 limit of the tick engine: a
    finer tick must move the tick engine's violation rates toward (and
    near) the event engine's, showing the residual gap at 20 ms is
    quantization bias, not an engine discrepancy."""
    mult = 2.0
    trace_ = arrivals(TraceConfig(duration_s=DURATION, base_rps=BASE_RPS,
                                  seed=11))

    def run_tick(tick_s):
        recon = Reconfigurator(num_gpus=0, max_gpus=32)
        pol = HybridAutoScaler(recon)
        pol.prewarm(SPEC, BASE_RPS)
        sim = TickClusterSimulator(SPEC, pol, recon, trace_,
                                   SimConfig(duration_s=DURATION,
                                             tick_s=tick_s))
        return sim.run().violations([mult])[mult]

    ev = _run(ClusterSimulator, "has", trace_).violations([mult])[mult]
    coarse = run_tick(0.02)
    fine = run_tick(0.005)
    assert abs(fine - ev) <= abs(coarse - ev) + 0.02  # converging
    assert abs(fine - ev) <= 0.08  # and already close at 5 ms


def test_event_engine_faster_on_long_trace():
    """The point of the rewrite: the event engine's work scales with
    events, not ticks. On a sparse long trace it must beat the tick
    engine by a wide margin."""
    import time
    arr = arrivals(TraceConfig(duration_s=300.0, base_rps=4.0, seed=3))

    def run(cls):
        recon = Reconfigurator(num_gpus=0, max_gpus=8)
        pol = HybridAutoScaler(recon)
        pol.prewarm(SPEC, 4.0)
        # CPU time, not wall clock: immune to scheduler stalls on
        # loaded CI runners
        t0 = time.process_time()
        res = cls(SPEC, pol, recon, arr, SimConfig(duration_s=300.0)).run()
        return time.process_time() - t0, res

    wall_tick, res_tick = run(TickClusterSimulator)
    wall_ev, res_ev = run(ClusterSimulator)
    assert res_ev.n_completed == res_tick.n_completed
    # conservative 3x floor so CI jitter can't flake this; locally ~10-30x
    assert wall_ev * 3 < wall_tick
