"""PR 10 batched-sweep units: BatchedKalman lane parity, the vectorized
observed-rate pass, the early-tick observed-rate normalization fix, the
sterile-down fast path, and the reclaim-bookkeeping prune.

The end-to-end byte-identity of the batched sweep is pinned by
``test_engine_parity.py`` (wide vs scalar vs batched-off); these tests
pin the component-level claims the batched path is built on, so a
failure localizes to the layer that broke.
"""
import dataclasses
import random

import numpy as np
import pytest

from repro.core import SimConfig
from repro.core.events import OBS_WINDOW_S, EventEngine, window_counts
from repro.core.kalman import BatchedKalman, KalmanPredictor
from repro.workloads.scenarios import get_scenario, make_policy
from tests.test_wide_engine import build_wide

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - hypothesis-free CI lanes
    HAVE_HYPOTHESIS = False


# ---- BatchedKalman: lane-exact parity with the scalar filter ---------------

def _random_predictor(rng):
    return KalmanPredictor(
        A=rng.uniform(0.5, 1.5), H=rng.uniform(0.5, 1.5),
        Q=rng.choice([0.0, rng.uniform(0.0, 16.0)]),
        D=rng.choice([0.0, rng.uniform(0.0, 16.0)]),
        R=rng.uniform(-5.0, 50.0), P=rng.choice([0.0, rng.uniform(0.0, 4.0)]))


def _assert_bank_matches(scalars, bank, zs_seq, mask=None):
    """Drive the scalar filters and the bank through the same
    observation sequence; state and returns must match BITWISE."""
    n = len(scalars)
    if mask is None:
        mask = np.ones(n, dtype=bool)
    for zs in zs_seq:
        want = [f.update(z) if m else None
                for f, z, m in zip(scalars, zs, mask)]
        got = bank.update(np.asarray(zs, dtype=float), mask)
        for i in range(n):
            if mask[i]:
                assert got[i] == want[i], f"lane {i} return diverged"
            assert bank.R[i] == scalars[i].R, f"lane {i} R diverged"
            assert bank.P[i] == scalars[i].P, f"lane {i} P diverged"


def test_batched_kalman_matches_scalar_seeded():
    rng = random.Random(0xBEEF)
    for trial in range(20):
        n = rng.randrange(1, 9)
        scalars = [_random_predictor(rng) for _ in range(n)]
        bank = BatchedKalman(n)
        for i, f in enumerate(scalars):
            bank.bind(i, dataclasses.replace(f))
        zs_seq = [[rng.uniform(-10.0, 100.0) for _ in range(n)]
                  for _ in range(rng.randrange(1, 12))]
        _assert_bank_matches(scalars, bank, zs_seq)


def test_batched_kalman_degenerate_covariance_coasts():
    """Q = D = 0 with collapsed P: the scalar filter must coast (not
    ZeroDivisionError), and the bank lane must match it bitwise while a
    healthy neighbor lane keeps filtering."""
    deg = KalmanPredictor(Q=0.0, D=0.0, P=0.0, R=3.0)
    ok = KalmanPredictor(R=1.0)
    bank = BatchedKalman(2)
    bank.bind(0, dataclasses.replace(deg))
    bank.bind(1, dataclasses.replace(ok))
    for z in (5.0, 7.0, 2.0):
        want0 = deg.update(z)          # would raise before the guard
        want1 = ok.update(z)
        got = bank.update(np.array([z, z]), np.array([True, True]))
        assert (got[0], got[1]) == (want0, want1)
        assert deg.R == 3.0 * deg.A ** 0  # coasting: A=1 keeps R at 3.0
    assert bank.R[0] == deg.R and bank.P[0] == deg.P


def test_batched_kalman_mask_freezes_lanes():
    """Unmasked lanes must keep their state across updates."""
    a, b = KalmanPredictor(R=2.0), KalmanPredictor(R=4.0)
    bank = BatchedKalman(2)
    bank.bind(0, dataclasses.replace(a))
    bank.bind(1, dataclasses.replace(b))
    a.update(9.0)
    bank.update(np.array([9.0, 9.0]), np.array([True, False]))
    assert bank.R[0] == a.R and bank.P[0] == a.P
    assert bank.R[1] == b.R and bank.P[1] == b.P   # untouched


def test_batched_kalman_sync_back():
    pred = KalmanPredictor()
    bank = BatchedKalman(1)
    bank.bind(0, pred)
    bank.update(np.array([12.0]), np.array([True]))
    assert pred.R == 0.0               # scalar ref not yet synced
    bank.sync_back()
    ref = KalmanPredictor()
    ref.update(12.0)
    assert pred.R == ref.R and pred.P == ref.P


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**32 - 1))
    @settings(max_examples=25, deadline=None)
    def test_batched_kalman_matches_scalar_hypothesis(seed):
        rng = random.Random(seed)
        n = rng.randrange(1, 6)
        scalars = [_random_predictor(rng) for _ in range(n)]
        bank = BatchedKalman(n)
        for i, f in enumerate(scalars):
            bank.bind(i, dataclasses.replace(f))
        mask = np.array([rng.random() < 0.8 for _ in range(n)])
        zs_seq = [[rng.uniform(-10.0, 100.0) for _ in range(n)]
                  for _ in range(6)]
        _assert_bank_matches(scalars, bank, zs_seq, mask)


# ---- window_counts: the vectorized observed-rate pass ----------------------

def test_window_counts_matches_observed_in_window():
    """The one-searchsorted-pass arrival counter over the merged arrays
    must agree with the per-function window count at every sweep time,
    including ticks earlier than OBS_WINDOW_S."""
    rng = np.random.default_rng(42)
    n_fns = 7
    per_fn = [np.sort(rng.uniform(0.0, 30.0, size=rng.integers(0, 200)))
              for _ in range(n_fns)]
    m_t = np.concatenate(per_fn)
    m_slot = np.concatenate([np.full(len(a), i, dtype=np.int64)
                             for i, a in enumerate(per_fn)])
    order = np.argsort(m_t, kind="stable")
    m_t, m_slot = m_t[order], m_slot[order]
    for t in [0.5, 1.0, 2.5, 4.999, 5.0, 7.3, 15.0, 29.9, 31.0]:
        got = window_counts(m_t, m_slot, t, n_fns)
        for i, arr in enumerate(per_fn):
            lo = np.searchsorted(arr, t - OBS_WINDOW_S, side="left")
            hi = np.searchsorted(arr, t, side="right")
            assert got[i] == hi - lo, (t, i)


# ---- the observed-rate normalization fix (both engines) --------------------

def _observed_series(engine_cls=None):
    """One small run whose first sweeps land inside the warm-up window
    (t < OBS_WINDOW_S), returning the (t, observed) timeline rows."""
    sc = get_scenario("steady_poisson").with_(max_gpus=4)
    out = sc.run(policy="has", seed=5, duration_s=8.0, base_rps=40.0,
                 engine_cls=engine_cls)
    eng = out.simulator.engine
    st = eng.fn_list[0] if hasattr(eng, "fn_list") else next(iter(eng.fns.values()))
    return [(row[0], row[1]) for row in st.timeline]


def test_early_tick_observed_rate_uses_elapsed_window():
    """Regression pin for the warm-up normalization fix: at sweep time
    0 < t < OBS_WINDOW_S both the arrival count and the backlog divide
    by the ELAPSED window (min(t, OBS_WINDOW_S)), not the full window —
    the old code under-reported pressure by up to 5x on the first
    sweeps after launch. At t=0 the observed rate stays backlog-only
    divided by the full window (nothing has elapsed), and from
    t >= OBS_WINDOW_S onward the formula is unchanged."""
    rows = _observed_series()
    early = [(t, o) for t, o in rows if 0.0 < t < OBS_WINDOW_S]
    assert early, "no sweep landed inside the warm-up window"
    sim_rows = dict(rows)
    # recompute from the trace: at 40 rps a 1s-elapsed window holds ~40
    # arrivals; under the old /OBS_WINDOW_S normalization the observed
    # value would sit near count/5 instead of count/t
    st = None
    out = get_scenario("steady_poisson").with_(max_gpus=4).run(
        policy="has", seed=5, duration_s=8.0, base_rps=40.0)
    st = out.simulator.engine.fn_list[0]
    for t, obs in early:
        count = st.observed_in_window(t)
        assert count > 0
        # observed = count/min(t,W) + backlog/min(t,W) >= count/t
        assert obs >= count / t - 1e-9, (
            f"t={t}: observed {obs} < count/elapsed {count / t} — "
            f"warm-up window normalization regressed")
    # and the scalar reference engine applies the identical formula
    from repro.core.engine_scalar import ScalarEventEngine
    assert _observed_series(ScalarEventEngine) == rows


# ---- the batched fast path engages (and changes nothing) -------------------

def test_fast_path_engages_and_matches_legacy_loop():
    sim = build_wide(width=60, duration_s=10.0, seed=11)
    sim.engine.run()
    assert sim.engine.fast_ticks > 0, "batched fast path never engaged"
    assert sim.engine.n_sweeps > 0 and sim.engine.sweep_seconds > 0.0

    nob = build_wide(width=60, duration_s=10.0, seed=11)
    nob.engine.cfg = dataclasses.replace(nob.engine.cfg,
                                         batched_policy=False)
    nob.engine.run()
    assert nob.engine.fast_ticks == 0
    assert nob.engine.n_events == sim.engine.n_events
    from tests.test_wide_engine import _traces
    assert _traces(sim) == _traces(nob)


def test_sterile_down_memo_suppresses_repeat_scale_calls():
    """A fleet pinned at its scale-down floor re-candidates every sweep
    (scale() sheds nothing, so the cooldown clock never refreshes); the
    sterility memo must absorb those ticks into the fast path."""
    sim = build_wide(width=40, duration_s=12.0, seed=23, rps=0.5)
    sim.engine.run()
    dec = sim.engine._decider
    assert dec is not None
    # at trickle load most eligible ticks must resolve on the fast path
    total_ticks = sim.engine.n_sweeps * 40
    assert sim.engine.fast_ticks > 0.5 * total_ticks, (
        f"only {sim.engine.fast_ticks}/{total_ticks} ticks took the "
        f"fast path — the sterile-down memo is not engaging")
    assert np.isfinite(dec.sterile_delta).any(), (
        "no slot ever memoized an action-free scale-down proof")


# ---- reclaim bookkeeping stays bounded -------------------------------------

def test_reclaim_scheduled_pruned_to_live_chips():
    """``_reclaim_scheduled`` must not accumulate dead chip uuids: the
    drop listener prunes entries when a chip leaves the cluster, so the
    set stays a subset of the LIVE spot fleet."""
    sc = get_scenario("spot_reclaim_storm")
    out = sc.run(policy="has", seed=9, duration_s=30.0)
    eng = out.simulator.engine
    live = set(eng.recon.gpus)
    assert eng._reclaim_scheduled <= live, (
        f"{len(eng._reclaim_scheduled - live)} dead chip uuids retained")
    # the run must actually have reclaimed something for this to bite
    assert eng.recon.reclaim_log, "scenario produced no reclaims"
