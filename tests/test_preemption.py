"""Spot-preemption suite: the GPUMarket hazard process, the
RECLAIM_NOTICE/RECLAIM_KILL engine path (grace-window draining,
in-flight requeue, weight demotion, scheduler-state release), the
hybrid cost/SLO router's decisions, and the golden-pinned acceptance
claim (hybrid cheaper than all-on-demand AND fewer violations than
all-spot on the identical trace).

See docs/architecture.md "The life of a spot reclaim".
"""
import math
import pathlib

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.gpus import GPUMarket, get_gpu_type, spot
from repro.core import (ClusterSimulator, FnSpec, HybridAutoScaler,
                        Reconfigurator, SimConfig)
from repro.core.metrics import RunMetrics
from repro.core.scheduler import HASGPUScheduler
from repro.core.vgpu import PodAlloc
from repro.workloads.scenarios import get_scenario

SPEC = FnSpec(ARCHS["olmo-1b"])
GOLDEN_DIR = pathlib.Path(__file__).parent / "goldens"

CALM = GPUMarket(price_multiplier=0.3, reclaim_rate_per_hour=6.0,
                 grace_period_s=5.0)
STORMY = GPUMarket(price_multiplier=0.3, reclaim_rate_per_hour=1.0,
                   grace_period_s=5.0, storm_multiplier=100.0,
                   storm_period_s=60.0, storm_duration_s=10.0,
                   storm_start_s=20.0)
V5E_SPOT = spot("v5e", CALM)


# ---------------------------------------------------------------------------
# GPUMarket: descriptor validation and the hazard process
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(price_multiplier=0.0), dict(price_multiplier=1.5),
    dict(reclaim_rate_per_hour=-1.0), dict(grace_period_s=-1.0),
    dict(storm_multiplier=0.5),
    dict(storm_period_s=5.0, storm_duration_s=5.0),
])
def test_market_rejects_invalid_fields(bad):
    with pytest.raises(ValueError):
        GPUMarket(**bad)


def test_rate_at_piecewise_constant_storm_windows():
    base = STORMY.reclaim_rate_per_hour / 3600.0
    assert STORMY.rate_at(0.0) == pytest.approx(base)      # before start
    assert STORMY.rate_at(19.9) == pytest.approx(base)
    assert STORMY.rate_at(20.0) == pytest.approx(base * 100)   # in storm
    assert STORMY.rate_at(29.9) == pytest.approx(base * 100)
    assert STORMY.rate_at(30.1) == pytest.approx(base)     # between
    assert STORMY.rate_at(80.5) == pytest.approx(base * 100)   # next period
    assert not CALM.has_storms
    assert CALM.rate_at(1e6) == pytest.approx(6.0 / 3600.0)


def test_sample_reclaim_deterministic_monotone_and_inf_for_safe_market():
    rng = np.random.default_rng(7)
    a = STORMY.sample_reclaim(3.0, np.random.default_rng(7))
    b = STORMY.sample_reclaim(3.0, np.random.default_rng(7))
    assert a == b                      # same stream -> same draw
    assert a > 3.0                     # strictly after observation start
    draws = [CALM.sample_reclaim(0.0, rng) for _ in range(50)]
    assert all(d > 0 and math.isfinite(d) for d in draws)
    never = GPUMarket(price_multiplier=0.5, reclaim_rate_per_hour=0.0)
    assert never.sample_reclaim(0.0, rng) == math.inf


def test_storms_concentrate_reclaims():
    """With a 100x storm hazard most draws must land inside the storm
    windows — correlated reclaims, not a thinned-out Poisson."""
    rng = np.random.default_rng(42)
    def in_storm(t):
        if t < STORMY.storm_start_s:
            return False
        return ((t - STORMY.storm_start_s) % STORMY.storm_period_s
                < STORMY.storm_duration_s)
    draws = [STORMY.sample_reclaim(0.0, rng) for _ in range(400)]
    frac = sum(in_storm(d) for d in draws) / len(draws)
    assert frac > 0.8, frac


def test_spot_variant_derivation():
    base = get_gpu_type("v5e")
    assert V5E_SPOT.name == "v5e-spot"
    assert V5E_SPOT.market is CALM
    assert V5E_SPOT.price_per_hour == pytest.approx(
        base.price_per_hour * 0.3)
    assert V5E_SPOT.sm_total == base.sm_total    # same silicon
    assert base.market is None                   # base untouched
    with pytest.raises(KeyError):
        get_gpu_type("v5e-spot")                 # NOT in the registry
    assert get_gpu_type(V5E_SPOT) is V5E_SPOT    # instances pass through


# ---------------------------------------------------------------------------
# Reconfigurator: doomed chips and forced removal
# ---------------------------------------------------------------------------

def _spot_cluster(n_pods=2):
    recon = Reconfigurator(num_gpus=0, fleet=((V5E_SPOT, 8),))
    pods = []
    for _ in range(n_pods):
        p = PodAlloc(fn_id=SPEC.fn_id, sm=8, quota=1.0, batch=8)
        recon.place_pod(p, None, now=0.0, cold_start_s=0.0, spec=SPEC)
        pods.append(p)
    return recon, pods


def test_mark_doomed_flags_pods_and_logs_pressure():
    recon, pods = _spot_cluster()
    g = recon.gpu_of_pod(pods[0].pod_id)
    recon.mark_doomed(g.uuid, kill_at=15.0, now=10.0)
    assert g.doomed and g.reclaim_at == 15.0
    assert all(p.doomed for p in g.pods)
    assert recon.reclaim_log == [10.0]       # the router's pressure signal
    # a doomed chip is never a scale-up target
    assert recon.lowest_hgo_gpu() is None or \
        recon.lowest_hgo_gpu().uuid != g.uuid


def test_remove_gpu_demotes_weights_and_releases_scheduler_state():
    """RECLAIM_KILL removes pods through the indexed path: weights
    demote to the node's host cache and the vGPU remove listeners fire
    (token-ledger + client release)."""
    from repro.core import LifecycleConfig, ModelStateTracker
    from repro.core.modelstate import WeightState

    recon, pods = _spot_cluster(n_pods=1)
    tracker = ModelStateTracker(LifecycleConfig(derive_from_physics=True,
                                                host_cache_gb=16.0))
    recon.attach_modelstate(tracker)
    p = PodAlloc(fn_id=SPEC.fn_id, sm=8, quota=1.0, batch=8)
    recon.place_pod(p, None, now=1.0, cold_start_s=2.5, spec=SPEC)
    g = recon.gpu_of_pod(p.pod_id)
    sched = HASGPUScheduler()
    sched.client_for(g, p.pod_id).ledger.acquire(p.pod_id, 1e-3, 2.0)

    recon.remove_gpu(g.uuid, now=50.0)
    assert g.uuid not in recon.gpus
    assert recon.gpu_of_pod(p.pod_id) is None
    assert tracker.state(g.node, SPEC.fn_id, 51.0) is WeightState.HOST
    ledger = sched.ledgers[g.uuid]
    assert not ledger._window_start and not sched.clients


# ---------------------------------------------------------------------------
# Engine: the notice -> drain -> kill path
# ---------------------------------------------------------------------------

class _StaticPolicy:
    """No-op policy: isolates the reclaim mechanics from control
    feedback (no replacement capacity is ever placed)."""

    def tick(self, now, spec, observed_rps):
        return []


# hot market: mean time-to-reclaim ~2 s, so a 12 s run reclaims every
# chip deterministically (fixed engine seed) with work in flight
HOT = GPUMarket(price_multiplier=0.5, reclaim_rate_per_hour=1800.0,
                grace_period_s=0.02)
HOT_SPOT = spot("v5e", HOT)


def _reclaim_sim(requeue: bool):
    recon = Reconfigurator(num_gpus=0, fleet=((HOT_SPOT, 2),))
    for _ in range(2):
        recon.place_pod(PodAlloc(fn_id=SPEC.fn_id, sm=8, quota=1.0,
                                 batch=8),
                        None, now=0.0, cold_start_s=0.0, spec=SPEC)
    arr = np.arange(0.0, 10.0, 0.01)   # 1000 arrivals, 100 rps
    return ClusterSimulator(
        SPEC, _StaticPolicy(), recon, arr,
        SimConfig(duration_s=12.0, seed=3, drop_after_s=5.0,
                  reclaim_requeue=requeue))


def test_kill_requeues_in_flight_and_conserves_requests():
    sim = _reclaim_sim(requeue=True)
    res = sim.run()
    pre = sim.engine.preempt
    assert pre["reclaims"] == 2              # both chips reclaimed
    assert pre["requeued_requests"] > 0
    assert pre["dropped_in_flight"] == 0
    assert res.n_completed + res.n_dropped == res.n_arrived == 1000
    # requeued requests keep their ORIGINAL arrival stamps: with the
    # whole fleet gone their wait ages them past drop_after_s, so the
    # engine's conservation accounting must absorb them as drops
    assert res.n_dropped > 0


def test_kill_drop_mode_counts_dropped_in_flight():
    sim = _reclaim_sim(requeue=False)
    res = sim.run()
    pre = sim.engine.preempt
    assert pre["reclaims"] == 2
    assert pre["requeued_requests"] == 0
    assert pre["dropped_in_flight"] > 0
    assert res.n_completed + res.n_dropped == res.n_arrived == 1000


def test_notice_counts_batches_that_drain_inside_grace():
    """A batch finishing before the kill is a drain, not a kill: it is
    delivered, its requests complete."""
    sim = _reclaim_sim(requeue=True)
    sim.run()
    pre = sim.engine.preempt
    assert pre["drained_batches"] + pre["killed_batches"] > 0
    # drains and kills partition the in-flight batches of the 2 chips:
    # every reclaim either drained or killed at most one running batch
    assert pre["drained_batches"] <= pre["reclaims"]
    assert pre["killed_batches"] <= pre["reclaims"]


def test_market_free_fleet_is_reclaim_inert():
    """No market -> the reclaim machinery must not even engage: no rng
    draws, zero counters, ``preemptions`` omitted from the record."""
    recon = Reconfigurator(num_gpus=0, max_gpus=4)
    recon.place_pod(PodAlloc(fn_id=SPEC.fn_id, sm=8, quota=1.0, batch=8),
                    None, now=0.0, cold_start_s=0.0, spec=SPEC)
    arr = np.arange(0.0, 5.0, 0.5)
    sim = ClusterSimulator(SPEC, _StaticPolicy(), recon, arr,
                           SimConfig(duration_s=8.0, seed=3))
    sim.run()
    assert not sim.engine._has_spot
    assert not sim.engine._reclaim_scheduled
    assert all(v == 0 for v in sim.engine.preempt.values())
    m = RunMetrics.from_sim(sim, "t", "has", 3)
    assert m.preemptions is None
    assert "preemptions" not in m.to_dict()


def test_reclaim_path_is_deterministic():
    a = get_scenario("spot_reclaim_storm").run(seed=11, duration_s=30.0)
    b = get_scenario("spot_reclaim_storm").run(seed=11, duration_s=30.0)
    assert a.metrics.to_json() == b.metrics.to_json()
    assert (a.metrics.preemptions or {}).get("reclaims", 0) > 0


# ---------------------------------------------------------------------------
# Hybrid router: floor, pressure breaker, routing, migration
# ---------------------------------------------------------------------------

def _router(fleet):
    recon = Reconfigurator(num_gpus=0, fleet=fleet)
    return recon, HybridAutoScaler(recon)


def test_router_only_arms_on_spot_fleets():
    _, od_only = _router((("v5e", 8),))
    assert not od_only._spot_fleet
    _, hybrid = _router((("v5e", 4), (V5E_SPOT, 8)))
    assert hybrid._spot_fleet


def test_reclaim_pressure_reads_trailing_window():
    recon, scaler = _router((("v5e", 4), (V5E_SPOT, 8)))
    w = scaler.cfg.reclaim_pressure_window_s
    recon.reclaim_log.extend([1.0, 2.0, 100.0, 101.0, 102.0])
    assert scaler._reclaim_pressure(102.0) == 3    # the two old ones aged
    assert scaler._reclaim_pressure(102.0 + w + 1) == 0


def test_spot_allowed_requires_floor_and_calm_market():
    recon, scaler = _router((("v5e", 4), (V5E_SPOT, 8)))
    # empty cluster: zero on-demand capacity, so the floor is not held
    assert not scaler._spot_allowed(0.0, SPEC, R=100.0)
    # hold the floor with an on-demand pod, calm market -> allowed
    scaler.scale(0.0, SPEC, 50.0)       # bootstraps on-demand first
    assert scaler._od_capacity(SPEC, recon.pods_of(SPEC.fn_id)) > 0
    assert scaler._spot_allowed(1.0, SPEC, R=50.0)
    # a storm of notices trips the breaker
    recon.reclaim_log.extend([10.0] * (scaler.cfg.reclaim_pressure_max + 1))
    assert not scaler._spot_allowed(10.0, SPEC, R=50.0)


def test_route_types_never_empties():
    _, scaler = _router((("v5e", 4), (V5E_SPOT, 8)))
    od = get_gpu_type("v5e")
    both = [od, V5E_SPOT]
    assert scaler._route_types(both, spot_ok=True) == both
    assert scaler._route_types(both, spot_ok=False) == [od]
    # an all-spot fleet must still serve even when spot is "forbidden"
    assert scaler._route_types([V5E_SPOT], spot_ok=False) == [V5E_SPOT]


def test_scale_down_sheds_on_demand_first_but_keeps_the_floor():
    """On-demand pods are the expensive ones: shed them first on the
    way down — but never below the od floor, so a demand trough cannot
    leave a spot-only rump for the next storm to wipe out."""
    recon, scaler = _router((("v5e", 8), (V5E_SPOT, 16)))
    for i in range(200):
        scaler.scale(float(i), SPEC, 400.0)
    # collapse demand; drive well past cooldown
    for i in range(200, 400):
        scaler.scale(float(i), SPEC, 5.0)
    pods = [p for p in recon.pods_of(SPEC.fn_id) if not p.standby]
    od = [p for p in pods if p.gpu_type.market is None]
    assert pods, "scale-to-zero"
    assert od, "trough shed the entire on-demand floor"


def test_migration_is_make_before_break():
    """After a storm forced overflow onto on-demand, the return path
    od->spot places the spot replacement FIRST and retires the
    on-demand pod only once the replacement is ready."""
    recon, scaler = _router((("v5e", 8), (V5E_SPOT, 16)))
    takeover_t = handover_t = None
    for i in range(1, 200):
        now = float(i)
        if i <= 10:
            # a notice per tick: the breaker routes all growth on-demand
            recon.reclaim_log.append(now)
        for a in scaler.scale(now, SPEC, 250.0):
            if "spot takeover" in a.detail and takeover_t is None:
                takeover_t = now
                pend = scaler._migrations[SPEC.fn_id]
                by_id = {p.pod_id: p for p in recon.pods_of(SPEC.fn_id)}
                assert pend[0] in by_id and pend[1] in by_id  # both alive
                assert by_id[pend[1]].ready_at > now   # replacement cold
            if "migrated to spot" in a.detail and handover_t is None:
                handover_t = now
        if i == 10:
            # the storm really did pin growth to reliable capacity
            assert sum(1 for p in recon.pods_of(SPEC.fn_id)
                       if p.gpu_type.market is None) > 1
        if handover_t is not None:
            break
    assert takeover_t is not None, "router never migrated back to spot"
    assert takeover_t > 10.0           # not while the breaker was tripped
    assert handover_t is not None and handover_t > takeover_t
    assert SPEC.fn_id not in scaler._migrations


# ---------------------------------------------------------------------------
# The golden-pinned acceptance claim
# ---------------------------------------------------------------------------

def _load(name):
    path = GOLDEN_DIR / f"{name}__has.json"
    if not path.exists():
        pytest.skip("spot golden corpus not generated yet")
    return RunMetrics.load(path)


def test_goldens_pin_hybrid_beats_both_controls():
    """THE acceptance pin of the hybrid router: on the identical
    diurnal trace with correlated evening reclaims, the hybrid fleet is
    cheaper than the all-on-demand control AND violates SLO less than
    the all-spot control."""
    hybrid = _load("diurnal_spot_reclaims")
    ondemand = _load("diurnal_spot_ondemand")
    allspot = _load("diurnal_spot_allspot")
    assert hybrid.cost_usd < ondemand.cost_usd
    assert (hybrid.slo_violation_rate["2.0"]
            < allspot.slo_violation_rate["2.0"])
    # the controls are what they claim to be
    assert ondemand.preemptions["reclaims"] == 0
    assert allspot.preemptions["reclaims"] > 0
    assert hybrid.preemptions["reclaims"] > 0


def test_storm_golden_pins_drain_and_replace_counters():
    m = _load("spot_reclaim_storm")
    pre = m.preemptions
    assert pre["reclaims"] >= 3           # a violent market, exercised
    assert pre["dropped_in_flight"] == 0  # requeue mode is the default
    assert m.n_completed > 0
    assert set(pre) == {"reclaims", "drained_batches", "killed_batches",
                        "requeued_requests", "dropped_in_flight"}


def test_legacy_goldens_omit_preemptions():
    m = _load("steady_poisson")
    assert m.preemptions is None
