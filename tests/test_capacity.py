"""Lattice/scalar parity: the vectorized control plane must be a pure
re-plumbing of the scalar reference implementations.

Oracle-path equality is pinned BITWISE (the golden traces ride on
`lat > cap`-style comparisons, so even one ulp of drift changes scaling
decisions); the RaPP vmap lattice is pinned to per-call `forward_one`
at 1e-5."""
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import perf_model
from repro.core.capacity import CapacityTable, shared_table
from repro.core.perf_model import FnSpec
from repro.core.vgpu import TOTAL_SLICES

SPECS = [FnSpec(cfg) for cfg in ARCHS.values()]
BATCHES = (1, 2, 4, 8, 16, 32)


def test_quota_grid_matches_loop_arithmetic():
    for step in (0.1, 0.2, 0.25, 0.5, 1.0):
        grid = perf_model.quota_grid(step)
        loop = [qi * step for qi in range(1, int(round(1.0 / step)) + 1)]
        assert grid.tolist() == loop


def test_latency_lattice_bitwise_equals_scalar():
    sms = np.arange(1, TOTAL_SLICES + 1)
    quotas = perf_model.quota_grid(0.1)
    for spec in SPECS[:4]:
        for b in BATCHES:
            tab = perf_model.latency_lattice(spec, b, sms, quotas)
            for i, sm in enumerate(sms):
                for j, q in enumerate(quotas):
                    assert tab[i, j] == perf_model.latency(
                        spec, b, int(sm), float(q)), (spec.fn_id, b, sm, q)


def test_throughput_and_cost_lattice_bitwise():
    sms = np.arange(1, TOTAL_SLICES + 1)
    quotas = perf_model.quota_grid(0.1)
    spec = SPECS[0]
    thpt = perf_model.throughput_lattice(spec, 8, sms, quotas,
                                         overhead_s=0.02)
    cost = perf_model.cost_rate_lattice(sms, quotas)
    for i, sm in enumerate(sms):
        for j, q in enumerate(quotas):
            assert thpt[i, j] == perf_model.throughput(
                spec, 8, int(sm), float(q), overhead_s=0.02)
            assert cost[i, j] == perf_model.cost_rate(int(sm), float(q))


def test_table_most_efficient_config_identical_all_specs():
    """Satellite: the table-backed argmin returns the identical
    (b, sm, q) tuple as the reference triple loop, every registered
    spec, a spread of targets, both SLO modes."""
    table = shared_table()
    for spec in SPECS:
        for target in (0.1, 2.0, 25.0, 200.0, 5000.0):
            for mult in (1.5, 2.0, None):
                ref = perf_model.most_efficient_config(
                    spec, target, slo_multiplier=mult)
                got = table.most_efficient_config(
                    spec, target, slo_multiplier=mult)
                assert got == ref, (spec.fn_id, target, mult, got, ref)


def test_table_min_quota_for_slo_identical():
    table = shared_table()
    for spec in SPECS:
        for b in (1, 8, 32):
            for sm in range(1, TOTAL_SLICES + 1):
                ref = perf_model.min_quota_for_slo(spec, b, sm, 2.0)
                got = table.min_quota_for_slo(spec, b, sm, 2.0)
                assert got == ref, (spec.fn_id, b, sm, got, ref)


def test_table_lat_on_and_off_lattice():
    table = CapacityTable()
    spec = SPECS[0]
    # on-grid values come from the lattice and equal the scalar oracle
    for qi in range(1, 11):
        q = qi * 0.1
        assert table.lat(spec, 8, 4, q) == perf_model.latency(spec, 8, 4, q)
    # off-grid falls back to the exact scalar path: the literal 0.6
    # (0.59999999999999998) is NOT the grid point 6*0.1
    # (0.60000000000000009)
    q_off = 0.6
    assert q_off != 6 * 0.1
    assert table.lat(spec, 8, 4, q_off) == perf_model.latency(
        spec, 8, 4, q_off)


def test_table_wraps_arbitrary_scalar_predictor():
    calls = []

    def pred(spec, b, sm, q):
        calls.append((b, sm, q))
        return perf_model.latency(spec, b, sm, q) * 1.5

    table = CapacityTable(predictor=pred)
    spec = SPECS[0]
    v = table.lat(spec, 8, 4, 0.5)
    assert v == perf_model.latency(spec, 8, 4, 0.5) * 1.5
    n = len(calls)
    assert n == 80  # one full (sm x quota) lattice fill
    table.lat(spec, 8, 7, 0.2)  # same (spec, batch): no new calls
    assert len(calls) == n


# ---- RaPP lattice parity (needs jax) ----------------------------------------
def _rapp_model():
    jax = pytest.importorskip("jax")
    from repro.core.rapp import predictor as P
    params = P.init_params(jax.random.PRNGKey(0))
    return P.RaPPModel(params, seed=7)


def test_rapp_lattice_matches_scalar_calls():
    """Satellite: one `forward_batch` vmap over the stacked lattice
    agrees with per-call `forward_one` to 1e-5."""
    model = _rapp_model()
    spec = FnSpec(ARCHS["olmo-1b"])
    sms = (1, 4, 8)
    quotas = (0.2, 0.5, 1.0)
    lattice = model.predict_lattice(spec, 4, sms, quotas)
    fresh = _rapp_model()  # scalar-only path, no lattice cache
    for i, sm in enumerate(sms):
        for j, q in enumerate(quotas):
            scalar = fresh(spec, 4, sm, q)
            assert lattice[i, j] == pytest.approx(scalar, rel=1e-5), \
                (sm, q, lattice[i, j], scalar)


def test_rapp_predictions_order_independent():
    """Satellite: noise is keyed by (arch, batch, sm, quota), so the
    same query yields the same latency regardless of what was asked
    before it."""
    spec = FnSpec(ARCHS["olmo-1b"])
    queries = [(4, 2, 0.3), (4, 8, 1.0), (4, 1, 0.1), (4, 4, 0.6)]
    a, b = _rapp_model(), _rapp_model()
    got_a = {q: a(spec, *q) for q in queries}
    got_b = {q: b(spec, *q) for q in reversed(queries)}
    assert got_a == got_b


def test_rapp_table_single_batched_fill():
    """CapacityTable + RaPPModel: the whole lattice is served from one
    predict_lattice call and most_efficient_config works end to end."""
    model = _rapp_model()
    spec = FnSpec(ARCHS["olmo-1b"])
    table = CapacityTable(predictor=model)
    b, sm, q = table.most_efficient_config(spec, 5.0, batches=(4,))
    assert b == 4 and 1 <= sm <= TOTAL_SLICES and 0.0 < q <= 1.0
    # lookups agree with the model's own (cache-consistent) answers
    assert table.lat(spec, 4, sm, q) == pytest.approx(
        model(spec, 4, sm, q), rel=1e-5)
