"""Tests for the measured-profile calibration loop: grid enumeration,
the analytic twins, the CI gate, the calibration table lookup, and its
consumers (``CapacityTable(calibration=...)``, the RaPP dataset)."""
import copy

import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.gpus import get_gpu_type
from repro.core import perf_model
from repro.core.capacity import CapacityTable
from repro.core.perf_model import FnSpec
from repro.core.rapp import dataset as rapp_dataset
from repro.profiling import (SCHEMA, CalibrationTable, GridSpec,
                             build_grid, check_report, error_summary,
                             run_profile, windowed_wall)
from repro.profiling.harness import prompt_len


def _pt(arch, gpu, batch, sm, quota, phase, measured, analytic=1e-3):
    return {"arch": arch, "gpu": gpu, "batch": batch, "sm": sm,
            "quota": quota, "phase": phase, "measured_s": measured,
            "analytic_s": analytic,
            "rel_err": abs(measured - analytic) / max(analytic, 1e-12)}


def _report(points, seq=32, reduced_flag=True, grid=None, smoke=True):
    grid = grid or GridSpec()
    return {"schema": SCHEMA, "smoke": smoke,
            "meta": {"backend": "cpu", "device_kind": "cpu",
                     "jax_version": "0", "reduced": reduced_flag,
                     "seq": seq, "window_ms": 20.0, "warmup": 1,
                     "iters": 3, "grid": grid.grid_meta()},
            "points": points, "error": error_summary(points)}


# ---------------------------------------------------------------------------
# grid + analytic twins
# ---------------------------------------------------------------------------

def test_build_grid_deterministic_order_and_device_width():
    spec = GridSpec(archs=("olmo-1b",), gpu_types=("t4",), batches=(2, 1),
                    sms=(2, 8), quotas=(1.0,))
    pts = build_grid(spec)
    assert pts == build_grid(spec)             # deterministic
    # sm=8 exceeds the t4's 4 slices and is skipped; tuple order is
    # preserved literally (batches stay (2, 1))
    assert [(p.batch, p.sm, p.phase) for p in pts] == [
        (2, 2, "prefill"), (2, 2, "decode"),
        (1, 2, "prefill"), (1, 2, "decode")]
    assert all(p.gpu == "t4" and p.quota == 1.0 for p in pts)


def test_build_grid_rejects_unknown_arch():
    with pytest.raises(KeyError):
        build_grid(GridSpec(archs=("no-such-arch",)))


def test_windowed_wall_matches_latency_quantization():
    spec = FnSpec(ARCHS["olmo-1b"])
    for batch, sm, q in ((2, 4, 0.3), (1, 8, 0.7), (4, 2, 1.0)):
        t = perf_model.exec_time(spec, batch, sm)
        assert windowed_wall(t, q, 0.1) == perf_model.latency(
            spec, batch, sm, q)
    assert windowed_wall(0.42, 1.0, 0.1) == 0.42   # full quota: no stall


def test_error_summary_percentiles():
    pts = [_pt("a", "v5e", 1, 2, 1.0, "prefill", m, analytic=1.0)
           for m in (1.0, 2.0, 3.0, 4.0)]          # rel errs 0,1,2,3
    s = error_summary(pts)
    assert s["overall"]["p50"] == pytest.approx(1.5)
    assert s["overall"]["n"] == 4
    assert set(s["per_arch"]) == {"a"}


# ---------------------------------------------------------------------------
# the CI gate
# ---------------------------------------------------------------------------

def _base_reports():
    vals = iter(range(1, 9))
    pts = [_pt("olmo-1b", "v5e", 1, sm, q, phase, next(vals) * 1e-3)
           for sm in (2, 4) for q in (0.5, 1.0)
           for phase in ("prefill", "decode")]
    ref = _report(pts)
    return copy.deepcopy(ref), ref


def test_check_report_identical_passes():
    new, ref = _base_reports()
    assert check_report(new, ref) == []


def test_check_report_uniform_machine_speed_is_cancelled():
    new, ref = _base_reports()
    for p in new["points"]:
        p["measured_s"] *= 7.0                 # a 7x slower machine
    assert check_report(new, ref) == []


@pytest.mark.parametrize("mutate,expect", [
    (lambda r: r.update(schema="other/v0"), "schema mismatch"),
    (lambda r: r["meta"].update(seq=64), "meta.seq mismatch"),
    (lambda r: r["points"].reverse(), "point set/order drifted"),
    (lambda r: r["points"][2].update(analytic_s=9.9), "analytic drift"),
    (lambda r: r["points"][3].update(measured_s=4e3),
     "measured-shape drift"),
])
def test_check_report_failures(mutate, expect):
    new, ref = _base_reports()
    mutate(new)
    failures = check_report(new, ref)
    assert any(expect in f for f in failures), failures


# ---------------------------------------------------------------------------
# CalibrationTable
# ---------------------------------------------------------------------------

def _surface_report():
    """Measured prefill surface: sm in {2,4} x quota in {0.5,1.0}."""
    vals = {(2, 0.5): 0.01, (2, 1.0): 0.02, (4, 0.5): 0.03,
            (4, 1.0): 0.04}
    pts = [_pt("olmo-1b", "v5e", 1, sm, q, "prefill", m)
           for (sm, q), m in sorted(vals.items())]
    # decode points must not leak into the latency surface
    pts.append(_pt("olmo-1b", "v5e", 1, 2, 0.5, "decode", 999.0))
    return _report(pts)


def test_calibration_table_exact_and_interpolated_lookup():
    tab = CalibrationTable(_surface_report())
    assert len(tab) == 1
    assert tab.latency("olmo-1b", 1, 2, 0.5) == pytest.approx(0.01)
    assert tab.latency("olmo-1b", 1, 4, 1.0) == pytest.approx(0.04)
    # bilinear interior points
    assert tab.latency("olmo-1b", 1, 3, 0.5) == pytest.approx(0.02)
    assert tab.latency("olmo-1b", 1, 2, 0.75) == pytest.approx(0.015)
    assert tab.latency("olmo-1b", 1, 3, 0.75) == pytest.approx(0.025)
    # off-hull / unmeasured keys -> None (caller falls back to analytic)
    assert tab.latency("olmo-1b", 1, 1, 0.5) is None
    assert tab.latency("olmo-1b", 1, 5, 0.5) is None
    assert tab.latency("olmo-1b", 1, 2, 0.4) is None
    assert tab.latency("olmo-1b", 2, 2, 0.5) is None
    assert tab.latency("olmo-1b", 1, 2, 0.5,
                       gpu=get_gpu_type("t4")) is None
    assert tab.latency("qwen2.5-3b", 1, 2, 0.5) is None


def test_calibration_table_spec_guard_and_schema():
    tab = CalibrationTable(_surface_report())
    cfg = reduced(ARCHS["olmo-1b"])
    good = FnSpec(cfg, seq=prompt_len(cfg, 32))
    assert tab.latency(good, 1, 2, 0.5) == pytest.approx(0.01)
    # the full (non-reduced) arch shares the name but not the physics
    assert tab.latency(FnSpec(ARCHS["olmo-1b"]), 1, 2, 0.5) is None
    # a different profiled seq is a different measured quantity
    assert tab.latency(FnSpec(cfg, seq=24), 1, 2, 0.5) is None
    with pytest.raises(ValueError):
        CalibrationTable({"schema": "bogus", "points": []})


def test_calibration_table_refuses_ragged_grid():
    pts = [_pt("olmo-1b", "v5e", 1, sm, q, "prefill", 0.01)
           for sm, q in ((2, 0.5), (2, 1.0), (4, 0.5))]  # missing corner
    tab = CalibrationTable(_report(pts))
    assert tab.latency("olmo-1b", 1, 2, 0.5) == pytest.approx(0.01)
    assert tab.latency("olmo-1b", 1, 3, 0.75) is None


# ---------------------------------------------------------------------------
# consumers
# ---------------------------------------------------------------------------

def test_capacity_table_calibration_overlay():
    cfg = reduced(ARCHS["olmo-1b"])
    spec = FnSpec(cfg, seq=prompt_len(cfg, 32))
    cal = CalibrationTable(_surface_report())
    cap = CapacityTable(calibration=cal)
    base = CapacityTable()
    lat_cal = cap.lattice(spec, 1)
    lat_base = base.lattice(spec, 1)
    # measured hits on the lattice (rows sm-1, cols quota/0.1 - 1)
    assert lat_cal[1, 4] == pytest.approx(0.01)   # sm=2, q=0.5
    assert lat_cal[3, 9] == pytest.approx(0.04)   # sm=4, q=1.0
    assert lat_cal[2, 4] == pytest.approx(0.02)   # sm=3: interpolated
    assert lat_cal[1, 6] == pytest.approx(
        0.01 + 0.01 * (0.7 - 0.5) / 0.5)          # q=0.7: interpolated
    # everything off the measured hull keeps the analytic physics
    np.testing.assert_array_equal(lat_cal[0], lat_base[0])   # sm=1 row
    np.testing.assert_array_equal(lat_cal[:, :4], lat_base[:, :4])
    np.testing.assert_array_equal(lat_cal[4:], lat_base[4:])
    # scalar (off-grid quota) path: measured inside the hull, analytic
    # outside it
    assert cap.lat(spec, 1, 3, 0.75) == pytest.approx(0.025)
    assert cap.lat(spec, 1, 6, 0.55) == base.lat(spec, 1, 6, 0.55)
    # default (no calibration) stays bitwise the oracle lattice
    np.testing.assert_array_equal(
        lat_base, perf_model.latency_lattice(spec, 1, base.sms,
                                             base.quotas, base.window_ms))


def test_rapp_dataset_samples_measured_labels():
    cfg = ARCHS["olmo-1b"]
    # profiled at seq=256 (prompt_len 128) on the FULL config: exactly
    # the FnSpec the dataset builder queries
    pts = [_pt("olmo-1b", "v5e", 1, sm, q, "prefill", 0.002)
           for sm in (2, 4) for q in (0.5, 1.0)]
    cal = CalibrationTable(_report(pts, seq=256, reduced_flag=False))
    assert cal.latency(FnSpec(cfg), 1, 2, 0.5) == pytest.approx(0.002)
    ds = rapp_dataset.generate(corpus=[cfg], batches=(1,), sms=(2,),
                               quotas=(0.5,), samples_per_graph=1,
                               calibration=cal)
    assert len(ds) == 1
    assert ds.labels_logms[0] == pytest.approx(np.log1p(0.002 * 1e3))
    # uncovered configs keep the (noisy) oracle label
    ds_miss = rapp_dataset.generate(corpus=[cfg], batches=(1,), sms=(2,),
                                    quotas=(0.4,), samples_per_graph=1,
                                    calibration=cal)
    assert ds_miss.labels_logms[0] != pytest.approx(np.log1p(2.0))


# ---------------------------------------------------------------------------
# one real end-to-end point (reduced config, CPU)
# ---------------------------------------------------------------------------

def test_run_profile_single_point_end_to_end():
    grid = GridSpec(archs=("olmo-1b",), gpu_types=("v5e",), batches=(1,),
                    sms=(4,), quotas=(1.0,), seq=32, warmup=1, iters=1)
    report = run_profile(grid, smoke=True)
    assert report["schema"] == SCHEMA
    assert [p["phase"] for p in report["points"]] == ["prefill", "decode"]
    assert all(p["measured_s"] > 0 for p in report["points"])
    cfg = reduced(ARCHS["olmo-1b"])
    spec = FnSpec(cfg, seq=prompt_len(cfg, 32))
    assert report["points"][0]["analytic_s"] == perf_model.latency(
        spec, 1, 4, 1.0, window_ms=20.0)
    assert report["error"]["overall"]["n"] == 2
    # a fresh report round-trips through its own CI gate and the table
    assert check_report(copy.deepcopy(report), report) == []
    tab = CalibrationTable(report)
    assert tab.latency(spec, 1, 4, 1.0) == pytest.approx(
        report["points"][0]["measured_s"])
