"""Fault-injection + resilience suite (core/faults.py and its engine
integration): config validation, the dedicated rng streams, request
conservation under arbitrary fault schedules, byte-identity of inert
configs, each fault kind's engine path, and the golden-pinned
acceptance claims of the three chaos scenarios.

See docs/architecture.md "The life of a fault".
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core import FaultInjector, FaultModel, HealthTracker, \
    ResilienceConfig
from repro.core.metrics import RunMetrics
from repro.core.modelstate import LifecycleConfig, ModelStateTracker, \
    NodeWeightCache
from repro.core.reconfigurator import Reconfigurator
from repro.workloads.scenarios import get_scenario

GOLDEN_DIR = pathlib.Path(__file__).parent / "goldens"

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as hyp_st
    HAVE_HYPOTHESIS = True
except ImportError:      # container ships without hypothesis: seeded
    HAVE_HYPOTHESIS = False   # fallback below runs the same property


def _load(name):
    path = GOLDEN_DIR / f"{name}__has.json"
    if not path.exists():
        pytest.skip("fault golden corpus not generated yet")
    return RunMetrics.load(path)


# ---------------------------------------------------------------------------
# Config validation and inertness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(chip_failure_rate_per_hour=-1.0),
    dict(straggler_rate_per_hour=-0.1),
    dict(cache_loss_rate_per_hour=-5.0),
    dict(blackout_rate_per_hour=-1.0),
    dict(straggler_factor=0.5),
    dict(straggler_duration_s=0.0),
    dict(blackout_duration_s=-2.0),
])
def test_fault_model_rejects_invalid_fields(bad):
    with pytest.raises(ValueError):
        FaultModel(**bad)


@pytest.mark.parametrize("bad", [
    dict(deadline_s=-1.0),
    dict(retry_backoff_s=-0.5),
    dict(max_retries=-1),
    dict(health_alpha=0.0),
    dict(health_alpha=1.5),
    dict(quarantine_ratio=-1.0),
    dict(quarantine_min_samples=0),
    dict(quarantine_duration_s=0.0),
    dict(admission_headroom=-0.1),
])
def test_resilience_config_rejects_invalid_fields(bad):
    with pytest.raises(ValueError):
        ResilienceConfig(**bad)


def test_default_configs_are_inert():
    assert not FaultModel().is_active
    r = ResilienceConfig()
    assert not r.is_active and not r.quarantine_active \
        and not r.admission_active
    # admission needs BOTH a headroom and a deadline to measure against
    assert not ResilienceConfig(admission_headroom=1.0).admission_active
    assert ResilienceConfig(deadline_s=5.0,
                            admission_headroom=1.0).admission_active


def test_zero_rate_model_is_byte_identical_to_no_faults_golden():
    """A zero-rate FaultModel must leave the engine on the exact legacy
    code paths: the serialized record equals the committed golden."""
    path = GOLDEN_DIR / "steady_poisson__has.json"
    if not path.exists():
        pytest.skip("golden corpus not generated yet")
    scen = get_scenario("steady_poisson").with_(faults=FaultModel())
    m = scen.run(policy="has", seed=42, duration_s=45.0).metrics
    assert json.loads(json.dumps(m.to_dict())) == json.loads(
        path.read_text())


# ---------------------------------------------------------------------------
# The injector's rng streams
# ---------------------------------------------------------------------------

def test_injector_streams_are_seeded_and_decorrelated():
    fm = FaultModel(chip_failure_rate_per_hour=10.0,
                    straggler_rate_per_hour=10.0,
                    cache_loss_rate_per_hour=10.0)
    a = FaultInjector(fm, seed=7, horizon_s=100.0)
    b = FaultInjector(fm, seed=7, horizon_s=100.0)
    # reproducible per seed
    assert a.draw_chip_failure(0.0) == b.draw_chip_failure(0.0)
    assert a.draw_straggler(0.0) == b.draw_straggler(0.0)
    # distinct streams: same rate, same seed, different first draws
    c = FaultInjector(fm, seed=7, horizon_s=100.0)
    draws = {c.draw_chip_failure(0.0), c.draw_straggler(0.0),
             c.draw_cache_loss(0.0)}
    assert len(draws) == 3
    # a different seed moves every stream
    d = FaultInjector(fm, seed=8, horizon_s=100.0)
    assert d.draw_chip_failure(0.0) != b.draw_chip_failure(0.0)


def test_blackout_windows_precomputed_and_ordered():
    fm = FaultModel(blackout_rate_per_hour=600.0, blackout_duration_s=3.0)
    inj = FaultInjector(fm, seed=3, horizon_s=60.0)
    assert inj.blackouts, "600/hr over 60 s should draw windows"
    prev_end = 0.0
    for a, b in inj.blackouts:
        assert b - a == pytest.approx(3.0)
        assert a >= prev_end and a <= 60.0   # ordered, non-overlapping
        prev_end = b
        assert inj.in_blackout((a + b) / 2)
        assert not inj.in_blackout(a - 1e-6)
    assert not inj.in_blackout(prev_end + 1e-6)
    # zero rate: no windows at all
    assert FaultInjector(FaultModel(straggler_rate_per_hour=1.0), 3,
                         60.0).blackouts == []


# ---------------------------------------------------------------------------
# Health scoring
# ---------------------------------------------------------------------------

def test_health_tracker_trips_after_min_samples():
    cfg = ResilienceConfig(quarantine_ratio=1.5, quarantine_min_samples=3,
                           health_alpha=0.5)
    h = HealthTracker(cfg)
    # a 4x straggler: EWMA climbs but min_samples gates the trip
    assert not h.observe("p", 4.0)     # n=1
    assert not h.observe("p", 4.0)     # n=2
    assert h.observe("p", 4.0)         # n=3 and EWMA >> 1.5
    assert h.score("p") > 1.5
    # reset forgets the history (fresh start after a lift)
    h.reset("p")
    assert h.score("p") == 1.0
    assert not h.observe("p", 4.0)


def test_health_tracker_ignores_healthy_noise():
    cfg = ResilienceConfig(quarantine_ratio=1.5, quarantine_min_samples=3)
    h = HealthTracker(cfg)
    rng = np.random.default_rng(0)
    for _ in range(200):
        assert not h.observe("p", float(rng.lognormal(0.0, 0.03)))
    assert h.score("p") == pytest.approx(1.0, abs=0.05)


def test_reconfigurator_set_quarantined_roundtrip():
    from repro.core.vgpu import PodAlloc
    recon = Reconfigurator(num_gpus=1)
    pod = PodAlloc(fn_id="f", sm=2, quota=0.5, batch=2)
    assert recon.place_pod(pod) is not None
    assert not pod.quarantined
    recon.set_quarantined(pod.pod_id, True)
    assert pod.quarantined
    recon.set_quarantined(pod.pod_id, True)    # idempotent
    recon.set_quarantined(pod.pod_id, False)
    assert not pod.quarantined
    recon.set_quarantined("no-such-pod", True)  # unknown pod: no-op


# ---------------------------------------------------------------------------
# Host-cache loss
# ---------------------------------------------------------------------------

def test_node_cache_clear_and_drop_node_cache():
    c = NodeWeightCache(capacity_bytes=8e9)
    c.admit("fn-a", 1e9)
    c.admit("fn-b", 2e9)
    assert c.clear() == 2
    assert not c.contains("fn-a") and c.used_bytes == 0

    tracker = ModelStateTracker(LifecycleConfig(derive_from_physics=True,
                                                host_cache_gb=8.0))
    assert not tracker.is_passive
    tracker._cache("node-1").admit("fn-a", 1e9)
    assert tracker.host_cached("node-1", "fn-a")
    assert tracker.drop_node_cache("node-1", now=1.0) == 1
    assert not tracker.host_cached("node-1", "fn-a")
    # unknown node / passive tracker: harmless zero
    assert tracker.drop_node_cache("nowhere") == 0
    assert ModelStateTracker().drop_node_cache("node-1") == 0


# ---------------------------------------------------------------------------
# Conservation property: arrived == completed + dropped under any faults
# ---------------------------------------------------------------------------

def _conservation_case(chip_rate, strag_rate, cache_rate, black_rate,
                       deadline, retries, q_ratio, headroom, seed):
    fm = FaultModel(chip_failure_rate_per_hour=chip_rate,
                    straggler_rate_per_hour=strag_rate,
                    straggler_factor=6.0, straggler_duration_s=5.0,
                    cache_loss_rate_per_hour=cache_rate,
                    blackout_rate_per_hour=black_rate,
                    blackout_duration_s=3.0)
    res = ResilienceConfig(deadline_s=deadline, max_retries=retries,
                           retry_backoff_s=0.25 if retries else 0.0,
                           quarantine_ratio=q_ratio,
                           quarantine_min_samples=2,
                           quarantine_duration_s=4.0,
                           admission_headroom=headroom)
    scen = get_scenario("steady_poisson").with_(
        base_rps=120.0, max_gpus=4, faults=fm,
        resilience=res if res.is_active else None,
        sim_overrides={"reclaim_requeue": False, "drop_after_s": 8.0})
    out = scen.run(policy="has", seed=seed, duration_s=12.0)
    m = out.metrics
    assert m.n_arrived == m.n_completed + m.n_dropped, (
        f"conservation violated: {m.n_arrived} != "
        f"{m.n_completed} + {m.n_dropped}")
    d = m.to_dict()
    if d.get("drop_breakdown") is not None:
        assert sum(d["drop_breakdown"].values()) == m.n_dropped
    if d.get("availability") is not None:
        assert 0.0 <= d["availability"] <= 1.0


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(chip=hyp_st.sampled_from([0.0, 150.0, 600.0]),
           strag=hyp_st.sampled_from([0.0, 300.0]),
           cache=hyp_st.sampled_from([0.0, 300.0]),
           black=hyp_st.sampled_from([0.0, 240.0]),
           deadline=hyp_st.sampled_from([0.0, 0.5, 6.0]),
           retries=hyp_st.integers(min_value=0, max_value=2),
           q_ratio=hyp_st.sampled_from([0.0, 2.0]),
           headroom=hyp_st.sampled_from([0.0, 0.5]),
           seed=hyp_st.integers(min_value=0, max_value=10_000))
    def test_conservation_under_arbitrary_fault_schedules(
            chip, strag, cache, black, deadline, retries, q_ratio,
            headroom, seed):
        _conservation_case(chip, strag, cache, black, deadline, retries,
                           q_ratio, headroom, seed)
else:
    @pytest.mark.parametrize("case_seed", range(8))
    def test_conservation_under_arbitrary_fault_schedules(case_seed):
        """Seeded fallback for the hypothesis property: random fault/
        resilience mixes must conserve requests exactly."""
        rng = np.random.default_rng(1234 + case_seed)
        _conservation_case(
            chip_rate=float(rng.choice([0.0, 150.0, 600.0])),
            strag_rate=float(rng.choice([0.0, 300.0])),
            cache_rate=float(rng.choice([0.0, 300.0])),
            black_rate=float(rng.choice([0.0, 240.0])),
            deadline=float(rng.choice([0.0, 0.5, 6.0])),
            retries=int(rng.integers(0, 3)),
            q_ratio=float(rng.choice([0.0, 2.0])),
            headroom=float(rng.choice([0.0, 0.5])),
            seed=int(rng.integers(0, 10_000)))


# ---------------------------------------------------------------------------
# Each fault kind's engine path (hot rates, short horizons)
# ---------------------------------------------------------------------------

def _hot_run(fm, res=None, seed=42, duration_s=15.0, **over):
    scen = get_scenario("steady_poisson").with_(
        base_rps=over.pop("base_rps", 150.0),
        max_gpus=over.pop("max_gpus", 4),
        faults=fm, resilience=res,
        sim_overrides=over or None)
    return scen.run(policy="has", seed=seed, duration_s=duration_s)


def test_chip_failures_kill_without_retry_and_requeue_with():
    fm = FaultModel(chip_failure_rate_per_hour=800.0)
    ctrl = _hot_run(fm, reclaim_requeue=False).metrics.to_dict()
    assert ctrl["faults"]["chip_failures"] > 0
    assert ctrl["drop_breakdown"]["killed"] > 0
    assert ctrl["retries"] == 0

    res = ResilienceConfig(deadline_s=10.0, max_retries=3)
    resil = _hot_run(fm, res, reclaim_requeue=False).metrics.to_dict()
    assert resil["faults"]["chip_failures"] > 0
    assert resil["retries"] > 0
    assert resil["drop_breakdown"]["killed"] < ctrl["drop_breakdown"]["killed"]
    assert resil["mttr_s"] is None or resil["mttr_s"] > 0
    assert 0.0 <= resil["availability"] <= 1.0


def test_retry_budget_of_zero_behaves_like_no_requeue():
    fm = FaultModel(chip_failure_rate_per_hour=800.0)
    res = ResilienceConfig(deadline_s=10.0, max_retries=0)
    d = _hot_run(fm, res, reclaim_requeue=True).metrics.to_dict()
    if d["faults"]["chip_failures"]:
        assert d["retries"] == 0   # budget 0 overrides legacy requeue=True


def test_stragglers_trip_quarantines():
    fm = FaultModel(straggler_rate_per_hour=2000.0, straggler_factor=8.0,
                    straggler_duration_s=6.0)
    res = ResilienceConfig(quarantine_ratio=2.0, quarantine_min_samples=2,
                           quarantine_duration_s=3.0)
    out = _hot_run(fm, res)
    d = out.metrics.to_dict()
    assert d["faults"]["stragglers"] > 0
    assert d["faults"]["quarantines"] > 0
    # quarantine is reversible: benches are short here, so by the end
    # of the run no live pod should still be benched
    eng = out.simulator.engine
    horizon = eng.cfg.duration_s
    for st in eng.fns.values():
        for p in st.pod_order:
            assert not p.quarantined or p.ready_at > horizon - 3.0


def test_blackout_suppresses_scaling_but_not_serving():
    fm = FaultModel(blackout_rate_per_hour=3600.0, blackout_duration_s=4.0)
    out = _hot_run(fm)
    m = out.metrics
    d = m.to_dict()
    assert d["faults"]["blackouts"] > 0
    assert m.n_completed > 0            # dispatch kept serving
    # identical run without blackouts makes at least as many decisions
    calm = _hot_run(FaultModel(straggler_rate_per_hour=1e-9)).metrics
    assert sum(m.scaling_actions.values()) <= \
        sum(calm.scaling_actions.values())


def test_cache_loss_counted_with_lifecycle_attached():
    from repro.workloads.scenarios import LIFECYCLE_CACHED
    fm = FaultModel(cache_loss_rate_per_hour=3000.0)
    scen = get_scenario("steady_poisson").with_(
        base_rps=150.0, max_gpus=4, faults=fm, lifecycle=LIFECYCLE_CACHED)
    d = scen.run(policy="has", seed=42, duration_s=15.0).metrics.to_dict()
    assert d["faults"]["cache_losses"] > 0


# ---------------------------------------------------------------------------
# Golden-pinned acceptance claims of the chaos scenarios
# ---------------------------------------------------------------------------

def test_golden_chip_failure_wave_retry_policy_saves_goodput():
    resil = _load("chip_failure_wave")
    ctrl = _load("chip_failure_wave_control")
    # the same failure draws hit both arms
    assert resil.faults["chip_failures"] == ctrl.faults["chip_failures"] > 0
    # control loses in-flight work; the retry policy recovers all of it
    assert ctrl.drop_breakdown["killed"] > 0
    assert resil.drop_breakdown["killed"] == 0
    assert resil.retries > 0
    assert resil.n_dropped < ctrl.n_dropped
    # at no extra cost and without hurting SLO beyond noise. The noise
    # bound covers the latency price of the recovered requests: each
    # retried request re-enters a live queue and can push a handful of
    # neighbors past the threshold — in this ~900-request scenario a
    # few retries move the rate by ~1pp, which is small-sample noise,
    # not a systemic SLO regression.
    assert resil.cost_usd <= ctrl.cost_usd * 1.02
    assert resil.slo_violation_rate["2.0"] <= \
        ctrl.slo_violation_rate["2.0"] + 0.02
    # the repair loop is metered
    assert resil.mttr_s > 0
    assert 0.0 < resil.availability < 1.0


def test_golden_straggler_tail_quarantine_cuts_tail():
    resil = _load("straggler_tail")
    ctrl = _load("straggler_tail_control")
    assert resil.faults["quarantines"] > 0
    assert ctrl.faults["quarantines"] == 0
    # the acceptance pins: p99 cut AND fewer violations...
    assert resil.latency_ms["p99"] < ctrl.latency_ms["p99"]
    assert resil.slo_violation_rate["2.0"] < ctrl.slo_violation_rate["2.0"]
    # ...at <= 10% cost overhead (the benched pod + warm backfill)
    assert resil.cost_usd <= ctrl.cost_usd * 1.10


def test_golden_brownout_overload_sheds_and_cuts_violations():
    resil = _load("brownout_overload")
    ctrl = _load("brownout_overload_control")
    # brownout shedding is explicit (admission drops, not queue aging)
    assert resil.drop_breakdown["shed"] > 0
    # and buys a large 2.0x violation cut at identical cost
    assert resil.slo_violation_rate["2.0"] < \
        ctrl.slo_violation_rate["2.0"] - 0.2
    assert resil.latency_ms["p99"] < ctrl.latency_ms["p99"]
    assert resil.cost_usd <= ctrl.cost_usd * 1.02


def test_legacy_goldens_omit_fault_fields():
    m = _load("steady_poisson")
    for field in ("faults", "retries", "drop_breakdown", "mttr_s",
                  "availability"):
        assert getattr(m, field) is None
    # the resilience-off, fault-free brownout control is legacy too
    assert _load("brownout_overload_control").faults is None
