"""Determinism guards: scenario runs are pure functions of (name,
policy, seed) and generators own their RNG.

Same scenario + seed must produce byte-identical ``RunMetrics`` JSON
across two runs (this is what makes the golden corpus meaningful);
different seeds must produce different traces (guards against a
generator quietly ignoring its seed or leaking through numpy's global
RNG state).
"""
import numpy as np

from repro.workloads import generators
from repro.workloads.azure import standard_workload
from repro.workloads.scenarios import get_scenario

DURATION = 30.0
GENS = {
    "poisson": lambda s: generators.homogeneous_poisson(DURATION, 20.0, s),
    "mmpp": lambda s: generators.mmpp(DURATION, 20.0, seed=s),
    "diurnal": lambda s: generators.diurnal(DURATION, 20.0, seed=s),
    "flash_crowd": lambda s: generators.flash_crowd(DURATION, 20.0, seed=s),
    "ramp": lambda s: generators.ramp(DURATION, 5.0, 40.0, seed=s),
    "azure": lambda s: standard_workload(DURATION, 20.0, seed=s),
}


def test_same_seed_byte_identical_run_metrics():
    for name in ("flash_crowd", "colocated_mix"):
        scen = get_scenario(name)
        a = scen.run(policy="has", seed=9, duration_s=DURATION).metrics
        b = scen.run(policy="has", seed=9, duration_s=DURATION).metrics
        assert a.to_json() == b.to_json(), name


def test_different_seeds_differ():
    for name, gen in GENS.items():
        t0, t1 = gen(0), gen(1)
        assert not (len(t0) == len(t1) and np.array_equal(t0, t1)), name
    a = get_scenario("flash_crowd").run(seed=0, duration_s=DURATION).metrics
    b = get_scenario("flash_crowd").run(seed=1, duration_s=DURATION).metrics
    assert a.to_json() != b.to_json()


def test_same_seed_identical_traces():
    for name, gen in GENS.items():
        assert np.array_equal(gen(7), gen(7)), name


def test_generators_ignore_global_numpy_rng():
    """Seeding (or not) the legacy global RNG must not leak into any
    generator's output — they own their Generator instances."""
    np.random.seed(1)
    before = {name: gen(3) for name, gen in GENS.items()}
    np.random.seed(999)
    np.random.uniform(size=50)  # perturb global state
    after = {name: gen(3) for name, gen in GENS.items()}
    for name in GENS:
        assert np.array_equal(before[name], after[name]), name


def test_traces_are_sorted_and_in_horizon():
    for name, gen in GENS.items():
        t = gen(11)
        assert np.all(np.diff(t) >= 0), name
        if len(t):
            assert t[0] >= 0.0 and t[-1] <= DURATION, name
