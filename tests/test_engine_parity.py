"""Differential-fuzz parity: wide engine vs the frozen scalar reference.

The PR 9 wide engine (``core/events.py``: merged arrival stream, batched
autoscale sweeps, O(1) peak tracking) must be observably IDENTICAL to
the pre-refactor event loop kept verbatim as
``core/engine_scalar.ScalarEventEngine`` — same role
``simulator_tick.py`` played for the PR 1 engine swap. These tests
generate random small scenario configs across the feature matrix
(mixed fleets, spot markets, fault models, lifecycle on/off, all three
policies) and assert the serialized ``RunMetrics`` records are
byte-identical.

hypothesis drives the search when installed (optional dev dependency);
the seeded-fallback test always runs on a fixed config sample so a
hypothesis-free CI lane still gets differential coverage — the
``test_core_properties.py`` idiom, extended with the fallback.

The scalar reference predates ``stream_metrics`` / ``rng_isolation``,
so every generated config keeps both off (their own behavior is pinned
by ``tests/test_wide_engine.py`` and ``tests/test_streaming_metrics.py``).
"""
import dataclasses
import random

import pytest

from repro.core import FaultModel, ResilienceConfig
from repro.core.engine_scalar import ScalarEventEngine
from repro.core.events import EventEngine
from repro.workloads import azure, generators
from repro.workloads.scenarios import LIFECYCLE_CACHED, Scenario


class NoBatchEngine(EventEngine):
    """Wide engine with the batched decide path disabled: every sweep
    takes the legacy per-function loop. The third arm of the diff —
    the vectorized sweep must be byte-identical to both this and the
    frozen scalar reference."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        # replace, don't mutate: the SimConfig may be shared with the
        # simulator that built us
        self.cfg = dataclasses.replace(self.cfg, batched_policy=False)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - hypothesis-free CI lanes
    HAVE_HYPOTHESIS = False

# deterministic spot market for the fuzzed spot-fleet option (storms
# land inside the short fuzz horizons)
from repro.configs.gpus import GPUMarket, spot  # noqa: E402

_FUZZ_MARKET = GPUMarket(price_multiplier=0.25, reclaim_rate_per_hour=30.0,
                         grace_period_s=3.0, storm_multiplier=40.0,
                         storm_period_s=20.0, storm_duration_s=5.0,
                         storm_start_s=4.0)

TRACES = {
    "poisson": generators.homogeneous_poisson,
    "mmpp": lambda d, r, s: generators.mmpp(d, r, burst_multiplier=6.0,
                                            mean_calm_s=8.0,
                                            mean_burst_s=4.0, seed=s),
    "flash": lambda d, r, s: generators.flash_crowd(d, r,
                                                    spike_multiplier=6.0,
                                                    ramp_s=3.0, hold_s=5.0,
                                                    seed=s),
    "azure": lambda d, r, s: azure.standard_workload(d, r, seed=s),
}

FLEETS = {
    "homog": None,
    "het": (("a10g", 8), ("a100", 4)),
    "spot": (("v5e", 3), (spot("v5e", _FUZZ_MARKET), 10)),
}

FAULTS = {
    "none": (None, None),
    "chaos": (FaultModel(chip_failure_rate_per_hour=200.0,
                         straggler_rate_per_hour=80.0,
                         straggler_factor=6.0, straggler_duration_s=8.0),
              None),
    "resilient": (FaultModel(chip_failure_rate_per_hour=150.0,
                             cache_loss_rate_per_hour=40.0),
                  ResilienceConfig(deadline_s=8.0, max_retries=2,
                                   retry_backoff_s=0.3,
                                   quarantine_ratio=3.0,
                                   quarantine_min_samples=2,
                                   quarantine_duration_s=5.0)),
}

ARCH_SETS = (("olmo-1b",), ("mamba2-2.7b",),
             ("olmo-1b", "whisper-medium"),
             ("olmo-1b", "mamba2-2.7b", "whisper-medium"))


def run_both(trace, archs, rps, dur, policy, fleet_key, fault_key,
             lifecycle, width, seed):
    """One differential run: (wide RunMetrics, scalar ditto, wide with
    the batched decide path off)."""
    faults, resilience = FAULTS[fault_key]
    sc = Scenario(
        name="fuzz", description="differential-fuzz config",
        trace=TRACES[trace], archs=archs, base_rps=rps, duration_s=dur,
        max_gpus=12, colocated=len(archs) > 1 or width > 1,
        fleet=FLEETS[fleet_key],
        lifecycle=LIFECYCLE_CACHED if lifecycle else None,
        faults=faults, resilience=resilience, width=width)
    wide = sc.run(policy, seed=seed).metrics
    scalar = sc.run(policy, seed=seed,
                    engine_cls=ScalarEventEngine).metrics
    nobatch = sc.run(policy, seed=seed, engine_cls=NoBatchEngine).metrics
    return wide, scalar, nobatch


def assert_parity(wide, scalar, nobatch=None):
    # diff() first for a readable field-by-field failure, then the
    # byte-level pin the goldens rely on
    assert wide.diff(scalar, rel=0.0, abs_tol=0.0) == []
    assert wide.to_json() == scalar.to_json()
    if nobatch is not None:
        assert wide.diff(nobatch, rel=0.0, abs_tol=0.0) == []
        assert wide.to_json() == nobatch.to_json()


# a fixed sample spanning the feature matrix: every trace family, every
# fleet kind, every fault mode, every policy, lifecycle on and off,
# single- and multi-function, width>len(archs) (variant fn_ids)
FALLBACK_CASES = [
    ("poisson", ARCH_SETS[0], 30.0, 10.0, "has", "homog", "none",
     False, 1, 7),
    ("mmpp", ARCH_SETS[2], 15.0, 12.0, "kserve", "het", "none",
     False, 1, 11),
    ("flash", ARCH_SETS[0], 25.0, 10.0, "fast", "homog", "chaos",
     False, 1, 3),
    ("azure", ARCH_SETS[3], 8.0, 10.0, "has", "homog", "none",
     True, 5, 23),
    ("poisson", ARCH_SETS[1], 40.0, 9.0, "has", "spot", "none",
     False, 1, 5),
    ("mmpp", ARCH_SETS[0], 20.0, 10.0, "has", "homog", "resilient",
     True, 1, 13),
]


@pytest.mark.parametrize("case", FALLBACK_CASES,
                         ids=[f"{c[0]}-{c[4]}-{c[5]}-{c[6]}-w{c[8]}"
                              for c in FALLBACK_CASES])
def test_parity_seeded_fallback(case):
    """Always-on differential sample (no hypothesis required)."""
    wide, scalar, nobatch = run_both(*case)
    assert_parity(wide, scalar, nobatch)
    # the runs must carry signal, not vacuous empty traces
    assert wide.n_arrived > 20


def test_parity_random_sample():
    """A seeded random walk over the config space — catches corners the
    hand-picked fallback list misses, without hypothesis installed."""
    rng = random.Random(0xC0FFEE)
    for _ in range(4):
        case = (rng.choice(list(TRACES)),
                rng.choice(ARCH_SETS),
                rng.uniform(5.0, 40.0),
                rng.uniform(8.0, 12.0),
                rng.choice(["has", "kserve", "fast"]),
                rng.choice(list(FLEETS)),
                rng.choice(list(FAULTS)),
                rng.random() < 0.5,
                rng.choice([1, 1, 4]),
                rng.randrange(10_000))
        wide, scalar, nobatch = run_both(*case)
        assert_parity(wide, scalar, nobatch)


if HAVE_HYPOTHESIS:
    @given(trace=st.sampled_from(sorted(TRACES)),
           archs=st.sampled_from(ARCH_SETS),
           rps=st.floats(5.0, 40.0),
           policy=st.sampled_from(["has", "kserve", "fast"]),
           fleet_key=st.sampled_from(sorted(FLEETS)),
           fault_key=st.sampled_from(sorted(FAULTS)),
           lifecycle=st.booleans(),
           width=st.sampled_from([1, 3, 6]),
           seed=st.integers(0, 2**16))
    @settings(max_examples=12, deadline=None)
    def test_parity_hypothesis(trace, archs, rps, policy, fleet_key,
                               fault_key, lifecycle, width, seed):
        """hypothesis-driven differential fuzz over the same space."""
        wide, scalar, nobatch = run_both(trace, archs, rps, 9.0, policy,
                                         fleet_key, fault_key, lifecycle,
                                         width, seed)
        assert_parity(wide, scalar, nobatch)


def test_scalar_reference_is_frozen():
    """The reference must stay the pre-refactor loop: no merged-stream
    or sweep machinery may leak into it (it would defeat the diff)."""
    import inspect

    src = inspect.getsource(ScalarEventEngine)
    assert "_sweep" not in src
    assert "argsort" not in src
    assert "_on_autoscale" in src   # per-function timers, not sweeps
