"""Per-architecture smoke tests: reduced variant (2 layers, d_model<=512,
<=4 experts), one forward + one train step on CPU; output shapes + finite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ARCHS, list_archs, reduced
from repro.models import CallOpts
from repro.training import optimizer as opt_mod, steps

B, S = 2, 64


def _batch(cfg, rng):
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        batch["frame_embeds"] = jax.random.normal(
            rng, (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.num_visual_tokens:
        batch["visual_embeds"] = jax.random.normal(
            rng, (B, cfg.num_visual_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch):
    cfg = reduced(ARCHS[arch])
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    rng = jax.random.PRNGKey(0)
    params = models.init_params(rng, cfg)
    batch = _batch(cfg, rng)
    logits, aux = models.forward(params, cfg, batch)
    v = cfg.num_visual_tokens or 0
    assert logits.shape == (B, S + v, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", list_archs())
def test_train_step(arch):
    cfg = reduced(ARCHS[arch])
    rng = jax.random.PRNGKey(1)
    params = models.init_params(rng, cfg)
    opt_state = opt_mod.init_opt_state(params)
    adamw = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    train_step = jax.jit(steps.make_train_step(cfg, adamw, CallOpts()))
    batch = _batch(cfg, rng)
    params2, opt_state2, metrics = train_step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = max(float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ["olmo-1b", "deepseek-moe-16b",
                                  "mamba2-2.7b", "jamba-v0.1-52b",
                                  "whisper-medium", "llava-next-34b"])
def test_prefill_decode_consistency(arch):
    cfg = reduced(ARCHS[arch])
    rng = jax.random.PRNGKey(2)
    params = models.init_params(rng, cfg)
    toks = jax.random.randint(rng, (B, 17), 0, cfg.vocab_size)
    opts = CallOpts(capacity_factor=100.0)  # no-drop MoE for exactness
    extra = {k: v for k, v in _batch(cfg, rng).items() if k != "tokens"}
    full, _ = models.forward(params, cfg, {"tokens": toks, **extra}, opts)
    v = cfg.num_visual_tokens or 0
    last, cache = models.prefill(params, cfg,
                                 {"tokens": toks[:, :-1], **extra},
                                 32 + v, opts)
    ref = full[:, v + toks.shape[1] - 2]
    err = float(jnp.abs(last[:, 0] - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert err < 3e-2, f"prefill mismatch {err}"
    pos = jnp.asarray(v + toks.shape[1] - 1, jnp.int32)
    dec, _ = models.decode_step(params, cfg, toks[:, -1:], pos, cache,
                                opts=opts)
    ref2 = full[:, v + toks.shape[1] - 1]
    err2 = float(jnp.abs(dec[:, 0] - ref2).max()
                 / (jnp.abs(ref2).max() + 1e-9))
    assert err2 < 3e-2, f"decode mismatch {err2}"


def test_sliding_window_ring_buffer():
    """Decode with a ring buffer (window < seq) matches windowed forward."""
    cfg = reduced(ARCHS["qwen2.5-3b"])
    W = 16
    rng = jax.random.PRNGKey(3)
    params = models.init_params(rng, cfg)
    total = 40
    toks = jax.random.randint(rng, (1, total), 0, cfg.vocab_size)
    opts = CallOpts(window=W)
    full, _ = models.forward(params, cfg, {"tokens": toks}, opts)
    # prefill W tokens then decode the rest through the ring
    last, cache = models.prefill(params, cfg, {"tokens": toks[:, :W]}, W, opts)
    logits = None
    for i in range(W, total):
        pos = jnp.asarray(i, jnp.int32)
        logits, cache = models.decode_step(params, cfg, toks[:, i:i + 1],
                                           pos, cache, opts=opts)
    ref = full[:, -1]
    err = float(jnp.abs(logits[:, 0] - ref).max()
                / (jnp.abs(ref).max() + 1e-9))
    assert err < 3e-2, f"ring-buffer mismatch {err}"


def test_use_kernels_matches_reference_path():
    """Pallas (interpret) forward == jnp forward on a dense and an ssm arch."""
    for arch in ["olmo-1b", "mamba2-2.7b", "deepseek-moe-16b"]:
        cfg = reduced(ARCHS[arch])
        rng = jax.random.PRNGKey(4)
        params = models.init_params(rng, cfg)
        batch = _batch(cfg, rng)
        ref_logits, _ = models.forward(params, cfg, batch, CallOpts())
        k_logits, _ = models.forward(params, cfg, batch,
                                     CallOpts(use_kernels=True))
        err = float(jnp.abs(ref_logits - k_logits).max()
                    / (jnp.abs(ref_logits).max() + 1e-9))
        assert err < 5e-2, f"{arch}: kernel path mismatch {err}"
