import os
import sys

# tests run on the single CPU device (the dry-run sets its own XLA_FLAGS
# in-process and is exercised via subprocess in test_dryrun.py)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
