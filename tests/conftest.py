import os
import sys

# tests run on the single CPU device (the dry-run sets its own XLA_FLAGS
# in-process and is exercised via subprocess in test_dryrun.py)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_addoption(parser):
    parser.addoption(
        "--update-goldens", action="store_true", default=False,
        help="rewrite tests/goldens/*.json from the current code instead "
             "of comparing against them (commit the diff intentionally)")
