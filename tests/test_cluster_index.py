"""Indexed cluster state: the Reconfigurator's O(1) views must stay
exactly equivalent to the linear scans they replaced — including pod
ORDER (policies tie-break stable sorts on it) — and the incremental
per-function capacity must match the naive re-summation bitwise."""
import numpy as np

from repro.configs import ARCHS
from repro.core.autoscaler import HybridAutoScaler
from repro.core.perf_model import FnSpec
from repro.core.reconfigurator import Reconfigurator
from repro.core.vgpu import PodAlloc

SPEC = FnSpec(ARCHS["qwen2.5-3b"])


def naive_pods_of(recon, fn_id):
    return [p for g in recon.gpus.values() for p in g.pods
            if p.fn_id == fn_id]


def naive_gpu_of_pod(recon, pod_id):
    for g in recon.gpus.values():
        if any(p.pod_id == pod_id for p in g.pods):
            return g
    return None


def _random_mutations(recon, rng, fns=("fn-a", "fn-b", "fn-c"), steps=200):
    pods = []
    for _ in range(steps):
        op = rng.random()
        if op < 0.45 or not pods:
            fn = fns[rng.integers(len(fns))]
            pod = PodAlloc(fn_id=fn, sm=int(rng.integers(1, 5)),
                           quota=float(rng.integers(1, 6)) / 10, batch=4)
            # sometimes target an existing GPU with room
            cands = [g for g in recon.gpus.values()
                     if g.can_place(pod.sm, pod.quota)]
            target = (cands[rng.integers(len(cands))].uuid
                      if cands and rng.random() < 0.5 else None)
            try:
                recon.place_pod(pod, target)
                pods.append(pod)
            except RuntimeError:
                pass
        elif op < 0.7:
            pod = pods.pop(rng.integers(len(pods)))
            recon.remove_pod(pod.pod_id)
            recon.release_empty_gpus()
        else:
            pod = pods[rng.integers(len(pods))]
            g = recon.gpu_of_pod(pod.pod_id)
            room = g.max_avail_quota_for(pod)
            recon.set_quota(pod.pod_id, min(room, pod.quota))
    return pods


def test_indexed_views_match_naive_scans():
    rng = np.random.default_rng(0)
    recon = Reconfigurator(num_gpus=2, max_gpus=12)
    pods = _random_mutations(recon, rng)
    for fn in ("fn-a", "fn-b", "fn-c", "fn-absent"):
        got = recon.pods_of(fn)
        ref = naive_pods_of(recon, fn)
        assert [p.pod_id for p in got] == [p.pod_id for p in ref], fn
    for pod in pods:
        assert recon.gpu_of_pod(pod.pod_id) is \
            naive_gpu_of_pod(recon, pod.pod_id)
    assert recon.gpu_of_pod("pod-nope") is None
    assert recon.invariant_ok()


def test_direct_gpu_mutations_update_indexes():
    """Placing/removing straight on a VirtualGPU owned by a
    Reconfigurator must keep the cluster indexes authoritative."""
    recon = Reconfigurator(num_gpus=1)
    gpu = next(iter(recon.gpus.values()))
    pod = PodAlloc(fn_id="fn-x", sm=4, quota=0.5, batch=8)
    gpu.place(pod)
    assert [p.pod_id for p in recon.pods_of("fn-x")] == [pod.pod_id]
    assert recon.gpu_of_pod(pod.pod_id) is gpu
    gpu.set_quota(pod.pod_id, 0.8)
    assert recon.pod(pod.pod_id).quota == 0.8
    gpu.remove(pod.pod_id)
    assert recon.pods_of("fn-x") == []
    assert recon.gpu_of_pod(pod.pod_id) is None
    assert recon.invariant_ok()


def test_gpu_counter_is_per_instance():
    """Satellite: GPU uuids are a function of the cluster's own
    history, not of how many Reconfigurators the process built before —
    two identically-driven clusters name their chips identically."""
    def drive():
        recon = Reconfigurator(num_gpus=2, max_gpus=8)
        recon.place_pod(PodAlloc(fn_id="f", sm=8, quota=1.0, batch=8))
        recon.remove_pod(recon.pods_of("f")[0].pod_id)
        recon.release_empty_gpus()
        recon.place_pod(PodAlloc(fn_id="f", sm=4, quota=0.5, batch=8))
        return sorted(recon.gpus)
    assert drive() == drive()
    assert sorted(Reconfigurator(num_gpus=1).gpus) == ["GPU-0000"]


def test_incremental_capacity_matches_naive_sum():
    recon = Reconfigurator(num_gpus=0, max_gpus=16)
    scaler = HybridAutoScaler(recon)
    scaler.prewarm(SPEC, 40.0)
    rng = np.random.default_rng(1)
    for now in range(0, 120, 7):
        scaler.scale(float(now), SPEC, float(rng.uniform(1.0, 120.0)))
        naive = sum(scaler.pod_thpt(SPEC, p)
                    for p in recon.pods_of(SPEC.fn_id))
        assert scaler.capacity(SPEC) == naive  # bitwise, not approx
    assert recon.invariant_ok()
