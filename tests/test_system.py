"""End-to-end behaviour tests for the HAS-GPU system."""
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core import (ClusterSimulator, FaSTGShareLikePolicy, FnSpec,
                        HybridAutoScaler, KServeLikePolicy, Reconfigurator,
                        SimConfig)
from repro.workloads import TraceConfig, arrivals, rate_series


SPEC = FnSpec(ARCHS["olmo-1b"])

# short default trace keeps the fast path fast; the event engine makes
# each run sub-second even at minutes of simulated time
TRACE_S = 40.0


def _run(policy_name, arr, duration=TRACE_S, base=20.0):
    recon = Reconfigurator(num_gpus=0, max_gpus=32)
    pol = {"has": HybridAutoScaler, "kserve": KServeLikePolicy,
           "fast": FaSTGShareLikePolicy}[policy_name](recon)
    pol.prewarm(SPEC, base)
    sim = ClusterSimulator(SPEC, pol, recon, arr,
                           SimConfig(duration_s=duration,
                                     whole_gpu_cost=policy_name == "kserve"))
    return sim.run()


@pytest.fixture(scope="module")
def trace():
    return arrivals(TraceConfig(duration_s=TRACE_S, base_rps=20.0, seed=7))


def test_all_policies_complete_requests(trace):
    for name in ["has", "kserve", "fast"]:
        res = _run(name, trace)
        assert res.n_completed + res.n_dropped == res.n_arrived
        assert res.n_completed > 0.95 * res.n_arrived


def test_has_cheaper_than_kserve(trace):
    has = _run("has", trace)
    ks = _run("kserve", trace)
    assert has.cost_per_1k < ks.cost_per_1k


def test_has_violations_beat_fast_gshare(trace):
    has = _run("has", trace)
    fast = _run("fast", trace)
    v_has = has.violations([2.0])[2.0]
    v_fast = fast.violations([2.0])[2.0]
    assert v_has <= v_fast + 1e-6


def test_vertical_scaling_first_on_burst():
    """Algorithm 1: with quota headroom in the partition, a demand jump is
    absorbed by a quota increase (vertical) before any new pod."""
    from repro.core.vgpu import PodAlloc
    recon = Reconfigurator(num_gpus=1, max_gpus=4)
    gpu = list(recon.gpus.values())[0]
    pod = PodAlloc(fn_id=SPEC.fn_id, sm=4, quota=0.3, batch=8)
    gpu.place(pod)
    scaler = HybridAutoScaler(recon)
    cap0 = scaler.capacity(SPEC)
    actions = scaler.scale(10.0, SPEC, cap0 * 1.6)  # 60% demand jump
    kinds = [a.kind for a in actions]
    assert kinds and kinds[0] == "vup"
    assert pod.quota > 0.3  # quota actually rewritten at runtime
    assert scaler.capacity(SPEC) > cap0


def test_workload_generator_deterministic():
    a1 = arrivals(TraceConfig(duration_s=30, seed=5))
    a2 = arrivals(TraceConfig(duration_s=30, seed=5))
    np.testing.assert_array_equal(a1, a2)
    lam = rate_series(TraceConfig(duration_s=30, seed=5))
    assert (lam >= 0).all()


def test_serving_engine_end_to_end():
    """Real reduced model served through gateway + token scheduler."""
    import time
    from repro.core.scheduler import HASGPUScheduler
    from repro.core.vgpu import PodAlloc, VirtualGPU
    from repro.serving import Gateway, InferenceRequest, PodEngine

    cfg = reduced(ARCHS["olmo-1b"])
    vgpu = VirtualGPU("GPU-T", window_ms=20.0)
    sched = HASGPUScheduler()
    gw = Gateway()
    pod = PodAlloc(fn_id="f", sm=4, quota=0.8, batch=2)
    vgpu.place(pod)
    gw.register("f", PodEngine(cfg, pod, vgpu, sched, max_seq=32))
    rng = np.random.default_rng(0)
    for _ in range(4):
        gw.route("f", InferenceRequest(
            prompt=rng.integers(1, cfg.vocab_size, size=8).astype(np.int32),
            max_new_tokens=3))
    done, t0 = [], time.monotonic()
    while len(done) < 4 and time.monotonic() - t0 < 120:
        done.extend(gw.pump("f"))
    assert len(done) == 4
    assert all(r.output is not None and len(r.output) == 3 for r in done)
