"""Wide-engine invariants at fleet width (PR 9).

Conservation and rng-isolation property tests at width N~200, the
``_thpt_cache`` bound regression, the azure_wide bounded-memory smoke,
and the incremental ``n_used_gpus`` counter — extending the
``test_determinism.py`` byte-identity discipline to the
struct-of-arrays cluster state.
"""
import numpy as np
import pytest

from repro.core import FaultModel, ResilienceConfig, SimConfig
from repro.core.events import _THPT_CACHE_MAX
from repro.core.multisim import MultiFunctionSimulator
from repro.workloads.scenarios import get_scenario, make_policy


def build_wide(width, duration_s, seed, rps=2.0, max_gpus=64,
               rng_isolation=False, faults=None, resilience=None,
               arrival_edit=None):
    """An azure_wide-shaped simulator in retain mode, with optional
    fault layer and an ``arrival_edit(i, arr) -> arr`` hook for
    perturbation experiments."""
    sc = get_scenario("azure_wide").with_(width=width, max_gpus=max_gpus,
                                          sim_overrides=None)
    specs = sc.fn_specs()
    recon = sc.make_recon(None)
    cfg = SimConfig(duration_s=duration_s, whole_gpu_cost=False, seed=seed,
                    rng_isolation=rng_isolation, faults=faults,
                    resilience=resilience)
    policies, arrs = {}, {}
    for i, spec in enumerate(specs):
        pol = make_policy("has", recon)
        pol.prewarm(spec, rps)
        policies[spec.fn_id] = pol
        a = sc.arrivals_for(i, duration_s, rps, seed)
        if arrival_edit is not None:
            a = arrival_edit(i, a)
        arrs[spec.fn_id] = a
    return MultiFunctionSimulator(specs, policies, recon, arrs, cfg)


def _traces(sim):
    return {st.fid: tuple(r.latency for r in st.completed)
            for st in sim.states}


# ---- conservation at width -------------------------------------------------

def test_conservation_at_width_200():
    """Every arrival is accounted for, per function: arrived ==
    completed + dropped, with the drop breakdown summing exactly."""
    sim = build_wide(width=200, duration_s=8.0, seed=17)
    sim.engine.run()
    assert len(sim.states) == 200
    total = 0
    for st in sim.states:
        n_comp = len([r for r in st.completed if r.latency is not None])
        assert len(st.arrivals) == n_comp + st.dropped, st.fid
        assert st.dropped == sum(st.drop_kinds.values()), st.fid
        total += len(st.arrivals)
    assert total > 1000   # the property must be exercised by real load


def test_conservation_under_faults():
    """Conservation survives the chaos paths (kills, retries, sheds)
    and the breakdown still sums to the per-function drop count."""
    fm = FaultModel(chip_failure_rate_per_hour=250.0,
                    straggler_rate_per_hour=60.0, straggler_factor=6.0,
                    straggler_duration_s=6.0)
    res = ResilienceConfig(deadline_s=6.0, max_retries=2,
                           retry_backoff_s=0.3, admission_headroom=0.5)
    sim = build_wide(width=40, duration_s=10.0, seed=23, rps=6.0,
                     max_gpus=24, faults=fm, resilience=res)
    sim.engine.run()
    assert sim.engine.fault_counts   # chaos actually fired
    for st in sim.states:
        n_comp = len([r for r in st.completed if r.latency is not None])
        assert len(st.arrivals) == n_comp + st.dropped, st.fid
        assert st.dropped == sum(st.drop_kinds.values()), st.fid


# ---- rng isolation -----------------------------------------------------------

def test_arrival_perturbation_is_isolated():
    """Under ``rng_isolation`` each function draws service noise from
    its own stream: halving function 0's arrivals leaves every other
    function's completed-latency trace byte-identical."""
    kw = dict(width=12, duration_s=10.0, seed=9, rps=4.0,
              rng_isolation=True)
    a = build_wide(**kw)
    a.engine.run()
    b = build_wide(**kw, arrival_edit=lambda i, arr: arr[::2] if i == 0
                   else arr)
    b.engine.run()
    ta, tb = _traces(a), _traces(b)
    victim = a.states[0].fid
    assert ta[victim] != tb[victim]        # the perturbation landed
    for fid in ta:
        if fid != victim:
            assert ta[fid] == tb[fid], fid


def test_shared_stream_is_coupled_without_isolation():
    """The control: with the legacy shared rng, the same perturbation
    leaks into other functions' draws — documenting exactly what
    ``rng_isolation`` buys (and why goldens keep it off)."""
    kw = dict(width=12, duration_s=10.0, seed=9, rps=4.0,
              rng_isolation=False)
    a = build_wide(**kw)
    a.engine.run()
    b = build_wide(**kw, arrival_edit=lambda i, arr: arr[::2] if i == 0
                   else arr)
    b.engine.run()
    ta, tb = _traces(a), _traces(b)
    victim = a.states[0].fid
    assert any(ta[fid] != tb[fid] for fid in ta if fid != victim)


def test_fault_toggle_leaves_untouched_functions_identical():
    """Arming pod-level stragglers perturbs only the functions the
    engine marks touched (``touched_fns``); everything else keeps a
    byte-identical trace under rng isolation."""
    fm = FaultModel(straggler_rate_per_hour=60.0, straggler_factor=8.0,
                    straggler_duration_s=6.0)
    kw = dict(width=12, duration_s=10.0, seed=9, rps=4.0,
              rng_isolation=True)
    a = build_wide(**kw)
    a.engine.run()
    b = build_wide(**kw, faults=fm)
    b.engine.run()
    touched = b.engine.touched_fns
    assert touched               # the fault model actually fired
    untouched = [fid for fid in _traces(a) if fid not in touched]
    assert untouched             # and the blast radius was partial
    ta, tb = _traces(a), _traces(b)
    for fid in untouched:
        assert ta[fid] == tb[fid], fid


# ---- _thpt_cache bound (bugfix regression) ---------------------------------

def test_thpt_cache_is_bounded():
    """The dispatch-throughput memo must stay flat across a long wide
    run: the engine-level cache grew one entry per (fn, batch, sm,
    quota, device) EVER seen — unbounded under vertical scaling's
    off-grid quota floats. Now per-function and capped."""
    sim = build_wide(width=2, duration_s=2.0, seed=1)
    eng = sim.engine
    st = sim.states[0]

    class _P:
        def __init__(self, q):
            self.batch, self.sm, self.quota, self.gpu_type = 8, 4, q, None

    for i in range(3 * _THPT_CACHE_MAX):
        eng._thpt(st, _P(0.1 + i * 1e-6))   # off-grid quota floats
        assert len(st._thpt_cache) <= _THPT_CACHE_MAX
    # memo stays correct across the clears
    q = 0.1 + 7 * 1e-6
    assert eng._thpt(st, _P(q)) == eng._thpt(st, _P(q))
    # and it is per-function state, not engine-global
    assert st._thpt_cache is not sim.states[1]._thpt_cache


# ---- azure_wide / streaming ------------------------------------------------

def test_azure_wide_bounded_memory_smoke():
    """The registered azure_wide scenario runs the constant-memory
    path: no completion records retained, the streaming sink carries
    every completion, and the record declares its provenance."""
    sc = get_scenario("azure_wide")
    assert sc.width == 400
    out = sc.run("has", seed=3, duration_s=6.0)
    eng = out.simulator.engine
    assert sum(len(st.completed) for st in eng.fns.values()) == 0
    assert eng.stream_stats is not None
    assert eng.stream_stats.n > 0
    m = out.metrics
    assert m.streaming is not None
    assert m.n_completed == eng.stream_stats.n
    assert m.n_arrived == m.n_completed + m.n_dropped
    # 400 distinct tenant functions, physics caches shared per arch
    assert len(eng.fns) == 400
    assert len({st.spec.arch.name for st in eng.fns.values()}) == 3


def test_n_used_gpus_counter_matches_scan():
    """The incremental used-chip counter (O(1) peak tracking) agrees
    with the authoritative O(G) scan after a churny spot run."""
    sc = get_scenario("spot_reclaim_storm")
    out = sc.run("has", seed=11, duration_s=12.0)
    recon = out.simulator.engine.recon
    assert recon.n_used_gpus == len(recon.used_gpus())
    assert recon.invariant_ok()
