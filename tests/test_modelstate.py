"""Cold-start test suite for the model-state lifecycle engine
(core/modelstate.py).

Pins, in order: the shared start-latency constants (the policies'
cold-start fields must stay sums of one physics source), the
state-machine transitions cold -> fetching -> host -> gpu -> host ->
cold, LRU eviction order under a capacity budget, weight-transfer
events racing arrivals and scale-downs (mirroring
test_event_edge_cases.py), keep-warm standby pods (capacity exclusion,
hot reactivation, idle-retention billing), forecast-driven pre-warming
beating the reactive policy on the flash-crowd trace, and — the
load-bearing one — byte-identical legacy goldens when a tracker with
default (passive) lifecycle parameters is attached.
"""
import dataclasses
import pathlib
import warnings

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.gpus import DEFAULT_GPU_TYPE, GPU_TYPES
from repro.core import (AutoScalerConfig, ClusterSimulator, FnSpec,
                        HybridAutoScaler, LifecycleConfig, ModelStateTracker,
                        NodeWeightCache, Reconfigurator, SimConfig,
                        WeightState)
from repro.core import modelstate as ms
from repro.core.baselines import FaSTGShareLikeConfig, KServeLikeConfig
from repro.core.cost import CostMeter
from repro.core.metrics import RunMetrics
from repro.core.vgpu import PodAlloc
from repro.workloads.scenarios import get_scenario

SPEC = FnSpec(ARCHS["olmo-1b"])
GOLDEN_DIR = pathlib.Path(__file__).parent / "goldens"

PHYSICS = LifecycleConfig(derive_from_physics=True, host_cache_gb=16.0)


def make_tracker(**kw) -> ModelStateTracker:
    base = dict(derive_from_physics=True, host_cache_gb=16.0)
    base.update(kw)
    return ModelStateTracker(LifecycleConfig(**base))


# ---------------------------------------------------------------- constants
def test_legacy_constants_are_exact_component_sums():
    """The flat cold-start constants every golden was produced with must
    be EXACTLY the sums of the shared physics components — bitwise, so
    the derivation can never drift the goldens."""
    assert ms.WARM_CHIP_COLD_START_S == 2.5
    assert ms.NEW_GPU_COLD_START_S == 8.0
    assert ms.FAST_GSHARE_COLD_START_S == 5.0
    assert ms.KSERVE_COLD_START_S == 15.0
    assert ms.WARM_CHIP_COLD_START_S == (
        ms.CONTAINER_INIT_S + ms.WEIGHT_FETCH_S + ms.WEIGHT_LOAD_S)
    assert ms.NEW_GPU_COLD_START_S == (
        ms.WARM_CHIP_COLD_START_S + ms.CHIP_INIT_S)


def test_policies_share_one_cold_start_physics_source():
    """Regression for the duplicated-constants bug: every policy config
    quotes its cold-start default from core/modelstate.py, not from an
    independent literal that can silently diverge."""
    assert AutoScalerConfig().cold_start_s == ms.WARM_CHIP_COLD_START_S
    assert AutoScalerConfig().new_gpu_cold_start_s == ms.NEW_GPU_COLD_START_S
    assert KServeLikeConfig().cold_start_s == ms.KSERVE_COLD_START_S
    assert FaSTGShareLikeConfig().cold_start_s == ms.FAST_GSHARE_COLD_START_S
    # and the cross-policy relations hold by construction
    assert KServeLikeConfig().cold_start_s == (
        ms.NEW_GPU_COLD_START_S + KServeLikeConfig().start_overhead_s)
    assert FaSTGShareLikeConfig().cold_start_s == (
        ms.WARM_CHIP_COLD_START_S + FaSTGShareLikeConfig().start_overhead_s)


def test_physics_scales_with_model_size_and_bus():
    """Derived tier latencies follow the weight footprint and the
    device's host->HBM bandwidth."""
    small = ms.physics_cold_model(SPEC, DEFAULT_GPU_TYPE)
    big = ms.physics_cold_model(FnSpec(ARCHS["mamba2-2.7b"]),
                                DEFAULT_GPU_TYPE)
    assert big.fetch_to_host_s > small.fetch_to_host_s
    assert big.load_to_gpu_s > small.load_to_gpu_s
    slow_bus = ms.physics_cold_model(SPEC, GPU_TYPES["t4"])
    fast_bus = ms.physics_cold_model(SPEC, GPU_TYPES["h100"])
    assert slow_bus.load_to_gpu_s > fast_bus.load_to_gpu_s
    # fetch is an object-store property, not a device property
    assert slow_bus.fetch_to_host_s == fast_bus.fetch_to_host_s


def test_cold_start_model_tier_composition():
    m = ms.ColdStartModel(container_init_s=0.3, fetch_to_host_s=2.0,
                          load_to_gpu_s=0.1, chip_init_s=5.0)
    assert m.time_to_ready(WeightState.COLD) == pytest.approx(2.4)
    assert m.time_to_ready(WeightState.HOST) == pytest.approx(0.4)
    assert m.time_to_ready(WeightState.GPU) == pytest.approx(0.3)
    assert m.time_to_ready(WeightState.FETCHING,
                           wait_s=0.7) == pytest.approx(1.1)
    assert m.time_to_ready(WeightState.COLD,
                           fresh_chip=True) == pytest.approx(7.4)
    assert m.time_to_ready(WeightState.HOST,
                           overhead_s=1.5) == pytest.approx(1.9)


def test_lifecycle_config_validation():
    with pytest.raises(ValueError):
        LifecycleConfig(host_cache_gb=8.0)   # cache needs physics mode
    with pytest.raises(ValueError):
        LifecycleConfig(keep_warm_pods=1)
    assert LifecycleConfig().is_passive
    assert not LifecycleConfig(derive_from_physics=True).is_passive


# ---------------------------------------------------------------- LRU cache
def test_lru_eviction_order():
    wb = 1.0
    cache = NodeWeightCache(capacity_bytes=3.0)
    assert cache.admit("a", wb) == []
    assert cache.admit("b", wb) == []
    assert cache.admit("c", wb) == []
    cache.touch("a")                      # LRU order now b, c, a
    assert cache.lru_order() == ["b", "c", "a"]
    assert cache.admit("d", wb) == ["b"]  # least-recently-used evicted first
    assert cache.admit("e", 2.0) == ["c", "a"]
    assert cache.lru_order() == ["d", "e"]


def test_lru_rejects_model_bigger_than_budget():
    cache = NodeWeightCache(capacity_bytes=2.0)
    cache.admit("small", 1.5)
    assert cache.admit("huge", 5.0) == []   # not admitted, nothing flushed
    assert cache.contains("small") and not cache.contains("huge")


# ---------------------------------------------------------------- tracker
def test_state_machine_transitions():
    """COLD -> FETCHING -> HOST -> GPU -> (remove) -> HOST -> (evict)
    -> COLD, with transfer completion folded in lazily."""
    tr = make_tracker()
    recon = Reconfigurator(num_gpus=0, max_gpus=4)
    recon.attach_modelstate(tr)
    assert tr.state("node-0", SPEC.fn_id, 0.0) is WeightState.COLD

    done_at = tr.promote("node-0", SPEC, now=0.0)
    assert done_at == pytest.approx(ms.weight_bytes(SPEC) / ms.OBJECT_STORE_BW)
    assert tr.state("node-0", SPEC.fn_id, 0.5) is WeightState.FETCHING
    # re-promoting mid-flight keeps the original completion time
    assert tr.promote("node-0", SPEC, now=0.5) == done_at
    assert tr.state("node-0", SPEC.fn_id, done_at + 0.1) is WeightState.HOST

    pod = PodAlloc(fn_id=SPEC.fn_id, sm=4, quota=0.5, batch=8)
    recon.place_pod(pod, None, now=done_at + 1.0, cold_start_s=2.5, spec=SPEC)
    g = recon.gpu_of_pod(pod.pod_id)
    # at the placement instant the HBM load is still in flight; the
    # weights only count as GPU-resident once they have arrived
    assert tr.state(g.node, SPEC.fn_id, done_at + 1.0,
                    gpu_uuid=g.uuid) is WeightState.FETCHING
    assert tr.state(g.node, SPEC.fn_id, pod.ready_at,
                    gpu_uuid=g.uuid) is WeightState.GPU
    assert pod.start_kind == "warm"       # host-cached at placement

    recon.remove_pod(pod.pod_id)          # demote: HBM -> host cache
    assert tr.state(g.node, SPEC.fn_id, done_at + 2.0,
                    gpu_uuid=g.uuid) is WeightState.HOST

    tr._cache(g.node).evict(SPEC.fn_id)   # pressure-evict -> COLD
    assert tr.state(g.node, SPEC.fn_id, done_at + 3.0) is WeightState.COLD


def test_placement_tier_latencies():
    """A COLD placement pays fetch+load, a HOST placement only load, a
    second pod on the same chip starts hot (container only)."""
    tr = make_tracker()
    recon = Reconfigurator(num_gpus=0, max_gpus=4)
    recon.attach_modelstate(tr)
    model = tr.cold_model(SPEC, DEFAULT_GPU_TYPE)

    p1 = PodAlloc(fn_id=SPEC.fn_id, sm=4, quota=0.5, batch=8)
    recon.place_pod(p1, None, now=0.0, cold_start_s=2.5, spec=SPEC)
    assert p1.start_kind == "cold"
    assert p1.ready_at == pytest.approx(
        model.time_to_ready(WeightState.COLD, fresh_chip=True))

    g = recon.gpu_of_pod(p1.pod_id)
    p2 = PodAlloc(fn_id=SPEC.fn_id, sm=4, quota=0.4, batch=8)
    recon.place_pod(p2, g.uuid, now=10.0, cold_start_s=2.5, spec=SPEC)
    assert p2.start_kind == "hot"
    assert p2.ready_at - 10.0 == pytest.approx(model.container_init_s)

    # remove both -> host cache; a re-placement on the same node is warm
    recon.remove_pod(p1.pod_id)
    recon.remove_pod(p2.pod_id)
    recon.release_empty_gpus()
    p3 = PodAlloc(fn_id=SPEC.fn_id, sm=4, quota=0.5, batch=8)
    recon.place_pod(p3, None, now=20.0, cold_start_s=2.5, spec=SPEC)
    assert recon.gpu_of_pod(p3.pod_id).node == g.node  # node slot reused
    assert p3.start_kind == "warm"
    assert p3.ready_at - 20.0 == pytest.approx(model.time_to_ready(
        WeightState.HOST, fresh_chip=True))


def test_placement_mid_transfer_waits_remaining_time():
    """A pod placed while the prewarm fetch is in flight pays only the
    remaining transfer time plus the load — the race the pre-warming
    policy wins."""
    tr = make_tracker()
    recon = Reconfigurator(num_gpus=0, max_gpus=4)
    recon.attach_modelstate(tr)
    done_at = tr.promote(recon.peek_next_node(), SPEC, now=0.0)
    t_place = done_at * 0.5
    pod = PodAlloc(fn_id=SPEC.fn_id, sm=4, quota=0.5, batch=8)
    recon.place_pod(pod, None, now=t_place, cold_start_s=2.5, spec=SPEC)
    assert pod.start_kind == "warm"
    model = tr.cold_model(SPEC, DEFAULT_GPU_TYPE)
    want = model.time_to_ready(WeightState.FETCHING, fresh_chip=True,
                               wait_s=done_at - t_place)
    assert pod.ready_at - t_place == pytest.approx(want)
    assert pod.ready_at - t_place < model.time_to_ready(
        WeightState.COLD, fresh_chip=True)


# --------------------------------------------------- races inside the engine
class ScriptedPolicy:
    """Replays (time, fn) mutation callbacks against the Reconfigurator
    (mirrors test_event_edge_cases.ScriptedPolicy)."""

    def __init__(self, recon, script):
        self.recon = recon
        self.script = sorted(script, key=lambda s: s[0])

    def prewarm(self, spec, expected_rps):
        pass

    def tick(self, now, spec, observed_rps):
        while self.script and self.script[0][0] <= now:
            _, fn = self.script.pop(0)
            fn(self.recon, now)


def test_weight_transfer_races_scale_down():
    """A prewarm transfer is in flight when a scale-down removes every
    pod of the function on that node: the engine must stay conservative
    and the transfer must still complete into the host cache, so the
    NEXT scale-up on the node is warm, not cold."""
    tr = make_tracker()
    recon = Reconfigurator(num_gpus=0, max_gpus=8)
    recon.attach_modelstate(tr)
    first = PodAlloc(fn_id=SPEC.fn_id, sm=4, quota=0.5, batch=8,
                     pod_id="perm")
    recon.place_pod(first, None, now=0.0, cold_start_s=0.0, spec=SPEC)
    node = recon.gpu_of_pod("perm").node

    def promote_other(recon_, now):
        tr.promote("node-7", SPEC, now)      # transfer to an empty node

    def add_pod(recon_, now):
        recon_.place_pod(PodAlloc(fn_id=SPEC.fn_id, sm=4, quota=0.5,
                                  batch=8, pod_id="victim"),
                         None, now=now, cold_start_s=2.5, spec=SPEC)

    def remove_pod(recon_, now):
        recon_.remove_pod("victim")          # racing its own cold start
        recon_.release_empty_gpus()

    pol = ScriptedPolicy(recon, [(1.0, promote_other), (2.0, add_pod),
                                 (3.0, remove_pod)])
    arr = np.sort(np.random.default_rng(3).uniform(0, 15.0, size=200))
    sim = ClusterSimulator(SPEC, pol, recon, arr, SimConfig(duration_s=15.0))
    res = sim.run()
    assert res.n_completed + res.n_dropped == res.n_arrived
    assert "victim" not in sim.runtimes
    # the removed pod demoted its weights into its node's host cache
    victim_node = "node-1"   # second chip -> second node slot
    assert tr.host_cached(victim_node, SPEC.fn_id, now=15.0)
    # the raced transfer still completed into node-7's cache
    assert tr.host_cached("node-7", SPEC.fn_id, now=15.0)
    assert tr.state("node-7", SPEC.fn_id, 15.0) is WeightState.HOST


# ------------------------------------------------------------ keep-warm pool
def _keepwarm_scaler():
    recon = Reconfigurator(num_gpus=0, max_gpus=8)
    recon.attach_modelstate(make_tracker(keep_warm_pods=1))
    scaler = HybridAutoScaler(recon, cfg=AutoScalerConfig(
        cooldown_s=0.0, keep_warm_pods=1))
    return recon, scaler


def test_scale_down_parks_keep_warm_standby():
    recon, scaler = _keepwarm_scaler()
    scaler.prewarm(SPEC, 120.0)
    assert len(recon.pods_of(SPEC.fn_id)) >= 2
    scaler.scale(30.0, SPEC, 1.0)           # collapse demand
    pods = recon.pods_of(SPEC.fn_id)
    standby = [p for p in pods if p.standby]
    active = [p for p in pods if not p.standby]
    assert len(standby) == 1                # exactly the keep-warm budget
    assert active                           # never scales to zero
    assert standby[0].quota == ms.KEEP_WARM_QUOTA
    # standby pods hold no capacity
    assert scaler.capacity(SPEC) == pytest.approx(
        sum(scaler.pod_thpt(SPEC, p) for p in active))
    # ...but their chip stays provisioned (weights are HBM-resident)
    g = recon.gpu_of_pod(standby[0].pod_id)
    assert g is not None
    assert recon.modelstate.gpu_resident(g.uuid, SPEC.fn_id)


def test_standby_reactivation_is_hot_and_instant():
    recon, scaler = _keepwarm_scaler()
    scaler.prewarm(SPEC, 120.0)
    scaler.scale(30.0, SPEC, 1.0)
    standby = [p for p in recon.pods_of(SPEC.fn_id) if p.standby]
    assert standby
    before = recon.modelstate.start_counts()["hot"]
    scaler.scale(31.0, SPEC, 200.0)         # demand returns
    pod = standby[0]
    assert not pod.standby
    assert pod.start_kind == "hot"
    assert pod.quota >= scaler.cfg.min_quota
    assert recon.modelstate.start_counts()["hot"] == before + 1


def test_standby_billed_at_idle_retention_price():
    recon = Reconfigurator(num_gpus=0, max_gpus=4)
    g = recon.add_gpu()
    active = PodAlloc(fn_id="f", sm=4, quota=0.5, batch=8)
    parked = PodAlloc(fn_id="f", sm=4, quota=ms.KEEP_WARM_QUOTA, batch=8,
                      standby=True)
    recon.place_pod(active, g.uuid)
    recon.place_pod(parked, g.uuid)
    meter = CostMeter(idle_retention_factor=0.2)
    usd_rate, frac = meter.rates(recon)
    want_frac = (4 / 8) * 0.5 + 0.2 * (4 / 8)
    assert frac == pytest.approx(want_frac)
    assert usd_rate == pytest.approx(
        want_frac * DEFAULT_GPU_TYPE.price_per_hour / 3600.0)
    # factor 0 parks for free; the active pod still bills
    assert CostMeter(idle_retention_factor=0.0).rates(recon)[1] == \
        pytest.approx((4 / 8) * 0.5)


# ------------------------------------------------------- end-to-end behavior
def test_legacy_goldens_byte_identical_with_passive_tracker():
    """Attaching a tracker whose lifecycle defaults reproduce the old
    constants must leave the serialized RunMetrics BYTE-identical to
    the pre-lifecycle goldens — placement latencies, statistics
    surfacing, everything."""
    for name, policy in (("steady_poisson", "has"),
                         ("steady_poisson", "kserve"),
                         ("steady_poisson", "fast"),
                         ("azure_standard", "has")):
        path = GOLDEN_DIR / f"{name}__{policy}.json"
        if not path.exists():
            pytest.skip("corpus not generated yet")
        scen = get_scenario(name).with_(lifecycle=LifecycleConfig())
        metrics = scen.run(policy=policy, seed=42, duration_s=45.0).metrics
        assert metrics.to_json() == path.read_text(), (name, policy)


def test_prewarm_beats_reactive_on_flash_crowd():
    """Forecast-driven pre-warming on the flash-crowd trace: strictly
    fewer cold starts and lower time-to-ready than the identical
    lifecycle config without pre-warming, and strictly fewer cold
    starts plus a lower SLO violation rate than the reactive legacy
    HAS policy on the same arrivals."""
    prewarm_scen = get_scenario("flash_crowd_prewarm")
    no_prewarm = prewarm_scen.with_(
        name="flash_crowd_no_prewarm",
        lifecycle=dataclasses.replace(prewarm_scen.lifecycle,
                                      prewarm_lead_s=0.0))
    pre = prewarm_scen.run(policy="has", seed=42, duration_s=45.0).metrics
    rea = no_prewarm.run(policy="has", seed=42, duration_s=45.0).metrics
    assert rea.start_kinds["cold"] > 0
    assert pre.start_kinds["cold"] < rea.start_kinds["cold"]
    # pre-warmed starts exist and reach ready faster end to end
    assert pre.start_kinds["warm"] + pre.start_kinds["hot"] > 0
    assert pre.time_to_ready_ms["p99"] < rea.time_to_ready_ms["p99"]
    assert pre.slo_violation_rate["1.5"] <= rea.slo_violation_rate["1.5"]
    # and vs the reactive legacy policy (flat constants, no lifecycle)
    legacy = get_scenario("flash_crowd").run(policy="has", seed=42,
                                             duration_s=45.0).metrics
    assert pre.cold_starts < legacy.cold_starts
    assert pre.slo_violation_rate["1.5"] < legacy.slo_violation_rate["1.5"]


def test_prewarm_golden_pins_fewer_cold_starts_than_reactive_golden():
    """The acceptance pin: the flash_crowd_prewarm golden shows strictly
    fewer cold starts and lower violations than the reactive HAS golden
    on the same arrival process."""
    pre_path = GOLDEN_DIR / "flash_crowd_prewarm__has.json"
    rea_path = GOLDEN_DIR / "flash_crowd__has.json"
    if not (pre_path.exists() and rea_path.exists()):
        pytest.skip("corpus not generated yet")
    pre = RunMetrics.load(pre_path)
    rea = RunMetrics.load(rea_path)
    assert rea.cold_starts > 0
    assert pre.cold_starts < rea.cold_starts
    for mult in ("1.5", "2.0", "2.5"):
        assert pre.slo_violation_rate[mult] <= rea.slo_violation_rate[mult]
    assert pre.slo_violation_rate["1.5"] < rea.slo_violation_rate["1.5"]


def test_lifecycle_metrics_round_trip():
    m = get_scenario("scale_to_zero_lru").run(policy="has", seed=7,
                                              duration_s=45.0).metrics
    assert m.start_kinds is not None
    assert set(m.start_kinds) == {"cold", "warm", "hot"}
    back = RunMetrics.from_json(m.to_json())
    assert back.to_json() == m.to_json()
    assert back.start_kinds == m.start_kinds
    # legacy records still round-trip without the lifecycle fields
    legacy = get_scenario("steady_poisson").run(policy="has", seed=7,
                                                duration_s=30.0).metrics
    assert legacy.start_kinds is None
    assert "start_kinds" not in legacy.to_dict()


def test_nonfinite_time_to_ready_round_trips():
    """to_dict serializes non-finite floats as null (RFC 8259); from_dict
    must symmetrize the OPTIONAL float dicts too, or a loaded golden
    with an inf time-to-ready percentile compares None != inf and every
    subsequent golden check flaps."""
    import dataclasses as _dc
    m = get_scenario("scale_to_zero_lru").run(policy="has", seed=7,
                                              duration_s=45.0).metrics
    broken = _dc.replace(m, time_to_ready_ms={"p50": 12.5,
                                              "p99": float("inf")})
    back = RunMetrics.from_json(broken.to_json())
    assert back.time_to_ready_ms == {"p50": 12.5, "p99": float("inf")}
    assert back.to_json() == broken.to_json()
    assert not broken.diff(back)


def test_baselines_get_physics_but_no_cache():
    """On a lifecycle scenario the baselines run the same derived
    start-latency physics but with caching/keep-warm/pre-warm stripped
    — their tracker is active yet cache-less."""
    scen = get_scenario("scale_to_zero_lru")
    out = scen.run(policy="kserve", seed=42, duration_s=45.0)
    tracker = out.simulator.recon.modelstate
    assert tracker is not None and not tracker.is_passive
    assert tracker.cfg.derive_from_physics
    assert tracker.cfg.host_cache_gb == 0.0
    assert tracker.cfg.keep_warm_pods == 0
    assert out.metrics.start_kinds is not None


def test_scaler_adopts_lifecycle_knobs_from_tracker():
    """Any HybridAutoScaler built against a cluster with an active
    tracker — including custom policy_factory hooks that know nothing
    about lifecycles — honors the tracker's keep-warm/pre-warm knobs;
    explicit config values still win."""
    recon = Reconfigurator(num_gpus=0, max_gpus=4)
    recon.attach_modelstate(make_tracker(keep_warm_pods=2,
                                         prewarm_lead_s=7.0))
    adopted = HybridAutoScaler(recon)
    assert adopted.cfg.keep_warm_pods == 2
    assert adopted.cfg.prewarm_lead_s == 7.0
    explicit = HybridAutoScaler(recon, cfg=AutoScalerConfig(
        keep_warm_pods=1))
    assert explicit.cfg.keep_warm_pods == 1      # explicit beats adopted
    assert explicit.cfg.prewarm_lead_s == 7.0    # unset still adopted
    # no tracker: defaults untouched
    legacy = HybridAutoScaler(Reconfigurator(num_gpus=0, max_gpus=4))
    assert legacy.cfg.keep_warm_pods == 0
    assert legacy.cfg.prewarm_lead_s == 0.0


def test_placement_prefers_weight_affine_chip_with_room():
    """Used-chip selection ranks weight affinity only among chips that
    can actually host a pod: a full chip holding the weights must not
    dead-end the used-GPU path into a fresh-chip provision."""
    recon = Reconfigurator(num_gpus=0, max_gpus=8)
    recon.attach_modelstate(make_tracker())
    scaler = HybridAutoScaler(recon)
    # chip A: full (8 slices, quota 1.0) and weight-affine
    a = PodAlloc(fn_id=SPEC.fn_id, sm=8, quota=1.0, batch=8)
    recon.place_pod(a, None, now=0.0, cold_start_s=2.5, spec=SPEC)
    # chip B: a different function's half-empty chip, no affinity
    b = PodAlloc(fn_id="fn-other", sm=4, quota=0.5, batch=8)
    recon.place_pod(b, None, now=0.0, cold_start_s=0.0)
    scaler._ensure_capacity_model(SPEC)
    n_gpus = len(recon.gpus)
    delta, acts = scaler._horizontal_up_used(5.0, SPEC, 1.0)
    assert acts, "used-GPU path dead-ended despite a chip with room"
    assert len(recon.gpus) == n_gpus   # no fresh chip was provisioned
    host = recon.gpu_of_pod(acts[0].pod_id)
    assert host is not None and host.uuid != recon.gpu_of_pod(a.pod_id).uuid


# --------------------------------------------------- CostMeter deprecation
def test_gpu_price_deprecation_warns_exactly_once():
    """The deprecated module constant warns on first access only (a hot
    loop reading it must not flood the warning stream)."""
    from repro.core import cost as cost_mod
    cost_mod._reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        v1 = cost_mod.GPU_PRICE_PER_HOUR
        v2 = cost_mod.GPU_PRICE_PER_HOUR
        v3 = cost_mod.GPU_PRICE_PER_HOUR
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    assert v1 == v2 == v3 == DEFAULT_GPU_TYPE.price_per_hour


def test_deprecated_and_new_accounting_agree_on_reference_trace():
    """On an all-reference fleet the legacy flat-price accounting
    (gpu_seconds x GPU_PRICE_PER_HOUR) must equal the per-type meter."""
    from repro.core import cost as cost_mod
    cost_mod._reset_deprecation_warnings()
    out = get_scenario("steady_poisson").run(policy="has", seed=3,
                                             duration_s=30.0)
    m = out.metrics
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        price = cost_mod.GPU_PRICE_PER_HOUR
    assert m.cost_usd == pytest.approx(m.gpu_seconds * price / 3600.0,
                                       rel=1e-12)
    assert m.cost_usd > 0
