"""RaPP feature-extraction and predictor tests."""
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.rapp import dataset as D, features as F, predictor as P


def test_graph_extraction_all_archs():
    for name in ["olmo-1b", "dbrx-132b", "mamba2-2.7b", "jamba-v0.1-52b",
                 "whisper-medium", "llava-next-34b"]:
        g = F.extract_graph(ARCHS[name], batch=4)
        assert len(g.nodes) > 10, name
        assert g.total_flops > 0, name
        assert len(g.edges) > 0, name
        # moe archs should show gather-class ops (top_k routing)
        classes = {n.op_class for n in g.nodes}
        assert F.OP_CLASSES.index("dot") in classes


def test_tensorize_shapes():
    from repro.core.perf_model import FnSpec
    g = F.extract_graph(ARCHS["olmo-1b"], batch=8)
    rng = np.random.default_rng(0)
    t = F.tensorize(g, FnSpec(ARCHS["olmo-1b"]), 8, 4, 0.5, rng)
    assert t["node_feats"].shape == (F.MAX_NODES, F.NODE_F)
    assert t["adj"].shape == (F.MAX_NODES, F.MAX_NODES)
    assert t["global"].shape == (F.GLOBAL_F,)
    assert np.isfinite(t["node_feats"]).all()
    assert np.isfinite(t["global"]).all()


def test_dippm_static_features_zero_runtime():
    from repro.core.perf_model import FnSpec
    g = F.extract_graph(ARCHS["olmo-1b"], batch=8)
    rng = np.random.default_rng(0)
    t = F.tensorize(g, FnSpec(ARCHS["olmo-1b"]), 8, 4, 0.5, rng,
                    with_runtime=False)
    assert (t["node_feats"][:, F.NODE_STATIC_F:] == 0).all()
    assert (t["global"][F.GLOBAL_STATIC_F:] == 0).all()


def test_predictor_forward():
    import jax
    params = P.init_params(jax.random.PRNGKey(0))
    from repro.core.perf_model import FnSpec
    g = F.extract_graph(ARCHS["olmo-1b"], batch=8)
    rng = np.random.default_rng(0)
    t = F.tensorize(g, FnSpec(ARCHS["olmo-1b"]), 8, 4, 0.5, rng)
    out = P.forward_one(params, t["node_feats"], t["adj"], t["mask"],
                        t["global"])
    assert np.isfinite(float(out))


@pytest.mark.slow
def test_rapp_learns_better_than_random():
    """Tiny training run: MAPE must drop well below the untrained level."""
    from repro.core.rapp import train as T
    corpus = [ARCHS["olmo-1b"], ARCHS["qwen2.5-3b"]]
    ds = D.generate(corpus, batches=(1, 8), samples_per_graph=10, seed=1)
    tr, va, te = D.split(ds, holdout_archs=())
    params = T.train(tr, va, cfg=T.TrainConfig(steps=200, log_every=1000),
                     verbose=False)
    mape = T.evaluate(params, tr)
    assert mape < 40.0, f"train MAPE {mape}"
