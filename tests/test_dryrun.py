"""Dry-run machinery tests.

The full 512-device sweep runs via ``python -m repro.launch.dryrun``;
here we validate the HLO analyzer's exactness and one real combo through
a subprocess (so the XLA device-count flag does not leak into this test
process, which must keep seeing 1 device).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as ha

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_analyzer_scan_equals_unroll():
    D, L, B = 256, 8, 4
    w = jax.ShapeDtypeStruct((L, D, D), jnp.bfloat16)
    x = jax.ShapeDtypeStruct((B, D), jnp.bfloat16)

    def scanned(w, x):
        def f(h, wl):
            return h @ wl, None
        h, _ = jax.lax.scan(f, x, w)
        return h

    def unrolled(w, x):
        h = x
        for i in range(L):
            h = h @ w[i]
        return h

    a_scan = ha.analyze(jax.jit(scanned).lower(w, x).compile().as_text())
    a_unroll = ha.analyze(jax.jit(unrolled).lower(w, x).compile().as_text())
    analytic = 2.0 * B * D * D * L
    assert a_scan.flops == pytest.approx(analytic, rel=1e-6)
    assert a_unroll.flops == pytest.approx(analytic, rel=1e-6)
    assert not a_scan.unknown_trip_whiles


def test_analyzer_collectives():
    from repro.launch.mesh import mesh_kwargs
    mesh = jax.make_mesh((1,), ("x",), **mesh_kwargs(1))
    # single-device: no collectives expected
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    a = ha.analyze(jax.jit(lambda t: t @ t).lower(x).compile().as_text())
    assert a.collective_bytes == 0.0
    assert a.flops == pytest.approx(2 * 64**3, rel=1e-6)


@pytest.mark.slow
def test_dryrun_one_combo_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "olmo-1b",
         "--shape", "decode_32k", "--out", "/tmp/dryrun_test"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "ALL DRY-RUN COMBOS PASSED" in out.stdout


def test_device_count_not_polluted():
    assert len(jax.devices()) == 1
