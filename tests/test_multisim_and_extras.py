"""Tests for multi-function co-location, low-precision optimizer moments,
and the real-time token scheduler."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core import (FnSpec, HybridAutoScaler, Reconfigurator, SimConfig)
from repro.core.multisim import MultiFunctionSimulator
from repro.workloads import standard_workload


def test_multisim_shared_cluster():
    specs = [FnSpec(ARCHS["olmo-1b"]), FnSpec(ARCHS["qwen2.5-3b"])]
    recon = Reconfigurator(num_gpus=0, max_gpus=16)
    policies, arrivals = {}, {}
    for i, spec in enumerate(specs):
        pol = HybridAutoScaler(recon)
        pol.prewarm(spec, 10.0)
        policies[spec.fn_id] = pol
        arrivals[spec.fn_id] = standard_workload(30.0, 10.0, seed=i)
    sim = MultiFunctionSimulator(specs, policies, recon, arrivals,
                                 SimConfig(duration_s=30.0))
    res = sim.run()
    assert set(res.per_fn) == {s.fn_id for s in specs}
    for fid, r in res.per_fn.items():
        assert r.n_completed + r.n_dropped == r.n_arrived
        assert r.n_completed > 0.9 * r.n_arrived, fid
    assert res.cluster_cost_usd > 0
    assert recon.invariant_ok()
    # co-location actually happened: at least one chip hosts 2+ functions
    co = any(len({p.fn_id for p in g.pods}) >= 2
             for g in recon.used_gpus())
    assert co or len(recon.used_gpus()) <= 2


def test_bf16_optimizer_moments_halve_state_and_still_learn():
    from repro import models
    from repro.models import CallOpts
    from repro.training import data as data_mod, optimizer as opt_mod, steps
    cfg = reduced(ARCHS["olmo-1b"])
    params = models.init_params(jax.random.PRNGKey(0), cfg)
    s32 = opt_mod.init_opt_state(params, "float32")
    s16 = opt_mod.init_opt_state(params, "bfloat16")
    b32 = sum(x.nbytes for x in jax.tree.leaves(s32.mu))
    b16 = sum(x.nbytes for x in jax.tree.leaves(s16.mu))
    assert b16 * 2 == b32
    adamw = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30,
                                moment_dtype="bfloat16")
    step = jax.jit(steps.make_train_step(cfg, adamw, CallOpts()))
    ds = data_mod.SyntheticLMData(cfg.vocab_size)
    state = s16
    losses = []
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i, 8, 64).items()}
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3  # still learns
    assert jax.tree.leaves(state.mu)[0].dtype == jnp.bfloat16


def test_gpu_client_realtime_pacing():
    """A q=0.5 pod must take ~2x the owned time in wall clock."""
    from repro.core.scheduler import HASGPUScheduler
    from repro.core.vgpu import PodAlloc, VirtualGPU
    vgpu = VirtualGPU("G", window_ms=20.0)
    pod = PodAlloc(fn_id="f", sm=8, quota=0.5, batch=1)
    vgpu.place(pod)
    client = HASGPUScheduler().client_for(vgpu, pod.pod_id)
    t0 = time.monotonic()
    total = 0.0
    for _ in range(10):
        client.acquire(0.01)
        total += 0.01
    wall = time.monotonic() - t0
    assert wall >= total / 0.5 - 0.03  # rate-limited to the quota
    assert wall < total / 0.5 + 0.5


def test_quota_rewrite_takes_effect_next_window():
    from repro.core.scheduler import TokenLedger
    from repro.core.vgpu import PodAlloc, VirtualGPU
    vgpu = VirtualGPU("G", window_ms=100.0)
    pod = PodAlloc(fn_id="f", sm=8, quota=0.2, batch=1)
    vgpu.place(pod)
    ledger = TokenLedger(vgpu)
    t1 = ledger.acquire(pod.pod_id, 0.05, 0.0)   # 50ms work at q=0.2
    vgpu.set_quota(pod.pod_id, 1.0)              # vertical scale-up
    t2 = ledger.acquire(pod.pod_id, 0.05, t1)
    # after the rewrite the same work completes much faster
    assert (t2 - t1) < (t1 - 0.0)
