"""Hypothesis-free invariant tests for HybridAutoScaler (Algorithm 1).

These mirror the property-based suite in test_core_properties.py but run
on fixed seeded scenarios, so they execute even when the optional
`hypothesis` dependency is absent.

Invariants:
  * retained capacity never scaled below r_min;
  * every pod's quota stays in [min_quota, 1];
  * scale-downs respect the cooldown;
  * at least one pod survives any scale-down sequence (no scale-to-zero).
"""
import numpy as np

from repro.configs import ARCHS
from repro.core import FnSpec, HybridAutoScaler, Reconfigurator

SPEC = FnSpec(ARCHS["olmo-1b"])


def _demand_sequence(seed: int, n: int = 120):
    """A bursty, collapsing demand trace exercising both scale directions."""
    rng = np.random.default_rng(seed)
    level = 40.0
    out = []
    for i in range(n):
        if rng.uniform() < 0.08:
            level = rng.uniform(0.0, 300.0)  # regime switch
        out.append(max(0.0, level + rng.normal(0.0, 5.0)))
    return out


def _drive(seed: int):
    """Run the scaler over a demand sequence at 1 s ticks, recording the
    cluster state after every step."""
    recon = Reconfigurator(num_gpus=0, max_gpus=64)
    scaler = HybridAutoScaler(recon)
    history = []
    for i, rps in enumerate(_demand_sequence(seed)):
        now = float(i)
        actions = scaler.scale(now, SPEC, rps)
        pods = recon.pods_of(SPEC.fn_id)
        history.append((now, rps, actions, list(pods),
                        scaler.capacity(SPEC)))
        assert recon.invariant_ok()
    return recon, scaler, history


def test_capacity_never_below_r_min():
    for seed in (0, 1, 2):
        _, scaler, history = _drive(seed)
        r_min = scaler.cfg.r_min
        for now, rps, actions, pods, cap in history:
            assert cap >= r_min - 1e-6, (now, rps, cap)


def test_pod_quotas_within_bounds():
    for seed in (0, 1, 2):
        _, scaler, history = _drive(seed)
        lo = scaler.cfg.min_quota
        for now, _, _, pods, _ in history:
            for p in pods:
                assert lo - 1e-9 <= p.quota <= 1.0 + 1e-9, (now, p.quota)


def test_scale_downs_respect_cooldown():
    for seed in (0, 1, 2):
        _, scaler, history = _drive(seed)
        cooldown = scaler.cfg.cooldown_s
        down_times = [now for now, _, actions, _, _ in history
                      if any(a.kind in ("vdown", "hdown") for a in actions)]
        for a, b in zip(down_times, down_times[1:]):
            assert b - a >= cooldown - 1e-9, (a, b)


def test_at_least_one_pod_survives_collapse():
    recon = Reconfigurator(num_gpus=0, max_gpus=64)
    scaler = HybridAutoScaler(recon)
    # scale up hard, then collapse demand to zero for a long time
    for i in range(5):
        scaler.scale(float(i), SPEC, 250.0)
    assert len(recon.pods_of(SPEC.fn_id)) >= 1
    t = 100.0
    for i in range(30):  # every step beyond the cooldown
        scaler.scale(t + i * (scaler.cfg.cooldown_s + 1.0), SPEC, 0.0)
        assert len(recon.pods_of(SPEC.fn_id)) >= 1
        assert recon.invariant_ok()
    # fully collapsed yet still serving floor capacity
    assert scaler.capacity(SPEC) >= scaler.cfg.r_min - 1e-6
