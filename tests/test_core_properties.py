"""Property-based tests (hypothesis) for the HAS-GPU core invariants.

hypothesis is an optional dev dependency (requirements-dev.txt); without
it this module skips instead of failing the whole suite at collection.
Hypothesis-free versions of the autoscaler invariants live in
tests/test_autoscaler_invariants.py and always run.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import ARCHS
from repro.core import (FnSpec, HybridAutoScaler, KalmanPredictor, PodAlloc,
                        Reconfigurator, TOTAL_SLICES, VirtualGPU, latency,
                        throughput)
from repro.core.scheduler import TokenLedger

SPEC = FnSpec(ARCHS["olmo-1b"])


# ---------------------------------------------------------------- vGPU
@given(st.lists(st.tuples(st.integers(1, 8),
                          st.floats(0.1, 1.0)), min_size=1, max_size=12))
@settings(max_examples=100, deadline=None)
def test_vgpu_placement_never_oversubscribes(allocs):
    """Whatever placements succeed, slices<=8 and per-partition quota<=1."""
    g = VirtualGPU("G")
    for sm, q in allocs:
        pod = PodAlloc(fn_id="f", sm=sm, quota=round(q, 2), batch=1)
        if g.can_place(pod.sm, pod.quota):
            try:
                g.place(pod)
            except RuntimeError:
                pass
    assert g.invariant_ok()
    assert 0.0 <= g.hgo <= 1.0 + 1e-9


@given(st.integers(1, 8), st.floats(0.1, 1.0), st.floats(0.1, 1.0))
@settings(max_examples=50, deadline=None)
def test_vertical_scaling_respects_partition(sm, q1, q2):
    g = VirtualGPU("G")
    p1 = PodAlloc(fn_id="f", sm=sm, quota=round(q1, 2), batch=1)
    g.place(p1)
    new_q = round(q2, 2)
    if new_q <= 1.0:
        g.set_quota(p1.pod_id, new_q)
        assert g.invariant_ok()
    part = g.partition_of(p1.pod_id)
    assert part.quota_used <= 1.0 + 1e-9


def test_sm_alignment_no_fragmentation():
    """Same-size pods share a partition instead of fragmenting slices."""
    g = VirtualGPU("G")
    g.place(PodAlloc(fn_id="a", sm=4, quota=0.5, batch=1))
    g.place(PodAlloc(fn_id="b", sm=4, quota=0.4, batch=1))
    assert len(g.partitions) == 1 and g.slices_used == 4
    g.place(PodAlloc(fn_id="c", sm=4, quota=0.5, batch=1))
    assert g.slices_used == 8 and len(g.partitions) == 2


# ---------------------------------------------------------------- latency
@given(st.integers(1, 32), st.integers(1, 8),
       st.floats(0.1, 1.0), st.floats(0.1, 1.0))
@settings(max_examples=80, deadline=None)
def test_latency_monotonic_in_quota_and_sm(batch, sm, qa, qb):
    qa, qb = round(qa, 2), round(qb, 2)
    la = latency(SPEC, batch, sm, qa)
    lb = latency(SPEC, batch, sm, qb)
    if qa < qb:
        assert la >= lb - 1e-9  # more quota never slower
    if sm < TOTAL_SLICES:
        assert latency(SPEC, batch, sm + 1, qa) <= \
            latency(SPEC, batch, sm, qa) + 1e-9


@given(st.integers(1, 32))
@settings(max_examples=30, deadline=None)
def test_full_allocation_equals_exec_time(batch):
    from repro.core.perf_model import exec_time
    assert latency(SPEC, batch, TOTAL_SLICES, 1.0) == \
        pytest.approx(exec_time(SPEC, batch, TOTAL_SLICES))


# ---------------------------------------------------------------- ledger
@given(st.floats(0.1, 1.0), st.lists(st.floats(1e-4, 0.2), min_size=1,
                                     max_size=10))
@settings(max_examples=60, deadline=None)
def test_token_ledger_rate_bound(quota, costs):
    """Over any horizon, granted execution time <= quota * elapsed + W."""
    quota = round(quota, 2)
    g = VirtualGPU("G", window_ms=100.0)
    pod = PodAlloc(fn_id="f", sm=8, quota=quota, batch=1)
    g.place(pod)
    ledger = TokenLedger(g)
    t = 0.0
    total_cost = sum(costs)
    for c in costs:
        t = ledger.acquire(pod.pod_id, c, t)
    # wall time must be at least total_cost / quota - one window of slack
    assert t >= total_cost / quota - ledger.window_s - 1e-9
    # and the schedule is feasible (can't finish faster than the work)
    assert t >= total_cost - 1e-9


# ---------------------------------------------------------------- kalman
@given(st.floats(0.0, 500.0))
@settings(max_examples=30, deadline=None)
def test_kalman_converges_to_constant(level):
    k = KalmanPredictor()
    for _ in range(60):
        pred = k.update(level)
    assert pred == pytest.approx(level, rel=0.02, abs=0.5)


# ---------------------------------------------------------------- autoscaler
@given(st.floats(5.0, 300.0), st.integers(0, 3))
@settings(max_examples=25, deadline=None)
def test_autoscaler_reaches_capacity_and_keeps_invariants(rps, seed):
    recon = Reconfigurator(num_gpus=0, max_gpus=64)
    scaler = HybridAutoScaler(recon)
    for i in range(12):
        scaler.scale(float(i) * 31.0, SPEC, rps)  # beyond cooldown each time
        assert recon.invariant_ok()
    cap = scaler.capacity(SPEC)
    assert cap * scaler.cfg.alpha >= rps * 0.95  # capacity covers demand
    # at least one pod always retained
    assert len(recon.pods_of(SPEC.fn_id)) >= 1


@given(st.floats(300.0, 800.0))
@settings(max_examples=15, deadline=None)
def test_autoscaler_scales_down_after_peak(rps):
    recon = Reconfigurator(num_gpus=0, max_gpus=64)
    scaler = HybridAutoScaler(recon)
    for i in range(6):
        scaler.scale(float(i), SPEC, rps)
    cap_peak = scaler.capacity(SPEC)
    n_peak = len(recon.pods_of(SPEC.fn_id))
    t = 1000.0
    for i in range(10):
        scaler.scale(t + i * 40.0, SPEC, 1.0)  # demand collapses
    cap_end = scaler.capacity(SPEC)
    n_end = len(recon.pods_of(SPEC.fn_id))
    # capacity shrinks unless already at the single-pod SLO floor
    assert cap_end < cap_peak or n_peak == 1
    assert n_end <= n_peak and n_end >= 1
    assert recon.invariant_ok()
