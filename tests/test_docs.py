"""Docs drift gates, run in tier-1 (not only in the CI docs job):

  * docs/scenarios.md must document exactly the registered scenarios
    (its ``## `` headings are compared to the registry by name);
  * every intra-repo Markdown link in README.md / docs/*.md resolves
    (tools/check_links.py);
  * the designated public APIs stay documented
    (tools/check_docstrings.py).
"""
import dataclasses
import pathlib
import re
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

import check_docstrings  # noqa: E402
import check_links  # noqa: E402

from repro.workloads.scenarios import get_scenario, scenario_names  # noqa: E402

SCENARIOS_MD = REPO / "docs" / "scenarios.md"
ARCHITECTURE_MD = REPO / "docs" / "architecture.md"


def documented_scenarios():
    """The ``## <name>`` headings of docs/scenarios.md, in file order."""
    return re.findall(r"^## +(\S+) *$", SCENARIOS_MD.read_text(),
                      flags=re.M)


def test_docs_exist_and_are_linked_from_readme():
    assert SCENARIOS_MD.exists() and ARCHITECTURE_MD.exists()
    readme = (REPO / "README.md").read_text()
    assert "docs/architecture.md" in readme
    assert "docs/scenarios.md" in readme


def test_scenarios_doc_matches_registry_exactly():
    """The doc's heading set == the registry's name set: a scenario
    cannot be added, renamed, or removed without updating the page."""
    documented = documented_scenarios()
    assert len(documented) == len(set(documented)), "duplicate headings"
    assert set(documented) == set(scenario_names()), (
        f"docs/scenarios.md drifted from the registry:\n"
        f"  undocumented: {sorted(set(scenario_names()) - set(documented))}\n"
        f"  stale:        {sorted(set(documented) - set(scenario_names()))}")


def test_scenarios_doc_mentions_each_fleet():
    """Heterogeneous scenarios must state their fleet in the doc."""
    text = SCENARIOS_MD.read_text()
    for name in scenario_names():
        scen = get_scenario(name)
        if scen.fleet:
            for entry, _cap in scen.fleet:
                # fleet entries are registry names (str) or GPUType
                # instances (spot variants live outside the registry)
                type_name = getattr(entry, "name", entry)
                assert type_name in text, (
                    f"{name}: fleet type {type_name!r} not mentioned in "
                    f"docs/scenarios.md")


def test_full_replay_doc_drift():
    """docs/scenarios.md must document the ``--full`` multi-day Azure
    replay and name the pieces it is built from (the vectorized trace
    builders and the bench entry the run lands in)."""
    from repro.workloads.azure import replay_workload  # noqa: F401

    text = SCENARIOS_MD.read_text()
    assert "### The `--full` replay" in text
    section = text.split("### The `--full` replay", 1)[1]
    section = section.split("\n## ", 1)[0]
    for needle in ("replay_workload", "rate_series_fast",
                   "arrivals_fast", "engine_wide_replay",
                   "bench_engine --full", "streaming"):
        assert needle in section, (
            f"{needle!r} missing from the --full replay doc")


def test_cold_start_lifecycle_doc_drift():
    """architecture.md's "life of a cold start" section must exist and
    stay in sync with the code: every registered device type appears in
    its tier-latency table (each type has a distinct host->HBM
    bandwidth) and every weight-residency tier is named."""
    from repro.configs.gpus import GPU_TYPES
    from repro.core.modelstate import WeightState

    text = ARCHITECTURE_MD.read_text()
    assert "## The life of a cold start" in text
    section = text.split("## The life of a cold start", 1)[1]
    section = section.split("\n## ", 1)[0]
    for name, t in GPU_TYPES.items():
        if name == "default":
            continue   # alias of v5e
        assert f"`{name}`" in section, (
            f"GPU type {name!r} missing from the cold-start tier table")
    for tier in WeightState:
        assert tier.name in section, (
            f"weight tier {tier.name} not described in the cold-start doc")


def test_fault_lifecycle_doc_drift():
    """architecture.md's "life of a fault" section must exist and stay
    in sync with the code: every FaultModel fault kind (counter key),
    every resilience mechanism's tripwire knob, and the surfaced
    metrics fields are all named in the walkthrough."""
    from repro.core.faults import FaultModel, ResilienceConfig

    text = ARCHITECTURE_MD.read_text()
    assert "## The life of a fault" in text
    section = text.split("## The life of a fault", 1)[1]
    section = section.split("\n## ", 1)[0]
    # one rate knob per fault kind — each kind must be walked through
    for knob in ("chip_failure_rate_per_hour", "straggler_rate_per_hour",
                 "cache_loss_rate_per_hour", "blackout_rate_per_hour"):
        assert knob in dataclasses.asdict(FaultModel()), knob
    for kind in ("chip hard failure", "straggler", "host-cache loss",
                 "blackout"):
        assert kind in section, (
            f"fault kind {kind!r} missing from the fault walkthrough")
    # the three resilience mechanisms, by their configuring knob
    for knob in ("max_retries", "quarantine_ratio", "headroom"):
        assert knob in dataclasses.asdict(ResilienceConfig()) or any(
            knob in k for k in dataclasses.asdict(ResilienceConfig())), knob
        assert knob in section, (
            f"resilience knob {knob!r} missing from the fault walkthrough")
    # the surfaced accounting
    for needle in ("availability", "mttr_s", "shed", "killed", "aged",
                   "QUAR_LIFT", "core/faults.py", "tests/test_faults.py"):
        assert needle in section, (
            f"{needle!r} missing from the fault walkthrough")


def test_wide_engine_doc_drift():
    """architecture.md's "The wide engine" section must exist and name
    the load-bearing pieces of the PR 9 rewrite: the struct-of-arrays
    sources, the frozen scalar reference, the streaming-metrics
    accumulator and its knobs, the bench gate, and the three test
    suites pinning it."""
    from repro.core.engine_scalar import ScalarEventEngine  # noqa: F401
    from repro.core.metrics import STREAM_EXACT_LIMIT  # noqa: F401

    text = ARCHITECTURE_MD.read_text()
    assert "## The wide engine" in text
    section = text.split("## The wide engine", 1)[1]
    section = section.split("\n## ", 1)[0]
    for needle in ("struct-of-arrays", "sweep", "heap",
                   "engine_scalar", "stream_metrics", "rng_isolation",
                   "StreamingQuantiles", "STREAM_EXACT_LIMIT",
                   "n_used_gpus", "_THPT_CACHE_MAX", "azure_wide",
                   "benchmarks/bench_engine.py",
                   "benchmarks/ref_engine.json",
                   "tests/test_engine_parity.py",
                   "tests/test_streaming_metrics.py",
                   "tests/test_wide_engine.py",
                   # PR 10: the batched decide path and its bugfixes
                   "window_counts", "BatchedKalman", "SweepDecider",
                   "batched_policy", "sterile-down", "sweep_speedup",
                   "OBS_WINDOW_S", "normalization",
                   "_reclaim_scheduled", "drop_listeners", "--full",
                   "tests/test_batched_sweep.py"):
        assert needle in section, (
            f"{needle!r} missing from the wide-engine section")
    assert (REPO / "benchmarks" / "ref_engine.json").exists(), (
        "benchmarks/ref_engine.json (the CI gate's committed reference) "
        "is missing; regenerate with: python -m benchmarks.bench_engine "
        "--smoke --update-ref")


def test_calibration_doc_drift():
    """architecture.md's "Calibrating the physics" section must exist
    and name the load-bearing pieces of the sim-to-silicon loop: the
    CLI entry point, the report schema, the committed CPU reference the
    CI gate compares against, and both consumers of a table."""
    from repro.profiling import SCHEMA
    REF_PATH = "benchmarks/ref_profile_cpu.json"

    text = ARCHITECTURE_MD.read_text()
    assert "## Calibrating the physics" in text
    section = text.split("## Calibrating the physics", 1)[1]
    section = section.split("\n## ", 1)[0]
    for needle in ("benchmarks.profile_stack", SCHEMA, REF_PATH,
                   "CalibrationTable", "calibration=...", "--update-ref"):
        assert needle in section, (
            f"{needle!r} missing from the calibration section")
    assert (REPO / REF_PATH).exists(), (
        f"{REF_PATH} (the CI gate's committed reference) is missing; "
        f"regenerate with: python -m benchmarks.profile_stack --smoke "
        f"--update-ref")
    readme = (REPO / "README.md").read_text()
    assert "calibrating-the-physics" in readme.lower() or \
        "Calibrating the physics" in readme, (
        "README must point at the calibration section")


def test_no_broken_intra_repo_links():
    failures = check_links.run()
    assert not failures, "broken links:\n  " + "\n  ".join(failures)


def test_designated_public_apis_documented():
    failures = check_docstrings.run()
    assert not failures, ("undocumented public symbols:\n  "
                          + "\n  ".join(failures))
