"""Heterogeneous-fleet unit tests: GPUType physics, per-type capacity
tables, fleet-aware Reconfigurator topology, per-type cost accounting,
FFD placement, and the cross-type dollar-minimizing config search.

The homogeneous-equivalence END-TO-END pins live in
tests/test_goldens.py (byte-identical RunMetrics); these tests pin the
component-level invariants the refactor rests on.
"""
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.gpus import (DEFAULT_GPU_TYPE, GPU_TYPES, GPUType,
                                get_gpu_type)
from repro.core import perf_model
from repro.core.capacity import CapacityTable
from repro.core.cost import CostMeter
from repro.core.perf_model import FnSpec
from repro.core.reconfigurator import Reconfigurator
from repro.core.scheduler import FleetPlacer
from repro.core.vgpu import PodAlloc, VirtualGPU

SPEC = FnSpec(ARCHS["olmo-1b"])
H100 = GPU_TYPES["h100"]
A10G = GPU_TYPES["a10g"]
T4 = GPU_TYPES["t4"]
MIX = (("a10g", 4), ("a100", 2), ("t4", 4))


# ---------------------------------------------------------------- registry
def test_registry_and_alias():
    assert get_gpu_type("default") is DEFAULT_GPU_TYPE
    assert get_gpu_type("v5e") is DEFAULT_GPU_TYPE
    assert get_gpu_type(H100) is H100
    with pytest.raises(KeyError):
        get_gpu_type("dgx-spark")


def test_default_type_is_the_legacy_constants():
    assert DEFAULT_GPU_TYPE.peak_flops == perf_model.PEAK_FLOPS
    assert DEFAULT_GPU_TYPE.hbm_bw == perf_model.HBM_BW
    assert DEFAULT_GPU_TYPE.sm_total == 8
    assert DEFAULT_GPU_TYPE.price_per_hour == 2.48


# ---------------------------------------------------------------- physics
def test_default_gpu_physics_bitwise():
    """exec_time/latency with an explicit default gpu argument must be
    bitwise the no-argument legacy value."""
    for b in (1, 8, 32):
        for sm in (1, 4, 8):
            assert perf_model.exec_time(SPEC, b, sm) == \
                perf_model.exec_time(SPEC, b, sm, DEFAULT_GPU_TYPE)
            assert perf_model.latency(SPEC, b, sm, 0.7) == \
                perf_model.latency(SPEC, b, sm, 0.7, gpu=DEFAULT_GPU_TYPE)


def test_lattice_bitwise_per_type():
    """The vectorized lattice equals the scalar physics on EVERY device
    type, not just the reference."""
    quotas = perf_model.quota_grid(0.1)
    for gpu in (H100, A10G, T4):
        sms = np.arange(1, gpu.sm_total + 1)
        tab = perf_model.latency_lattice(SPEC, 8, sms, quotas, gpu=gpu)
        for i, sm in enumerate(sms):
            for j, q in enumerate(quotas):
                assert tab[i, j] == perf_model.latency(
                    SPEC, 8, int(sm), float(q), gpu=gpu), (gpu.name, sm, q)


def test_faster_chip_is_faster_at_scale():
    """At saturating batch, a whole premium chip beats a whole cheap
    chip (sanity of the capability ladder)."""
    fast = perf_model.exec_time(SPEC, 32, H100.sm_total, H100)
    slow = perf_model.exec_time(SPEC, 32, T4.sm_total, T4)
    assert fast < slow


def test_slo_baseline_is_device_independent():
    """The SLO anchor must not move with the serving device."""
    base = perf_model.slo_baseline(SPEC, 8)
    # nothing in the signature takes a gpu; pin the reference value
    assert base == perf_model.exec_time(SPEC, 8, 8)


# ---------------------------------------------------------------- capacity
def test_single_type_best_config_over_matches_per_type():
    table = CapacityTable()
    for gpu in (DEFAULT_GPU_TYPE, H100, T4):
        for target in (0.5, 25.0, 400.0):
            got = table.best_config_over(SPEC, target, [gpu])
            want = (gpu,) + table.most_efficient_config(SPEC, target,
                                                        gpu=gpu)
            assert got == want, (gpu.name, target)


def test_scalar_reference_matches_table_per_type():
    table = CapacityTable()
    for gpu in (H100, A10G, T4):
        for target in (0.5, 25.0, 400.0):
            assert table.most_efficient_config(SPEC, target, gpu=gpu) == \
                perf_model.most_efficient_config(SPEC, target, gpu=gpu)


def test_cross_type_search_minimizes_dollars():
    """Whatever the cross-type search returns is at least as cheap (in
    $/s) as every single-type optimum that meets the target."""
    table = CapacityTable()
    types = [get_gpu_type(n) for n, _ in MIX]
    target = 25.0
    gpu, b, sm, q = table.best_config_over(SPEC, target, types)
    chosen_cost = perf_model.cost_rate(sm, q, gpu)
    for t in types:
        cand = table.most_efficient_config(SPEC, target, gpu=t)
        cb, csm, cq = cand
        lat = table.lat(SPEC, cb, csm, cq, t)
        if cb / lat >= target:   # this type can actually meet the target
            assert chosen_cost <= perf_model.cost_rate(csm, cq, t) + 1e-15


def test_min_quota_for_slo_per_type():
    table = CapacityTable()
    # premium meets the SLO at the narrowest slice; spot t4 never does
    assert table.min_quota_for_slo(SPEC, 8, 1, 1.5, gpu=H100) is not None
    assert table.min_quota_for_slo(SPEC, 8, T4.sm_total, 1.5, gpu=T4) \
        is None


# ---------------------------------------------------------------- vgpu
def test_vgpu_respects_type_slice_count():
    g = VirtualGPU("G", gpu_type=T4)
    assert g.sm_total == 4 and g.slices_free == 4
    g.place(PodAlloc(fn_id="f", sm=4, quota=0.5, batch=1))
    assert g.slices_free == 0
    assert not g.can_place(2, 0.5)          # no free slices, no 2-wide part
    assert g.can_place(4, 0.4)              # joins the 4-wide partition
    with pytest.raises(RuntimeError):
        g.place(PodAlloc(fn_id="f", sm=2, quota=0.1, batch=1))
    assert g.invariant_ok()


def test_place_stamps_gpu_type():
    g = VirtualGPU("G", gpu_type=A10G)
    pod = PodAlloc(fn_id="f", sm=2, quota=0.5, batch=8)
    assert pod.gpu_type is None
    g.place(pod)
    assert pod.gpu_type is A10G


# ---------------------------------------------------------------- recon
def test_fleet_caps_and_type_order():
    recon = Reconfigurator(num_gpus=0, fleet=MIX)
    assert recon.is_heterogeneous
    assert [t.name for t in recon.available_gpu_types()] == \
        ["a10g", "a100", "t4"]
    for _ in range(4):
        assert recon.add_gpu().gpu_type.name == "a10g"
    assert recon.add_gpu().gpu_type.name == "a100"   # a10g pool exhausted
    assert recon.add_gpu("t4").gpu_type.name == "t4"
    with pytest.raises(RuntimeError):
        recon.add_gpu("a10g")
    # min_sm skips types too narrow for the pod
    assert recon.add_gpu(min_sm=8).gpu_type.name == "a100"
    recon.add_gpu(min_sm=1)   # t4 still open
    assert [t.name for t in recon.available_gpu_types()] == ["t4"]


def test_release_empty_gpus_restores_type_capacity():
    recon = Reconfigurator(num_gpus=0, fleet=(("a10g", 1),))
    g = recon.add_gpu()
    with pytest.raises(RuntimeError):
        recon.add_gpu()
    recon.release_empty_gpus()
    assert recon.type_count(A10G) == 0
    assert recon.add_gpu().gpu_type is A10G


def test_homogeneous_default_fleet_is_legacy():
    legacy = Reconfigurator(num_gpus=2, max_gpus=3)
    assert not legacy.is_heterogeneous
    assert legacy.fleet == ((DEFAULT_GPU_TYPE, 3),)
    assert sorted(legacy.gpus) == ["GPU-0000", "GPU-0001"]
    legacy.add_gpu()
    with pytest.raises(RuntimeError):
        legacy.add_gpu()


def test_fragmentation_metric():
    recon = Reconfigurator(num_gpus=0, fleet=MIX)
    assert recon.fragmentation() == 0.0     # empty cluster
    g = recon.add_gpu("a10g")
    recon.place_pod(PodAlloc(fn_id="f", sm=6, quota=1.0, batch=8), g.uuid)
    assert recon.fragmentation() == pytest.approx(2 / 8)


# ---------------------------------------------------------------- cost
def test_cost_meter_prices_by_type():
    recon = Reconfigurator(num_gpus=0, fleet=MIX)
    ga = recon.add_gpu("a10g")
    gt = recon.add_gpu("t4")
    recon.place_pod(PodAlloc(fn_id="f", sm=4, quota=0.5, batch=8), ga.uuid)
    recon.place_pod(PodAlloc(fn_id="f", sm=2, quota=1.0, batch=8), gt.uuid)
    usd_rate, frac = CostMeter().rates(recon)
    want = ((4 / 8) * 0.5 * A10G.price_per_hour
            + (2 / 4) * 1.0 * T4.price_per_hour) / 3600.0
    assert usd_rate == pytest.approx(want)
    assert frac == pytest.approx(0.25 + 0.5)
    # whole-GPU billing: one full chip of each type
    usd_whole, frac_whole = CostMeter(whole_gpu=True).rates(recon)
    assert usd_whole == pytest.approx(
        (A10G.price_per_hour + T4.price_per_hour) / 3600.0)
    assert frac_whole == 2.0


def test_deprecated_price_constant_warns():
    import importlib
    cost_mod = importlib.import_module("repro.core.cost")
    cost_mod._reset_deprecation_warnings()   # warning is once-per-process
    with pytest.warns(DeprecationWarning):
        value = cost_mod.GPU_PRICE_PER_HOUR
    assert value == DEFAULT_GPU_TYPE.price_per_hour


# ---------------------------------------------------------------- placer
def test_ffd_prefers_cheap_slo_capable_types():
    recon = Reconfigurator(num_gpus=0, fleet=MIX)
    placer = FleetPlacer(recon, CapacityTable(), slo_multiplier=2.0)
    pod = PodAlloc(fn_id="f", sm=8, quota=0.5, batch=8)
    host = placer.place_one(SPEC, pod)
    assert host.gpu_type.name == "a10g"     # cheapest type meeting the SLO


def test_ffd_packs_decreasing_and_fills_fragments():
    # a generous SLO isolates the pure packing behavior (a tight one
    # correctly overrides fragment reuse — narrow slivers of cheap
    # chips are slow; see test_ffd_prefers_cheap_slo_capable_types)
    recon = Reconfigurator(num_gpus=0, fleet=(("a10g", 2), ("a100", 2)))
    placer = FleetPlacer(recon, CapacityTable(), slo_multiplier=50.0)
    reqs = [(SPEC, PodAlloc(fn_id="f", sm=s, quota=1.0, batch=8))
            for s in (2, 6, 4, 4, 2, 6)]
    placed = placer.pack(reqs)
    assert all(g is not None for _, g in placed)
    # FFD order: widths descend
    widths = [p.sm for p, _ in placed]
    assert widths == sorted(widths, reverse=True)
    # 6+2, 6+2, 4+4 pack into exactly 3 chips with zero fragmentation
    assert len(recon.used_gpus()) == 3
    assert recon.fragmentation() == 0.0


def test_spot_overflow_lands_on_slo_violating_type():
    recon = Reconfigurator(num_gpus=0, fleet=(("t4", 2),))
    placer = FleetPlacer(recon, CapacityTable(), slo_multiplier=1.5)
    pod = PodAlloc(fn_id="f", sm=4, quota=1.0, batch=8)
    assert not placer.slo_ok(SPEC, pod, T4)
    host = placer.place_one(SPEC, pod)      # overflow rather than fail
    assert host is not None and host.gpu_type is T4
    strict = PodAlloc(fn_id="f", sm=4, quota=1.0, batch=8)
    assert placer.place_one(SPEC, strict,
                            allow_slo_overflow=False) is None


# -------------------------------------------------- placer FFD properties
# Property-based invariants of the first-fit-decreasing packer. The
# hypothesis versions explore the request/fleet space; the seeded
# versions below them always run (hypothesis is an optional dep).

def _pack_and_check(fleet, reqs, slo_multiplier=2.0):
    """Pack ``reqs`` = [(sm, quota)] into ``fleet``; return the placer,
    cluster, and pack result after asserting the universal invariants:
    no slice/quota overcommit anywhere and FFD (decreasing-sm) order."""
    recon = Reconfigurator(num_gpus=0, fleet=fleet)
    placer = FleetPlacer(recon, CapacityTable(),
                         slo_multiplier=slo_multiplier)
    pods = [(SPEC, PodAlloc(fn_id="f", sm=sm, quota=q, batch=8))
            for sm, q in reqs]
    placed = placer.pack(pods)
    for g in recon.gpus.values():
        assert g.invariant_ok()
        assert g.slices_used <= g.gpu_type.sm_total
    widths = [p.sm for p, _ in placed]
    assert widths == sorted(widths, reverse=True), "not FFD order"
    for pod, g in placed:
        if g is not None:
            assert pod in g.pods   # a reported host actually hosts it
    return placer, recon, placed


def _spot_last_ok(placer, placed):
    """Spot-last: a pod may only sit on an SLO-violating host if no
    SLO-capable type could have hosted a pod of its shape at all."""
    for pod, g in placed:
        if g is None or placer.slo_ok(SPEC, pod, g.gpu_type):
            continue
        capable = [t for t, _ in placer.recon.fleet
                   if t.sm_total >= pod.sm
                   and placer.slo_ok(SPEC, pod, t)]
        # every SLO-capable type was at cap (otherwise the placer
        # would have opened a fresh chip there before overflowing)
        for t in capable:
            cap = placer.recon._cap_of(t)
            assert cap is not None and placer.recon.type_count(t) >= cap, (
                f"pod {pod.pod_id} overflowed onto {g.gpu_type.name} while "
                f"SLO-capable {t.name} still had capacity")


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # optional dep; seeded versions still run
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    fleet_strategy = st.lists(
        st.tuples(st.sampled_from(["a10g", "a100", "h100", "t4", "v5e"]),
                  st.integers(1, 4)),
        min_size=1, max_size=4)
    reqs_strategy = st.lists(
        st.tuples(st.integers(1, 8),
                  st.sampled_from([0.2, 0.5, 0.8, 1.0])),
        min_size=1, max_size=16)

    @given(fleet=fleet_strategy, reqs=reqs_strategy)
    @settings(max_examples=60, deadline=None)
    def test_ffd_never_overcommits_slices(fleet, reqs):
        """Whatever the fleet and request mix, packing never violates
        the per-chip slice/quota conservation invariants and the pack
        order is decreasing-sm (FFD)."""
        _pack_and_check(fleet, reqs)

    @given(fleet=fleet_strategy, reqs=reqs_strategy)
    @settings(max_examples=60, deadline=None)
    def test_ffd_spot_types_are_last_resort(fleet, reqs):
        """SLO-violating (spot) hosts are only used once every
        SLO-capable type is exhausted."""
        placer, _, placed = _pack_and_check(fleet, reqs)
        _spot_last_ok(placer, placed)

    @given(reqs=reqs_strategy)
    @settings(max_examples=40, deadline=None)
    def test_ffd_prefers_cheapest_slo_capable_type(reqs):
        """On an uncapped all-SLO-capable two-type fleet with a huge
        multiplier, every fresh chip the packer opens is of the
        cheaper $/slice-hour class."""
        fleet = (("a100", None), ("a10g", None))
        recon = Reconfigurator(num_gpus=0, fleet=fleet)
        placer = FleetPlacer(recon, CapacityTable(), slo_multiplier=50.0)
        for sm, q in reqs:
            pod = PodAlloc(fn_id="f", sm=sm, quota=q, batch=8)
            g = placer.place_one(SPEC, pod)
            assert g is not None
            assert g.gpu_type is A10G   # strictly cheaper per slice

    @given(reqs=reqs_strategy)
    @settings(max_examples=40, deadline=None)
    def test_strict_placer_never_violates_slo(reqs):
        """With overflow disabled, every successful placement sits on
        an SLO-capable host — or fails outright."""
        recon = Reconfigurator(num_gpus=0, fleet=(("t4", 2), ("a100", 2)))
        placer = FleetPlacer(recon, CapacityTable(), slo_multiplier=1.5)
        for sm, q in reqs:
            pod = PodAlloc(fn_id="f", sm=sm, quota=q, batch=8)
            g = placer.place_one(SPEC, pod, allow_slo_overflow=False)
            if g is not None:
                assert placer.slo_ok(SPEC, pod, g.gpu_type)


def test_ffd_invariants_seeded():
    """Hypothesis-free sweep of the same FFD invariants on seeded
    random fleets/requests (runs even without the optional dep)."""
    rng = np.random.default_rng(11)
    names = ["a10g", "a100", "h100", "t4", "v5e"]
    for trial in range(25):
        fleet = tuple(
            (names[int(rng.integers(len(names)))], int(rng.integers(1, 5)))
            for _ in range(int(rng.integers(1, 4))))
        reqs = [(int(rng.integers(1, 9)),
                 float(rng.choice([0.2, 0.5, 0.8, 1.0])))
                for _ in range(int(rng.integers(1, 17)))]
        placer, _, placed = _pack_and_check(fleet, reqs)
        _spot_last_ok(placer, placed)


# ---------------------------------------------------------------- policy
def test_autoscaler_runs_on_mixed_fleet():
    from repro.core import AutoScalerConfig, HybridAutoScaler
    recon = Reconfigurator(num_gpus=0, fleet=MIX)
    scaler = HybridAutoScaler(recon, cfg=AutoScalerConfig(cooldown_s=0.0))
    scaler.prewarm(SPEC, 30.0)
    assert recon.pods_of(SPEC.fn_id)
    for now, r in ((1.0, 120.0), (2.0, 400.0), (30.0, 2.0), (60.0, 300.0)):
        scaler.scale(now, SPEC, r)
        assert recon.invariant_ok()
    types_used = {p.gpu_type.name for p in recon.pods_of(SPEC.fn_id)}
    assert types_used <= {n for n, _ in MIX}
    assert scaler.capacity(SPEC) > 0
