"""TokenLedger (scheduler.py) window-accounting properties.

The ledger is the observable contract of the paper's time-token
scheduler: per-window execution never exceeds the pod's quota, windows
only move forward, and a quota rewrite (vertical scaling) takes effect
at the next window boundary — the already-granted budget of the current
window is honored, never clawed back or topped up.

Deterministic exact-value tests always run; the randomized property
versions require hypothesis (optional dev dependency) and skip cleanly
without it.
"""
import pytest

from repro.core.scheduler import TokenLedger
from repro.core.vgpu import PodAlloc, VirtualGPU

WINDOW_MS = 100.0
W = WINDOW_MS / 1e3


def make_ledger(quota: float):
    g = VirtualGPU("G", window_ms=WINDOW_MS)
    pod = PodAlloc(fn_id="f", sm=8, quota=quota, batch=1)
    g.place(pod)
    return g, pod, TokenLedger(g)


# ---- deterministic exact-value semantics -----------------------------------

def test_acquire_spills_across_windows_exactly():
    """cost 0.15 s at quota 0.5 spends 0.05 s in each of three windows:
    finishes 0.05 s into window 2 -> t = 0.25 s."""
    _, pod, ledger = make_ledger(0.5)
    assert ledger.acquire(pod.pod_id, 0.15, 0.0) == pytest.approx(0.25)


def test_within_budget_acquire_completes_inline():
    _, pod, ledger = make_ledger(0.5)
    assert ledger.acquire(pod.pod_id, 0.04, 0.0) == pytest.approx(0.04)


def test_acquired_time_never_exceeds_quota_per_window():
    """Back-to-back acquires from t=0 (windows aligned to multiples of
    W): at any completion time t, at most floor(t/W)+1 windows have been
    touched and each grants at most quota * W — so cumulative work must
    satisfy C <= (floor(t/W)+1) * quota * W. The bound is tight (hit
    with equality) whenever a window's budget is fully consumed."""
    quota = 0.3
    _, pod, ledger = make_ledger(quota)
    t, total = 0.0, 0.0
    hit_equality = False
    for _ in range(20):
        t = ledger.acquire(pod.pod_id, 0.01, t)
        total += 0.01
        windows_touched = int((t - 1e-9) / W) + 1
        cap = windows_touched * quota * W
        assert total <= cap + 1e-9, (t, total, cap)
        hit_equality |= abs(total - cap) < 1e-9
    assert hit_equality, "bound never tight: test lost its teeth"


def test_windows_advance_monotonically():
    _, pod, ledger = make_ledger(0.4)
    t, starts = 0.0, []
    for i in range(15):
        t = ledger.acquire(pod.pod_id, 0.015 + 0.001 * (i % 3), t)
        starts.append(ledger._window_start[pod.pod_id])
    assert all(a <= b + 1e-12 for a, b in zip(starts, starts[1:])), starts


def test_quota_raise_takes_effect_next_window():
    """Exhaust window 0's budget at quota 0.2, raise to 0.8 mid-window:
    nothing more runs before the boundary (old budget is spent), and the
    next acquire runs under the NEW per-window budget from t=W."""
    g, pod, ledger = make_ledger(0.2)
    t = ledger.acquire(pod.pod_id, 0.02, 0.0)   # consumes q*W exactly
    assert t == pytest.approx(0.02)
    g.set_quota(pod.pod_id, 0.8)
    # 0.08 s fits entirely inside window 1's new budget: [0.1, 0.18)
    assert ledger.acquire(pod.pod_id, 0.08, t) == pytest.approx(0.18)


def test_quota_cut_honors_current_window_grant():
    """Lowering quota mid-window does not claw back the remaining budget
    already granted for this window; the cut binds from the next one."""
    g, pod, ledger = make_ledger(0.8)
    t = ledger.acquire(pod.pod_id, 0.01, 0.0)
    assert t == pytest.approx(0.01)
    g.set_quota(pod.pod_id, 0.1)
    # old window budget had 0.07 left -> runs to 0.08 inside window 0
    assert ledger.acquire(pod.pod_id, 0.07, t) == pytest.approx(0.08)
    # but the NEXT window only grants 0.1 * W = 0.01 per window
    t2 = ledger.acquire(pod.pod_id, 0.02, 0.08)
    assert t2 == pytest.approx(0.1 + W + 0.01)  # spills into window 2


# ---- randomized properties (hypothesis, optional) --------------------------
# guarded with try/except (not module-level importorskip) so the exact-
# value tests above always run even without the optional dependency

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @given(st.floats(0.1, 1.0), st.lists(st.floats(1e-4, 0.15),
                                         min_size=1, max_size=12))
    @settings(max_examples=80, deadline=None)
    def test_rate_bound_and_feasibility(quota, costs):
        """Granted time is rate-limited: finishing C seconds of work
        takes at least C / quota - W wall-clock, never less than C."""
        quota = round(quota, 2)
        _, pod, ledger = make_ledger(quota)
        t = 0.0
        for c in costs:
            t = ledger.acquire(pod.pod_id, c, t)
        total = sum(costs)
        assert t >= total / quota - W - 1e-9
        assert t >= total - 1e-9

    @given(st.floats(0.1, 1.0), st.lists(st.floats(1e-4, 0.1),
                                         min_size=2, max_size=10))
    @settings(max_examples=60, deadline=None)
    def test_windows_monotone_under_random_load(quota, costs):
        quota = round(quota, 2)
        _, pod, ledger = make_ledger(quota)
        t, prev = 0.0, -1.0
        for c in costs:
            t = ledger.acquire(pod.pod_id, c, t)
            ws = ledger._window_start[pod.pod_id]
            assert ws >= prev - 1e-12
            prev = ws

    @given(st.floats(0.1, 0.5), st.floats(0.5, 1.0))
    @settings(max_examples=40, deadline=None)
    def test_rewrite_never_applies_before_boundary(q_old, q_new):
        """However the quota is rewritten mid-window, total time granted
        inside the current window never exceeds the OLD budget."""
        q_old, q_new = round(q_old, 2), round(q_new, 2)
        g, pod, ledger = make_ledger(q_old)
        # burn the whole old budget, then raise
        t = ledger.acquire(pod.pod_id, q_old * W, 0.0)
        assert t == pytest.approx(q_old * W)
        g.set_quota(pod.pod_id, q_new)
        t2 = ledger.acquire(pod.pod_id, 1e-3, t)
        assert t2 >= W  # nothing more ran inside window 0


# ---- pod-churn state release (spot reclaims, scale-down) -------------------

def test_quota_of_unplaced_pod_raises_descriptive_keyerror():
    """A stale client (its pod removed — scale-down or spot reclaim)
    must fail loudly and readably, not with a bare StopIteration."""
    _, pod, ledger = make_ledger(0.5)
    with pytest.raises(KeyError, match="stale client"):
        ledger.quota_of("no-such-pod")
    ledger.vgpu.remove(pod.pod_id)
    with pytest.raises(KeyError, match=pod.pod_id):
        ledger.quota_of(pod.pod_id)


def test_release_is_idempotent_and_drops_window_state():
    _, pod, ledger = make_ledger(0.5)
    ledger.acquire(pod.pod_id, 0.01, 0.0)
    assert pod.pod_id in ledger._window_start
    ledger.release(pod.pod_id)
    assert pod.pod_id not in ledger._window_start
    assert pod.pod_id not in ledger._budget
    ledger.release(pod.pod_id)  # second release is a no-op
    ledger.release("never-placed")


def test_scheduler_releases_state_on_pod_removal():
    """Pod churn must not leak ledger/client state for the life of the
    chip: HASGPUScheduler hooks the vGPU remove listeners, so ANY
    removal path (scale-down, spot RECLAIM_KILL) releases both the
    window/budget entries and the client handle."""
    from repro.core.scheduler import HASGPUScheduler

    sched = HASGPUScheduler()
    g = VirtualGPU("G", window_ms=WINDOW_MS)
    for i in range(50):  # churn: place, run, remove, repeat
        pod = PodAlloc(fn_id="f", sm=8, quota=0.5, batch=1,
                       pod_id=f"pod-churn-{i}")
        g.place(pod)
        client = sched.client_for(g, pod.pod_id)
        client.ledger.acquire(pod.pod_id, 1e-4, float(i))
        g.remove(pod.pod_id)
    ledger = sched.ledgers["G"]
    assert not ledger._window_start and not ledger._budget
    assert not sched.clients
    assert len(sched.ledgers) == 1  # the chip's ledger itself persists
