"""Serving-path unit tests: batcher, libhas, gateway routing, and the
device-blind regressions (pods must be served/billed/routed at the
physics of the chip actually hosting them, not the reference device)."""
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.gpus import get_gpu_type
from repro.core.perf_model import FnSpec, exec_time
from repro.core.scheduler import HASGPUScheduler
from repro.core.vgpu import PodAlloc, VirtualGPU
from repro.serving import (Batcher, Gateway, InferenceRequest, LibHas,
                           MemoryBudgetExceeded, PodEngine)


def _req(n=4, arrival=None):
    kw = {} if arrival is None else {"arrival": arrival}
    return InferenceRequest(prompt=np.arange(1, n + 1, dtype=np.int32),
                            **kw)


# ---------------------------------------------------------------------------
# Batcher
# ---------------------------------------------------------------------------

def test_batcher_ready_semantics_with_injected_now():
    b = Batcher(max_batch=4, max_wait_s=0.5)
    assert not b.ready(now=123.0)          # empty queue is never ready
    b.submit(_req(arrival=100.0))
    assert not b.ready(now=100.1)          # under the wait deadline
    assert b.ready(now=100.5)              # deadline reached
    assert b.ready(now=900.0)
    for _ in range(3):
        b.submit(_req(arrival=100.0))
    assert b.ready(now=100.0)              # full batch: ready immediately
    assert len(b.next_batch()) == 4
    assert not b.ready(now=100.0)


def test_batcher_pad_prompts_left_pads_with_pad_id():
    reqs = [InferenceRequest(prompt=np.array([3, 4], np.int32)),
            InferenceRequest(prompt=np.array([5, 6, 7, 8], np.int32))]
    out = Batcher.pad_prompts(reqs, pad_id=7)
    np.testing.assert_array_equal(
        out, np.array([[7, 7, 3, 4], [5, 6, 7, 8]], np.int32))
    out6 = Batcher.pad_prompts(reqs, pad_id=9, pad_to=6)
    assert out6.shape == (2, 6)
    np.testing.assert_array_equal(out6[0], [9, 9, 9, 9, 3, 4])
    assert out6.dtype == np.int32


def test_batcher_pad_prompts_none_fits_longest_prompt():
    """pad_to=None (the default) must mean "fit the batch" explicitly,
    not fall through any numeric branch."""
    reqs = [InferenceRequest(prompt=np.array([1], np.int32)),
            InferenceRequest(prompt=np.array([2, 3, 4], np.int32))]
    out = Batcher.pad_prompts(reqs, pad_id=0, pad_to=None)
    assert out.shape == (2, 3)
    np.testing.assert_array_equal(out, [[0, 0, 1], [2, 3, 4]])


def test_batcher_pad_prompts_truncates_to_trailing_tokens():
    """A prompt longer than pad_to keeps its TRAILING pad_to tokens —
    with left padding, the tail is what sits next to the decode
    position. The old code raised a broadcast error here."""
    reqs = [InferenceRequest(prompt=np.arange(1, 7, dtype=np.int32)),
            InferenceRequest(prompt=np.array([9], np.int32))]
    out = Batcher.pad_prompts(reqs, pad_id=0, pad_to=4)
    np.testing.assert_array_equal(out, [[3, 4, 5, 6], [0, 0, 0, 9]])


@pytest.mark.parametrize("bad", [0, -3])
def test_batcher_pad_prompts_rejects_nonpositive_width(bad):
    reqs = [InferenceRequest(prompt=np.array([1, 2], np.int32))]
    with pytest.raises(ValueError, match="pad_to"):
        Batcher.pad_prompts(reqs, pad_to=bad)


def test_batcher_pad_prompts_rejects_empty_batch():
    with pytest.raises(ValueError, match="empty"):
        Batcher.pad_prompts([])


# ---------------------------------------------------------------------------
# LibHas
# ---------------------------------------------------------------------------

class _FakeClient:
    def __init__(self):
        self.costs = []

    def acquire(self, cost_s):
        self.costs.append(cost_s)


def test_libhas_token_accounting_and_estimator():
    client = _FakeClient()
    lib = LibHas(client=client)
    assert lib.launch(lambda x: x + 1, 1, cost_s=0.25) == 2
    assert lib.launches == 1
    assert lib.tokens_acquired_s == pytest.approx(0.25)
    assert client.costs == [0.25]
    # no cost and no estimator: dispatch without a token acquire
    lib.launch(lambda: 0)
    assert lib.launches == 2
    assert client.costs == [0.25]
    # estimator fills in the cost when the caller doesn't pass one
    est = LibHas(client=client, cost_estimator=lambda *a, **kw: 0.5)
    est.launch(lambda x: x, 3)
    assert est.tokens_acquired_s == pytest.approx(0.5)
    assert client.costs == [0.25, 0.5]


class _Compiled:
    def __init__(self, arg_bytes, temp_bytes, out_bytes=0):
        self._m = (arg_bytes, temp_bytes, out_bytes)

    def memory_analysis(self):
        import types
        return types.SimpleNamespace(argument_size_in_bytes=self._m[0],
                                     temp_size_in_bytes=self._m[1],
                                     output_size_in_bytes=self._m[2])


def test_libhas_memory_budget():
    lib = LibHas(client=_FakeClient(), hbm_budget_bytes=100)
    lib.check_memory(_Compiled(60, 30))    # 90 <= 100: fits
    with pytest.raises(MemoryBudgetExceeded):
        lib.check_memory(_Compiled(80, 30))
    # no budget configured: never inspects the compiled object
    LibHas(client=_FakeClient()).check_memory(object())


def test_libhas_memory_budget_counts_outputs():
    # regression: the footprint must include output buffers — a step
    # that fits only when outputs are ignored has to be rejected
    lib = LibHas(client=_FakeClient(), hbm_budget_bytes=100)
    lib.check_memory(_Compiled(50, 30, 20))   # 100 <= 100: fits exactly
    with pytest.raises(MemoryBudgetExceeded):
        lib.check_memory(_Compiled(50, 30, 21))  # args+temp fit, +out not


# ---------------------------------------------------------------------------
# Gateway routing (stub engines: routing only reads spec/pod/batcher)
# ---------------------------------------------------------------------------

class _StubEngine:
    def __init__(self, cfg, pod, max_seq=32):
        self.cfg = cfg
        self.pod = pod
        self.spec = FnSpec(cfg, seq=max_seq)
        self.batcher = Batcher(max_batch=pod.batch)

    def submit(self, req):
        self.batcher.submit(req)


def _placed_pod(gpu_name, sm=2, quota=0.5, batch=2, uid=""):
    g = VirtualGPU(f"GPU-route-{gpu_name}{uid}",
                   gpu_type=get_gpu_type(gpu_name))
    pod = PodAlloc(fn_id="f", sm=sm, quota=quota, batch=batch)
    g.place(pod)
    return pod


def test_gateway_least_backlog_routing():
    cfg = ARCHS["olmo-1b"]
    gw = Gateway()
    busy = _StubEngine(cfg, _placed_pod("v5e", uid="a"))
    idle = _StubEngine(cfg, _placed_pod("v5e", uid="b"))
    gw.register("f", busy)
    gw.register("f", idle)
    for _ in range(3):
        busy.submit(_req())
    assert gw.route("f", _req()) is idle
    assert len(idle.batcher.queue) == 1
    with pytest.raises(KeyError):
        gw.route("ghost", _req())


def test_gateway_routes_by_hosting_device_physics():
    """Regression: routing must score each pod at its OWN chip's
    throughput. At identical (batch, sm, quota) and equal backlog, the
    h100-hosted pod has the higher capability, so it must win even when
    the t4 pod registered first (device-blind scoring tied them and
    picked the t4)."""
    cfg = ARCHS["olmo-1b"]
    gw = Gateway()
    slow = _StubEngine(cfg, _placed_pod("t4"))
    fast = _StubEngine(cfg, _placed_pod("h100"))
    assert slow.pod.gpu_type.name == "t4"       # stamped at placement
    assert fast.pod.gpu_type.name == "h100"
    gw.register("f", slow)
    gw.register("f", fast)
    slow.submit(_req())
    fast.submit(_req())
    assert gw.route("f", _req()) is fast


def test_gateway_deregister_unknown_fn_is_a_noop():
    gw = Gateway()
    gw.deregister("ghost", "pod-x")
    assert gw.engines == {}                     # no empty entry created
    cfg = ARCHS["olmo-1b"]
    eng = _StubEngine(cfg, _placed_pod("v5e", uid="d"))
    gw.register("f", eng)
    gw.deregister("f", "not-this-pod")
    assert gw.engines["f"] == [eng]
    gw.deregister("f", eng.pod.pod_id)
    assert "f" not in gw.engines            # last pod gone: key pruned


# ---------------------------------------------------------------------------
# PodEngine device-blind regressions (real engines, reduced config)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def _olmo_reduced():
    import jax
    from repro import models
    cfg = reduced(ARCHS["olmo-1b"])
    return cfg, models.init_params(jax.random.PRNGKey(0), cfg)


def _engine_on(gpu_name, cfg, params, quota=1.0, **kw):
    gpu = get_gpu_type(gpu_name)
    vgpu = VirtualGPU(f"GPU-eng-{gpu_name}-{id(params) % 97}",
                      window_ms=20.0, gpu_type=gpu)
    pod = PodAlloc(fn_id="f", sm=2, quota=quota, batch=2)
    vgpu.place(pod)
    return PodEngine(cfg, pod, vgpu, HASGPUScheduler(), max_seq=32,
                     params=params, **kw)


def test_engine_cost_scales_with_hosting_chip(_olmo_reduced):
    """Regression: token costs must follow the hosting chip's physics —
    the same pod shape on a t4 owns more accelerator-seconds per
    dispatch than on an h100 (charging reference-device physics made
    them identical)."""
    cfg, params = _olmo_reduced
    e_t4 = _engine_on("t4", cfg, params)
    e_h100 = _engine_on("h100", cfg, params)
    c_t4, c_h100 = e_t4._cost(8), e_h100._cost(8)
    assert c_t4 > c_h100
    spec = FnSpec(cfg, seq=32)
    want = (exec_time(spec, 2, 2, get_gpu_type("t4"))
            / exec_time(spec, 2, 2, get_gpu_type("h100")))
    assert c_t4 / c_h100 == pytest.approx(want)


def test_engine_pad_id_round_trip(_olmo_reduced):
    """Regression: ``step`` must pad with the engine's configured
    ``pad_id`` (it used to silently pad with 0) and account every
    dispatch through libhas."""
    cfg, params = _olmo_reduced
    eng = _engine_on("v5e", cfg, params, pad_id=1)
    assert eng.batcher.pad_id == 1
    seen = {}
    orig = Batcher.pad_prompts

    def spy(reqs, pad_id=0, pad_to=None):
        seen["pad_id"] = pad_id
        return orig(reqs, pad_id=pad_id, pad_to=pad_to)

    eng.batcher.pad_prompts = spy
    rng = np.random.default_rng(0)
    for n in (5, 9):
        eng.submit(InferenceRequest(
            prompt=rng.integers(2, cfg.vocab_size, size=n).astype(np.int32),
            max_new_tokens=2))
    done = eng.step()
    assert seen["pad_id"] == 1
    assert len(done) == 2
    assert all(r.output is not None and len(r.output) == 2 for r in done)
    assert eng.libhas.launches == 1 + 2        # prefill + 2 decode steps
    assert eng.libhas.tokens_acquired_s > 0.0
